//! Figure 2 — RMAE(OT) versus subsample size s, comparing Nys-Sink,
//! Rand-Sink and Spar-Sink over C1-C3 × ε ∈ {1e-1,1e-2,1e-3} ×
//! d ∈ {5,10,20,50}, s = {2,4,8,16}·s₀(n).

use super::common::{exact_ot, ot_cost, rmae_over_reps, row, run_method_ot, Method};
use super::{ExperimentOutput, Profile};
use crate::data::synthetic::{instance, Scenario};
use crate::rng::Rng;
use crate::util::json::Json;
use crate::util::table::{f, Table};

/// Figure 2: RMAE(OT) vs subsample size s across the C1–C3 scenarios, ε and d sweeps.
pub fn run(profile: Profile) -> ExperimentOutput {
    let n = profile.pick(400, 1000);
    let reps = profile.reps(5, 100);
    let dims: &[usize] = profile.pick(&[5usize, 20][..], &[5, 10, 20, 50][..]);
    let epss = [1e-1, 1e-2, 1e-3];
    let s_mults = [2.0, 4.0, 8.0, 16.0];

    let mut table = Table::new(&[
        "scenario", "eps", "d", "method", "s/s0", "rmae", "se", "fail",
    ]);
    let mut rows = Vec::new();
    let mut rng = Rng::seed_from(0xF162);
    for scenario in Scenario::all() {
        for &eps in &epss {
            for &d in dims {
                let inst = instance(scenario, n, d, 1.0, 1.0, &mut rng);
                let cost = ot_cost(&inst.points);
                let Ok(truth) = exact_ot(&cost, &inst.a, &inst.b, eps) else {
                    table.row(vec![
                        scenario.name().into(),
                        format!("{eps:.0e}"),
                        d.to_string(),
                        "(exact failed)".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                    ]);
                    continue;
                };
                for method in Method::all() {
                    for &s_mult in &s_mults {
                        let (rmae, se, failures) = rmae_over_reps(
                            reps,
                            truth,
                            |r| run_method_ot(method, &cost, &inst.a, &inst.b, eps, s_mult, r),
                            &mut rng,
                        );
                        table.row(vec![
                            scenario.name().into(),
                            format!("{eps:.0e}"),
                            d.to_string(),
                            method.name().into(),
                            f(s_mult, 0),
                            f(rmae, 4),
                            f(se, 4),
                            failures.to_string(),
                        ]);
                        rows.push(row(vec![
                            ("scenario", Json::str(scenario.name())),
                            ("eps", Json::num(eps)),
                            ("d", Json::num(d as f64)),
                            ("method", Json::str(method.name())),
                            ("s_mult", Json::num(s_mult)),
                            ("rmae", Json::num(rmae)),
                            ("se", Json::num(se)),
                        ]));
                    }
                }
            }
        }
    }
    let text = format!(
        "Figure 2 — RMAE(OT) vs s  (n = {n}, {reps} reps/point)\n{}",
        table.render()
    );
    ExperimentOutput { id: "fig2", text, rows: Json::arr(rows) }
}
