//! Algorithm 2 — the unbalanced Sinkhorn algorithm (Chizat et al.,
//! 2018b): scaling updates raised to the power `ρ = λ/(λ+ε)`, which
//! relaxes the marginal constraints through KL penalties.

use super::sinkhorn::{sinkhorn_scalings, SinkhornParams};
use super::{objective, SinkhornSolution};
use crate::error::{Error, Result};
use crate::linalg::Mat;

/// Exponent `ρ = λ / (λ + ε)` of the unbalanced scaling update.
#[inline]
pub fn uot_rho(lambda: f64, eps: f64) -> f64 {
    lambda / (lambda + eps)
}

/// Run Algorithm 2 and evaluate the entropic UOT objective (Eq. 10).
///
/// * `a`, `b` — arbitrary positive measures (total masses may differ).
/// * `lambda` — marginal-relaxation weight; `λ → ∞` recovers Algorithm 1.
pub fn sinkhorn_uot(
    kernel: &Mat,
    cost: &Mat,
    a: &[f64],
    b: &[f64],
    lambda: f64,
    eps: f64,
    params: &SinkhornParams,
) -> Result<SinkhornSolution> {
    if lambda <= 0.0 || eps <= 0.0 {
        return Err(Error::InvalidParam(format!(
            "lambda ({lambda}) and eps ({eps}) must be positive"
        )));
    }
    let rho = uot_rho(lambda, eps);
    let (u, v, iterations, displacement, converged) =
        sinkhorn_scalings(kernel, a, b, rho, params)?;
    let objective =
        objective::uot_objective_dense(kernel, cost, a, b, &u, &v, lambda, eps);
    if !objective.is_finite() {
        return Err(Error::Numerical(format!(
            "UOT objective is not finite (lambda={lambda}, eps={eps})"
        )));
    }
    Ok(SinkhornSolution { u, v, objective, iterations, displacement, converged })
}

/// The Wasserstein–Fisher–Rao distance `WFR_λ = UOT^{1/2}` (Section 2.2),
/// computed from an already-evaluated UOT objective. Clamps tiny negative
/// values caused by entropic bias.
#[inline]
pub fn wfr_distance_from_objective(uot_objective: f64) -> f64 {
    uot_objective.max(0.0).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::cost::{gibbs_kernel, sq_euclidean_cost, wfr_cost};
    use crate::ot::objective::plan_marginals_dense;
    use crate::ot::sinkhorn::sinkhorn_ot;

    fn measures(n: usize, mass_a: f64, mass_b: f64) -> (Vec<f64>, Vec<f64>, Vec<Vec<f64>>) {
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|i| vec![(i as f64 * 0.618).fract(), (i as f64 * 0.383).fract()])
            .collect();
        let raw_a: Vec<f64> = (0..n).map(|i| 1.0 + (i % 5) as f64).collect();
        let raw_b: Vec<f64> = (0..n).map(|i| 1.5 + ((i + 2) % 3) as f64).collect();
        let sa: f64 = raw_a.iter().sum();
        let sb: f64 = raw_b.iter().sum();
        (
            raw_a.iter().map(|x| x / sa * mass_a).collect(),
            raw_b.iter().map(|x| x / sb * mass_b).collect(),
            pts,
        )
    }

    #[test]
    fn handles_unbalanced_masses() {
        // Paper setting: total masses 5 and 3, eps = lambda = 0.1.
        let (a, b, pts) = measures(24, 5.0, 3.0);
        let cost = sq_euclidean_cost(&pts, &pts);
        let kernel = gibbs_kernel(&cost, 0.1);
        let sol =
            sinkhorn_uot(&kernel, &cost, &a, &b, 0.1, 0.1, &SinkhornParams::default()).unwrap();
        assert!(sol.converged);
        assert!(sol.objective.is_finite());
        // The plan carries positive, finite mass. (With eps comparable to
        // lambda the entropy term spreads mass over the n^2 support, so
        // the total can exceed the input masses — that is the correct
        // entropic-UOT behaviour, not a bug.)
        let (row, _) = plan_marginals_dense(&kernel, &sol.u, &sol.v);
        let mass: f64 = row.iter().sum();
        assert!(mass > 0.0 && mass.is_finite(), "plan mass {mass}");
    }

    #[test]
    fn degenerates_to_ot_for_large_lambda() {
        // Section 2.2: lambda -> inf recovers Algorithm 1 on balanced input.
        let (a, b, pts) = measures(16, 1.0, 1.0);
        let cost = sq_euclidean_cost(&pts, &pts);
        let eps = 0.1;
        let kernel = gibbs_kernel(&cost, eps);
        let params = SinkhornParams { delta: 1e-10, max_iters: 5000, strict: false };
        let uot =
            sinkhorn_uot(&kernel, &cost, &a, &b, 1e7, eps, &params).unwrap();
        let ot = sinkhorn_ot(&kernel, &cost, &a, &b, eps, &params).unwrap();
        assert!(
            (uot.objective - ot.objective).abs() < 1e-3,
            "uot {} vs ot {}",
            uot.objective,
            ot.objective
        );
    }

    #[test]
    fn large_lambda_mass_approaches_geometric_compromise() {
        // For mismatched masses m_a, m_b and lambda >> eps, the optimal
        // plan mass approaches sqrt(m_a * m_b) (the KL-balanced
        // compromise); for lambda << eps the entropy term dominates and
        // the plan mass blows up past the inputs.
        let (a, b, pts) = measures(20, 2.0, 1.0);
        let cost = sq_euclidean_cost(&pts, &pts);
        let kernel = gibbs_kernel(&cost, 0.1);
        let params = SinkhornParams { delta: 1e-9, max_iters: 5000, strict: false };
        let mass_for = |lam: f64| {
            let sol = sinkhorn_uot(&kernel, &cost, &a, &b, lam, 0.1, &params).unwrap();
            let (row, _) = plan_marginals_dense(&kernel, &sol.u, &sol.v);
            row.iter().sum::<f64>()
        };
        let small = mass_for(0.05);
        let large = mass_for(20.0);
        let geo = (2.0f64 * 1.0).sqrt();
        assert!(small > large, "small-lambda mass {small} vs large {large}");
        assert!((large - geo).abs() < 0.25, "mass {large} vs geometric {geo}");
    }

    #[test]
    fn wfr_kernel_workflow_converges() {
        // Sparse WFR kernel (small eta blocks long-range transport).
        let (a, b, pts) = measures(24, 5.0, 3.0);
        let cost = wfr_cost(&pts, &pts, 0.15);
        let kernel = cost.map(|c| if c.is_infinite() { 0.0 } else { (-c / 0.1).exp() });
        let sol =
            sinkhorn_uot(&kernel, &cost, &a, &b, 1.0, 0.1, &SinkhornParams::default()).unwrap();
        assert!(sol.objective.is_finite());
        let wfr = wfr_distance_from_objective(sol.objective);
        assert!(wfr >= 0.0);
    }

    #[test]
    fn rejects_nonpositive_params() {
        let (a, b, pts) = measures(8, 1.0, 1.0);
        let cost = sq_euclidean_cost(&pts, &pts);
        let kernel = gibbs_kernel(&cost, 0.1);
        assert!(sinkhorn_uot(&kernel, &cost, &a, &b, 0.0, 0.1, &SinkhornParams::default()).is_err());
        assert!(sinkhorn_uot(&kernel, &cost, &a, &b, 1.0, -0.1, &SinkhornParams::default()).is_err());
    }

    #[test]
    fn rho_limits() {
        assert!((uot_rho(1e12, 0.1) - 1.0).abs() < 1e-10);
        assert!(uot_rho(0.1, 0.1) < 1.0);
    }
}
