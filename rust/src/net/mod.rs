//! Serve-mode HTTP front ends for the coordinator: the single-process
//! gateway (`repro serve --port`) and the multi-process
//! fingerprint-affine balancer (`repro balance --backends`).
//!
//! A zero-dependency HTTP/1.1 gateway over the batched
//! [`DistanceService`](crate::coordinator::DistanceService): clients
//! `POST /solve` and `POST /barycenter` JSON jobs, scrape Prometheus
//! text from `GET /metrics`, and probe `GET /healthz`. The layering is
//! deliberately boring —
//!
//! ```text
//!              clients / loadgen replay            [loadgen]
//!                       │
//!   Balancer ── affinity route + health probes + retry/failover
//!        │          [balancer]  ⇄  HTTP client leg  [client]
//!        ▼ (× N backends, same wire protocol either way)
//!   TcpListener ── accept loop (bounded, non-blocking poll)
//!        │               [gateway]
//!   per-connection thread: parse → route → respond, keep-alive loop
//!        │        [http]      [router]     [response]
//!   JSON body ⇄ DistanceJob / BarycenterJob          [codec]
//!        │
//!   DistanceService::try_submit  →  429 when the queue is full
//! ```
//!
//! — so each layer is testable without the ones below it: the parser
//! hardening corpus runs on byte slices, the router tests on an
//! in-process service, and only `tests/gateway_integration.rs` /
//! `tests/balancer_integration.rs` open real sockets. The balancer
//! speaks the gateway's own protocol on both legs, so clients cannot
//! tell one process from N, and relays job bodies verbatim, so the
//! gateway's bitwise-transparency contract extends through it.
//!
//! Two properties carry the module's weight:
//!
//! * **Admission control over backpressure.** Every path that could
//!   block on a saturated system instead answers a status code: full
//!   coordinator queue → `429`, connection cap → `503`, draining →
//!   `503`, oversized request → `413`/`431`. The accept loop never
//!   parks behind the solver.
//! * **Bitwise transparency.** A job round-tripped through the wire
//!   codec solves to bit-identical results as an in-process submission
//!   (floats survive JSON via shortest-round-trip formatting), so
//!   putting the gateway in front of the coordinator cannot change any
//!   reproduced number. Pinned by the loopback-parity test wall.
//!
//! Unlike the solver layers ([`crate::ot`], [`crate::engine`], …), this
//! module is free to read wall clocks (timeouts, polls) — the
//! contract-lint wall-clock rule deliberately stops at the serving
//! boundary (see [`crate::lint`]).

pub mod balancer;
pub mod client;
pub mod codec;
pub mod gateway;
pub mod http;
pub mod loadgen;
pub mod response;
pub mod router;

pub use balancer::{Balancer, BalancerConfig};
pub use gateway::{Gateway, GatewayConfig};
pub use http::{HttpLimits, ParseError, Request};
pub use loadgen::{LoadReport, LoadgenConfig};
pub use response::Response;
