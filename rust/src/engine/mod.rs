//! # The shared-cost artifact engine
//!
//! Batched workloads — the echocardiogram pairwise-distance matrix
//! above all — solve many transport problems whose marginals differ but
//! whose geometry (support × η × ε × formulation) is identical. Cold,
//! every job re-derives the WFR cost oracle, the Gibbs kernel, and the
//! cost-dependent part of its sampling probabilities from scratch; with
//! this engine the cost-dependent work is materialized once as
//! [`CostArtifacts`] behind a content-addressed [`ArtifactCache`]
//! (fingerprint = support hash × η × ε × formulation, byte-budget LRU,
//! hit/miss/eviction counters) and every later job is "reuse +
//! reweight": only the per-job marginal factor is recomputed.
//!
//! The flow through the stack:
//!
//! ```text
//!   supports (η, ε, formulation)
//!        │ Fingerprint::for_supports / ::for_dense
//!        ▼
//!   ArtifactCache::get_or_build ──▶ CostArtifacts
//!        │                           cost, kernel, row/col sums,
//!        │                           ‖K‖_F, β·ln K (UOT factor)
//!        ▼
//!   CostSource::Shared(CostHandle)          (api layer)
//!        ▼
//!   samplers consume the amortized factor   (sparse layer)
//!        ▼
//!   api::solve_batch / coordinator workers  (serving layer)
//! ```
//!
//! ## Cache semantics
//!
//! * **Content addressing.** A [`Fingerprint`] hashes the support pair
//!   (or the dense cost's contents) with two independent 64-bit streams
//!   and combines them with the dimensions, `η` (WFR truncation), `ε`,
//!   and the [`FormulationKey`] (λ bit-exact for unbalanced problems).
//!   Equal fingerprints ⇒ bitwise-identical artifacts; a single-ULP
//!   perturbation of any coordinate changes the fingerprint.
//! * **Single-flight builds.** [`ArtifactCache::get_or_build`] builds
//!   each fingerprint exactly once, OUTSIDE the map lock: concurrent
//!   misses on the same fingerprint block on that fingerprint's slot
//!   and share the published `Arc` (counted as hits), while misses on
//!   other fingerprints build in parallel — a long kernel build at one
//!   ε never stalls a many-ε sweep. A build that panics clears its slot
//!   so the next caller retries.
//! * **Eviction.** A byte-budget LRU, accounted at publish time:
//!   resident bytes never exceed the budget, a building slot is never
//!   evicted, and an artifact larger than the whole budget is served to
//!   its callers but never retained. [`global_cache`] (behind
//!   [`solve_batch`](crate::api::solve_batch) and the CLI) reads its
//!   budget from the `SPAR_SINK_CACHE_BYTES` env var, defaulting to
//!   [`DEFAULT_CACHE_BYTES`].
//!
//! Warm solves are bitwise-identical to cold solves: the artifacts
//! store exactly the values the entry oracles would have produced, and
//! the factored samplers compose probabilities with the same arithmetic
//! (pinned by `rust/tests/cache_parity.rs`; the single-flight contract
//! by `rust/tests/cache_concurrency.rs`).
//!
//! ```
//! use spar_sink::engine::{ArtifactCache, CostArtifacts, Fingerprint, FormulationKey};
//!
//! let pts: Vec<Vec<f64>> = (0..16).map(|i| vec![i as f64 * 0.25]).collect();
//! let (eps, key) = (0.1, FormulationKey::Balanced);
//! let fingerprint = Fingerprint::for_supports(&pts, &pts, None, eps, key);
//!
//! let cache = ArtifactCache::new(64 << 20);
//! // First lookup builds (a miss)…
//! let warm = cache.get_or_build(fingerprint, || {
//!     CostArtifacts::for_sq_euclidean_support(&pts, eps, key)
//! });
//! // …every later lookup shares the resident artifacts (a hit).
//! let hit = cache.get_or_build(fingerprint, || unreachable!("built above"));
//! assert!(std::sync::Arc::ptr_eq(&warm.share(), &hit.share()));
//!
//! let stats = cache.stats();
//! assert_eq!((stats.hits, stats.misses, stats.entries), (1, 1, 1));
//! assert!(stats.bytes <= stats.byte_budget);
//! println!("artifact cache: {}", stats.render());
//! ```

mod artifacts;
mod cache;

pub use artifacts::{
    CostArtifacts, CostHandle, Fingerprint, FormulationKey, UotLogFactor,
    SHARED_ARTIFACT_ENTRY_CAP,
};
pub use cache::{global_cache, ArtifactCache, CacheStats, DEFAULT_CACHE_BYTES};
