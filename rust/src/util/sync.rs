//! Poison-recovering synchronization helpers shared by the coordinator
//! shards, the worker pool, and the artifact cache.
//!
//! ## Why recovering a poisoned lock is sound here
//!
//! `std`'s mutex poisoning exists to stop a thread from observing state
//! that a panicking critical section left half-mutated. Every mutex
//! that goes through these helpers holds state whose critical sections
//! are panic-free by construction: queue push/pop, counter bumps, and
//! map slot insert/remove — never user code, never a solver, never a
//! kernel build (the artifact cache runs builds OUTSIDE its map lock by
//! design). A poisoned flag therefore never indicates a broken
//! invariant; it only records that some OTHER thread panicked while it
//! happened to hold the guard (e.g. an assert in a test worker). Before
//! these helpers, that one panic cascaded: every subsequent
//! `.lock().unwrap()` — including ones running inside `Drop` during
//! unwinding — double-panicked with a confusing `PoisonError`, aborting
//! the process and burying the original failure. Recovering the guard
//! keeps the first panic the only panic.
//!
//! The contract-lint rule `lock-unwrap` (see [`crate::lint`]) rejects
//! bare `.lock().unwrap()` in the coordinator/pool/engine worker paths
//! so new call sites go through here.

use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// Lock `mutex`, recovering the guard if a previous holder panicked.
///
/// See the module docs for why recovery is sound for the state guarded
/// by this crate's mutexes.
pub fn lock_unpoisoned<T>(mutex: &Mutex<T>) -> MutexGuard<'_, T> {
    mutex.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Block on `cond` with `guard`, recovering the reacquired guard if the
/// mutex was poisoned while this thread was parked.
pub fn wait_unpoisoned<'a, T>(cond: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cond.wait(guard).unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Block on `cond` for at most `timeout`, recovering the reacquired
/// guard if the mutex was poisoned while this thread was parked. The
/// timed-out flag is intentionally dropped: every caller re-checks its
/// predicate after waking regardless of why it woke.
pub fn wait_timeout_unpoisoned<'a, T>(
    cond: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: Duration,
) -> MutexGuard<'a, T> {
    match cond.wait_timeout(guard, timeout) {
        Ok((guard, _timed_out)) => guard,
        Err(poisoned) => poisoned.into_inner().0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_unpoisoned_recovers_after_a_panicking_holder() {
        let shared = Arc::new(Mutex::new(vec![1u32]));
        let panicker = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || {
                let _guard = lock_unpoisoned(&shared);
                panic!("poison the lock");
            })
        };
        assert!(panicker.join().is_err());
        assert!(shared.lock().is_err(), "the mutex must actually be poisoned");
        // A bare `.lock().unwrap()` would double-panic here; the helper
        // hands back the (structurally intact) state.
        let mut guard = lock_unpoisoned(&shared);
        guard.push(2);
        assert_eq!(*guard, vec![1, 2]);
    }

    #[test]
    fn wait_timeout_unpoisoned_times_out_normally() {
        let mutex = Mutex::new(0u32);
        let cond = Condvar::new();
        let guard = lock_unpoisoned(&mutex);
        let guard = wait_timeout_unpoisoned(&cond, guard, Duration::from_millis(5));
        assert_eq!(*guard, 0);
    }
}
