//! The shared sparse Sinkhorn scaling loop and sparse objectives: runs
//! Algorithms 1/2 over a CSR sketch in O(nnz) per iteration and
//! evaluates the entropic objectives over sampled entries only.

use crate::error::{Error, Result};
use crate::linalg::l1_diff;
use crate::ot::objective::kl_divergence;
use crate::ot::sinkhorn::{safe_div, SinkhornParams};

/// Division for the sparse loop: a row/column absent from the sketch
/// (denominator exactly 0) can never receive transport, so its scaling
/// is 0 — NOT the huge `safe_div` fallback, which would keep the
/// stopping statistic from ever settling (Theorem 3's iteration bound
/// relies on this convention).
#[inline(always)]
fn sketch_div(num: f64, den: f64) -> f64 {
    if den == 0.0 {
        0.0
    } else {
        safe_div(num, den)
    }
}
use crate::ot::SinkhornSolution;
use crate::sparse::CsrMatrix;

/// Sparse scaling loop; `rho = 1` is OT, `rho = λ/(λ+ε)` is UOT.
pub fn sparse_scalings(
    sketch: &CsrMatrix,
    a: &[f64],
    b: &[f64],
    rho: f64,
    params: &SinkhornParams,
) -> Result<(Vec<f64>, Vec<f64>, usize, f64, bool)> {
    if sketch.rows() != a.len() || sketch.cols() != b.len() {
        return Err(Error::Dimension(format!(
            "sketch {}x{} vs a[{}], b[{}]",
            sketch.rows(),
            sketch.cols(),
            a.len(),
            b.len()
        )));
    }
    let n = a.len();
    let m = b.len();
    let mut u = vec![1.0; n];
    let mut v = vec![1.0; m];
    let mut u_prev = vec![1.0; n];
    let mut v_prev = vec![1.0; m];
    let mut displacement = f64::INFINITY;
    let mut iters = 0;
    // `rho == 1.0` (balanced OT) is loop-invariant: hoist the branch so
    // the fused update closures stay branch-free on the hot path.
    let unbalanced = rho != 1.0;
    while iters < params.max_iters {
        iters += 1;
        u_prev.copy_from_slice(&u);
        v_prev.copy_from_slice(&v);
        // Fused matvec + elementwise divide: one pass over the CSR
        // arrays per half-update, no per-iteration allocation, values
        // bitwise-identical to the unfused matvec-then-divide sequence.
        sketch.matvec_map_into(&v, &mut u, |i, kv| {
            let val = sketch_div(a[i], kv);
            if unbalanced {
                val.powf(rho)
            } else {
                val
            }
        });
        sketch.matvec_t_map_into(&u, &mut v, |j, ktu| {
            let val = sketch_div(b[j], ktu);
            if unbalanced {
                val.powf(rho)
            } else {
                val
            }
        });
        if u.iter().chain(v.iter()).any(|x| !x.is_finite()) {
            return Err(Error::Numerical(format!(
                "sparse scalings diverged at iteration {iters}"
            )));
        }
        displacement = l1_diff(&u, &u_prev) + l1_diff(&v, &v_prev);
        if displacement <= params.delta {
            return Ok((u, v, iters, displacement, true));
        }
    }
    if params.strict {
        return Err(Error::NotConverged { iters, err: displacement });
    }
    Ok((u, v, iters, displacement, false))
}

/// Entropic OT objective over the sparse plan `T̃ = diag(u) K̃ diag(v)`
/// (Algorithm 3 step 4): only the sampled entries contribute.
pub fn sparse_ot_objective(sketch: &CsrMatrix, u: &[f64], v: &[f64], eps: f64) -> f64 {
    let mut transport = 0.0;
    let mut entropy = 0.0;
    for (i, j, k, c) in sketch.iter() {
        let t = u[i] * k * v[j];
        if t > 0.0 {
            transport += t * c;
            entropy -= t * (t.ln() - 1.0);
        }
    }
    transport - eps * entropy
}

/// Row/column marginals of the sparse plan.
pub fn sparse_plan_marginals(sketch: &CsrMatrix, u: &[f64], v: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let mut row = vec![0.0; sketch.rows()];
    let mut col = vec![0.0; sketch.cols()];
    for (i, j, k, _) in sketch.iter() {
        let t = u[i] * k * v[j];
        row[i] += t;
        col[j] += t;
    }
    (row, col)
}

/// Entropic UOT objective (Eq. 10, Algorithm 4 step 4) over the sparse
/// plan.
pub fn sparse_uot_objective(
    sketch: &CsrMatrix,
    a: &[f64],
    b: &[f64],
    u: &[f64],
    v: &[f64],
    lambda: f64,
    eps: f64,
) -> f64 {
    let base = sparse_ot_objective(sketch, u, v, eps);
    let (row, col) = sparse_plan_marginals(sketch, u, v);
    base + lambda * kl_divergence(&row, a) + lambda * kl_divergence(&col, b)
}

/// Assemble a [`SinkhornSolution`] from sparse loop outputs.
pub fn solution(
    u: Vec<f64>,
    v: Vec<f64>,
    objective: f64,
    iterations: usize,
    displacement: f64,
    converged: bool,
) -> Result<SinkhornSolution> {
    if !objective.is_finite() {
        return Err(Error::Numerical("sparse objective is not finite".into()));
    }
    Ok(SinkhornSolution { u, v, objective, iterations, displacement, converged })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::ot::cost::{gibbs_kernel, sq_euclidean_cost};
    use crate::ot::objective::ot_objective_dense;
    use crate::ot::sinkhorn::sinkhorn_scalings;
    use crate::sparse::csr::CsrMatrix as Csr;

    /// CSR holding the FULL kernel: the sparse loop must then agree with
    /// the dense loop exactly.
    fn full_csr(kernel: &Mat, cost: &Mat) -> Csr {
        let rows = (0..kernel.rows())
            .map(|i| {
                (0..kernel.cols())
                    .map(|j| (j as u32, kernel.get(i, j), cost.get(i, j)))
                    .collect()
            })
            .collect();
        Csr::from_rows(kernel.rows(), kernel.cols(), rows)
    }

    fn toy(n: usize, eps: f64) -> (Mat, Mat, Vec<f64>, Vec<f64>) {
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|i| vec![(i as f64 * 0.618).fract(), (i as f64 * 0.383).fract()])
            .collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        let kernel = gibbs_kernel(&cost, eps);
        let a = vec![1.0 / n as f64; n];
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 2) as f64).collect();
        let sb: f64 = b.iter().sum();
        (kernel, cost, a, b.iter().map(|x| x / sb).collect())
    }

    #[test]
    fn sparse_loop_matches_dense_on_full_kernel() {
        let (kernel, cost, a, b) = toy(24, 0.1);
        let sk = full_csr(&kernel, &cost);
        let params = SinkhornParams::default();
        let (u1, v1, i1, _, c1) = sparse_scalings(&sk, &a, &b, 1.0, &params).unwrap();
        let (u2, v2, i2, _, c2) = sinkhorn_scalings(&kernel, &a, &b, 1.0, &params).unwrap();
        assert_eq!(i1, i2);
        assert_eq!(c1, c2);
        for (x, y) in u1.iter().zip(&u2) {
            assert!((x - y).abs() < 1e-10);
        }
        for (x, y) in v1.iter().zip(&v2) {
            assert!((x - y).abs() < 1e-10);
        }
        let o1 = sparse_ot_objective(&sk, &u1, &v1, 0.1);
        let o2 = ot_objective_dense(&kernel, &cost, &u2, &v2, 0.1);
        assert!((o1 - o2).abs() < 1e-10);
    }

    #[test]
    fn sparse_uot_objective_matches_dense_on_full_kernel() {
        let (kernel, cost, a, b) = toy(16, 0.1);
        let sk = full_csr(&kernel, &cost);
        let params = SinkhornParams::default();
        let rho = 1.0 / (1.0 + 0.1);
        let (u, v, ..) = sparse_scalings(&sk, &a, &b, rho, &params).unwrap();
        let o1 = sparse_uot_objective(&sk, &a, &b, &u, &v, 1.0, 0.1);
        let o2 = crate::ot::objective::uot_objective_dense(&kernel, &cost, &a, &b, &u, &v, 1.0, 0.1);
        assert!((o1 - o2).abs() < 1e-10, "{o1} vs {o2}");
    }

    #[test]
    fn empty_sketch_rows_do_not_crash() {
        let sk = Csr::from_rows(3, 3, vec![vec![(0, 1.0, 0.0)], vec![], vec![(2, 1.0, 0.0)]]);
        let a = [0.4, 0.2, 0.4];
        let b = [0.4, 0.2, 0.4];
        let params = SinkhornParams { delta: 1e-8, max_iters: 50, strict: false };
        let (u, v, ..) = sparse_scalings(&sk, &a, &b, 1.0, &params).unwrap();
        assert!(u.iter().all(|x| x.is_finite()));
        assert!(v.iter().all(|x| x.is_finite()));
    }
}
