//! `repro` — the Spar-Sink reproduction driver (L3 leader entrypoint).
//!
//! Subcommands (see `repro --help`): `experiment` regenerates any paper
//! figure/table, `solve` runs a one-off synthetic problem, `serve`
//! exercises the batched WFR distance coordinator (or its HTTP gateway
//! with `--port`), `balance` fronts N gateways with the
//! fingerprint-affine load balancer, `bench coordinator` measures the
//! sharded service (1 vs N shards, cold vs warm cache) and writes
//! `BENCH_coordinator.json`, `bench gateway` replays the serving
//! workload over HTTP and writes `BENCH_gateway.json`, `runtime-info`
//! inspects the PJRT artifact menu.

use spar_sink::cli::{usage, Args};
use spar_sink::experiments::{self, Profile};

const VALUE_KEYS: &[&str] = &[
    "out", "n", "eps", "lambda", "method", "seed", "videos", "frames", "workers", "problem", "s",
    "d", "backend", "threshold", "shards", "size", "root", "config", "port", "addr", "duration",
    "backends", "jobs", "clients",
];

fn main() {
    let args = match Args::parse(std::env::args().skip(1), VALUE_KEYS) {
        Ok(args) => args,
        Err(e) => {
            eprintln!("{e}\n\n{}", usage());
            std::process::exit(2);
        }
    };
    let code = match args.command.as_deref() {
        Some("experiment") => cmd_experiment(&args),
        Some("solve") => cmd_solve(&args),
        Some("serve") => cmd_serve(&args),
        Some("balance") => cmd_balance(&args),
        Some("bench") => cmd_bench(&args),
        Some("lint") => cmd_lint(&args),
        Some("runtime-info") => cmd_runtime_info(),
        Some("list") => {
            for (id, desc, _) in experiments::registry() {
                println!("{id:<10} {desc}");
            }
            0
        }
        Some("help") | None => {
            println!("{}", usage());
            0
        }
        Some(other) => {
            eprintln!("unknown command '{other}'\n\n{}", usage());
            2
        }
    };
    std::process::exit(code);
}

fn cmd_experiment(args: &Args) -> i32 {
    let Some(id) = args.positional.first() else {
        eprintln!("experiment requires an id (or 'all'); see `repro list`");
        return 2;
    };
    let profile = if args.flag("full") { Profile::Full } else { Profile::Quick };
    match experiments::run(id, profile) {
        Ok(outputs) => {
            for out in outputs {
                println!("{}", out.text);
                if let Some(dir) = args.get("out") {
                    let _ = std::fs::create_dir_all(dir);
                    let path = format!("{dir}/{}.json", out.id);
                    if let Err(e) = std::fs::write(&path, out.rows.to_string_compact()) {
                        eprintln!("warning: could not write {path}: {e}");
                    } else {
                        println!("[rows written to {path}]");
                    }
                }
            }
            0
        }
        Err(e) => {
            eprintln!("{e}");
            2
        }
    }
}

fn cmd_solve(args: &Args) -> i32 {
    use spar_sink::api::{self, parse_backend, Method, OtProblem, SolverSpec};
    use spar_sink::data::synthetic::{instance, Scenario};
    use spar_sink::experiments::common::{ot_cost, wfr_cost_at_density};
    use spar_sink::rng::Rng;

    use spar_sink::data::synthetic::barycenter_measures;
    use spar_sink::metrics::{l1_distance, normalized_histogram};

    let n: usize = args.get_parsed("n", 500);
    let eps: f64 = args.get_parsed("eps", 0.05);
    let lambda: f64 = args.get_parsed("lambda", 1.0);
    let d: usize = args.get_parsed("d", 5);
    let s_mult: f64 = args.get_parsed("s", 8.0);
    let seed: u64 = args.get_parsed("seed", 42);
    let problem_kind = args.get("problem").unwrap_or("ot").to_string();
    // Barycenter problems default to the barycenter-capable sparsified
    // method; OT/UOT keep spar-sink.
    let default_method = if problem_kind == "barycenter" { "spar-ibp" } else { "spar-sink" };
    let method_name = args.get("method").unwrap_or(default_method);
    let Some(method) = Method::parse(method_name) else {
        eprintln!("unknown method '{method_name}'; available: {}", method_names());
        return 2;
    };

    // One synthetic problem, two specs, one dispatch surface: the exact
    // reference and the requested method both go through `api::solve`.
    let mut rng = Rng::seed_from(seed);
    let problem = match problem_kind.as_str() {
        "uot" => {
            let inst = instance(Scenario::C1, n, d, 5.0, 3.0, &mut rng);
            let cost = wfr_cost_at_density(&inst.points, 0.5);
            OtProblem::unbalanced(&cost, inst.a, inst.b, lambda, eps)
        }
        "barycenter" => {
            // The paper's 1-D barycenter setting: three synthetic
            // measures on a shared grid (Appendix A / C.3).
            let pts: Vec<Vec<f64>> =
                (0..n).map(|i| vec![i as f64 / (n.max(2) - 1) as f64]).collect();
            let bs = barycenter_measures(n, &mut rng);
            OtProblem::barycenter(ot_cost(&pts), bs, vec![1.0 / 3.0; 3], eps)
        }
        _ => {
            let inst = instance(Scenario::C1, n, d, 1.0, 1.0, &mut rng);
            let cost = ot_cost(&inst.points);
            OtProblem::balanced(&cost, inst.a, inst.b, eps)
        }
    };
    let mut spec = SolverSpec::new(method).with_budget(s_mult).with_seed(seed);
    if let Some(name) = args.get("backend") {
        let Some(backend) = parse_backend(name) else {
            eprintln!("unknown backend '{name}' (auto|multiplicative|log-domain)");
            return 2;
        };
        spec = spec.with_backend(backend);
    }

    // Both solves dispatch through the batch API: the dense cost is
    // upgraded to a shared artifact in the global cache, so the exact
    // reference builds the kernel-side work and the approx run is a
    // cache hit on the same artifacts.
    let problems = [problem];
    let exact = api::solve_batch(&problems, &SolverSpec::new(Method::Sinkhorn))
        .pop()
        .expect("one problem in, one solution out");
    let approx = api::solve_batch(&problems, &spec).pop().expect("one problem in");
    let cache = spar_sink::engine::global_cache().stats();
    println!("artifact cache: {}", cache.render());
    match (exact, approx) {
        (Ok(exact), Ok(approx)) => {
            if let (Some(q_exact), Some(q_approx)) =
                (exact.barycenter.as_deref(), approx.barycenter.as_deref())
            {
                // Barycenter solves report the histogram gap, not an
                // objective (normalized — the sketched multiplicative
                // update does not renormalize).
                let gap = l1_distance(
                    &normalized_histogram(q_exact),
                    &normalized_histogram(q_approx),
                );
                println!(
                    "problem={problem_kind} n={n} eps={eps} method={} s={s_mult}s0\n\
                     exact  IBP: {} iters ({:?}, backend {:?})\n\
                     approx    : {} iters ({:?}, backend {:?}, nnz {:?})\n\
                     normalized L1 gap = {gap:.5}",
                    method.name(),
                    exact.iterations,
                    exact.wall_time,
                    exact.backend,
                    approx.iterations,
                    approx.wall_time,
                    approx.backend,
                    approx.nnz(),
                );
                return 0;
            }
            let rel = (approx.objective - exact.objective).abs()
                / exact.objective.abs().max(f64::MIN_POSITIVE);
            println!(
                "problem={problem_kind} n={n} d={d} eps={eps} method={} s={s_mult}s0\n\
                 exact objective   = {:.8}   ({:?})\n\
                 approx objective  = {:.8}   ({:?}, backend {:?}, nnz {:?})\n\
                 relative error    = {rel:.5}",
                method.name(),
                exact.objective,
                exact.wall_time,
                approx.objective,
                approx.wall_time,
                approx.backend,
                approx.nnz(),
            );
            0
        }
        (e, a) => {
            eprintln!(
                "solve failed: exact={:?} approx={:?}",
                e.map(|s| s.objective),
                a.map(|s| s.objective)
            );
            1
        }
    }
}

fn method_names() -> String {
    spar_sink::api::Method::ALL
        .iter()
        .map(|m| m.name())
        .collect::<Vec<_>>()
        .join("|")
}

fn cmd_serve(args: &Args) -> i32 {
    // `--port`/`--addr` switch serve from the self-driving echo demo to
    // the HTTP gateway: bind a listener and wait for remote jobs.
    if args.get("port").is_some() || args.get("addr").is_some() {
        return cmd_serve_gateway(args);
    }
    use spar_sink::api::parse_backend;
    use spar_sink::coordinator::{
        CoordinatorConfig, DistanceJob, DistanceService, Measure, Method, ProblemSpec,
    };
    use spar_sink::data::echo::{downsample_frames, frame_to_measure, generate, EchoConfig, Health};
    use spar_sink::rng::Rng;
    use spar_sink::solvers::backend::BackendKind;

    let videos: usize = args.get_parsed("videos", 2);
    let frames_n: usize = args.get_parsed("frames", 36);
    let workers: usize = args.get_parsed("workers", spar_sink::pool::num_threads().min(8));
    // 0 = available parallelism, clamped to the worker count (see
    // CoordinatorConfig::resolved_shards).
    let shards: usize = args.get_parsed("shards", 0);
    let steal = !args.flag("no-steal");
    let eps: f64 = args.get_parsed("eps", 0.05);
    // --shared-grid keeps every frame on the full pixel grid (zero-mass
    // pixels included), so all pairwise jobs share ONE support and the
    // coordinator's artifact cache builds the WFR cost/kernel exactly
    // once per (eta, eps) — the paper's echocardiogram workload shape.
    let shared_grid = args.flag("shared-grid");
    let threshold: f64 = args.get_parsed("threshold", 0.05);
    let method_name = args.get("method").unwrap_or("spar-sink");
    let Some(method) = Method::parse(method_name) else {
        eprintln!("unknown method '{method_name}'; available: {}", method_names());
        return 2;
    };
    // Per-job scaling-backend override, honored end-to-end by the
    // workers and reported back in the result + escalation metrics.
    let backend = match args.get("backend") {
        None => None,
        Some(name) => match parse_backend(name) {
            Some(b) => Some(b),
            None => {
                eprintln!("unknown backend '{name}' (auto|multiplicative|log-domain)");
                return 2;
            }
        },
    };
    let size = 40;

    let config = CoordinatorConfig { workers, shards, steal, ..Default::default() };
    println!(
        "starting distance service: {} workers, {} shards (steal {}), method {}",
        config.resolved_workers(),
        config.resolved_shards(),
        if steal { "on" } else { "off" },
        method.name()
    );
    let service = DistanceService::start(config);
    let mut rng = Rng::seed_from(7);
    let mut id = 0u64;
    let t0 = std::time::Instant::now();
    for v in 0..videos {
        let video = generate(
            &EchoConfig { size, frames: frames_n, period: 12.0, health: Health::Normal, noise: 0.01 },
            &mut rng,
        );
        let keep = downsample_frames(&video, 3);
        let grid: std::sync::Arc<Vec<Vec<f64>>> = std::sync::Arc::new(
            (0..size * size)
                .map(|k| vec![(k % size) as f64, (k / size) as f64])
                .collect(),
        );
        let measures: Vec<Measure> = keep
            .iter()
            .map(|&i| {
                if shared_grid {
                    let frame = &video.frames[i];
                    let total: f64 = frame.iter().map(|v| v.max(0.0)).sum();
                    let mass: Vec<f64> =
                        frame.iter().map(|v| v.max(0.0) / total.max(f64::MIN_POSITIVE)).collect();
                    Measure { points: grid.clone(), mass: std::sync::Arc::new(mass) }
                } else {
                    let (pts, mass) = frame_to_measure(&video.frames[i], size, threshold);
                    Measure::new(pts, mass)
                }
            })
            .collect();
        let mut jobs = Vec::new();
        for i in 0..measures.len() {
            for j in (i + 1)..measures.len() {
                jobs.push(DistanceJob {
                    id,
                    source: measures[i].clone(),
                    target: measures[j].clone(),
                    method,
                    spec: ProblemSpec {
                        eta: size as f64 / 7.5,
                        eps,
                        backend,
                        ..Default::default()
                    },
                    seed: id,
                });
                id += 1;
            }
        }
        let results = match service.submit_all(jobs) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("service error: {e}");
                return 1;
            }
        };
        let ok = results.iter().filter(|r| r.error.is_none()).count();
        let log_domain = results
            .iter()
            .filter(|r| r.backend == Some(BackendKind::LogDomain))
            .count();
        println!(
            "video {v}: {} distances ({} ok, {} via log-domain engine)",
            results.len(),
            ok,
            log_domain
        );
    }
    println!("total wall time: {:?}", t0.elapsed());
    println!("{}", service.shutdown().render());
    0
}

/// `serve --port P [--addr A]`: the HTTP gateway over the coordinator.
/// Blocks forever by default; `--duration SECS` runs a bounded session
/// (drain + metrics dump at the end), which is how scripted smoke tests
/// drive it.
fn cmd_serve_gateway(args: &Args) -> i32 {
    use spar_sink::coordinator::{CoordinatorConfig, DistanceService};
    use spar_sink::net::{Gateway, GatewayConfig};
    use std::sync::Arc;

    let workers: usize = args.get_parsed("workers", spar_sink::pool::num_threads().min(8));
    let shards: usize = args.get_parsed("shards", 0);
    let steal = !args.flag("no-steal");
    let port: u16 = args.get_parsed("port", 8517);
    let addr = args.get("addr").unwrap_or("127.0.0.1").to_string();
    let duration: u64 = args.get_parsed("duration", 0);

    let config = CoordinatorConfig { workers, shards, steal, ..Default::default() };
    println!(
        "starting distance service: {} workers, {} shards (steal {})",
        config.resolved_workers(),
        config.resolved_shards(),
        if steal { "on" } else { "off" }
    );
    let service = Arc::new(DistanceService::start(config));
    let gateway = match Gateway::start(
        Arc::clone(&service),
        GatewayConfig { addr, port, ..GatewayConfig::default() },
    ) {
        Ok(gateway) => gateway,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    println!("gateway listening on http://{}", gateway.local_addr());
    println!("endpoints: POST /solve, POST /barycenter, GET /metrics, GET /healthz");
    println!("admission control: full queue answers 429, connection cap answers 503");

    if duration == 0 {
        // Serve until killed; the process owns no other work.
        loop {
            std::thread::sleep(std::time::Duration::from_secs(60));
        }
    }
    std::thread::sleep(std::time::Duration::from_secs(duration));
    println!("duration elapsed; draining (in-flight jobs complete, new ones are refused)");
    println!("{}", gateway.shutdown().render());
    0
}

/// `balance --backends A,B,... [--port P]`: the fingerprint-affine load
/// balancer over already-running gateway backends. Blocks forever by
/// default; `--duration SECS` runs a bounded session, draining at the
/// end — the scripted-smoke-test shape, mirroring `serve --port`.
fn cmd_balance(args: &Args) -> i32 {
    use spar_sink::net::{Balancer, BalancerConfig};

    // One comma-separated value: `Args::parse` rejects repeated
    // options, so `--backends a --backends b` is already a loud error.
    let Some(list) = args.get("backends") else {
        eprintln!("balance requires --backends HOST:PORT[,HOST:PORT...]");
        return 2;
    };
    let backends: Vec<String> =
        list.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect();
    let port: u16 = args.get_parsed("port", 8518);
    let addr = args.get("addr").unwrap_or("127.0.0.1").to_string();
    let duration: u64 = args.get_parsed("duration", 0);

    let mut balancer = match Balancer::start(BalancerConfig {
        addr,
        port,
        backends,
        ..BalancerConfig::default()
    }) {
        Ok(balancer) => balancer,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    println!("balancer listening on http://{}", balancer.local_addr());
    for stats in balancer.stats() {
        println!("{}", stats.render());
    }
    println!("routing: jobs by cost fingerprint (slot = key mod backends), else round-robin");
    println!("health: /healthz probes evict and re-admit backends; retries are budgeted");

    if duration == 0 {
        // Balance until killed; the process owns no other work.
        loop {
            std::thread::sleep(std::time::Duration::from_secs(60));
        }
    }
    std::thread::sleep(std::time::Duration::from_secs(duration));
    println!("duration elapsed; draining (in-flight proxies complete, new ones are refused)");
    balancer.drain();
    for stats in balancer.stats() {
        println!("{}", stats.render());
    }
    0
}

fn cmd_lint(args: &Args) -> i32 {
    use spar_sink::lint::{self, LintConfig};
    use std::path::PathBuf;

    if args.flag("list-rules") {
        for rule in lint::RULES {
            let scope =
                if rule.scope.is_empty() { "all files".to_string() } else { rule.scope.join(" ") };
            println!("{:<18} [{scope}]\n    {}", rule.id, rule.summary);
        }
        return 0;
    }

    let root: PathBuf = match args.get("root") {
        Some(dir) => PathBuf::from(dir),
        // Work from either the repo root or rust/.
        None => match ["rust/src", "src"]
            .iter()
            .map(PathBuf::from)
            .find(|c| c.join("lib.rs").is_file())
        {
            Some(found) => found,
            None => {
                eprintln!("could not find the source tree (tried rust/src, src); pass --root DIR");
                return 2;
            }
        },
    };

    let config_text = match args.get("config") {
        Some(path) => match std::fs::read_to_string(path) {
            Ok(text) => Some(text),
            Err(e) => {
                eprintln!("read {path}: {e}");
                return 2;
            }
        },
        // Default: lint.toml looked up from the current directory
        // toward the repo root; absent means no allowlists.
        None => ["lint.toml", "../lint.toml", "../../lint.toml"]
            .iter()
            .find_map(|cand| std::fs::read_to_string(cand).ok()),
    };
    let config = match config_text {
        None => LintConfig::empty(),
        Some(text) => match LintConfig::parse(&text) {
            Ok(config) => config,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
    };

    match lint::lint_tree(&root, &config) {
        Ok(findings) if findings.is_empty() => {
            println!("lint clean: {} rules over {}", lint::RULES.len(), root.display());
            0
        }
        Ok(findings) => {
            for finding in &findings {
                println!("{finding}");
            }
            eprintln!(
                "{} finding(s); see `repro lint --list-rules` and README \"Static contracts\"",
                findings.len()
            );
            1
        }
        Err(e) => {
            eprintln!("lint error: {e}");
            2
        }
    }
}

fn cmd_bench(args: &Args) -> i32 {
    use spar_sink::bench::coordinator::{self, BenchConfig};

    let Some(target) = args.positional.first() else {
        eprintln!("bench requires a target (available: coordinator, kernels, gateway)");
        return 2;
    };
    if target == "kernels" {
        return cmd_bench_kernels(args);
    }
    if target == "gateway" {
        return cmd_bench_gateway(args);
    }
    if target != "coordinator" {
        eprintln!("unknown bench target '{target}' (available: coordinator, kernels, gateway)");
        return 2;
    }
    let workers: usize = args.get_parsed("workers", spar_sink::pool::num_threads().clamp(2, 8));
    let mut cfg = BenchConfig::quick(workers);
    cfg.size = args.get_parsed("size", cfg.size);
    cfg.frames = args.get_parsed("frames", cfg.frames);
    // The 1-vs-N contrast: always bench one shard against N.
    let default_contrast = cfg.shard_counts.last().copied().unwrap_or(4);
    let contrast: usize = args.get_parsed("shards", default_contrast);
    cfg.shard_counts = vec![1, contrast.max(2)];
    cfg.steal = !args.flag("no-steal");
    let doc = coordinator::run(&cfg);
    let path = args.get("out").unwrap_or("BENCH_coordinator.json");
    match std::fs::write(path, doc.to_string_compact()) {
        Ok(()) => {
            println!("[bench rows written to {path}]");
            0
        }
        Err(e) => {
            eprintln!("could not write {path}: {e}");
            1
        }
    }
}

fn cmd_bench_kernels(args: &Args) -> i32 {
    use spar_sink::bench::kernels::{self, BenchConfig};

    let mut cfg = if args.flag("quick") {
        BenchConfig::quick()
    } else {
        BenchConfig::full()
    };
    cfg.eps = args.get_parsed("eps", cfg.eps);
    cfg.s_multiplier = args.get_parsed("s", cfg.s_multiplier);
    let doc = kernels::run(&cfg);
    let path = args.get("out").unwrap_or("BENCH_kernels.json");
    match std::fs::write(path, doc.to_string_compact()) {
        Ok(()) => {
            println!("[bench rows written to {path}]");
            0
        }
        Err(e) => {
            eprintln!("could not write {path}: {e}");
            1
        }
    }
}

fn cmd_bench_gateway(args: &Args) -> i32 {
    use spar_sink::bench::gateway::{self, BenchConfig};

    let workers: usize = args.get_parsed("workers", spar_sink::pool::num_threads().clamp(2, 8));
    let mut cfg = BenchConfig::quick(workers);
    if args.flag("quick") {
        // The CI smoke shape: enough jobs to exercise every scenario,
        // small enough to finish in seconds.
        cfg.jobs = 16;
        cfg.clients = 2;
        cfg.size = 8;
        cfg.frames = 9;
    }
    cfg.size = args.get_parsed("size", cfg.size);
    cfg.jobs = args.get_parsed("jobs", cfg.jobs);
    cfg.clients = args.get_parsed("clients", cfg.clients);
    let doc = gateway::run(&cfg);
    let path = args.get("out").unwrap_or("BENCH_gateway.json");
    match std::fs::write(path, doc.to_string_compact()) {
        Ok(()) => {
            println!("[bench rows written to {path}]");
            0
        }
        Err(e) => {
            eprintln!("could not write {path}: {e}");
            1
        }
    }
}

#[cfg(feature = "xla")]
fn cmd_runtime_info() -> i32 {
    use spar_sink::runtime::{default_artifact_dir, ArtifactRegistry, Entry};
    let dir = default_artifact_dir();
    match ArtifactRegistry::open(&dir) {
        Ok(reg) => {
            println!("artifact dir : {}", dir.display());
            println!("platform     : {}", reg.client().platform_name());
            println!("block iters  : {}", reg.block_iters());
            for entry in [
                Entry::SinkhornBlock,
                Entry::OtObjective,
                Entry::UotObjective,
                Entry::KernelFromCost,
            ] {
                println!("{:<18} sizes {:?}", entry.name(), reg.sizes(entry));
            }
            0
        }
        Err(e) => {
            eprintln!("runtime unavailable: {e}");
            1
        }
    }
}

#[cfg(not(feature = "xla"))]
fn cmd_runtime_info() -> i32 {
    eprintln!(
        "built without the `xla` feature — the PJRT runtime is unavailable.\n\
         Rebuild with `cargo build --features xla` (requires the xla_extension toolchain)."
    );
    1
}
