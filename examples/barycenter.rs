//! Wasserstein barycenters with IBP vs Spar-IBP (Appendix A / C.3):
//! three 1-D measures (Gaussian, mixture, t5) and a digit-glyph demo —
//! both solved from the same barycenter `OtProblem` through
//! `api::solve` (`sinkhorn` = exact IBP, `spar-ibp` = Algorithm 6).
//!
//! ```sh
//! cargo run --release --example barycenter
//! ```

use spar_sink::api::{self, Method, OtProblem, Solution, SolverSpec};
use spar_sink::data::digits::random_digit;
use spar_sink::data::synthetic::barycenter_measures;
use spar_sink::experiments::fig12::ascii_render;
use spar_sink::metrics::{l1_distance, normalized_histogram};
use spar_sink::ot::cost::{normalize_cost, sq_euclidean_cost};
use spar_sink::rng::Rng;

fn q(sol: &Solution) -> &[f64] {
    sol.barycenter.as_deref().expect("barycenter solve returns q")
}

fn main() {
    let mut rng = Rng::seed_from(21);

    // --- 1-D synthetic measures ---
    let n = 400;
    let pts: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 / (n - 1) as f64]).collect();
    let cost = normalize_cost(&sq_euclidean_cost(&pts, &pts));
    let bs = barycenter_measures(n, &mut rng);
    let problem = OtProblem::barycenter(cost, bs, vec![1.0 / 3.0; 3], 5e-3);

    let exact_spec = SolverSpec::new(Method::Sinkhorn).with_tolerance(1e-7);
    let exact = api::solve(&problem, &exact_spec).expect("ibp");
    let spar_spec = SolverSpec::new(Method::SparIbp)
        .with_budget(20.0)
        .with_tolerance(1e-7)
        .with_seed(21);
    let approx = api::solve(&problem, &spar_spec).expect("spar-ibp");
    let gap = l1_distance(&normalized_histogram(q(&exact)), &normalized_histogram(q(&approx)));
    println!(
        "1-D barycenter (n = {n}): IBP {:?} vs Spar-IBP {:?} (sketch nnz {:?})",
        exact.wall_time,
        approx.wall_time,
        approx.nnz()
    );
    println!(
        "normalized L1 gap = {gap:.4}  (IBP iters {}, Spar-IBP iters {})",
        exact.iterations, approx.iterations
    );

    // --- digit glyphs (Fig. 12 style) ---
    let grid = 24;
    let n = grid * grid;
    let pts: Vec<Vec<f64>> = (0..n)
        .map(|k| vec![(k % grid) as f64 / grid as f64, (k / grid) as f64 / grid as f64])
        .collect();
    let cost = normalize_cost(&sq_euclidean_cost(&pts, &pts));
    let digit = 3u8;
    let bs: Vec<Vec<f64>> = (0..8).map(|_| random_digit(digit, grid, &mut rng)).collect();
    let problem = OtProblem::barycenter(cost, bs, vec![1.0 / 8.0; 8], 2e-3);
    let exact = api::solve(&problem, &exact_spec).expect("ibp digits");
    let approx = api::solve(&problem, &spar_spec).expect("spar-ibp digits");
    println!("\ndigit {digit} barycenter, IBP:");
    println!("{}", ascii_render(&normalized_histogram(q(&exact)), grid));
    println!("digit {digit} barycenter, Spar-IBP:");
    println!("{}", ascii_render(&normalized_histogram(q(&approx)), grid));
}
