//! Seeded violation (wall-clock): clock and machine-shape reads inside
//! a result-affecting module.

use std::time::Instant;

/// A solve whose output depends on when and where it ran.
pub fn timed_solve() -> f64 {
    let t0 = Instant::now();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    (threads as f64) + t0.elapsed().as_secs_f64()
}
