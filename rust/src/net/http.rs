//! Hand-rolled HTTP/1.1 request parsing with hard size caps.
//!
//! The offline image has no hyper/tokio, and the gateway needs only a
//! narrow slice of HTTP: request line, headers, `Content-Length`
//! bodies, keep-alive, pipelining. Everything is read through bounded
//! loops — a peer can never make the parser buffer more than
//! [`HttpLimits`] allows, which is the protocol-layer half of the
//! gateway's admission-control story (the coordinator-queue half is
//! `try_submit`). Parsing is transport-agnostic (`BufRead`), so the
//! hardening corpus below runs the exact production code path with no
//! sockets.

use std::io::{BufRead, Read};

/// Size caps applied while parsing one request. Exceeding a cap is a
/// protocol error with a definite status code — never an allocation.
#[derive(Clone, Debug)]
pub struct HttpLimits {
    /// Longest accepted request line (method + target + version), in
    /// bytes, terminator excluded.
    pub max_request_line: usize,
    /// Longest accepted single header line, in bytes.
    pub max_header_line: usize,
    /// Maximum number of header lines.
    pub max_headers: usize,
    /// Largest accepted `Content-Length` body, in bytes.
    pub max_body: usize,
}

impl Default for HttpLimits {
    fn default() -> Self {
        HttpLimits {
            max_request_line: 8 * 1024,
            max_header_line: 8 * 1024,
            max_headers: 64,
            max_body: 4 * 1024 * 1024,
        }
    }
}

/// One parsed request. Header names are lowercased at parse time;
/// values keep their bytes verbatim (surrounding whitespace trimmed).
#[derive(Clone, Debug)]
pub struct Request {
    /// Request method, verbatim (`GET`, `POST`, …).
    pub method: String,
    /// Request target (path plus optional query), verbatim.
    pub path: String,
    /// `(lowercased-name, value)` pairs in arrival order. A `Vec`, not
    /// a map: arrival order is preserved and iteration is
    /// deterministic (the `unordered-iter` contract applies to all
    /// files, this one included).
    pub headers: Vec<(String, String)>,
    /// The `Content-Length` body (empty when the header is absent).
    pub body: Vec<u8>,
}

impl Request {
    /// First value of header `name` (give it lowercased), if present.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// Whether the client asked to keep the connection open: HTTP/1.1
    /// defaults to keep-alive unless `Connection: close` was sent.
    pub fn keep_alive(&self) -> bool {
        !matches!(self.header("connection"), Some(v) if v.eq_ignore_ascii_case("close"))
    }
}

/// Why parsing failed. [`ParseError::status`] maps each protocol
/// violation to the response the connection should send before
/// closing; `None` means the peer is gone mid-request and no response
/// can be delivered.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ParseError {
    /// Clean end of stream at a request boundary (zero bytes read):
    /// the client closed an idle connection. Not a protocol error.
    Eof,
    /// The stream ended mid-request — request line, headers, or a
    /// declared body cut short. No response is possible.
    Truncated,
    /// Malformed request line (wrong token count or not UTF-8).
    BadRequestLine(String),
    /// A version other than HTTP/1.0 or HTTP/1.1.
    UnsupportedVersion(String),
    /// Request line longer than [`HttpLimits::max_request_line`].
    RequestLineTooLong,
    /// One header line longer than [`HttpLimits::max_header_line`], or
    /// more than [`HttpLimits::max_headers`] lines.
    HeadersTooLarge,
    /// A header line without a `:` or with an empty name.
    BadHeader(String),
    /// `Content-Length` that does not parse as a base-10 integer.
    BadContentLength(String),
    /// More than one `Content-Length` header (identical or not). The
    /// message is ambiguous about where the body ends — two parsers
    /// picking different values is the classic request-smuggling
    /// vector, so the request is refused outright.
    DuplicateContentLength {
        /// The first declared value.
        first: String,
        /// The second declared value (conflicting or a duplicate).
        second: String,
    },
    /// Declared body larger than [`HttpLimits::max_body`].
    BodyTooLarge {
        /// What `Content-Length` declared.
        declared: usize,
        /// The configured cap it exceeded.
        cap: usize,
    },
    /// Transport error from the underlying reader (includes read
    /// timeouts on idle keep-alive connections).
    Io(String),
}

impl ParseError {
    /// The HTTP status the connection should answer with before
    /// closing, or `None` when no response can reach the peer.
    pub fn status(&self) -> Option<u16> {
        match self {
            ParseError::Eof | ParseError::Truncated | ParseError::Io(_) => None,
            ParseError::BadRequestLine(_)
            | ParseError::BadHeader(_)
            | ParseError::BadContentLength(_)
            | ParseError::DuplicateContentLength { .. } => Some(400),
            ParseError::UnsupportedVersion(_) => Some(505),
            ParseError::RequestLineTooLong | ParseError::HeadersTooLarge => Some(431),
            ParseError::BodyTooLarge { .. } => Some(413),
        }
    }

    /// Human-readable detail for the JSON error body.
    pub fn message(&self) -> String {
        match self {
            ParseError::Eof => "connection closed".to_string(),
            ParseError::Truncated => "request truncated mid-stream".to_string(),
            ParseError::BadRequestLine(line) => format!("malformed request line '{line}'"),
            ParseError::UnsupportedVersion(v) => {
                format!("unsupported version '{v}' (use HTTP/1.1)")
            }
            ParseError::RequestLineTooLong => "request line exceeds the size cap".to_string(),
            ParseError::HeadersTooLarge => "headers exceed the size caps".to_string(),
            ParseError::BadHeader(line) => format!("malformed header line '{line}'"),
            ParseError::BadContentLength(v) => format!("bad content-length '{v}'"),
            ParseError::DuplicateContentLength { first, second } => {
                format!("conflicting content-length headers '{first}' and '{second}'")
            }
            ParseError::BodyTooLarge { declared, cap } => {
                format!("declared body of {declared} bytes exceeds the {cap}-byte cap")
            }
            ParseError::Io(e) => format!("transport error: {e}"),
        }
    }
}

/// Parse exactly one request from `reader`. Repeated calls on one
/// reader parse pipelined requests back to back — the parser consumes
/// exactly one request's bytes per call, so connection state stays
/// consistent across a mixed sequence (pinned by the hardening corpus
/// below and socket-side by `tests/gateway_integration.rs`).
pub fn read_request<R: BufRead>(
    reader: &mut R,
    limits: &HttpLimits,
) -> Result<Request, ParseError> {
    let line = match read_line_bounded(
        reader,
        limits.max_request_line,
        ParseError::RequestLineTooLong,
    )? {
        None => return Err(ParseError::Eof),
        Some(line) => line,
    };
    let line = String::from_utf8(line)
        .map_err(|_| ParseError::BadRequestLine("<non-UTF-8 bytes>".into()))?;
    let mut parts = line.split_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v), None) => (m, p, v),
        _ => return Err(ParseError::BadRequestLine(line.clone())),
    };
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(ParseError::UnsupportedVersion(version.to_string()));
    }
    let (method, path) = (method.to_string(), path.to_string());

    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let header = read_line_bounded(reader, limits.max_header_line, ParseError::HeadersTooLarge)?
            .ok_or(ParseError::Truncated)?;
        if header.is_empty() {
            break;
        }
        if headers.len() >= limits.max_headers {
            return Err(ParseError::HeadersTooLarge);
        }
        let header =
            String::from_utf8(header).map_err(|_| ParseError::BadHeader("<non-UTF-8>".into()))?;
        let Some((name, value)) = header.split_once(':') else {
            return Err(ParseError::BadHeader(header.clone()));
        };
        let name = name.trim();
        if name.is_empty() {
            return Err(ParseError::BadHeader(header.clone()));
        }
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }

    let mut request = Request { method, path, headers, body: Vec::new() };
    // All `Content-Length` occurrences, not `Request::header` (which
    // returns the first match and used to let a second, conflicting
    // declaration ride along silently — the smuggling ambiguity the
    // `DuplicateContentLength` arm refuses).
    let lengths: Vec<&str> = request
        .headers
        .iter()
        .filter(|(name, _)| name == "content-length")
        .map(|(_, value)| value.as_str())
        .collect();
    if lengths.len() > 1 {
        return Err(ParseError::DuplicateContentLength {
            first: lengths[0].to_string(),
            second: lengths[1].to_string(),
        });
    }
    if let Some(&declared) = lengths.first() {
        let declared: usize = declared
            .parse()
            .map_err(|_| ParseError::BadContentLength(declared.to_string()))?;
        // Refused BEFORE allocating: the declaration alone rejects the
        // request, so an attacker cannot make the gateway reserve the
        // buffer first.
        if declared > limits.max_body {
            return Err(ParseError::BodyTooLarge { declared, cap: limits.max_body });
        }
        let mut body = vec![0u8; declared];
        read_exact_or_truncated(reader, &mut body)?;
        request.body = body;
    }
    Ok(request)
}

/// Read one CRLF- or LF-terminated line of at most `cap` bytes
/// (terminator excluded). `Ok(None)` is clean EOF before any byte —
/// the caller decides whether that is a request boundary or a
/// truncation. Byte-at-a-time through the `BufRead` buffer: unlike
/// `read_until`, growth is capped.
fn read_line_bounded<R: BufRead>(
    reader: &mut R,
    cap: usize,
    overflow: ParseError,
) -> Result<Option<Vec<u8>>, ParseError> {
    let mut line: Vec<u8> = Vec::new();
    loop {
        let mut byte = [0u8; 1];
        match reader.read(&mut byte) {
            Ok(0) => {
                return if line.is_empty() { Ok(None) } else { Err(ParseError::Truncated) };
            }
            Ok(_) => {
                if byte[0] == b'\n' {
                    if line.last() == Some(&b'\r') {
                        line.pop();
                    }
                    return Ok(Some(line));
                }
                if line.len() >= cap {
                    return Err(overflow);
                }
                line.push(byte[0]);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(ParseError::Io(e.to_string())),
        }
    }
}

/// Fill `buf` completely or report [`ParseError::Truncated`].
fn read_exact_or_truncated<R: BufRead>(reader: &mut R, buf: &mut [u8]) -> Result<(), ParseError> {
    let mut filled = 0;
    while filled < buf.len() {
        match reader.read(&mut buf[filled..]) {
            Ok(0) => return Err(ParseError::Truncated),
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(ParseError::Io(e.to_string())),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(raw: &[u8]) -> Result<Request, ParseError> {
        read_request(&mut BufReader::new(raw), &HttpLimits::default())
    }

    #[test]
    fn parses_a_simple_get() {
        let req = parse(b"GET /healthz HTTP/1.1\r\nHost: gw\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.header("host"), Some("gw"));
        assert!(req.keep_alive());
        assert!(req.body.is_empty());
    }

    #[test]
    fn parses_a_post_with_body_and_lf_only_lines() {
        let req = parse(b"POST /solve HTTP/1.1\ncontent-length: 4\n\nwxyz").unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"wxyz");
    }

    #[test]
    fn connection_close_disables_keep_alive() {
        let req = parse(b"GET / HTTP/1.1\r\nConnection: Close\r\n\r\n").unwrap();
        assert!(!req.keep_alive());
        // HTTP/1.0 without the header keeps the 1.1 default here; the
        // router never upgrades the response version, so this is safe.
        let req = parse(b"GET / HTTP/1.0\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
    }

    /// The hardening corpus: every malformed-input arm asserts the
    /// exact variant AND the exact status code the connection must
    /// answer with, table-driven so new arms are one line each.
    #[test]
    fn malformed_inputs_map_to_exact_statuses() {
        let oversized_line = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(9000));
        let many_headers = {
            let mut raw = String::from("GET / HTTP/1.1\r\n");
            for i in 0..80 {
                raw.push_str(&format!("x-h-{i}: v\r\n"));
            }
            raw.push_str("\r\n");
            raw
        };
        let long_header = format!("GET / HTTP/1.1\r\nx-big: {}\r\n\r\n", "b".repeat(9000));
        let cases: Vec<(&str, Vec<u8>, ParseError, Option<u16>)> = vec![
            ("empty stream", b"".to_vec(), ParseError::Eof, None),
            (
                "garbage request line",
                b"GARBAGE\r\n\r\n".to_vec(),
                ParseError::BadRequestLine("GARBAGE".into()),
                Some(400),
            ),
            (
                "four-token request line",
                b"GET / extra HTTP/1.1\r\n\r\n".to_vec(),
                ParseError::BadRequestLine("GET / extra HTTP/1.1".into()),
                Some(400),
            ),
            (
                "http/2 preface",
                b"GET / HTTP/2\r\n\r\n".to_vec(),
                ParseError::UnsupportedVersion("HTTP/2".into()),
                Some(505),
            ),
            (
                "oversized request line",
                oversized_line.into_bytes(),
                ParseError::RequestLineTooLong,
                Some(431),
            ),
            (
                "oversized header line",
                long_header.into_bytes(),
                ParseError::HeadersTooLarge,
                Some(431),
            ),
            (
                "too many headers",
                many_headers.into_bytes(),
                ParseError::HeadersTooLarge,
                Some(431),
            ),
            (
                "header without a colon",
                b"GET / HTTP/1.1\r\nNoColonHere\r\n\r\n".to_vec(),
                ParseError::BadHeader("NoColonHere".into()),
                Some(400),
            ),
            (
                "empty header name",
                b"GET / HTTP/1.1\r\n: v\r\n\r\n".to_vec(),
                ParseError::BadHeader(": v".into()),
                Some(400),
            ),
            (
                "non-numeric content-length",
                b"POST /solve HTTP/1.1\r\ncontent-length: abc\r\n\r\n".to_vec(),
                ParseError::BadContentLength("abc".into()),
                Some(400),
            ),
            (
                "oversized declared body",
                b"POST /solve HTTP/1.1\r\ncontent-length: 99999999\r\n\r\n".to_vec(),
                ParseError::BodyTooLarge { declared: 99_999_999, cap: 4 * 1024 * 1024 },
                Some(413),
            ),
            (
                "duplicate identical content-length",
                b"POST /solve HTTP/1.1\r\ncontent-length: 3\r\ncontent-length: 3\r\n\r\nabc"
                    .to_vec(),
                ParseError::DuplicateContentLength { first: "3".into(), second: "3".into() },
                Some(400),
            ),
            (
                "conflicting content-length",
                b"POST /solve HTTP/1.1\r\ncontent-length: 4\r\ncontent-length: 9999\r\n\r\nwxyz"
                    .to_vec(),
                ParseError::DuplicateContentLength { first: "4".into(), second: "9999".into() },
                Some(400),
            ),
            (
                "truncated body",
                b"POST /solve HTTP/1.1\r\ncontent-length: 10\r\n\r\nwxyz".to_vec(),
                ParseError::Truncated,
                None,
            ),
            (
                "truncated headers",
                b"GET / HTTP/1.1\r\nHost: gw\r\n".to_vec(),
                ParseError::Truncated,
                None,
            ),
        ];
        for (name, raw, expected, status) in cases {
            let err = parse(&raw).expect_err(name);
            assert_eq!(err, expected, "{name}");
            assert_eq!(err.status(), status, "{name}");
            assert!(!err.message().is_empty(), "{name}");
        }
    }

    /// Decode-error extension of the corpus: full POST byte streams
    /// whose HTTP layer is well-formed but whose JSON body must be
    /// refused by the codec with an error naming the offending field —
    /// the exact two-layer path the gateway's `400` body takes. The
    /// non-finite rows pin the fix for `1e999`-style literals, which
    /// the JSON number parser turns into `f64::INFINITY` and the codec
    /// used to pass straight into `Measure::new`.
    #[test]
    fn well_formed_posts_with_poisoned_bodies_name_the_field() {
        let cases: Vec<(&str, &str, &str)> = vec![
            (
                "infinite mass literal",
                r#"{"source": {"points": [[0]], "mass": [1e999]},
                    "target": {"points": [[0]], "mass": [1]}}"#,
                "'source.mass' must be a finite number",
            ),
            (
                "negative-infinite support coordinate",
                r#"{"source": {"points": [[0]], "mass": [1]},
                    "target": {"points": [[-1e999]], "mass": [1]}}"#,
                "each point in 'target.points' must be a finite number",
            ),
            (
                "infinite spec parameter",
                r#"{"source": {"points": [[0]], "mass": [1]},
                    "target": {"points": [[0]], "mass": [1]},
                    "spec": {"lambda": 1e999}}"#,
                "field 'lambda' must be a finite number",
            ),
        ];
        for (name, body, needle) in cases {
            let raw = format!(
                "POST /solve HTTP/1.1\r\ncontent-length: {}\r\n\r\n{}",
                body.len(),
                body
            );
            let request = parse(raw.as_bytes()).expect(name);
            let json = crate::util::json::Json::parse(
                std::str::from_utf8(&request.body).expect(name),
            )
            .expect(name);
            let err = crate::net::codec::decode_distance_job(&json).expect_err(name);
            assert!(err.contains(needle), "{name}: '{err}' should contain '{needle}'");
        }
    }

    #[test]
    fn pipelined_requests_parse_back_to_back() {
        // Two requests on one stream: each call consumes exactly one
        // request's bytes, the second sees a clean boundary, and the
        // third call reports plain EOF — the consistent-connection
        // contract the keep-alive loop relies on.
        let raw: &[u8] = b"POST /solve HTTP/1.1\r\ncontent-length: 3\r\n\r\nabc\
                           GET /metrics HTTP/1.1\r\n\r\n";
        let mut reader = BufReader::new(raw);
        let limits = HttpLimits::default();
        let first = read_request(&mut reader, &limits).unwrap();
        assert_eq!((first.method.as_str(), first.body.as_slice()), ("POST", &b"abc"[..]));
        let second = read_request(&mut reader, &limits).unwrap();
        assert_eq!((second.method.as_str(), second.path.as_str()), ("GET", "/metrics"));
        assert_eq!(read_request(&mut reader, &limits), Err(ParseError::Eof));
    }

    #[test]
    fn an_error_does_not_poison_custom_limits() {
        // Tight custom caps: the request that fits parses, the one
        // that does not is refused with the configured cap reported.
        let limits =
            HttpLimits { max_request_line: 64, max_header_line: 32, max_headers: 4, max_body: 8 };
        let ok = read_request(
            &mut BufReader::new(&b"POST /s HTTP/1.1\r\ncontent-length: 8\r\n\r\n12345678"[..]),
            &limits,
        )
        .unwrap();
        assert_eq!(ok.body.len(), 8);
        let err = read_request(
            &mut BufReader::new(&b"POST /s HTTP/1.1\r\ncontent-length: 9\r\n\r\n123456789"[..]),
            &limits,
        )
        .expect_err("nine bytes over an eight-byte cap");
        assert_eq!(err, ParseError::BodyTooLarge { declared: 9, cap: 8 });
    }
}
