//! The serve-mode gateway's contract wall — real sockets end to end:
//!
//! * a job posted over loopback solves BITWISE-identically to the same
//!   job submitted in-process (OT and barycenter alike): the HTTP layer
//!   cannot change any reproduced number;
//! * N concurrent clients each get their own correct answer;
//! * a saturated coordinator queue answers `429 Too Many Requests`
//!   without stalling the accept loop (health probes keep working);
//! * graceful drain completes in-flight jobs and then refuses new
//!   connections;
//! * `/metrics` serves well-formed Prometheus text whose counters match
//!   the service's real state;
//! * protocol errors carry their exact status codes over the wire.
//!
//! Runs in the CI cache-parity job (release) alongside the determinism
//! suites.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use spar_sink::coordinator::{
    BarycenterJob, CoordinatorConfig, DistanceJob, DistanceService, Measure, Method, ProblemSpec,
};
use spar_sink::net::codec;
use spar_sink::net::{Gateway, GatewayConfig};
use spar_sink::util::json::Json;

// ---------------------------------------------------------------- helpers

struct HttpResponse {
    status: u16,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

impl HttpResponse {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    fn json(&self) -> Json {
        Json::parse(std::str::from_utf8(&self.body).expect("utf-8 body")).expect("json body")
    }
}

fn read_response<R: BufRead>(reader: &mut R) -> HttpResponse {
    let mut status_line = String::new();
    reader.read_line(&mut status_line).expect("status line");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line '{status_line}'"));
    let mut headers = Vec::new();
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).expect("header line");
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        let (name, value) = line.split_once(':').expect("header colon");
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let length: usize = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .and_then(|(_, v)| v.parse().ok())
        .unwrap_or(0);
    let mut body = vec![0u8; length];
    reader.read_exact(&mut body).expect("body");
    HttpResponse { status, headers, body }
}

/// One request/response round trip on a fresh connection
/// (`connection: close`, so the handler releases its slot right away).
fn request(addr: SocketAddr, method: &str, path: &str, body: &[u8]) -> HttpResponse {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(120))).expect("timeout");
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nconnection: close\r\ncontent-length: {}\r\n\r\n",
        body.len()
    )
    .expect("request head");
    stream.write_all(body).expect("request body");
    read_response(&mut BufReader::new(stream))
}

fn post_json(addr: SocketAddr, path: &str, payload: &Json) -> HttpResponse {
    request(addr, "POST", path, payload.to_string_compact().as_bytes())
}

fn bits(v: &Json, key: &str) -> u64 {
    v.get(key)
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("missing numeric field '{key}'"))
        .to_bits()
}

// ----------------------------------------------------------- job fixtures

fn toy_measure(seed: u64, n: usize, mass: f64) -> Measure {
    let mut rng = spar_sink::rng::Rng::seed_from(seed);
    let points: Vec<Vec<f64>> =
        (0..n).map(|_| vec![rng.uniform() * 10.0, rng.uniform() * 10.0]).collect();
    let mut weights: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.1).collect();
    let total: f64 = weights.iter().sum();
    weights.iter_mut().for_each(|w| *w *= mass / total);
    Measure::new(points, weights)
}

fn distance_job(id: u64) -> DistanceJob {
    DistanceJob {
        id,
        source: toy_measure(1000 + id, 40, 1.0),
        target: toy_measure(2000 + id, 40, 1.2),
        method: Method::SparSink,
        spec: ProblemSpec { eta: 3.0, eps: 0.05, ..ProblemSpec::default() },
        seed: 42 + id,
    }
}

fn barycenter_job(id: u64) -> BarycenterJob {
    let n = 32;
    let support: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 / (n - 1) as f64]).collect();
    let bump = |mu: f64| -> Vec<f64> {
        let raw: Vec<f64> =
            support.iter().map(|p| (-(p[0] - mu).powi(2) / 0.01).exp() + 1e-4).collect();
        let total: f64 = raw.iter().sum();
        raw.iter().map(|x| x / total).collect()
    };
    BarycenterJob {
        id,
        marginals: vec![bump(0.25), bump(0.75)],
        support: Arc::new(support),
        weights: vec![0.5, 0.5],
        method: Method::SparIbp,
        spec: ProblemSpec { eps: 0.01, s_multiplier: 40.0, ..ProblemSpec::default() },
        seed: 7,
    }
}

/// A job that holds its worker for a long time: δ = 0 never converges,
/// so the solver runs the full iteration budget.
fn stalled_worker_job(id: u64) -> DistanceJob {
    DistanceJob {
        id,
        source: toy_measure(1, 64, 1.0),
        target: toy_measure(2, 64, 1.2),
        method: Method::Sinkhorn,
        spec: ProblemSpec {
            eps: 0.05,
            eta: 3.0,
            delta: 0.0,
            max_iters: 40_000,
            ..ProblemSpec::default()
        },
        seed: 0,
    }
}

fn small_gateway(config: CoordinatorConfig) -> Gateway {
    let service = Arc::new(DistanceService::start(config));
    Gateway::start(service, GatewayConfig::default()).expect("gateway start")
}

fn default_coordinator() -> CoordinatorConfig {
    CoordinatorConfig { workers: 2, shards: 1, ..CoordinatorConfig::default() }
}

// ----------------------------------------------------------------- tests

#[test]
fn loopback_round_trip_is_bitwise_equal_to_in_process_submit() {
    // Same job twice: once over the wire, once through a separate
    // in-process reference service. Results are pure functions of the
    // job (the determinism walls pin that), so any drift here is the
    // HTTP layer corrupting a float.
    let gateway = small_gateway(default_coordinator());
    let reference = DistanceService::start(default_coordinator());

    let job = distance_job(1);
    let expected = reference.submit(job.clone()).unwrap().recv().unwrap();
    assert!(expected.error.is_none(), "{:?}", expected.error);
    let resp = post_json(gateway.local_addr(), "/solve", &codec::distance_job_json(&job));
    assert_eq!(resp.status, 200);
    let wire = resp.json();
    assert_eq!(bits(&wire, "distance"), expected.distance.to_bits());
    assert_eq!(bits(&wire, "objective"), expected.objective.to_bits());
    assert_eq!(wire.get("backend").unwrap().as_str(), Some("multiplicative"));
    assert!(wire.get("error").is_none());

    let bary = barycenter_job(2);
    let expected = reference.submit_barycenter(bary.clone()).unwrap().recv().unwrap();
    assert!(expected.error.is_none(), "{:?}", expected.error);
    let resp = post_json(gateway.local_addr(), "/barycenter", &codec::barycenter_job_json(&bary));
    assert_eq!(resp.status, 200);
    let wire = resp.json();
    let q = wire.get("q").unwrap().items();
    assert_eq!(q.len(), expected.q.len());
    for (sent, got) in q.iter().zip(expected.q.iter()) {
        assert_eq!(sent.as_f64().unwrap().to_bits(), got.to_bits());
    }

    reference.shutdown();
    gateway.shutdown();
}

#[test]
fn concurrent_clients_all_get_correct_answers() {
    let gateway = small_gateway(CoordinatorConfig {
        workers: 4,
        shards: 2,
        ..CoordinatorConfig::default()
    });
    let addr = gateway.local_addr();
    let clients: Vec<_> = (0..8)
        .map(|id| {
            std::thread::spawn(move || {
                let job = distance_job(id);
                let resp = post_json(addr, "/solve", &codec::distance_job_json(&job));
                assert_eq!(resp.status, 200, "client {id}");
                let result = resp.json();
                assert_eq!(result.get("id").unwrap().as_f64(), Some(id as f64), "client {id}");
                assert!(result.get("error").is_none(), "client {id}");
                let distance = result.get("distance").unwrap().as_f64().unwrap();
                assert!(distance.is_finite() && distance >= 0.0, "client {id}: {distance}");
            })
        })
        .collect();
    for client in clients {
        client.join().expect("client thread");
    }
    let metrics = gateway.shutdown();
    assert_eq!(metrics.completed, 8);
    assert_eq!(metrics.failed, 0);
}

#[test]
fn keep_alive_connection_serves_pipelined_requests() {
    let gateway = small_gateway(default_coordinator());
    let mut stream = TcpStream::connect(gateway.local_addr()).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(120))).expect("timeout");

    // Two identical solves written back to back on ONE connection
    // before reading anything: the handler must answer both, in order,
    // with identical bits (same job → same result).
    let payload = codec::distance_job_json(&distance_job(3)).to_string_compact();
    for _ in 0..2 {
        write!(
            stream,
            "POST /solve HTTP/1.1\r\ncontent-length: {}\r\n\r\n{payload}",
            payload.len()
        )
        .expect("pipelined request");
    }
    let mut reader = BufReader::new(stream);
    let first = read_response(&mut reader);
    let second = read_response(&mut reader);
    assert_eq!((first.status, second.status), (200, 200));
    assert_eq!(bits(&first.json(), "distance"), bits(&second.json(), "distance"));
    assert_eq!(bits(&first.json(), "objective"), bits(&second.json(), "objective"));

    // Release the connection before draining: the handler is parked in
    // read_request waiting for a third request until we hang up.
    drop(reader);
    let metrics = gateway.shutdown();
    assert_eq!(metrics.completed, 2);
}

#[test]
fn saturated_queue_answers_429_without_stalling_the_accept_loop() {
    // A deliberately tiny pipeline: 1 worker, queue_cap 1, batches of
    // 1, and jobs that hold the worker for the full iteration budget.
    // Total in-flight capacity is a handful of jobs; a burst of 10 must
    // split into some 200s and some 429s — and NEVER a stall.
    let gateway = small_gateway(CoordinatorConfig {
        workers: 1,
        shards: 1,
        queue_cap: 1,
        max_batch: 1,
        batch_window: Duration::from_millis(1),
        ..CoordinatorConfig::default()
    });
    let addr = gateway.local_addr();

    let barrier = Arc::new(Barrier::new(10));
    let clients: Vec<_> = (0..10u64)
        .map(|id| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let payload =
                    codec::distance_job_json(&stalled_worker_job(id)).to_string_compact();
                // Connect first, then fire all bodies at once: the
                // submissions hit the queue within microseconds of each
                // other, far faster than any job completes.
                let mut stream = TcpStream::connect(addr).expect("connect");
                stream.set_read_timeout(Some(Duration::from_secs(300))).expect("timeout");
                barrier.wait();
                write!(
                    stream,
                    "POST /solve HTTP/1.1\r\nconnection: close\r\ncontent-length: {}\r\n\r\n{payload}",
                    payload.len()
                )
                .expect("request");
                read_response(&mut BufReader::new(stream)).status
            })
        })
        .collect();
    let statuses: Vec<u16> = clients.into_iter().map(|c| c.join().expect("client")).collect();

    assert!(statuses.iter().any(|&s| s == 429), "no backpressure rejection in {statuses:?}");
    assert!(statuses.iter().any(|&s| s == 200), "no accepted job in {statuses:?}");
    assert!(statuses.iter().all(|&s| s == 200 || s == 429), "unexpected status in {statuses:?}");

    // The accept loop stayed responsive through the saturation burst.
    assert_eq!(request(addr, "GET", "/healthz", b"").status, 200);

    let metrics = gateway.shutdown();
    let accepted = statuses.iter().filter(|&&s| s == 200).count() as u64;
    assert_eq!(metrics.completed, accepted);
    assert_eq!(metrics.failed, 0);
}

#[test]
fn graceful_drain_completes_in_flight_and_refuses_new_connections() {
    let gateway = small_gateway(CoordinatorConfig {
        workers: 1,
        shards: 1,
        ..CoordinatorConfig::default()
    });
    let addr = gateway.local_addr();

    let in_flight = std::thread::spawn(move || {
        let job = codec::distance_job_json(&stalled_worker_job(77));
        post_json(addr, "/solve", &job)
    });
    // Let the job reach the coordinator before draining.
    std::thread::sleep(Duration::from_millis(300));
    let metrics = gateway.shutdown();

    // Drain returned only after the in-flight job finished — and the
    // client got its full answer, not a torn connection.
    let resp = in_flight.join().expect("in-flight client");
    assert_eq!(resp.status, 200);
    assert_eq!(resp.json().get("id").unwrap().as_f64(), Some(77.0));
    assert_eq!(metrics.completed, 1);

    // New connections are refused outright (the listener is gone). On
    // the off chance the OS still completes the handshake, the socket
    // must deliver zero bytes — never a served request.
    match TcpStream::connect_timeout(&addr, Duration::from_secs(2)) {
        Err(_) => {}
        Ok(mut stream) => {
            stream.set_read_timeout(Some(Duration::from_secs(5))).expect("timeout");
            let _ = stream.write_all(b"GET /healthz HTTP/1.1\r\nconnection: close\r\n\r\n");
            let mut sink = Vec::new();
            let bytes = stream.read_to_end(&mut sink).unwrap_or(0);
            assert_eq!(bytes, 0, "served a request after drain: {sink:?}");
        }
    }
}

#[test]
fn metrics_endpoint_exposes_prometheus_text() {
    let gateway = small_gateway(default_coordinator());
    let addr = gateway.local_addr();
    for id in 0..3 {
        let resp = post_json(addr, "/solve", &codec::distance_job_json(&distance_job(id)));
        assert_eq!(resp.status, 200);
    }

    let resp = request(addr, "GET", "/metrics", b"");
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("content-type"), Some("text/plain; version=0.0.4"));
    let text = String::from_utf8(resp.body.clone()).expect("utf-8 exposition");

    // Scrape-then-parse: every non-comment line is `name[{labels}] value`
    // with a spar_sink_-prefixed name and a parseable float value.
    let mut samples = 0;
    for line in text.lines() {
        if line.starts_with('#') {
            assert!(
                line.starts_with("# HELP spar_sink_") || line.starts_with("# TYPE spar_sink_"),
                "{line}"
            );
            continue;
        }
        let (name, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad line '{line}'"));
        assert!(name.starts_with("spar_sink_"), "{line}");
        assert!(value.parse::<f64>().is_ok(), "{line}");
        samples += 1;
    }
    assert!(samples >= 20, "only {samples} samples in:\n{text}");

    // The counters reflect the service's actual state.
    let completed = text
        .lines()
        .find(|l| l.starts_with("spar_sink_jobs_completed_total "))
        .and_then(|l| l.rsplit_once(' '))
        .and_then(|(_, v)| v.parse::<f64>().ok())
        .expect("jobs_completed_total sample");
    assert_eq!(completed, 3.0);
    assert!(text.contains("# TYPE spar_sink_jobs_completed_total counter"), "{text}");
    assert!(text.contains("spar_sink_shard_completed_total{shard=\"0\"}"), "{text}");

    gateway.shutdown();
}

#[test]
fn protocol_errors_carry_exact_statuses_over_the_wire() {
    let gateway = small_gateway(default_coordinator());
    let addr = gateway.local_addr();

    assert_eq!(request(addr, "GET", "/no-such-endpoint", b"").status, 404);
    let resp = request(addr, "DELETE", "/solve", b"");
    assert_eq!(resp.status, 405);
    assert_eq!(resp.header("allow"), Some("POST"));

    let resp = request(addr, "POST", "/solve", b"this is not json");
    assert_eq!(resp.status, 400);
    assert!(resp.json().get("error").unwrap().as_str().unwrap().contains("bad JSON"));

    // Header overflow straight over the socket: 431 and the connection
    // closes.
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(120))).expect("timeout");
    write!(stream, "GET /healthz HTTP/1.1\r\nx-big: {}\r\n\r\n", "x".repeat(9000))
        .expect("oversized header");
    let resp = read_response(&mut BufReader::new(stream));
    assert_eq!(resp.status, 431);

    gateway.shutdown();
}
