//! Artifact discovery and compilation cache.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::util::json::Json;

/// An AOT entry point name.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Entry {
    SinkhornBlock,
    OtObjective,
    UotObjective,
    KernelFromCost,
}

impl Entry {
    pub fn name(&self) -> &'static str {
        match self {
            Entry::SinkhornBlock => "sinkhorn_block",
            Entry::OtObjective => "ot_objective",
            Entry::UotObjective => "uot_objective",
            Entry::KernelFromCost => "kernel_from_cost",
        }
    }

    fn from_name(s: &str) -> Option<Entry> {
        match s {
            "sinkhorn_block" => Some(Entry::SinkhornBlock),
            "ot_objective" => Some(Entry::OtObjective),
            "uot_objective" => Some(Entry::UotObjective),
            "kernel_from_cost" => Some(Entry::KernelFromCost),
            _ => None,
        }
    }
}

/// Path to the manifest inside an artifact directory.
pub fn manifest_path(dir: &Path) -> PathBuf {
    dir.join("manifest.json")
}

struct ManifestRecord {
    entry: Entry,
    n: usize,
    file: PathBuf,
}

/// Compiles artifacts on demand and caches the executables.
pub struct ArtifactRegistry {
    client: xla::PjRtClient,
    records: Vec<ManifestRecord>,
    /// Fused scaling iterations per `sinkhorn_block` call.
    block_iters: usize,
    cache: Mutex<HashMap<(Entry, usize), std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl ArtifactRegistry {
    /// Open an artifact directory (reads `manifest.json`, creates the
    /// PJRT CPU client; compilation happens lazily per entry/size).
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest_file = manifest_path(dir);
        let text = std::fs::read_to_string(&manifest_file).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_file.display()
            ))
        })?;
        let manifest =
            Json::parse(&text).map_err(|e| Error::Runtime(format!("bad manifest: {e}")))?;
        let block_iters = manifest
            .get("block_iters")
            .and_then(|j| j.as_f64())
            .ok_or_else(|| Error::Runtime("manifest missing block_iters".into()))?
            as usize;
        let mut records = Vec::new();
        for item in manifest
            .get("artifacts")
            .ok_or_else(|| Error::Runtime("manifest missing artifacts".into()))?
            .items()
        {
            let entry_name = item
                .get("entry")
                .and_then(|j| j.as_str())
                .ok_or_else(|| Error::Runtime("artifact missing entry".into()))?;
            let Some(entry) = Entry::from_name(entry_name) else {
                continue; // forward-compatible: skip unknown entries
            };
            let n = item
                .get("n")
                .and_then(|j| j.as_f64())
                .ok_or_else(|| Error::Runtime("artifact missing n".into()))?
                as usize;
            let file = item
                .get("file")
                .and_then(|j| j.as_str())
                .ok_or_else(|| Error::Runtime("artifact missing file".into()))?;
            records.push(ManifestRecord { entry, n, file: dir.join(file) });
        }
        if records.is_empty() {
            return Err(Error::Runtime("manifest lists no usable artifacts".into()));
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(ArtifactRegistry { client, records, block_iters, cache: Mutex::new(HashMap::new()) })
    }

    /// Scaling iterations fused into one `sinkhorn_block` execution.
    pub fn block_iters(&self) -> usize {
        self.block_iters
    }

    /// Sizes available for an entry, ascending.
    pub fn sizes(&self, entry: Entry) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .records
            .iter()
            .filter(|r| r.entry == entry)
            .map(|r| r.n)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Smallest compiled size ≥ `n` for the entry.
    pub fn padded_size(&self, entry: Entry, n: usize) -> Result<usize> {
        self.sizes(entry)
            .into_iter()
            .find(|&m| m >= n)
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "no artifact of entry {} compiled for n >= {n} (menu: {:?})",
                    entry.name(),
                    self.sizes(entry)
                ))
            })
    }

    /// Get (compiling if needed) the executable for (entry, n-exact).
    pub fn executable(
        &self,
        entry: Entry,
        n: usize,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(exe) = cache.get(&(entry, n)) {
                return Ok(exe.clone());
            }
        }
        let record = self
            .records
            .iter()
            .find(|r| r.entry == entry && r.n == n)
            .ok_or_else(|| {
                Error::Runtime(format!("artifact {}_n{n} not in manifest", entry.name()))
            })?;
        let path_str = record.file.to_string_lossy().to_string();
        let proto = xla::HloModuleProto::from_text_file(&path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.cache
            .lock()
            .unwrap()
            .insert((entry, n), exe.clone());
        Ok(exe)
    }

    /// The underlying PJRT client (platform name etc.).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = crate::runtime::default_artifact_dir();
        if manifest_path(&dir).exists() {
            Some(dir)
        } else {
            None
        }
    }

    #[test]
    fn open_registry_and_list_sizes() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let reg = ArtifactRegistry::open(&dir).unwrap();
        let sizes = reg.sizes(Entry::SinkhornBlock);
        assert!(!sizes.is_empty());
        assert!(sizes.windows(2).all(|w| w[0] < w[1]));
        assert!(reg.block_iters() > 0);
    }

    #[test]
    fn padded_size_selects_next_menu_size() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let reg = ArtifactRegistry::open(&dir).unwrap();
        let sizes = reg.sizes(Entry::SinkhornBlock);
        let smallest = sizes[0];
        assert_eq!(reg.padded_size(Entry::SinkhornBlock, 1).unwrap(), smallest);
        assert_eq!(
            reg.padded_size(Entry::SinkhornBlock, smallest).unwrap(),
            smallest
        );
        let too_big = sizes.last().unwrap() + 1;
        assert!(reg.padded_size(Entry::SinkhornBlock, too_big).is_err());
    }

    #[test]
    fn missing_dir_is_a_clean_error() {
        let err = ArtifactRegistry::open(Path::new("/nonexistent-artifacts"));
        assert!(matches!(err, Err(Error::Runtime(_))));
    }
}
