//! Workload generators for every experiment in the paper: the C1–C3
//! synthetic measures, the R1–R3 WFR sparsity regimes, synthetic
//! echocardiogram videos (Table 1 / Figs. 6-7 substitution), digit
//! glyphs for barycenters (Fig. 12), and RGB point clouds for color
//! transfer (Fig. 13).

pub mod digits;
pub mod echo;
pub mod images;
pub mod synthetic;
