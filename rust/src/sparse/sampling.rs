//! Importance sparsification (the paper's core contribution).
//!
//! Given a kernel oracle `K(i,j)` and cost oracle `C(i,j)`, constructs
//! the Poisson-sampled sparse sketch `K̃` of Eq. (7):
//!
//! ```text
//! K̃_ij = K_ij / p*_ij   with prob. p*_ij = min(1, s·p_ij),   else 0,
//! ```
//!
//! with the importance probabilities
//!
//! * OT  (Eq. 9):  p_ij ∝ √(a_i b_j) — separable, so normalization is
//!   O(n) and sampling needs no O(n²) pre-pass;
//! * UOT (Eq. 11): p_ij ∝ (a_i b_j)^{λ/(2λ+ε)} K_ij^{ε/(2λ+ε)} — needs
//!   one O(nnz(K)) normalization pass;
//! * uniform (the Rand-Sink ablation): p_ij = 1/n².
//!
//! A shrinkage mixing `p ← θ·p + (1−θ)/n²` implements condition (ii) of
//! Theorem 1 (probabilities bounded below by c₃·s/n²).
//!
//! The `_logk` variants take a LOG-kernel oracle `ln K(i,j)` instead:
//! they sample the same probabilities but store exact log-kernel values
//! in the sketch (`CsrMatrix::from_rows_logk`), so entries whose linear
//! kernel value underflows f64 — the small-ε regime — are preserved for
//! the log-domain scaling loop instead of being silently dropped.
//!
//! ## Probability factorization (the shared-cost artifact engine)
//!
//! Every importance probability splits into a cost-dependent factor and
//! a per-job marginal factor:
//!
//! * OT (Eq. 9) and IBP (Appendix A.2) probabilities are purely
//!   marginal — their amortizable part is the kernel/cost ORACLE itself,
//!   which [`CostSource::Shared`](crate::api::CostSource) serves from
//!   cached [`CostArtifacts`](crate::engine::CostArtifacts) matrices
//!   instead of re-deriving per job;
//! * the UOT probability (Eq. 11) additionally carries the
//!   cost-dependent `K_ij^β` (log domain: `β·ln K_ij`), which
//!   [`poisson_sparsify_uot_logk_amortized`] consumes precomputed from
//!   the artifacts, leaving only the O(n + m) marginal factor
//!   `α(ln a_i + ln b_j)` per job.
//!
//! The amortized paths compose probabilities with the same arithmetic
//! and consume the same RNG streams as the cold samplers, so sketches
//! are bitwise identical (pinned by `rust/tests/cache_parity.rs`).

use super::csr::CsrMatrix;
use crate::error::{Error, Result};
use crate::pool;
use crate::rng::Rng;

/// Largest `rows × cols` grid the samplers materialize intermediate
/// per-entry buffers for (UOT weight/log-weight stores); larger
/// problems fall back to memory-free two-pass oracles. This is THE
/// materialization cap — the artifact engine's
/// [`SHARED_ARTIFACT_ENTRY_CAP`](crate::engine::SHARED_ARTIFACT_ENTRY_CAP)
/// aliases it so the two memory policies cannot drift apart.
pub const MATERIALIZE_CAP: usize = 16_000_000;

/// Statistics about one sparsification pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct SparsifyStats {
    /// Stored non-zeros in the sketch.
    pub nnz: usize,
    /// Expected sample budget `s` used.
    pub budget: f64,
    /// Entries whose clipped probability hit 1 (kept deterministically).
    pub saturated: usize,
}

fn validate_common(s: f64, shrinkage: f64) -> Result<()> {
    if !(0.0..=1.0).contains(&shrinkage) {
        return Err(Error::InvalidParam(format!("shrinkage {shrinkage} outside [0,1]")));
    }
    if s <= 0.0 {
        return Err(Error::InvalidParam(format!("budget s = {s} must be positive")));
    }
    Ok(())
}

/// Shared Poisson-sampling core. `entry` gates an (i, j) BEFORE any RNG
/// is consumed (out-of-support entries return `None` and never draw,
/// keeping per-row streams deterministic) and yields the normalized
/// importance probability plus an oracle context `G` (e.g. the kernel
/// value, so it is evaluated once); `make` turns an accepted entry into
/// `(kernel, log_kernel, cost)` given its context and clipped
/// probability `p*`. Saturated entries (`p* ≥ 1`, kept
/// deterministically) are counted across the support.
fn poisson_core<G>(
    n_rows: usize,
    n_cols: usize,
    entry: impl Fn(usize, usize) -> Option<(f64, G)> + Sync,
    make: impl Fn(usize, usize, G, f64) -> Option<(f64, f64, f64)> + Sync,
    s: f64,
    shrinkage: f64,
    rng: &mut Rng,
) -> Result<(CsrMatrix, SparsifyStats)> {
    validate_common(s, shrinkage)?;
    let unif = 1.0 / ((n_rows as f64) * (n_cols as f64));
    // Per-row RNG streams keep the pass deterministic AND parallel.
    let mut seeds = Vec::with_capacity(n_rows);
    for _ in 0..n_rows {
        seeds.push(rng.next_u64());
    }
    let theta = shrinkage;
    let rows: Vec<(Vec<(u32, f64, f64, f64)>, usize)> = pool::parallel_map(n_rows, |i| {
        let mut r = Rng::seed_from(seeds[i]);
        let mut entries = Vec::new();
        let mut saturated = 0usize;
        for j in 0..n_cols {
            let Some((p_imp, ctx)) = entry(i, j) else {
                continue;
            };
            let p = theta * p_imp + (1.0 - theta) * unif;
            let p_star = (s * p).min(1.0);
            if p_star <= 0.0 {
                continue;
            }
            if p_star >= 1.0 {
                saturated += 1;
            }
            if r.uniform() < p_star {
                if let Some(made) = make(i, j, ctx, p_star) {
                    entries.push((j as u32, made.0, made.1, made.2));
                }
            }
        }
        (entries, saturated)
    });
    let saturated: usize = rows.iter().map(|(_, c)| *c).sum();
    let nnz: usize = rows.iter().map(|(r, _)| r.len()).sum();
    let m =
        CsrMatrix::from_rows_logk(n_rows, n_cols, rows.into_iter().map(|(r, _)| r).collect());
    Ok((m, SparsifyStats { nnz, budget: s, saturated }))
}

/// Poisson-sparsify with explicit (unnormalized) probability oracle.
///
/// `prob(i, j)` must return a non-negative weight; `total_prob` is the sum
/// over the entire support (entries where `kernel(i,j) > 0`). Entries with
/// zero kernel value are never sampled.
#[allow(clippy::too_many_arguments)]
pub fn poisson_sparsify_with(
    n_rows: usize,
    n_cols: usize,
    kernel: impl Fn(usize, usize) -> f64 + Sync,
    cost: impl Fn(usize, usize) -> f64 + Sync,
    prob: impl Fn(usize, usize) -> f64 + Sync,
    total_prob: f64,
    s: f64,
    shrinkage: f64,
    rng: &mut Rng,
) -> Result<(CsrMatrix, SparsifyStats)> {
    if s > 0.0 && total_prob <= 0.0 {
        return Err(Error::InvalidParam(format!(
            "budget s = {s} and total probability {total_prob} must be positive"
        )));
    }
    poisson_core(
        n_rows,
        n_cols,
        |i, j| {
            let k = kernel(i, j);
            if k > 0.0 {
                Some((prob(i, j) / total_prob, k))
            } else {
                None
            }
        },
        |i, j, k, p_star| {
            let kt = k / p_star;
            Some((kt, kt.ln(), cost(i, j)))
        },
        s,
        shrinkage,
        rng,
    )
}

/// Inner separable sampler shared by the kernel- and log-kernel-oracle
/// OT sparsifiers: `p*_ij = min(1, s(θ√(a_i b_j)/total + (1−θ)/nm))`
/// depends only on the marginals, so `make` is invoked lazily for
/// SELECTED entries only (~s oracle evaluations instead of n²).
fn separable_ot_core(
    make: impl Fn(usize, usize, f64) -> Option<(f64, f64, f64)> + Sync,
    a: &[f64],
    b: &[f64],
    s: f64,
    shrinkage: f64,
    rng: &mut Rng,
) -> Result<(CsrMatrix, SparsifyStats)> {
    validate_common(s, shrinkage)?;
    if a.iter().any(|&x| x < 0.0) || b.iter().any(|&x| x < 0.0) {
        return Err(Error::InvalidParam("marginals must be non-negative".into()));
    }
    let n = a.len();
    let m = b.len();
    let sqrt_a: Vec<f64> = a.iter().map(|x| x.sqrt()).collect();
    let sqrt_b: Vec<f64> = b.iter().map(|x| x.sqrt()).collect();
    let sum_a: f64 = sqrt_a.iter().sum();
    let sum_b: f64 = sqrt_b.iter().sum();
    let total = sum_a * sum_b;
    if total <= 0.0 {
        return Err(Error::InvalidParam("total probability mass is zero".into()));
    }
    // p*_ij = min(1, s·(θ·√a_i·√b_j/total + (1−θ)/(nm)))
    //       = min(1, row_coef_i·√b_j + unif_coef)
    let unif_coef = s * (1.0 - shrinkage) / (n as f64 * m as f64);
    let mut seeds = Vec::with_capacity(n);
    for _ in 0..n {
        seeds.push(rng.next_u64());
    }
    let max_sqrt_b = sqrt_b.iter().cloned().fold(0.0f64, f64::max);
    let make = &make;
    let rows: Vec<(Vec<(u32, f64, f64, f64)>, usize)> = pool::parallel_map(n, |i| {
        let mut r = Rng::seed_from(seeds[i]);
        let row_coef = s * shrinkage * sqrt_a[i] / total;
        let p_max = (row_coef * max_sqrt_b + unif_coef).min(1.0);
        let mut entries = Vec::new();
        let mut saturated = 0usize;
        if p_max <= 0.0 {
            return (entries, saturated);
        }
        if p_max < 0.2 {
            // Geometric skip-sampling (thinning): bound every p*_ij by
            // p_max, jump ahead Geometric(p_max) columns, then accept
            // the landing column with probability p*_ij / p_max. Exact,
            // and reduces per-row work from O(m) RNG draws to
            // O(m·p_max) ≈ O(s_i · max√b/avg√b). Every probability in
            // this branch is below p_max < 1, so nothing can saturate.
            let log1m = (1.0 - p_max).ln();
            let mut j = 0usize;
            loop {
                let u = r.uniform().max(f64::MIN_POSITIVE);
                j += (u.ln() / log1m) as usize;
                if j >= m {
                    break;
                }
                let p_star = (row_coef * sqrt_b[j] + unif_coef).min(1.0);
                if r.uniform() * p_max < p_star {
                    if let Some(entry) = make(i, j, p_star) {
                        entries.push((j as u32, entry.0, entry.1, entry.2));
                    }
                }
                j += 1;
            }
        } else {
            for (j, &sb) in sqrt_b.iter().enumerate() {
                let p_star = (row_coef * sb + unif_coef).min(1.0);
                if p_star <= 0.0 {
                    continue;
                }
                if r.uniform() < p_star {
                    if let Some(entry) = make(i, j, p_star) {
                        // p* ≥ 1 always passes the draw, so counting
                        // stored entries here matches poisson_core's
                        // support-gated count: blocked entries (make =
                        // None) are kept out of the statistic.
                        if p_star >= 1.0 {
                            saturated += 1;
                        }
                        entries.push((j as u32, entry.0, entry.1, entry.2));
                    }
                }
            }
        }
        (entries, saturated)
    });
    let saturated: usize = rows.iter().map(|(_, c)| *c).sum();
    let nnz: usize = rows.iter().map(|(r, _)| r.len()).sum();
    let msk = CsrMatrix::from_rows_logk(n, m, rows.into_iter().map(|(r, _)| r).collect());
    Ok((msk, SparsifyStats { nnz, budget: s, saturated }))
}

/// Spar-Sink sparsifier for OT (Eq. 9): `p_ij ∝ √(a_i b_j)`.
///
/// Separability makes the normalization `Σ√a · Σ√b` exact in O(n), and —
/// unlike the UOT probability — `p_ij` does not depend on `K_ij`, so the
/// kernel oracle is only evaluated for SELECTED entries (the §Perf lazy
/// evaluation: ~s kernel/exp calls instead of n²).
pub fn poisson_sparsify_ot(
    kernel: impl Fn(usize, usize) -> f64 + Sync,
    cost: impl Fn(usize, usize) -> f64 + Sync,
    a: &[f64],
    b: &[f64],
    s: f64,
    shrinkage: f64,
    rng: &mut Rng,
) -> Result<(CsrMatrix, SparsifyStats)> {
    let kernel = &kernel;
    let cost = &cost;
    separable_ot_core(
        |i, j, p_star| {
            // Lazy kernel evaluation: only for selected entries.
            let k = kernel(i, j);
            if k > 0.0 {
                let kt = k / p_star;
                Some((kt, kt.ln(), cost(i, j)))
            } else {
                None
            }
        },
        a,
        b,
        s,
        shrinkage,
        rng,
    )
}

/// Spar-Sink sparsifier for OT from a LOG-kernel oracle `ln K(i,j)`
/// (−∞ = blocked entry). Selection probabilities are identical to
/// [`poisson_sparsify_ot`] — same RNG stream, same sketch support — but
/// entries whose kernel underflows f64 (`ln K < −745`) are stored with
/// their exact log value instead of being dropped, so the log-domain
/// scaling loop can still iterate on them.
pub fn poisson_sparsify_ot_logk(
    log_kernel: impl Fn(usize, usize) -> f64 + Sync,
    cost: impl Fn(usize, usize) -> f64 + Sync,
    a: &[f64],
    b: &[f64],
    s: f64,
    shrinkage: f64,
    rng: &mut Rng,
) -> Result<(CsrMatrix, SparsifyStats)> {
    let log_kernel = &log_kernel;
    let cost = &cost;
    separable_ot_core(
        |i, j, p_star| {
            let lk = log_kernel(i, j);
            if lk == f64::NEG_INFINITY {
                None
            } else {
                Some((lk.exp() / p_star, lk - p_star.ln(), cost(i, j)))
            }
        },
        a,
        b,
        s,
        shrinkage,
        rng,
    )
}

/// Spar-Sink sparsifier for UOT (Eq. 11):
/// `p_ij ∝ (a_i b_j)^{λ/(2λ+ε)} · K_ij^{ε/(2λ+ε)}`.
///
/// One O(n²) (or O(nnz)) pass computes the normalization; the pass is
/// parallel over rows.
#[allow(clippy::too_many_arguments)]
pub fn poisson_sparsify_uot(
    kernel: impl Fn(usize, usize) -> f64 + Sync,
    cost: impl Fn(usize, usize) -> f64 + Sync,
    a: &[f64],
    b: &[f64],
    lambda: f64,
    eps: f64,
    s: f64,
    shrinkage: f64,
    rng: &mut Rng,
) -> Result<(CsrMatrix, SparsifyStats)> {
    if lambda <= 0.0 || eps <= 0.0 {
        return Err(Error::InvalidParam("lambda and eps must be positive".into()));
    }
    let alpha = lambda / (2.0 * lambda + eps);
    let beta = eps / (2.0 * lambda + eps);
    let pa: Vec<f64> = a.iter().map(|x| x.powf(alpha)).collect();
    let pb: Vec<f64> = b.iter().map(|x| x.powf(alpha)).collect();
    let n = a.len();
    let m = b.len();
    // §Perf: the probability needs K_ij^beta for every support entry.
    // For problems that fit (n*m <= 16M entries) we materialize the
    // weights once and reuse them in the sampling pass, halving the
    // kernel evaluations and removing the duplicated powf; larger
    // problems fall back to the memory-free two-pass oracle.
    if n * m <= MATERIALIZE_CAP {
        let pa_ref = &pa;
        let pb_ref = &pb;
        let kernel_ref = &kernel;
        let weights: Vec<f64> = pool::parallel_map(n * m, |idx| {
            let (i, j) = (idx / m, idx % m);
            let k = kernel_ref(i, j);
            if k <= 0.0 {
                0.0
            } else {
                pa_ref[i] * pb_ref[j] * k.powf(beta)
            }
        });
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return Err(Error::Numerical(
                "UOT sampling weights are all zero (empty kernel?)".into(),
            ));
        }
        let w_ref = &weights;
        return poisson_sparsify_with(
            n,
            m,
            &kernel,
            cost,
            move |i, j| w_ref[i * m + j],
            total,
            s,
            shrinkage,
            rng,
        );
    }
    let kernel_ref = &kernel;
    let weight = move |i: usize, j: usize| {
        let k = kernel_ref(i, j);
        if k <= 0.0 {
            0.0
        } else {
            pa[i] * pb[j] * k.powf(beta)
        }
    };
    let total = pool::parallel_fold(
        n,
        |start, end| {
            let mut acc = 0.0;
            for i in start..end {
                for j in 0..m {
                    acc += weight(i, j);
                }
            }
            acc
        },
        |x, y| x + y,
        0.0,
    );
    if total <= 0.0 {
        return Err(Error::Numerical("UOT sampling weights are all zero (empty kernel?)".into()));
    }
    poisson_sparsify_with(n, m, &kernel, cost, &weight, total, s, shrinkage, rng)
}

/// Spar-Sink sparsifier for UOT from a LOG-kernel oracle: Eq. 11
/// computed entirely in the log domain. Log-weights
/// `lw_ij = α(log a_i + log b_j) + β·ln K_ij` are normalized via a
/// streaming log-sum-exp, so the probabilities stay meaningful even when
/// every linear kernel entry underflows f64 — the regime where
/// [`poisson_sparsify_uot`] fails with an "all zero" error.
#[allow(clippy::too_many_arguments)]
pub fn poisson_sparsify_uot_logk(
    log_kernel: impl Fn(usize, usize) -> f64 + Sync,
    cost: impl Fn(usize, usize) -> f64 + Sync,
    a: &[f64],
    b: &[f64],
    lambda: f64,
    eps: f64,
    s: f64,
    shrinkage: f64,
    rng: &mut Rng,
) -> Result<(CsrMatrix, SparsifyStats)> {
    if lambda <= 0.0 || eps <= 0.0 {
        return Err(Error::InvalidParam("lambda and eps must be positive".into()));
    }
    // Fail on bad s/shrinkage BEFORE the O(n·m) weight passes.
    validate_common(s, shrinkage)?;
    let alpha = lambda / (2.0 * lambda + eps);
    let beta = eps / (2.0 * lambda + eps);
    let la: Vec<f64> =
        a.iter().map(|&x| if x > 0.0 { x.ln() } else { f64::NEG_INFINITY }).collect();
    let lb: Vec<f64> =
        b.iter().map(|&x| if x > 0.0 { x.ln() } else { f64::NEG_INFINITY }).collect();
    let n = a.len();
    let m = b.len();
    let log_kernel = &log_kernel;
    let la_ref = &la;
    let lb_ref = &lb;
    // Encoding: NaN = blocked entry (zero kernel, never sampled);
    // −∞ = zero importance weight but positive kernel — still reachable
    // through the shrinkage floor, like the linear sampler's zero-mass
    // rows (condition (ii) of Theorem 1).
    let lw_eval = move |i: usize, j: usize| -> f64 {
        let lk = log_kernel(i, j);
        if lk == f64::NEG_INFINITY {
            return f64::NAN;
        }
        if la_ref[i] == f64::NEG_INFINITY || lb_ref[j] == f64::NEG_INFINITY {
            return f64::NEG_INFINITY;
        }
        alpha * (la_ref[i] + lb_ref[j]) + beta * lk
    };
    // Materialize the log-weights when they fit (one oracle call per
    // entry instead of three: normalization + support + probability).
    let lw_store: Option<Vec<f64>> = if n * m <= MATERIALIZE_CAP {
        Some(pool::parallel_map(n * m, |idx| lw_eval(idx / m, idx % m)))
    } else {
        None
    };
    let lw_store = &lw_store;
    let lw = move |i: usize, j: usize| -> f64 {
        match lw_store {
            Some(v) => v[i * m + j],
            None => lw_eval(i, j),
        }
    };
    uot_logk_from_lw(n, m, lw, log_kernel, cost, s, shrinkage, rng)
}

/// Spar-Sink sparsifier for UOT from a LOG-kernel oracle with the
/// cost-dependent probability factor PRECOMPUTED: `beta_log_kernel`
/// holds `β·ln K_ij` per entry (`NaN` = blocked entry, i.e. zero
/// kernel), typically amortized across a batch from
/// [`CostArtifacts::uot_factor`](crate::engine::CostArtifacts). Per job
/// only the marginal factor `α(ln a_i + ln b_j)` is computed — O(n + m)
/// transcendental work instead of O(n·m).
///
/// Log-weights, normalization, RNG consumption and the stored sketch
/// are bitwise-identical to [`poisson_sparsify_uot_logk`] with the same
/// oracle and the same (λ, ε): the cold path evaluates
/// `α(ln a_i + ln b_j) + β·ln K_ij` with `β·ln K_ij` computed inline,
/// this one reads the identical product from the factor.
#[allow(clippy::too_many_arguments)]
pub fn poisson_sparsify_uot_logk_amortized(
    beta_log_kernel: &[f64],
    alpha: f64,
    log_kernel: impl Fn(usize, usize) -> f64 + Sync,
    cost: impl Fn(usize, usize) -> f64 + Sync,
    a: &[f64],
    b: &[f64],
    s: f64,
    shrinkage: f64,
    rng: &mut Rng,
) -> Result<(CsrMatrix, SparsifyStats)> {
    let (n, m) = (a.len(), b.len());
    if beta_log_kernel.len() != n * m {
        return Err(Error::Dimension(format!(
            "amortized UOT factor has {} entries for a {n}x{m} problem",
            beta_log_kernel.len()
        )));
    }
    if !(alpha.is_finite() && alpha > 0.0) {
        return Err(Error::InvalidParam(format!("alpha = {alpha} must be positive")));
    }
    validate_common(s, shrinkage)?;
    let la: Vec<f64> =
        a.iter().map(|&x| if x > 0.0 { x.ln() } else { f64::NEG_INFINITY }).collect();
    let lb: Vec<f64> =
        b.iter().map(|&x| if x > 0.0 { x.ln() } else { f64::NEG_INFINITY }).collect();
    let la = &la;
    let lb = &lb;
    let lw = move |i: usize, j: usize| -> f64 {
        let blk = beta_log_kernel[i * m + j];
        if blk.is_nan() {
            return f64::NAN; // blocked entry (zero kernel)
        }
        if la[i] == f64::NEG_INFINITY || lb[j] == f64::NEG_INFINITY {
            return f64::NEG_INFINITY; // zero weight; shrinkage floor applies
        }
        alpha * (la[i] + lb[j]) + blk
    };
    uot_logk_from_lw(n, m, lw, log_kernel, cost, s, shrinkage, rng)
}

/// Shared tail of the log-domain UOT samplers: normalize the composed
/// log-weights `lw(i, j)` (encoding: `NaN` = blocked entry, never
/// sampled; `−∞` = zero importance weight but positive kernel, still
/// reachable through the shrinkage floor) via a streaming log-sum-exp
/// and run the Poisson core.
#[allow(clippy::too_many_arguments)]
fn uot_logk_from_lw(
    n: usize,
    m: usize,
    lw: impl Fn(usize, usize) -> f64 + Sync,
    log_kernel: impl Fn(usize, usize) -> f64 + Sync,
    cost: impl Fn(usize, usize) -> f64 + Sync,
    s: f64,
    shrinkage: f64,
    rng: &mut Rng,
) -> Result<(CsrMatrix, SparsifyStats)> {
    let lw = &lw;
    let log_kernel = &log_kernel;
    // Streaming LSE of the log-weights over the whole support — one
    // O(n·m) pass, parallel over row blocks, (max, scaled-sum) pairs
    // merged associatively.
    let (mx, sm) = pool::parallel_fold(
        n,
        |start, end| {
            let mut mx = f64::NEG_INFINITY;
            let mut sm = 0.0f64;
            for i in start..end {
                for j in 0..m {
                    let w = lw(i, j);
                    if w == f64::NEG_INFINITY || w.is_nan() {
                        continue;
                    }
                    if w > mx {
                        sm = sm * (mx - w).exp() + 1.0;
                        mx = w;
                    } else {
                        sm += (w - mx).exp();
                    }
                }
            }
            (mx, sm)
        },
        |(mx_a, sm_a), (mx_b, sm_b)| {
            if mx_b == f64::NEG_INFINITY {
                (mx_a, sm_a)
            } else if mx_a == f64::NEG_INFINITY {
                (mx_b, sm_b)
            } else if mx_b > mx_a {
                (mx_b, sm_a * (mx_a - mx_b).exp() + sm_b)
            } else {
                (mx_a, sm_a + sm_b * (mx_b - mx_a).exp())
            }
        },
        (f64::NEG_INFINITY, 0.0),
    );
    if mx == f64::NEG_INFINITY {
        return Err(Error::Numerical(
            "UOT sampling weights are all zero (empty kernel?)".into(),
        ));
    }
    let log_total = mx + sm.ln();
    let cost = &cost;
    poisson_core(
        n,
        m,
        |i, j| {
            let w = lw(i, j);
            if w.is_nan() {
                None // blocked entry (zero kernel)
            } else if w == f64::NEG_INFINITY {
                Some((0.0, ())) // zero weight; shrinkage floor applies
            } else {
                Some(((w - log_total).exp(), ()))
            }
        },
        |i, j, _ctx, p_star| {
            let lk = log_kernel(i, j);
            Some((lk.exp() / p_star, lk - p_star.ln(), cost(i, j)))
        },
        s,
        shrinkage,
        rng,
    )
}

/// Spar-IBP sparsifier (Appendix A.2) from a LOG-kernel oracle:
/// `p_{ij} ∝ √(b_j)` — row-uniform, the unknown barycenter replaced by
/// the uniform `q⁽⁰⁾ = 1/n` exactly as in
/// [`sparsify_ibp_kernel`](crate::solvers::spar_ibp::sparsify_ibp_kernel).
/// Selection probabilities, normalization arithmetic and RNG consumption
/// are identical to that linear sampler wherever the kernel has not
/// underflowed, so the two produce the SAME sketch support at moderate ε;
/// sampled entries here additionally keep their exact `ln K̃`, keeping the
/// sketch solvable by the log-domain IBP engine at any ε.
pub fn poisson_sparsify_ibp_logk(
    n_rows: usize,
    log_kernel: impl Fn(usize, usize) -> f64 + Sync,
    b_k: &[f64],
    s: f64,
    shrinkage: f64,
    rng: &mut Rng,
) -> Result<(CsrMatrix, SparsifyStats)> {
    let sqrt_b: Vec<f64> = b_k.iter().map(|x| x.sqrt()).collect();
    let total = n_rows as f64 * sqrt_b.iter().sum::<f64>();
    if s > 0.0 && total <= 0.0 {
        return Err(Error::InvalidParam(format!(
            "budget s = {s} and total probability {total} must be positive"
        )));
    }
    let sqrt_b = &sqrt_b;
    let log_kernel = &log_kernel;
    poisson_core(
        n_rows,
        b_k.len(),
        |i, j| {
            let lk = log_kernel(i, j);
            if lk == f64::NEG_INFINITY {
                None
            } else {
                Some((sqrt_b[j] / total, lk))
            }
        },
        // IBP needs no per-entry costs (cf. the linear sampler's zero
        // cost oracle), so store 0.
        |_, _, lk, p_star| Some((lk.exp() / p_star, lk - p_star.ln(), 0.0)),
        s,
        shrinkage,
        rng,
    )
}

/// Sampling-with-replacement ablation for OT (Appendix comparison /
/// Wang & Zou 2021 discussion): draw `s` iid entries from `p_ij` and
/// average `K_ij / (s p_ij)` over draws.
pub fn sample_with_replacement_ot(
    kernel: impl Fn(usize, usize) -> f64,
    cost: impl Fn(usize, usize) -> f64,
    a: &[f64],
    b: &[f64],
    s: usize,
    rng: &mut Rng,
) -> Result<CsrMatrix> {
    use crate::rng::AliasTable;
    let sqrt_a: Vec<f64> = a.iter().map(|x| x.sqrt()).collect();
    let sqrt_b: Vec<f64> = b.iter().map(|x| x.sqrt()).collect();
    let ta = AliasTable::new(&sqrt_a);
    let tb = AliasTable::new(&sqrt_b);
    let sum_a: f64 = sqrt_a.iter().sum();
    let sum_b: f64 = sqrt_b.iter().sum();
    let mut trips = Vec::with_capacity(s);
    for _ in 0..s {
        let i = ta.sample(rng);
        let j = tb.sample(rng);
        let p = (sqrt_a[i] / sum_a) * (sqrt_b[j] / sum_b);
        let k = kernel(i, j);
        if k <= 0.0 {
            continue;
        }
        trips.push(super::csr::Triplet {
            row: i,
            col: j,
            kernel: k / (s as f64 * p),
            cost: cost(i, j),
        });
    }
    CsrMatrix::from_triplets(a.len(), b.len(), trips)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn toy(n: usize) -> (Mat, Mat, Vec<f64>, Vec<f64>) {
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|i| vec![(i as f64 * 0.618).fract(), (i as f64 * 0.383).fract()])
            .collect();
        let cost = crate::ot::cost::sq_euclidean_cost(&pts, &pts);
        let kernel = crate::ot::cost::gibbs_kernel(&cost, 0.2);
        let a = vec![1.0 / n as f64; n];
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 3) as f64).collect();
        let sb: f64 = b.iter().sum();
        let b: Vec<f64> = b.iter().map(|x| x / sb).collect();
        (kernel, cost, a, b)
    }

    #[test]
    fn sketch_is_unbiased_in_expectation() {
        // Average many independent sketches: entries converge to K.
        let (kernel, cost, a, b) = toy(12);
        let mut rng = Rng::seed_from(42);
        let reps = 3000;
        let mut acc = Mat::zeros(12, 12);
        for _ in 0..reps {
            let (sk, _) = poisson_sparsify_ot(
                |i, j| kernel.get(i, j),
                |i, j| cost.get(i, j),
                &a,
                &b,
                40.0,
                1.0,
                &mut rng,
            )
            .unwrap();
            for (i, j, k, _) in sk.iter() {
                acc.set(i, j, acc.get(i, j) + k / reps as f64);
            }
        }
        let mut max_rel = 0.0f64;
        for i in 0..12 {
            for j in 0..12 {
                let want = kernel.get(i, j);
                if want > 0.05 {
                    max_rel = max_rel.max((acc.get(i, j) - want).abs() / want);
                }
            }
        }
        assert!(max_rel < 0.15, "max relative bias {max_rel}");
    }

    #[test]
    fn expected_nnz_close_to_budget() {
        let (kernel, cost, a, b) = toy(40);
        let mut rng = Rng::seed_from(1);
        let s = 300.0;
        let mut total = 0usize;
        let reps = 30;
        for _ in 0..reps {
            let (_, stats) = poisson_sparsify_ot(
                |i, j| kernel.get(i, j),
                |i, j| cost.get(i, j),
                &a,
                &b,
                s,
                1.0,
                &mut rng,
            )
            .unwrap();
            total += stats.nnz;
        }
        let mean = total as f64 / reps as f64;
        // E[nnz] <= s (Section 3.2); with full support it's close to s.
        assert!(mean <= s * 1.05, "mean nnz {mean} exceeds budget {s}");
        assert!(mean >= s * 0.7, "mean nnz {mean} too far below {s}");
    }

    #[test]
    fn zero_kernel_entries_never_sampled() {
        let n = 16;
        let (mut kernel, cost, a, b) = toy(n);
        // Blank out a block.
        for i in 0..n {
            for j in 0..4 {
                kernel.set(i, j, 0.0);
            }
        }
        let mut rng = Rng::seed_from(3);
        let (sk, _) = poisson_sparsify_ot(
            |i, j| kernel.get(i, j),
            |i, j| cost.get(i, j),
            &a,
            &b,
            600.0,
            1.0,
            &mut rng,
        )
        .unwrap();
        for (_, j, k, _) in sk.iter() {
            assert!(j >= 4, "sampled blocked column {j} with value {k}");
        }
    }

    #[test]
    fn shrinkage_keeps_probabilities_positive() {
        // With pure importance probs, a zero-mass row never gets samples;
        // with shrinkage theta < 1, uniform mass floors it (condition ii).
        let n = 10;
        let (kernel, cost, mut a, b) = toy(n);
        a[0] = 0.0;
        let mut rng = Rng::seed_from(5);
        let mut hit_row0 = false;
        for _ in 0..200 {
            let (sk, _) = poisson_sparsify_ot(
                |i, j| kernel.get(i, j),
                |i, j| cost.get(i, j),
                &a,
                &b,
                50.0,
                0.5,
                &mut rng,
            )
            .unwrap();
            if sk.row_entries(0).next().is_some() {
                hit_row0 = true;
                break;
            }
        }
        assert!(hit_row0, "shrinkage should allow sampling zero-weight rows");
    }

    #[test]
    fn uot_probability_prefers_high_kernel_entries() {
        // Two identical (a_i b_j) weights, very different K -> the larger
        // K must be sampled more often.
        let a = vec![0.5, 0.5];
        let b = vec![0.5, 0.5];
        let kval = |i: usize, j: usize| if i == j { 1.0 } else { 1e-6 };
        let mut rng = Rng::seed_from(7);
        let mut diag = 0usize;
        let mut off = 0usize;
        for _ in 0..500 {
            let (sk, _) = poisson_sparsify_uot(
                kval,
                |_, _| 1.0,
                &a,
                &b,
                1.0,
                0.5,
                2.0,
                1.0,
                &mut rng,
            )
            .unwrap();
            for (i, j, _, _) in sk.iter() {
                if i == j {
                    diag += 1;
                } else {
                    off += 1;
                }
            }
        }
        assert!(diag > 10 * off.max(1), "diag {diag} off {off}");
    }

    #[test]
    fn uot_degenerates_to_ot_probability_for_large_lambda() {
        // Eq. 11 -> Eq. 9 as lambda -> inf (the exponent on K vanishes).
        let (kernel, cost, a, b) = toy(8);
        let mut r1 = Rng::seed_from(11);
        let mut r2 = Rng::seed_from(11);
        let (sk_uot, _) = poisson_sparsify_uot(
            |i, j| kernel.get(i, j),
            |i, j| cost.get(i, j),
            &a,
            &b,
            1e12,
            0.1,
            30.0,
            1.0,
            &mut r1,
        )
        .unwrap();
        let (sk_ot, _) = poisson_sparsify_ot(
            |i, j| kernel.get(i, j),
            |i, j| cost.get(i, j),
            &a,
            &b,
            30.0,
            1.0,
            &mut r2,
        )
        .unwrap();
        // Same RNG stream + (numerically) same probabilities -> identical sketches.
        assert_eq!(sk_uot.nnz(), sk_ot.nnz());
        for ((i1, j1, k1, _), (i2, j2, k2, _)) in sk_uot.iter().zip(sk_ot.iter()) {
            assert_eq!((i1, j1), (i2, j2));
            assert!((k1 - k2).abs() < 1e-6 * k2.abs().max(1.0));
        }
    }

    #[test]
    fn with_replacement_unbiased() {
        let (kernel, cost, a, b) = toy(10);
        let mut rng = Rng::seed_from(13);
        let reps = 2000;
        let mut acc = Mat::zeros(10, 10);
        for _ in 0..reps {
            let sk = sample_with_replacement_ot(
                |i, j| kernel.get(i, j),
                |i, j| cost.get(i, j),
                &a,
                &b,
                50,
                &mut rng,
            )
            .unwrap();
            for (i, j, k, _) in sk.iter() {
                acc.set(i, j, acc.get(i, j) + k / reps as f64);
            }
        }
        let mut max_rel = 0.0f64;
        for i in 0..10 {
            for j in 0..10 {
                let want = kernel.get(i, j);
                if want > 0.1 {
                    max_rel = max_rel.max((acc.get(i, j) - want).abs() / want);
                }
            }
        }
        assert!(max_rel < 0.2, "max relative bias {max_rel}");
    }

    #[test]
    fn rejects_bad_params() {
        let (kernel, cost, a, b) = toy(4);
        let mut rng = Rng::seed_from(17);
        assert!(poisson_sparsify_ot(
            |i, j| kernel.get(i, j),
            |i, j| cost.get(i, j),
            &a,
            &b,
            -1.0,
            1.0,
            &mut rng
        )
        .is_err());
        assert!(poisson_sparsify_ot(
            |i, j| kernel.get(i, j),
            |i, j| cost.get(i, j),
            &a,
            &b,
            10.0,
            1.5,
            &mut rng
        )
        .is_err());
    }

    #[test]
    fn saturated_counted_in_all_sampler_paths() {
        // Budget so large that every probability clips at 1: all n²
        // entries are kept deterministically and counted as saturated.
        let n = 6;
        let a = vec![1.0 / n as f64; n];
        let b = vec![1.0 / n as f64; n];
        let s = 3.0 * (n * n) as f64; // p_imp = 1/n² uniform -> s·p = 3
        let mut rng = Rng::seed_from(21);

        // Path 1: separable OT sampler (dense branch, p_max = 1 >= 0.2).
        let (sk, stats) =
            poisson_sparsify_ot(|_, _| 1.0, |_, _| 0.5, &a, &b, s, 1.0, &mut rng).unwrap();
        assert_eq!(stats.nnz, n * n);
        assert_eq!(stats.saturated, n * n, "ot sampler saturated {}", stats.saturated);
        assert_eq!(sk.nnz(), n * n);

        // Path 1b: log-kernel OT sampler counts identically.
        let (_, stats_logk) =
            poisson_sparsify_ot_logk(|_, _| 0.0, |_, _| 0.5, &a, &b, s, 1.0, &mut rng).unwrap();
        assert_eq!(stats_logk.saturated, n * n);

        // Path 2: generic probability-oracle sampler.
        let (_, stats_with) = poisson_sparsify_with(
            n,
            n,
            |_, _| 1.0,
            |_, _| 0.5,
            |_, _| 1.0,
            (n * n) as f64,
            s,
            1.0,
            &mut rng,
        )
        .unwrap();
        assert_eq!(stats_with.saturated, n * n, "with sampler saturated {}", stats_with.saturated);

        // Path 3: UOT samplers (uniform weights -> p_imp = 1/n²).
        let (_, stats_uot) =
            poisson_sparsify_uot(|_, _| 1.0, |_, _| 0.5, &a, &b, 1.0, 0.1, s, 1.0, &mut rng)
                .unwrap();
        assert_eq!(stats_uot.saturated, n * n, "uot sampler saturated {}", stats_uot.saturated);
        let (_, stats_uot_logk) = poisson_sparsify_uot_logk(
            |_, _| 0.0,
            |_, _| 0.5,
            &a,
            &b,
            1.0,
            0.1,
            s,
            1.0,
            &mut rng,
        )
        .unwrap();
        assert_eq!(stats_uot_logk.saturated, n * n);
    }

    #[test]
    fn skip_sampling_branch_reports_zero_saturated() {
        // Small budget on a larger problem drives p_max below the 0.2
        // skip-sampling threshold: probabilities cannot clip there, so
        // saturated must be 0 while nnz is still populated.
        let (kernel, cost, a, b) = toy(40);
        let mut rng = Rng::seed_from(23);
        let (_, stats) = poisson_sparsify_ot(
            |i, j| kernel.get(i, j),
            |i, j| cost.get(i, j),
            &a,
            &b,
            100.0,
            1.0,
            &mut rng,
        )
        .unwrap();
        assert_eq!(stats.saturated, 0);
        assert!(stats.nnz > 0);
    }

    #[test]
    fn logk_sampler_matches_linear_sampler_at_moderate_eps() {
        // With no underflow the two OT samplers consume identical RNG
        // streams and must produce identical sketches.
        let (kernel, cost, a, b) = toy(24);
        let mut r1 = Rng::seed_from(29);
        let mut r2 = Rng::seed_from(29);
        let (sk_lin, st_lin) = poisson_sparsify_ot(
            |i, j| kernel.get(i, j),
            |i, j| cost.get(i, j),
            &a,
            &b,
            200.0,
            1.0,
            &mut r1,
        )
        .unwrap();
        let (sk_log, st_log) = poisson_sparsify_ot_logk(
            |i, j| kernel.get(i, j).ln(),
            |i, j| cost.get(i, j),
            &a,
            &b,
            200.0,
            1.0,
            &mut r2,
        )
        .unwrap();
        assert_eq!(st_lin.nnz, st_log.nnz);
        assert!(sk_log.has_log_kernel());
        for ((i1, j1, k1, _), (i2, j2, k2, _)) in sk_lin.iter().zip(sk_log.iter()) {
            assert_eq!((i1, j1), (i2, j2));
            assert!((k1 - k2).abs() < 1e-12 * k1.abs().max(1.0), "{k1} vs {k2}");
        }
    }

    #[test]
    fn ibp_logk_sampler_matches_linear_ibp_sampler_at_moderate_eps() {
        // Same RNG stream and the same √b_j probabilities as the linear
        // IBP sampler (poisson_sparsify_with + √b oracle): identical
        // sketch support and bitwise-identical kernel values when the
        // log oracle is the exact `−C/ε` the linear kernel exponentiates.
        let (kernel, cost, _, b) = toy(20);
        let n = 20;
        let total = n as f64 * b.iter().map(|x: &f64| x.sqrt()).sum::<f64>();
        let sqrt_b: Vec<f64> = b.iter().map(|x| x.sqrt()).collect();
        let mut r1 = Rng::seed_from(37);
        let mut r2 = Rng::seed_from(37);
        let (sk_lin, st_lin) = poisson_sparsify_with(
            n,
            n,
            |i, j| kernel.get(i, j),
            |_, _| 0.0,
            |_, j| sqrt_b[j],
            total,
            120.0,
            1.0,
            &mut r1,
        )
        .unwrap();
        let (sk_log, st_log) = poisson_sparsify_ibp_logk(
            n,
            |i, j| -cost.get(i, j) / 0.2,
            &b,
            120.0,
            1.0,
            &mut r2,
        )
        .unwrap();
        assert_eq!(st_lin.nnz, st_log.nnz);
        assert!(sk_log.has_log_kernel());
        for ((i1, j1, k1, _), (i2, j2, k2, _)) in sk_lin.iter().zip(sk_log.iter()) {
            assert_eq!((i1, j1), (i2, j2));
            assert_eq!(k1.to_bits(), k2.to_bits(), "{k1} vs {k2}");
        }
    }

    #[test]
    fn ibp_logk_sampler_survives_full_underflow() {
        // Every linear kernel value underflows; the log sampler still
        // stores finite ln K̃ and a usable support.
        let n = 16;
        let b = vec![1.0 / n as f64; n];
        let mut rng = Rng::seed_from(41);
        let lk = |i: usize, j: usize| -1.0e4 * (1.0 + (i + j) as f64);
        let (sk, stats) = poisson_sparsify_ibp_logk(n, lk, &b, 80.0, 1.0, &mut rng).unwrap();
        assert!(stats.nnz > 0);
        assert_eq!(sk.kernel_frob_norm(), 0.0, "linear values should all underflow");
        for (_, _, lk, _) in sk.iter_log() {
            assert!(lk.is_finite());
        }
    }

    #[test]
    fn amortized_uot_logk_matches_cold_sampler_bitwise() {
        // Same oracle, same RNG stream: the amortized sampler (β·ln K
        // precomputed, marginal factor per job) must reproduce the cold
        // sampler's sketch bit for bit — including a zero-mass row
        // reachable only through the shrinkage floor.
        let n = 18;
        let (_, cost, mut a, b) = toy(n);
        a[0] = 0.0;
        let (lambda, eps) = (1.0, 0.05);
        let lk = |i: usize, j: usize| -cost.get(i, j) / eps;
        let alpha = lambda / (2.0 * lambda + eps);
        let beta = eps / (2.0 * lambda + eps);
        let factor: Vec<f64> = (0..n * n)
            .map(|idx| {
                let v = lk(idx / n, idx % n);
                if v == f64::NEG_INFINITY {
                    f64::NAN
                } else {
                    beta * v
                }
            })
            .collect();
        let mut r1 = Rng::seed_from(101);
        let mut r2 = Rng::seed_from(101);
        let (sk_cold, st_cold) = poisson_sparsify_uot_logk(
            lk,
            |i, j| cost.get(i, j),
            &a,
            &b,
            lambda,
            eps,
            80.0,
            0.8,
            &mut r1,
        )
        .unwrap();
        let (sk_warm, st_warm) = poisson_sparsify_uot_logk_amortized(
            &factor,
            alpha,
            lk,
            |i, j| cost.get(i, j),
            &a,
            &b,
            80.0,
            0.8,
            &mut r2,
        )
        .unwrap();
        assert_eq!(st_cold.nnz, st_warm.nnz);
        assert_eq!(st_cold.saturated, st_warm.saturated);
        for ((i1, j1, k1, c1), (i2, j2, k2, c2)) in sk_cold.iter().zip(sk_warm.iter()) {
            assert_eq!((i1, j1), (i2, j2));
            assert_eq!(k1.to_bits(), k2.to_bits());
            assert_eq!(c1.to_bits(), c2.to_bits());
        }
        for ((_, _, l1, _), (_, _, l2, _)) in sk_cold.iter_log().zip(sk_warm.iter_log()) {
            assert_eq!(l1.to_bits(), l2.to_bits());
        }
    }

    #[test]
    fn amortized_uot_logk_rejects_bad_factor() {
        let n = 6;
        let a = vec![1.0 / n as f64; n];
        let factor = vec![0.0; n * n - 1]; // wrong length
        let mut rng = Rng::seed_from(7);
        assert!(poisson_sparsify_uot_logk_amortized(
            &factor,
            0.3,
            |_, _| 0.0,
            |_, _| 0.5,
            &a,
            &a,
            10.0,
            1.0,
            &mut rng
        )
        .is_err());
        let factor = vec![0.0; n * n];
        assert!(poisson_sparsify_uot_logk_amortized(
            &factor,
            f64::NAN,
            |_, _| 0.0,
            |_, _| 0.5,
            &a,
            &a,
            10.0,
            1.0,
            &mut rng
        )
        .is_err());
    }

    #[test]
    fn uot_logk_sampler_survives_full_underflow() {
        // ln K so negative that exp underflows everywhere: the linear
        // UOT sampler errors ("weights all zero"), the log-domain one
        // still samples and stores finite log-kernel values.
        let n = 12;
        let (_, cost, a, b) = toy(n);
        let lk = |i: usize, j: usize| -2.0e4 * (1.0 + cost.get(i, j));
        let mut rng = Rng::seed_from(31);
        let err = poisson_sparsify_uot(
            |i, j| lk(i, j).exp(),
            |i, j| cost.get(i, j),
            &a,
            &b,
            1.0,
            1e-4,
            60.0,
            1.0,
            &mut rng,
        );
        assert!(err.is_err(), "linear sampler should fail on full underflow");
        let mut rng = Rng::seed_from(31);
        let (sk, stats) = poisson_sparsify_uot_logk(
            lk,
            |i, j| cost.get(i, j),
            &a,
            &b,
            1.0,
            1e-4,
            60.0,
            1.0,
            &mut rng,
        )
        .unwrap();
        assert!(stats.nnz > 0, "log sampler produced an empty sketch");
        for (_, _, lkv, _) in sk.iter_log() {
            assert!(lkv.is_finite(), "stored log-kernel not finite: {lkv}");
        }
        // Linear kernel values all underflowed to 0 but entries remain.
        assert_eq!(sk.kernel_frob_norm(), 0.0);
    }
}
