//! Tree-level contract-lint gate: the shipped source must lint clean
//! under the committed `lint.toml`, mirroring what `repro lint` (and
//! the CI lint job) runs. A regression that trips any rule in
//! `spar_sink::lint::RULES` fails `cargo test` before CI even gets to
//! the dedicated lint step.

use spar_sink::lint::{lint_source, lint_tree, LintConfig};
use std::path::Path;

fn committed_config(manifest: &Path) -> LintConfig {
    match std::fs::read_to_string(manifest.join("../lint.toml")) {
        Ok(text) => LintConfig::parse(&text).expect("committed lint.toml parses"),
        Err(_) => LintConfig::empty(),
    }
}

#[test]
fn shipped_tree_lints_clean() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let findings = lint_tree(&manifest.join("src"), &committed_config(manifest))
        .expect("tree walk succeeds");
    assert!(findings.is_empty(), "contract-lint findings on the shipped tree:\n{findings:#?}");
}

#[test]
fn fixture_corpus_is_skipped_by_the_walk_but_fires_directly() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let fixtures = manifest.join("src/lint/fixtures");
    assert!(fixtures.join("budget_bad.rs").is_file(), "fixture corpus missing");
    // `shipped_tree_lints_clean` above passes even though the fixture
    // files under src/ contain seeded violations — because the walk
    // skips lint/fixtures/. Linting one directly must still fire.
    let bad = std::fs::read_to_string(fixtures.join("lock_bad.rs")).expect("fixture readable");
    let findings = lint_source("pool/fixture.rs", &bad, &LintConfig::empty());
    assert!(!findings.is_empty(), "seeded fixture must fire when linted directly");
}
