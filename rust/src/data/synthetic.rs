//! Section 5.1 synthetic workloads.
//!
//! * **C1** — a, b empirical Gaussians N(1/3, 1/20), N(1/2, 1/20);
//!   supports x_i ~ U(0,1)^d.
//! * **C2** — same a, b; supports x_i ~ N(0_d, Σ), Σ_jk = 0.5^|j−k|.
//! * **C3** — a, b empirical t₅(1/3, 1/20), t₅(1/2, 1/20); supports as C1.
//!
//! "Empirical Gaussian N(μ, σ²)" follows the standard construction in
//! the POT examples the paper builds on: draw n values from the
//! distribution, take absolute weights, and normalize to the simplex.
//!
//! UOT experiments additionally scale total masses to 5 and 3 and select
//! the WFR η for target kernel densities ~70%/50%/30% (**R1–R3**).

use crate::rng::Rng;

/// Scenario tag for the data-generation patterns of Section 5.1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    /// C1: Gaussian point clouds, near-uniform marginals.
    C1,
    /// C2: heavier-tailed marginal skew.
    C2,
    /// C3: strongly clustered supports.
    C3,
}

impl Scenario {
    /// All three scenarios, in paper order.
    pub fn all() -> [Scenario; 3] {
        [Scenario::C1, Scenario::C2, Scenario::C3]
    }

    /// Label used in experiment output rows.
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::C1 => "C1",
            Scenario::C2 => "C2",
            Scenario::C3 => "C3",
        }
    }
}

/// WFR kernel sparsity regimes (Section 5.1): target nnz fractions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SparsityRegime {
    /// R1: densest regime (largest target kernel density).
    R1,
    /// R2: intermediate density.
    R2,
    /// R3: sparsest regime.
    R3,
}

impl SparsityRegime {
    /// All three regimes, in paper order.
    pub fn all() -> [SparsityRegime; 3] {
        [SparsityRegime::R1, SparsityRegime::R2, SparsityRegime::R3]
    }

    /// Label used in experiment output rows.
    pub fn name(&self) -> &'static str {
        match self {
            SparsityRegime::R1 => "R1",
            SparsityRegime::R2 => "R2",
            SparsityRegime::R3 => "R3",
        }
    }

    /// Target fraction of non-zero kernel entries.
    pub fn density(&self) -> f64 {
        match self {
            SparsityRegime::R1 => 0.7,
            SparsityRegime::R2 => 0.5,
            SparsityRegime::R3 => 0.3,
        }
    }
}

/// One generated OT/UOT problem instance.
#[derive(Clone, Debug)]
pub struct Instance {
    /// Shared support points (n × d).
    pub points: Vec<Vec<f64>>,
    /// Source histogram.
    pub a: Vec<f64>,
    /// Target histogram.
    pub b: Vec<f64>,
}

fn normalize_to_mass(xs: &mut [f64], mass: f64) {
    let s: f64 = xs.iter().sum();
    assert!(s > 0.0);
    for x in xs.iter_mut() {
        *x *= mass / s;
    }
}

/// Empirical histogram: |draws| from the given sampler, normalized.
fn empirical_hist(n: usize, mass: f64, mut draw: impl FnMut() -> f64) -> Vec<f64> {
    let mut h: Vec<f64> = (0..n).map(|_| draw().abs().max(1e-12)).collect();
    normalize_to_mass(&mut h, mass);
    h
}

/// Sample support points for a scenario.
pub fn support(scenario: Scenario, n: usize, d: usize, rng: &mut Rng) -> Vec<Vec<f64>> {
    match scenario {
        Scenario::C1 | Scenario::C3 => (0..n)
            .map(|_| (0..d).map(|_| rng.uniform()).collect())
            .collect(),
        Scenario::C2 => {
            // x ~ N(0, Σ), Σ_jk = 0.5^|j-k| via Cholesky of the AR(1)-like
            // covariance. For this Kac–Murdock–Szegő matrix the Cholesky
            // factor is analytic: L_00 = 1; L_j0 = 0.5^j; and the process
            // representation x_j = 0.5 x_{j-1} + sqrt(1-0.25) z_j matches
            // Σ exactly (stationary AR(1) with unit variance).
            (0..n)
                .map(|_| {
                    let mut x = Vec::with_capacity(d);
                    let mut prev = rng.normal();
                    x.push(prev);
                    for _ in 1..d {
                        let z = rng.normal();
                        prev = 0.5 * prev + (1.0f64 - 0.25).sqrt() * z;
                        x.push(prev);
                    }
                    x
                })
                .collect()
        }
    }
}

/// Generate a full instance with the paper's marginals.
///
/// `mass_a`/`mass_b` are 1.0 for OT and (5.0, 3.0) for UOT.
pub fn instance(
    scenario: Scenario,
    n: usize,
    d: usize,
    mass_a: f64,
    mass_b: f64,
    rng: &mut Rng,
) -> Instance {
    let points = support(scenario, n, d, rng);
    let sd = (1.0f64 / 20.0).sqrt();
    let (a, b) = match scenario {
        Scenario::C1 | Scenario::C2 => (
            empirical_hist(n, mass_a, || rng.normal_ms(1.0 / 3.0, sd)),
            empirical_hist(n, mass_b, || rng.normal_ms(0.5, sd)),
        ),
        Scenario::C3 => (
            empirical_hist(n, mass_a, || rng.student_t_ls(5.0, 1.0 / 3.0, 1.0 / 20.0)),
            empirical_hist(n, mass_b, || rng.student_t_ls(5.0, 0.5, 1.0 / 20.0)),
        ),
    };
    Instance { points, a, b }
}

/// The barycenter inputs of Appendix C.3: Gaussian, Gaussian mixture and
/// t₅ histograms over shared uniform support, with the paper's floor
/// `+1e-2 max(b)` and renormalization.
pub fn barycenter_measures(n: usize, rng: &mut Rng) -> Vec<Vec<f64>> {
    let mut measures = Vec::with_capacity(3);
    let b1 = empirical_hist(n, 1.0, || rng.normal_ms(1.0 / 5.0, (1.0f64 / 50.0).sqrt()));
    let b2: Vec<f64> = (0..n)
        .map(|_| {
            if rng.bernoulli(0.5) {
                rng.normal_ms(0.5, (1.0f64 / 60.0).sqrt()).abs()
            } else {
                rng.normal_ms(4.0 / 5.0, (1.0f64 / 80.0).sqrt()).abs()
            }
            .max(1e-12)
        })
        .collect();
    let b3 = empirical_hist(n, 1.0, || rng.student_t_ls(5.0, 3.0 / 5.0, 1.0 / 100.0));
    let mut b2 = b2;
    normalize_to_mass(&mut b2, 1.0);
    measures.push(b1);
    measures.push(b2);
    measures.push(b3);
    // Paper: add 1e-2 * max(b_k) to every component, renormalize.
    for b in measures.iter_mut() {
        let floor = 1e-2 * b.iter().cloned().fold(0.0, f64::max);
        for x in b.iter_mut() {
            *x += floor;
        }
        normalize_to_mass(b, 1.0);
    }
    measures
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histograms_normalized_to_requested_mass() {
        let mut rng = Rng::seed_from(91);
        for scen in Scenario::all() {
            let inst = instance(scen, 200, 5, 5.0, 3.0, &mut rng);
            let sa: f64 = inst.a.iter().sum();
            let sb: f64 = inst.b.iter().sum();
            assert!((sa - 5.0).abs() < 1e-9, "{scen:?} mass a {sa}");
            assert!((sb - 3.0).abs() < 1e-9, "{scen:?} mass b {sb}");
            assert!(inst.a.iter().all(|&x| x > 0.0));
            assert_eq!(inst.points.len(), 200);
            assert_eq!(inst.points[0].len(), 5);
        }
    }

    #[test]
    fn c1_support_in_unit_cube() {
        let mut rng = Rng::seed_from(93);
        let pts = support(Scenario::C1, 500, 4, &mut rng);
        assert!(pts.iter().flatten().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn c2_support_has_ar1_covariance() {
        let mut rng = Rng::seed_from(95);
        let d = 4;
        let n = 60_000;
        let pts = support(Scenario::C2, n, d, &mut rng);
        // Sample covariance ≈ 0.5^{|j-k|}.
        for j in 0..d {
            for k in 0..d {
                let cov: f64 =
                    pts.iter().map(|x| x[j] * x[k]).sum::<f64>() / n as f64;
                let want = 0.5f64.powi((j as i32 - k as i32).abs());
                assert!(
                    (cov - want).abs() < 0.03,
                    "cov[{j}][{k}] = {cov}, want {want}"
                );
            }
        }
    }

    #[test]
    fn c3_marginals_heavier_tailed_than_c1() {
        let mut rng = Rng::seed_from(97);
        let n = 20_000;
        let c1 = instance(Scenario::C1, n, 2, 1.0, 1.0, &mut rng);
        let c3 = instance(Scenario::C3, n, 2, 1.0, 1.0, &mut rng);
        // Heavier tails -> larger max/mean weight ratio.
        let ratio = |h: &[f64]| h.iter().cloned().fold(0.0, f64::max) * n as f64;
        assert!(ratio(&c3.a) > ratio(&c1.a), "{} vs {}", ratio(&c3.a), ratio(&c1.a));
    }

    #[test]
    fn barycenter_measures_are_simplex_points() {
        let mut rng = Rng::seed_from(99);
        let ms = barycenter_measures(300, &mut rng);
        assert_eq!(ms.len(), 3);
        for m in &ms {
            let s: f64 = m.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(m.iter().all(|&x| x > 0.0));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::seed_from(101);
        let mut r2 = Rng::seed_from(101);
        let i1 = instance(Scenario::C2, 50, 3, 1.0, 1.0, &mut r1);
        let i2 = instance(Scenario::C2, 50, 3, 1.0, 1.0, &mut r2);
        assert_eq!(i1.a, i2.a);
        assert_eq!(i1.points, i2.points);
    }
}
