//! Dense linear-algebra substrate: row-major matrices, parallel
//! matvecs, power iteration (spectral norm / top eigenpairs), a Jacobi
//! eigensolver for small symmetric systems (Nyström cores), and
//! classical multidimensional scaling (the paper's Fig. 7 pipeline).

mod eigen;
mod mds;
mod nystrom;

pub use eigen::{jacobi_eigen, power_iteration, spectral_norm, top_eigenpairs};
pub use mds::classical_mds;
pub use nystrom::{NystromFactor, nystrom_factorize};

use crate::pool;

/// Dense row-major `f64` matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f64 + Sync) -> Self {
        let data = pool::parallel_map(rows * cols, |k| f(k / cols, k % cols));
        Mat { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length mismatch");
        Mat { rows, cols, data }
    }

    /// Number of rows.
    #[inline(always)]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline(always)]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Element `(i, j)`.
    #[inline(always)]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    /// Set element `(i, j)`.
    #[inline(always)]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Immutable view of row `i`.
    #[inline(always)]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable view of row `i`.
    #[inline(always)]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Raw row-major slice.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw row-major slice.
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Element-wise map into a new matrix.
    pub fn map(&self, f: impl Fn(f64) -> f64 + Sync) -> Mat {
        let data = pool::parallel_map(self.data.len(), |k| f(self.data[k]));
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Matrix transpose.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// `y = A x` (parallel over row blocks).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "matvec dimension mismatch");
        let cols = self.cols;
        let data = &self.data;
        pool::parallel_map(self.rows, |i| {
            let row = &data[i * cols..(i + 1) * cols];
            dot(row, x)
        })
    }

    /// `y = A^T x` (parallel over column blocks of the transpose, i.e.
    /// accumulated row-major with per-worker scratch).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows, "matvec_t dimension mismatch");
        let cols = self.cols;
        let data = &self.data;
        pool::parallel_fold(
            self.rows,
            |start, end| {
                let mut acc = vec![0.0; cols];
                for i in start..end {
                    let xi = x[i];
                    if xi == 0.0 {
                        continue;
                    }
                    let row = &data[i * cols..(i + 1) * cols];
                    for (a, &r) in acc.iter_mut().zip(row) {
                        *a += xi * r;
                    }
                }
                acc
            },
            |mut a, b| {
                for (x, y) in a.iter_mut().zip(b) {
                    *x += y;
                }
                a
            },
            vec![0.0; cols],
        )
    }

    /// Dense matmul `A B` (blocked, parallel over rows of A).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dimension mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let a = &self.data;
        let b = &other.data;
        let data = pool::parallel_map(m, |i| {
            let mut row = vec![0.0; n];
            for p in 0..k {
                let aip = a[i * k + p];
                if aip == 0.0 {
                    continue;
                }
                let brow = &b[p * n..(p + 1) * n];
                for (r, &bv) in row.iter_mut().zip(brow) {
                    *r += aip * bv;
                }
            }
            row
        })
        .into_iter()
        .flatten()
        .collect();
        Mat { rows: m, cols: n, data }
    }

    /// Frobenius inner product `<A, B>`.
    pub fn frob_inner(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        dot(&self.data, &other.data)
    }

    /// Sum of all entries.
    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }

    /// Max entry.
    pub fn max(&self) -> f64 {
        self.data.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Row sums (`A 1`).
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.rows).map(|i| self.row(i).iter().sum()).collect()
    }

    /// Column sums (`A^T 1`).
    pub fn col_sums(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            for (o, &v) in out.iter_mut().zip(self.row(i)) {
                *o += v;
            }
        }
        out
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: keeps independent dependency chains so
    // the compiler can vectorize without -ffast-math.
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut tail = 0.0;
    for i in chunks * 4..a.len() {
        tail += a[i] * b[i];
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

/// L1 norm of the difference of two vectors.
#[inline]
pub fn l1_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

/// L2 norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_matches_manual() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let y = a.matvec(&[1., 0., -1.]);
        assert_eq!(y, vec![-2., -2.]);
    }

    #[test]
    fn matvec_t_matches_transpose() {
        let a = Mat::from_fn(7, 5, |i, j| (i * 5 + j) as f64 * 0.37);
        let x: Vec<f64> = (0..7).map(|i| (i as f64).sin()).collect();
        let y1 = a.matvec_t(&x);
        let y2 = a.transpose().matvec(&x);
        for (u, v) in y1.iter().zip(&y2) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(4, 4, |i, j| (i + 2 * j) as f64);
        let id = Mat::from_fn(4, 4, |i, j| if i == j { 1.0 } else { 0.0 });
        assert_eq!(a.matmul(&id), a);
    }

    #[test]
    fn matmul_rectangular() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.as_slice(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn row_col_sums() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        assert_eq!(a.row_sums(), vec![3., 7.]);
        assert_eq!(a.col_sums(), vec![4., 6.]);
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        let a: Vec<f64> = (0..103).map(|i| (i as f64).cos()).collect();
        let b: Vec<f64> = (0..103).map(|i| (i as f64 * 0.5).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_fn(3, 5, |i, j| (i * 17 + j * 3) as f64);
        assert_eq!(a.transpose().transpose(), a);
    }
}
