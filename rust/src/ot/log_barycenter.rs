//! Log-domain stabilized IBP for fixed-support Wasserstein barycenters —
//! Algorithm 5 iterated entirely on log-potentials, so the geometric-mean
//! update survives ε far below the `exp(−C/ε)` underflow cliff where the
//! multiplicative loop silently collapses to a zero histogram.
//!
//! The multiplicative IBP state `(u_k, v_k, q)` maps to potentials
//! `φ_k = ln u_k` and the log-histogram `ln q`:
//!
//! ```text
//! ψ_k,j ← log b_k,j − LSE_i(ln K_k,ij + φ_k,i)        (v_k = b_k ./ K_kᵀ u_k)
//! r_k,i ← LSE_j(ln K_k,ij + ψ_k,j)                    (r_k = ln K_k v_k)
//! ln q  ← Σ_k w_k · r_k  −  LSE_i(Σ_k w_k · r_k,i)    (normalized geo-mean)
//! φ_k,i ← ln q_i − r_k,i                              (u_k = q ./ K_k v_k)
//! ```
//!
//! Unlike the multiplicative loop this engine NORMALIZES `q` every
//! iteration (the subtracted log-partition). Scaling `q` by a constant
//! scales the next `v_k` by its inverse and leaves the following `u_k`
//! unchanged, so the normalized iterates are exactly the multiplicative
//! iterates renormalized — same fixed point, but `q` is a probability
//! vector by construction at every step, even when the solve is stopped
//! before convergence at sub-threshold ε.
//!
//! Kernels enter through [`LogKernelOp`], the log-domain twin of
//! [`KernelOp`](crate::ot::barycenter::KernelOp): a dense cost matrix
//! wrapped in [`DenseLogKernel`] (entries `−C_ij/ε`, blocked = −∞), or a
//! [`CsrMatrix`](crate::sparse::CsrMatrix) sketch whose stored `ln K̃`
//! values drive the CSR row/col log-sum-exp — the sparse path used by
//! [`log_spar_ibp`](crate::solvers::log_spar_ibp).

use crate::error::{Error, Result};
use crate::linalg::{l1_diff, Mat};
use crate::ot::barycenter::BarycenterSolution;
use crate::ot::cost::log_gibbs_from_cost;
use crate::ot::sinkhorn::SinkhornParams;
use crate::pool;
use crate::sparse::CsrMatrix;

/// A log-kernel operator: row/column log-sum-exp against a potential
/// vector, the log-domain analogue of `apply`/`apply_t` on
/// [`KernelOp`](crate::ot::barycenter::KernelOp). Entries and potentials
/// may be −∞ (blocked / zero scaling); an all-−∞ row or column yields −∞.
pub trait LogKernelOp: Sync {
    /// `y_i = LSE_j(ln K_ij + g_j)`, i.e. `ln (K e^g)_i`.
    fn row_lse(&self, g: &[f64]) -> Vec<f64>;
    /// `y_j = LSE_i(ln K_ij + f_i)`, i.e. `ln (Kᵀ e^f)_j`.
    fn col_lse(&self, f: &[f64]) -> Vec<f64>;
    /// Number of kernel rows.
    fn rows(&self) -> usize;
    /// Number of kernel columns.
    fn cols(&self) -> usize;
}

impl<K: LogKernelOp> LogKernelOp for &K {
    fn row_lse(&self, g: &[f64]) -> Vec<f64> {
        (**self).row_lse(g)
    }
    fn col_lse(&self, f: &[f64]) -> Vec<f64> {
        (**self).col_lse(f)
    }
    fn rows(&self) -> usize {
        (**self).rows()
    }
    fn cols(&self) -> usize {
        (**self).cols()
    }
}

impl LogKernelOp for CsrMatrix {
    fn row_lse(&self, g: &[f64]) -> Vec<f64> {
        CsrMatrix::row_lse(self, g)
    }
    fn col_lse(&self, f: &[f64]) -> Vec<f64> {
        CsrMatrix::col_lse(self, f)
    }
    fn rows(&self) -> usize {
        CsrMatrix::rows(self)
    }
    fn cols(&self) -> usize {
        CsrMatrix::cols(self)
    }
}

/// Dense Gibbs log-kernel `ln K_ij = −C_ij/ε` evaluated from the cost
/// matrix on the fly (blocked `C = ∞` entries are −∞). Stores the
/// transposed cost so the column LSE runs cache-friendly and parallel
/// like the row pass.
pub struct DenseLogKernel {
    cost: Mat,
    cost_t: Mat,
    eps: f64,
}

impl DenseLogKernel {
    /// Wrap a dense kernel (and its log twin) for the log-IBP loop.
    pub fn new(cost: &Mat, eps: f64) -> Self {
        DenseLogKernel { cost: cost.clone(), cost_t: cost.transpose(), eps }
    }
}

/// Streaming LSE of `−c_j/ε + g_j` over one cost row.
fn lse_cost_row(cost_row: &[f64], g: &[f64], eps: f64) -> f64 {
    let mut max = f64::NEG_INFINITY;
    for (c, gj) in cost_row.iter().zip(g) {
        let t = log_gibbs_from_cost(*c, eps) + gj;
        if t > max {
            max = t;
        }
    }
    if max == f64::NEG_INFINITY {
        return f64::NEG_INFINITY;
    }
    let mut acc = 0.0;
    for (c, gj) in cost_row.iter().zip(g) {
        let t = log_gibbs_from_cost(*c, eps) + gj;
        if t > f64::NEG_INFINITY {
            acc += (t - max).exp();
        }
    }
    max + acc.ln()
}

impl LogKernelOp for DenseLogKernel {
    fn row_lse(&self, g: &[f64]) -> Vec<f64> {
        assert_eq!(g.len(), self.cost.cols(), "dense row_lse dimension mismatch");
        pool::parallel_map(self.cost.rows(), |i| lse_cost_row(self.cost.row(i), g, self.eps))
    }
    fn col_lse(&self, f: &[f64]) -> Vec<f64> {
        assert_eq!(f.len(), self.cost.rows(), "dense col_lse dimension mismatch");
        pool::parallel_map(self.cost_t.rows(), |j| lse_cost_row(self.cost_t.row(j), f, self.eps))
    }
    fn rows(&self) -> usize {
        self.cost.rows()
    }
    fn cols(&self) -> usize {
        self.cost.cols()
    }
}

/// LSE of a full vector (the log-partition used to normalize `ln q`).
fn lse_vec(x: &[f64]) -> f64 {
    let max = x.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if max == f64::NEG_INFINITY || !max.is_finite() {
        return max; // −∞ (empty) propagates; NaN/+∞ caught by the caller
    }
    let acc: f64 = x.iter().map(|&v| (v - max).exp()).sum();
    max + acc.ln()
}

/// Run log-domain IBP (Algorithm 5 on potentials) over any log-kernel
/// operators. Same contract as
/// [`ibp_barycenter_with`](crate::ot::barycenter::ibp_barycenter_with),
/// except the returned `q` is normalized to a probability vector (see
/// the module docs) and the displacement is measured on that normalized
/// histogram.
pub fn log_ibp_barycenter_with<K: LogKernelOp>(
    kernels: &[K],
    bs: &[Vec<f64>],
    weights: &[f64],
    params: &SinkhornParams,
) -> Result<BarycenterSolution> {
    let m = kernels.len();
    if m == 0 || bs.len() != m || weights.len() != m {
        return Err(Error::Dimension(format!(
            "got {} kernels, {} measures, {} weights",
            m,
            bs.len(),
            weights.len()
        )));
    }
    let n = kernels[0].rows();
    for (k, kern) in kernels.iter().enumerate() {
        if kern.rows() != n || kern.cols() != bs[k].len() {
            return Err(Error::Dimension(format!(
                "kernel {k} is {}x{} but barycenter support is {n} and b[{k}] has {}",
                kern.rows(),
                kern.cols(),
                bs[k].len()
            )));
        }
    }
    let wsum: f64 = weights.iter().sum();
    if weights.iter().any(|&w| w < 0.0) || wsum <= 0.0 {
        return Err(Error::InvalidParam("weights must be non-negative with positive sum".into()));
    }
    let w: Vec<f64> = weights.iter().map(|x| x / wsum).collect();
    let log_bs: Vec<Vec<f64>> = bs
        .iter()
        .map(|b| {
            b.iter().map(|&x| if x > 0.0 { x.ln() } else { f64::NEG_INFINITY }).collect()
        })
        .collect();

    let mut phis: Vec<Vec<f64>> = (0..m).map(|_| vec![0.0; n]).collect();
    let mut q = vec![1.0 / n as f64; n];
    let mut q_prev = q.clone();
    let mut log_q = vec![0.0; n];
    let mut rs: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut displacement = f64::INFINITY;
    let mut iters = 0;
    while iters < params.max_iters {
        iters += 1;
        q_prev.copy_from_slice(&q);
        log_q.iter_mut().for_each(|x| *x = 0.0);
        rs.clear();
        for k in 0..m {
            // ψ_k = log b_k − ln(K_kᵀ u_k); zero-mass columns keep v = 0.
            let lse_cols = kernels[k].col_lse(&phis[k]);
            let psi: Vec<f64> = log_bs[k]
                .iter()
                .zip(&lse_cols)
                .map(|(&lb, &lse)| {
                    if lb == f64::NEG_INFINITY || lse == f64::NEG_INFINITY {
                        f64::NEG_INFINITY
                    } else {
                        lb - lse
                    }
                })
                .collect();
            // r_k = ln(K_k v_k).
            let r = kernels[k].row_lse(&psi);
            if w[k] > 0.0 {
                // A −∞ row under a positively-weighted kernel pins
                // q_i = 0 (the multiplicative loop's 1e-300 guard is the
                // linear-domain shadow of the same convention).
                for i in 0..n {
                    if r[i] == f64::NEG_INFINITY {
                        log_q[i] = f64::NEG_INFINITY;
                    } else if log_q[i] != f64::NEG_INFINITY {
                        log_q[i] += w[k] * r[i];
                    }
                }
            }
            rs.push(r);
        }
        // Normalize: ln q ← ln q − LSE(ln q). Keeps q on the simplex at
        // every iteration without moving the fixed point (module docs).
        let lz = lse_vec(&log_q);
        if !lz.is_finite() {
            return Err(Error::Numerical(format!(
                "log-domain barycenter collapsed at iteration {iters} (log-partition {lz})"
            )));
        }
        for i in 0..n {
            if log_q[i] != f64::NEG_INFINITY {
                log_q[i] -= lz;
            }
            q[i] = log_q[i].exp();
        }
        // φ_k = ln q − r_k.
        for k in 0..m {
            for i in 0..n {
                let blocked = log_q[i] == f64::NEG_INFINITY || rs[k][i] == f64::NEG_INFINITY;
                phis[k][i] = if blocked { f64::NEG_INFINITY } else { log_q[i] - rs[k][i] };
            }
        }
        displacement = l1_diff(&q, &q_prev);
        if displacement <= params.delta {
            return Ok(BarycenterSolution { q, iterations: iters, displacement, converged: true });
        }
    }
    if params.strict {
        return Err(Error::NotConverged { iters, err: displacement });
    }
    Ok(BarycenterSolution { q, iterations: iters, displacement, converged: false })
}

/// Dense convenience wrapper: log-domain IBP over the shared-support
/// Gibbs kernel `ln K = −C/ε` — the stable reference for barycenters at
/// any ε, and the engine behind `BackendKind::LogDomain` barycenter
/// solves in the registry.
pub fn log_ibp_barycenter(
    cost: &Mat,
    bs: &[Vec<f64>],
    weights: &[f64],
    eps: f64,
    params: &SinkhornParams,
) -> Result<BarycenterSolution> {
    if eps <= 0.0 {
        return Err(Error::InvalidParam("eps must be positive".into()));
    }
    let op = DenseLogKernel::new(cost, eps);
    let ops: Vec<&DenseLogKernel> = vec![&op; bs.len()];
    log_ibp_barycenter_with(&ops, bs, weights, params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::barycenter::ibp_barycenter;
    use crate::ot::cost::{gibbs_kernel, sq_euclidean_cost};

    fn grid_support(n: usize) -> Vec<Vec<f64>> {
        (0..n).map(|i| vec![i as f64 / (n - 1) as f64]).collect()
    }

    fn gauss_hist(pts: &[Vec<f64>], mu: f64, s2: f64) -> Vec<f64> {
        let w: Vec<f64> =
            pts.iter().map(|p| (-(p[0] - mu).powi(2) / (2.0 * s2)).exp() + 1e-4).collect();
        let s: f64 = w.iter().sum();
        w.iter().map(|x| x / s).collect()
    }

    #[test]
    fn matches_multiplicative_ibp_at_moderate_eps() {
        // Same fixed point, and with the normalization argument the
        // iterates correspond exactly — tight tolerances agree to 1e-8.
        let pts = grid_support(40);
        let cost = sq_euclidean_cost(&pts, &pts);
        let eps = 0.01;
        let kernel = gibbs_kernel(&cost, eps);
        let bs = vec![gauss_hist(&pts, 0.3, 0.004), gauss_hist(&pts, 0.7, 0.004)];
        let w = vec![0.5, 0.5];
        let params = SinkhornParams { delta: 1e-11, max_iters: 20_000, strict: false };
        let mult =
            ibp_barycenter(&[kernel.clone(), kernel.clone()], &bs, &w, &params).unwrap();
        let logd = log_ibp_barycenter(&cost, &bs, &w, eps, &params).unwrap();
        assert!(mult.converged && logd.converged);
        let mass: f64 = mult.q.iter().sum();
        let sup = mult
            .q
            .iter()
            .zip(&logd.q)
            .map(|(x, y)| (x / mass - y).abs())
            .fold(0.0f64, f64::max);
        assert!(sup < 1e-8, "sup-norm gap {sup}");
    }

    #[test]
    fn q_is_a_probability_vector_even_at_tiny_eps() {
        // ε two orders below the multiplicative underflow cliff: the
        // multiplicative IBP collapses toward zero mass, the log engine
        // returns a normalized, finite histogram.
        let pts = grid_support(32);
        let cost = sq_euclidean_cost(&pts, &pts);
        let eps = 1e-5;
        let bs = vec![gauss_hist(&pts, 0.25, 0.003), gauss_hist(&pts, 0.75, 0.003)];
        let params = SinkhornParams { delta: 1e-9, max_iters: 2000, strict: false };
        let sol = log_ibp_barycenter(&cost, &bs, &[0.5, 0.5], eps, &params).unwrap();
        assert!(sol.q.iter().all(|x| x.is_finite() && *x >= 0.0));
        let mass: f64 = sol.q.iter().sum();
        assert!((mass - 1.0).abs() < 1e-9, "mass {mass}");
        // The ε → 0 barycenter of two symmetric Gaussians centers at 0.5.
        let mean: f64 = pts.iter().zip(&sol.q).map(|(p, q)| p[0] * q).sum();
        assert!((mean - 0.5).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn sparse_sketch_kernels_run_through_the_same_loop() {
        // Full-support CSR sketches with exact log-kernel values must
        // reproduce the dense log engine bit-for-bit in shape terms.
        let pts = grid_support(24);
        let cost = sq_euclidean_cost(&pts, &pts);
        let eps = 5e-4;
        let rows: Vec<Vec<(u32, f64, f64, f64)>> = (0..24)
            .map(|i| {
                (0..24)
                    .map(|j| {
                        let lk = -cost.get(i, j) / eps;
                        (j as u32, lk.exp(), lk, cost.get(i, j))
                    })
                    .collect()
            })
            .collect();
        let sk = CsrMatrix::from_rows_logk(24, 24, rows);
        let bs = vec![gauss_hist(&pts, 0.3, 0.004), gauss_hist(&pts, 0.6, 0.004)];
        let params = SinkhornParams { delta: 1e-10, max_iters: 5000, strict: false };
        let dense = log_ibp_barycenter(&cost, &bs, &[0.5, 0.5], eps, &params).unwrap();
        let sparse =
            log_ibp_barycenter_with(&[sk.clone(), sk], &bs, &[0.5, 0.5], &params).unwrap();
        let sup = dense
            .q
            .iter()
            .zip(&sparse.q)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        assert!(sup < 1e-8, "dense vs sparse-full sup gap {sup}");
    }

    #[test]
    fn zero_weight_kernels_do_not_poison_q() {
        let pts = grid_support(16);
        let cost = sq_euclidean_cost(&pts, &pts);
        let bs = vec![gauss_hist(&pts, 0.4, 0.01), gauss_hist(&pts, 0.8, 0.01)];
        let params = SinkhornParams { delta: 1e-9, max_iters: 2000, strict: false };
        let sol = log_ibp_barycenter(&cost, &bs, &[1.0, 0.0], 0.01, &params).unwrap();
        let mass: f64 = sol.q.iter().sum();
        assert!((mass - 1.0).abs() < 1e-9);
        assert!(sol.q.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn rejects_mismatched_inputs_like_the_multiplicative_loop() {
        let pts = grid_support(8);
        let cost = sq_euclidean_cost(&pts, &pts);
        let b = gauss_hist(&pts, 0.5, 0.01);
        let params = SinkhornParams::default();
        assert!(log_ibp_barycenter(&cost, &[b.clone(), b.clone()], &[0.5], 0.1, &params).is_err());
        assert!(log_ibp_barycenter(&cost, &[b.clone()], &[-1.0], 0.1, &params).is_err());
        assert!(log_ibp_barycenter(&cost, &[b], &[1.0], 0.0, &params).is_err());
    }
}
