"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

This is the CORE correctness signal for the compiled hot-spot: hypothesis
sweeps shapes and tile sizes, asserting allclose against ``ref.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import sinkhorn_pallas as kern

jax.config.update("jax_platform_name", "cpu")


def _mk(rng, shape, lo=0.05, hi=1.0, dtype="float32"):
    return jnp.asarray(rng.uniform(lo, hi, shape).astype(dtype))


# Tile-divisible shape/tile combos: n = tiles_r * bn, m = tiles_c * bm.
shape_strategy = st.tuples(
    st.integers(1, 4),  # row tiles
    st.integers(1, 4),  # col tiles
    st.sampled_from([4, 8, 16]),  # bn
    st.sampled_from([4, 8, 16]),  # bm
    st.integers(0, 2**31 - 1),  # seed
)


@settings(max_examples=25, deadline=None)
@given(shape_strategy)
def test_kv_scale_matches_ref(params):
    tr, tc, bn, bm, seed = params
    n, m = tr * bn, tc * bm
    rng = np.random.default_rng(seed)
    kmat = _mk(rng, (n, m))
    v = _mk(rng, (m, 1), 0.5, 2.0)
    a = _mk(rng, (n, 1))
    got = kern.kv_scale(kmat, v, a, block_rows=bn, block_cols=bm)
    want = ref.kv_scale_ref(kmat, v, a)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(shape_strategy)
def test_ktu_scale_matches_ref(params):
    tr, tc, bn, bm, seed = params
    n, m = tr * bn, tc * bm
    rng = np.random.default_rng(seed)
    kmat = _mk(rng, (n, m))
    u = _mk(rng, (n, 1), 0.5, 2.0)
    b = _mk(rng, (m, 1))
    got = kern.ktu_scale(kmat, u, b, block_rows=bn, block_cols=bm)
    want = ref.ktu_scale_ref(kmat, u, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n", [8, 64, 128, 256])
def test_kv_scale_default_tiles(n):
    """Default (128-capped) tiles across the artifact size menu edge."""
    rng = np.random.default_rng(n)
    kmat = _mk(rng, (n, n))
    v = _mk(rng, (n, 1), 0.5, 2.0)
    a = _mk(rng, (n, 1))
    got = kern.kv_scale(kmat, v, a)
    np.testing.assert_allclose(got, ref.kv_scale_ref(kmat, v, a), rtol=1e-5)


def test_rectangular_support():
    """Kernels accept rectangular K (n != m), needed for padded requests."""
    rng = np.random.default_rng(7)
    kmat = _mk(rng, (32, 16))
    v = _mk(rng, (16, 1))
    a = _mk(rng, (32, 1))
    b = _mk(rng, (16, 1))
    u = kern.kv_scale(kmat, v, a, block_rows=8, block_cols=8)
    np.testing.assert_allclose(u, ref.kv_scale_ref(kmat, v, a), rtol=1e-5)
    vv = kern.ktu_scale(kmat, u, b, block_rows=8, block_cols=8)
    np.testing.assert_allclose(vv, ref.ktu_scale_ref(kmat, u, b), rtol=1e-5)


def test_indivisible_tiling_rejected():
    rng = np.random.default_rng(1)
    kmat = _mk(rng, (10, 10))
    v = _mk(rng, (10, 1))
    a = _mk(rng, (10, 1))
    with pytest.raises(ValueError, match="not divisible"):
        kern.kv_scale(kmat, v, a, block_rows=4, block_cols=4)


def test_single_tile_degenerate():
    """bn == n, bm == m: the grid collapses to one program."""
    rng = np.random.default_rng(2)
    kmat = _mk(rng, (8, 8))
    v = _mk(rng, (8, 1))
    a = _mk(rng, (8, 1))
    got = kern.kv_scale(kmat, v, a, block_rows=8, block_cols=8)
    np.testing.assert_allclose(got, ref.kv_scale_ref(kmat, v, a), rtol=1e-5)


def test_float64_dtype():
    """x64 round-trips when enabled (the oracle and kernel agree)."""
    rng = np.random.default_rng(3)
    with jax.experimental.enable_x64():
        kmat = jnp.asarray(rng.uniform(0.05, 1.0, (16, 16)))
        v = jnp.asarray(rng.uniform(0.5, 2.0, (16, 1)))
        a = jnp.asarray(rng.uniform(0.05, 1.0, (16, 1)))
        got = kern.kv_scale(kmat, v, a, block_rows=8, block_cols=8)
        np.testing.assert_allclose(got, ref.kv_scale_ref(kmat, v, a), rtol=1e-12)
