//! # The unified solver API
//!
//! One stable request/response surface over every solver in the crate:
//!
//! * [`OtProblem`] — WHAT to solve: marginals, a cost source (dense
//!   [`Mat`](crate::linalg::Mat) or entry oracles), the entropic
//!   regularization ε, and a [`Formulation`] (balanced OT, unbalanced
//!   OT, or a fixed-support barycenter).
//! * [`SolverSpec`] — HOW to solve it: a registered [`Method`], sample
//!   budget, optional [`ScalingBackend`](crate::solvers::backend::ScalingBackend)
//!   override, stopping rule, and seed.
//! * [`Solution`] — what came back: objective (or barycenter), dual
//!   scalings, sparsification stats, the
//!   [`BackendKind`](crate::solvers::backend::BackendKind) that actually
//!   ran, iteration count, and wall time.
//!
//! Batched workloads go through [`solve_batch`]: dense costs are
//! upgraded to [`CostSource::Shared`] handles over cache-resident
//! [`CostArtifacts`](crate::engine::CostArtifacts) (content-addressed
//! by support × η × ε × formulation), so a sweep over one support
//! builds its cost/kernel/sampling-factor work exactly once and every
//! warm solve is bitwise-identical to the cold path.
//!
//! Dispatch goes through a [`Solver`] trait + static [`registry`]
//! (name → adapter) covering Sinkhorn/IBP, Spar-Sink (± forced
//! log-domain), Rand-Sink, Nys-Sink (± robust clip), Greenkhorn,
//! Screenkhorn, and Spar-IBP. The coordinator, CLI, experiment harness,
//! and examples all route through [`solve`]; the legacy free functions
//! under [`crate::ot`] and [`crate::solvers`] remain as the thin
//! paper-reproduction entry points the adapters call into.
//!
//! ```
//! use spar_sink::api::{self, Method, OtProblem, SolverSpec};
//! use spar_sink::ot::cost::sq_euclidean_cost;
//! use spar_sink::rng::Rng;
//!
//! let n = 64;
//! let mut rng = Rng::seed_from(7);
//! let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform(), rng.uniform()]).collect();
//! let a = vec![1.0 / n as f64; n];
//! let problem = OtProblem::balanced(sq_euclidean_cost(&pts, &pts), a.clone(), a, 0.05);
//!
//! let exact = api::solve(&problem, &SolverSpec::new(Method::Sinkhorn)).unwrap();
//! let spec = SolverSpec::new(Method::SparSink).with_budget(8.0).with_seed(7);
//! let approx = api::solve(&problem, &spec).unwrap();
//! assert!(approx.nnz().unwrap() > 0);
//! println!("exact {:.6} sparse {:.6} ({:?}, nnz {:?})",
//!          exact.objective, approx.objective, approx.wall_time, approx.nnz());
//! ```
//!
//! A batch over one support amortizes the kernel-side work through the
//! global [`ArtifactCache`](crate::engine::ArtifactCache): slot `i`
//! runs at seed `spec.seed + i`, and `solve_batch(&[p], spec)[0]` is
//! bitwise-identical to `solve(&p, spec)`:
//!
//! ```
//! use spar_sink::api::{self, Method, OtProblem, SolverSpec};
//! use spar_sink::engine::ArtifactCache;
//! use spar_sink::ot::cost::sq_euclidean_cost;
//! use spar_sink::rng::Rng;
//!
//! let n = 48;
//! let mut rng = Rng::seed_from(3);
//! let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform(), rng.uniform()]).collect();
//! let cost = std::sync::Arc::new(sq_euclidean_cost(&pts, &pts));
//! let a = vec![1.0 / n as f64; n];
//! // Three replicates of one problem = a three-seed sweep.
//! let problems: Vec<OtProblem> =
//!     (0..3).map(|_| OtProblem::balanced(cost.clone(), a.clone(), a.clone(), 0.05)).collect();
//! let spec = SolverSpec::new(Method::SparSink).with_budget(8.0).with_seed(41);
//!
//! let cache = ArtifactCache::new(64 << 20);
//! let solutions = api::solve_batch_with_cache(&problems, &spec, &cache);
//! assert_eq!(solutions.len(), 3);
//! assert!(solutions.iter().all(|s| s.is_ok()));
//! // One kernel materialization served all three solves.
//! let stats = cache.stats();
//! assert_eq!((stats.misses, stats.hits), (1, 2));
//! // Slot 0 is bitwise the solo solve.
//! let solo = api::solve(&problems[0], &spec).unwrap();
//! assert_eq!(
//!     solo.objective.to_bits(),
//!     solutions[0].as_ref().unwrap().objective.to_bits()
//! );
//! ```

pub mod problem;
pub mod registry;
pub mod solution;
pub mod spec;

pub use crate::engine::CostHandle;
pub use problem::{CostSource, EntryOracle, Formulation, OtProblem};
pub use registry::{
    formulation_key, lookup, registry, share_via_cache, solve, solve_batch,
    solve_batch_with_cache, solve_with_rng, Solver,
};
pub use solution::Solution;
pub use spec::{parse_backend, Method, SolverSpec};
