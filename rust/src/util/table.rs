//! Fixed-width table printer for experiment output (the harness prints
//! the same rows/series the paper reports).

/// A simple column-aligned table builder.
#[derive(Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header width).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with column alignment.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for c in 0..ncol {
                if c > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", cells[c], width = widths[c]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed decimals.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

/// Format `mean ± sd` like the paper's tables.
pub fn pm(mean: f64, sd: f64, decimals: usize) -> String {
    format!("{mean:.decimals$}±{sd:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["method", "rmae"]);
        t.row(vec!["spar-sink".into(), f(0.0123, 4)]);
        t.row(vec!["nys".into(), f(0.5, 4)]);
        let s = t.render();
        assert!(s.contains("spar-sink  0.0123"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_bad_width() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn pm_format() {
        assert_eq!(pm(0.06, 0.05, 2), "0.06±0.05");
    }
}
