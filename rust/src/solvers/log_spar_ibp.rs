//! Log-domain Spar-IBP — Algorithm 6 with the sketch AND the scaling
//! loop kept in the log domain end to end.
//!
//! Each kernel is Poisson-sparsified with the Appendix A.2 probabilities
//! through [`poisson_sparsify_ibp_logk`], so every sampled entry carries
//! its exact `ln K̃_ij = −C_ij/ε − ln p*` even when the linear kernel
//! value underflows f64 — and the iteration is the stabilized log-IBP of
//! [`log_ibp_barycenter_with`] driving the CSR row/column log-sum-exp
//! primitives. Per-iteration cost stays O(nnz) like the multiplicative
//! Spar-IBP; the returned `q` is a probability vector by construction.
//!
//! This is the pinned-log paper entry point (the barycenter analogue of
//! `spar-sink-log`); policy-driven engine selection — multiplicative
//! above the ε threshold, escalation on collapse — lives behind
//! [`ScalingBackend::sparse_ibp`](super::backend::ScalingBackend), which
//! the `spar-ibp` registry adapter dispatches to.

use crate::error::Result;
use crate::linalg::Mat;
use crate::ot::cost::log_gibbs_from_cost;
use crate::ot::log_barycenter::log_ibp_barycenter_with;
use crate::ot::sinkhorn::SinkhornParams;
use crate::rng::Rng;
use crate::solvers::spar_ibp::SparIbpSolution;
use crate::sparse::{poisson_sparsify_ibp_logk, CsrMatrix, SparsifyStats};

/// Sparsify one IBP kernel from a LOG-kernel oracle (−∞ = blocked).
/// Identical selection probabilities and RNG stream to
/// [`sparsify_ibp_kernel`](super::spar_ibp::sparsify_ibp_kernel)
/// wherever the linear kernel has not underflowed.
pub fn sparsify_ibp_kernel_logk(
    n: usize,
    log_kernel: impl Fn(usize, usize) -> f64 + Sync,
    b_k: &[f64],
    s: f64,
    rng: &mut Rng,
) -> Result<(CsrMatrix, SparsifyStats)> {
    poisson_sparsify_ibp_logk(n, log_kernel, b_k, s, 1.0, rng)
}

/// Run log-domain Spar-IBP from the shared-support cost matrix:
/// sparsify every kernel with exact `ln K̃` values, then iterate the
/// stabilized log-IBP. `s` is the absolute expected sample budget per
/// kernel, as in [`spar_ibp`](super::spar_ibp::spar_ibp).
///
/// Unlike the multiplicative entry point this takes `(cost, eps)` rather
/// than pre-materialized Gibbs kernels — materializing `exp(−C/ε)` is
/// exactly what destroys the information the log engine needs.
pub fn log_spar_ibp(
    cost: &Mat,
    bs: &[Vec<f64>],
    weights: &[f64],
    eps: f64,
    s: f64,
    params: &SinkhornParams,
    rng: &mut Rng,
) -> Result<SparIbpSolution> {
    let n = cost.rows();
    let mut sketches = Vec::with_capacity(bs.len());
    let mut stats = Vec::with_capacity(bs.len());
    for b_k in bs {
        let (sk, st) = sparsify_ibp_kernel_logk(
            n,
            |i, j| log_gibbs_from_cost(cost.get(i, j), eps),
            b_k,
            s,
            rng,
        )?;
        sketches.push(sk);
        stats.push(st);
    }
    let solution = log_ibp_barycenter_with(&sketches, bs, weights, params)?;
    Ok(SparIbpSolution { solution, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{l1_distance, s0};
    use crate::ot::cost::{gibbs_kernel, sq_euclidean_cost};
    use crate::solvers::spar_ibp::spar_ibp;

    fn setup(n: usize) -> (Mat, Vec<Vec<f64>>, Vec<f64>) {
        let pts: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 / (n - 1) as f64]).collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        let hist = |mu: f64, s2: f64| -> Vec<f64> {
            let w: Vec<f64> =
                pts.iter().map(|p| (-(p[0] - mu).powi(2) / (2.0 * s2)).exp() + 1e-4).collect();
            let s: f64 = w.iter().sum();
            w.iter().map(|x| x / s).collect()
        };
        let bs = vec![hist(0.2, 0.003), hist(0.5, 0.004), hist(0.8, 0.003)];
        (cost, bs, vec![1.0 / 3.0; 3])
    }

    #[test]
    fn matches_multiplicative_spar_ibp_at_moderate_eps() {
        // Same seed → same sketch support and values; the two IBP loops
        // are the same map modulo normalization, so the normalized
        // multiplicative q and the log q agree tightly.
        let n = 64;
        let (cost, bs, w) = setup(n);
        let eps = 0.01;
        let kernel = gibbs_kernel(&cost, eps);
        let kernels = vec![kernel.clone(), kernel.clone(), kernel];
        let params = SinkhornParams { delta: 1e-11, max_iters: 20_000, strict: false };
        let budget = 40.0 * s0(n);
        let mut r1 = Rng::seed_from(91);
        let mut r2 = Rng::seed_from(91);
        let mult = spar_ibp(&kernels, &bs, &w, budget, &params, &mut r1).unwrap();
        let logd = log_spar_ibp(&cost, &bs, &w, eps, budget, &params, &mut r2).unwrap();
        assert_eq!(mult.stats.len(), logd.stats.len());
        for (sm, sl) in mult.stats.iter().zip(&logd.stats) {
            assert_eq!(sm.nnz, sl.nnz, "sketch supports diverged");
        }
        let mass: f64 = mult.solution.q.iter().sum();
        assert!(mass > 0.0);
        let sup = mult
            .solution
            .q
            .iter()
            .zip(&logd.solution.q)
            .map(|(x, y)| (x / mass - y).abs())
            .fold(0.0f64, f64::max);
        assert!(sup < 1e-8, "normalized sup-norm gap {sup}");
    }

    #[test]
    fn survives_tiny_eps_where_the_linear_sketch_is_empty() {
        // ε far below the underflow cliff: the materialized Gibbs kernel
        // keeps only a thin near-diagonal band, starving the linear
        // sampler; the log pipeline samples the full support and still
        // returns a probability vector.
        let n = 48;
        let (cost, bs, w) = setup(n);
        let eps = 1e-5;
        let kernel = gibbs_kernel(&cost, eps);
        assert!(
            kernel.as_slice().iter().filter(|&&k| k > 0.0).count() < n * n / 2,
            "expected heavy underflow"
        );
        let params = SinkhornParams { delta: 1e-8, max_iters: 3000, strict: false };
        let mut rng = Rng::seed_from(93);
        let sol = log_spar_ibp(&cost, &bs, &w, eps, 30.0 * s0(n), &params, &mut rng).unwrap();
        assert!(sol.stats.iter().all(|s| s.nnz > 0));
        let mass: f64 = sol.solution.q.iter().sum();
        assert!((mass - 1.0).abs() < 1e-9, "mass {mass}");
        assert!(sol.solution.q.iter().all(|x| x.is_finite() && *x >= 0.0));
    }

    #[test]
    fn approximates_the_exact_log_barycenter() {
        let n = 64;
        let (cost, bs, w) = setup(n);
        let eps = 5e-4; // below the multiplicative threshold
        let params = SinkhornParams { delta: 1e-9, max_iters: 4000, strict: false };
        let exact =
            crate::ot::log_barycenter::log_ibp_barycenter(&cost, &bs, &w, eps, &params).unwrap();
        let mut rng = Rng::seed_from(97);
        let approx =
            log_spar_ibp(&cost, &bs, &w, eps, 40.0 * s0(n), &params, &mut rng).unwrap();
        let err = l1_distance(&approx.solution.q, &exact.q);
        assert!(err < 0.6, "L1 error {err}");
    }

    #[test]
    fn budget_respected() {
        let n = 48;
        let (cost, bs, w) = setup(n);
        let mut rng = Rng::seed_from(99);
        let budget = 10.0 * s0(n);
        let sol =
            log_spar_ibp(&cost, &bs, &w, 0.01, budget, &SinkhornParams::default(), &mut rng)
                .unwrap();
        assert_eq!(sol.stats.len(), 3);
        for st in &sol.stats {
            assert!((st.nnz as f64) <= budget * 1.25, "nnz {} vs {budget}", st.nnz);
        }
    }
}
