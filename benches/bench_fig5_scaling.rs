//! Fig. 5 companion bench: wall-time scaling of Sinkhorn vs Spar-Sink
//! for OT and UOT as n grows — regenerates the paper's timing rows with
//! statistical repetition (the `repro experiment fig5` harness does the
//! single-shot version).

use spar_sink::bench::Bencher;
use spar_sink::data::synthetic::{instance, Scenario, SparsityRegime};
use spar_sink::experiments::common::{
    exact_uot, gibbs_kernel_inf, ot_cost, run_method_ot, run_method_uot, wfr_cost_at_density,
    Method,
};
use spar_sink::ot::cost::gibbs_kernel;
use spar_sink::ot::sinkhorn::{sinkhorn_ot, SinkhornParams};
use spar_sink::rng::Rng;

fn main() {
    let mut bencher = Bencher::quick();
    let eps = 0.05;
    // OT scaling.
    for &n in &[800usize, 1600, 3200] {
        let mut rng = Rng::seed_from(5);
        let inst = instance(Scenario::C1, n, 5, 1.0, 1.0, &mut rng);
        let cost = ot_cost(&inst.points);
        let kernel = gibbs_kernel(&cost, eps);
        bencher.bench(format!("ot/sinkhorn/n={n}"), || {
            std::hint::black_box(
                sinkhorn_ot(&kernel, &cost, &inst.a, &inst.b, eps, &SinkhornParams::default())
                    .unwrap(),
            );
        });
        bencher.bench(format!("ot/spar-sink/n={n}"), || {
            let mut r = Rng::seed_from(6);
            let _ = std::hint::black_box(run_method_ot(
                Method::SparSink,
                &cost,
                &inst.a,
                &inst.b,
                eps,
                8.0,
                &mut r,
            ));
        });
    }
    // UOT scaling (WFR @ 50% density).
    for &n in &[800usize, 1600] {
        let mut rng = Rng::seed_from(7);
        let inst = instance(Scenario::C1, n, 5, 5.0, 3.0, &mut rng);
        let cost = wfr_cost_at_density(&inst.points, SparsityRegime::R2.density());
        let _ = gibbs_kernel_inf(&cost, eps); // warm the kernel build path
        bencher.bench(format!("uot/sinkhorn/n={n}"), || {
            let _ = std::hint::black_box(exact_uot(&cost, &inst.a, &inst.b, 0.1, eps));
        });
        bencher.bench(format!("uot/spar-sink/n={n}"), || {
            let mut r = Rng::seed_from(8);
            let _ = std::hint::black_box(run_method_uot(
                Method::SparSink,
                &cost,
                &inst.a,
                &inst.b,
                0.1,
                eps,
                8.0,
                &mut r,
            ));
        });
    }
    println!("\n{}", bencher.report("bench_fig5_scaling"));
}
