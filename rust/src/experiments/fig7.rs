//! Figure 7 — cardiac cycle visualization: pairwise WFR distance matrix
//! of each synthetic patient's video (computed by the Spar-Sink
//! coordinator) followed by 2-D classical MDS; healthy vs heart-failure
//! vs arrhythmia patients show visibly different cycle loops.

use super::common::row;
use super::{ExperimentOutput, Profile};
use crate::coordinator::{
    CoordinatorConfig, DistanceJob, DistanceService, Measure, Method, ProblemSpec,
};
use crate::data::echo::{downsample_frames, frame_to_measure, generate, EchoConfig, Health};
use crate::linalg::{classical_mds, Mat};
use crate::rng::Rng;
use crate::util::json::Json;
use crate::util::table::f;

/// Compute the pairwise WFR distance matrix for a video through the
/// coordinator, then MDS-embed it.
///
/// Entropic UOT carries an additive entropy bias that makes raw
/// objectives of near-identical frames negative; we debias with the
/// Sinkhorn-divergence construction
/// `d(i,j)^2 = max(0, obj(i,j) - (obj(i,i) + obj(j,j)) / 2)`,
/// which is ~0 for identical frames and restores the cycle geometry.
pub fn video_distance_matrix(
    frames: &[Measure],
    spec: &ProblemSpec,
    service: &DistanceService,
    seed: u64,
) -> crate::error::Result<Mat> {
    let m = frames.len();
    let mut jobs = Vec::new();
    let mut id = 0u64;
    // Self jobs (debias terms) first, then the upper triangle.
    for frame in frames.iter() {
        jobs.push(DistanceJob {
            id,
            source: frame.clone(),
            target: frame.clone(),
            method: Method::SparSink,
            spec: spec.clone(),
            seed: seed + id,
        });
        id += 1;
    }
    for i in 0..m {
        for j in (i + 1)..m {
            jobs.push(DistanceJob {
                id,
                source: frames[i].clone(),
                target: frames[j].clone(),
                method: Method::SparSink,
                spec: spec.clone(),
                seed: seed + id,
            });
            id += 1;
        }
    }
    let results = service.submit_all(jobs)?;
    let self_obj: Vec<f64> = results[..m]
        .iter()
        .map(|r| if r.objective.is_finite() { r.objective } else { 0.0 })
        .collect();
    let mut dist = Mat::zeros(m, m);
    let mut idx = m;
    for i in 0..m {
        for j in (i + 1)..m {
            let o = results[idx].objective;
            let d = if o.is_finite() {
                (o - 0.5 * (self_obj[i] + self_obj[j])).max(0.0).sqrt()
            } else {
                0.0
            };
            dist.set(i, j, d);
            dist.set(j, i, d);
            idx += 1;
        }
    }
    Ok(dist)
}

/// Figure 7: cardiac-cycle WFR-distance curves for the three synthetic echo conditions.
pub fn run(profile: Profile) -> ExperimentOutput {
    let size = profile.pick(40, 64);
    let frames_n = profile.pick(36, 90);
    let sample_period = 3; // the paper's temporal downsampling
    let spec = ProblemSpec {
        lambda: 1.0,
        eps: 0.05,
        eta: size as f64 / 7.5, // ~15 at size 112, scaled down
        s_multiplier: 8.0,
        ..Default::default()
    };
    let service = DistanceService::start(CoordinatorConfig::default());

    let mut text = String::from("Figure 7 — cardiac cycles via WFR distance matrices + 2-D MDS\n");
    let mut rows = Vec::new();
    let mut rng = Rng::seed_from(0xF167);
    for health in [Health::Normal, Health::HeartFailure, Health::Arrhythmia] {
        let video = generate(
            &EchoConfig {
                size,
                frames: frames_n,
                period: 12.0,
                health,
                noise: 0.01,
            },
            &mut rng,
        );
        let keep = downsample_frames(&video, sample_period);
        let frames: Vec<Measure> = keep
            .iter()
            .map(|&i| {
                let (pts, mass) = frame_to_measure(&video.frames[i], size, 0.05);
                Measure::new(pts, mass)
            })
            .collect();
        let dist = video_distance_matrix(&frames, &spec, &service, 7 + health as u64)
            .expect("distance matrix");
        let mut mds_rng = Rng::seed_from(11);
        let emb = classical_mds(&dist, 2, &mut mds_rng);

        // Report: normalized distance-matrix summary + loop geometry.
        let max_d = dist.max();
        let (cx, cy) = (
            emb.iter().map(|p| p[0]).sum::<f64>() / emb.len() as f64,
            emb.iter().map(|p| p[1]).sum::<f64>() / emb.len() as f64,
        );
        let radii: Vec<f64> = emb
            .iter()
            .map(|p| ((p[0] - cx).powi(2) + (p[1] - cy).powi(2)).sqrt())
            .collect();
        let mean_r = radii.iter().sum::<f64>() / radii.len() as f64;
        let sd_r = (radii.iter().map(|r| (r - mean_r).powi(2)).sum::<f64>()
            / radii.len() as f64)
            .sqrt();
        text.push_str(&format!(
            "\n[{}] frames kept: {}  max WFR: {:.4}  MDS loop radius: {:.4} ± {:.4} (cv {:.2})\n",
            health.name(),
            frames.len(),
            max_d,
            mean_r,
            sd_r,
            sd_r / mean_r.max(1e-12),
        ));
        text.push_str("  MDS coordinates (frame: x, y):\n");
        for (k, p) in emb.iter().enumerate() {
            text.push_str(&format!("   {:>3}: {:>8}, {:>8}\n", keep[k], f(p[0], 4), f(p[1], 4)));
        }
        rows.push(row(vec![
            ("condition", Json::str(health.name())),
            ("frames", Json::num(frames.len() as f64)),
            ("max_wfr", Json::num(max_d)),
            ("loop_radius_mean", Json::num(mean_r)),
            ("loop_radius_cv", Json::num(sd_r / mean_r.max(1e-12))),
            (
                "mds",
                Json::arr(
                    emb.iter()
                        .map(|p| Json::arr(vec![Json::num(p[0]), Json::num(p[1])]))
                        .collect(),
                ),
            ),
        ]));
    }
    let m = service.shutdown();
    text.push_str(&format!("\ncoordinator: {}\n", m.render()));
    ExperimentOutput { id: "fig7", text, rows: Json::arr(rows) }
}
