//! The multi-process front end: a fingerprint-affine load balancer
//! over N backend gateways.
//!
//! ```text
//!   clients ──▶ Balancer (accept loop, same admission control
//!        │       as the gateway: connection cap → 503)
//!        │
//!        │  POST /solve | /barycenter
//!        │  decode a LOCAL copy → routing_fingerprint()
//!        │  home = routing_key() % backend count
//!        ▼
//!   ┌─ backend 0 ─┐  ┌─ backend 1 ─┐     ┌─ backend N-1 ─┐
//!   │ gateway +   │  │ gateway +   │  …  │ gateway +     │
//!   │ coordinator │  │ coordinator │     │ coordinator   │
//!   │ + own cache │  │ + own cache │     │ + own cache   │
//!   └─────────────┘  └─────────────┘     └───────────────┘
//! ```
//!
//! Three properties carry this module's weight:
//!
//! * **Affinity keeps caches warm.** Every job with a shareable cost
//!   fingerprint is routed by `routing_key() % N` — the SAME
//!   computation the in-process shard router uses
//!   ([`routing_fingerprint`](crate::coordinator::DistanceJob::routing_fingerprint)),
//!   one layer up. A given
//!   geometry therefore always lands on the same backend, whose
//!   `ArtifactCache` already holds its kernel: K distinct fingerprints
//!   cost K cache builds across the whole fleet, not K × N.
//!   Fingerprint-less jobs (oversized grids) round-robin.
//! * **Bitwise transparency.** The balancer decodes a local copy of
//!   the body only to compute the fingerprint; what it forwards is the
//!   ORIGINAL request body, byte for byte, and what it returns is the
//!   backend's response body, byte for byte. Placement can never
//!   change a reproduced number (pinned by the parity leg of
//!   `tests/balancer_integration.rs`).
//! * **Bounded failover, loud exhaustion.** 429 answers honor
//!   `retry-after` (clamped to [`BalancerConfig::backoff_cap`]); 503
//!   answers and socket errors evict the backend and fail over
//!   immediately; `/healthz` probes re-admit an evicted backend when
//!   it recovers. When [`BalancerConfig::retry_budget`] attempts are
//!   spent, the client gets an explicit `503` naming the budget — the
//!   balancer never hangs and never silently drops an accepted job.

use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::{render_balancer_prometheus, BalancerBackendStats};
use crate::error::{Error, Result};
use crate::net::client::{self, ClientResponse};
use crate::net::codec;
use crate::net::http::{read_request, HttpLimits, Request};
use crate::net::response::Response;
use crate::util::json::Json;
use crate::util::sync::{lock_unpoisoned, wait_timeout_unpoisoned};

/// How often the accept loop re-checks the drain flag between polls of
/// the non-blocking listener.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// Granularity at which sleeping loops (probe interval, retry backoff)
/// re-check the drain flag, so drains stay prompt.
const SLEEP_SLICE: Duration = Duration::from_millis(10);

/// Balancer tuning. `Default` binds an OS-picked loopback port and
/// carries test-friendly probe/retry settings; the CLI overrides
/// `addr`/`port`/`backends`.
#[derive(Clone, Debug)]
pub struct BalancerConfig {
    /// Bind address (default loopback).
    pub addr: String,
    /// Bind port; `0` lets the OS pick (reported by
    /// [`Balancer::local_addr`]).
    pub port: u16,
    /// Backend gateway addresses (`host:port`), in slot order. The
    /// affinity modulus is this list's LENGTH, so the mapping
    /// fingerprint → slot is stable regardless of which backends are
    /// currently healthy.
    pub backends: Vec<String>,
    /// Maximum concurrently served client connections; excess
    /// connections are refused with `503`, exactly like the gateway.
    pub max_connections: usize,
    /// Parser size caps per client request.
    pub limits: HttpLimits,
    /// Client-side socket read timeout (idle keep-alive connections).
    pub read_timeout: Duration,
    /// How often each backend's `/healthz` is probed for
    /// eviction/re-admission.
    pub probe_interval: Duration,
    /// Per-probe socket timeout (connect and read).
    pub probe_timeout: Duration,
    /// Upstream connect timeout for proxied jobs.
    pub connect_timeout: Duration,
    /// Upstream response timeout for proxied jobs (a solve can be
    /// slow; this guards against a wedged backend, not a busy one).
    pub upstream_timeout: Duration,
    /// Total attempts per proxied job (first try included). Exhaustion
    /// is a loud `503`, never a hang.
    pub retry_budget: usize,
    /// Backoff before retrying a `429` that carried no `retry-after`.
    pub retry_backoff: Duration,
    /// Upper clamp on any honored `retry-after` backoff.
    pub backoff_cap: Duration,
}

impl Default for BalancerConfig {
    fn default() -> Self {
        BalancerConfig {
            addr: "127.0.0.1".to_string(),
            port: 0,
            backends: Vec::new(),
            max_connections: 64,
            limits: HttpLimits::default(),
            read_timeout: Duration::from_secs(5),
            probe_interval: Duration::from_millis(200),
            probe_timeout: Duration::from_secs(1),
            connect_timeout: Duration::from_secs(1),
            upstream_timeout: Duration::from_secs(120),
            retry_budget: 4,
            retry_backoff: Duration::from_millis(100),
            backoff_cap: Duration::from_secs(2),
        }
    }
}

/// Live state + counters of one backend slot.
struct Backend {
    /// Slot index (the affinity modulus position).
    index: usize,
    /// The address as configured (metrics label).
    label: String,
    /// The resolved socket address probes and proxied jobs dial.
    addr: SocketAddr,
    /// Whether the balancer currently routes here.
    healthy: AtomicBool,
    routed_affine: AtomicU64,
    routed_round_robin: AtomicU64,
    completed: AtomicU64,
    retried: AtomicU64,
    evictions: AtomicU64,
    readmissions: AtomicU64,
}

impl Backend {
    /// Mark unhealthy; counts the transition (idempotent while down).
    fn evict(&self) {
        if self.healthy.swap(false, Ordering::SeqCst) {
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Mark healthy; counts the transition (idempotent while up).
    fn readmit(&self) {
        if !self.healthy.swap(true, Ordering::SeqCst) {
            self.readmissions.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn stats(&self) -> BalancerBackendStats {
        BalancerBackendStats {
            backend: self.index,
            addr: self.label.clone(),
            healthy: self.healthy.load(Ordering::SeqCst),
            routed_affine: self.routed_affine.load(Ordering::Relaxed),
            routed_round_robin: self.routed_round_robin.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            retried: self.retried.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            readmissions: self.readmissions.load(Ordering::Relaxed),
        }
    }
}

/// Shared state between the accept loop, handler threads, the probe
/// thread and `drain` (the balancer's analogue of the gateway's
/// lifecycle).
struct Shared {
    backends: Vec<Backend>,
    /// Round-robin cursor for fingerprint-less jobs.
    round_robin: AtomicUsize,
    /// Set once by `drain`: accept loop and probe thread exit,
    /// handlers answer `503` to new jobs.
    draining: AtomicBool,
    /// Live handler-thread count, guarded so `drain` can wait on it.
    active: Mutex<usize>,
    /// Signaled whenever a handler exits.
    idle: Condvar,
    /// Connections refused at the `max_connections` cap.
    rejected_at_cap: AtomicU64,
    config: BalancerConfig,
}

/// Decrements the active-connection count when a handler exits, panic
/// or not.
struct ConnectionGuard {
    shared: Arc<Shared>,
}

impl Drop for ConnectionGuard {
    fn drop(&mut self) {
        let mut active = lock_unpoisoned(&self.shared.active);
        *active = active.saturating_sub(1);
        drop(active);
        self.shared.idle.notify_all();
    }
}

/// A running balancer. See the module docs for the routing contract;
/// construction is [`Balancer::start`].
pub struct Balancer {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    probe: Option<JoinHandle<()>>,
}

impl Balancer {
    /// Resolve the backend addresses, bind the front listener, and
    /// start the accept and probe threads. Backends start healthy (the
    /// first failed probe or proxied request evicts them). At least one
    /// backend is required; an unresolvable address is a loud startup
    /// error, not a permanently dead slot.
    pub fn start(config: BalancerConfig) -> Result<Balancer> {
        if config.backends.is_empty() {
            return Err(Error::Coordinator("balancer needs at least one backend".into()));
        }
        if config.retry_budget == 0 {
            return Err(Error::Coordinator("balancer retry budget must be at least 1".into()));
        }
        let mut backends = Vec::with_capacity(config.backends.len());
        for (index, label) in config.backends.iter().enumerate() {
            let addr = label
                .to_socket_addrs()
                .map_err(|e| Error::Coordinator(format!("backend '{label}': {e}")))?
                .next()
                .ok_or_else(|| {
                    Error::Coordinator(format!("backend '{label}' resolved to no address"))
                })?;
            backends.push(Backend {
                index,
                label: label.clone(),
                addr,
                healthy: AtomicBool::new(true),
                routed_affine: AtomicU64::new(0),
                routed_round_robin: AtomicU64::new(0),
                completed: AtomicU64::new(0),
                retried: AtomicU64::new(0),
                evictions: AtomicU64::new(0),
                readmissions: AtomicU64::new(0),
            });
        }
        let listener = match TcpListener::bind((config.addr.as_str(), config.port)) {
            Ok(listener) => listener,
            Err(e) => {
                let msg = format!("balancer bind {}:{}: {e}", config.addr, config.port);
                return Err(Error::Coordinator(msg));
            }
        };
        let addr = listener
            .local_addr()
            .map_err(|e| Error::Coordinator(format!("balancer local_addr: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::Coordinator(format!("balancer set_nonblocking: {e}")))?;
        let shared = Arc::new(Shared {
            backends,
            round_robin: AtomicUsize::new(0),
            draining: AtomicBool::new(false),
            active: Mutex::new(0),
            idle: Condvar::new(),
            rejected_at_cap: AtomicU64::new(0),
            config,
        });
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("balancer-accept".to_string())
                .spawn(move || accept_loop(listener, shared))
                .map_err(|e| Error::Coordinator(format!("balancer accept thread: {e}")))?
        };
        let probe = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("balancer-probe".to_string())
                .spawn(move || probe_loop(&shared))
                .map_err(|e| Error::Coordinator(format!("balancer probe thread: {e}")))?
        };
        Ok(Balancer { shared, addr, accept: Some(accept), probe: Some(probe) })
    }

    /// The bound front address (resolves port `0` to the OS pick).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connections refused at the connection cap so far.
    pub fn rejected_at_cap(&self) -> u64 {
        self.shared.rejected_at_cap.load(Ordering::Relaxed)
    }

    /// Per-backend counters, in slot order — what `/metrics` renders
    /// and what the integration wall asserts on.
    pub fn stats(&self) -> Vec<BalancerBackendStats> {
        self.shared.backends.iter().map(Backend::stats).collect()
    }

    /// Graceful drain: stop accepting and probing, refuse new jobs,
    /// and wait for in-flight connections (their proxied jobs complete
    /// normally). Idempotent.
    pub fn drain(&mut self) {
        self.shared.draining.store(true, Ordering::SeqCst);
        if let Some(accept) = self.accept.take() {
            let _ = accept.join();
        }
        if let Some(probe) = self.probe.take() {
            let _ = probe.join();
        }
        let mut active = lock_unpoisoned(&self.shared.active);
        while *active > 0 {
            active =
                wait_timeout_unpoisoned(&self.shared.idle, active, Duration::from_millis(50));
        }
    }
}

impl Drop for Balancer {
    fn drop(&mut self) {
        self.drain();
    }
}

/// Sleep `total` in [`SLEEP_SLICE`] steps, bailing early on drain.
fn interruptible_sleep(shared: &Shared, total: Duration) {
    let mut remaining = total;
    while !remaining.is_zero() && !shared.draining.load(Ordering::SeqCst) {
        let step = remaining.min(SLEEP_SLICE);
        std::thread::sleep(step);
        remaining = remaining.saturating_sub(step);
    }
}

/// The health-probe loop: every `probe_interval`, hit each backend's
/// `/healthz`. `200` re-admits, anything else (including a refused
/// connection or a `503 draining`) evicts. This is the ONLY
/// re-admission path — proxied traffic can evict but never re-admit,
/// so one good probe is required before an evicted backend sees jobs
/// again.
fn probe_loop(shared: &Shared) {
    while !shared.draining.load(Ordering::SeqCst) {
        for backend in &shared.backends {
            let healthy = matches!(
                client::request(
                    backend.addr,
                    "GET",
                    "/healthz",
                    None,
                    shared.config.probe_timeout,
                    shared.config.probe_timeout,
                ),
                Ok(ClientResponse { status: 200, .. })
            );
            if healthy {
                backend.readmit();
            } else {
                backend.evict();
            }
        }
        interruptible_sleep(shared, shared.config.probe_interval);
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    loop {
        if shared.draining.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let admitted = {
                    let mut active = lock_unpoisoned(&shared.active);
                    if *active >= shared.config.max_connections {
                        false
                    } else {
                        *active += 1;
                        true
                    }
                };
                if !admitted {
                    shared.rejected_at_cap.fetch_add(1, Ordering::Relaxed);
                    refuse_at_capacity(stream);
                    continue;
                }
                let guard = ConnectionGuard { shared: Arc::clone(&shared) };
                let shared = Arc::clone(&shared);
                let spawned = std::thread::Builder::new()
                    .name("balancer-conn".to_string())
                    .spawn(move || {
                        let _guard = guard;
                        handle_connection(stream, &shared);
                    });
                // Spawn failure drops `guard` here, releasing the slot.
                drop(spawned);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

/// Answer `503` on a connection refused at the connection cap.
fn refuse_at_capacity(mut stream: TcpStream) {
    let _ = Response::error(503, "connection capacity reached").write_to(&mut stream);
    let _ = stream.flush();
}

/// Serve one client connection: parse → route/proxy → respond, looping
/// while the client keeps the connection alive.
fn handle_connection(stream: TcpStream, shared: &Shared) {
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    let _ = stream.set_read_timeout(Some(shared.config.read_timeout));
    let _ = stream.set_nodelay(true);
    let Ok(mut writer) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(stream);
    loop {
        match read_request(&mut reader, &shared.config.limits) {
            Ok(request) => {
                let response = route(shared, &request);
                let close = response.close || !request.keep_alive();
                if response.write_to(&mut writer).is_err() || close {
                    return;
                }
            }
            Err(err) => {
                if let Some(status) = err.status() {
                    let _ = Response::error(status, &err.message()).write_to(&mut writer);
                }
                return;
            }
        }
    }
}

/// The balancer's route table — the same surface as the gateway's
/// router, with `/solve` and `/barycenter` proxied instead of solved.
fn route(shared: &Shared, req: &Request) -> Response {
    let path = req.path.split('?').next().unwrap_or("");
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => healthz(shared),
        ("GET", "/metrics") => {
            let stats: Vec<BalancerBackendStats> =
                shared.backends.iter().map(Backend::stats).collect();
            Response::text(200, "text/plain; version=0.0.4", render_balancer_prometheus(&stats))
        }
        ("POST", "/solve") => proxy_job(shared, req, "/solve", JobKind::Distance),
        ("POST", "/barycenter") => proxy_job(shared, req, "/barycenter", JobKind::Barycenter),
        (_, "/healthz" | "/metrics") => method_not_allowed("GET"),
        (_, "/solve" | "/barycenter") => method_not_allowed("POST"),
        _ => Response::error(404, &format!("no such endpoint '{path}'")),
    }
}

/// `200 ok` while at least one backend is routable and the balancer is
/// not draining; `503` otherwise (probes in front of the balancer see
/// the fleet's aggregate health).
fn healthz(shared: &Shared) -> Response {
    if shared.draining.load(Ordering::SeqCst) {
        return Response::json(503, &Json::obj(vec![("status", Json::str("draining"))]));
    }
    let healthy =
        shared.backends.iter().filter(|b| b.healthy.load(Ordering::SeqCst)).count();
    if healthy == 0 {
        return Response::json(
            503,
            &Json::obj(vec![("status", Json::str("no healthy backends"))]),
        );
    }
    Response::json(
        200,
        &Json::obj(vec![
            ("status", Json::str("ok")),
            ("healthy_backends", Json::num(healthy as f64)),
        ]),
    )
}

fn method_not_allowed(allow: &'static str) -> Response {
    Response::error(405, &format!("method not allowed (use {allow})"))
        .with_header("allow", allow.to_string())
}

/// Which job endpoint a proxied request targets (fingerprints are
/// computed with the matching decoder so balancer affinity and the
/// backend's own shard router always agree).
#[derive(Clone, Copy)]
enum JobKind {
    Distance,
    Barycenter,
}

/// Decode a LOCAL copy of the body just far enough to compute the
/// routing fingerprint. Decode failures answer `400` here with the
/// same codec error a backend would produce — a malformed job never
/// spends retry budget.
fn routing_slot(shared: &Shared, req: &Request, kind: JobKind) -> Result2<Option<usize>> {
    if req.body.is_empty() {
        return Err(Response::error(400, "missing JSON body"));
    }
    let Ok(text) = std::str::from_utf8(&req.body) else {
        return Err(Response::error(400, "body is not valid UTF-8"));
    };
    let payload = match Json::parse(text) {
        Ok(payload) => payload,
        Err(e) => return Err(Response::error(400, &format!("bad JSON payload: {e}"))),
    };
    let fingerprint = match kind {
        JobKind::Distance => match codec::decode_distance_job(&payload) {
            Ok(job) => job.routing_fingerprint(),
            Err(e) => return Err(Response::error(400, &e)),
        },
        JobKind::Barycenter => match codec::decode_barycenter_job(&payload) {
            Ok(job) => job.routing_fingerprint(),
            Err(e) => return Err(Response::error(400, &e)),
        },
    };
    Ok(fingerprint.map(|f| (f.routing_key() % shared.backends.len() as u64) as usize))
}

/// Internal early-return plumbing: `Err` is a ready client response.
type Result2<T> = std::result::Result<T, Response>;

/// Pick the backend for one attempt: the home slot when it is healthy
/// (affine), otherwise the first healthy slot scanning forward
/// (failover, counted round-robin); fingerprint-less jobs start from
/// the round-robin cursor. `None` = no healthy backend at all.
fn pick_backend<'a>(shared: &'a Shared, home: Option<usize>) -> Option<(&'a Backend, bool)> {
    let n = shared.backends.len();
    let start = match home {
        Some(slot) => slot,
        None => shared.round_robin.fetch_add(1, Ordering::Relaxed) % n,
    };
    for offset in 0..n {
        let backend = &shared.backends[(start + offset) % n];
        if backend.healthy.load(Ordering::SeqCst) {
            let affine = home == Some(backend.index);
            return Some((backend, affine));
        }
    }
    None
}

/// Proxy one job: route by fingerprint, forward the ORIGINAL body
/// verbatim, and relay the backend's response verbatim. Retries are
/// bounded by `retry_budget`; see the module docs for the 429/503/IO
/// policy.
fn proxy_job(shared: &Shared, req: &Request, path: &str, kind: JobKind) -> Response {
    if shared.draining.load(Ordering::SeqCst) {
        return Response::error(503, "balancer is draining");
    }
    let home = match routing_slot(shared, req, kind) {
        Ok(home) => home,
        Err(response) => return response,
    };
    let mut last_error = String::new();
    for _ in 0..shared.config.retry_budget {
        let Some((backend, affine)) = pick_backend(shared, home) else {
            return Response::error(503, "no healthy backends");
        };
        if affine {
            backend.routed_affine.fetch_add(1, Ordering::Relaxed);
        } else {
            backend.routed_round_robin.fetch_add(1, Ordering::Relaxed);
        }
        match client::request(
            backend.addr,
            "POST",
            path,
            Some(&req.body),
            shared.config.connect_timeout,
            shared.config.upstream_timeout,
        ) {
            Ok(upstream) if upstream.status == 429 => {
                backend.retried.fetch_add(1, Ordering::Relaxed);
                last_error = format!(
                    "backend {} ({}) answered 429",
                    backend.index, backend.label
                );
                // Saturation is transient: honor retry-after (clamped),
                // keep the backend healthy, try again.
                let backoff = upstream
                    .retry_after()
                    .unwrap_or(shared.config.retry_backoff)
                    .min(shared.config.backoff_cap);
                interruptible_sleep(shared, backoff);
            }
            Ok(upstream) if upstream.status == 503 => {
                backend.retried.fetch_add(1, Ordering::Relaxed);
                backend.evict();
                last_error = format!(
                    "backend {} ({}) answered 503 (evicted)",
                    backend.index, backend.label
                );
                // Draining/stopped is not transient for THIS backend:
                // evict it and fail over immediately.
            }
            Ok(upstream) => {
                if upstream.status < 400 {
                    backend.completed.fetch_add(1, Ordering::Relaxed);
                }
                // 2xx results and deterministic client errors (400,
                // 413, …) relay verbatim — retrying them cannot
                // change the answer.
                return relay(&upstream);
            }
            Err(e) => {
                backend.retried.fetch_add(1, Ordering::Relaxed);
                backend.evict();
                last_error = format!(
                    "backend {} ({}) failed: {e} (evicted)",
                    backend.index, backend.label
                );
            }
        }
    }
    Response::error(
        503,
        &format!(
            "retry budget exhausted after {} attempts; last error: {last_error}",
            shared.config.retry_budget
        ),
    )
}

/// Relay an upstream response to the client byte-for-byte, mapping the
/// content-type onto the gateway's static vocabulary and preserving
/// `retry-after` when present.
fn relay(upstream: &ClientResponse) -> Response {
    let content_type: &'static str = match upstream.header("content-type") {
        Some("application/json") | None => "application/json",
        Some("text/plain; version=0.0.4") => "text/plain; version=0.0.4",
        Some(_) => "application/octet-stream",
    };
    let mut response = Response {
        status: upstream.status,
        content_type,
        body: upstream.body.clone(),
        close: upstream.status >= 400,
        extra: Vec::new(),
    };
    if let Some(retry_after) = upstream.header("retry-after") {
        response = response.with_header("retry-after", retry_after.to_string());
    }
    response
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn start_refuses_an_empty_backend_list_and_a_zero_budget() {
        assert!(Balancer::start(BalancerConfig::default()).is_err());
        let err = Balancer::start(BalancerConfig {
            backends: vec!["127.0.0.1:1".to_string()],
            retry_budget: 0,
            ..BalancerConfig::default()
        });
        assert!(err.is_err());
    }

    #[test]
    fn start_refuses_an_unresolvable_backend_loudly() {
        let err = Balancer::start(BalancerConfig {
            backends: vec!["not-an-address".to_string()],
            ..BalancerConfig::default()
        })
        .err()
        .expect("must not start");
        assert!(err.to_string().contains("not-an-address"), "{err}");
    }

    #[test]
    fn relay_preserves_body_bytes_and_retry_after() {
        let upstream = ClientResponse {
            status: 429,
            headers: vec![
                ("content-type".to_string(), "application/json".to_string()),
                ("retry-after".to_string(), "1".to_string()),
            ],
            body: b"{\"error\":\"busy\"}".to_vec(),
        };
        let relayed = relay(&upstream);
        assert_eq!(relayed.status, 429);
        assert_eq!(relayed.body, upstream.body);
        assert_eq!(relayed.extra, vec![("retry-after", "1".to_string())]);
        assert!(relayed.close);
    }
}
