//! Appendix Fig. 13 — color transfer: transfer the sunset palette onto
//! the daytime cloud via entropic OT plans computed by Sinkhorn,
//! Nys-Sink and Spar-Sink; report each method's barycentric color-map
//! deviation from the Sinkhorn map plus wall time.

use std::sync::Arc;

use super::common::row;
use super::{ExperimentOutput, Profile};
use crate::api::{self, Method, OtProblem, SolverSpec};
use crate::data::images::{barycentric_map, daytime_cloud, sunset_cloud};
use crate::linalg::Mat;
use crate::ot::cost::{gibbs_kernel, normalize_cost, sq_euclidean_cost};
use crate::ot::sinkhorn::transport_plan;
use crate::rng::Rng;
use crate::util::json::Json;
use crate::util::table::{f, Table};

/// Mean RGB deviation between two color maps.
fn map_deviation(a: &[Vec<f64>], b: &[Vec<f64>]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            x.iter()
                .zip(y)
                .map(|(u, v)| (u - v) * (u - v))
                .sum::<f64>()
                .sqrt()
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Appendix Figure 13: color-transfer map deviation and timing.
pub fn run(profile: Profile) -> ExperimentOutput {
    let n = profile.pick(600, 5000);
    let eps = 1e-2;
    let s_mult = 8.0;
    let mut rng = Rng::seed_from(0xF173);
    let source = daytime_cloud(n, &mut rng);
    let target = sunset_cloud(n, &mut rng);
    let a = vec![1.0 / n as f64; n];
    let b = vec![1.0 / n as f64; n];
    let cost = Arc::new(normalize_cost(&sq_euclidean_cost(&source, &target)));
    let kernel = gibbs_kernel(&cost, eps);
    let problem = OtProblem::balanced(&cost, a, b, eps);

    // Reference: full Sinkhorn plan -> barycentric map. As in fig5, each
    // solve's wall time now includes its own kernel materialization (the
    // full cost a fresh request pays); the kernel built above is reused
    // only for the plan/map reconstruction.
    let exact = api::solve_with_rng(&problem, &SolverSpec::new(Method::Sinkhorn), &mut rng)
        .expect("sinkhorn");
    let sink_secs = exact.wall_time.as_secs_f64();
    let plan = transport_plan(&kernel, &exact.u, &exact.v);
    let ref_map = barycentric_map(
        |i| (0..n).map(|j| (j, plan.get(i, j))).collect(),
        &target,
        n,
    );

    let mut table = Table::new(&["method", "seconds", "map deviation (RGB)"]);
    let mut rows = Vec::new();
    let push = |name: &str, secs: f64, dev: f64, table: &mut Table, rows: &mut Vec<Json>| {
        table.row(vec![name.into(), f(secs, 3), f(dev, 4)]);
        rows.push(row(vec![
            ("method", Json::str(name)),
            ("seconds", Json::num(secs)),
            ("deviation", Json::num(dev)),
        ]));
    };
    push("sinkhorn", sink_secs, 0.0, &mut table, &mut rows);

    // The accelerated arms, all through the registry. For each, rebuild
    // the represented plan from the returned scalings against the full
    // kernel for the barycentric map (sketch rows alone would miss the
    // unsampled entries the scalings still describe).
    let arms = [
        ("spar-sink", SolverSpec::new(Method::SparSink).with_budget(s_mult)),
        ("nys-sink", SolverSpec::new(Method::NysSink).with_budget(s_mult)),
        (
            "robust-nyssink",
            SolverSpec::new(Method::NysSink).with_budget(s_mult).with_robust_clip(1e3),
        ),
    ];
    for (name, spec) in arms {
        if let Ok(sol) = api::solve_with_rng(&problem, &spec, &mut rng) {
            let plan_s = Mat::from_fn(n, n, |i, j| sol.u[i] * kernel.get(i, j) * sol.v[j]);
            let map =
                barycentric_map(|i| (0..n).map(|j| (j, plan_s.get(i, j))).collect(), &target, n);
            push(
                name,
                sol.wall_time.as_secs_f64(),
                map_deviation(&ref_map, &map),
                &mut table,
                &mut rows,
            );
        }
    }

    let text = format!(
        "Appendix Fig. 13 — color transfer (n = {n} RGB samples, eps = {eps}, s = 8 s0(n))\n\
         deviation = mean RGB distance from the Sinkhorn barycentric map\n{}",
        table.render()
    );
    ExperimentOutput { id: "fig13", text, rows: Json::arr(rows) }
}
