//! # The shared-cost artifact engine
//!
//! Batched workloads — the echocardiogram pairwise-distance matrix
//! above all — solve many transport problems whose marginals differ but
//! whose geometry (support × η × ε × formulation) is identical. Cold,
//! every job re-derives the WFR cost oracle, the Gibbs kernel, and the
//! cost-dependent part of its sampling probabilities from scratch; with
//! this engine the cost-dependent work is materialized once as
//! [`CostArtifacts`] behind a content-addressed [`ArtifactCache`]
//! (fingerprint = support hash × η × ε × formulation, byte-budget LRU,
//! hit/miss/eviction counters) and every later job is "reuse +
//! reweight": only the per-job marginal factor is recomputed.
//!
//! The flow through the stack:
//!
//! ```text
//!   supports (η, ε, formulation)
//!        │ Fingerprint::for_supports / ::for_dense
//!        ▼
//!   ArtifactCache::get_or_build ──▶ CostArtifacts
//!        │                           cost, kernel, row/col sums,
//!        │                           ‖K‖_F, β·ln K (UOT factor)
//!        ▼
//!   CostSource::Shared(CostHandle)          (api layer)
//!        ▼
//!   samplers consume the amortized factor   (sparse layer)
//!        ▼
//!   api::solve_batch / coordinator workers  (serving layer)
//! ```
//!
//! Warm solves are bitwise-identical to cold solves: the artifacts
//! store exactly the values the entry oracles would have produced, and
//! the factored samplers compose probabilities with the same arithmetic
//! (pinned by `rust/tests/cache_parity.rs`).

mod artifacts;
mod cache;

pub use artifacts::{
    CostArtifacts, CostHandle, Fingerprint, FormulationKey, UotLogFactor,
    SHARED_ARTIFACT_ENTRY_CAP,
};
pub use cache::{global_cache, ArtifactCache, CacheStats, DEFAULT_CACHE_BYTES};
