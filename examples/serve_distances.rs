//! END-TO-END DRIVER: the full three-layer system on a real workload.
//!
//! Serves batched WFR-distance requests for a fleet of synthetic
//! echocardiogram videos through the coordinator (L3) — every job
//! dispatched through `api::solve` — and, when built with the `xla`
//! feature, cross-checks the exact dense path on the PJRT runtime
//! (L2 JAX blocks + L1 Pallas kernels compiled AOT to
//! `artifacts/*.hlo.txt`) where the artifact menu covers the support
//! size. Reports per-method latency/throughput, the accuracy gap, and
//! the log-domain escalation metrics — proving all layers compose.
//!
//! ```sh
//! make artifacts && cargo run --release --features xla --example serve_distances
//! cargo run --release --example serve_distances   # coordinator only
//! ```

use std::time::Instant;

use spar_sink::coordinator::{
    CoordinatorConfig, DistanceJob, DistanceService, Measure, Method, ProblemSpec,
};
use spar_sink::data::echo::{downsample_frames, frame_to_measure, generate, EchoConfig, Health};
use spar_sink::rng::Rng;

fn main() {
    let size = 24; // keeps supports <= 1024 so the PJRT menu covers them
    let videos = 3;
    let spec = ProblemSpec { eta: size as f64 / 7.5, eps: 0.05, s_multiplier: 8.0, ..Default::default() };
    let mut rng = Rng::seed_from(31);

    // Build the workload: all frame pairs of each video.
    let mut measures_all: Vec<Vec<Measure>> = Vec::new();
    for v in 0..videos {
        let video = generate(
            &EchoConfig {
                size,
                frames: 24,
                period: 8.0,
                health: [Health::Normal, Health::HeartFailure, Health::Arrhythmia][v % 3],
                noise: 0.01,
            },
            &mut rng,
        );
        let keep = downsample_frames(&video, 3);
        measures_all.push(
            keep.iter()
                .map(|&i| {
                    let (pts, mass) = frame_to_measure(&video.frames[i], size, 0.05);
                    Measure::new(pts, mass)
                })
                .collect(),
        );
    }

    // --- L3 coordinator path (Spar-Sink + exact Sinkhorn jobs) ---
    let service = DistanceService::start(CoordinatorConfig::default());
    let mut jobs = Vec::new();
    let mut id = 0u64;
    for frames in &measures_all {
        for i in 0..frames.len() {
            for j in (i + 1)..frames.len() {
                for method in [Method::SparSink, Method::Sinkhorn] {
                    jobs.push(DistanceJob {
                        id,
                        source: frames[i].clone(),
                        target: frames[j].clone(),
                        method,
                        spec: spec.clone(),
                        seed: id,
                    });
                    id += 1;
                }
            }
        }
    }
    let total_jobs = jobs.len();
    println!("submitting {total_jobs} WFR jobs ({videos} videos) to the coordinator…");
    let t0 = Instant::now();
    let results = service.submit_all(jobs).expect("service");
    let wall = t0.elapsed();
    let ok = results.iter().filter(|r| r.error.is_none()).count();
    // Accuracy: pair up (spar, sinkhorn) results.
    let mut gaps = Vec::new();
    for pair in results.chunks(2) {
        if let [a, b] = pair {
            if a.error.is_none() && b.error.is_none() {
                gaps.push((a.objective - b.objective).abs() / b.objective.abs().max(1e-12));
            }
        }
    }
    let mean_gap = gaps.iter().sum::<f64>() / gaps.len().max(1) as f64;
    println!(
        "coordinator: {ok}/{total_jobs} ok in {wall:?}  mean spar-vs-exact objective gap {mean_gap:.4}"
    );
    println!("{}\n", service.shutdown().render());

    // --- PJRT runtime path: the same UOT solve through the AOT stack ---
    pjrt_cross_check(&measures_all, &spec);
}

#[cfg(feature = "xla")]
fn pjrt_cross_check(measures_all: &[Vec<Measure>], spec: &ProblemSpec) {
    use std::sync::Arc;

    use spar_sink::linalg::Mat;
    use spar_sink::ot::cost::{euclidean, wfr_cost_from_distance, wfr_kernel_from_distance};
    use spar_sink::runtime::{
        default_artifact_dir, manifest_path, ArtifactRegistry, DenseSinkhornRuntime,
    };

    let dir = default_artifact_dir();
    if !manifest_path(&dir).exists() {
        println!("artifacts not built — skipping PJRT cross-check (run `make artifacts`)");
        return;
    }
    let registry = Arc::new(ArtifactRegistry::open(&dir).expect("registry"));
    let runtime = DenseSinkhornRuntime::new(registry);
    let frames = &measures_all[0];
    let (src, dst) = (&frames[0], &frames[frames.len() / 2]);
    let (n_s, n_t) = (src.len(), dst.len());
    let n = n_s.max(n_t);
    // Shared padded support: embed both measures in one index space.
    let kernel = Mat::from_fn(n, n, |i, j| {
        if i < n_s && j < n_t {
            wfr_kernel_from_distance(euclidean(&src.points[i], &dst.points[j]), spec.eta, spec.eps)
        } else if i == j {
            1.0
        } else {
            0.0
        }
    });
    let cost = Mat::from_fn(n, n, |i, j| {
        if i < n_s && j < n_t {
            let c = wfr_cost_from_distance(euclidean(&src.points[i], &dst.points[j]), spec.eta);
            if c.is_finite() { c } else { 0.0 }
        } else {
            0.0
        }
    });
    let mut a = vec![1e-12; n];
    a[..n_s].copy_from_slice(&src.mass);
    let mut b = vec![1e-12; n];
    b[..n_t].copy_from_slice(&dst.mass);

    let t0 = Instant::now();
    match runtime.solve_uot(&kernel, &cost, &a, &b, spec.lambda, spec.eps, 1e-6, 1000) {
        Ok(sol) => {
            println!(
                "PJRT runtime (L1 Pallas + L2 JAX + PJRT CPU): UOT objective {:.6} in {:?} ({} iters, converged {})",
                sol.objective,
                t0.elapsed(),
                sol.iterations,
                sol.converged
            );
            // Native cross-check.
            let native = spar_sink::ot::uot::sinkhorn_uot(
                &kernel,
                &cost,
                &a,
                &b,
                spec.lambda,
                spec.eps,
                &spar_sink::ot::sinkhorn::SinkhornParams::default(),
            )
            .expect("native");
            let rel = (sol.objective - native.objective).abs() / native.objective.abs().max(1e-12);
            println!(
                "native Rust solver:                            UOT objective {:.6}  (relative gap {rel:.2e})",
                native.objective
            );
        }
        Err(e) => println!("runtime solve failed: {e}"),
    }
}

#[cfg(not(feature = "xla"))]
fn pjrt_cross_check(_measures_all: &[Vec<Measure>], _spec: &ProblemSpec) {
    println!(
        "built without the `xla` feature — skipping the PJRT cross-check \
         (rebuild with `--features xla` after `make artifacts`)"
    );
}
