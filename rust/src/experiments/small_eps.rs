//! Small-ε stability harness: sweeps ε across and below the
//! multiplicative underflow point and reports, per scaling backend,
//! failure counts and RMAE against the stable dense truth.
//!
//! With the cost normalized to c₀ = 1, `K = exp(−C/ε)` loses its last
//! representable entries around ε ≈ c₀/708 ≈ 1.4×10⁻³ — below that,
//! the multiplicative sparse loop either errors or collapses onto the
//! degenerate all-zero plan, which is exactly what this sweep makes
//! visible (`fail` counts plus RMAE ≈ 1). The log-domain backend (and
//! `Auto`, which escalates to it) keeps solving.

use super::common::{exact_ot_stable, ot_cost, rmae_over_reps, row};
use super::{ExperimentOutput, Profile};
use crate::api::{self, Method, OtProblem, SolverSpec};
use crate::data::synthetic::{instance, Scenario};
use crate::rng::Rng;
use crate::solvers::backend::ScalingBackend;
use crate::util::json::Json;
use crate::util::table::{f, Table};

pub fn run(profile: Profile) -> ExperimentOutput {
    let n = profile.pick(120, 500);
    let reps = profile.reps(3, 20);
    let s_mult = 16.0;
    let mut rng = Rng::seed_from(0x5E95);
    let inst = instance(Scenario::C1, n, 5, 1.0, 1.0, &mut rng);
    let cost = ot_cost(&inst.points);

    let backends: [(&str, ScalingBackend); 3] = [
        ("multiplicative", ScalingBackend::Multiplicative),
        ("log", ScalingBackend::LogDomain),
        ("auto", ScalingBackend::default()),
    ];
    let mut table = Table::new(&["eps", "backend", "rmae", "se", "fail", "truth"]);
    let mut rows = Vec::new();
    for &eps in &[1e-1, 1e-2, 2e-3, 5e-4, 1e-4] {
        let Ok(truth) = exact_ot_stable(&cost, &inst.a, &inst.b, eps) else {
            table.row(vec![
                format!("{eps:.0e}"),
                "(truth failed)".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
            continue;
        };
        let problem = OtProblem::balanced(&cost, inst.a.clone(), inst.b.clone(), eps);
        for (name, backend) in backends {
            let spec =
                SolverSpec::new(Method::SparSink).with_budget(s_mult).with_backend(backend);
            let (rmae, se, failures) = rmae_over_reps(
                reps,
                truth,
                |r| api::solve_with_rng(&problem, &spec, r).map(|s| s.objective),
                &mut rng,
            );
            table.row(vec![
                format!("{eps:.0e}"),
                name.into(),
                f(rmae, 4),
                f(se, 4),
                failures.to_string(),
                f(truth, 4),
            ]);
            rows.push(row(vec![
                ("eps", Json::num(eps)),
                ("backend", Json::str(name)),
                ("rmae", Json::num(rmae)),
                ("se", Json::num(se)),
                ("failures", Json::num(failures as f64)),
                ("truth", Json::num(truth)),
            ]));
        }
    }
    ExperimentOutput {
        id: "smalleps",
        text: format!(
            "Small-eps backend stability (n={n}, s={s_mult}s0, {reps} reps)\n{}",
            table.render()
        ),
        rows: Json::arr(rows),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_profile_runs_and_reports_all_backends() {
        let out = run(Profile::Quick);
        assert_eq!(out.id, "smalleps");
        // 5 eps values x 3 backends.
        assert_eq!(out.rows.items().len(), 15);
        // At the smallest eps the log backend must have zero failures.
        let log_small = out
            .rows
            .items()
            .iter()
            .find(|r| {
                r.get("backend").and_then(|b| b.as_str()) == Some("log")
                    && r.get("eps").and_then(|e| e.as_f64()) == Some(1e-4)
            })
            .expect("missing log row");
        assert_eq!(log_small.get("failures").and_then(|x| x.as_f64()), Some(0.0));
    }
}
