//! Screenkhorn (Alaya et al., 2019) — screened Sinkhorn: identify the
//! "active" support points whose dual scalings cannot sit at the
//! screening floor, solve the restricted problem on the active set, and
//! pin the screened-out scalings at the floor value.
//!
//! We implement the static screening rule of the original paper: with a
//! decimation factor κ, keep the n_b = n/κ rows (and columns) with the
//! largest screening statistic `a_i / Σ_j K_ij` (resp. `b_j / Σ_i K_ij`),
//! run full Sinkhorn on the restricted kernel with renormalized
//! marginals, and set screened scalings to the floor. This reproduces
//! the accuracy/speed trade-off the paper's Figs. 4-5 show (including
//! its failure for very small ε, which we also observe).

use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::ot::objective::ot_objective_dense;
use crate::ot::sinkhorn::{sinkhorn_scalings, SinkhornParams};
use crate::ot::SinkhornSolution;

/// Screenkhorn configuration (paper default decimation 3).
#[derive(Clone, Debug)]
pub struct ScreenkhornParams {
    /// Scaling-loop parameters (δ, iteration cap).
    pub sinkhorn: SinkhornParams,
    /// Decimation factor κ: keep n/κ active rows and columns.
    pub decimation: usize,
}

impl Default for ScreenkhornParams {
    fn default() -> Self {
        ScreenkhornParams { sinkhorn: SinkhornParams::default(), decimation: 3 }
    }
}

/// Indices of the `keep` largest values of `score`.
fn top_indices(score: &[f64], keep: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..score.len()).collect();
    idx.sort_by(|&i, &j| score[j].total_cmp(&score[i]));
    let mut out = idx[..keep.min(score.len())].to_vec();
    out.sort_unstable();
    out
}

/// Run Screenkhorn for entropic OT and evaluate Eq. 6 on the full plan.
pub fn screenkhorn_ot(
    kernel: &Mat,
    cost: &Mat,
    a: &[f64],
    b: &[f64],
    eps: f64,
    params: &ScreenkhornParams,
) -> Result<SinkhornSolution> {
    let n = a.len();
    let m = b.len();
    if kernel.rows() != n || kernel.cols() != m {
        return Err(Error::Dimension(format!(
            "kernel {}x{} vs a[{n}], b[{m}]",
            kernel.rows(),
            kernel.cols()
        )));
    }
    if params.decimation == 0 {
        return Err(Error::InvalidParam("decimation must be >= 1".into()));
    }
    let keep_r = (n / params.decimation).max(2);
    let keep_c = (m / params.decimation).max(2);

    // Screening statistic: how much scaling a point needs relative to the
    // kernel mass available to it. The screening floor for inactive
    // scalings follows Alaya et al.'s (epsilon-scaled) kappa value.
    let row_mass = kernel.row_sums();
    let col_mass = kernel.col_sums();
    let score_r: Vec<f64> = (0..n).map(|i| a[i] / row_mass[i].max(1e-300)).collect();
    let score_c: Vec<f64> = (0..m).map(|j| b[j] / col_mass[j].max(1e-300)).collect();
    let active_r = top_indices(&score_r, keep_r);
    let active_c = top_indices(&score_c, keep_c);

    // Restricted problem with renormalized marginals.
    let a_mass: f64 = active_r.iter().map(|&i| a[i]).sum();
    let b_mass: f64 = active_c.iter().map(|&j| b[j]).sum();
    if a_mass <= 0.0 || b_mass <= 0.0 {
        return Err(Error::Numerical("screening removed all mass".into()));
    }
    let a_r: Vec<f64> = active_r.iter().map(|&i| a[i] / a_mass).collect();
    let b_r: Vec<f64> = active_c.iter().map(|&j| b[j] / b_mass).collect();
    let k_r = Mat::from_fn(active_r.len(), active_c.len(), |p, q| {
        kernel.get(active_r[p], active_c[q])
    });
    let (u_r, v_r, iterations, displacement, converged) =
        sinkhorn_scalings(&k_r, &a_r, &b_r, 1.0, &params.sinkhorn)?;

    // Screening floor: inactive scalings sit at the smallest active
    // scaling (they transport negligible mass by construction).
    let floor_u = u_r.iter().cloned().fold(f64::INFINITY, f64::min).min(1.0) * 1e-6;
    let floor_v = v_r.iter().cloned().fold(f64::INFINITY, f64::min).min(1.0) * 1e-6;
    let mut u = vec![floor_u; n];
    let mut v = vec![floor_v; m];
    for (p, &i) in active_r.iter().enumerate() {
        u[i] = u_r[p] * a_mass.sqrt();
    }
    for (q, &j) in active_c.iter().enumerate() {
        v[j] = v_r[q] * b_mass.sqrt();
    }
    let objective = ot_objective_dense(kernel, cost, &u, &v, eps);
    if !objective.is_finite() {
        return Err(Error::Numerical(format!(
            "Screenkhorn objective is not finite (eps = {eps} too small — the paper \
             observes the same failure for eps = 1e-3)"
        )));
    }
    Ok(SinkhornSolution { u, v, objective, iterations, displacement, converged })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::cost::{gibbs_kernel, sq_euclidean_cost};
    use crate::ot::sinkhorn::sinkhorn_ot;
    use crate::rng::Rng;

    fn problem(n: usize, seed: u64) -> (Mat, Mat, Vec<f64>, Vec<f64>) {
        let mut rng = Rng::seed_from(seed);
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..2).map(|_| rng.uniform()).collect())
            .collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        let kernel = gibbs_kernel(&cost, 0.1);
        // Concentrated marginals: most mass on few points, the
        // screening-friendly regime.
        let a: Vec<f64> = (0..n).map(|i| if i < n / 3 { 1.0 } else { 0.01 }).collect();
        let sa: f64 = a.iter().sum();
        let b: Vec<f64> = (0..n).map(|i| if i >= 2 * n / 3 { 1.0 } else { 0.01 }).collect();
        let sb: f64 = b.iter().sum();
        (
            kernel,
            cost,
            a.iter().map(|x| x / sa).collect(),
            b.iter().map(|x| x / sb).collect(),
        )
    }

    #[test]
    fn reasonable_approximation_on_concentrated_mass() {
        let (kernel, cost, a, b) = problem(60, 61);
        let eps = 0.1;
        let exact = sinkhorn_ot(&kernel, &cost, &a, &b, eps, &SinkhornParams::default()).unwrap();
        let screen =
            screenkhorn_ot(&kernel, &cost, &a, &b, eps, &ScreenkhornParams::default()).unwrap();
        let rel = (screen.objective - exact.objective).abs() / exact.objective.abs();
        assert!(rel < 0.5, "relative gap {rel}");
    }

    #[test]
    fn smaller_decimation_is_more_accurate() {
        let (kernel, cost, a, b) = problem(60, 67);
        let eps = 0.1;
        let exact = sinkhorn_ot(&kernel, &cost, &a, &b, eps, &SinkhornParams::default()).unwrap();
        let err_for = |dec: usize| {
            let p = ScreenkhornParams { decimation: dec, ..Default::default() };
            let s = screenkhorn_ot(&kernel, &cost, &a, &b, eps, &p).unwrap();
            (s.objective - exact.objective).abs()
        };
        // decimation 1 = no screening = near-exact.
        assert!(err_for(1) <= err_for(6) + 1e-9);
    }

    #[test]
    fn decimation_one_matches_sinkhorn() {
        let (kernel, cost, a, b) = problem(24, 71);
        let eps = 0.1;
        let exact = sinkhorn_ot(&kernel, &cost, &a, &b, eps, &SinkhornParams::default()).unwrap();
        let p = ScreenkhornParams { decimation: 1, ..Default::default() };
        let s = screenkhorn_ot(&kernel, &cost, &a, &b, eps, &p).unwrap();
        let rel = (s.objective - exact.objective).abs() / exact.objective.abs();
        assert!(rel < 1e-3, "relative gap {rel}");
    }

    #[test]
    fn rejects_zero_decimation() {
        let (kernel, cost, a, b) = problem(8, 73);
        let p = ScreenkhornParams { decimation: 0, ..Default::default() };
        assert!(screenkhorn_ot(&kernel, &cost, &a, &b, 0.1, &p).is_err());
    }
}
