//! Echocardiogram cardiac-cycle analysis — the paper's Section 6
//! pipeline end to end, driven through the batched coordinator:
//!
//! 1. generate synthetic echo videos (healthy / failing / arrhythmic),
//! 2. compute each video's pairwise WFR distance matrix with Spar-Sink
//!    jobs batched by the [`DistanceService`],
//! 3. embed with classical MDS and report the cycle geometry,
//! 4. predict the ED frame from the ES frame and report the error.
//!
//! ```sh
//! cargo run --release --example echo_analysis
//! ```

use spar_sink::coordinator::{CoordinatorConfig, DistanceService, Measure, ProblemSpec};
use spar_sink::data::echo::{downsample_frames, frame_to_measure, generate, EchoConfig, Health};
use spar_sink::experiments::fig7::video_distance_matrix;
use spar_sink::linalg::classical_mds;
use spar_sink::metrics::ed_prediction_error;
use spar_sink::rng::Rng;

fn main() {
    let size = 48;
    let service = DistanceService::start(CoordinatorConfig::default());
    let mut rng = Rng::seed_from(2026);

    for health in [Health::Normal, Health::HeartFailure, Health::Arrhythmia] {
        let video = generate(
            &EchoConfig { size, frames: 48, period: 12.0, health, noise: 0.01 },
            &mut rng,
        );
        let keep = downsample_frames(&video, 3);
        let frames: Vec<Measure> = keep
            .iter()
            .map(|&i| {
                let (pts, mass) = frame_to_measure(&video.frames[i], size, 0.05);
                Measure::new(pts, mass)
            })
            .collect();
        let spec = ProblemSpec { eta: size as f64 / 7.5, eps: 0.05, ..Default::default() };
        let dist = video_distance_matrix(&frames, &spec, &service, 99).expect("distances");

        // Cycle geometry via MDS.
        let mut mds_rng = Rng::seed_from(5);
        let emb = classical_mds(&dist, 2, &mut mds_rng);
        let (cx, cy) = (
            emb.iter().map(|p| p[0]).sum::<f64>() / emb.len() as f64,
            emb.iter().map(|p| p[1]).sum::<f64>() / emb.len() as f64,
        );
        let mean_r = emb
            .iter()
            .map(|p| ((p[0] - cx).powi(2) + (p[1] - cy).powi(2)).sqrt())
            .sum::<f64>()
            / emb.len() as f64;

        // ED prediction from the first (ES, ED) ground-truth pair, using
        // the debiased distance matrix restricted to kept frames.
        let mut pred_line = String::from("no full cycle in sampled frames");
        if let (Some(&t_es), Some(&t_ed)) = (
            video.es_frames.first(),
            video.ed_frames.iter().find(|&&d| d > video.es_frames[0]),
        ) {
            // Nearest kept indices.
            let k_of = |t: usize| keep.iter().position(|&k| k >= t).unwrap_or(keep.len() - 1);
            let (k_es, k_ed) = (k_of(t_es), k_of(t_ed));
            if k_ed > k_es {
                let best = (k_es + 1..(k_es + 2 * (k_ed - k_es) + 1).min(keep.len()))
                    .max_by(|&a, &b| dist.get(k_es, a).partial_cmp(&dist.get(k_es, b)).unwrap());
                if let Some(k_hat) = best {
                    let err = ed_prediction_error(
                        keep[k_es] as f64,
                        keep[k_ed] as f64,
                        keep[k_hat] as f64,
                    );
                    pred_line = format!(
                        "ES frame {} -> predicted ED {} (truth {}), error {:.2}",
                        keep[k_es], keep[k_hat], keep[k_ed], err
                    );
                }
            }
        }
        println!(
            "[{:<13}] frames {}  max WFR {:.4}  MDS loop radius {:.4}\n                {}",
            health.name(),
            frames.len(),
            dist.max(),
            mean_r,
            pred_line
        );
    }
    println!("\ncoordinator metrics:\n{}", service.shutdown().render());
}
