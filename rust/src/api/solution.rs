//! The unified solver result: one shape for every registered method.

use std::time::Duration;

use crate::ot::barycenter::BarycenterSolution;
use crate::ot::SinkhornSolution;
use crate::solvers::backend::BackendKind;
use crate::solvers::spar_sink::SparSolution;
use crate::sparse::SparsifyStats;

/// What a [`crate::api::solve`] call produced, independent of which
/// solver ran: the objective, the dual scalings (or barycenter), the
/// sparsification diagnostics when a sketch was built, the scaling
/// engine that actually ran, and the wall time.
#[derive(Clone, Debug)]
pub struct Solution {
    /// Registry name of the solver that produced this solution.
    pub method: &'static str,
    /// Entropic objective (Eq. 6 / Eq. 10). `NaN` for barycenter solves,
    /// which report the histogram in [`Solution::barycenter`] instead.
    pub objective: f64,
    /// Row scalings `u` (empty for barycenter solves).
    pub u: Vec<f64>,
    /// Column scalings `v` (empty for barycenter solves).
    pub v: Vec<f64>,
    /// The barycenter histogram `q` (barycenter solves only).
    pub barycenter: Option<Vec<f64>>,
    /// Scaling iterations performed.
    pub iterations: usize,
    /// Final L1 displacement (the stopping statistic).
    pub displacement: f64,
    /// Whether the tolerance was met before the iteration cap.
    pub converged: bool,
    /// Sparsification diagnostics: empty for dense/low-rank solvers, one
    /// entry for the sketch-based solvers, one per input kernel for
    /// Spar-IBP.
    pub stats: Vec<SparsifyStats>,
    /// Which scaling engine actually produced the solution (`None` for
    /// solvers outside the multiplicative/log-domain switch).
    pub backend: Option<BackendKind>,
    /// End-to-end solve wall time (filled by [`crate::api::solve`]).
    pub wall_time: Duration,
}

impl Solution {
    pub(crate) fn from_sinkhorn(
        method: &'static str,
        sol: SinkhornSolution,
        backend: Option<BackendKind>,
    ) -> Self {
        Solution {
            method,
            objective: sol.objective,
            u: sol.u,
            v: sol.v,
            barycenter: None,
            iterations: sol.iterations,
            displacement: sol.displacement,
            converged: sol.converged,
            stats: Vec::new(),
            backend,
            wall_time: Duration::ZERO,
        }
    }

    pub(crate) fn from_spar(method: &'static str, sol: SparSolution) -> Self {
        let backend = sol.backend;
        let mut out = Solution::from_sinkhorn(method, sol.solution, Some(backend));
        out.stats = vec![sol.stats];
        out
    }

    pub(crate) fn from_barycenter(
        method: &'static str,
        sol: BarycenterSolution,
        stats: Vec<SparsifyStats>,
        backend: Option<BackendKind>,
    ) -> Self {
        Solution {
            method,
            objective: f64::NAN,
            u: Vec::new(),
            v: Vec::new(),
            barycenter: Some(sol.q),
            iterations: sol.iterations,
            displacement: sol.displacement,
            converged: sol.converged,
            stats,
            backend,
            wall_time: Duration::ZERO,
        }
    }

    /// The dual scalings `(u, v)` of the transport plan
    /// `T = diag(u) K diag(v)`.
    pub fn scalings(&self) -> (&[f64], &[f64]) {
        (&self.u, &self.v)
    }

    /// Total stored non-zeros across every sketch this solve built
    /// (`None` for dense/low-rank solvers).
    pub fn nnz(&self) -> Option<usize> {
        if self.stats.is_empty() {
            None
        } else {
            Some(self.stats.iter().map(|s| s.nnz).sum())
        }
    }

    /// First sketch's sparsification diagnostics, if any.
    pub fn sparsify_stats(&self) -> Option<&SparsifyStats> {
        self.stats.first()
    }
}
