//! Ground costs and Gibbs kernels.
//!
//! * Squared Euclidean cost (the paper's OT experiments, Section 5.1).
//! * Wasserstein–Fisher–Rao cost `C_ij = -log cos²₊(d_ij / 2η)` whose
//!   kernel is sparse and near-full-rank (Section 2.2) — the regime where
//!   Nyström-based acceleration breaks down and Spar-Sink shines.

use crate::linalg::Mat;
use crate::pool;

/// Euclidean distance between two points.
#[inline]
pub fn euclidean(x: &[f64], y: &[f64]) -> f64 {
    sq_euclidean(x, y).sqrt()
}

/// Squared Euclidean distance.
#[inline]
pub fn sq_euclidean(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum()
}

/// Tile height (rows) of the blocked dense builders. One tile of
/// `TILE_ROWS × TILE_COLS` f64 values (32 KiB) fits comfortably in L1
/// alongside the source points it reads.
pub const TILE_ROWS: usize = 32;

/// Tile width (columns) of the blocked dense builders: the column strip
/// re-traversed for each row of a tile, sized so the strip of `ys`
/// points stays cache-resident across the tile's rows.
pub const TILE_COLS: usize = 128;

/// Run `f(i, j0, seg)` over fixed-size cache tiles of an `n × m`
/// row-major buffer: `seg` is the slice of row `i` covering columns
/// `j0 .. j0 + seg.len()`.
///
/// The tile grid is fixed by [`TILE_ROWS`]/[`TILE_COLS`] and the block
/// order is independent of thread count (workers split whole row-bands
/// via [`pool::parallel_fill_row_tiles`]); every entry is written
/// exactly once by a pure function of its (i, j), so tiling cannot
/// change a single bit relative to the naive row sweep — pinned by
/// `parallel_builders_match_from_fn`, the tiled-builder property test,
/// and the `thread_determinism` wall.
fn fill_tiled<F>(data: &mut [f64], m: usize, f: F)
where
    F: Fn(usize, usize, &mut [f64]) + Sync,
{
    pool::parallel_fill_row_tiles(data, m, TILE_ROWS, |r0, r1, slab| {
        let mut j0 = 0;
        while j0 < m {
            let j1 = (j0 + TILE_COLS).min(m);
            for i in r0..r1 {
                let base = (i - r0) * m;
                f(i, j0, &mut slab[base + j0..base + j1]);
            }
            j0 = j1;
        }
    });
}

/// Pairwise squared-Euclidean cost matrix `C_ij = ||x_i - y_j||²`.
///
/// Blocked into [`TILE_ROWS`]`×`[`TILE_COLS`] cache tiles via
/// [`fill_tiled`]: the `ys` strip of a tile stays hot across its rows.
/// Every entry is an independent function of (i, j) and the tile grid
/// is thread-count independent, so the result is bit-identical for any
/// thread count and to the untiled row sweep.
pub fn sq_euclidean_cost(xs: &[Vec<f64>], ys: &[Vec<f64>]) -> Mat {
    let (n, m) = (xs.len(), ys.len());
    let mut data = vec![0.0; n * m];
    fill_tiled(&mut data, m, |i, j0, seg| {
        let x = &xs[i];
        for (out, y) in seg.iter_mut().zip(&ys[j0..]) {
            *out = sq_euclidean(x, y);
        }
    });
    Mat::from_vec(n, m, data)
}

/// WFR ground cost for a single distance:
/// `-log cos²₊(d / 2η)` with `cos₊(z) = cos(min(z, π/2))`.
/// Returns `f64::INFINITY` when `d ≥ π η` (transport blocked).
#[inline]
pub fn wfr_cost_from_distance(d: f64, eta: f64) -> f64 {
    let z = d / (2.0 * eta);
    if z >= std::f64::consts::FRAC_PI_2 {
        return f64::INFINITY;
    }
    let c = z.cos();
    -(c * c).ln()
}

/// WFR kernel entry `K_ij = exp(-C_ij / ε) = cos₊(d/2η)^(2/ε)`.
/// Exactly zero when `d ≥ π η`.
#[inline]
pub fn wfr_kernel_from_distance(d: f64, eta: f64, eps: f64) -> f64 {
    let z = d / (2.0 * eta);
    if z >= std::f64::consts::FRAC_PI_2 {
        return 0.0;
    }
    let c = z.cos();
    (c * c).powf(1.0 / eps)
}

/// Pairwise WFR cost matrix from supports (Euclidean ground distance).
/// Cache-tiled like [`sq_euclidean_cost`], bit-deterministic for any
/// thread count and bitwise-equal to the untiled row sweep.
pub fn wfr_cost(xs: &[Vec<f64>], ys: &[Vec<f64>], eta: f64) -> Mat {
    let (n, m) = (xs.len(), ys.len());
    let mut data = vec![0.0; n * m];
    fill_tiled(&mut data, m, |i, j0, seg| {
        let x = &xs[i];
        for (out, y) in seg.iter_mut().zip(&ys[j0..]) {
            *out = wfr_cost_from_distance(euclidean(x, y), eta);
        }
    });
    Mat::from_vec(n, m, data)
}

/// Gibbs kernel `K = exp(-C / ε)`, mapping `C = ∞` to exactly 0.
/// Cache-tiled like [`sq_euclidean_cost`], bit-deterministic for any
/// thread count and bitwise-equal to the untiled row sweep.
pub fn gibbs_kernel(cost: &Mat, eps: f64) -> Mat {
    let (n, m) = (cost.rows(), cost.cols());
    let mut data = vec![0.0; n * m];
    fill_tiled(&mut data, m, |i, j0, seg| {
        for (out, &c) in seg.iter_mut().zip(&cost.row(i)[j0..]) {
            *out = if c.is_infinite() { 0.0 } else { (-c / eps).exp() };
        }
    });
    Mat::from_vec(n, m, data)
}

/// Normalize a cost matrix to max 1 — the standard preprocessing that
/// keeps `exp(-C/eps)` representable down to eps = 1e-3 (C_ij <= c0 is
/// the paper's boundedness assumption; this fixes c0 = 1). Infinite
/// (blocked) entries are ignored by the max and preserved by the scale.
///
/// THE shared helper: `experiments::common` re-exports it, and every
/// call site (experiments, examples, backend tests) resolves here.
pub fn normalize_cost(cost: &Mat) -> Mat {
    let max = cost
        .as_slice()
        .iter()
        .cloned()
        .filter(|c| c.is_finite())
        .fold(0.0f64, f64::max);
    if max <= 0.0 {
        return cost.clone();
    }
    cost.map(move |c| c / max)
}

/// Log-Gibbs kernel entry `ln K = −C/ε`, mapping `C = ∞` (blocked
/// transport) to −∞. The single blocked-entry convention shared by every
/// log-kernel oracle — the Spar-Sink `_logk` entry points and the
/// coordinator build their sketches through this.
#[inline]
pub fn log_gibbs_from_cost(c: f64, eps: f64) -> f64 {
    if c.is_infinite() {
        f64::NEG_INFINITY
    } else {
        -c / eps
    }
}

/// Fraction of non-zero entries in a kernel (used to calibrate η for the
/// paper's R1/R2/R3 sparsity regimes: ~70%, ~50%, ~30% nnz).
pub fn kernel_density(kernel: &Mat) -> f64 {
    let nnz = kernel.as_slice().iter().filter(|&&k| k > 0.0).count();
    nnz as f64 / (kernel.rows() * kernel.cols()) as f64
}

/// Binary-search η so that the WFR kernel has approximately the target
/// density (fraction of entries with `d_ij < π η`).
pub fn calibrate_eta(
    xs: &[Vec<f64>],
    ys: &[Vec<f64>],
    target_density: f64,
    tol: f64,
) -> f64 {
    // Collect all pairwise distances once (O(n²)); pick the quantile.
    let mut ds: Vec<f64> = Vec::with_capacity(xs.len() * ys.len());
    for x in xs {
        for y in ys {
            ds.push(euclidean(x, y));
        }
    }
    ds.sort_by(f64::total_cmp);
    let q = ((target_density * ds.len() as f64) as usize).min(ds.len() - 1);
    let _ = tol;
    // d < π η  ⇔  η > d/π: choose η at the target quantile distance.
    ds[q] / std::f64::consts::PI
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sq_euclidean_basic() {
        assert_eq!(sq_euclidean(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
        assert_eq!(euclidean(&[0.0, 0.0], &[3.0, 4.0]), 5.0);
    }

    #[test]
    fn cost_matrix_symmetric_on_shared_support() {
        let pts = vec![vec![0.0], vec![1.0], vec![2.5]];
        let c = sq_euclidean_cost(&pts, &pts);
        for i in 0..3 {
            assert_eq!(c.get(i, i), 0.0);
            for j in 0..3 {
                assert_eq!(c.get(i, j), c.get(j, i));
            }
        }
    }

    #[test]
    fn wfr_cost_blocks_long_range() {
        let eta = 2.0;
        // d >= pi*eta -> infinite cost, zero kernel.
        let d_blocked = std::f64::consts::PI * eta;
        assert!(wfr_cost_from_distance(d_blocked, eta).is_infinite());
        assert_eq!(wfr_kernel_from_distance(d_blocked, eta, 0.1), 0.0);
        // d = 0 -> zero cost, kernel 1.
        assert_eq!(wfr_cost_from_distance(0.0, eta), 0.0);
        assert!((wfr_kernel_from_distance(0.0, eta, 0.1) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn wfr_kernel_consistent_with_cost() {
        let (eta, eps) = (1.5, 0.3);
        for &d in &[0.1, 0.5, 1.0, 2.0, 4.0] {
            let c = wfr_cost_from_distance(d, eta);
            let k = wfr_kernel_from_distance(d, eta, eps);
            if c.is_infinite() {
                assert_eq!(k, 0.0);
            } else {
                assert!((k - (-c / eps).exp()).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn smaller_eta_sparser_kernel() {
        let pts: Vec<Vec<f64>> = (0..40).map(|i| vec![i as f64 * 0.1]).collect();
        let dense = gibbs_kernel(&wfr_cost(&pts, &pts, 2.0), 0.1);
        let sparse = gibbs_kernel(&wfr_cost(&pts, &pts, 0.2), 0.1);
        assert!(kernel_density(&sparse) < kernel_density(&dense));
    }

    #[test]
    fn calibrate_eta_hits_target_density() {
        let pts: Vec<Vec<f64>> = (0..60)
            .map(|i| vec![(i as f64 * 0.618).fract(), (i as f64 * 0.383).fract()])
            .collect();
        for &target in &[0.7, 0.5, 0.3] {
            let eta = calibrate_eta(&pts, &pts, target, 1e-3);
            let k = gibbs_kernel(&wfr_cost(&pts, &pts, eta), 0.1);
            let density = kernel_density(&k);
            assert!(
                (density - target).abs() < 0.05,
                "target {target}, got {density}"
            );
        }
    }

    #[test]
    fn normalize_cost_caps_at_one() {
        let c = Mat::from_fn(3, 3, |i, j| (i * 3 + j) as f64);
        let n = normalize_cost(&c);
        assert!((n.max() - 1.0).abs() < 1e-12);
        // Blocked entries survive normalization; an all-blocked/zero
        // matrix is returned unchanged.
        let mut blocked = Mat::zeros(2, 2);
        blocked.set(0, 1, f64::INFINITY);
        blocked.set(1, 0, 2.0);
        let nb = normalize_cost(&blocked);
        assert!(nb.get(0, 1).is_infinite());
        assert_eq!(nb.get(1, 0), 1.0);
        let zeros = Mat::zeros(2, 2);
        assert_eq!(normalize_cost(&zeros), zeros);
    }

    #[test]
    fn parallel_builders_match_from_fn() {
        let pts: Vec<Vec<f64>> = (0..23)
            .map(|i| vec![(i as f64 * 0.618).fract(), (i as f64 * 0.383).fract()])
            .collect();
        let tgt: Vec<Vec<f64>> = (0..17).map(|i| vec![i as f64 * 0.1, 0.5]).collect();
        let c = sq_euclidean_cost(&pts, &tgt);
        let c_ref = Mat::from_fn(23, 17, |i, j| sq_euclidean(&pts[i], &tgt[j]));
        assert_eq!(c.as_slice(), c_ref.as_slice());
        let w = wfr_cost(&pts, &tgt, 0.4);
        let w_ref = Mat::from_fn(23, 17, |i, j| {
            wfr_cost_from_distance(euclidean(&pts[i], &tgt[j]), 0.4)
        });
        for (a, b) in w.as_slice().iter().zip(w_ref.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let g = gibbs_kernel(&w, 0.2);
        let g_ref = w_ref.map(|c| if c.is_infinite() { 0.0 } else { (-c / 0.2).exp() });
        for (a, b) in g.as_slice().iter().zip(g_ref.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Empty shapes are fine.
        assert_eq!(sq_euclidean_cost(&pts, &[]).cols(), 0);
        assert_eq!(sq_euclidean_cost(&[], &tgt).rows(), 0);
    }

    #[test]
    fn tiled_builders_match_reference_at_tile_boundaries() {
        for &n in &[TILE_ROWS - 1, TILE_ROWS, TILE_ROWS + 1] {
            for &m in &[TILE_COLS - 1, TILE_COLS, TILE_COLS + 1] {
                let xs: Vec<Vec<f64>> =
                    (0..n).map(|i| vec![(i as f64 * 0.618).fract()]).collect();
                let ys: Vec<Vec<f64>> =
                    (0..m).map(|j| vec![(j as f64 * 0.383).fract()]).collect();
                let c = sq_euclidean_cost(&xs, &ys);
                let c_ref = Mat::from_fn(n, m, |i, j| sq_euclidean(&xs[i], &ys[j]));
                assert_eq!(c.as_slice(), c_ref.as_slice(), "{n}x{m}");
            }
        }
    }

    #[test]
    fn gibbs_kernel_handles_infinite_cost() {
        let mut c = Mat::zeros(2, 2);
        c.set(0, 1, f64::INFINITY);
        let k = gibbs_kernel(&c, 0.5);
        assert_eq!(k.get(0, 1), 0.0);
        assert_eq!(k.get(0, 0), 1.0);
    }
}
