//! Entropic optimal-transport core: cost/kernel construction, exact
//! Sinkhorn solvers for OT (Alg. 1) and UOT (Alg. 2), objectives
//! (Eqs. 6 and 10), and the IBP barycenter solver (Alg. 5).
//!
//! Every formulation has a log-domain stabilized twin for the small-ε
//! regime where `exp(−C/ε)` underflows: [`log_sinkhorn`] covers balanced
//! and unbalanced OT, [`log_barycenter`] covers IBP barycenters — both
//! reached through the [`ScalingBackend`](crate::solvers::backend)
//! switch rather than called directly in most code.

pub mod barycenter;
pub mod cost;
pub mod log_barycenter;
pub mod log_sinkhorn;
pub mod objective;
pub mod sinkhorn;
pub mod uot;

/// Result of a Sinkhorn-type solve.
#[derive(Clone, Debug)]
pub struct SinkhornSolution {
    /// Row scaling u.
    pub u: Vec<f64>,
    /// Column scaling v.
    pub v: Vec<f64>,
    /// Objective value (entropic OT Eq. 6 or entropic UOT Eq. 10).
    pub objective: f64,
    /// Number of scaling iterations performed.
    pub iterations: usize,
    /// Final L1 displacement (the stopping statistic).
    pub displacement: f64,
    /// Whether the tolerance was met before the iteration cap.
    pub converged: bool,
}
