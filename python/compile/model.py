"""L2 — JAX compute graph for the Sinkhorn / unbalanced-Sinkhorn blocks.

This module defines the computations that `aot.py` lowers ONCE to HLO text
(the build-time half of the three-layer stack).  The Rust runtime
(`rust/src/runtime/`) loads the artifacts and drives the outer convergence
loop; Python never runs on the request path.

Entry points (all shapes static at lowering time, see `aot.py`):

* ``sinkhorn_block``   — ``T`` fused scaling iterations of Algorithms 1/2.
  ``rho`` is a *runtime* scalar: ``rho = 1`` gives balanced OT (Alg. 1) and
  ``rho = lam / (lam + eps)`` gives unbalanced OT (Alg. 2), so a single
  artifact serves both problems and any (lam, eps) pair.
* ``ot_objective``     — entropic OT objective  <T,C> - eps H(T).
* ``uot_objective``    — entropic UOT objective (Eq. 10).
* ``kernel_from_cost`` — K = exp(-C / eps).

The matvec+scale hot-spot inside ``sinkhorn_block`` is the L1 Pallas kernel
(`kernels.sinkhorn_pallas`), so it lowers into the same HLO module.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import sinkhorn_pallas as kern

# Iterations fused per HLO call.  The Rust driver checks the returned L1
# displacement after each block and stops when it drops below delta, so the
# effective iteration count is a multiple of BLOCK_ITERS (matching how the
# paper's implementations check convergence every few sweeps).
BLOCK_ITERS = 10


import os

# Tile size for the Pallas kernels inside the lowered block.  128 matches
# the MXU lane width on real TPU; under interpret=True on CPU, larger
# tiles amortize the interpreter's per-grid-step overhead (see
# EXPERIMENTS.md §Perf for the sweep).  Overridable at `make artifacts`
# time via SPAR_SINK_PALLAS_BLOCK.
PALLAS_BLOCK = int(os.environ.get("SPAR_SINK_PALLAS_BLOCK", "512"))


def _scaling_step(kmat, a, b, u, v, rho, *, block=None):
    """One Sinkhorn scaling sweep using the Pallas matvec+scale kernels."""
    block = block or PALLAS_BLOCK
    bn = min(block, kmat.shape[0])
    bm = min(block, kmat.shape[1])
    u_new = kern.kv_scale(kmat, v, a, block_rows=bn, block_cols=bm) ** rho
    v_new = kern.ktu_scale(kmat, u_new, b, block_rows=bn, block_cols=bm) ** rho
    return u_new, v_new


def sinkhorn_block(kmat, a, b, u, v, rho, *, n_iters: int = BLOCK_ITERS):
    """Run ``n_iters`` scaling iterations; return (u', v', l1_displacement).

    All vectors are (n, 1) columns.  The displacement is
    ``||u' - u_prev||_1 + ||v' - v_prev||_1`` of the LAST iteration — the
    stopping statistic of Algorithms 1-2.
    """

    def body(carry, _):
        u_c, v_c = carry
        u_n, v_n = _scaling_step(kmat, a, b, u_c, v_c, rho)
        err = jnp.sum(jnp.abs(u_n - u_c)) + jnp.sum(jnp.abs(v_n - v_c))
        return (u_n, v_n), err

    (u_f, v_f), errs = jax.lax.scan(body, (u, v), None, length=n_iters)
    return u_f, v_f, errs[-1]


def plan(kmat, u, v):
    """Transport plan ``T = diag(u) K diag(v)`` for (n,1) scalings."""
    return u * kmat * v.reshape(1, -1)


def _entropy(t):
    # H(T) = -sum T (log T - 1), with 0 log 0 = 0.
    return -jnp.sum(t * (jnp.log(jnp.where(t > 0, t, 1.0)) - 1.0))


def ot_objective(kmat, cost, u, v, eps):
    """Entropic OT objective (Eq. 6): <T,C> - eps H(T)."""
    t = plan(kmat, u, v)
    return jnp.sum(t * cost) - eps * _entropy(t)


def _kl(x, y):
    ratio = jnp.where(x > 0, x / y, 1.0)
    return jnp.sum(jnp.where(x > 0, x * jnp.log(ratio), 0.0) - x + y)


def uot_objective(kmat, cost, a, b, u, v, lam, eps):
    """Entropic UOT objective (Eq. 10)."""
    t = plan(kmat, u, v)
    row = jnp.sum(t, axis=1, keepdims=True)
    col = jnp.sum(t, axis=0, keepdims=True).T
    return (
        jnp.sum(t * cost)
        + lam * _kl(row, a)
        + lam * _kl(col, b)
        - eps * _entropy(t)
    )


def kernel_from_cost(cost, eps):
    """Gibbs kernel K = exp(-C / eps)."""
    return jnp.exp(-cost / eps)


# ---------------------------------------------------------------------------
# Lowering-ready wrappers (tuple outputs, fixed signature order).
# ---------------------------------------------------------------------------


def sinkhorn_block_entry(kmat, a, b, u, v, rho):
    """AOT entry: returns a 3-tuple (u', v', err)."""
    u_f, v_f, err = sinkhorn_block(kmat, a, b, u, v, rho)
    return (u_f, v_f, err)


def ot_objective_entry(kmat, cost, u, v, eps):
    return (ot_objective(kmat, cost, u, v, eps),)


def uot_objective_entry(kmat, cost, a, b, u, v, lam, eps):
    return (uot_objective(kmat, cost, a, b, u, v, lam, eps),)


def kernel_from_cost_entry(cost, eps):
    return (kernel_from_cost(cost, eps),)


def specs_for(n: int, dtype=jnp.float32):
    """ShapeDtypeStructs for each entry point at problem size ``n``."""
    mat = jax.ShapeDtypeStruct((n, n), dtype)
    col = jax.ShapeDtypeStruct((n, 1), dtype)
    scal = jax.ShapeDtypeStruct((), dtype)
    return {
        "sinkhorn_block": (mat, col, col, col, col, scal),
        "ot_objective": (mat, mat, col, col, scal),
        "uot_objective": (mat, mat, col, col, col, col, scal, scal),
        "kernel_from_cost": (mat, scal),
    }


ENTRIES = {
    "sinkhorn_block": sinkhorn_block_entry,
    "ot_objective": ot_objective_entry,
    "uot_objective": uot_objective_entry,
    "kernel_from_cost": kernel_from_cost_entry,
}
