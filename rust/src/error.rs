//! Crate-wide error type.

use thiserror::Error;

/// Errors surfaced by solvers, the runtime, and the coordinator.
#[derive(Error, Debug)]
pub enum Error {
    /// Input shapes/sizes are inconsistent.
    #[error("dimension mismatch: {0}")]
    Dimension(String),

    /// A solver failed to make progress (NaN/Inf scalings, empty kernel…).
    #[error("numerical failure: {0}")]
    Numerical(String),

    /// An iteration limit was reached before the tolerance was met.
    /// Carries the last objective estimate so callers can still use it.
    #[error("did not converge within {iters} iterations (last displacement {err:.3e})")]
    NotConverged { iters: usize, err: f64 },

    /// Invalid parameter value.
    #[error("invalid parameter: {0}")]
    InvalidParam(String),

    /// PJRT runtime failure (artifact missing, compile error, …).
    #[error("runtime: {0}")]
    Runtime(String),

    /// Coordinator failure (queue closed, worker panicked, …).
    #[error("coordinator: {0}")]
    Coordinator(String),

    /// I/O error.
    #[error(transparent)]
    Io(#[from] std::io::Error),

    /// Error bubbled up from the `xla` crate (only with the `xla`
    /// feature, which gates the PJRT runtime).
    #[cfg(feature = "xla")]
    #[error("xla: {0}")]
    Xla(String),
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
