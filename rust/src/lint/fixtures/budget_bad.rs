//! Seeded violation (budget-convention): a hand-rolled sampling budget
//! that multiplies `s_multiplier` by `s0(..)` directly instead of going
//! through `solvers::sketch_budget`. Never compiled — pinned by the
//! lint unit tests under a virtual `solvers/` path.

/// Computes a sketch budget without the one convention entry point.
pub fn raw_budget(s_multiplier: f64, n: usize) -> usize {
    (s_multiplier * s0(n)) as usize
}
