//! Rand-Sink — the naive uniform element-wise subsampling baseline
//! (Section 5): identical to Spar-Sink except every entry has the same
//! probability `p_ij = 1/n²`. Implemented as the θ = 0 shrinkage limit
//! of the Poisson sparsifier so the code path is shared.

use super::backend::BackendKind;
use super::spar_sink::SparSolution;
use super::sparse_loop;
use crate::error::Result;
use crate::linalg::Mat;
use crate::ot::sinkhorn::SinkhornParams;
use crate::ot::uot::uot_rho;
use crate::rng::Rng;
use crate::sparse::poisson_sparsify_with;

fn oracle_kernel(cost: &Mat, eps: f64) -> impl Fn(usize, usize) -> f64 + Sync + '_ {
    move |i, j| {
        let c = cost.get(i, j);
        if c.is_infinite() {
            0.0
        } else {
            (-c / eps).exp()
        }
    }
}

/// Rand-Sink for OT: uniform Poisson sampling + sparse Sinkhorn.
pub fn rand_sink_ot(
    cost: &Mat,
    a: &[f64],
    b: &[f64],
    eps: f64,
    s_multiplier: f64,
    params: &SinkhornParams,
    rng: &mut Rng,
) -> Result<SparSolution> {
    let n = a.len();
    let m = b.len();
    let s = s_multiplier * crate::metrics::s0(n);
    let n2 = (n * m) as f64;
    let (sketch, stats) = poisson_sparsify_with(
        n,
        m,
        oracle_kernel(cost, eps),
        |i, j| cost.get(i, j),
        |_, _| 1.0,
        n2,
        s,
        1.0,
        rng,
    )?;
    let (u, v, iterations, displacement, converged) =
        sparse_loop::sparse_scalings(&sketch, a, b, 1.0, params)?;
    let objective = sparse_loop::sparse_ot_objective(&sketch, &u, &v, eps);
    let solution = sparse_loop::solution(u, v, objective, iterations, displacement, converged)?;
    Ok(SparSolution { solution, stats, backend: BackendKind::Multiplicative })
}

/// Rand-Sink for UOT.
#[allow(clippy::too_many_arguments)]
pub fn rand_sink_uot(
    cost: &Mat,
    a: &[f64],
    b: &[f64],
    lambda: f64,
    eps: f64,
    s_multiplier: f64,
    params: &SinkhornParams,
    rng: &mut Rng,
) -> Result<SparSolution> {
    let n = a.len();
    let m = b.len();
    let s = s_multiplier * crate::metrics::s0(n);
    let n2 = (n * m) as f64;
    let (sketch, stats) = poisson_sparsify_with(
        n,
        m,
        oracle_kernel(cost, eps),
        |i, j| cost.get(i, j),
        |_, _| 1.0,
        n2,
        s,
        1.0,
        rng,
    )?;
    let rho = uot_rho(lambda, eps);
    let (u, v, iterations, displacement, converged) =
        sparse_loop::sparse_scalings(&sketch, a, b, rho, params)?;
    let objective = sparse_loop::sparse_uot_objective(&sketch, a, b, &u, &v, lambda, eps);
    let solution = sparse_loop::solution(u, v, objective, iterations, displacement, converged)?;
    Ok(SparSolution { solution, stats, backend: BackendKind::Multiplicative })
}

/// Oracle variant of [`rand_sink_uot`] for problems whose kernel is
/// never materialized densely (echo pipeline).
#[allow(clippy::too_many_arguments)]
pub fn rand_sink_uot_oracle(
    kernel: impl Fn(usize, usize) -> f64 + Sync,
    cost: impl Fn(usize, usize) -> f64 + Sync,
    a: &[f64],
    b: &[f64],
    lambda: f64,
    eps: f64,
    s: f64,
    params: &SinkhornParams,
    rng: &mut Rng,
) -> Result<SparSolution> {
    let n = a.len();
    let m = b.len();
    let n2 = (n * m) as f64;
    let (sketch, stats) =
        poisson_sparsify_with(n, m, kernel, cost, |_, _| 1.0, n2, s, 1.0, rng)?;
    let rho = uot_rho(lambda, eps);
    let (u, v, iterations, displacement, converged) =
        sparse_loop::sparse_scalings(&sketch, a, b, rho, params)?;
    let objective = sparse_loop::sparse_uot_objective(&sketch, a, b, &u, &v, lambda, eps);
    let solution = sparse_loop::solution(u, v, objective, iterations, displacement, converged)?;
    Ok(SparSolution { solution, stats, backend: BackendKind::Multiplicative })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::cost::{gibbs_kernel, sq_euclidean_cost};
    use crate::ot::sinkhorn::sinkhorn_ot;
    use crate::solvers::spar_sink::spar_sink_ot;

    fn problem(n: usize, seed: u64) -> (Mat, Vec<f64>, Vec<f64>) {
        let mut rng = Rng::seed_from(seed);
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..3).map(|_| rng.uniform()).collect())
            .collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        // Strongly non-uniform marginals: the regime where importance
        // sampling beats uniform sampling.
        let a: Vec<f64> = (0..n).map(|i| ((i % 10) as f64 + 0.1).powi(3)).collect();
        let sa: f64 = a.iter().sum();
        let b: Vec<f64> = (0..n).map(|i| (((i + 5) % 10) as f64 + 0.1).powi(3)).collect();
        let sb: f64 = b.iter().sum();
        (cost, a.iter().map(|x| x / sa).collect(), b.iter().map(|x| x / sb).collect())
    }

    #[test]
    fn runs_and_is_in_the_ballpark() {
        let n = 200;
        let (cost, a, b) = problem(n, 21);
        let eps = 0.1;
        let kernel = gibbs_kernel(&cost, eps);
        let exact = sinkhorn_ot(&kernel, &cost, &a, &b, eps, &SinkhornParams::default()).unwrap();
        let mut rng = Rng::seed_from(2);
        let sol = rand_sink_ot(&cost, &a, &b, eps, 16.0, &SinkhornParams::default(), &mut rng)
            .unwrap();
        let rel = (sol.solution.objective - exact.objective).abs() / exact.objective.abs();
        assert!(rel < 1.0, "relative error {rel}");
    }

    #[test]
    fn spar_sink_beats_rand_sink_on_skewed_marginals() {
        // The paper's headline: importance sampling dominates uniform.
        let n = 256;
        let (cost, a, b) = problem(n, 23);
        let eps = 0.1;
        let kernel = gibbs_kernel(&cost, eps);
        let exact = sinkhorn_ot(&kernel, &cost, &a, &b, eps, &SinkhornParams::default()).unwrap();
        let reps = 10;
        let mut rng = Rng::seed_from(4);
        let mut rand_err = 0.0;
        let mut spar_err = 0.0;
        for _ in 0..reps {
            let r = rand_sink_ot(&cost, &a, &b, eps, 4.0, &SinkhornParams::default(), &mut rng)
                .unwrap();
            rand_err += (r.solution.objective - exact.objective).abs();
            let s = spar_sink_ot(
                &cost,
                &a,
                &b,
                eps,
                4.0,
                &crate::solvers::spar_sink::SparSinkParams::default(),
                &mut rng,
            )
            .unwrap();
            spar_err += (s.solution.objective - exact.objective).abs();
        }
        assert!(
            spar_err < rand_err,
            "spar {spar_err:.4} should beat rand {rand_err:.4}"
        );
    }
}
