//! Small utilities: a dependency-free JSON writer for experiment
//! output, a minimal JSON reader for the artifact manifest, and
//! poison-recovering lock helpers for the worker paths.

pub mod json;
pub mod sync;
pub mod table;
