//! Accelerated Sinkhorn variants: the paper's Spar-Sink / Spar-IBP and
//! every baseline in the evaluation section.
//!
//! All of these are registered behind the unified [`crate::api`]
//! surface — describe the problem as an
//! [`OtProblem`](crate::api::OtProblem), pick the method in a
//! [`SolverSpec`](crate::api::SolverSpec), and call
//! [`api::solve`](crate::api::solve). The per-module free functions
//! below remain as the thin paper-reproduction entry points the
//! registry adapters dispatch to.
//!
//! | Solver | Registry name | Paper | Per-iteration cost |
//! |---|---|---|---|
//! | [`spar_sink`] | `spar-sink` / `spar-sink-log` | Alg. 3-4 (this paper) | O(s), s = Õ(n) |
//! | [`rand_sink`] | `rand-sink` | uniform-sampling ablation | O(s) |
//! | [`nys_sink`] | `nys-sink` (± robust clip) | Altschuler et al. 2019 (+ Le et al. 2021) | O(nr) |
//! | [`greenkhorn`] | `greenkhorn` | Altschuler et al. 2017 | O(n) per greedy update |
//! | [`screenkhorn`] | `screenkhorn` | Alaya et al. 2019 | O((n/κ)²) |
//! | [`spar_ibp`] | `spar-ibp` | Alg. 6 (this paper) | O(ms) |
//!
//! The multiplicative loops and their log-domain stabilized twins sit
//! behind the [`backend::ScalingBackend`] switch, which auto-escalates
//! to the log engine for small ε or on numerical failure — and the
//! coverage is now complete across EVERY formulation: sparse OT/UOT
//! ([`sparse_loop`] / [`log_sparse`]), dense OT/UOT
//! ([`crate::ot::sinkhorn`]+[`crate::ot::uot`] /
//! [`crate::ot::log_sinkhorn`]), and IBP barycenters, dense and sketched
//! ([`crate::ot::barycenter`]+[`spar_ibp`] /
//! [`crate::ot::log_barycenter`]+[`log_spar_ibp`]). All engine pairs
//! share the `DEFAULT_LOG_EPS_THRESHOLD` ε switch (calibrated for costs
//! normalized to c₀ = 1) and formulation-aware collapse detection.
//! [`SolverSpec::backend`](crate::api::SolverSpec::backend) overrides
//! the policy per solve, and every backend-switched
//! [`Solution`](crate::api::Solution) reports the
//! [`BackendKind`](backend::BackendKind) that actually ran.

pub mod backend;
pub mod greenkhorn;
pub mod log_spar_ibp;
pub mod log_sparse;
pub mod nys_sink;
pub mod proximal;
pub mod rand_sink;
pub mod screenkhorn;
pub mod spar_ibp;
pub mod spar_sink;
pub mod sparse_loop;

/// THE sampling-budget convention, shared by every sketch-based solver
/// (spar-sink, rand-sink, nys-sink's matched-budget rank, spar-ibp) in
/// every cost arm (dense, oracle, shared-artifact):
///
/// ```text
/// s = s_multiplier · s₀(max(rows, cols)),   s₀(n) = 10⁻³ n ln⁴ n
/// ```
///
/// `s₀` is the paper's subsample-size unit (Section 5.1, in the light
/// of Theorem 1); resolving it against the LARGER side of the support
/// pair makes the convention shape-agnostic — square problems (every
/// paper workload) are unchanged from the historical `s₀(a.len())`
/// convention, and rectangular problems sample the same expected budget
/// no matter which cost representation (dense, oracle, or cached
/// artifact) carries them. That last property is what lets
/// [`solve_batch`](crate::api::solve_batch) upgrade rectangular dense
/// costs to [`CostSource::Shared`](crate::api::CostSource) without
/// changing their sketches; it is also the contract future sharding PRs
/// must preserve when splitting a support across nodes.
pub fn sketch_budget(s_multiplier: f64, rows: usize, cols: usize) -> f64 {
    s_multiplier * crate::metrics::s0(rows.max(cols))
}
