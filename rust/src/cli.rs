//! From-scratch CLI argument parser (the offline image has no `clap`).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with generated usage text.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First positional token (subcommand).
    pub command: Option<String>,
    /// Remaining positionals.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: HashMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (usually `std::env::args().skip(1)`).
    /// `value_keys` lists options that consume the following token.
    pub fn parse(tokens: impl IntoIterator<Item = String>, value_keys: &[&str]) -> Args {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if value_keys.contains(&stripped)
                    && it.peek().map(|n| !n.starts_with("--")).unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Whether bare `--name` was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The value of `--key value` / `--key=value`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Parse an option's value, falling back to `default` when absent
    /// or unparsable.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

/// Usage text for the `repro` binary.
pub fn usage() -> String {
    "repro — Spar-Sink reproduction driver\n\
     \n\
     USAGE:\n\
       repro <COMMAND> [OPTIONS]\n\
     \n\
     COMMANDS:\n\
       experiment <id|all> [--full] [--out results/]   regenerate a paper figure/table\n\
       solve --problem ot|uot|barycenter [--n N] [--d D] [--eps E] [--lambda L]\n\
             [--s MULT] [--method M] [--backend B] [--seed S]\n\
             one-off synthetic solve; dispatches through api::solve_batch —\n\
             the dense cost (square or rectangular) is upgraded to a shared\n\
             artifact in the global cache, so the exact reference and the\n\
             approx run share one kernel build; prints the cache counters\n\
             (hits/misses/evictions, resident entries + in-flight builds,\n\
             bytes vs budget) after both solves\n\
       serve [--videos V] [--frames F] [--workers W] [--shards S] [--no-steal]\n\
             [--method M] [--eps E] [--backend B] [--threshold T] [--shared-grid]\n\
             run the batched WFR distance service; --shared-grid keeps\n\
             every frame on the full pixel grid so all pairwise jobs\n\
             share one support and the coordinator's artifact cache\n\
             builds cost/kernel once per (eta, eps) — workers racing a\n\
             build coalesce on its single-flight slot, distinct (eta,\n\
             eps) builds overlap, and the final metrics include the full\n\
             cache gauge line (hits / misses / evictions, resident\n\
             entries, `building` = in-flight builds, bytes vs budget);\n\
             --threshold T (default 0.05) is the per-frame support\n\
             cutoff when --shared-grid is NOT set (pixels below T of\n\
             the frame max are dropped, so each frame gets its own\n\
             support and cache sharing across frames is incidental);\n\
             --workers/--shards take 0 = available parallelism (shards\n\
             clamp to the worker count), --no-steal disables work\n\
             stealing — batches are routed to shards by their cost\n\
             fingerprint, so placement never changes results\n\
       bench coordinator [--workers W] [--shards N] [--size G] [--frames F]\n\
             [--no-steal] [--out FILE]\n\
             sharded-service throughput/latency on the echocardiogram\n\
             pairwise workload: 1 vs N shards, cold vs warm artifact\n\
             cache; writes BENCH_coordinator.json (or FILE)\n\
       runtime-info                                    PJRT platform + artifact menu (xla feature)\n\
       list                                            list available experiments\n\
     \n\
     OPTIONS:\n\
       --full        paper-scale parameters (default: quick profile)\n\
       --out DIR     also write JSON rows to DIR/<id>.json\n\
       --s MULT      sketch budget multiplier (default 8): every sketch\n\
                     solver samples s = MULT * s0(max(n, m)) expected\n\
                     entries, s0(n) = 1e-3 n ln^4 n\n\
       --method M    any solver registered in the unified API:\n\
                     sinkhorn|spar-sink|spar-sink-log|rand-sink|nys-sink|\n\
                     greenkhorn|screenkhorn|spar-ibp\n\
                     (solve and serve dispatch through api::solve; methods\n\
                     that do not support the requested formulation report\n\
                     a per-job error)\n\
       --backend B   scaling-loop override: auto|multiplicative|log-domain,\n\
                     valid for every formulation — balanced/unbalanced OT,\n\
                     dense sinkhorn, and barycenters (spar-ibp included).\n\
                     Defaults per method: the backend-switched solvers use\n\
                     auto (multiplicative above the eps threshold, log-domain\n\
                     below it or on numerical failure/collapse; see\n\
                     `experiment smalleps`); rand-sink stays the\n\
                     multiplicative baseline unless overridden\n\
     \n\
     ENVIRONMENT:\n\
       SPAR_SINK_CACHE_BYTES   byte budget of the global artifact cache\n\
                               (default 512 MiB); the coordinator's cache\n\
                               is sized by CoordinatorConfig.cache_bytes\n\
       SPAR_SINK_THREADS       worker threads for the parallel cost/kernel\n\
                               builders (results are bit-identical at any\n\
                               thread count)\n"
        .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(
            tokens.iter().map(|s| s.to_string()),
            &[
                "out", "n", "eps", "lambda", "method", "seed", "videos", "frames", "workers",
                "problem", "s",
            ],
        )
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = parse(&["experiment", "fig2", "--full", "--out", "results"]);
        assert_eq!(a.command.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["fig2"]);
        assert!(a.flag("full"));
        assert_eq!(a.get("out"), Some("results"));
    }

    #[test]
    fn parses_equals_form() {
        let a = parse(&["solve", "--eps=0.05", "--n=500"]);
        assert_eq!(a.get_parsed("eps", 0.0), 0.05);
        assert_eq!(a.get_parsed("n", 0usize), 500);
    }

    #[test]
    fn default_when_missing() {
        let a = parse(&["solve"]);
        assert_eq!(a.get_parsed("n", 123usize), 123);
        assert!(!a.flag("full"));
    }

    #[test]
    fn flag_does_not_swallow_positional() {
        let a = parse(&["experiment", "--full", "fig3"]);
        assert_eq!(a.positional, vec!["fig3"]);
    }
}
