//! Shared-cost artifacts: everything derivable from a support pair and
//! the regularization knobs (η, ε, formulation) that does NOT depend on
//! per-job marginals, materialized once and reused across a batch.
//!
//! The echocardiogram workload (paper §5, Figs. 11–12) computes O(T²)
//! pairwise UOT distances between frames living on one pixel grid: the
//! WFR cost, the Gibbs kernel, and the cost-dependent factor of the
//! Spar-Sink sampling probabilities are identical for every pair, and
//! only the marginal factor changes per job. [`CostArtifacts`] captures
//! the amortizable part:
//!
//! * the dense ground cost (WFR or squared-Euclidean);
//! * the linear Gibbs kernel `K = exp(−C/ε)`, plus its row/column sums
//!   and Frobenius norm as LAZILY-computed kernel-side statistics
//!   (available to kernel-aware sampling extensions and diagnostics;
//!   they cost nothing until first accessed);
//! * for unbalanced formulations, the cost-dependent factor `β·ln K` of
//!   the Eq. 11 importance probability
//!   `p_ij ∝ (a_i b_j)^α K_ij^β` — the per-job residual is the cheap
//!   marginal factor `α(ln a_i + ln b_j)` (see
//!   [`poisson_sparsify_uot_logk_amortized`](crate::sparse::sampling::poisson_sparsify_uot_logk_amortized));
//! * the WFR truncation radius η used (optionally calibrated to a
//!   target kernel density via [`CostArtifacts::for_wfr_supports_at_density`]).
//!
//! Artifacts are content-addressed by a [`Fingerprint`] — a 128-bit
//! support hash × η × ε × formulation — so two different supports (or
//! the same support at different knobs) never alias in the
//! [`ArtifactCache`](super::ArtifactCache).

use std::sync::{Arc, OnceLock};

use crate::linalg::{dot, Mat};
use crate::ot::cost::{calibrate_eta, gibbs_kernel, log_gibbs_from_cost, sq_euclidean_cost, wfr_cost};
use crate::pool;

/// Largest `rows × cols` grid routed through the artifact engine: above
/// this the dense cost/kernel materialization would dominate memory, so
/// callers (coordinator, `solve_batch`) keep the oracle cold path.
/// Aliases the samplers' [`MATERIALIZE_CAP`](crate::sparse::sampling::MATERIALIZE_CAP)
/// so the two memory policies cannot drift apart.
pub const SHARED_ARTIFACT_ENTRY_CAP: usize = crate::sparse::sampling::MATERIALIZE_CAP;

/// Formulation component of a [`Fingerprint`]. λ enters bit-exactly:
/// the unbalanced sampling factor `β·ln K` depends on it.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum FormulationKey {
    /// Balanced entropic OT.
    Balanced,
    /// Unbalanced entropic OT at a bit-exact λ.
    Unbalanced {
        /// `λ.to_bits()` — λ enters the fingerprint bit-exactly.
        lambda_bits: u64,
    },
    /// Fixed-support barycenter (shared square support).
    Barycenter,
}

impl FormulationKey {
    /// Key for an unbalanced formulation with relaxation strength λ.
    pub fn unbalanced(lambda: f64) -> Self {
        FormulationKey::Unbalanced { lambda_bits: lambda.to_bits() }
    }
}

/// Content address of one [`CostArtifacts`]: support hash (128-bit, two
/// independent streams) × dimensions × η × ε × formulation. Equal
/// fingerprints ⇒ bitwise-identical artifacts; different supports get
/// different fingerprints (up to the 128-bit collision bound).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct Fingerprint {
    support: [u64; 2],
    rows: u64,
    cols: u64,
    /// `η.to_bits()`, or `None` for non-WFR (squared-Euclidean / dense)
    /// costs.
    eta_bits: Option<u64>,
    eps_bits: u64,
    formulation: FormulationKey,
}

/// Two independent 64-bit streams over the same input: FNV-1a plus a
/// multiply-rotate mix. Not cryptographic — the cache is trusted-input
/// — but 128 bits make accidental support collisions negligible.
struct Hash128 {
    h1: u64,
    h2: u64,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

impl Hash128 {
    fn new() -> Self {
        Hash128 { h1: FNV_OFFSET, h2: FNV_OFFSET ^ 0x9e37_79b9_7f4a_7c15 }
    }

    fn write_u64(&mut self, v: u64) {
        for byte in v.to_le_bytes() {
            self.h1 = (self.h1 ^ u64::from(byte)).wrapping_mul(FNV_PRIME);
        }
        self.h2 = (self.h2 ^ v)
            .wrapping_mul(0x2545_f491_4f6c_dd1d)
            .rotate_left(27)
            .wrapping_add(0x9e37_79b9_7f4a_7c15);
    }

    fn write_f64(&mut self, v: f64) {
        self.write_u64(v.to_bits());
    }

    fn finish(self) -> [u64; 2] {
        [self.h1, self.h2]
    }
}

fn hash_points(h: &mut Hash128, pts: &[Vec<f64>]) {
    h.write_u64(pts.len() as u64);
    for p in pts {
        h.write_u64(p.len() as u64);
        for &x in p {
            h.write_f64(x);
        }
    }
}

impl Fingerprint {
    /// Fingerprint of a support pair (the coordinator's job shape):
    /// hashes both point sets, so two jobs share artifacts exactly when
    /// source AND target supports are bit-identical.
    pub fn for_supports(
        xs: &[Vec<f64>],
        ys: &[Vec<f64>],
        eta: Option<f64>,
        eps: f64,
        formulation: FormulationKey,
    ) -> Fingerprint {
        let mut h = Hash128::new();
        h.write_u64(0x5355_5050); // "SUPP" domain separator
        hash_points(&mut h, xs);
        h.write_u64(0x2f2f); // xs/ys separator
        hash_points(&mut h, ys);
        Fingerprint {
            support: h.finish(),
            rows: xs.len() as u64,
            cols: ys.len() as u64,
            eta_bits: eta.map(f64::to_bits),
            eps_bits: eps.to_bits(),
            formulation,
        }
    }

    /// Fingerprint of an already-materialized dense cost (the
    /// `solve_batch` upgrade path): hashes the matrix contents, so two
    /// problems share artifacts exactly when their costs are
    /// bit-identical.
    pub fn for_dense(cost: &Mat, eps: f64, formulation: FormulationKey) -> Fingerprint {
        let mut h = Hash128::new();
        h.write_u64(0x4445_4e53); // "DENS" domain separator
        h.write_u64(cost.rows() as u64);
        h.write_u64(cost.cols() as u64);
        for &c in cost.as_slice() {
            h.write_f64(c);
        }
        Fingerprint {
            support: h.finish(),
            rows: cost.rows() as u64,
            cols: cost.cols() as u64,
            eta_bits: None,
            eps_bits: eps.to_bits(),
            formulation,
        }
    }

    /// Source-side support size (cost rows).
    pub fn rows(&self) -> usize {
        self.rows as usize
    }

    /// Target-side support size (cost columns).
    pub fn cols(&self) -> usize {
        self.cols as usize
    }

    /// Stable 64-bit routing key for shard placement: mixes both
    /// support-hash streams with the dimensions, η, ε, and formulation
    /// bits, so equal fingerprints always produce equal keys (batches
    /// sharing artifacts land on the same shard) while distinct
    /// fingerprints — a many-ε sweep, say — spread across shards.
    /// Deterministic across runs and platforms, unlike `std` hashing:
    /// shard placement must be reproducible for the determinism wall.
    pub fn routing_key(&self) -> u64 {
        let mut h = Hash128::new();
        h.write_u64(0x524f_5554); // "ROUT" domain separator
        h.write_u64(self.support[0]);
        h.write_u64(self.support[1]);
        h.write_u64(self.rows);
        h.write_u64(self.cols);
        h.write_u64(u64::from(self.eta_bits.is_some()));
        h.write_u64(self.eta_bits.unwrap_or(0));
        h.write_u64(self.eps_bits);
        match self.formulation {
            FormulationKey::Balanced => h.write_u64(1),
            FormulationKey::Unbalanced { lambda_bits } => {
                h.write_u64(2);
                h.write_u64(lambda_bits);
            }
            FormulationKey::Barycenter => h.write_u64(3),
        }
        let [a, b] = h.finish();
        a ^ b.rotate_left(32)
    }
}

/// The amortizable cost-dependent factor of the unbalanced (Eq. 11)
/// importance probability `p_ij ∝ (a_i b_j)^α K_ij^β` in the log
/// domain: `β·ln K̃_ij` per entry, with `NaN` marking blocked entries
/// (`K = 0`). Per job only the marginal factor `α(ln a_i + ln b_j)`
/// remains — O(n + m) instead of O(n·m) transcendental work.
#[derive(Clone, Debug)]
pub struct UotLogFactor {
    /// Marginal relaxation λ this factor was built for (bit-matched at
    /// consumption time).
    pub lambda: f64,
    /// `α = λ / (2λ + ε)`.
    pub alpha: f64,
    /// `β = ε / (2λ + ε)`.
    pub beta: f64,
    /// `β·ln K` per entry, row-major `rows × cols`; `NaN` = blocked.
    pub beta_log_kernel: Arc<Vec<f64>>,
}

/// Shared cost/kernel artifacts for one fingerprint. See the module
/// docs for what is amortized; construction is O(n·m) once, after which
/// every consumer is "reuse + reweight".
pub struct CostArtifacts {
    fingerprint: Fingerprint,
    /// Regularization ε the kernel-side artifacts were built at.
    pub eps: f64,
    /// WFR truncation radius η, when the cost is a WFR cost.
    pub eta: Option<f64>,
    /// Dense ground cost (`∞` = blocked transport).
    pub cost: Arc<Mat>,
    /// Linear Gibbs kernel `exp(−C/ε)` (blocked entries exactly 0) —
    /// bitwise identical to what the entry oracles derive, so warm
    /// solves reproduce cold solves exactly.
    pub kernel: Arc<Mat>,
    /// Lazily computed kernel row sums (see
    /// [`CostArtifacts::kernel_row_sums`]).
    row_sums: OnceLock<Vec<f64>>,
    /// Lazily computed kernel column sums.
    col_sums: OnceLock<Vec<f64>>,
    /// Lazily computed kernel Frobenius norm.
    frob_norm: OnceLock<f64>,
    /// Cost-dependent unbalanced sampling factor (unbalanced
    /// fingerprints only).
    pub uot_factor: Option<UotLogFactor>,
}

impl CostArtifacts {
    /// Build from an already-materialized dense cost (shared, not
    /// copied). The `solve_batch` upgrade path.
    pub fn from_dense(cost: Arc<Mat>, eps: f64, formulation: FormulationKey) -> Arc<Self> {
        let fingerprint = Fingerprint::for_dense(&cost, eps, formulation);
        Self::build(fingerprint, cost, None, eps, formulation)
    }

    /// Build WFR-cost artifacts for a support pair (the coordinator's
    /// distance-job shape).
    pub fn for_wfr_supports(
        xs: &[Vec<f64>],
        ys: &[Vec<f64>],
        eta: f64,
        eps: f64,
        formulation: FormulationKey,
    ) -> Arc<Self> {
        let fingerprint = Fingerprint::for_supports(xs, ys, Some(eta), eps, formulation);
        let cost = Arc::new(wfr_cost(xs, ys, eta));
        Self::build(fingerprint, cost, Some(eta), eps, formulation)
    }

    /// [`CostArtifacts::for_wfr_supports`] with η calibrated so the WFR
    /// kernel hits a target density (the paper's R1–R3 regimes).
    pub fn for_wfr_supports_at_density(
        xs: &[Vec<f64>],
        ys: &[Vec<f64>],
        target_density: f64,
        eps: f64,
        formulation: FormulationKey,
    ) -> Arc<Self> {
        let eta = calibrate_eta(xs, ys, target_density, 1e-3);
        Self::for_wfr_supports(xs, ys, eta, eps, formulation)
    }

    /// Build squared-Euclidean artifacts on one shared support (the
    /// coordinator's barycenter-job shape).
    pub fn for_sq_euclidean_support(
        points: &[Vec<f64>],
        eps: f64,
        formulation: FormulationKey,
    ) -> Arc<Self> {
        let fingerprint = Fingerprint::for_supports(points, points, None, eps, formulation);
        let cost = Arc::new(sq_euclidean_cost(points, points));
        Self::build(fingerprint, cost, None, eps, formulation)
    }

    fn build(
        fingerprint: Fingerprint,
        cost: Arc<Mat>,
        eta: Option<f64>,
        eps: f64,
        formulation: FormulationKey,
    ) -> Arc<Self> {
        let kernel = Arc::new(gibbs_kernel(&cost, eps));
        let uot_factor = match formulation {
            FormulationKey::Unbalanced { lambda_bits } => {
                let lambda = f64::from_bits(lambda_bits);
                // Same α/β arithmetic as the cold sampler, so the
                // composed log-weights are bitwise identical.
                let alpha = lambda / (2.0 * lambda + eps);
                let beta = eps / (2.0 * lambda + eps);
                let (n, m) = (cost.rows(), cost.cols());
                let cost_ref = &cost;
                let beta_log_kernel: Vec<f64> = pool::parallel_map(n * m, |idx| {
                    let lk = log_gibbs_from_cost(cost_ref.get(idx / m, idx % m), eps);
                    if lk == f64::NEG_INFINITY {
                        f64::NAN
                    } else {
                        beta * lk
                    }
                });
                Some(UotLogFactor {
                    lambda,
                    alpha,
                    beta,
                    beta_log_kernel: Arc::new(beta_log_kernel),
                })
            }
            _ => None,
        };
        Arc::new(CostArtifacts {
            fingerprint,
            eps,
            eta,
            cost,
            kernel,
            row_sums: OnceLock::new(),
            col_sums: OnceLock::new(),
            frob_norm: OnceLock::new(),
            uot_factor,
        })
    }

    /// Kernel row sums `K·1` — kernel-side statistics for kernel-aware
    /// sampling extensions and diagnostics, computed on first access
    /// and cached for the artifact's lifetime.
    pub fn kernel_row_sums(&self) -> &[f64] {
        self.row_sums.get_or_init(|| self.kernel.row_sums())
    }

    /// Kernel column sums `Kᵀ·1` (lazy, like
    /// [`CostArtifacts::kernel_row_sums`]).
    pub fn kernel_col_sums(&self) -> &[f64] {
        self.col_sums.get_or_init(|| self.kernel.col_sums())
    }

    /// Kernel Frobenius norm `‖K‖_F` (lazy).
    pub fn kernel_frob_norm(&self) -> f64 {
        *self
            .frob_norm
            .get_or_init(|| dot(self.kernel.as_slice(), self.kernel.as_slice()).sqrt())
    }

    /// The content address these artifacts were built for.
    pub fn fingerprint(&self) -> Fingerprint {
        self.fingerprint
    }

    /// Source-side support size (cost rows).
    pub fn rows(&self) -> usize {
        self.cost.rows()
    }

    /// Target-side support size (cost columns).
    pub fn cols(&self) -> usize {
        self.cost.cols()
    }

    /// Exact log-Gibbs entry `ln K = −C/ε` at the artifact's own ε
    /// (−∞ = blocked) — the oracle the samplers consume.
    #[inline]
    pub fn log_kernel_at(&self, i: usize, j: usize) -> f64 {
        log_gibbs_from_cost(self.cost.get(i, j), self.eps)
    }

    /// Whether the kernel is identically zero (fully blocked/underflowed
    /// — no linear-domain solve can make progress on it).
    pub fn kernel_is_empty(&self) -> bool {
        self.kernel_frob_norm() == 0.0
    }

    /// Resident size in bytes (the LRU accounting unit): the O(n·m)
    /// parts — cost + kernel + the optional unbalanced factor. The lazy
    /// O(n + m) statistics are accounting noise and excluded so the
    /// figure is stable whether or not they have materialized.
    pub fn bytes(&self) -> usize {
        let grid = self.cost.rows() * self.cost.cols();
        let factor = self
            .uot_factor
            .as_ref()
            .map_or(0, |f| f.beta_log_kernel.len());
        (2 * grid + factor) * std::mem::size_of::<f64>()
    }
}

impl std::fmt::Debug for CostArtifacts {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "CostArtifacts({}x{}, eps {}, eta {:?}, uot_factor {}, {} B)",
            self.rows(),
            self.cols(),
            self.eps,
            self.eta,
            self.uot_factor.is_some(),
            self.bytes()
        )
    }
}

/// A cheap, clonable handle to cache-resident [`CostArtifacts`] — the
/// payload of [`CostSource::Shared`](crate::api::CostSource::Shared).
#[derive(Clone)]
pub struct CostHandle(Arc<CostArtifacts>);

impl CostHandle {
    /// Wrap shared artifacts in a handle.
    pub fn new(artifacts: Arc<CostArtifacts>) -> Self {
        CostHandle(artifacts)
    }

    /// Borrow the underlying artifacts.
    pub fn artifacts(&self) -> &CostArtifacts {
        &self.0
    }

    /// The underlying shared artifacts.
    pub fn share(&self) -> Arc<CostArtifacts> {
        self.0.clone()
    }
}

impl std::fmt::Debug for CostHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "CostHandle({:?})", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts(n: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = crate::rng::Rng::seed_from(seed);
        (0..n).map(|_| vec![rng.uniform() * 5.0, rng.uniform() * 5.0]).collect()
    }

    #[test]
    fn fingerprint_separates_supports_and_knobs() {
        let a = pts(12, 1);
        let b = pts(12, 2);
        let key = FormulationKey::unbalanced(1.0);
        let base = Fingerprint::for_supports(&a, &a, Some(3.0), 0.05, key);
        assert_eq!(base, Fingerprint::for_supports(&a, &a, Some(3.0), 0.05, key));
        assert_ne!(base, Fingerprint::for_supports(&a, &b, Some(3.0), 0.05, key));
        assert_ne!(base, Fingerprint::for_supports(&b, &a, Some(3.0), 0.05, key));
        assert_ne!(base, Fingerprint::for_supports(&a, &a, Some(3.1), 0.05, key));
        assert_ne!(base, Fingerprint::for_supports(&a, &a, None, 0.05, key));
        assert_ne!(base, Fingerprint::for_supports(&a, &a, Some(3.0), 0.06, key));
        assert_ne!(
            base,
            Fingerprint::for_supports(&a, &a, Some(3.0), 0.05, FormulationKey::unbalanced(2.0))
        );
        assert_ne!(
            base,
            Fingerprint::for_supports(&a, &a, Some(3.0), 0.05, FormulationKey::Balanced)
        );
    }

    #[test]
    fn routing_key_is_a_fingerprint_function() {
        // Equal fingerprints ⇒ equal routing keys (affinity); distinct
        // knobs ⇒ distinct keys (spread), up to the 64-bit bound.
        let a = pts(10, 21);
        let key = FormulationKey::unbalanced(1.0);
        let base = Fingerprint::for_supports(&a, &a, Some(3.0), 0.05, key);
        let again = Fingerprint::for_supports(&a, &a, Some(3.0), 0.05, key);
        assert_eq!(base.routing_key(), again.routing_key());
        let eps2 = Fingerprint::for_supports(&a, &a, Some(3.0), 0.06, key);
        let bal = Fingerprint::for_supports(&a, &a, Some(3.0), 0.05, FormulationKey::Balanced);
        let bare = Fingerprint::for_supports(&a, &a, None, 0.05, key);
        assert_ne!(base.routing_key(), eps2.routing_key());
        assert_ne!(base.routing_key(), bal.routing_key());
        assert_ne!(base.routing_key(), bare.routing_key());
    }

    #[test]
    fn fingerprint_sensitive_to_single_ulp() {
        let a = pts(8, 3);
        let mut b = a.clone();
        b[4][1] = f64::from_bits(b[4][1].to_bits() + 1);
        let key = FormulationKey::Balanced;
        assert_ne!(
            Fingerprint::for_supports(&a, &a, None, 0.1, key),
            Fingerprint::for_supports(&b, &b, None, 0.1, key)
        );
    }

    #[test]
    fn wfr_artifacts_match_cold_oracles_bitwise() {
        let xs = pts(10, 5);
        let ys = pts(9, 6);
        let (eta, eps) = (2.5, 0.05);
        let arts =
            CostArtifacts::for_wfr_supports(&xs, &ys, eta, eps, FormulationKey::unbalanced(1.0));
        assert_eq!(arts.rows(), 10);
        assert_eq!(arts.cols(), 9);
        let factor = arts.uot_factor.as_ref().expect("unbalanced factor");
        for i in 0..10 {
            for j in 0..9 {
                let c = crate::ot::cost::wfr_cost_from_distance(
                    crate::ot::cost::euclidean(&xs[i], &ys[j]),
                    eta,
                );
                assert_eq!(arts.cost.get(i, j).to_bits(), c.to_bits());
                let lk = log_gibbs_from_cost(c, eps);
                assert_eq!(arts.log_kernel_at(i, j).to_bits(), lk.to_bits());
                let k = if c.is_infinite() { 0.0 } else { (-c / eps).exp() };
                assert_eq!(arts.kernel.get(i, j).to_bits(), k.to_bits());
                let blk = factor.beta_log_kernel[i * 9 + j];
                if lk == f64::NEG_INFINITY {
                    assert!(blk.is_nan());
                } else {
                    assert_eq!(blk.to_bits(), (factor.beta * lk).to_bits());
                }
            }
        }
    }

    #[test]
    fn kernel_statistics_are_consistent() {
        let xs = pts(14, 9);
        let arts =
            CostArtifacts::for_sq_euclidean_support(&xs, 0.2, FormulationKey::Balanced);
        assert!(arts.uot_factor.is_none());
        assert_eq!(arts.eta, None);
        let total_rows: f64 = arts.kernel_row_sums().iter().sum();
        let total_cols: f64 = arts.kernel_col_sums().iter().sum();
        assert!((total_rows - total_cols).abs() < 1e-9 * total_rows.abs().max(1.0));
        assert!(arts.kernel_frob_norm() > 0.0);
        assert!(!arts.kernel_is_empty());
        // Lazy statistics repeat bitwise and match a direct computation.
        assert_eq!(
            arts.kernel_frob_norm().to_bits(),
            dot(arts.kernel.as_slice(), arts.kernel.as_slice()).sqrt().to_bits()
        );
        assert_eq!(arts.kernel_row_sums(), &arts.kernel.row_sums()[..]);
        assert!(arts.bytes() >= 2 * 14 * 14 * 8);
    }
}
