//! Algorithms 3 & 4 — the Spar-Sink solver: importance-sparsify the
//! kernel with the paper's probabilities (Eqs. 9 / 11), then run the
//! sparse Sinkhorn loop and evaluate the objective over the sketch.
//!
//! The dense-cost entry points build their sketches through the
//! log-kernel samplers, so every sampled entry keeps an exact `ln K̃`
//! even when `exp(−C/ε)` underflows — combined with the
//! [`ScalingBackend`] escalation this makes `spar_sink_ot` /
//! `spar_sink_uot` return finite objectives at ε orders of magnitude
//! below the multiplicative loop's underflow point.

use super::backend::{BackendKind, ScalingBackend};
use crate::error::Result;
use crate::linalg::Mat;
use crate::ot::sinkhorn::SinkhornParams;
use crate::ot::SinkhornSolution;
use crate::rng::Rng;
use crate::sparse::{
    poisson_sparsify_ot, poisson_sparsify_ot_logk, poisson_sparsify_uot,
    poisson_sparsify_uot_logk, CsrMatrix, SparsifyStats,
};

/// Parameters for the Spar-Sink estimators.
#[derive(Clone, Debug)]
pub struct SparSinkParams {
    /// Sinkhorn loop parameters (δ, iteration cap).
    pub sinkhorn: SinkhornParams,
    /// Shrinkage θ mixing importance and uniform probabilities
    /// (condition (ii) of Theorem 1); 1.0 = pure importance sampling,
    /// matching the paper's experiments.
    pub shrinkage: f64,
    /// Scaling-loop backend; the default `Auto` escalates to the
    /// stabilized log-domain engine for small ε or on numerical failure
    /// of the multiplicative loop.
    pub backend: ScalingBackend,
}

impl Default for SparSinkParams {
    fn default() -> Self {
        SparSinkParams {
            sinkhorn: SinkhornParams::default(),
            shrinkage: 1.0,
            backend: ScalingBackend::default(),
        }
    }
}

/// Solution plus sparsification diagnostics.
#[derive(Clone, Debug)]
pub struct SparSolution {
    pub solution: SinkhornSolution,
    pub stats: SparsifyStats,
    /// Which scaling engine actually produced the solution.
    pub backend: BackendKind,
}

/// Algorithm 3 with oracles: `s_multiplier` is the budget in units of
/// s₀(n) = 10⁻³ n log⁴ n when `s_absolute` is None.
fn resolve_budget(n: usize, s_multiplier: f64) -> f64 {
    s_multiplier * crate::metrics::s0(n)
}

/// Algorithm 3 (OT) from kernel/cost *oracles* — the kernel never needs
/// to be materialized densely.
pub fn spar_sink_ot_oracle(
    kernel: impl Fn(usize, usize) -> f64 + Sync,
    cost: impl Fn(usize, usize) -> f64 + Sync,
    a: &[f64],
    b: &[f64],
    eps: f64,
    s: f64,
    params: &SparSinkParams,
    rng: &mut Rng,
) -> Result<SparSolution> {
    let (sketch, stats) =
        poisson_sparsify_ot(kernel, cost, a, b, s, params.shrinkage, rng)?;
    solve_ot_on_sketch(&sketch, a, b, eps, params, stats)
}

/// Algorithm 3 (OT) from a LOG-kernel oracle `ln K(i,j)` (−∞ = blocked
/// entry) — the stable entry point for ε far below the multiplicative
/// underflow threshold: sampled entries keep exact log-kernel values.
#[allow(clippy::too_many_arguments)]
pub fn spar_sink_ot_logk_oracle(
    log_kernel: impl Fn(usize, usize) -> f64 + Sync,
    cost: impl Fn(usize, usize) -> f64 + Sync,
    a: &[f64],
    b: &[f64],
    eps: f64,
    s: f64,
    params: &SparSinkParams,
    rng: &mut Rng,
) -> Result<SparSolution> {
    let (sketch, stats) =
        poisson_sparsify_ot_logk(log_kernel, cost, a, b, s, params.shrinkage, rng)?;
    solve_ot_on_sketch(&sketch, a, b, eps, params, stats)
}

/// Algorithm 3 (OT) from a dense cost matrix; `s_multiplier` is in units
/// of s₀(n) (the paper sweeps s ∈ {2,4,8,16}·s₀(n)). The sketch is
/// built with exact log-kernel values `−C_ij/ε`, so small-ε problems
/// stay solvable through the log-domain backend.
pub fn spar_sink_ot(
    cost: &Mat,
    a: &[f64],
    b: &[f64],
    eps: f64,
    s_multiplier: f64,
    params: &SparSinkParams,
    rng: &mut Rng,
) -> Result<SparSolution> {
    let s = resolve_budget(a.len(), s_multiplier);
    spar_sink_ot_logk_oracle(
        |i, j| crate::ot::cost::log_gibbs_from_cost(cost.get(i, j), eps),
        |i, j| cost.get(i, j),
        a,
        b,
        eps,
        s,
        params,
        rng,
    )
}

fn solve_ot_on_sketch(
    sketch: &CsrMatrix,
    a: &[f64],
    b: &[f64],
    eps: f64,
    params: &SparSinkParams,
    stats: SparsifyStats,
) -> Result<SparSolution> {
    let (solution, backend) = params.backend.sparse_ot(sketch, a, b, eps, &params.sinkhorn)?;
    Ok(SparSolution { solution, stats, backend })
}

fn solve_uot_on_sketch(
    sketch: &CsrMatrix,
    a: &[f64],
    b: &[f64],
    lambda: f64,
    eps: f64,
    params: &SparSinkParams,
    stats: SparsifyStats,
) -> Result<SparSolution> {
    let (solution, backend) =
        params.backend.sparse_uot(sketch, a, b, lambda, eps, &params.sinkhorn)?;
    Ok(SparSolution { solution, stats, backend })
}

/// Algorithm 4 (UOT) from kernel/cost oracles.
#[allow(clippy::too_many_arguments)]
pub fn spar_sink_uot_oracle(
    kernel: impl Fn(usize, usize) -> f64 + Sync,
    cost: impl Fn(usize, usize) -> f64 + Sync,
    a: &[f64],
    b: &[f64],
    lambda: f64,
    eps: f64,
    s: f64,
    params: &SparSinkParams,
    rng: &mut Rng,
) -> Result<SparSolution> {
    let (sketch, stats) = poisson_sparsify_uot(
        kernel,
        cost,
        a,
        b,
        lambda,
        eps,
        s,
        params.shrinkage,
        rng,
    )?;
    solve_uot_on_sketch(&sketch, a, b, lambda, eps, params, stats)
}

/// Algorithm 4 (UOT) from a LOG-kernel oracle: both the Eq. 11 sampling
/// probabilities and the stored sketch values are computed in the log
/// domain, so the pipeline survives full kernel underflow end to end.
#[allow(clippy::too_many_arguments)]
pub fn spar_sink_uot_logk_oracle(
    log_kernel: impl Fn(usize, usize) -> f64 + Sync,
    cost: impl Fn(usize, usize) -> f64 + Sync,
    a: &[f64],
    b: &[f64],
    lambda: f64,
    eps: f64,
    s: f64,
    params: &SparSinkParams,
    rng: &mut Rng,
) -> Result<SparSolution> {
    let (sketch, stats) = poisson_sparsify_uot_logk(
        log_kernel,
        cost,
        a,
        b,
        lambda,
        eps,
        s,
        params.shrinkage,
        rng,
    )?;
    solve_uot_on_sketch(&sketch, a, b, lambda, eps, params, stats)
}

/// Algorithm 4 (UOT) from a dense cost matrix; `s_multiplier` in units
/// of s₀(n). Routes through the log-kernel pipeline like
/// [`spar_sink_ot`].
#[allow(clippy::too_many_arguments)]
pub fn spar_sink_uot(
    cost: &Mat,
    a: &[f64],
    b: &[f64],
    lambda: f64,
    eps: f64,
    s_multiplier: f64,
    params: &SparSinkParams,
    rng: &mut Rng,
) -> Result<SparSolution> {
    let s = resolve_budget(a.len(), s_multiplier);
    spar_sink_uot_logk_oracle(
        |i, j| crate::ot::cost::log_gibbs_from_cost(cost.get(i, j), eps),
        |i, j| cost.get(i, j),
        a,
        b,
        lambda,
        eps,
        s,
        params,
        rng,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::cost::{gibbs_kernel, sq_euclidean_cost, wfr_cost};
    use crate::ot::sinkhorn::sinkhorn_ot;
    use crate::ot::uot::sinkhorn_uot;
    use crate::rng::Rng;

    fn problem(n: usize, seed: u64) -> (Mat, Vec<f64>, Vec<f64>, Vec<Vec<f64>>) {
        let mut rng = Rng::seed_from(seed);
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..3).map(|_| rng.uniform()).collect())
            .collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        let a: Vec<f64> = (0..n).map(|_| rng.normal_ms(1.0 / 3.0, (1.0f64 / 20.0).sqrt()).abs() + 1e-3).collect();
        let sa: f64 = a.iter().sum();
        let b: Vec<f64> = (0..n).map(|_| rng.normal_ms(0.5, (1.0f64 / 20.0).sqrt()).abs() + 1e-3).collect();
        let sb: f64 = b.iter().sum();
        (
            cost,
            a.iter().map(|x| x / sa).collect(),
            b.iter().map(|x| x / sb).collect(),
            pts,
        )
    }

    #[test]
    fn approximates_exact_ot_objective() {
        let n = 200;
        let (cost, a, b, _) = problem(n, 7);
        let eps = 0.1;
        let kernel = gibbs_kernel(&cost, eps);
        let exact = sinkhorn_ot(&kernel, &cost, &a, &b, eps, &SinkhornParams::default()).unwrap();
        let mut rng = Rng::seed_from(1);
        let mut errs = Vec::new();
        for _ in 0..5 {
            let approx =
                spar_sink_ot(&cost, &a, &b, eps, 16.0, &SparSinkParams::default(), &mut rng)
                    .unwrap();
            errs.push((approx.solution.objective - exact.objective).abs() / exact.objective.abs());
        }
        let mean_err = errs.iter().sum::<f64>() / errs.len() as f64;
        // n = 200 is small for the sqrt(n^(3-2a)/s) bound; the
        // fig2 harness at n = 1000 shows the paper-scale errors.
        assert!(mean_err < 0.5, "mean relative error {mean_err}");
    }

    #[test]
    fn error_decreases_with_budget() {
        let n = 200;
        let (cost, a, b, _) = problem(n, 11);
        let eps = 0.1;
        let kernel = gibbs_kernel(&cost, eps);
        let exact = sinkhorn_ot(&kernel, &cost, &a, &b, eps, &SinkhornParams::default()).unwrap();
        let mut rng = Rng::seed_from(3);
        let mut rmae_for = |mult: f64| -> f64 {
            let reps = 8;
            let mut acc = 0.0;
            for _ in 0..reps {
                let approx =
                    spar_sink_ot(&cost, &a, &b, eps, mult, &SparSinkParams::default(), &mut rng)
                        .unwrap();
                acc += (approx.solution.objective - exact.objective).abs()
                    / exact.objective.abs();
            }
            acc / reps as f64
        };
        let small = rmae_for(2.0);
        let large = rmae_for(16.0);
        assert!(large < small, "rmae did not decrease: s=2s0 {small} vs s=16s0 {large}");
    }

    #[test]
    fn uot_wfr_workflow() {
        let n = 150;
        let (_, a, b, pts) = problem(n, 13);
        // Unbalance the masses (5 and 3 as in the paper).
        let a: Vec<f64> = a.iter().map(|x| x * 5.0).collect();
        let b: Vec<f64> = b.iter().map(|x| x * 3.0).collect();
        let eta = crate::ot::cost::calibrate_eta(&pts, &pts, 0.5, 1e-3);
        let cost = wfr_cost(&pts, &pts, eta);
        let (lambda, eps) = (1.0, 0.1);
        let kernel = cost.map(|c| if c.is_infinite() { 0.0 } else { (-c / eps).exp() });
        let exact =
            sinkhorn_uot(&kernel, &cost, &a, &b, lambda, eps, &SinkhornParams::default()).unwrap();
        let mut rng = Rng::seed_from(5);
        let mut errs = Vec::new();
        for _ in 0..5 {
            let approx = spar_sink_uot(
                &cost,
                &a,
                &b,
                lambda,
                eps,
                16.0,
                &SparSinkParams::default(),
                &mut rng,
            )
            .unwrap();
            errs.push((approx.solution.objective - exact.objective).abs() / exact.objective.abs());
        }
        let mean_err = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!(mean_err < 0.9, "mean relative UOT error {mean_err}");
    }

    #[test]
    fn tiny_eps_ot_succeeds_with_default_backend() {
        // ε two orders of magnitude below the multiplicative underflow
        // cliff: the multiplicative backend errors or collapses; the
        // default (Auto) backend routes to the log engine and returns a
        // finite, positive objective.
        let n = 120;
        let (cost, a, b, _) = problem(n, 23);
        let eps = 1e-5;
        let mut rng = Rng::seed_from(71);
        let sol = spar_sink_ot(&cost, &a, &b, eps, 16.0, &SparSinkParams::default(), &mut rng)
            .unwrap();
        assert_eq!(sol.backend, crate::solvers::backend::BackendKind::LogDomain);
        assert!(sol.solution.objective.is_finite());
        assert!(sol.solution.objective > 0.0, "objective {}", sol.solution.objective);
        // The multiplicative backend on the same sketch either errors,
        // stalls, or collapses onto the handful of entries whose kernel
        // survived underflow — a gross underestimate of the transport.
        let mult_params = SparSinkParams {
            backend: crate::solvers::backend::ScalingBackend::Multiplicative,
            ..Default::default()
        };
        let mut rng = Rng::seed_from(71);
        match spar_sink_ot(&cost, &a, &b, eps, 16.0, &mult_params, &mut rng) {
            Err(crate::error::Error::Numerical(_)) => {}
            Err(e) => panic!("unexpected error kind: {e}"),
            Ok(s) => assert!(
                !s.solution.converged || s.solution.objective < 0.5 * sol.solution.objective,
                "multiplicative loop unexpectedly healthy at eps={eps}: {} vs log {}",
                s.solution.objective,
                sol.solution.objective
            ),
        }
    }

    #[test]
    fn tiny_eps_uot_succeeds_with_default_backend() {
        let n = 100;
        let (_, a, b, pts) = problem(n, 29);
        let a: Vec<f64> = a.iter().map(|x| x * 5.0).collect();
        let b: Vec<f64> = b.iter().map(|x| x * 3.0).collect();
        let eta = crate::ot::cost::calibrate_eta(&pts, &pts, 0.5, 1e-3);
        let cost = wfr_cost(&pts, &pts, eta);
        let (lambda, eps) = (1.0, 1e-4);
        let mut rng = Rng::seed_from(37);
        let sol = spar_sink_uot(
            &cost,
            &a,
            &b,
            lambda,
            eps,
            16.0,
            &SparSinkParams::default(),
            &mut rng,
        )
        .unwrap();
        assert_eq!(sol.backend, crate::solvers::backend::BackendKind::LogDomain);
        assert!(sol.solution.objective.is_finite());
        assert!(sol.stats.nnz > 0);
    }

    #[test]
    fn moderate_eps_still_runs_multiplicative() {
        // Above the threshold nothing changes: Auto uses the fast path.
        let n = 150;
        let (cost, a, b, _) = problem(n, 41);
        let mut rng = Rng::seed_from(43);
        let sol = spar_sink_ot(&cost, &a, &b, 0.1, 8.0, &SparSinkParams::default(), &mut rng)
            .unwrap();
        assert_eq!(sol.backend, crate::solvers::backend::BackendKind::Multiplicative);
        assert!(sol.solution.objective.is_finite());
    }

    #[test]
    fn sketch_budget_respected() {
        let n = 300;
        let (cost, a, b, _) = problem(n, 17);
        let mut rng = Rng::seed_from(9);
        let sol = spar_sink_ot(&cost, &a, &b, 0.1, 8.0, &SparSinkParams::default(), &mut rng)
            .unwrap();
        let budget = 8.0 * crate::metrics::s0(n);
        assert!(
            (sol.stats.nnz as f64) < budget * 1.2,
            "nnz {} exceeds budget {budget}",
            sol.stats.nnz
        );
        // Far sparser than dense.
        assert!((sol.stats.nnz as f64) < (n * n) as f64 * 0.5);
    }
}
