//! Synthetic echocardiogram videos — the Table 1 / Figs. 6-7 substrate.
//!
//! The EchoNet-Dynamic data set used by the paper is not available in
//! this environment, so we simulate apical-four-chamber-like videos (see
//! DESIGN.md §3 for the substitution argument): a bright myocardial
//! annulus whose inner radius follows a two-phase cardiac waveform
//! (rapid systolic contraction, slower diastolic relaxation), a darker
//! chamber pool whose brightness co-varies with blood volume, speckle
//! noise, and configurable pathologies:
//!
//! * `Health::Normal`      — fixed period, full ejection amplitude;
//! * `Health::HeartFailure`— reduced ejection amplitude (low EF);
//! * `Health::Arrhythmia`  — cycle-length jitter (irregular RR interval).
//!
//! Ground-truth end-diastole (ED = maximal volume) and end-systole
//! (ES = minimal volume) frame indices come from the waveform generator,
//! replacing the human annotations of the real data set.

use crate::rng::Rng;

/// Cardiac-function condition to simulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Health {
    /// Regular cycle with full contraction amplitude.
    Normal,
    /// Reduced ejection fraction (damped contraction).
    HeartFailure,
    /// Irregular cycle lengths.
    Arrhythmia,
}

impl Health {
    /// Label used in experiment output rows.
    pub fn name(&self) -> &'static str {
        match self {
            Health::Normal => "health",
            Health::HeartFailure => "heart-failure",
            Health::Arrhythmia => "arrhythmia",
        }
    }
}

/// Configuration of the synthetic echo generator.
#[derive(Clone, Debug)]
pub struct EchoConfig {
    /// Square frame side (paper: 112).
    pub size: usize,
    /// Number of frames.
    pub frames: usize,
    /// Frames per cardiac cycle (paper videos: ~30-60 at 51 fps).
    pub period: f64,
    /// Condition.
    pub health: Health,
    /// Pixel noise level (fraction of peak intensity).
    pub noise: f64,
}

impl Default for EchoConfig {
    fn default() -> Self {
        EchoConfig { size: 112, frames: 120, period: 30.0, health: Health::Normal, noise: 0.02 }
    }
}

/// A generated video: frames of `size*size` gray values in [0,1], plus
/// ground-truth ED/ES frame indices per cycle.
#[derive(Clone, Debug)]
pub struct EchoVideo {
    /// Frame side length in pixels.
    pub size: usize,
    /// One `size*size` gray-value buffer per frame.
    pub frames: Vec<Vec<f64>>,
    /// (ES index, ED index) pairs, ES before the following ED, per cycle.
    pub es_frames: Vec<usize>,
    /// End-diastole frame indices, one per cycle.
    pub ed_frames: Vec<usize>,
    /// The volume phase signal used to generate the video (diagnostics).
    pub phase: Vec<f64>,
}

/// Cardiac volume waveform on [0,1): 1 at end-diastole, 0 at end-systole.
/// Systole occupies ~1/3 of the cycle (rapid fall), diastole ~2/3
/// (slower refill) — the classical asymmetry.
fn volume_phase(t: f64) -> f64 {
    let t = t.rem_euclid(1.0);
    const SYSTOLE: f64 = 0.35;
    if t < SYSTOLE {
        // Contraction: cosine fall 1 -> 0.
        0.5 * (1.0 + (std::f64::consts::PI * t / SYSTOLE).cos())
    } else {
        // Relaxation: cosine rise 0 -> 1.
        let u = (t - SYSTOLE) / (1.0 - SYSTOLE);
        0.5 * (1.0 - (std::f64::consts::PI * u).cos())
    }
}

/// Generate one synthetic echocardiogram video.
pub fn generate(config: &EchoConfig, rng: &mut Rng) -> EchoVideo {
    let n = config.size;
    let center = (n as f64 - 1.0) / 2.0;
    // Ejection amplitude: how much the inner radius shrinks at ES.
    let amplitude = match config.health {
        Health::HeartFailure => 0.35, // reduced ejection fraction
        _ => 1.0,
    };
    // Per-cycle period jitter for arrhythmia.
    let mut phases = Vec::with_capacity(config.frames);
    let mut phase_acc = 0.0f64;
    let mut current_period = config.period;
    for _ in 0..config.frames {
        phases.push(phase_acc);
        phase_acc += 1.0 / current_period;
        if phase_acc.fract() < 1.0 / current_period && phase_acc >= 1.0 {
            // New cycle boundary: re-draw the period for arrhythmia.
            if config.health == Health::Arrhythmia {
                current_period = config.period * (0.6 + 0.8 * rng.uniform());
            }
        }
    }
    let vols: Vec<f64> = phases.iter().map(|&p| {
        let v = volume_phase(p);
        1.0 - amplitude * (1.0 - v)
    }).collect();

    // ED/ES ground truth: local maxima/minima of the volume signal.
    let mut ed_frames = Vec::new();
    let mut es_frames = Vec::new();
    for i in 1..config.frames.saturating_sub(1) {
        if vols[i] >= vols[i - 1] && vols[i] > vols[i + 1] {
            ed_frames.push(i);
        }
        if vols[i] <= vols[i - 1] && vols[i] < vols[i + 1] {
            es_frames.push(i);
        }
    }

    // Render frames.
    let r_outer = 0.42 * n as f64; // epicardial radius (fixed)
    let r_inner_ed = 0.30 * n as f64; // endocardial radius at ED
    let r_inner_es = 0.14 * n as f64; // endocardial radius at ES (full EF)
    let frames: Vec<Vec<f64>> = vols
        .iter()
        .map(|&vol| {
            let r_inner = r_inner_es + (r_inner_ed - r_inner_es) * vol;
            let mut img = vec![0.0f64; n * n];
            for y in 0..n {
                for x in 0..n {
                    let dx = x as f64 - center;
                    let dy = y as f64 - center * 1.05;
                    // Slight vertical eccentricity: apical view.
                    let r = (dx * dx + 1.15 * dy * dy).sqrt();
                    let mut val = 0.0;
                    if r <= r_outer && r >= r_inner {
                        // Myocardium: bright, smooth edges.
                        let edge_o = ((r_outer - r) / 2.0).clamp(0.0, 1.0);
                        let edge_i = ((r - r_inner) / 2.0).clamp(0.0, 1.0);
                        val = 0.85 * edge_o * edge_i;
                    } else if r < r_inner {
                        // Chamber blood pool: darker, brightness rises
                        // slightly at ES (denser speckle).
                        val = 0.15 + 0.1 * (1.0 - vol);
                    }
                    if val > 0.0 && config.noise > 0.0 {
                        val = (val + config.noise * rng.normal()).clamp(0.0, 1.0);
                    }
                    img[y * n + x] = val;
                }
            }
            img
        })
        .collect();

    EchoVideo { size: n, frames, es_frames, ed_frames, phase: vols }
}

/// A frame as a sparse 2-D measure: positive-mass pixels only,
/// normalized gray levels (the paper's construction, Section 6).
/// Pixels below `threshold` of the max are dropped — zero-mass pixels
/// can never receive transport, so this is exact for the WFR distance.
pub fn frame_to_measure(
    frame: &[f64],
    size: usize,
    threshold: f64,
) -> (Vec<Vec<f64>>, Vec<f64>) {
    let max = frame.iter().cloned().fold(0.0, f64::max);
    let cut = threshold * max;
    let mut support = Vec::new();
    let mut mass = Vec::new();
    for y in 0..size {
        for x in 0..size {
            let v = frame[y * size + x];
            if v > cut {
                support.push(vec![x as f64, y as f64]);
                mass.push(v);
            }
        }
    }
    let total: f64 = mass.iter().sum();
    for m in mass.iter_mut() {
        *m /= total;
    }
    (support, mass)
}

/// Mean-pool a frame with `k`×`k` filters and stride `k` (Table 1b).
pub fn mean_pool(frame: &[f64], size: usize, k: usize) -> (Vec<f64>, usize) {
    assert_eq!(size % k, 0, "pooling requires divisible size");
    let out_size = size / k;
    let mut out = vec![0.0; out_size * out_size];
    for oy in 0..out_size {
        for ox in 0..out_size {
            let mut acc = 0.0;
            for dy in 0..k {
                for dx in 0..k {
                    acc += frame[(oy * k + dy) * size + (ox * k + dx)];
                }
            }
            out[oy * out_size + ox] = acc / (k * k) as f64;
        }
    }
    (out, out_size)
}

/// Temporal downsampling: keep every `period`-th frame (the paper
/// samples every other two frames, period 3).
pub fn downsample_frames(video: &EchoVideo, period: usize) -> Vec<usize> {
    (0..video.frames.len()).step_by(period).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waveform_extremes() {
        assert!((volume_phase(0.0) - 1.0).abs() < 1e-12);
        assert!(volume_phase(0.35) < 1e-12); // end systole
        assert!((volume_phase(0.999) - 1.0).abs() < 1e-3);
    }

    #[test]
    fn video_has_cycles_with_ground_truth() {
        let mut rng = Rng::seed_from(103);
        let cfg = EchoConfig { size: 32, frames: 90, period: 30.0, ..Default::default() };
        let video = generate(&cfg, &mut rng);
        assert_eq!(video.frames.len(), 90);
        assert!(video.ed_frames.len() >= 2, "ed {:?}", video.ed_frames);
        assert!(video.es_frames.len() >= 2, "es {:?}", video.es_frames);
        // ES and ED alternate.
        for (&es, &ed) in video.es_frames.iter().zip(&video.ed_frames) {
            assert_ne!(es, ed);
        }
    }

    #[test]
    fn heart_failure_reduces_motion() {
        let mut r1 = Rng::seed_from(105);
        let mut r2 = Rng::seed_from(105);
        let normal = generate(
            &EchoConfig { size: 32, frames: 60, health: Health::Normal, noise: 0.0, ..Default::default() },
            &mut r1,
        );
        let failing = generate(
            &EchoConfig { size: 32, frames: 60, health: Health::HeartFailure, noise: 0.0, ..Default::default() },
            &mut r2,
        );
        // Frame-to-frame image change should be larger for the healthy
        // heart (more wall motion).
        let motion = |v: &EchoVideo| -> f64 {
            v.frames
                .windows(2)
                .map(|w| w[0].iter().zip(&w[1]).map(|(a, b)| (a - b).abs()).sum::<f64>())
                .sum()
        };
        assert!(motion(&normal) > 1.5 * motion(&failing));
    }

    #[test]
    fn arrhythmia_has_irregular_cycles() {
        let mut rng = Rng::seed_from(107);
        let video = generate(
            &EchoConfig {
                size: 24,
                frames: 300,
                period: 30.0,
                health: Health::Arrhythmia,
                noise: 0.0,
            },
            &mut rng,
        );
        let gaps: Vec<i64> = video
            .ed_frames
            .windows(2)
            .map(|w| w[1] as i64 - w[0] as i64)
            .collect();
        assert!(gaps.len() >= 3);
        let min = gaps.iter().min().unwrap();
        let max = gaps.iter().max().unwrap();
        assert!(max - min >= 4, "cycle lengths too regular: {gaps:?}");
    }

    #[test]
    fn measure_is_normalized_and_sparse() {
        let mut rng = Rng::seed_from(109);
        let video = generate(&EchoConfig { size: 48, frames: 3, ..Default::default() }, &mut rng);
        let (support, mass) = frame_to_measure(&video.frames[0], 48, 0.05);
        let s: f64 = mass.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
        assert!(support.len() < 48 * 48, "background should be dropped");
        assert!(support.len() > 100, "foreground too small: {}", support.len());
    }

    #[test]
    fn mean_pool_preserves_total_mass_scaled() {
        let frame: Vec<f64> = (0..16).map(|i| i as f64).collect();
        let (pooled, out_size) = mean_pool(&frame, 4, 2);
        assert_eq!(out_size, 2);
        assert_eq!(pooled.len(), 4);
        // Pool of [0,1,4,5] = 2.5 etc.
        assert!((pooled[0] - 2.5).abs() < 1e-12);
        let total_in: f64 = frame.iter().sum();
        let total_out: f64 = pooled.iter().sum::<f64>() * 4.0;
        assert!((total_in - total_out).abs() < 1e-9);
    }

    #[test]
    fn downsampling_period() {
        let mut rng = Rng::seed_from(111);
        let video = generate(&EchoConfig { size: 16, frames: 30, ..Default::default() }, &mut rng);
        let idx = downsample_frames(&video, 3);
        assert_eq!(idx, vec![0, 3, 6, 9, 12, 15, 18, 21, 24, 27]);
    }
}
