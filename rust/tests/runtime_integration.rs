//! Integration tests over the AOT artifact path: JAX/Pallas → HLO text →
//! PJRT CPU → Rust driver. These validate that the runtime-backed dense
//! Sinkhorn agrees with the native Rust solver (the two independent
//! implementations cross-check each other), including the padding path.
//!
//! Skipped gracefully when `artifacts/` has not been built. The whole
//! file is gated on the `xla` feature, which gates the PJRT runtime.
#![cfg(feature = "xla")]

use std::sync::Arc;

use spar_sink::linalg::Mat;
use spar_sink::ot::cost::{gibbs_kernel, sq_euclidean_cost};
use spar_sink::ot::sinkhorn::{sinkhorn_ot, SinkhornParams};
use spar_sink::ot::uot::sinkhorn_uot;
use spar_sink::rng::Rng;
use spar_sink::runtime::{default_artifact_dir, manifest_path, ArtifactRegistry, DenseSinkhornRuntime};

fn registry() -> Option<Arc<ArtifactRegistry>> {
    let dir = default_artifact_dir();
    if !manifest_path(&dir).exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Arc::new(ArtifactRegistry::open(&dir).expect("open registry")))
}

fn problem(n: usize, seed: u64, eps: f64) -> (Mat, Mat, Vec<f64>, Vec<f64>) {
    let mut rng = Rng::seed_from(seed);
    let pts: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..2).map(|_| rng.uniform()).collect())
        .collect();
    let cost = sq_euclidean_cost(&pts, &pts);
    let kernel = gibbs_kernel(&cost, eps);
    let a: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.2).collect();
    let sa: f64 = a.iter().sum();
    let b: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.2).collect();
    let sb: f64 = b.iter().sum();
    (
        kernel,
        cost,
        a.iter().map(|x| x / sa).collect(),
        b.iter().map(|x| x / sb).collect(),
    )
}

#[test]
fn runtime_ot_matches_native_solver_exact_size() {
    let Some(reg) = registry() else { return };
    let runtime = DenseSinkhornRuntime::new(reg.clone());
    let n = *reg.sizes(spar_sink::runtime::Entry::SinkhornBlock).first().unwrap();
    let eps = 0.1;
    let (kernel, cost, a, b) = problem(n, 131, eps);
    let native = sinkhorn_ot(&kernel, &cost, &a, &b, eps, &SinkhornParams::default()).unwrap();
    let rt = runtime.solve_ot(&kernel, &cost, &a, &b, eps, 1e-6, 1000).unwrap();
    let rel = (rt.objective - native.objective).abs() / native.objective.abs();
    assert!(rel < 1e-3, "runtime {} vs native {} (rel {rel})", rt.objective, native.objective);
    assert!(rt.converged);
}

#[test]
fn runtime_ot_padding_path() {
    let Some(reg) = registry() else { return };
    let runtime = DenseSinkhornRuntime::new(reg);
    // n = 50 is below the smallest menu size (64): exercises padding.
    let n = 50;
    let eps = 0.1;
    let (kernel, cost, a, b) = problem(n, 137, eps);
    let native = sinkhorn_ot(&kernel, &cost, &a, &b, eps, &SinkhornParams::default()).unwrap();
    let rt = runtime.solve_ot(&kernel, &cost, &a, &b, eps, 1e-6, 1000).unwrap();
    let rel = (rt.objective - native.objective).abs() / native.objective.abs();
    assert!(rel < 1e-3, "padded runtime {} vs native {} (rel {rel})", rt.objective, native.objective);
}

#[test]
fn runtime_uot_matches_native_solver() {
    let Some(reg) = registry() else { return };
    let runtime = DenseSinkhornRuntime::new(reg.clone());
    let n = *reg.sizes(spar_sink::runtime::Entry::SinkhornBlock).first().unwrap();
    let (lambda, eps) = (1.0, 0.1);
    let (kernel, cost, mut a, mut b) = problem(n, 139, eps);
    // Unbalance the masses.
    for x in a.iter_mut() {
        *x *= 5.0;
    }
    for x in b.iter_mut() {
        *x *= 3.0;
    }
    let native =
        sinkhorn_uot(&kernel, &cost, &a, &b, lambda, eps, &SinkhornParams::default()).unwrap();
    let rt = runtime
        .solve_uot(&kernel, &cost, &a, &b, lambda, eps, 1e-6, 1000)
        .unwrap();
    let rel = (rt.objective - native.objective).abs() / native.objective.abs();
    assert!(rel < 1e-2, "runtime {} vs native {} (rel {rel})", rt.objective, native.objective);
}

#[test]
fn runtime_scalings_match_native() {
    let Some(reg) = registry() else { return };
    let runtime = DenseSinkhornRuntime::new(reg.clone());
    let n = *reg.sizes(spar_sink::runtime::Entry::SinkhornBlock).first().unwrap();
    let eps = 0.2;
    let (kernel, cost, a, b) = problem(n, 149, eps);
    let native = sinkhorn_ot(&kernel, &cost, &a, &b, eps, &SinkhornParams::default()).unwrap();
    let rt = runtime.solve_ot(&kernel, &cost, &a, &b, eps, 1e-6, 1000).unwrap();
    // Scalings have a joint scale ambiguity (u*c, v/c); compare the plan
    // marginals instead (both must satisfy them).
    let plan_row = |u: &[f64], v: &[f64], i: usize| -> f64 {
        (0..n).map(|j| u[i] * kernel.get(i, j) * v[j]).sum()
    };
    for i in (0..n).step_by(7) {
        let r1 = plan_row(&native.u, &native.v, i);
        let r2 = plan_row(&rt.u, &rt.v, i);
        assert!((r1 - r2).abs() < 1e-4, "row {i}: {r1} vs {r2}");
    }
}

#[test]
fn runtime_reports_iteration_multiples() {
    let Some(reg) = registry() else { return };
    let block = reg.block_iters();
    let runtime = DenseSinkhornRuntime::new(reg.clone());
    let n = *reg.sizes(spar_sink::runtime::Entry::SinkhornBlock).first().unwrap();
    let eps = 0.1;
    let (kernel, cost, a, b) = problem(n, 151, eps);
    let rt = runtime.solve_ot(&kernel, &cost, &a, &b, eps, 1e-6, 1000).unwrap();
    assert_eq!(rt.iterations % block, 0);
    assert!(rt.iterations > 0);
}

#[test]
fn registry_caches_executables() {
    let Some(reg) = registry() else { return };
    let n = *reg.sizes(spar_sink::runtime::Entry::SinkhornBlock).first().unwrap();
    let t0 = std::time::Instant::now();
    let _e1 = reg.executable(spar_sink::runtime::Entry::SinkhornBlock, n).unwrap();
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    let _e2 = reg.executable(spar_sink::runtime::Entry::SinkhornBlock, n).unwrap();
    let second = t1.elapsed();
    assert!(second < first / 2, "cache hit {second:?} should beat compile {first:?}");
}
