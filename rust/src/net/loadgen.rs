//! Replay load generator: drive the echocardiogram pairwise workload
//! against any gateway or balancer address and measure serving
//! behavior under saturation.
//!
//! The workload is the SAME deterministic job list the coordinator
//! bench uses ([`crate::bench::coordinator::pairwise_jobs`]), encoded
//! once through the wire codec and replayed by N client threads over
//! fresh connections (`connection: close` — every request observes the
//! peer's current admission state). The report separates the outcomes
//! the serving stack distinguishes: `200` completions, `429`
//! admission-control rejections (the saturation signal), other HTTP
//! failures, and socket-level errors, plus p50/p99 latency over every
//! answered request. `repro bench gateway` wraps this into
//! `BENCH_gateway.json`.

use std::net::{SocketAddr, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use crate::bench::coordinator::pairwise_jobs;
use crate::coordinator::LatencyHistogram;
use crate::error::{Error, Result};
use crate::net::client;
use crate::net::codec;
use crate::util::json::Json;

/// Replay parameters. `Default` is a seconds-scale smoke load; the CLI
/// and the gateway bench override the counts.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Target address (`host:port`) of a gateway or balancer.
    pub addr: String,
    /// Concurrent client threads.
    pub clients: usize,
    /// Total requests to send (the workload list is cycled).
    pub jobs: usize,
    /// Workload pixel-grid side (`size²` support points per measure).
    pub size: usize,
    /// Workload frames per video (downsampled 3:1 before pairing).
    pub frames: usize,
    /// Workload ε sweep — one cost fingerprint per value, so affinity
    /// routing has several classes to place.
    pub eps_values: Vec<f64>,
    /// Per-request connect timeout.
    pub connect_timeout: Duration,
    /// Per-request response timeout.
    pub io_timeout: Duration,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:0".to_string(),
            clients: 4,
            jobs: 64,
            size: 12,
            frames: 12,
            eps_values: vec![0.05, 0.1],
            connect_timeout: Duration::from_secs(1),
            io_timeout: Duration::from_secs(120),
        }
    }
}

/// What one replay run observed.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Requests sent (= [`LoadgenConfig::jobs`] unless the run errored
    /// out early).
    pub sent: u64,
    /// `200` responses (job solved and delivered).
    pub ok: u64,
    /// `429` admission-control rejections.
    pub rejected_429: u64,
    /// Other HTTP error responses (`400`, `503`, …).
    pub failed_other: u64,
    /// Requests that died at the socket level (no HTTP response).
    pub io_errors: u64,
    /// Wall-clock time of the whole replay.
    pub wall: Duration,
    /// `200` responses per second of wall time.
    pub throughput: f64,
    /// `429` responses / requests sent.
    pub rate_429: f64,
    /// Median latency over answered requests (bucket upper bound).
    pub p50: Duration,
    /// 99th-percentile latency over answered requests.
    pub p99: Duration,
}

impl LoadReport {
    /// One-line human rendering (printed by the CLI and bench arms).
    pub fn render(&self) -> String {
        format!(
            "{} sent: {} ok / {} busy(429) / {} failed / {} io errors in {:.2?} \
             ({:.1} jobs/s, 429 rate {:.3}, p50 {:.1?}, p99 {:.1?})",
            self.sent,
            self.ok,
            self.rejected_429,
            self.failed_other,
            self.io_errors,
            self.wall,
            self.throughput,
            self.rate_429,
            self.p50,
            self.p99
        )
    }

    /// The report as a `BENCH_gateway.json` row fragment.
    pub fn json(&self) -> Json {
        Json::obj(vec![
            ("sent", Json::num(self.sent as f64)),
            ("ok", Json::num(self.ok as f64)),
            ("rejected_429", Json::num(self.rejected_429 as f64)),
            ("failed_other", Json::num(self.failed_other as f64)),
            ("io_errors", Json::num(self.io_errors as f64)),
            ("wall_ms", Json::num(self.wall.as_secs_f64() * 1e3)),
            ("throughput_jobs_per_sec", Json::num(self.throughput)),
            ("rate_429", Json::num(self.rate_429)),
            ("p50_us", Json::num(self.p50.as_micros() as f64)),
            ("p99_us", Json::num(self.p99.as_micros() as f64)),
        ])
    }
}

/// Run one replay: encode the workload once, fan it out over
/// `config.clients` threads, and aggregate the outcome counters. The
/// job list is cycled when `config.jobs` exceeds it — cycling is what
/// makes warm-cache behavior visible, since repeats share fingerprints
/// with their first occurrence.
pub fn run(config: &LoadgenConfig) -> Result<LoadReport> {
    let addr: SocketAddr = config
        .addr
        .to_socket_addrs()
        .map_err(|e| Error::Coordinator(format!("loadgen target '{}': {e}", config.addr)))?
        .next()
        .ok_or_else(|| {
            Error::Coordinator(format!("loadgen target '{}' resolved to no address", config.addr))
        })?;
    let bodies: Vec<Vec<u8>> =
        pairwise_jobs(config.size, config.frames, &config.eps_values)
            .iter()
            .map(|job| codec::distance_job_json(job).to_string_compact().into_bytes())
            .collect();
    if bodies.is_empty() {
        return Err(Error::Coordinator("loadgen workload is empty".into()));
    }

    let cursor = AtomicUsize::new(0);
    let ok = AtomicU64::new(0);
    let rejected = AtomicU64::new(0);
    let failed = AtomicU64::new(0);
    let io_errors = AtomicU64::new(0);
    let latency = LatencyHistogram::new();
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..config.clients.max(1) {
            scope.spawn(|| loop {
                let k = cursor.fetch_add(1, Ordering::Relaxed);
                if k >= config.jobs {
                    return;
                }
                let body = &bodies[k % bodies.len()];
                let sent_at = Instant::now();
                match client::request(
                    addr,
                    "POST",
                    "/solve",
                    Some(body),
                    config.connect_timeout,
                    config.io_timeout,
                ) {
                    Ok(response) => {
                        latency.record(sent_at.elapsed());
                        match response.status {
                            200 => ok.fetch_add(1, Ordering::Relaxed),
                            429 => rejected.fetch_add(1, Ordering::Relaxed),
                            _ => failed.fetch_add(1, Ordering::Relaxed),
                        };
                    }
                    Err(_) => {
                        io_errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    let wall = t0.elapsed();
    let sent = config.jobs as u64;
    let ok = ok.into_inner();
    let rejected_429 = rejected.into_inner();
    Ok(LoadReport {
        sent,
        ok,
        rejected_429,
        failed_other: failed.into_inner(),
        io_errors: io_errors.into_inner(),
        wall,
        throughput: ok as f64 / wall.as_secs_f64().max(1e-9),
        rate_429: rejected_429 as f64 / sent.max(1) as f64,
        p50: latency.quantile(0.5),
        p99: latency.quantile(0.99),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use crate::net::gateway::spawn_backends;

    #[test]
    fn replays_against_a_live_gateway_and_counts_outcomes() {
        let mut backends = spawn_backends(
            1,
            &CoordinatorConfig { workers: 2, shards: 1, ..CoordinatorConfig::default() },
        )
        .unwrap();
        let report = run(&LoadgenConfig {
            addr: backends[0].local_addr().to_string(),
            clients: 2,
            jobs: 6,
            size: 6,
            frames: 6,
            eps_values: vec![0.1],
            ..LoadgenConfig::default()
        })
        .unwrap();
        assert_eq!(report.sent, 6);
        assert_eq!(report.ok, 6, "{}", report.render());
        assert_eq!(report.io_errors, 0);
        assert!(report.throughput > 0.0);
        assert!(report.p50 <= report.p99);
        // The JSON fragment carries every counter the schema check
        // asserts on.
        let row = report.json();
        for key in ["sent", "ok", "rejected_429", "rate_429", "p50_us", "p99_us"] {
            assert!(row.get(key).is_some(), "{key}");
        }
        backends[0].drain();
    }

    #[test]
    fn unresolvable_target_is_a_loud_error() {
        let err = run(&LoadgenConfig {
            addr: "not-an-address".to_string(),
            ..LoadgenConfig::default()
        });
        assert!(err.is_err());
    }
}
