//! `lint.toml` — per-rule allowlists for the contract-lint pass.
//!
//! The format is a deliberately tiny TOML subset (the offline image has
//! no TOML crate): one `[allow]` table whose keys are rule ids and
//! whose values are arrays of path strings. A listed path exempts that
//! file from that rule entirely — reach for it only when a pragma
//! cannot express the exemption (e.g. feature-gated code that CI never
//! builds); prefer `// lint: allow(rule, "reason")` at the call site.
//!
//! ```toml
//! # Paths are matched as path suffixes relative to the lint root.
//! [allow]
//! lock-unwrap = ["runtime/registry.rs"]
//! ```

use std::collections::BTreeMap;

/// Parsed `lint.toml`: rule id → exempted path suffixes.
#[derive(Debug, Default, Clone)]
pub struct LintConfig {
    allow: BTreeMap<String, Vec<String>>,
}

impl LintConfig {
    /// An empty config: no allowlists, every rule applies everywhere.
    pub fn empty() -> Self {
        Self::default()
    }

    /// Parse the `lint.toml` subset described in the module docs.
    /// Unknown sections, malformed entries, and unknown rule ids are
    /// errors — a typo in an allowlist must not silently allow nothing.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut allow: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut in_allow = false;
        for (idx, raw) in text.lines().enumerate() {
            let line_no = idx + 1;
            let line = strip_toml_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(section) = line.strip_prefix('[') {
                let section = section
                    .strip_suffix(']')
                    .ok_or_else(|| format!("lint.toml:{line_no}: unterminated section header"))?;
                if section != "allow" {
                    return Err(format!(
                        "lint.toml:{line_no}: unknown section [{section}] (only [allow] exists)"
                    ));
                }
                in_allow = true;
                continue;
            }
            if !in_allow {
                return Err(format!("lint.toml:{line_no}: entry outside the [allow] section"));
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("lint.toml:{line_no}: expected `rule-id = [\"path\"]`"))?;
            let rule = key.trim();
            if !super::rules::RULES.iter().any(|r| r.id == rule) {
                return Err(format!(
                    "lint.toml:{line_no}: unknown rule id '{rule}' (see `repro lint --list-rules`)"
                ));
            }
            let paths = parse_string_array(value.trim())
                .map_err(|e| format!("lint.toml:{line_no}: {e}"))?;
            allow.entry(rule.to_string()).or_default().extend(paths);
        }
        Ok(Self { allow })
    }

    /// Whether `rule` is allowlisted for `path` (both relative to the
    /// lint root, forward slashes). Entries match as path suffixes so
    /// the config works whether the root is `rust/src` or `src`.
    pub fn allows(&self, rule: &str, path: &str) -> bool {
        self.allow.get(rule).is_some_and(|paths| {
            paths
                .iter()
                .any(|p| path == p || path.ends_with(&format!("/{p}")))
        })
    }
}

/// Drop a `#` comment, respecting `"`-quoted strings.
fn strip_toml_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, b) in line.bytes().enumerate() {
        match b {
            b'"' => in_str = !in_str,
            b'#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse `["a", "b"]` into its strings.
fn parse_string_array(value: &str) -> Result<Vec<String>, String> {
    let inner = value
        .strip_prefix('[')
        .and_then(|v| v.strip_suffix(']'))
        .ok_or_else(|| "expected an array of strings".to_string())?;
    let mut out = Vec::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let s = part
            .strip_prefix('"')
            .and_then(|p| p.strip_suffix('"'))
            .ok_or_else(|| format!("expected a quoted string, got `{part}`"))?;
        out.push(s.to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_allow_section_and_matches_suffixes() {
        let cfg = LintConfig::parse(
            "# comment\n[allow]\nlock-unwrap = [\"runtime/registry.rs\"] # gated\n",
        )
        .expect("config parses");
        assert!(cfg.allows("lock-unwrap", "runtime/registry.rs"));
        assert!(cfg.allows("lock-unwrap", "src/runtime/registry.rs"));
        assert!(!cfg.allows("lock-unwrap", "coordinator/shard.rs"));
        assert!(!cfg.allows("unordered-iter", "runtime/registry.rs"));
    }

    #[test]
    fn rejects_unknown_rule_ids_and_sections() {
        assert!(LintConfig::parse("[allow]\nno-such-rule = [\"x.rs\"]\n").is_err());
        assert!(LintConfig::parse("[deny]\n").is_err());
        assert!(LintConfig::parse("lock-unwrap = [\"x.rs\"]\n").is_err());
    }

    #[test]
    fn rejects_malformed_arrays() {
        assert!(LintConfig::parse("[allow]\nlock-unwrap = \"x.rs\"\n").is_err());
        assert!(LintConfig::parse("[allow]\nlock-unwrap = [x.rs]\n").is_err());
    }

    #[test]
    fn empty_config_allows_nothing() {
        assert!(!LintConfig::empty().allows("lock-unwrap", "a.rs"));
    }
}
