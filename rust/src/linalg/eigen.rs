//! Eigen solvers: power iteration with deflation (large matrices, top-k
//! eigenpairs / spectral norms) and a cyclic Jacobi solver for the small
//! symmetric cores produced by the Nyström factorization.

use super::{dot, norm2, Mat};
use crate::rng::Rng;

/// Largest-magnitude eigenvalue and eigenvector of a symmetric matrix by
/// power iteration. Returns `(lambda, v)` with `||v||_2 = 1`.
pub fn power_iteration(a: &Mat, max_iters: usize, tol: f64, rng: &mut Rng) -> (f64, Vec<f64>) {
    assert_eq!(a.rows(), a.cols(), "power iteration needs a square matrix");
    let n = a.rows();
    let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let nv = norm2(&v).max(f64::MIN_POSITIVE);
    v.iter_mut().for_each(|x| *x /= nv);
    let mut lambda = 0.0;
    for _ in 0..max_iters {
        let mut w = a.matvec(&v);
        let nw = norm2(&w);
        if nw <= f64::MIN_POSITIVE {
            return (0.0, v);
        }
        w.iter_mut().for_each(|x| *x /= nw);
        let new_lambda = dot(&w, &a.matvec(&w));
        let delta = (new_lambda - lambda).abs();
        v = w;
        lambda = new_lambda;
        if delta <= tol * lambda.abs().max(1.0) {
            break;
        }
    }
    (lambda, v)
}

/// Spectral norm (largest singular value). For a symmetric matrix this is
/// `|lambda_max|`; in general we run power iteration on `A^T A` implicitly.
pub fn spectral_norm(a: &Mat, max_iters: usize, tol: f64, rng: &mut Rng) -> f64 {
    let n = a.cols();
    let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let nv = norm2(&v).max(f64::MIN_POSITIVE);
    v.iter_mut().for_each(|x| *x /= nv);
    let mut sigma2 = 0.0;
    for _ in 0..max_iters {
        let av = a.matvec(&v);
        let mut w = a.matvec_t(&av); // A^T A v
        let nw = norm2(&w);
        if nw <= f64::MIN_POSITIVE {
            return 0.0;
        }
        w.iter_mut().for_each(|x| *x /= nw);
        let aw = a.matvec(&w);
        let new_sigma2 = dot(&aw, &aw);
        let delta = (new_sigma2 - sigma2).abs();
        v = w;
        sigma2 = new_sigma2;
        if delta <= tol * sigma2.max(1.0) {
            break;
        }
    }
    sigma2.max(0.0).sqrt()
}

/// Top-`k` eigenpairs of a symmetric matrix via power iteration with
/// Hotelling deflation. Eigenvalues returned in decreasing |lambda|.
pub fn top_eigenpairs(
    a: &Mat,
    k: usize,
    max_iters: usize,
    tol: f64,
    rng: &mut Rng,
) -> Vec<(f64, Vec<f64>)> {
    assert_eq!(a.rows(), a.cols());
    let mut work = a.clone();
    let n = a.rows();
    let mut out = Vec::with_capacity(k);
    for _ in 0..k.min(n) {
        let (lambda, v) = power_iteration(&work, max_iters, tol, rng);
        // Deflate: A <- A - lambda v v^T.
        for i in 0..n {
            let vi = v[i];
            let row = work.row_mut(i);
            for (j, r) in row.iter_mut().enumerate() {
                *r -= lambda * vi * v[j];
            }
        }
        out.push((lambda, v));
    }
    out
}

/// Cyclic Jacobi eigendecomposition for small symmetric matrices.
///
/// Returns `(eigenvalues, eigenvectors)` where `eigenvectors.row(k)` is
/// the eigenvector for `eigenvalues[k]`, sorted by decreasing value.
/// Cost O(n^3) per sweep — intended for the r×r Nyström core (r ≤ ~500).
pub fn jacobi_eigen(a: &Mat, max_sweeps: usize, tol: f64) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows(), a.cols());
    let n = a.rows();
    let mut m = a.clone();
    // v starts as identity; rows of the final v^T are eigenvectors.
    let mut v = Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 });
    for _ in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m.get(i, j) * m.get(i, j);
            }
        }
        if off.sqrt() <= tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m.get(p, q);
                if apq.abs() <= f64::MIN_POSITIVE {
                    continue;
                }
                let app = m.get(p, p);
                let aqq = m.get(q, q);
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p and q of m.
                for k in 0..n {
                    let mkp = m.get(k, p);
                    let mkq = m.get(k, q);
                    m.set(k, p, c * mkp - s * mkq);
                    m.set(k, q, s * mkp + c * mkq);
                }
                for k in 0..n {
                    let mpk = m.get(p, k);
                    let mqk = m.get(q, k);
                    m.set(p, k, c * mpk - s * mqk);
                    m.set(q, k, s * mpk + c * mqk);
                }
                for k in 0..n {
                    let vkp = v.get(k, p);
                    let vkq = v.get(k, q);
                    v.set(k, p, c * vkp - s * vkq);
                    v.set(k, q, s * vkp + c * vkq);
                }
            }
        }
    }
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m.get(i, i), i)).collect();
    pairs.sort_by(|a, b| b.0.total_cmp(&a.0));
    let eigenvalues: Vec<f64> = pairs.iter().map(|(l, _)| *l).collect();
    let eigenvectors = Mat::from_fn(n, n, |k, i| v.get(i, pairs[k].1));
    (eigenvalues, eigenvectors)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sym_from_eigs(eigs: &[f64], rng: &mut Rng) -> Mat {
        // Build Q diag(eigs) Q^T with a random orthogonal Q (Gram-Schmidt).
        let n = eigs.len();
        let mut q: Vec<Vec<f64>> = Vec::new();
        while q.len() < n {
            let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            for u in &q {
                let c = dot(&v, u);
                for (x, y) in v.iter_mut().zip(u) {
                    *x -= c * y;
                }
            }
            let nv = norm2(&v);
            if nv > 1e-8 {
                v.iter_mut().for_each(|x| *x /= nv);
                q.push(v);
            }
        }
        Mat::from_fn(n, n, |i, j| {
            (0..n).map(|k| q[k][i] * eigs[k] * q[k][j]).sum()
        })
    }

    #[test]
    fn power_iteration_finds_dominant() {
        let mut rng = Rng::seed_from(1);
        let a = sym_from_eigs(&[5.0, 2.0, 1.0, 0.5], &mut rng);
        let (lambda, _) = power_iteration(&a, 500, 1e-12, &mut rng);
        assert!((lambda - 5.0).abs() < 1e-6, "lambda {lambda}");
    }

    #[test]
    fn spectral_norm_of_diag() {
        let mut rng = Rng::seed_from(2);
        let a = Mat::from_fn(3, 3, |i, j| if i == j { [3.0, -7.0, 1.0][i] } else { 0.0 });
        let s = spectral_norm(&a, 500, 1e-12, &mut rng);
        assert!((s - 7.0).abs() < 1e-6, "sigma {s}");
    }

    #[test]
    fn spectral_norm_rectangular() {
        let mut rng = Rng::seed_from(3);
        // A = [[1, 0], [0, 2], [0, 0]]; singular values {2, 1}.
        let a = Mat::from_vec(3, 2, vec![1., 0., 0., 2., 0., 0.]);
        let s = spectral_norm(&a, 500, 1e-12, &mut rng);
        assert!((s - 2.0).abs() < 1e-6);
    }

    #[test]
    fn top_eigenpairs_ordered() {
        let mut rng = Rng::seed_from(4);
        let a = sym_from_eigs(&[4.0, 3.0, 0.25, 0.1], &mut rng);
        let pairs = top_eigenpairs(&a, 2, 1000, 1e-13, &mut rng);
        assert!((pairs[0].0 - 4.0).abs() < 1e-5);
        assert!((pairs[1].0 - 3.0).abs() < 1e-4);
    }

    #[test]
    fn jacobi_recovers_spectrum() {
        let mut rng = Rng::seed_from(5);
        let eigs = [6.0, 3.5, 1.0, -0.5, 0.0];
        let a = sym_from_eigs(&eigs, &mut rng);
        let (vals, vecs) = jacobi_eigen(&a, 50, 1e-14);
        let mut want = eigs.to_vec();
        want.sort_by(|x, y| y.partial_cmp(x).unwrap());
        for (got, want) in vals.iter().zip(&want) {
            assert!((got - want).abs() < 1e-9, "got {got} want {want}");
        }
        // Check A v = lambda v for the top eigenpair.
        let v0: Vec<f64> = (0..5).map(|j| vecs.get(0, j)).collect();
        let av = a.matvec(&v0);
        for (x, y) in av.iter().zip(&v0) {
            assert!((x - vals[0] * y).abs() < 1e-8);
        }
    }
}
