//! Boundary fixture for the wall-clock rule: serving-layer code that
//! legitimately reads clocks and machine shape. Under a `net/` path
//! this must lint clean — timeouts, accept-loop polls, and
//! thread-count defaults are operational concerns that cannot affect
//! any solver result. The SAME text under `engine/` must fire once per
//! token line: inside a result-affecting module these reads make
//! outputs depend on when/where the run happened.

use std::time::Instant;

/// Stamp the start of a connection, for read-timeout enforcement.
pub fn connection_started() -> Instant {
    Instant::now()
}

/// Default handler-thread cap: one per core, floor of 4.
pub fn default_connection_cap() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}
