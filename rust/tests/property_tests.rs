//! Property-based tests over randomized inputs (hand-rolled generator
//! loops — the offline image has no proptest). Each property runs many
//! random cases from seeded streams; failures print the seed for
//! reproduction. The per-property case count defaults to 24 and is
//! raised via the `PROPTEST_CASES` env var (the CI parity/property wall
//! runs at higher intensity).

use spar_sink::api::{self, Method, OtProblem, SolverSpec};
use spar_sink::linalg::{l1_diff, Mat};
use spar_sink::metrics::s0;
use spar_sink::ot::cost::{
    euclidean, gibbs_kernel, sq_euclidean, sq_euclidean_cost, wfr_cost, wfr_cost_from_distance,
    TILE_COLS, TILE_ROWS,
};
use spar_sink::ot::log_barycenter::log_ibp_barycenter;
use spar_sink::ot::objective::{kl_divergence, plan_marginals_dense};
use spar_sink::ot::sinkhorn::{sinkhorn_scalings, transport_plan, SinkhornParams};
use spar_sink::rng::Rng;
use spar_sink::solvers::backend::ScalingBackend;
use spar_sink::solvers::sparse_loop::{sparse_ot_objective, sparse_scalings};
use spar_sink::sparse::{poisson_sparsify_ot, poisson_sparsify_uot, CsrMatrix};

const CASES: usize = 24;

/// Case count, overridable via `PROPTEST_CASES` (proptest's spelling, so
/// the CI matrix leg and local runs share one knob).
fn cases() -> usize {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(CASES)
}

fn random_instance(rng: &mut Rng, n_max: usize) -> (Mat, Mat, Vec<f64>, Vec<f64>) {
    let n = 4 + rng.gen_range(n_max - 4);
    let d = 1 + rng.gen_range(4);
    let pts: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..d).map(|_| rng.uniform()).collect())
        .collect();
    let cost = sq_euclidean_cost(&pts, &pts);
    let eps = 0.05 + rng.uniform() * 0.3;
    let kernel = gibbs_kernel(&cost, eps);
    let mk = |rng: &mut Rng| -> Vec<f64> {
        let raw: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.05).collect();
        let s: f64 = raw.iter().sum();
        raw.iter().map(|x| x / s).collect()
    };
    let a = mk(rng);
    let b = mk(rng);
    (kernel, cost, a, b)
}

/// Property: the converged Sinkhorn plan satisfies both marginals.
#[test]
fn prop_sinkhorn_plan_feasible() {
    let mut master = Rng::seed_from(0x1001);
    for case in 0..cases() {
        let seed = master.next_u64();
        let mut rng = Rng::seed_from(seed);
        let (kernel, _cost, a, b) = random_instance(&mut rng, 48);
        let params = SinkhornParams { delta: 1e-9, max_iters: 4000, strict: false };
        let (u, v, _, _, converged) =
            sinkhorn_scalings(&kernel, &a, &b, 1.0, &params).unwrap();
        if !converged {
            continue; // tough eps draw; feasibility only guaranteed at the fixed point
        }
        let plan = transport_plan(&kernel, &u, &v);
        let rows = plan.row_sums();
        let cols = plan.col_sums();
        assert!(
            l1_diff(&rows, &a) < 1e-6 && l1_diff(&cols, &b) < 1e-6,
            "case {case} seed {seed}: marginal violation {} / {}",
            l1_diff(&rows, &a),
            l1_diff(&cols, &b)
        );
    }
}

/// Property: the sparse loop on a FULL sketch reproduces the dense loop.
#[test]
fn prop_sparse_loop_equals_dense_on_full_support() {
    let mut master = Rng::seed_from(0x1002);
    for case in 0..cases() {
        let seed = master.next_u64();
        let mut rng = Rng::seed_from(seed);
        let (kernel, cost, a, b) = random_instance(&mut rng, 32);
        let n = a.len();
        let rows = (0..n)
            .map(|i| {
                (0..n)
                    .map(|j| (j as u32, kernel.get(i, j), cost.get(i, j)))
                    .collect()
            })
            .collect();
        let sketch = CsrMatrix::from_rows(n, n, rows);
        let params = SinkhornParams { delta: 1e-8, max_iters: 500, strict: false };
        let (u1, v1, ..) = sparse_scalings(&sketch, &a, &b, 1.0, &params).unwrap();
        let (u2, v2, ..) = sinkhorn_scalings(&kernel, &a, &b, 1.0, &params).unwrap();
        for (x, y) in u1.iter().zip(&u2).chain(v1.iter().zip(&v2)) {
            assert!((x - y).abs() < 1e-9, "case {case} seed {seed}");
        }
    }
}

/// Property: E[nnz] of the Poisson sketch never exceeds the budget s
/// (Section 3.2's inequality), within 5 sigma of binomial noise.
#[test]
fn prop_sketch_respects_budget() {
    let mut master = Rng::seed_from(0x1003);
    for case in 0..cases() {
        let seed = master.next_u64();
        let mut rng = Rng::seed_from(seed);
        let (kernel, cost, a, b) = random_instance(&mut rng, 64);
        let n = a.len();
        let s = (2.0 + rng.uniform() * 14.0) * s0(n);
        let (sketch, stats) = poisson_sparsify_ot(
            |i, j| kernel.get(i, j),
            |i, j| cost.get(i, j),
            &a,
            &b,
            s,
            1.0,
            &mut rng,
        )
        .unwrap();
        let sigma = s.sqrt();
        assert!(
            (sketch.nnz() as f64) <= s + 5.0 * sigma,
            "case {case} seed {seed}: nnz {} budget {s}",
            sketch.nnz()
        );
        assert_eq!(stats.nnz, sketch.nnz());
    }
}

/// Property: every stored sketch entry equals K_ij / p*_ij with
/// p*_ij ≤ 1, i.e. entries only ever INFLATE (never shrink) and zero
/// kernel entries never appear.
#[test]
fn prop_sketch_entries_are_inflated_kernel_values() {
    let mut master = Rng::seed_from(0x1004);
    for case in 0..cases() {
        let seed = master.next_u64();
        let mut rng = Rng::seed_from(seed);
        let (kernel, cost, a, b) = random_instance(&mut rng, 48);
        let s = 8.0 * s0(a.len());
        let (sketch, _) = poisson_sparsify_ot(
            |i, j| kernel.get(i, j),
            |i, j| cost.get(i, j),
            &a,
            &b,
            s,
            0.7,
            &mut rng,
        )
        .unwrap();
        for (i, j, k, c) in sketch.iter() {
            let k_true = kernel.get(i, j);
            assert!(k_true > 0.0, "case {case} seed {seed}: zero-kernel entry stored");
            assert!(
                k >= k_true - 1e-12,
                "case {case} seed {seed}: entry ({i},{j}) shrank: {k} < {k_true}"
            );
            assert_eq!(c, cost.get(i, j));
        }
    }
}

/// Property: the UOT probability (Eq. 11) never samples blocked (K = 0)
/// WFR pairs, for random truncation radii.
#[test]
fn prop_uot_sampling_respects_wfr_support() {
    let mut master = Rng::seed_from(0x1005);
    for case in 0..cases() {
        let seed = master.next_u64();
        let mut rng = Rng::seed_from(seed);
        let n = 8 + rng.gen_range(40);
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|_| vec![rng.uniform() * 4.0, rng.uniform() * 4.0])
            .collect();
        let eta = 0.3 + rng.uniform();
        let eps = 0.05 + rng.uniform() * 0.2;
        let a: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.1).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.1).collect();
        let dist =
            |i: usize, j: usize| spar_sink::ot::cost::euclidean(&pts[i], &pts[j]);
        let result = poisson_sparsify_uot(
            |i, j| spar_sink::ot::cost::wfr_kernel_from_distance(dist(i, j), eta, eps),
            |i, j| wfr_cost_from_distance(dist(i, j), eta),
            &a,
            &b,
            1.0,
            eps,
            6.0 * s0(n),
            1.0,
            &mut rng,
        );
        let Ok((sketch, _)) = result else { continue };
        let cutoff = std::f64::consts::PI * eta;
        for (i, j, _, c) in sketch.iter() {
            assert!(
                dist(i, j) < cutoff,
                "case {case} seed {seed}: blocked pair sampled (d = {})",
                dist(i, j)
            );
            assert!(c.is_finite());
        }
    }
}

/// Property: generalized KL is non-negative and zero iff equal.
#[test]
fn prop_kl_nonnegative() {
    let mut master = Rng::seed_from(0x1006);
    for case in 0..200 {
        let seed = master.next_u64();
        let mut rng = Rng::seed_from(seed);
        let n = 1 + rng.gen_range(20);
        let x: Vec<f64> = (0..n).map(|_| rng.uniform() * 2.0).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.uniform() * 2.0 + 1e-9).collect();
        let kl = kl_divergence(&x, &y);
        assert!(kl >= -1e-12, "case {case} seed {seed}: KL {kl}");
        let self_kl = kl_divergence(&x, &x.iter().map(|v| v.max(1e-12)).collect::<Vec<_>>());
        assert!(self_kl.abs() < 1e-9, "case {case} seed {seed}");
    }
}

/// Property: the sparse OT objective is invariant under the (u*c, v/c)
/// scaling gauge.
#[test]
fn prop_objective_gauge_invariance() {
    let mut master = Rng::seed_from(0x1007);
    for case in 0..cases() {
        let seed = master.next_u64();
        let mut rng = Rng::seed_from(seed);
        let (kernel, cost, a, b) = random_instance(&mut rng, 32);
        let s = 8.0 * s0(a.len());
        let (sketch, _) = poisson_sparsify_ot(
            |i, j| kernel.get(i, j),
            |i, j| cost.get(i, j),
            &a,
            &b,
            s,
            1.0,
            &mut rng,
        )
        .unwrap();
        let params = SinkhornParams::default();
        let Ok((u, v, ..)) = sparse_scalings(&sketch, &a, &b, 1.0, &params) else { continue };
        let o1 = sparse_ot_objective(&sketch, &u, &v, 0.1);
        let c = 0.25 + rng.uniform() * 8.0;
        let uc: Vec<f64> = u.iter().map(|x| x * c).collect();
        let vc: Vec<f64> = v.iter().map(|x| x / c).collect();
        let o2 = sparse_ot_objective(&sketch, &uc, &vc, 0.1);
        assert!(
            (o1 - o2).abs() < 1e-9 * o1.abs().max(1.0),
            "case {case} seed {seed}: {o1} vs {o2}"
        );
    }
}

/// Property: UOT plan mass interpolates monotonically in lambda toward
/// the geometric-mean compromise for imbalanced inputs.
#[test]
fn prop_uot_mass_monotone_in_lambda() {
    let mut master = Rng::seed_from(0x1008);
    for case in 0..8 {
        let seed = master.next_u64();
        let mut rng = Rng::seed_from(seed);
        let (kernel, _cost, a0, b0) = random_instance(&mut rng, 24);
        let a: Vec<f64> = a0.iter().map(|x| x * 3.0).collect();
        let b: Vec<f64> = b0.iter().map(|x| x * 1.5).collect();
        let params = SinkhornParams { delta: 1e-9, max_iters: 4000, strict: false };
        let mass = |lam: f64, rng_params: &SinkhornParams| -> f64 {
            let rho = lam / (lam + 0.1);
            let (u, v, ..) = sinkhorn_scalings(&kernel, &a, &b, rho, rng_params).unwrap();
            let (row, _) = plan_marginals_dense(&kernel, &u, &v);
            row.iter().sum()
        };
        let m_small = mass(0.05, &params);
        let m_large = mass(50.0, &params);
        assert!(
            m_small > m_large,
            "case {case} seed {seed}: mass not decreasing ({m_small} -> {m_large})"
        );
    }
}

/// Random fixed-support barycenter instance: shared support in [0,1]^d,
/// 2-4 strictly positive marginals, random simplex weights, and ε drawn
/// log-uniformly across FOUR decades — deliberately straddling
/// `DEFAULT_LOG_EPS_THRESHOLD` so sub-threshold draws exercise the log
/// engine where the multiplicative kernel underflows.
fn random_barycenter(
    rng: &mut Rng,
) -> (Mat, Vec<Vec<f64>>, Vec<f64>, f64) {
    let n = 8 + rng.gen_range(24);
    let d = 1 + rng.gen_range(2);
    let pts: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..d).map(|_| rng.uniform()).collect())
        .collect();
    let cost = sq_euclidean_cost(&pts, &pts);
    let m = 2 + rng.gen_range(3);
    let marginals: Vec<Vec<f64>> = (0..m)
        .map(|_| {
            let raw: Vec<f64> = (0..n).map(|_| rng.uniform() + 1e-3).collect();
            let s: f64 = raw.iter().sum();
            raw.iter().map(|x| x / s).collect()
        })
        .collect();
    let raw_w: Vec<f64> = (0..m).map(|_| rng.uniform() + 0.05).collect();
    let ws: f64 = raw_w.iter().sum();
    let weights: Vec<f64> = raw_w.iter().map(|x| x / ws).collect();
    // log-uniform over [1e-5, 0.1]: roughly half the draws land below
    // the 2e-3 auto threshold.
    let eps = 10f64.powf(-5.0 + rng.uniform() * 4.0);
    (cost, marginals, weights, eps)
}

/// Property: the log-domain IBP barycenter q is a probability vector —
/// non-negative, finite, summing to 1 — across random marginals, costs
/// and ε, INCLUDING sub-threshold ε, for both the dense engine and the
/// Spar-IBP sketch path, converged or not.
#[test]
fn prop_log_ibp_q_is_probability_vector() {
    let mut master = Rng::seed_from(0x1009);
    for case in 0..cases() {
        let seed = master.next_u64();
        let mut rng = Rng::seed_from(seed);
        let (cost, marginals, weights, eps) = random_barycenter(&mut rng);
        let problem = OtProblem::barycenter(cost, marginals, weights, eps);
        // Alternate dense IBP and Spar-IBP so both log engines face the
        // random-instance wall.
        let method = if case % 2 == 0 { Method::Sinkhorn } else { Method::SparIbp };
        let spec = SolverSpec::new(method)
            .with_budget(25.0)
            .with_seed(seed)
            .with_backend(ScalingBackend::LogDomain)
            .with_max_iters(300);
        let sol = match api::solve(&problem, &spec) {
            Ok(s) => s,
            // A sparse draw on a tiny instance can empty every row of a
            // sketch; refusing with a numerical error is the correct
            // behavior — the property is that any RETURNED q is a
            // probability vector.
            Err(spar_sink::Error::Numerical(_)) if method == Method::SparIbp => continue,
            Err(e) => panic!("case {case} seed {seed} eps {eps:.2e}: {e}"),
        };
        let q = sol.barycenter.as_ref().expect("barycenter q");
        assert!(
            q.iter().all(|x| x.is_finite() && *x >= 0.0),
            "case {case} seed {seed} eps {eps:.2e}: q has bad entries"
        );
        let mass: f64 = q.iter().sum();
        assert!(
            (mass - 1.0).abs() < 1e-9,
            "case {case} seed {seed} eps {eps:.2e}: mass {mass}"
        );
    }
}

/// Property: the log-domain IBP barycenter is equivariant under a
/// relabeling of the support points: permuting the cost matrix rows and
/// columns together with every marginal permutes q the same way. Fixed
/// iteration count on both runs, so the iterates correspond exactly
/// (up to LSE summation-order rounding).
#[test]
fn prop_log_ibp_permutation_equivariant() {
    let mut master = Rng::seed_from(0x100A);
    for case in 0..cases() {
        let seed = master.next_u64();
        let mut rng = Rng::seed_from(seed);
        let (cost, marginals, weights, eps) = random_barycenter(&mut rng);
        let n = cost.rows();
        // Random permutation via Fisher-Yates on the index vector.
        let mut perm: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = rng.gen_range(i + 1);
            perm.swap(i, j);
        }
        let cost_p = Mat::from_fn(n, n, |i, j| cost.get(perm[i], perm[j]));
        let marginals_p: Vec<Vec<f64>> = marginals
            .iter()
            .map(|b| (0..n).map(|i| b[perm[i]]).collect())
            .collect();
        let params = SinkhornParams { delta: 0.0, max_iters: 120, strict: false };
        let base = log_ibp_barycenter(&cost, &marginals, &weights, eps, &params).unwrap();
        let permuted =
            log_ibp_barycenter(&cost_p, &marginals_p, &weights, eps, &params).unwrap();
        let sup = (0..n)
            .map(|i| (permuted.q[i] - base.q[perm[i]]).abs())
            .fold(0.0f64, f64::max);
        assert!(
            sup < 1e-8,
            "case {case} seed {seed} eps {eps:.2e}: equivariance sup gap {sup}"
        );
    }
}

/// Property: the cache-tiled dense builders (`sq_euclidean_cost`,
/// `wfr_cost`, `gibbs_kernel`) are bitwise-equal to a naive scalar
/// row sweep over every shape, with the sampled sizes concentrated on
/// the tile boundaries (tile−1, tile, tile+1) where blocking bugs
/// live. Rectangular shapes included. Thread-count invariance of the
/// same builders (`SPAR_SINK_THREADS` ∈ {1, 3, default}) is pinned by
/// the single-binary `thread_determinism` wall, which owns that env
/// var.
#[test]
fn prop_tiled_builders_bitwise_equal_naive_reference() {
    let mut master = Rng::seed_from(0x100B);
    // Tile-boundary biased size draw: t−1, t, t+1, or anything in
    // [1, 2t) covering sub-tile, exact-tile, and multi-tile extents.
    let boundary = |t: usize, rng: &mut Rng| match rng.gen_range(4) {
        0 => t - 1,
        1 => t,
        2 => t + 1,
        _ => 1 + rng.gen_range(2 * t),
    };
    for case in 0..cases() {
        let seed = master.next_u64();
        let mut rng = Rng::seed_from(seed);
        let n = boundary(TILE_ROWS, &mut rng);
        let m = boundary(TILE_COLS, &mut rng);
        let d = 1 + rng.gen_range(3);
        let pt = |rng: &mut Rng| -> Vec<f64> { (0..d).map(|_| rng.uniform()).collect() };
        let xs: Vec<Vec<f64>> = (0..n).map(|_| pt(&mut rng)).collect();
        let ys: Vec<Vec<f64>> = (0..m).map(|_| pt(&mut rng)).collect();
        let check = |got: &Mat, want: &Mat, what: &str| {
            for (e, (a, b)) in got.as_slice().iter().zip(want.as_slice()).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "case {case} seed {seed} {what} {n}x{m}: entry {e} differs ({a} vs {b})"
                );
            }
        };
        let c = sq_euclidean_cost(&xs, &ys);
        let c_ref = Mat::from_fn(n, m, |i, j| sq_euclidean(&xs[i], &ys[j]));
        check(&c, &c_ref, "sq_euclidean_cost");
        let eta = 0.2 + rng.uniform();
        let w = wfr_cost(&xs, &ys, eta);
        let w_ref =
            Mat::from_fn(n, m, |i, j| wfr_cost_from_distance(euclidean(&xs[i], &ys[j]), eta));
        check(&w, &w_ref, "wfr_cost");
        let eps = 0.05 + rng.uniform() * 0.3;
        let g = gibbs_kernel(&w, eps);
        let g_ref = w_ref.map(|c| {
            if c.is_infinite() {
                0.0
            } else {
                (-c / eps).exp()
            }
        });
        check(&g, &g_ref, "gibbs_kernel");
    }
}
