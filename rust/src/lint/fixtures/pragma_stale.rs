//! Seeded violations (lint-pragma): a stale pragma whose rule no longer
//! fires below it, and a pragma naming an unknown rule.

/// Sums slices; the pragmas above and inside are the violations.
pub fn stable_sum(xs: &[f64]) -> f64 {
    // lint: allow(wall-clock, "this pragma is stale: nothing below reads a clock")
    let sum: f64 = xs.iter().sum();
    // lint: allow(no-such-rule, "unknown rule ids are themselves findings")
    sum
}
