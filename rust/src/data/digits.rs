//! Procedurally rendered digit glyphs — the MNIST substitution for the
//! barycenter experiment (Appendix C.3, Fig. 12; see DESIGN.md §3).
//!
//! Each digit 0-9 is drawn from a 7-segment-like stroke skeleton with
//! Gaussian stroke thickness, then randomly rescaled (½×–2×) and
//! translated inside a larger grid with a bias towards corners, exactly
//! following the paper's preprocessing. Pixel values are normalized to
//! the simplex.

use crate::rng::Rng;

/// Stroke segments per digit on a [0,1]×[0,1] canvas (x right, y down).
/// Each stroke is a line segment (x0, y0, x1, y1).
fn skeleton(digit: u8) -> &'static [(f64, f64, f64, f64)] {
    // 7-segment layout corners.
    const TL: (f64, f64) = (0.25, 0.15);
    const TR: (f64, f64) = (0.75, 0.15);
    const ML: (f64, f64) = (0.25, 0.5);
    const MR: (f64, f64) = (0.75, 0.5);
    const BL: (f64, f64) = (0.25, 0.85);
    const BR: (f64, f64) = (0.75, 0.85);
    macro_rules! seg {
        ($a:ident, $b:ident) => {
            ($a.0, $a.1, $b.0, $b.1)
        };
    }
    const TOP: (f64, f64, f64, f64) = seg!(TL, TR);
    const MID: (f64, f64, f64, f64) = seg!(ML, MR);
    const BOT: (f64, f64, f64, f64) = seg!(BL, BR);
    const LT: (f64, f64, f64, f64) = seg!(TL, ML);
    const LB: (f64, f64, f64, f64) = seg!(ML, BL);
    const RT: (f64, f64, f64, f64) = seg!(TR, MR);
    const RB: (f64, f64, f64, f64) = seg!(MR, BR);
    match digit {
        0 => &[TOP, BOT, LT, LB, RT, RB],
        1 => &[RT, RB],
        2 => &[TOP, RT, MID, LB, BOT],
        3 => &[TOP, RT, MID, RB, BOT],
        4 => &[LT, MID, RT, RB],
        5 => &[TOP, LT, MID, RB, BOT],
        6 => &[TOP, LT, LB, MID, RB, BOT],
        7 => &[TOP, RT, RB],
        8 => &[TOP, MID, BOT, LT, LB, RT, RB],
        9 => &[TOP, MID, BOT, LT, RT, RB],
        _ => panic!("digit out of range"),
    }
}

/// Distance from point to segment.
fn seg_dist(px: f64, py: f64, seg: (f64, f64, f64, f64)) -> f64 {
    let (x0, y0, x1, y1) = seg;
    let (dx, dy) = (x1 - x0, y1 - y0);
    let len2 = dx * dx + dy * dy;
    let t = if len2 > 0.0 {
        (((px - x0) * dx + (py - y0) * dy) / len2).clamp(0.0, 1.0)
    } else {
        0.0
    };
    let (cx, cy) = (x0 + t * dx, y0 + t * dy);
    ((px - cx).powi(2) + (py - cy).powi(2)).sqrt()
}

/// Render a digit glyph on a `grid`×`grid` canvas.
///
/// * `scale` — glyph size relative to the grid (the paper rescales
///   between half and double of the 28px base inside a 64px grid).
/// * `(ox, oy)` — top-left offset of the glyph box in pixels.
/// Returns a normalized histogram (sums to 1).
pub fn render_digit(
    digit: u8,
    grid: usize,
    scale: f64,
    ox: f64,
    oy: f64,
    stroke: f64,
) -> Vec<f64> {
    let segs = skeleton(digit);
    let size = scale * grid as f64;
    let mut img = vec![0.0f64; grid * grid];
    for y in 0..grid {
        for x in 0..grid {
            // Map pixel into glyph-local [0,1] coordinates.
            let lx = (x as f64 - ox) / size;
            let ly = (y as f64 - oy) / size;
            if !(-0.2..=1.2).contains(&lx) || !(-0.2..=1.2).contains(&ly) {
                continue;
            }
            let d = segs
                .iter()
                .map(|&s| seg_dist(lx, ly, s))
                .fold(f64::INFINITY, f64::min);
            let sigma = stroke / size.max(1.0);
            let v = (-0.5 * (d / sigma).powi(2)).exp();
            // Cut the Gaussian tail: keeps glyphs crisp and sparse
            // (matching binarized MNIST density).
            if v > 5e-2 {
                img[y * grid + x] = v;
            }
        }
    }
    let total: f64 = img.iter().sum();
    assert!(total > 0.0, "glyph rendered empty");
    for v in img.iter_mut() {
        *v /= total;
    }
    img
}

/// The paper's randomized variant: random scale in [0.5, 2]× base,
/// random translation within the grid with a corner bias.
pub fn random_digit(digit: u8, grid: usize, rng: &mut Rng) -> Vec<f64> {
    let base = 28.0 / 64.0; // MNIST glyph inside the 64-grid
    let scale = base * (0.5 + 1.5 * rng.uniform());
    let size = scale * grid as f64;
    let max_off = (grid as f64 - size).max(0.0);
    // Corner bias: square the uniform draw and flip a corner coin.
    let off = |r: &mut Rng| -> f64 {
        let u = r.uniform();
        let edge = u * u * max_off;
        if r.bernoulli(0.5) {
            edge
        } else {
            max_off - edge
        }
    };
    let ox = off(rng);
    let oy = off(rng);
    render_digit(digit, grid, scale, ox, oy, 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_digits_render_normalized() {
        for d in 0..10u8 {
            let img = render_digit(d, 32, 0.8, 3.0, 3.0, 2.0);
            let s: f64 = img.iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "digit {d} sum {s}");
            let nnz = img.iter().filter(|&&v| v > 0.0).count();
            assert!(nnz > 20, "digit {d} too few pixels: {nnz}");
            assert!(nnz < 32 * 32 * 2 / 3, "digit {d} fills too much: {nnz}");
        }
    }

    #[test]
    fn digit_one_thinner_than_eight() {
        let one = render_digit(1, 32, 0.8, 3.0, 3.0, 2.0);
        let eight = render_digit(8, 32, 0.8, 3.0, 3.0, 2.0);
        let nnz = |im: &[f64]| im.iter().filter(|&&v| v > 1e-6).count();
        assert!(nnz(&one) < nnz(&eight));
    }

    #[test]
    fn random_digit_stays_in_grid() {
        let mut rng = Rng::seed_from(113);
        for _ in 0..20 {
            let img = random_digit(3, 48, &mut rng);
            let s: f64 = img.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn random_digits_differ() {
        let mut rng = Rng::seed_from(115);
        let a = random_digit(5, 48, &mut rng);
        let b = random_digit(5, 48, &mut rng);
        let diff: f64 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 0.1, "translated/rescaled copies should differ, diff {diff}");
    }
}
