//! Evaluation metrics used throughout the experiment harness.

/// Relative mean absolute error across replications:
/// `RMAE = (1/R) Σ |est_r − truth_r| / truth_r` (Section 5.1).
pub fn rmae(estimates: &[f64], truths: &[f64]) -> f64 {
    assert_eq!(estimates.len(), truths.len());
    assert!(!estimates.is_empty());
    estimates
        .iter()
        .zip(truths)
        .map(|(e, t)| (e - t).abs() / t.abs().max(f64::MIN_POSITIVE))
        .sum::<f64>()
        / estimates.len() as f64
}

/// Mean and (population) standard deviation.
pub fn mean_sd(xs: &[f64]) -> (f64, f64) {
    assert!(!xs.is_empty());
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// Standard error of the mean.
pub fn standard_error(xs: &[f64]) -> f64 {
    let (_, sd) = mean_sd(xs);
    sd / (xs.len() as f64).sqrt()
}

/// Median (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// L1 distance between two histograms (barycenter experiments, Fig. 11).
pub fn l1_distance(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len());
    p.iter().zip(q).map(|(a, b)| (a - b).abs()).sum()
}

/// Normalize a histogram to unit mass. The sketched multiplicative IBP
/// update does not renormalize, so barycenter comparisons are made
/// shape-to-shape through this ONE helper; a degenerate input (zero,
/// negative or non-finite mass) is returned unchanged rather than
/// amplified into huge or NaN values.
pub fn normalized_histogram(q: &[f64]) -> Vec<f64> {
    let mass: f64 = q.iter().sum();
    if mass > 0.0 && mass.is_finite() {
        q.iter().map(|x| x / mass).collect()
    } else {
        q.to_vec()
    }
}

/// The paper's ED-prediction error (Section 6):
/// `|1 − (t̂_ED − t_ES) / (t_ED − t_ES)|`.
pub fn ed_prediction_error(t_es: f64, t_ed: f64, t_ed_hat: f64) -> f64 {
    (1.0 - (t_ed_hat - t_es) / (t_ed - t_es)).abs()
}

/// s₀(n) = 10⁻³ · n · log⁴(n) — the paper's subsample-size unit
/// (Section 5.1, in the light of Theorem 1).
pub fn s0(n: usize) -> f64 {
    let n = n as f64;
    1e-3 * n * n.ln().powi(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmae_zero_for_exact() {
        assert_eq!(rmae(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
    }

    #[test]
    fn rmae_scale_invariant() {
        let r1 = rmae(&[1.1], &[1.0]);
        let r2 = rmae(&[110.0], &[100.0]);
        assert!((r1 - r2).abs() < 1e-12);
        assert!((r1 - 0.1).abs() < 1e-12);
    }

    #[test]
    fn mean_sd_known() {
        let (m, s) = mean_sd(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
    }

    #[test]
    fn ed_error_perfect_and_off() {
        assert_eq!(ed_prediction_error(10.0, 20.0, 20.0), 0.0);
        assert!((ed_prediction_error(10.0, 20.0, 15.0) - 0.5).abs() < 1e-12);
        // Overshoot is also penalized.
        assert!((ed_prediction_error(10.0, 20.0, 25.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn s0_matches_formula() {
        let n = 1000usize;
        let want = 1e-3 * 1000.0 * (1000.0f64).ln().powi(4);
        assert!((s0(n) - want).abs() < 1e-9);
    }
}
