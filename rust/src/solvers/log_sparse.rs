//! Log-domain stabilized sparse Sinkhorn: Algorithms 1/2 over a CSR
//! sketch, iterated on the dual potentials `(φ, ψ) = (ln u, ln v)`:
//!
//! ```text
//! φ_i ← ρ·(log a_i − LSE_j(ln K̃_ij + ψ_j))
//! ψ_j ← ρ·(log b_j − LSE_i(ln K̃_ij + φ_i))
//! ```
//!
//! with `ρ = 1` for OT and `ρ = λ/(λ+ε)` for UOT. The row/column
//! log-sum-exp runs over STORED entries only ([`CsrMatrix::row_lse`] /
//! [`CsrMatrix::col_lse`]), so the per-iteration cost is O(nnz) like the
//! multiplicative sparse loop — but no kernel entry ever underflows:
//! sketches built by the `_logk` sparsifiers carry exact `ln K̃` values
//! even when `exp(−C/ε)` is below f64's minimum positive, the regime the
//! paper flags citing Xie et al. (2020).
//!
//! Conventions mirror `sparse_loop::sketch_div`: a row/column with no
//! stored entries (or a zero marginal) gets potential −∞ — scaling 0 —
//! rather than a huge clamped value, preserving the stopping behaviour
//! that Theorem 3's iteration bound relies on. The stopping rule is the
//! dense log loop's: sup-norm displacement of the ε-scaled potentials
//! at or below `δ·max(ε, 1e-12)`.

use crate::error::{Error, Result};
use crate::ot::objective::kl_divergence;
use crate::ot::sinkhorn::SinkhornParams;
use crate::ot::SinkhornSolution;
use crate::sparse::CsrMatrix;

/// Log-domain sparse scaling loop; `rho = 1` is OT, `rho = λ/(λ+ε)` is
/// UOT. Returns `(φ, ψ, iterations, displacement, converged)` with the
/// potentials in log-scaling space (`u = e^φ`, `v = e^ψ`; −∞ allowed).
pub fn log_sparse_scalings(
    sketch: &CsrMatrix,
    a: &[f64],
    b: &[f64],
    rho: f64,
    eps: f64,
    params: &SinkhornParams,
) -> Result<(Vec<f64>, Vec<f64>, usize, f64, bool)> {
    if sketch.rows() != a.len() || sketch.cols() != b.len() {
        return Err(Error::Dimension(format!(
            "sketch {}x{} vs a[{}], b[{}]",
            sketch.rows(),
            sketch.cols(),
            a.len(),
            b.len()
        )));
    }
    if eps <= 0.0 {
        return Err(Error::InvalidParam("eps must be positive".into()));
    }
    let n = a.len();
    let m = b.len();
    let log_a: Vec<f64> =
        a.iter().map(|&x| if x > 0.0 { x.ln() } else { f64::NEG_INFINITY }).collect();
    let log_b: Vec<f64> =
        b.iter().map(|&x| if x > 0.0 { x.ln() } else { f64::NEG_INFINITY }).collect();
    let mut phi = vec![0.0; n];
    let mut psi = vec![0.0; m];
    let mut displacement = f64::INFINITY;
    let mut iters = 0;
    while iters < params.max_iters {
        iters += 1;
        let lse_rows = sketch.row_lse(&psi);
        let new_phi: Vec<f64> = (0..n)
            .map(|i| {
                if log_a[i] == f64::NEG_INFINITY || lse_rows[i] == f64::NEG_INFINITY {
                    f64::NEG_INFINITY
                } else {
                    rho * (log_a[i] - lse_rows[i])
                }
            })
            .collect();
        let lse_cols = sketch.col_lse(&new_phi);
        let new_psi: Vec<f64> = (0..m)
            .map(|j| {
                if log_b[j] == f64::NEG_INFINITY || lse_cols[j] == f64::NEG_INFINITY {
                    f64::NEG_INFINITY
                } else {
                    rho * (log_b[j] - lse_cols[j])
                }
            })
            .collect();
        if new_phi.iter().chain(new_psi.iter()).any(|x| x.is_nan()) {
            return Err(Error::Numerical(format!(
                "log-domain sparse potentials became NaN at iteration {iters}"
            )));
        }
        // Sup-norm displacement of the ε-scaled potentials (α = ε·φ),
        // matching the dense log loop's stopping statistic; pairs with a
        // −∞ side count 0, as in the dense loop.
        displacement = eps
            * phi
                .iter()
                .zip(&new_phi)
                .chain(psi.iter().zip(&new_psi))
                .map(|(x, y)| if x.is_finite() && y.is_finite() { (x - y).abs() } else { 0.0 })
                .fold(0.0f64, f64::max);
        phi = new_phi;
        psi = new_psi;
        if displacement <= params.delta * eps.max(1e-12) {
            return Ok((phi, psi, iters, displacement, true));
        }
    }
    if params.strict {
        return Err(Error::NotConverged { iters, err: displacement });
    }
    Ok((phi, psi, iters, displacement, false))
}

/// Entropic OT objective over the log-domain sparse plan
/// `ln T̃_ij = φ_i + ln K̃_ij + ψ_j` (only sampled entries contribute).
/// The entropy term uses the exact log-plan value, so no
/// `ln(exp(·))` round trip can underflow.
pub fn log_sparse_ot_objective(sketch: &CsrMatrix, phi: &[f64], psi: &[f64], eps: f64) -> f64 {
    let mut transport = 0.0;
    let mut entropy = 0.0;
    for (i, j, lk, c) in sketch.iter_log() {
        let lt = phi[i] + lk + psi[j];
        if lt == f64::NEG_INFINITY {
            continue;
        }
        let t = lt.exp();
        if t > 0.0 {
            transport += t * c;
            entropy -= t * (lt - 1.0);
        }
    }
    transport - eps * entropy
}

/// Row/column marginals of the log-domain sparse plan. The entry values
/// `e^{φ+ln K̃+ψ}` are bounded by the marginal masses after a scaling
/// pass, so the sums are safe in the linear domain.
pub fn log_sparse_plan_marginals(
    sketch: &CsrMatrix,
    phi: &[f64],
    psi: &[f64],
) -> (Vec<f64>, Vec<f64>) {
    let mut row = vec![0.0; sketch.rows()];
    let mut col = vec![0.0; sketch.cols()];
    for (i, j, lk, _) in sketch.iter_log() {
        let lt = phi[i] + lk + psi[j];
        if lt == f64::NEG_INFINITY {
            continue;
        }
        let t = lt.exp();
        row[i] += t;
        col[j] += t;
    }
    (row, col)
}

/// Entropic UOT objective (Eq. 10) over the log-domain sparse plan.
#[allow(clippy::too_many_arguments)]
pub fn log_sparse_uot_objective(
    sketch: &CsrMatrix,
    a: &[f64],
    b: &[f64],
    phi: &[f64],
    psi: &[f64],
    lambda: f64,
    eps: f64,
) -> f64 {
    let base = log_sparse_ot_objective(sketch, phi, psi, eps);
    let (row, col) = log_sparse_plan_marginals(sketch, phi, psi);
    base + lambda * kl_divergence(&row, a) + lambda * kl_divergence(&col, b)
}

/// Assemble a [`SinkhornSolution`] from log-domain outputs. The returned
/// `u`/`v` scalings are `e^φ`/`e^ψ` and may overflow to +∞ for tiny ε —
/// as in the dense log solver, the potentials are what is numerically
/// meaningful and the objective is evaluated before exponentiation.
pub fn solution(
    phi: Vec<f64>,
    psi: Vec<f64>,
    objective: f64,
    iterations: usize,
    displacement: f64,
    converged: bool,
) -> Result<SinkhornSolution> {
    if !objective.is_finite() {
        return Err(Error::Numerical("log-domain sparse objective is not finite".into()));
    }
    let u = phi.iter().map(|&x| x.exp()).collect();
    let v = psi.iter().map(|&x| x.exp()).collect();
    Ok(SinkhornSolution { u, v, objective, iterations, displacement, converged })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::ot::cost::{gibbs_kernel, sq_euclidean_cost};
    use crate::ot::log_sinkhorn::log_sinkhorn_ot;
    use crate::solvers::sparse_loop::{
        sparse_ot_objective, sparse_scalings, sparse_uot_objective,
    };
    use crate::sparse::csr::CsrMatrix as Csr;

    /// CSR holding the FULL kernel with exact log values `−C/ε`.
    fn full_csr_logk(cost: &Mat, eps: f64) -> Csr {
        let rows = (0..cost.rows())
            .map(|i| {
                (0..cost.cols())
                    .map(|j| {
                        let c = cost.get(i, j);
                        let lk = -c / eps;
                        (j as u32, lk.exp(), lk, c)
                    })
                    .collect()
            })
            .collect();
        Csr::from_rows_logk(cost.rows(), cost.cols(), rows)
    }

    /// CSR holding the FULL kernel from linear values (no log storage).
    fn full_csr(kernel: &Mat, cost: &Mat) -> Csr {
        let rows = (0..kernel.rows())
            .map(|i| {
                (0..kernel.cols())
                    .map(|j| (j as u32, kernel.get(i, j), cost.get(i, j)))
                    .collect()
            })
            .collect();
        Csr::from_rows(kernel.rows(), kernel.cols(), rows)
    }

    fn toy(n: usize) -> (Mat, Vec<f64>, Vec<f64>) {
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|i| vec![(i as f64 * 0.618).fract(), (i as f64 * 0.383).fract()])
            .collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        let a = vec![1.0 / n as f64; n];
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 2) as f64).collect();
        let sb: f64 = b.iter().sum();
        (cost, a, b.iter().map(|x| x / sb).collect())
    }

    #[test]
    fn matches_multiplicative_sparse_loop_at_moderate_eps() {
        // Fixed iteration count on both loops: the update maps are
        // mathematically identical, so the objectives must agree.
        let (cost, a, b) = toy(24);
        let eps = 0.1;
        let kernel = gibbs_kernel(&cost, eps);
        let sk_lin = full_csr(&kernel, &cost);
        let sk_log = full_csr_logk(&cost, eps);
        let params = SinkhornParams { delta: 0.0, max_iters: 300, strict: false };
        let (u, v, ..) = sparse_scalings(&sk_lin, &a, &b, 1.0, &params).unwrap();
        let (phi, psi, ..) = log_sparse_scalings(&sk_log, &a, &b, 1.0, eps, &params).unwrap();
        let o_lin = sparse_ot_objective(&sk_lin, &u, &v, eps);
        let o_log = log_sparse_ot_objective(&sk_log, &phi, &psi, eps);
        assert!((o_lin - o_log).abs() < 1e-8, "{o_lin} vs {o_log}");
        // Potentials agree with the multiplicative scalings where finite.
        for (ui, pi) in u.iter().zip(&phi) {
            assert!((ui.ln() - pi).abs() < 1e-8, "{} vs {pi}", ui.ln());
        }
    }

    #[test]
    fn matches_dense_log_loop_at_small_eps_on_full_kernel() {
        // The acceptance bar: at ε below the multiplicative underflow
        // point, the sparse log loop on a full-kernel sketch matches
        // log_sinkhorn_ot to 1e-8.
        let (cost, a, b) = toy(16);
        let eps = 5e-4;
        let sk = full_csr_logk(&cost, eps);
        let params = SinkhornParams { delta: 0.0, max_iters: 2000, strict: false };
        let (phi, psi, ..) = log_sparse_scalings(&sk, &a, &b, 1.0, eps, &params).unwrap();
        let o_sparse = log_sparse_ot_objective(&sk, &phi, &psi, eps);
        let dense = log_sinkhorn_ot(&cost, &a, &b, eps, &params).unwrap();
        assert!(
            (o_sparse - dense.objective).abs() < 1e-8,
            "sparse {o_sparse} vs dense {}",
            dense.objective
        );
    }

    #[test]
    fn uot_matches_multiplicative_sparse_loop_at_moderate_eps() {
        let (cost, a, b) = toy(16);
        let eps = 0.1;
        let lambda = 1.0;
        let rho = crate::ot::uot::uot_rho(lambda, eps);
        let kernel = gibbs_kernel(&cost, eps);
        let sk_lin = full_csr(&kernel, &cost);
        let sk_log = full_csr_logk(&cost, eps);
        let params = SinkhornParams { delta: 0.0, max_iters: 400, strict: false };
        let (u, v, ..) = sparse_scalings(&sk_lin, &a, &b, rho, &params).unwrap();
        let (phi, psi, ..) = log_sparse_scalings(&sk_log, &a, &b, rho, eps, &params).unwrap();
        let o_lin = sparse_uot_objective(&sk_lin, &a, &b, &u, &v, lambda, eps);
        let o_log = log_sparse_uot_objective(&sk_log, &a, &b, &phi, &psi, lambda, eps);
        assert!((o_lin - o_log).abs() < 1e-8, "{o_lin} vs {o_log}");
    }

    #[test]
    fn survives_tiny_eps_on_full_kernel() {
        let (cost, a, b) = toy(16);
        let eps = 1e-5;
        let sk = full_csr_logk(&cost, eps);
        // The bulk of the linear kernel underflowed (cost/ε reaches the
        // tens of thousands), yet the log loop still produces a finite
        // objective.
        let underflowed = sk.iter().filter(|&(_, _, k, _)| k == 0.0).count();
        assert!(underflowed > 16 * 16 / 2, "only {underflowed} entries underflowed");
        let params = SinkhornParams { delta: 1e-8, max_iters: 500, strict: false };
        let (phi, psi, iters, _, _) =
            log_sparse_scalings(&sk, &a, &b, 1.0, eps, &params).unwrap();
        assert!(iters >= 1);
        let obj = log_sparse_ot_objective(&sk, &phi, &psi, eps);
        assert!(obj.is_finite());
        // At ε → 0 the entropic objective approaches the non-negative
        // unregularized OT cost (the ε·H term bounds the slack).
        assert!(obj > -1e-3, "objective {obj}");
    }

    #[test]
    fn empty_rows_get_neg_infinity_potentials() {
        let sk = Csr::from_rows_logk(
            3,
            3,
            vec![
                vec![(0, 1.0, 0.0, 0.0)],
                vec![],
                vec![(2, 1.0, 0.0, 0.0)],
            ],
        );
        let a = [0.4, 0.2, 0.4];
        let b = [0.4, 0.2, 0.4];
        let params = SinkhornParams { delta: 1e-8, max_iters: 50, strict: false };
        let (phi, psi, ..) = log_sparse_scalings(&sk, &a, &b, 1.0, 0.1, &params).unwrap();
        assert_eq!(phi[1], f64::NEG_INFINITY, "empty row keeps scaling 0");
        assert_eq!(psi[1], f64::NEG_INFINITY, "empty column keeps scaling 0");
        assert!(phi[0].is_finite() && phi[2].is_finite());
        let obj = log_sparse_ot_objective(&sk, &phi, &psi, 0.1);
        assert!(obj.is_finite());
    }

    #[test]
    fn rejects_bad_input() {
        let (cost, a, b) = toy(8);
        let sk = full_csr_logk(&cost, 0.1);
        let params = SinkhornParams::default();
        assert!(log_sparse_scalings(&sk, &a[..4], &b, 1.0, 0.1, &params).is_err());
        assert!(log_sparse_scalings(&sk, &a, &b, 1.0, 0.0, &params).is_err());
    }

    #[test]
    fn solution_rejects_non_finite_objective() {
        assert!(solution(vec![0.0], vec![0.0], f64::NAN, 1, 0.0, true).is_err());
        let sol = solution(vec![0.0, f64::NEG_INFINITY], vec![0.0], 1.0, 3, 0.0, true).unwrap();
        assert_eq!(sol.u[1], 0.0, "e^{{-inf}} scaling is 0");
        assert_eq!(sol.iterations, 3);
    }
}
