//! The serving coordinator: a batched distance-computation service.
//!
//! The paper's echocardiogram pipeline (Section 6) reduces to computing
//! many pairwise WFR distances between video frames. This module turns
//! that into a production-shaped service:
//!
//! ```text
//!   clients ── submit(job) ──▶ bounded queue (backpressure)
//!                                  │
//!                             batcher thread
//!                      groups jobs by (method, size bucket)
//!                                  │
//!                          worker pool (N threads)
//!              solves each job through `api::solve` (one
//!            dispatch surface for every registered method)
//!                                  │
//!                       per-job response channels + metrics
//! ```
//!
//! Distance (pairwise WFR) and fixed-support barycenter jobs share the
//! same queue, batcher and worker pool — a [`BarycenterJob`] rides the
//! identical path via [`DistanceService::submit_barycenter`], honoring
//! per-job backend overrides and feeding the same per-method
//! log-escalation counters.
//!
//! * The submission queue is bounded: `submit` blocks once `queue_cap`
//!   jobs are in flight (backpressure instead of unbounded memory).
//! * The batcher flushes a batch when it reaches `max_batch` jobs or
//!   `batch_window` elapses, whichever comes first — the same policy as
//!   continuous-batching LLM servers, adapted to solver jobs.
//! * Latency/throughput metrics are recorded per job and exposed as a
//!   histogram snapshot ([`metrics::MetricsSnapshot`]).

mod jobs;
mod metrics;
mod service;

pub use jobs::{
    BarycenterJob, BarycenterResult, DistanceJob, DistanceResult, Measure, Method, ProblemSpec,
};
pub use metrics::{LatencyHistogram, MetricsSnapshot};
pub use service::{CoordinatorConfig, DistanceService};
