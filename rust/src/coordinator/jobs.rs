//! Job and result types for the distance service.

use std::sync::Arc;

/// A discrete measure: support points + masses (shared across jobs via
/// `Arc` so a video's frames are stored once).
#[derive(Clone, Debug)]
pub struct Measure {
    pub points: Arc<Vec<Vec<f64>>>,
    pub mass: Arc<Vec<f64>>,
}

impl Measure {
    pub fn new(points: Vec<Vec<f64>>, mass: Vec<f64>) -> Self {
        assert_eq!(points.len(), mass.len(), "support/mass length mismatch");
        Measure { points: Arc::new(points), mass: Arc::new(mass) }
    }

    pub fn len(&self) -> usize {
        self.mass.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mass.is_empty()
    }
}

/// Which solver executes the job.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Exact unbalanced Sinkhorn (Algorithm 2), dense.
    Sinkhorn,
    /// The paper's Spar-Sink (Algorithm 4); payload = s multiplier
    /// in units of s₀(n) is carried in [`ProblemSpec::s_multiplier`].
    /// Escalates to the log-domain backend on numerical failure.
    SparSink,
    /// Spar-Sink with the log-domain sparse engine forced on: the
    /// sketch is built from log-kernel values and the scaling loop runs
    /// on dual potentials, so jobs stay solvable at ε far below the
    /// multiplicative underflow point (these previously came back as
    /// NaN distances).
    SparSinkLog,
    /// Uniform-sampling ablation.
    RandSink,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Sinkhorn => "sinkhorn",
            Method::SparSink => "spar-sink",
            Method::SparSinkLog => "spar-sink-log",
            Method::RandSink => "rand-sink",
        }
    }
}

/// Problem parameters shared by a family of jobs.
#[derive(Clone, Debug)]
pub struct ProblemSpec {
    /// Marginal relaxation λ (WFR distance).
    pub lambda: f64,
    /// Entropic regularization ε.
    pub eps: f64,
    /// WFR truncation radius η.
    pub eta: f64,
    /// Subsample budget in units of s₀(n) (ignored by `Sinkhorn`).
    pub s_multiplier: f64,
    /// Sinkhorn stopping threshold δ.
    pub delta: f64,
    /// Iteration cap.
    pub max_iters: usize,
}

impl Default for ProblemSpec {
    fn default() -> Self {
        // Section 6 defaults: eps = 0.01 (scaled), lambda = 1, eta = 15.
        ProblemSpec {
            lambda: 1.0,
            eps: 0.01,
            eta: 15.0,
            s_multiplier: 8.0,
            delta: 1e-6,
            max_iters: 1000,
        }
    }
}

/// A single WFR-distance job between two measures.
#[derive(Clone, Debug)]
pub struct DistanceJob {
    /// Client-assigned id, echoed in the result.
    pub id: u64,
    pub source: Measure,
    pub target: Measure,
    pub method: Method,
    pub spec: ProblemSpec,
    /// RNG seed for the sparsifier (deterministic per job).
    pub seed: u64,
}

/// Result of a distance job.
#[derive(Clone, Debug)]
pub struct DistanceResult {
    pub id: u64,
    /// WFR distance (sqrt of the UOT objective, clamped at 0).
    pub distance: f64,
    /// Raw entropic UOT objective.
    pub objective: f64,
    /// Solver iterations used.
    pub iterations: usize,
    /// End-to-end latency (queue + solve).
    pub latency: std::time::Duration,
    /// Which batch the job ran in (diagnostics).
    pub batch_id: u64,
    /// Error message if the solve failed.
    pub error: Option<String>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_shares_storage() {
        let m = Measure::new(vec![vec![0.0, 1.0]], vec![1.0]);
        let m2 = m.clone();
        assert!(Arc::ptr_eq(&m.points, &m2.points));
        assert_eq!(m.len(), 1);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn measure_rejects_mismatch() {
        Measure::new(vec![vec![0.0]], vec![1.0, 2.0]);
    }

    #[test]
    fn default_spec_matches_paper_section6() {
        let spec = ProblemSpec::default();
        assert_eq!(spec.lambda, 1.0);
        assert_eq!(spec.eps, 0.01);
        assert_eq!(spec.eta, 15.0);
        assert_eq!(spec.s_multiplier, 8.0);
    }
}
