//! Microbench: CSR sparse matvec vs dense matvec, the Poisson
//! sparsifier construction pass — the O(s)-per-iteration claim of
//! Section 5.2 — and the multiplicative vs log-domain sparse scaling
//! iteration throughput (both are O(nnz)/iter; the log engine pays one
//! exp per stored entry per half-iteration).

use std::sync::Arc;

use spar_sink::api::{self, CostSource, EntryOracle, Method, OtProblem, SolverSpec};
use spar_sink::bench::Bencher;
use spar_sink::data::synthetic::{instance, Scenario};
use spar_sink::engine::{ArtifactCache, CostArtifacts, Fingerprint, FormulationKey};
use spar_sink::experiments::common::ot_cost;
use spar_sink::metrics::s0;
use spar_sink::ot::cost::{euclidean, gibbs_kernel, log_gibbs_from_cost, wfr_cost_from_distance};
use spar_sink::ot::sinkhorn::SinkhornParams;
use spar_sink::rng::Rng;
use spar_sink::solvers::log_sparse::log_sparse_scalings;
use spar_sink::solvers::sparse_loop::sparse_scalings;
use spar_sink::sparse::{poisson_sparsify_ot, poisson_sparsify_ot_logk};

fn main() {
    let mut bencher = Bencher::default();
    for &n in &[1000usize, 2000, 4000] {
        let mut rng = Rng::seed_from(1);
        let inst = instance(Scenario::C1, n, 5, 1.0, 1.0, &mut rng);
        let cost = ot_cost(&inst.points);
        let eps = 0.05;
        let kernel = gibbs_kernel(&cost, eps);
        let s = 8.0 * s0(n);
        let (sketch, _) = poisson_sparsify_ot(
            |i, j| kernel.get(i, j),
            |i, j| cost.get(i, j),
            &inst.a,
            &inst.b,
            s,
            1.0,
            &mut rng,
        )
        .unwrap();
        let x: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64 * 0.1).collect();

        bencher.bench(format!("dense_matvec/n={n}"), || {
            std::hint::black_box(kernel.matvec(std::hint::black_box(&x)));
        });
        bencher.bench(
            format!("sparse_matvec/n={n}/nnz={}", sketch.nnz()),
            || {
                std::hint::black_box(sketch.matvec(std::hint::black_box(&x)));
            },
        );
        bencher.bench(format!("sparsify_construct/n={n}"), || {
            let mut r = Rng::seed_from(2);
            std::hint::black_box(
                poisson_sparsify_ot(
                    |i, j| kernel.get(i, j),
                    |i, j| cost.get(i, j),
                    &inst.a,
                    &inst.b,
                    s,
                    1.0,
                    &mut r,
                )
                .unwrap(),
            );
        });

        // Multiplicative vs log-domain sparse scaling-loop throughput at
        // a fixed iteration count (delta = 0 disables early stopping) on
        // a log-kernel sketch of the same budget.
        let mut r = Rng::seed_from(3);
        let (logk_sketch, _) = poisson_sparsify_ot_logk(
            |i, j| -cost.get(i, j) / eps,
            |i, j| cost.get(i, j),
            &inst.a,
            &inst.b,
            s,
            1.0,
            &mut r,
        )
        .unwrap();
        let iter_params = SinkhornParams { delta: 0.0, max_iters: 25, strict: false };
        bencher.bench(format!("sparse_scalings_mult/n={n}/25it"), || {
            std::hint::black_box(
                sparse_scalings(&logk_sketch, &inst.a, &inst.b, 1.0, &iter_params).unwrap(),
            );
        });
        bencher.bench(format!("sparse_scalings_log/n={n}/25it"), || {
            std::hint::black_box(
                log_sparse_scalings(&logk_sketch, &inst.a, &inst.b, 1.0, eps, &iter_params)
                    .unwrap(),
            );
        });
    }

    // Batch-vs-cold pairwise UOT on one shared support — the echo
    // workload's shape. Cold re-derives the WFR cost oracle and the
    // Eq. 11 sampling normalization per pair; the batch path builds
    // CostArtifacts once per (eta, eps, lambda) in a fresh cache and
    // every subsequent pair is "reuse + reweight".
    {
        let n = 400;
        let frames = 6;
        let (eta, eps, lambda) = (3.0, 0.05, 1.0);
        let mut rng = Rng::seed_from(11);
        let pts: Arc<Vec<Vec<f64>>> = Arc::new(
            (0..n).map(|_| vec![rng.uniform() * 10.0, rng.uniform() * 10.0]).collect(),
        );
        let masses: Vec<Arc<Vec<f64>>> = (0..frames)
            .map(|_| {
                let mut m: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.1).collect();
                let s: f64 = m.iter().sum();
                m.iter_mut().for_each(|x| *x /= s);
                Arc::new(m)
            })
            .collect();
        let mut pairs = Vec::new();
        for i in 0..frames {
            for j in (i + 1)..frames {
                pairs.push((i, j));
            }
        }
        let spec = SolverSpec::new(Method::SparSink).with_budget(8.0).with_seed(3);

        let oracle_problem = |a: &Arc<Vec<f64>>, b: &Arc<Vec<f64>>| -> OtProblem {
            let (src, tgt) = (pts.clone(), pts.clone());
            let cost: EntryOracle = Arc::new(move |i: usize, j: usize| {
                wfr_cost_from_distance(euclidean(&src[i], &tgt[j]), eta)
            });
            let cost_for_lk = cost.clone();
            let log_kernel: EntryOracle =
                Arc::new(move |i: usize, j: usize| log_gibbs_from_cost(cost_for_lk(i, j), eps));
            OtProblem::unbalanced(
                CostSource::Oracle { rows: n, cols: n, cost, log_kernel: Some(log_kernel) },
                a.clone(),
                b.clone(),
                lambda,
                eps,
            )
        };
        bencher.bench(
            format!("uot_pairwise_cold_oracle/n={n}/pairs={}", pairs.len()),
            || {
                for &(i, j) in &pairs {
                    let problem = oracle_problem(&masses[i], &masses[j]);
                    std::hint::black_box(api::solve(&problem, &spec).unwrap());
                }
            },
        );
        bencher.bench(
            format!("uot_pairwise_batch_shared/n={n}/pairs={}", pairs.len()),
            || {
                // Fresh cache per iteration so the one-time artifact
                // build is inside the measurement.
                let cache = ArtifactCache::new(1 << 30);
                let key = FormulationKey::unbalanced(lambda);
                let problems: Vec<OtProblem> = pairs
                    .iter()
                    .map(|&(i, j)| {
                        let fingerprint =
                            Fingerprint::for_supports(&pts, &pts, Some(eta), eps, key);
                        let handle = cache.get_or_build(fingerprint, || {
                            CostArtifacts::for_wfr_supports(&pts, &pts, eta, eps, key)
                        });
                        OtProblem::unbalanced(
                            CostSource::Shared(handle),
                            masses[i].clone(),
                            masses[j].clone(),
                            lambda,
                            eps,
                        )
                    })
                    .collect();
                for solution in api::solve_batch_with_cache(&problems, &spec, &cache) {
                    std::hint::black_box(solution.unwrap());
                }
            },
        );
    }

    // Many-ε concurrent warm-up — the single-flight shape: worker
    // threads sweep distinct ε values over ONE support against one
    // shared cache. Each ε is its own fingerprint, so each kernel
    // builds exactly once. With 6 threads over 4 ε offsets, two pairs
    // of threads start on the SAME ε at every step (structural
    // same-fingerprint coalescing on the building slot) while the
    // remaining threads hold DISTINCT ε — whose builds overlap instead
    // of serializing behind the cache mutex (the pre-single-flight
    // behavior, which made this sweep effectively sequential).
    {
        let n = 300;
        let threads = 6usize;
        let eps_sweep = [0.02, 0.05, 0.08, 0.12];
        let (eta, lambda) = (3.0, 1.0);
        let mut rng = Rng::seed_from(17);
        let pts: Arc<Vec<Vec<f64>>> = Arc::new(
            (0..n).map(|_| vec![rng.uniform() * 10.0, rng.uniform() * 10.0]).collect(),
        );
        let mass = |rng: &mut Rng| -> Arc<Vec<f64>> {
            let mut m: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.1).collect();
            let s: f64 = m.iter().sum();
            m.iter_mut().for_each(|x| *x /= s);
            Arc::new(m)
        };
        let (a, b) = (mass(&mut rng), mass(&mut rng));
        let key = FormulationKey::unbalanced(lambda);
        let spec = SolverSpec::new(Method::SparSink).with_budget(8.0).with_seed(5);
        bencher.bench(
            format!(
                "uot_many_eps_concurrent_warm/n={n}/eps={}/threads={threads}",
                eps_sweep.len()
            ),
            || {
                // Fresh cache per iteration: every ε's one-time build is
                // inside the measurement, overlapping across threads.
                let cache = ArtifactCache::new(1 << 30);
                std::thread::scope(|scope| {
                    for t in 0..threads {
                        let (cache, pts, a, b, spec) =
                            (&cache, pts.clone(), a.clone(), b.clone(), spec.clone());
                        scope.spawn(move || {
                            for k in 0..eps_sweep.len() {
                                let eps = eps_sweep[(k + t) % eps_sweep.len()];
                                let fingerprint =
                                    Fingerprint::for_supports(&pts, &pts, Some(eta), eps, key);
                                let handle = cache.get_or_build(fingerprint, || {
                                    CostArtifacts::for_wfr_supports(&pts, &pts, eta, eps, key)
                                });
                                let problem = OtProblem::unbalanced(
                                    CostSource::Shared(handle),
                                    a.clone(),
                                    b.clone(),
                                    lambda,
                                    eps,
                                );
                                std::hint::black_box(api::solve(&problem, &spec).unwrap());
                            }
                        });
                    }
                });
            },
        );
    }

    println!("\n{}", bencher.report("bench_sparse"));
}
