//! Shared experiment plumbing: method runners at matched budgets (all
//! dispatched through [`crate::api::solve_with_rng`]), RMAE sweeps, and
//! result-row helpers.

use std::sync::Arc;

use crate::api::{self, OtProblem, SolverSpec};
use crate::linalg::Mat;
use crate::metrics::mean_sd;
use crate::ot::cost::{gibbs_kernel, sq_euclidean_cost, wfr_cost};
use crate::ot::sinkhorn::{sinkhorn_ot, SinkhornParams};
use crate::ot::uot::sinkhorn_uot;
use crate::rng::Rng;
use crate::solvers::backend::ScalingBackend;
use crate::util::json::Json;

/// Subsampling-based methods compared in Figs. 2-3 and 8-10, plus the
/// log-domain Spar-Sink variant used by the small-ε harness. A paper-
/// figure-sized subset of the full [`api::Method`] registry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Nyström-factorized Sinkhorn baseline.
    NysSink,
    /// Uniform-sampling baseline.
    RandSink,
    /// The paper's importance-sparsified solver.
    SparSink,
    /// Spar-Sink with the log-domain sparse backend forced on.
    SparSinkLog,
}

impl Method {
    /// The three methods the paper's figures compare.
    pub fn all() -> [Method; 3] {
        [Method::NysSink, Method::RandSink, Method::SparSink]
    }

    /// The registry method this experiment arm dispatches to.
    pub fn api(&self) -> api::Method {
        match self {
            Method::NysSink => api::Method::NysSink,
            Method::RandSink => api::Method::RandSink,
            Method::SparSink => api::Method::SparSink,
            Method::SparSinkLog => api::Method::SparSinkLog,
        }
    }

    /// The registry key / CLI spelling.
    pub fn name(&self) -> &'static str {
        self.api().name()
    }
}

/// The shared cost-normalization helper now lives in
/// [`crate::ot::cost::normalize_cost`]; re-exported so existing
/// experiment imports keep resolving.
pub use crate::ot::cost::normalize_cost;

/// Build the (normalized) squared-Euclidean cost of an instance,
/// `Arc`-shared so replication sweeps reuse one allocation across
/// every `api::solve` dispatch.
pub fn ot_cost(points: &[Vec<f64>]) -> Arc<Mat> {
    Arc::new(normalize_cost(&sq_euclidean_cost(points, points)))
}

/// Build the WFR cost at a target kernel density (R1-R3).
pub fn wfr_cost_at_density(points: &[Vec<f64>], density: f64) -> Arc<Mat> {
    let eta = crate::ot::cost::calibrate_eta(points, points, density, 1e-3);
    Arc::new(wfr_cost(points, points, eta))
}

/// Run one subsampling method on an OT problem at budget `s_mult`·s₀(n)
/// through the unified API; Nys-Sink gets rank r = ceil(s/n) per the
/// paper's matched protocol (the registry's default).
pub fn run_method_ot(
    method: Method,
    cost: &Arc<Mat>,
    a: &[f64],
    b: &[f64],
    eps: f64,
    s_mult: f64,
    rng: &mut Rng,
) -> crate::error::Result<f64> {
    let problem = OtProblem::balanced(cost, a.to_vec(), b.to_vec(), eps);
    let spec = SolverSpec::new(method.api()).with_budget(s_mult);
    api::solve_with_rng(&problem, &spec, rng).map(|s| s.objective)
}

/// Same for UOT (WFR cost).
#[allow(clippy::too_many_arguments)]
pub fn run_method_uot(
    method: Method,
    cost: &Arc<Mat>,
    a: &[f64],
    b: &[f64],
    lambda: f64,
    eps: f64,
    s_mult: f64,
    rng: &mut Rng,
) -> crate::error::Result<f64> {
    let problem = OtProblem::unbalanced(cost, a.to_vec(), b.to_vec(), lambda, eps);
    let spec = SolverSpec::new(method.api()).with_budget(s_mult);
    api::solve_with_rng(&problem, &spec, rng).map(|s| s.objective)
}

/// Gibbs kernel that maps infinite costs (WFR truncation) to zero.
pub fn gibbs_kernel_inf(cost: &Mat, eps: f64) -> Mat {
    cost.map(move |c| if c.is_finite() { (-c / eps).exp() } else { 0.0 })
}

/// Exact OT solve (truth for RMAE).
pub fn exact_ot(cost: &Mat, a: &[f64], b: &[f64], eps: f64) -> crate::error::Result<f64> {
    let kernel = gibbs_kernel(cost, eps);
    sinkhorn_ot(&kernel, cost, a, b, eps, &SinkhornParams::default()).map(|s| s.objective)
}

/// Exact OT truth that stays stable at small ε: routes through the
/// backend abstraction — the multiplicative dense solve above the
/// threshold, the dense log-domain solve below it or on failure.
pub fn exact_ot_stable(cost: &Mat, a: &[f64], b: &[f64], eps: f64) -> crate::error::Result<f64> {
    ScalingBackend::default()
        .dense_ot(cost, a, b, eps, &SinkhornParams::default())
        .map(|(s, _)| s.objective)
}

/// Exact UOT solve (truth for RMAE).
pub fn exact_uot(
    cost: &Mat,
    a: &[f64],
    b: &[f64],
    lambda: f64,
    eps: f64,
) -> crate::error::Result<f64> {
    let kernel = gibbs_kernel_inf(cost, eps);
    sinkhorn_uot(&kernel, cost, a, b, lambda, eps, &SinkhornParams::default())
        .map(|s| s.objective)
}

/// RMAE ± se of a method over `reps` independent sketches.
pub fn rmae_over_reps(
    reps: usize,
    truth: f64,
    mut run_once: impl FnMut(&mut Rng) -> crate::error::Result<f64>,
    rng: &mut Rng,
) -> (f64, f64, usize) {
    let mut errs = Vec::with_capacity(reps);
    let mut failures = 0usize;
    for _ in 0..reps {
        match run_once(rng) {
            Ok(est) => errs.push((est - truth).abs() / truth.abs().max(f64::MIN_POSITIVE)),
            Err(_) => failures += 1,
        }
    }
    if errs.is_empty() {
        return (f64::NAN, f64::NAN, failures);
    }
    let (mean, sd) = mean_sd(&errs);
    (mean, sd / (errs.len() as f64).sqrt(), failures)
}

/// A JSON row builder for experiment outputs.
pub fn row(fields: Vec<(&str, Json)>) -> Json {
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{instance, Scenario};

    #[test]
    fn methods_all_run_on_small_instance() {
        let mut rng = Rng::seed_from(7);
        let inst = instance(Scenario::C1, 120, 5, 1.0, 1.0, &mut rng);
        let cost = ot_cost(&inst.points);
        let truth = exact_ot(&cost, &inst.a, &inst.b, 0.1).unwrap();
        assert!(truth.is_finite());
        for m in Method::all() {
            let est = run_method_ot(m, &cost, &inst.a, &inst.b, 0.1, 8.0, &mut rng).unwrap();
            assert!(est.is_finite(), "{m:?}");
        }
    }

    #[test]
    fn rmae_over_reps_counts_failures() {
        let mut rng = Rng::seed_from(9);
        let mut flip = false;
        let (mean, se, failures) = rmae_over_reps(
            4,
            1.0,
            |_| {
                flip = !flip;
                if flip {
                    Ok(1.1)
                } else {
                    Err(crate::error::Error::Numerical("x".into()))
                }
            },
            &mut rng,
        );
        assert_eq!(failures, 2);
        assert!((mean - 0.1).abs() < 1e-12);
        assert!(se >= 0.0);
    }
}
