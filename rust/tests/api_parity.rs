//! Parity tests for the unified `api::solve` surface: for every
//! registered method, dispatching through the registry must return a
//! BITWISE-identical objective to the legacy free-function entry point
//! it adapts — on OT, UOT and barycenter formulations, from dense costs
//! and from entry oracles, for the multiplicative AND the log-domain
//! engines. Plus multiplicative-vs-log agreement pins (q within 1e-8
//! sup-norm where both backends converge) and registry-resolution
//! coverage.

use std::sync::Arc;

use spar_sink::api::{self, CostSource, Formulation, Method, OtProblem, SolverSpec};
use spar_sink::experiments::common::normalize_cost;
use spar_sink::linalg::Mat;
use spar_sink::metrics::{normalized_histogram, s0};
use spar_sink::ot::barycenter::ibp_barycenter;
use spar_sink::ot::cost::{gibbs_kernel, sq_euclidean_cost};
use spar_sink::ot::log_barycenter::log_ibp_barycenter;
use spar_sink::ot::log_sinkhorn::log_sinkhorn_uot;
use spar_sink::ot::sinkhorn::{sinkhorn_ot, SinkhornParams};
use spar_sink::ot::uot::sinkhorn_uot;
use spar_sink::rng::Rng;
use spar_sink::solvers::backend::{BackendKind, ScalingBackend};
use spar_sink::solvers::greenkhorn::{greenkhorn_ot, GreenkhornParams};
use spar_sink::solvers::log_spar_ibp::log_spar_ibp;
use spar_sink::solvers::nys_sink::{nys_sink_ot, nys_sink_uot, NysSinkParams};
use spar_sink::solvers::rand_sink::{rand_sink_ot, rand_sink_uot};
use spar_sink::solvers::screenkhorn::{screenkhorn_ot, ScreenkhornParams};
use spar_sink::solvers::spar_ibp::spar_ibp;
use spar_sink::solvers::spar_sink::{spar_sink_ot, spar_sink_uot, SparSinkParams};

const SEED: u64 = 77;
const S_MULT: f64 = 8.0;

/// Square instance with skewed marginals on a normalized cost.
fn instance(n: usize, seed: u64) -> (Arc<Mat>, Vec<f64>, Vec<f64>) {
    let mut rng = Rng::seed_from(seed);
    let pts: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..3).map(|_| rng.uniform()).collect())
        .collect();
    let cost = Arc::new(normalize_cost(&sq_euclidean_cost(&pts, &pts)));
    let mk = |rng: &mut Rng| -> Vec<f64> {
        let raw: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.05).collect();
        let s: f64 = raw.iter().sum();
        raw.iter().map(|x| x / s).collect()
    };
    let a = mk(&mut rng);
    let b = mk(&mut rng);
    (cost, a, b)
}

/// The same problem exposed through entry oracles instead of the dense
/// matrix (log-kernel left to the derived `−C/ε`, exactly what the
/// dense path samples through).
fn as_oracle(problem: &OtProblem) -> OtProblem {
    let dense = problem.cost.to_mat();
    let mut out = problem.clone();
    out.cost = CostSource::oracle(dense.rows(), dense.cols(), move |i, j| dense.get(i, j));
    out
}

fn spec(method: Method) -> SolverSpec {
    SolverSpec::new(method).with_budget(S_MULT).with_seed(SEED)
}

fn assert_bits(label: &str, api_obj: f64, legacy_obj: f64) {
    assert_eq!(
        api_obj.to_bits(),
        legacy_obj.to_bits(),
        "{label}: api {api_obj} != legacy {legacy_obj}"
    );
}

/// Legacy objective for `method` on a balanced problem (the free
/// functions the registry adapts).
fn legacy_ot(method: Method, cost: &Mat, a: &[f64], b: &[f64], eps: f64) -> f64 {
    let params = SinkhornParams::default();
    let mut rng = Rng::seed_from(SEED);
    match method {
        Method::Sinkhorn => {
            let kernel = gibbs_kernel(cost, eps);
            sinkhorn_ot(&kernel, cost, a, b, eps, &params).unwrap().objective
        }
        Method::SparSink => {
            spar_sink_ot(cost, a, b, eps, S_MULT, &SparSinkParams::default(), &mut rng)
                .unwrap()
                .solution
                .objective
        }
        Method::SparSinkLog => {
            let p = SparSinkParams { backend: ScalingBackend::LogDomain, ..Default::default() };
            spar_sink_ot(cost, a, b, eps, S_MULT, &p, &mut rng).unwrap().solution.objective
        }
        Method::RandSink => rand_sink_ot(cost, a, b, eps, S_MULT, &params, &mut rng)
            .unwrap()
            .solution
            .objective,
        Method::NysSink => {
            let n = a.len();
            let rank = ((S_MULT * s0(n) / n as f64).ceil() as usize).max(1);
            let kernel = gibbs_kernel(cost, eps);
            nys_sink_ot(
                |i, j| kernel.get(i, j),
                |i, j| cost.get(i, j),
                a,
                b,
                eps,
                rank,
                &NysSinkParams::default(),
                &mut rng,
            )
            .unwrap()
            .objective
        }
        Method::Greenkhorn => {
            let kernel = gibbs_kernel(cost, eps);
            greenkhorn_ot(&kernel, cost, a, b, eps, &GreenkhornParams::default())
                .unwrap()
                .objective
        }
        Method::Screenkhorn => {
            let kernel = gibbs_kernel(cost, eps);
            screenkhorn_ot(&kernel, cost, a, b, eps, &ScreenkhornParams::default())
                .unwrap()
                .objective
        }
        Method::SparIbp => unreachable!("barycenter-only"),
    }
}

/// Legacy objective for `method` on an unbalanced problem.
fn legacy_uot(method: Method, cost: &Mat, a: &[f64], b: &[f64], lambda: f64, eps: f64) -> f64 {
    let params = SinkhornParams::default();
    let mut rng = Rng::seed_from(SEED);
    match method {
        Method::Sinkhorn => {
            let kernel = gibbs_kernel(cost, eps);
            sinkhorn_uot(&kernel, cost, a, b, lambda, eps, &params).unwrap().objective
        }
        Method::SparSink => {
            spar_sink_uot(cost, a, b, lambda, eps, S_MULT, &SparSinkParams::default(), &mut rng)
                .unwrap()
                .solution
                .objective
        }
        Method::SparSinkLog => {
            let p = SparSinkParams { backend: ScalingBackend::LogDomain, ..Default::default() };
            spar_sink_uot(cost, a, b, lambda, eps, S_MULT, &p, &mut rng)
                .unwrap()
                .solution
                .objective
        }
        Method::RandSink => rand_sink_uot(cost, a, b, lambda, eps, S_MULT, &params, &mut rng)
            .unwrap()
            .solution
            .objective,
        Method::NysSink => {
            let n = a.len();
            let rank = ((S_MULT * s0(n) / n as f64).ceil() as usize).max(1);
            let kernel = gibbs_kernel(cost, eps);
            nys_sink_uot(
                |i, j| kernel.get(i, j),
                |i, j| cost.get(i, j),
                a,
                b,
                lambda,
                eps,
                rank,
                &NysSinkParams::default(),
                &mut rng,
            )
            .unwrap()
            .objective
        }
        _ => unreachable!("not a UOT method"),
    }
}

const OT_METHODS: [Method; 7] = [
    Method::Sinkhorn,
    Method::SparSink,
    Method::SparSinkLog,
    Method::RandSink,
    Method::NysSink,
    Method::Greenkhorn,
    Method::Screenkhorn,
];

const UOT_METHODS: [Method; 5] = [
    Method::Sinkhorn,
    Method::SparSink,
    Method::SparSinkLog,
    Method::RandSink,
    Method::NysSink,
];

#[test]
fn every_method_resolves_in_the_registry() {
    for method in Method::ALL {
        let solver = api::lookup(method.name())
            .unwrap_or_else(|| panic!("{method:?} has no registered solver"));
        assert_eq!(solver.name(), method.name());
        assert_eq!(Method::parse(method.name()), Some(method));
    }
    assert_eq!(api::registry().len(), Method::ALL.len());
}

#[test]
fn dense_ot_objectives_are_bitwise_identical_to_legacy() {
    let (cost, a, b) = instance(48, 101);
    let eps = 0.1;
    let problem = OtProblem::balanced(&cost, a.clone(), b.clone(), eps);
    for method in OT_METHODS {
        let sol = api::solve(&problem, &spec(method)).unwrap();
        let legacy = legacy_ot(method, &cost, &a, &b, eps);
        assert_bits(&format!("dense OT {method:?}"), sol.objective, legacy);
    }
}

#[test]
fn dense_uot_objectives_are_bitwise_identical_to_legacy() {
    let (cost, a, b) = instance(40, 103);
    // Unbalance the masses.
    let a: Vec<f64> = a.iter().map(|x| x * 5.0).collect();
    let b: Vec<f64> = b.iter().map(|x| x * 3.0).collect();
    let (lambda, eps) = (1.0, 0.1);
    let problem = OtProblem::unbalanced(&cost, a.clone(), b.clone(), lambda, eps);
    for method in UOT_METHODS {
        let sol = api::solve(&problem, &spec(method)).unwrap();
        let legacy = legacy_uot(method, &cost, &a, &b, lambda, eps);
        assert_bits(&format!("dense UOT {method:?}"), sol.objective, legacy);
    }
}

#[test]
fn oracle_ot_objectives_are_bitwise_identical_to_legacy() {
    // Oracle costs over the SAME entries: every method must sample /
    // materialize its way to the exact same objective as the dense
    // legacy call (every cost arm resolves the one crate-wide
    // sketch_budget convention s0(max(n, m)), so the representation
    // cannot change the sketch).
    let (cost, a, b) = instance(48, 107);
    let eps = 0.1;
    let dense = OtProblem::balanced(&cost, a.clone(), b.clone(), eps);
    let oracle = as_oracle(&dense);
    for method in OT_METHODS {
        let sol = api::solve(&oracle, &spec(method)).unwrap();
        let legacy = legacy_ot(method, &cost, &a, &b, eps);
        assert_bits(&format!("oracle OT {method:?}"), sol.objective, legacy);
    }
}

#[test]
fn oracle_uot_objectives_are_bitwise_identical_to_legacy() {
    let (cost, a, b) = instance(40, 109);
    let a: Vec<f64> = a.iter().map(|x| x * 5.0).collect();
    let b: Vec<f64> = b.iter().map(|x| x * 3.0).collect();
    let (lambda, eps) = (1.0, 0.1);
    let dense = OtProblem::unbalanced(&cost, a.clone(), b.clone(), lambda, eps);
    let oracle = as_oracle(&dense);
    for method in UOT_METHODS {
        let sol = api::solve(&oracle, &spec(method)).unwrap();
        let legacy = legacy_uot(method, &cost, &a, &b, lambda, eps);
        assert_bits(&format!("oracle UOT {method:?}"), sol.objective, legacy);
    }
}

#[test]
fn barycenter_solves_are_bitwise_identical_to_legacy() {
    let n = 32;
    let pts: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 / (n - 1) as f64]).collect();
    let cost = Arc::new(normalize_cost(&sq_euclidean_cost(&pts, &pts)));
    let eps = 0.01;
    let hist = |mu: f64| -> Vec<f64> {
        let w: Vec<f64> =
            pts.iter().map(|p| (-(p[0] - mu).powi(2) / 0.01).exp() + 1e-4).collect();
        let s: f64 = w.iter().sum();
        w.iter().map(|x| x / s).collect()
    };
    let marginals = vec![hist(0.2), hist(0.5), hist(0.8)];
    let weights = vec![1.0 / 3.0; 3];
    let problem =
        OtProblem::barycenter(&cost, marginals.clone(), weights.clone(), eps);
    let kernels = vec![gibbs_kernel(&cost, eps); 3];
    let params = SinkhornParams::default();

    // Exact IBP through the registry's `sinkhorn` entry.
    let exact = api::solve(&problem, &spec(Method::Sinkhorn)).unwrap();
    let legacy = ibp_barycenter(&kernels, &marginals, &weights, &params).unwrap();
    let q = exact.barycenter.as_ref().expect("q");
    assert_eq!(q.len(), legacy.q.len());
    for (i, (x, y)) in q.iter().zip(&legacy.q).enumerate() {
        assert_bits(&format!("ibp q[{i}]"), *x, *y);
    }

    // Spar-IBP through the registry.
    let sol = api::solve(&problem, &spec(Method::SparIbp)).unwrap();
    let mut rng = Rng::seed_from(SEED);
    let legacy =
        spar_ibp(&kernels, &marginals, &weights, S_MULT * s0(n), &params, &mut rng).unwrap();
    let q = sol.barycenter.as_ref().expect("q");
    assert_eq!(sol.stats.len(), 3);
    for (i, (x, y)) in q.iter().zip(&legacy.solution.q).enumerate() {
        assert_bits(&format!("spar-ibp q[{i}]"), *x, *y);
    }
}

/// Barycenter fixture shared by the parity pins below.
fn barycenter_fixture(n: usize, eps: f64) -> (Arc<Mat>, Vec<Vec<f64>>, Vec<f64>, OtProblem) {
    let pts: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 / (n - 1) as f64]).collect();
    let cost = Arc::new(normalize_cost(&sq_euclidean_cost(&pts, &pts)));
    let hist = |mu: f64| -> Vec<f64> {
        let w: Vec<f64> =
            pts.iter().map(|p| (-(p[0] - mu).powi(2) / 0.01).exp() + 1e-4).collect();
        let s: f64 = w.iter().sum();
        w.iter().map(|x| x / s).collect()
    };
    let marginals = vec![hist(0.2), hist(0.5), hist(0.8)];
    let weights = vec![1.0 / 3.0; 3];
    let problem = OtProblem::barycenter(&cost, marginals.clone(), weights.clone(), eps);
    (cost, marginals, weights, problem)
}

fn sup_diff(x: &[f64], y: &[f64]) -> f64 {
    x.iter().zip(y).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max)
}

#[test]
fn log_domain_barycenter_solves_are_bitwise_identical_to_legacy() {
    // The LogDomain override on both barycenter methods must reproduce
    // the legacy log engines bit for bit, exactly as the multiplicative
    // parity test pins the multiplicative pipeline.
    let n = 32;
    let eps = 0.01;
    let (cost, marginals, weights, problem) = barycenter_fixture(n, eps);
    let params = SinkhornParams::default();

    let exact = api::solve(
        &problem,
        &SolverSpec::new(Method::Sinkhorn).with_backend(ScalingBackend::LogDomain),
    )
    .unwrap();
    assert_eq!(exact.backend, Some(BackendKind::LogDomain));
    let legacy = log_ibp_barycenter(&cost, &marginals, &weights, eps, &params).unwrap();
    let q = exact.barycenter.as_ref().expect("q");
    for (i, (x, y)) in q.iter().zip(&legacy.q).enumerate() {
        assert_bits(&format!("log ibp q[{i}]"), *x, *y);
    }

    let sol = api::solve(
        &problem,
        &SolverSpec::new(Method::SparIbp)
            .with_budget(S_MULT)
            .with_seed(SEED)
            .with_backend(ScalingBackend::LogDomain),
    )
    .unwrap();
    assert_eq!(sol.backend, Some(BackendKind::LogDomain));
    let mut rng = Rng::seed_from(SEED);
    let legacy =
        log_spar_ibp(&cost, &marginals, &weights, eps, S_MULT * s0(n), &params, &mut rng)
            .unwrap();
    let q = sol.barycenter.as_ref().expect("q");
    assert_eq!(sol.stats.len(), 3);
    for (i, (x, y)) in q.iter().zip(&legacy.solution.q).enumerate() {
        assert_bits(&format!("log spar-ibp q[{i}]"), *x, *y);
    }
}

#[test]
fn dense_uot_log_override_is_bitwise_identical_to_legacy() {
    let (cost, a, b) = instance(32, 127);
    let a: Vec<f64> = a.iter().map(|x| x * 5.0).collect();
    let b: Vec<f64> = b.iter().map(|x| x * 3.0).collect();
    let (lambda, eps) = (1.0, 0.1);
    let problem = OtProblem::unbalanced(&cost, a.clone(), b.clone(), lambda, eps);
    let sol = api::solve(
        &problem,
        &SolverSpec::new(Method::Sinkhorn).with_backend(ScalingBackend::LogDomain),
    )
    .unwrap();
    assert_eq!(sol.backend, Some(BackendKind::LogDomain));
    let legacy =
        log_sinkhorn_uot(&cost, &a, &b, lambda, eps, &SinkhornParams::default()).unwrap();
    assert_bits("dense UOT log", sol.objective, legacy.objective);
}

#[test]
fn barycenter_backends_agree_at_moderate_eps() {
    // The mult-vs-log wall: where both engines converge, the barycenter
    // histograms agree to 1e-8 sup-norm (the multiplicative q compared
    // after normalization — the log engine normalizes by construction).
    let n = 32;
    let eps = 0.01;
    let (_, _, _, problem) = barycenter_fixture(n, eps);
    let tight = |spec: SolverSpec| spec.with_tolerance(1e-11).with_max_iters(30_000);

    // Dense IBP.
    let mult = api::solve(
        &problem,
        &tight(SolverSpec::new(Method::Sinkhorn).with_backend(ScalingBackend::Multiplicative)),
    )
    .unwrap();
    let logd = api::solve(
        &problem,
        &tight(SolverSpec::new(Method::Sinkhorn).with_backend(ScalingBackend::LogDomain)),
    )
    .unwrap();
    assert!(mult.converged && logd.converged, "both engines must converge for the pin");
    let gap = sup_diff(
        &normalized_histogram(mult.barycenter.as_ref().unwrap()),
        &normalized_histogram(logd.barycenter.as_ref().unwrap()),
    );
    assert!(gap < 1e-8, "dense IBP mult-vs-log sup gap {gap}");

    // Spar-IBP over the SAME sketch (same seed -> same support).
    let mult = api::solve(
        &problem,
        &tight(
            SolverSpec::new(Method::SparIbp)
                .with_budget(40.0)
                .with_seed(SEED)
                .with_backend(ScalingBackend::Multiplicative),
        ),
    )
    .unwrap();
    let logd = api::solve(
        &problem,
        &tight(
            SolverSpec::new(Method::SparIbp)
                .with_budget(40.0)
                .with_seed(SEED)
                .with_backend(ScalingBackend::LogDomain),
        ),
    )
    .unwrap();
    assert!(mult.converged && logd.converged, "both engines must converge for the pin");
    assert_eq!(mult.nnz(), logd.nnz(), "sketch supports diverged");
    let gap = sup_diff(
        &normalized_histogram(mult.barycenter.as_ref().unwrap()),
        &normalized_histogram(logd.barycenter.as_ref().unwrap()),
    );
    assert!(gap < 1e-8, "spar-ibp mult-vs-log sup gap {gap}");
}

#[test]
fn dense_uot_backends_agree_at_moderate_eps() {
    let (cost, a, b) = instance(28, 131);
    let a: Vec<f64> = a.iter().map(|x| x * 2.0).collect();
    let (lambda, eps) = (1.0, 0.1);
    let problem = OtProblem::unbalanced(&cost, a, b, lambda, eps);
    let tight = |backend| {
        SolverSpec::new(Method::Sinkhorn)
            .with_backend(backend)
            .with_tolerance(1e-10)
            .with_max_iters(20_000)
    };
    let mult = api::solve(&problem, &tight(ScalingBackend::Multiplicative)).unwrap();
    let logd = api::solve(&problem, &tight(ScalingBackend::LogDomain)).unwrap();
    assert!(mult.converged && logd.converged);
    let rel = (mult.objective - logd.objective).abs() / logd.objective.abs();
    assert!(rel < 1e-6, "mult {} vs log {}", mult.objective, logd.objective);
}

#[test]
fn small_eps_barycenter_returns_log_domain_probability_vector() {
    // Acceptance criterion: below DEFAULT_LOG_EPS_THRESHOLD the default
    // spec serves the log engine and a finite, normalized q — where the
    // multiplicative path previously errored, collapsed or was rejected.
    let n = 32;
    let (_, _, _, problem) = barycenter_fixture(n, 5e-4);
    for method in [Method::Sinkhorn, Method::SparIbp] {
        let sol = api::solve(&problem, &spec(method)).unwrap();
        assert_eq!(sol.backend, Some(BackendKind::LogDomain), "{method:?}");
        let q = sol.barycenter.as_ref().expect("q");
        assert!(q.iter().all(|x| x.is_finite() && *x >= 0.0), "{method:?}");
        let mass: f64 = q.iter().sum();
        assert!((mass - 1.0).abs() < 1e-9, "{method:?} mass {mass}");
    }
}

#[test]
fn formulation_mismatches_are_rejected() {
    let (cost, a, b) = instance(16, 113);
    let balanced = OtProblem::balanced(&cost, a, b, 0.1);
    assert!(api::solve(&balanced, &spec(Method::SparIbp)).is_err());
    let mut unbalanced = balanced.clone();
    unbalanced.formulation = Formulation::Unbalanced { lambda: 1.0 };
    for method in [Method::Greenkhorn, Method::Screenkhorn] {
        assert!(api::solve(&unbalanced, &spec(method)).is_err(), "{method:?}");
    }
}
