//! Appendix Fig. 8 — sensitivity of the UOT comparison to the marginal
//! regularization λ ∈ {0.1, 1, 5} across R1-R3.

use super::common::{exact_uot, rmae_over_reps, row, run_method_uot, wfr_cost_at_density, Method};
use super::{ExperimentOutput, Profile};
use crate::data::synthetic::{instance, Scenario, SparsityRegime};
use crate::rng::Rng;
use crate::util::json::Json;
use crate::util::table::{f, Table};

/// Figure 8: sensitivity of the UOT estimate to the marginal relaxation λ.
pub fn run(profile: Profile) -> ExperimentOutput {
    let n = profile.pick(300, 1000);
    let reps = profile.reps(5, 100);
    let d = 5;
    let eps = 0.1;
    let lambdas = [0.1, 1.0, 5.0];
    let s_mults = profile.pick(vec![4.0, 16.0], vec![2.0, 4.0, 8.0, 16.0]);

    let mut table = Table::new(&["lambda", "regime", "method", "s/s0", "rmae", "se"]);
    let mut rows = Vec::new();
    let mut rng = Rng::seed_from(0xF168);
    for &lambda in &lambdas {
        for regime in SparsityRegime::all() {
            let inst = instance(Scenario::C1, n, d, 5.0, 3.0, &mut rng);
            let cost = wfr_cost_at_density(&inst.points, regime.density());
            let Ok(truth) = exact_uot(&cost, &inst.a, &inst.b, lambda, eps) else {
                continue;
            };
            for method in Method::all() {
                for &s_mult in &s_mults {
                    let (rmae, se, _) = rmae_over_reps(
                        reps,
                        truth,
                        |r| run_method_uot(method, &cost, &inst.a, &inst.b, lambda, eps, s_mult, r),
                        &mut rng,
                    );
                    table.row(vec![
                        f(lambda, 1),
                        regime.name().into(),
                        method.name().into(),
                        f(s_mult, 0),
                        f(rmae, 4),
                        f(se, 4),
                    ]);
                    rows.push(row(vec![
                        ("lambda", Json::num(lambda)),
                        ("regime", Json::str(regime.name())),
                        ("method", Json::str(method.name())),
                        ("s_mult", Json::num(s_mult)),
                        ("rmae", Json::num(rmae)),
                    ]));
                }
            }
        }
    }
    let text = format!(
        "Appendix Fig. 8 — lambda sensitivity (n = {n}, eps = {eps}, {reps} reps)\n{}",
        table.render()
    );
    ExperimentOutput { id: "fig8", text, rows: Json::arr(rows) }
}
