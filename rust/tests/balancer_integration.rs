//! The balancer's contract wall — real sockets, real multi-process
//! topology (N gateway backends in one test binary, each with its own
//! coordinator and artifact cache):
//!
//! * fingerprint affinity is cache locality: K distinct-fingerprint
//!   jobs replayed over 2 backends cost exactly K artifact builds
//!   fleet-wide (scraped from each backend's own `/metrics`), not 2K;
//! * a job through the balancer solves BITWISE-identically to the same
//!   job submitted in-process — the extra hop cannot change a number;
//! * killing a backend mid-burst loses no accepted job: every client
//!   that got a `200` got a real answer, and later jobs fail over;
//! * a drained backend is evicted on its first `503` and re-admitted by
//!   the health probe once a replacement listens on the same port,
//!   while the in-flight job it was solving completes normally;
//! * retry-budget exhaustion is a loud, prompt `503` — never a hang.
//!
//! Runs in the CI cache-parity job (release) alongside the gateway
//! wall.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use spar_sink::coordinator::{
    BarycenterJob, CoordinatorConfig, DistanceJob, DistanceService, Measure, Method, ProblemSpec,
};
use spar_sink::net::codec;
use spar_sink::net::gateway::spawn_backends;
use spar_sink::net::{Balancer, BalancerConfig, Gateway, GatewayConfig};
use spar_sink::util::json::Json;

// ---------------------------------------------------------------- helpers

struct HttpResponse {
    status: u16,
    body: Vec<u8>,
}

impl HttpResponse {
    fn json(&self) -> Json {
        Json::parse(std::str::from_utf8(&self.body).expect("utf-8 body")).expect("json body")
    }

    fn text(&self) -> String {
        String::from_utf8(self.body.clone()).expect("utf-8 body")
    }
}

fn read_response<R: BufRead>(reader: &mut R) -> HttpResponse {
    let mut status_line = String::new();
    reader.read_line(&mut status_line).expect("status line");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line '{status_line}'"));
    let mut length = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).expect("header line");
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        let (name, value) = line.split_once(':').expect("header colon");
        if name.trim().eq_ignore_ascii_case("content-length") {
            length = value.trim().parse().expect("content-length value");
        }
    }
    let mut body = vec![0u8; length];
    reader.read_exact(&mut body).expect("body");
    HttpResponse { status, body }
}

/// One request/response round trip on a fresh connection. The long
/// timeout covers stalled-worker jobs held deliberately in flight.
fn request(addr: SocketAddr, method: &str, path: &str, body: &[u8]) -> HttpResponse {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(300))).expect("timeout");
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nconnection: close\r\ncontent-length: {}\r\n\r\n",
        body.len()
    )
    .expect("request head");
    stream.write_all(body).expect("request body");
    read_response(&mut BufReader::new(stream))
}

fn post_json(addr: SocketAddr, path: &str, payload: &Json) -> HttpResponse {
    request(addr, "POST", path, payload.to_string_compact().as_bytes())
}

fn bits(v: &Json, key: &str) -> u64 {
    v.get(key)
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("missing numeric field '{key}'"))
        .to_bits()
}

/// The value of an unlabeled sample `name <value>` on a Prometheus
/// text page.
fn prom_value(page: &str, name: &str) -> f64 {
    page.lines()
        .find_map(|line| line.strip_prefix(name).and_then(|rest| rest.strip_prefix(' ')))
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("no sample '{name}' in:\n{page}"))
}

fn scrape(addr: SocketAddr) -> String {
    let resp = request(addr, "GET", "/metrics", b"");
    assert_eq!(resp.status, 200);
    resp.text()
}

// ----------------------------------------------------------- job fixtures

fn toy_measure(seed: u64, n: usize, mass: f64) -> Measure {
    let mut rng = spar_sink::rng::Rng::seed_from(seed);
    let points: Vec<Vec<f64>> =
        (0..n).map(|_| vec![rng.uniform() * 10.0, rng.uniform() * 10.0]).collect();
    let mut weights: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.1).collect();
    let total: f64 = weights.iter().sum();
    weights.iter_mut().for_each(|w| *w *= mass / total);
    Measure::new(points, weights)
}

/// Distinct `id`s give distinct supports, hence distinct fingerprints.
fn distance_job(id: u64) -> DistanceJob {
    DistanceJob {
        id,
        source: toy_measure(1000 + id, 40, 1.0),
        target: toy_measure(2000 + id, 40, 1.2),
        method: Method::SparSink,
        spec: ProblemSpec { eta: 3.0, eps: 0.05, ..ProblemSpec::default() },
        seed: 42 + id,
    }
}

fn barycenter_job(id: u64) -> BarycenterJob {
    let n = 32;
    let support: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 / (n - 1) as f64]).collect();
    let bump = |mu: f64| -> Vec<f64> {
        let raw: Vec<f64> =
            support.iter().map(|p| (-(p[0] - mu).powi(2) / 0.01).exp() + 1e-4).collect();
        let total: f64 = raw.iter().sum();
        raw.iter().map(|x| x / total).collect()
    };
    BarycenterJob {
        id,
        marginals: vec![bump(0.25), bump(0.75)],
        support: Arc::new(support),
        weights: vec![0.5, 0.5],
        method: Method::SparIbp,
        spec: ProblemSpec { eps: 0.01, s_multiplier: 40.0, ..ProblemSpec::default() },
        seed: 7,
    }
}

/// A job that holds its worker for a long time: δ = 0 never converges,
/// so the solver runs the full iteration budget.
fn stalled_worker_job(id: u64) -> DistanceJob {
    DistanceJob {
        id,
        source: toy_measure(1, 64, 1.0),
        target: toy_measure(2, 64, 1.2),
        method: Method::Sinkhorn,
        spec: ProblemSpec {
            eps: 0.05,
            eta: 3.0,
            delta: 0.0,
            max_iters: 40_000,
            ..ProblemSpec::default()
        },
        seed: 0,
    }
}

fn default_coordinator() -> CoordinatorConfig {
    CoordinatorConfig { workers: 2, shards: 1, ..CoordinatorConfig::default() }
}

/// A balancer over `backends` with test-speed probes and backoffs.
fn balancer_over(backends: &[Gateway]) -> Balancer {
    Balancer::start(BalancerConfig {
        backends: backends.iter().map(|g| g.local_addr().to_string()).collect(),
        probe_interval: Duration::from_millis(50),
        retry_backoff: Duration::from_millis(10),
        backoff_cap: Duration::from_millis(40),
        ..BalancerConfig::default()
    })
    .expect("balancer start")
}

// ----------------------------------------------------------------- tests

#[test]
fn affinity_keeps_fleet_cache_misses_at_the_distinct_fingerprint_count() {
    // K distinct fingerprints, each job replayed 3 times over 2
    // backends. Affinity pins every fingerprint to ONE backend, so the
    // fleet builds exactly K artifact sets; round-robin replays would
    // rebuild on the other backend and the fleet-wide miss count would
    // drift toward 2K.
    const K: u64 = 4;
    let mut backends = spawn_backends(2, &default_coordinator()).expect("backends start");
    let mut balancer = balancer_over(&backends);
    let addr = balancer.local_addr();

    let mut first_bits: Vec<(u64, u64)> = Vec::new();
    for round in 0..3 {
        for id in 0..K {
            let resp = post_json(addr, "/solve", &codec::distance_job_json(&distance_job(id)));
            assert_eq!(resp.status, 200, "round {round} job {id}");
            let wire = resp.json();
            assert!(wire.get("error").is_none(), "round {round} job {id}");
            let got = (bits(&wire, "distance"), bits(&wire, "objective"));
            if round == 0 {
                first_bits.push(got);
            } else {
                // Replays land on the same backend's warm cache and
                // come back bitwise-equal.
                assert_eq!(first_bits[id as usize], got, "round {round} job {id}");
            }
        }
    }

    // Scraped from each backend's OWN metrics page: per-service caches,
    // summed fleet-wide.
    let fleet_misses: f64 = backends
        .iter()
        .map(|g| prom_value(&scrape(g.local_addr()), "spar_sink_cache_misses_total"))
        .sum();
    assert_eq!(fleet_misses, K as f64, "affinity must build each fingerprint exactly once");

    // Every post had a fingerprint and a healthy home slot: all affine,
    // none round-robin.
    let stats = balancer.stats();
    assert_eq!(stats.iter().map(|s| s.routed_affine).sum::<u64>(), 3 * K);
    assert_eq!(stats.iter().map(|s| s.routed_round_robin).sum::<u64>(), 0);
    assert_eq!(stats.iter().map(|s| s.completed).sum::<u64>(), 3 * K);

    // The balancer's own /metrics page serves the per-backend families.
    let page = scrape(addr);
    for backend in 0..2 {
        assert!(
            page.contains(&format!("spar_sink_balancer_backend_healthy{{backend=\"{backend}\"")),
            "{page}"
        );
    }

    balancer.drain();
    for gateway in &mut backends {
        gateway.drain();
    }
}

#[test]
fn balancer_round_trip_is_bitwise_equal_to_in_process_submit() {
    // Same jobs through (a) an in-process reference service and (b) the
    // balancer → gateway → coordinator chain. Results are pure
    // functions of the job, so any drift is a proxy layer corrupting a
    // float.
    let mut backends = spawn_backends(2, &default_coordinator()).expect("backends start");
    let reference = DistanceService::start(default_coordinator());
    let mut balancer = balancer_over(&backends);
    let addr = balancer.local_addr();

    for id in 0..3 {
        let job = distance_job(id);
        let expected = reference.submit(job.clone()).unwrap().recv().unwrap();
        assert!(expected.error.is_none(), "{:?}", expected.error);
        let resp = post_json(addr, "/solve", &codec::distance_job_json(&job));
        assert_eq!(resp.status, 200);
        let wire = resp.json();
        assert_eq!(bits(&wire, "distance"), expected.distance.to_bits(), "job {id}");
        assert_eq!(bits(&wire, "objective"), expected.objective.to_bits(), "job {id}");
    }

    let bary = barycenter_job(9);
    let expected = reference.submit_barycenter(bary.clone()).unwrap().recv().unwrap();
    assert!(expected.error.is_none(), "{:?}", expected.error);
    let resp = post_json(addr, "/barycenter", &codec::barycenter_job_json(&bary));
    assert_eq!(resp.status, 200);
    let q = resp.json().get("q").expect("barycenter q").items().to_vec();
    assert_eq!(q.len(), expected.q.len());
    for (sent, got) in q.iter().zip(expected.q.iter()) {
        assert_eq!(sent.as_f64().unwrap().to_bits(), got.to_bits());
    }

    reference.shutdown();
    balancer.drain();
    for gateway in &mut backends {
        gateway.drain();
    }
}

#[test]
fn backend_kill_mid_burst_loses_no_accepted_job_and_fails_over() {
    let mut backends = spawn_backends(2, &default_coordinator()).expect("backends start");
    let mut balancer = balancer_over(&backends);
    let addr = balancer.local_addr();

    // 6 clients, 4 jobs each, while the main thread kills backend 1
    // partway through. The contract: every response is a 200 carrying
    // the right job id — a kill may slow a job down (failover + retry)
    // but may never lose or corrupt one.
    let clients: Vec<_> = (0..6u64)
        .map(|client| {
            std::thread::spawn(move || {
                for round in 0..4u64 {
                    let id = client * 4 + round;
                    let resp =
                        post_json(addr, "/solve", &codec::distance_job_json(&distance_job(id)));
                    assert_eq!(resp.status, 200, "client {client} round {round}");
                    let wire = resp.json();
                    assert_eq!(
                        wire.get("id").and_then(Json::as_f64),
                        Some(id as f64),
                        "client {client} round {round}"
                    );
                    assert!(wire.get("error").is_none(), "client {client} round {round}");
                    let distance = wire.get("distance").and_then(Json::as_f64).unwrap();
                    assert!(distance.is_finite() && distance >= 0.0, "job {id}: {distance}");
                }
            })
        })
        .collect();

    // Kill one backend mid-burst: its drop drains gracefully (in-flight
    // proxied jobs complete) and then its listener is gone, so later
    // attempts evict it and fail over.
    std::thread::sleep(Duration::from_millis(100));
    drop(backends.remove(1));
    for client in clients {
        client.join().expect("burst client");
    }

    // The survivor keeps serving through the balancer.
    let resp = post_json(addr, "/solve", &codec::distance_job_json(&distance_job(99)));
    assert_eq!(resp.status, 200);

    // The dead backend is evicted (by a failed proxy attempt or by the
    // health probe — whichever saw it first).
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let stats = balancer.stats();
        if !stats[1].healthy {
            assert!(stats[1].evictions >= 1);
            break;
        }
        assert!(Instant::now() < deadline, "backend 1 never evicted: {stats:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
    // 24 burst jobs + 1 failover probe job, all completed somewhere.
    let stats = balancer.stats();
    assert_eq!(stats.iter().map(|s| s.completed).sum::<u64>(), 25);

    balancer.drain();
    backends[0].drain();
}

#[test]
fn drain_evicts_completes_in_flight_and_probe_readmits_on_recovery() {
    let mut backends = spawn_backends(1, &default_coordinator()).expect("backend starts");
    let gateway = backends.remove(0);
    let port = gateway.local_addr().port();
    let mut balancer = balancer_over(std::slice::from_ref(&gateway));
    let addr = balancer.local_addr();

    // Sanity: the chain serves before the fault.
    assert_eq!(
        post_json(addr, "/solve", &codec::distance_job_json(&distance_job(0))).status,
        200
    );

    // Park a long job in flight through the balancer, then put the
    // backend into probe-visible drain: its accept loop keeps answering
    // (503 to new jobs) while in-flight work completes.
    let in_flight = std::thread::spawn(move || {
        post_json(addr, "/solve", &codec::distance_job_json(&stalled_worker_job(1)))
    });
    std::thread::sleep(Duration::from_millis(300));
    gateway.begin_drain();

    // A new job meets the draining backend: first 503 evicts it, and
    // with no other backend the balancer answers a loud 503 instead of
    // hanging.
    let resp = post_json(addr, "/solve", &codec::distance_job_json(&distance_job(2)));
    assert_eq!(resp.status, 503);
    let stats = balancer.stats();
    assert!(stats[0].evictions >= 1, "{stats:?}");
    assert!(!stats[0].healthy, "{stats:?}");

    // The fault injection cost the in-flight job nothing.
    let resp = in_flight.join().expect("in-flight client");
    assert_eq!(resp.status, 200);
    assert_eq!(resp.json().get("id").and_then(Json::as_f64), Some(1.0));

    // Recovery: retire the drained process and stand a fresh one up on
    // the SAME port (the balancer's backend list is fixed at start).
    drop(gateway);
    let service = Arc::new(DistanceService::start(default_coordinator()));
    let replacement = Gateway::start(
        service,
        GatewayConfig { port, ..GatewayConfig::default() },
    )
    .expect("replacement gateway binds the vacated port");

    // The health probe is the only re-admission path; wait for it.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let stats = balancer.stats();
        if stats[0].healthy {
            assert!(stats[0].readmissions >= 1, "{stats:?}");
            break;
        }
        assert!(Instant::now() < deadline, "backend never re-admitted: {stats:?}");
        std::thread::sleep(Duration::from_millis(20));
    }

    // Jobs route to the replacement again.
    let resp = post_json(addr, "/solve", &codec::distance_job_json(&distance_job(3)));
    assert_eq!(resp.status, 200);

    balancer.drain();
    drop(replacement);
}

#[test]
fn retry_budget_exhaustion_is_a_loud_503_not_a_hang() {
    // One deliberately starved backend: 1 worker, queue of 1, batches
    // of 1, occupied by never-converging jobs — it answers 429 for as
    // long as the test cares to ask.
    let mut backends = spawn_backends(
        1,
        &CoordinatorConfig {
            workers: 1,
            shards: 1,
            queue_cap: 1,
            max_batch: 1,
            batch_window: Duration::from_millis(1),
            ..CoordinatorConfig::default()
        },
    )
    .expect("backend starts");
    let backend_addr = backends[0].local_addr();
    let occupiers: Vec<_> = (0..4u64)
        .map(|id| {
            std::thread::spawn(move || {
                post_json(
                    backend_addr,
                    "/solve",
                    &codec::distance_job_json(&stalled_worker_job(id)),
                )
                .status
            })
        })
        .collect();
    // Let the occupiers saturate the pipeline before measuring.
    std::thread::sleep(Duration::from_millis(300));

    let mut balancer = Balancer::start(BalancerConfig {
        backends: vec![backend_addr.to_string()],
        retry_budget: 2,
        retry_backoff: Duration::from_millis(10),
        backoff_cap: Duration::from_millis(40),
        ..BalancerConfig::default()
    })
    .expect("balancer start");

    let t0 = Instant::now();
    let resp =
        post_json(balancer.local_addr(), "/solve", &codec::distance_job_json(&distance_job(5)));
    let elapsed = t0.elapsed();
    assert_eq!(resp.status, 503);
    let error = resp.json().get("error").and_then(Json::as_str).expect("error body").to_string();
    assert!(error.contains("retry budget exhausted after 2 attempts"), "{error}");
    assert!(error.contains("429"), "{error}");
    // Loud means prompt: two attempts with clamped backoff, not a
    // wait-for-the-queue hang.
    assert!(elapsed < Duration::from_secs(30), "{elapsed:?}");

    // Saturation never evicts: 429 is a healthy backend saying "later".
    let stats = balancer.stats();
    assert_eq!(stats[0].evictions, 0, "{stats:?}");
    assert!(stats[0].healthy, "{stats:?}");
    assert!(stats[0].retried >= 2, "{stats:?}");

    balancer.drain();
    for status in occupiers.into_iter().map(|c| c.join().expect("occupier")) {
        assert!(status == 200 || status == 429, "{status}");
    }
    backends[0].drain();
}
