//! Boundary fixture for the wall-clock rule at the bench seam:
//! harness code that times kernels with `Instant::now` and sizes its
//! runner off machine shape. Under a `bench/` path (e.g. the
//! `bench/kernels.rs` hot-loop arm) this must lint clean — the harness
//! OWNS timing; measurements never feed back into solver results. The
//! SAME text under `sparse/` or `ot/` must fire once per token line:
//! a clock read inside the kernels being measured would make results
//! depend on when/where the run happened.

use std::time::{Duration, Instant};

/// Time one closure invocation, the harness's innermost measurement.
pub fn time_once(f: impl FnOnce()) -> Duration {
    let t0 = Instant::now();
    f();
    t0.elapsed()
}

/// Default sample cap: scale with the core count, floor of 8.
pub fn default_sample_cap() -> usize {
    std::thread::available_parallelism().map(|n| n.get() * 4).unwrap_or(8)
}
