//! Minimal JSON value model + writer/parser (no serde in the offline
//! image). Covers exactly what the experiment harness and the artifact
//! manifest need: objects, arrays, strings, numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (always stored as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array of values.
    Arr(Vec<Json>),
    /// An object (sorted keys, so serialization is deterministic).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Wrap a number.
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// Wrap a string.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Wrap an array.
    pub fn arr(xs: Vec<Json>) -> Json {
        Json::Arr(xs)
    }

    /// Access object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Access array elements.
    pub fn items(&self) -> &[Json] {
        match self {
            Json::Arr(v) => v,
            _ => &[],
        }
    }

    /// The numeric value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The string value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (recursive descent; enough for manifests).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| format!("non-ASCII bytes in number at byte {start}"))?;
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{s}': {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or("bad \\u escape")?,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8")?;
                    let c = rest.chars().next().ok_or("unterminated string")?;
                    out.push(c);
                    self.pos += c.len_utf8();
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("name", Json::str("fig2")),
            ("rmae", Json::num(0.125)),
            ("n", Json::num(1000.0)),
            ("series", Json::arr(vec![Json::num(1.0), Json::num(2.0)])),
            ("ok", Json::Bool(true)),
        ]);
        let s = j.to_string_compact();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{
            "block_iters": 10,
            "artifacts": [
                {"entry": "sinkhorn_block", "n": 64, "file": "sinkhorn_block_n64.hlo.txt"}
            ]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("block_iters").unwrap().as_f64(), Some(10.0));
        let arts = j.get("artifacts").unwrap().items();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("entry").unwrap().as_str(), Some("sinkhorn_block"));
    }

    #[test]
    fn escapes() {
        let j = Json::str("a\"b\\c\nd");
        let s = j.to_string_compact();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("123abc").is_err());
    }

    #[test]
    fn nonfinite_to_null() {
        assert_eq!(Json::num(f64::NAN).to_string_compact(), "null");
    }
}
