//! From-scratch PRNG substrate (the build image has no `rand` crate).
//!
//! * [`Rng`] — xoshiro256++ core (Blackman & Vigna), seeded through
//!   SplitMix64 so any `u64` seed yields a well-mixed state.
//! * Distributions needed by the paper's workloads: uniform, standard
//!   normal (Box–Muller), Student-t (normal over scaled chi), gamma
//!   (Marsaglia–Tsang) and chi-square, plus discrete helpers
//!   (Bernoulli, weighted choice, shuffling).
//!
//! Determinism: every experiment takes an explicit seed; runs are
//! reproducible bit-for-bit on the same build.

mod distributions;

pub use distributions::*;

/// xoshiro256++ PRNG.
///
/// Period 2^256 − 1; passes BigCrush. Not cryptographically secure —
/// used only for Monte-Carlo workloads and the Poisson sparsifier.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    cached_normal: Option<f64>,
}

#[inline(always)]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// SplitMix64 step — used to expand a single `u64` seed into the
/// 256-bit xoshiro state (the construction recommended by the authors).
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed from a single `u64` via SplitMix64 expansion.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Next raw 64-bit output (xoshiro256++ scrambler).
    #[inline(always)]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform f64 in [0, 1) with 53-bit resolution.
    #[inline(always)]
    pub fn uniform(&mut self) -> f64 {
        // Take the top 53 bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline(always)]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (Lemire's multiply-shift rejection).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Bernoulli trial with success probability `p`.
    #[inline(always)]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Derive an independent child generator (for per-thread streams).
    pub fn split(&mut self) -> Rng {
        Rng::seed_from(self.next_u64() ^ 0xA5A5_A5A5_DEAD_BEEF)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices uniformly from [0, n) (partial
    /// Fisher–Yates over an index array; O(n) memory, O(k) swaps).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    pub(crate) fn take_cached_normal(&mut self) -> Option<f64> {
        self.cached_normal.take()
    }

    pub(crate) fn set_cached_normal(&mut self, v: f64) {
        self.cached_normal = Some(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::seed_from(7);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::seed_from(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::seed_from(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.gen_range(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seed_from(11);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(13);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent_ish() {
        let mut parent = Rng::seed_from(5);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let same = (0..32).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
