"""L1 — Pallas kernels for the Sinkhorn scaling iteration hot-spot.

The Sinkhorn/unbalanced-Sinkhorn iteration is dominated by the pair of
kernel-matrix/vector products ``z = K v`` and ``z' = K^T u`` followed by an
element-wise scaling update ``u = (a / z) ** rho`` (``rho = 1`` for balanced
OT, ``rho = lambda / (lambda + eps)`` for UOT; see Algorithms 1-2 of the
paper).  These kernels tile ``K`` into (block_rows x block_cols) VMEM tiles
with a 2-D grid; the inner grid dimension streams column (resp. row) tiles
into an output-resident accumulator and the division epilogue is fused into
the final tile so the intermediate ``z`` never round-trips to HBM.

Hardware adaptation (see DESIGN.md §6): the paper's CUDA-oriented dense BLAS
hot-spot becomes a BlockSpec-scheduled HBM->VMEM tile stream; on a real TPU
the (bn x bm) @ (bm x 1) products map onto the MXU.  Everything here is
lowered with ``interpret=True`` because the CPU PJRT plugin cannot execute
Mosaic custom-calls; numerics are validated against ``ref.py`` in pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes.  128 matches the MXU lane width; callers may override
# (tests sweep small tiles).  Shapes must be divisible by the tile size —
# `aot.py` only emits sizes from the supported menu, and the Rust runtime
# zero-pads requests up to the next menu size.
DEFAULT_BLOCK_ROWS = 128
DEFAULT_BLOCK_COLS = 128


def _kv_scale_kernel(k_ref, v_ref, a_ref, u_ref, *, n_col_tiles):
    """One (row-tile, col-tile) grid step of ``u = a / (K @ v)``.

    The output block is revisited by every column tile (its index map is
    constant in ``c``), so it doubles as the VMEM accumulator for the
    partial row sums; the last column tile applies the fused division
    epilogue in place.
    """
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        u_ref[...] = jnp.zeros_like(u_ref)

    u_ref[...] += k_ref[...] @ v_ref[...]

    @pl.when(c == n_col_tiles - 1)
    def _epilogue():
        u_ref[...] = a_ref[...] / u_ref[...]


def _ktu_scale_kernel(k_ref, u_ref, b_ref, v_ref, *, n_row_tiles):
    """One (col-tile, row-tile) grid step of ``v = b / (K.T @ u)``."""
    r = pl.program_id(1)

    @pl.when(r == 0)
    def _init():
        v_ref[...] = jnp.zeros_like(v_ref)

    v_ref[...] += k_ref[...].T @ u_ref[...]

    @pl.when(r == n_row_tiles - 1)
    def _epilogue():
        v_ref[...] = b_ref[...] / v_ref[...]


def _check_tiling(n: int, m: int, bn: int, bm: int) -> None:
    if n % bn != 0 or m % bm != 0:
        raise ValueError(
            f"matrix ({n}x{m}) not divisible by tile ({bn}x{bm}); "
            "pad to the artifact size menu first"
        )


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols"))
def kv_scale(
    kmat: jax.Array,
    v: jax.Array,
    a: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_cols: int = DEFAULT_BLOCK_COLS,
) -> jax.Array:
    """``u = a / (K @ v)`` via the tiled Pallas kernel.

    Args:
      kmat: (n, m) kernel matrix.
      v:    (m, 1) scaling column.
      a:    (n, 1) source marginal.
    Returns:
      (n, 1) updated scaling ``u`` (before any UOT exponent).
    """
    n, m = kmat.shape
    bn = min(block_rows, n)
    bm = min(block_cols, m)
    _check_tiling(n, m, bn, bm)
    n_col_tiles = m // bm
    kernel = functools.partial(_kv_scale_kernel, n_col_tiles=n_col_tiles)
    return pl.pallas_call(
        kernel,
        grid=(n // bn, n_col_tiles),
        in_specs=[
            pl.BlockSpec((bn, bm), lambda r, c: (r, c)),
            pl.BlockSpec((bm, 1), lambda r, c: (c, 0)),
            pl.BlockSpec((bn, 1), lambda r, c: (r, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda r, c: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), kmat.dtype),
        interpret=True,
    )(kmat, v, a)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols"))
def ktu_scale(
    kmat: jax.Array,
    u: jax.Array,
    b: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_cols: int = DEFAULT_BLOCK_COLS,
) -> jax.Array:
    """``v = b / (K.T @ u)`` via the tiled Pallas kernel.

    Args:
      kmat: (n, m) kernel matrix (NOT pre-transposed).
      u:    (n, 1) scaling column.
      b:    (m, 1) target marginal.
    Returns:
      (m, 1) updated scaling ``v`` (before any UOT exponent).
    """
    n, m = kmat.shape
    bn = min(block_rows, n)
    bm = min(block_cols, m)
    _check_tiling(n, m, bn, bm)
    n_row_tiles = n // bn
    kernel = functools.partial(_ktu_scale_kernel, n_row_tiles=n_row_tiles)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n_row_tiles),
        in_specs=[
            pl.BlockSpec((bn, bm), lambda c, r: (r, c)),
            pl.BlockSpec((bn, 1), lambda c, r: (r, 0)),
            pl.BlockSpec((bm, 1), lambda c, r: (c, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda c, r: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), kmat.dtype),
        interpret=True,
    )(kmat, u, b)
