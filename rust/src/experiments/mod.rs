//! Experiment harness: one module per figure/table of the paper's
//! evaluation (see DESIGN.md §4 for the full index). Each experiment
//! prints the same rows/series the paper reports and returns them as
//! JSON for EXPERIMENTS.md.
//!
//! Run via `repro experiment <id> [--full]`; the default "quick" profile
//! shrinks n/replications to keep a full sweep in CI-scale time while
//! preserving the comparisons' *shape* (who wins, by what factor).

pub mod ablation;
#[macro_use]
pub mod common;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig7;
pub mod fig8;
pub mod fig9_10;
pub mod small_eps;
pub mod table1;
pub mod table2;
pub mod theory;

use crate::util::json::Json;

/// Effort profile.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Profile {
    /// Reduced n / replications; preserves comparison shape.
    Quick,
    /// Paper-scale parameters (n = 1000, 100 replications, …).
    Full,
}

impl Profile {
    /// Replication count for this profile.
    pub fn reps(&self, quick: usize, full: usize) -> usize {
        match self {
            Profile::Quick => quick,
            Profile::Full => full,
        }
    }

    /// Pick the profile-appropriate value of any parameter.
    pub fn pick<T>(&self, quick: T, full: T) -> T {
        match self {
            Profile::Quick => quick,
            Profile::Full => full,
        }
    }
}

/// An experiment's output: rendered text + structured rows.
pub struct ExperimentOutput {
    /// Registry id (e.g. `fig2`), used as the JSON output filename.
    pub id: &'static str,
    /// Rendered table/series text, as printed by the CLI.
    pub text: String,
    /// The same rows as structured JSON (for EXPERIMENTS.md).
    pub rows: Json,
}

type Runner = fn(Profile) -> ExperimentOutput;

/// All registered experiments in paper order.
pub fn registry() -> Vec<(&'static str, &'static str, Runner)> {
    vec![
        ("fig2", "RMAE(OT) vs subsample size s (C1-C3 x eps x d)", fig2::run),
        ("fig3", "RMAE(UOT/WFR) vs s (C1-C3 x R1-R3)", fig3::run),
        ("fig4", "RMAE(OT) vs n incl. Greenkhorn/Screenkhorn", fig4::run),
        ("fig5", "CPU time vs n (OT & UOT)", fig5::run),
        ("fig7", "cardiac cycle visualization (3 conditions)", fig7::run),
        ("fig8", "lambda sensitivity (UOT)", fig8::run),
        ("fig9", "RMAE(OT) vs n, asymptotics", fig9_10::run_fig9),
        ("fig10", "RMAE(UOT) vs n, asymptotics", fig9_10::run_fig10),
        ("fig11", "barycenter error vs s (Spar-IBP)", fig11::run),
        ("fig12", "digit barycenters: IBP vs Spar-IBP", fig12::run),
        ("fig13", "color transfer map deviation + time", fig13::run),
        ("table1", "echo ED-prediction error & time", table1::run),
        ("table2", "Sinkhorn divergence (SSAE ingredient)", table2::run),
        ("ablation", "shrinkage theta + sampling-scheme ablations", ablation::run),
        ("theory", "empirical validation of Lemma 5 / Theorems 1 & 3", theory::run),
        ("smalleps", "small-eps stability: multiplicative vs log-domain backend", small_eps::run),
    ]
}

/// Look up and run one experiment (or "all").
pub fn run(id: &str, profile: Profile) -> Result<Vec<ExperimentOutput>, String> {
    let reg = registry();
    if id == "all" {
        return Ok(reg.into_iter().map(|(_, _, f)| f(profile)).collect());
    }
    match reg.into_iter().find(|(name, _, _)| *name == id) {
        Some((_, _, f)) => Ok(vec![f(profile)]),
        None => Err(format!(
            "unknown experiment '{id}'; available: {}",
            registry()
                .iter()
                .map(|(n, _, _)| *n)
                .collect::<Vec<_>>()
                .join(", ")
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_unique() {
        let reg = registry();
        let mut names: Vec<&str> = reg.iter().map(|(n, _, _)| *n).collect();
        names.sort_unstable();
        let len = names.len();
        names.dedup();
        assert_eq!(names.len(), len);
    }

    #[test]
    fn unknown_experiment_is_an_error() {
        assert!(run("nope", Profile::Quick).is_err());
    }
}
