//! Small-ε stability harness: sweeps ε across and below the
//! multiplicative underflow point and reports, per scaling backend,
//! failure counts and error against the stable log-domain truth — for
//! the balanced-OT sketch path AND the Spar-IBP barycenter path.
//!
//! With the cost normalized to c₀ = 1, `K = exp(−C/ε)` loses its last
//! representable entries around ε ≈ c₀/708 ≈ 1.4×10⁻³ — below that,
//! the multiplicative loops either error, collapse onto the degenerate
//! all-zero plan, or (IBP's guarded geometric mean) converge onto a
//! zero histogram, which is exactly what this sweep makes visible
//! (`fail` counts plus error ≈ 1). The log-domain backends (and `Auto`,
//! which escalates to them) keep solving every formulation.

use super::common::{exact_ot_stable, ot_cost, rmae_over_reps, row};
use super::{ExperimentOutput, Profile};
use crate::api::{self, Method, OtProblem, SolverSpec};
use crate::data::synthetic::{barycenter_measures, instance, Scenario};
use crate::metrics::{l1_distance, mean_sd, normalized_histogram};
use crate::rng::Rng;
use crate::solvers::backend::ScalingBackend;
use crate::util::json::Json;
use crate::util::table::{f, Table};

/// The backend sweep shared by the OT and barycenter legs.
fn backends() -> [(&'static str, ScalingBackend); 3] {
    [
        ("multiplicative", ScalingBackend::Multiplicative),
        ("log", ScalingBackend::LogDomain),
        ("auto", ScalingBackend::default()),
    ]
}

/// Small-ε stability: multiplicative vs log-domain backend across formulations.
pub fn run(profile: Profile) -> ExperimentOutput {
    let n = profile.pick(120, 500);
    let reps = profile.reps(3, 20);
    let s_mult = 16.0;
    let mut rng = Rng::seed_from(0x5E95);
    let inst = instance(Scenario::C1, n, 5, 1.0, 1.0, &mut rng);
    let cost = ot_cost(&inst.points);

    let mut table = Table::new(&["problem", "eps", "backend", "err", "se", "fail", "truth"]);
    let mut rows = Vec::new();
    for &eps in &[1e-1, 1e-2, 2e-3, 5e-4, 1e-4] {
        let Ok(truth) = exact_ot_stable(&cost, &inst.a, &inst.b, eps) else {
            table.row(vec![
                "ot".into(),
                format!("{eps:.0e}"),
                "(truth failed)".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
            continue;
        };
        let problem = OtProblem::balanced(&cost, inst.a.clone(), inst.b.clone(), eps);
        for (name, backend) in backends() {
            let spec =
                SolverSpec::new(Method::SparSink).with_budget(s_mult).with_backend(backend);
            let (rmae, se, failures) = rmae_over_reps(
                reps,
                truth,
                |r| api::solve_with_rng(&problem, &spec, r).map(|s| s.objective),
                &mut rng,
            );
            table.row(vec![
                "ot".into(),
                format!("{eps:.0e}"),
                name.into(),
                f(rmae, 4),
                f(se, 4),
                failures.to_string(),
                f(truth, 4),
            ]);
            rows.push(row(vec![
                ("problem", Json::str("ot")),
                ("eps", Json::num(eps)),
                ("backend", Json::str(name)),
                ("rmae", Json::num(rmae)),
                ("se", Json::num(se)),
                ("failures", Json::num(failures as f64)),
                ("truth", Json::num(truth)),
            ]));
        }
    }

    // Barycenter leg: the Spar-IBP path through the same backend sweep.
    // Truth is the dense log-domain IBP histogram (stable at any ε);
    // the error is the normalized L1 gap of the sketched q against it.
    let bn = profile.pick(48, 200);
    let bary_reps = profile.reps(3, 10);
    let pts: Vec<Vec<f64>> =
        (0..bn).map(|i| vec![i as f64 / (bn - 1) as f64]).collect();
    let bcost = ot_cost(&pts);
    let bs = barycenter_measures(bn, &mut rng);
    let weights = vec![1.0 / 3.0; 3];
    for &eps in &[1e-2, 5e-4] {
        let problem = OtProblem::barycenter(&bcost, bs.clone(), weights.clone(), eps);
        let truth_spec = SolverSpec::new(Method::Sinkhorn)
            .with_backend(ScalingBackend::LogDomain)
            .with_tolerance(1e-9)
            .with_max_iters(5000);
        let Ok(truth_sol) = api::solve(&problem, &truth_spec) else {
            table.row(vec![
                "barycenter".into(),
                format!("{eps:.0e}"),
                "(truth failed)".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
            continue;
        };
        let truth_q = normalized_histogram(truth_sol.barycenter.as_deref().unwrap_or(&[]));
        for (name, backend) in backends() {
            let spec =
                SolverSpec::new(Method::SparIbp).with_budget(s_mult).with_backend(backend);
            let mut errs = Vec::with_capacity(bary_reps);
            let mut failures = 0usize;
            for _ in 0..bary_reps {
                match api::solve_with_rng(&problem, &spec, &mut rng) {
                    Ok(sol) => {
                        let q = normalized_histogram(sol.barycenter.as_deref().unwrap_or(&[]));
                        let err = l1_distance(&q, &truth_q);
                        if err.is_finite() {
                            errs.push(err);
                        } else {
                            failures += 1;
                        }
                    }
                    Err(_) => failures += 1,
                }
            }
            let (mean, se) = if errs.is_empty() {
                (f64::NAN, f64::NAN)
            } else {
                let (mean, sd) = mean_sd(&errs);
                (mean, sd / (errs.len() as f64).sqrt())
            };
            table.row(vec![
                "barycenter".into(),
                format!("{eps:.0e}"),
                name.into(),
                f(mean, 4),
                f(se, 4),
                failures.to_string(),
                "q(log)".into(),
            ]);
            rows.push(row(vec![
                ("problem", Json::str("barycenter")),
                ("eps", Json::num(eps)),
                ("backend", Json::str(name)),
                ("rmae", Json::num(mean)),
                ("se", Json::num(se)),
                ("failures", Json::num(failures as f64)),
                ("truth", Json::num(f64::NAN)),
            ]));
        }
    }

    ExperimentOutput {
        id: "smalleps",
        text: format!(
            "Small-eps backend stability (OT n={n}, barycenter n={bn}, s={s_mult}s0, {reps} reps)\n{}",
            table.render()
        ),
        rows: Json::arr(rows),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_profile_runs_and_reports_all_backends() {
        let out = run(Profile::Quick);
        assert_eq!(out.id, "smalleps");
        // OT: 5 eps values x 3 backends; barycenter: 2 eps x 3 backends.
        assert_eq!(out.rows.items().len(), 21);
        // At the smallest eps the log backend must have zero failures.
        let log_small = out
            .rows
            .items()
            .iter()
            .find(|r| {
                r.get("problem").and_then(|p| p.as_str()) == Some("ot")
                    && r.get("backend").and_then(|b| b.as_str()) == Some("log")
                    && r.get("eps").and_then(|e| e.as_f64()) == Some(1e-4)
            })
            .expect("missing log row");
        assert_eq!(log_small.get("failures").and_then(|x| x.as_f64()), Some(0.0));
    }

    #[test]
    fn barycenter_leg_solves_below_the_threshold_on_log_and_auto() {
        let out = run(Profile::Quick);
        for backend in ["log", "auto"] {
            let r = out
                .rows
                .items()
                .iter()
                .find(|r| {
                    r.get("problem").and_then(|p| p.as_str()) == Some("barycenter")
                        && r.get("backend").and_then(|b| b.as_str()) == Some(backend)
                        && r.get("eps").and_then(|e| e.as_f64()) == Some(5e-4)
                })
                .unwrap_or_else(|| panic!("missing barycenter {backend} row"));
            assert_eq!(
                r.get("failures").and_then(|x| x.as_f64()),
                Some(0.0),
                "{backend} failed below the threshold"
            );
            let err = r.get("rmae").and_then(|x| x.as_f64()).unwrap();
            // L1 distance of two probability vectors is at most 2; a
            // solved (non-collapsed) sketch stays clearly below that.
            assert!(err.is_finite() && err < 1.5, "{backend} err {err}");
        }
    }
}
