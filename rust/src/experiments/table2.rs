//! Table 2 (recast) — the Sinkhorn-divergence ingredient of the SSAE
//! generative model: `S(μ,ν) = OT_ε(μ,ν) − ½(OT_ε(μ,μ) + OT_ε(ν,ν))`
//! on minibatches of latent vectors (n = 500, d = 10, ε = 0.01, the
//! SSAE hyper-parameters).  Reports accuracy (RMAE vs the exact
//! divergence) and wall time per divergence for Sinkhorn vs Spar-Sink.
//!
//! Full SSAE training needs GPU NN training — out of scope for this CPU
//! image (DESIGN.md §3); the divergence is the exact quantity SSAE
//! replaces, so matching it at half the cost is the reproduction target.

use std::sync::Arc;
use std::time::Instant;

use super::common::{exact_ot, row};
use super::{ExperimentOutput, Profile};
use crate::api::{self, Method, OtProblem, SolverSpec};
use crate::linalg::Mat;
use crate::metrics::mean_sd;
use crate::ot::cost::{normalize_cost, sq_euclidean_cost};
use crate::rng::Rng;
use crate::util::json::Json;
use crate::util::table::{f, pm, Table};

/// Latent minibatch: encoder posterior ~ mixture around class means vs
/// the standard Gaussian prior (what SSAE matches).
fn latent_batches(n: usize, d: usize, rng: &mut Rng) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let means: Vec<Vec<f64>> = (0..10)
        .map(|_| (0..d).map(|_| rng.normal() * 1.5).collect())
        .collect();
    let posterior: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            let c = rng.gen_range(10);
            (0..d).map(|k| means[c][k] + 0.3 * rng.normal()).collect()
        })
        .collect();
    let prior: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..d).map(|_| rng.normal()).collect())
        .collect();
    (posterior, prior)
}

fn divergence(
    xy: &Arc<Mat>,
    xx: &Arc<Mat>,
    yy: &Arc<Mat>,
    a: &[f64],
    eps: f64,
    mut solve: impl FnMut(&Arc<Mat>) -> crate::error::Result<f64>,
) -> crate::error::Result<f64> {
    let _ = a;
    let oxy = solve(xy)?;
    let oxx = solve(xx)?;
    let oyy = solve(yy)?;
    let _ = eps;
    Ok(oxy - 0.5 * (oxx + oyy))
}

/// Table 2 (recast): Sinkhorn divergence on SSAE-style minibatches.
pub fn run(profile: Profile) -> ExperimentOutput {
    let n = profile.pick(300, 500);
    let d = 10;
    let eps = 0.01;
    let s_mult = 10.0; // the SSAE setting s = 10 s0(n)
    let batches = profile.reps(3, 20);
    let mut rng = Rng::seed_from(0xAB2E);

    let mut exact_times = Vec::new();
    let mut spar_times = Vec::new();
    let mut rmaes = Vec::new();
    for _ in 0..batches {
        let (post, prior) = latent_batches(n, d, &mut rng);
        let a = vec![1.0 / n as f64; n];
        let cost_xy = Arc::new(normalize_cost(&sq_euclidean_cost(&post, &prior)));
        let cost_xx = Arc::new(normalize_cost(&sq_euclidean_cost(&post, &post)));
        let cost_yy = Arc::new(normalize_cost(&sq_euclidean_cost(&prior, &prior)));

        let t0 = Instant::now();
        let exact = divergence(&cost_xy, &cost_xx, &cost_yy, &a, eps, |c| {
            exact_ot(c, &a, &a, eps)
        });
        exact_times.push(t0.elapsed().as_secs_f64());
        let Ok(exact) = exact else { continue };

        let t0 = Instant::now();
        let spec = SolverSpec::new(Method::SparSink).with_budget(s_mult);
        let approx = divergence(&cost_xy, &cost_xx, &cost_yy, &a, eps, |c| {
            let problem = OtProblem::balanced(c, a.clone(), a.clone(), eps);
            api::solve_with_rng(&problem, &spec, &mut rng).map(|s| s.objective)
        });
        spar_times.push(t0.elapsed().as_secs_f64());
        if let Ok(approx) = approx {
            rmaes.push((approx - exact).abs() / exact.abs().max(f64::MIN_POSITIVE));
        }
    }

    let (rmae_mean, rmae_sd) = if rmaes.is_empty() { (f64::NAN, 0.0) } else { mean_sd(&rmaes) };
    let (te, _) = mean_sd(&exact_times);
    let (ts, _) = mean_sd(&spar_times);
    let mut table = Table::new(&["method", "divergence RMAE", "secs/divergence", "speedup"]);
    table.row(vec!["sinkhorn (SAE)".into(), "0 (reference)".into(), f(te, 3), "1.0".into()]);
    table.row(vec![
        "spar-sink (SSAE)".into(),
        pm(rmae_mean, rmae_sd, 4),
        f(ts, 3),
        f(te / ts.max(1e-9), 1),
    ]);
    let text = format!(
        "Table 2 (recast) — Sinkhorn divergence on SSAE minibatches (n = {n}, d = {d}, eps = {eps}, s = 10 s0(n), {batches} batches)\n{}",
        table.render()
    );
    let rows = Json::arr(vec![row(vec![
        ("rmae_mean", Json::num(rmae_mean)),
        ("rmae_sd", Json::num(rmae_sd)),
        ("sinkhorn_secs", Json::num(te)),
        ("spar_secs", Json::num(ts)),
        ("speedup", Json::num(te / ts.max(1e-9))),
    ])]);
    ExperimentOutput { id: "table2", text, rows }
}
