//! From-scratch micro-benchmark harness (the offline image has no
//! `criterion`). `cargo bench` runs the `benches/*.rs` targets, each of
//! which uses this module: warmup, timed samples, mean/median/stddev,
//! and a rendered report. The [`coordinator`] arm (`repro bench
//! coordinator`) instead measures the sharded distance service end to
//! end and emits `BENCH_coordinator.json`; the [`kernels`] arm
//! (`repro bench kernels`) n-sweeps the dense/sparse hot loops and
//! emits `BENCH_kernels.json`; the [`gateway`] arm (`repro bench
//! gateway`) replays the same workload over HTTP through the balancer
//! and emits `BENCH_gateway.json`.

use std::time::{Duration, Instant};

pub mod coordinator;
pub mod gateway;
pub mod kernels;

/// One benchmark's measurements.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark label, as printed in the report.
    pub name: String,
    /// Per-iteration wall times.
    pub samples: Vec<Duration>,
}

impl BenchResult {
    /// Mean sample time.
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len().max(1) as u32
    }

    /// Median sample time.
    pub fn median(&self) -> Duration {
        let mut v = self.samples.clone();
        v.sort();
        v[v.len() / 2]
    }

    /// Sample standard deviation (Bessel-corrected, dividing by n−1).
    /// Zero when fewer than two samples exist — a single measurement
    /// has no spread estimate.
    pub fn stddev(&self) -> Duration {
        let n = self.samples.len();
        if n <= 1 {
            return Duration::ZERO;
        }
        let mean = self.mean().as_secs_f64();
        let var = self
            .samples
            .iter()
            .map(|d| (d.as_secs_f64() - mean).powi(2))
            .sum::<f64>()
            / (n - 1) as f64;
        Duration::from_secs_f64(var.sqrt())
    }

    /// One-line rendering: name, mean, median, sd, sample count.
    pub fn render(&self) -> String {
        format!(
            "{:<44} mean {:>12.3?}  median {:>12.3?}  sd {:>10.3?}  ({} samples)",
            self.name,
            self.mean(),
            self.median(),
            self.stddev(),
            self.samples.len()
        )
    }
}

/// Benchmark runner with warmup and a time budget per benchmark.
pub struct Bencher {
    /// Untimed warmup iterations before sampling.
    pub warmup_iters: usize,
    /// Samples collected even past the time budget.
    pub min_samples: usize,
    /// Hard cap on samples per benchmark.
    pub max_samples: usize,
    /// Sampling stops after this much wall time (past `min_samples`).
    pub time_budget: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 2,
            min_samples: 5,
            max_samples: 50,
            time_budget: Duration::from_secs(5),
            results: Vec::new(),
        }
    }
}

impl Bencher {
    /// A low-budget runner for smoke-testing bench targets.
    pub fn quick() -> Self {
        Bencher {
            warmup_iters: 1,
            min_samples: 3,
            max_samples: 10,
            time_budget: Duration::from_secs(2),
            ..Default::default()
        }
    }

    /// Run one benchmark. The closure is called repeatedly; use
    /// [`std::hint::black_box`] on inputs/outputs inside it.
    pub fn bench(&mut self, name: impl Into<String>, mut f: impl FnMut()) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let started = Instant::now();
        while samples.len() < self.max_samples
            && (samples.len() < self.min_samples || started.elapsed() < self.time_budget)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        let result = BenchResult { name: name.into(), samples };
        println!("{}", result.render());
        self.results.push(result);
        self.results.last().expect("the result was pushed just above")
    }

    /// All results so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Render the final report.
    pub fn report(&self, title: &str) -> String {
        let mut out = format!("=== {title} ===\n");
        for r in &self.results {
            out.push_str(&r.render());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_stats() {
        let mut b = Bencher {
            warmup_iters: 1,
            min_samples: 3,
            max_samples: 5,
            time_budget: Duration::from_millis(50),
            results: Vec::new(),
        };
        let r = b.bench("noop-ish", || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(r.samples.len() >= 3);
        assert!(r.mean() >= Duration::ZERO);
        assert!(r.median() <= r.samples.iter().max().cloned().unwrap());
        assert!(b.report("t").contains("noop-ish"));
    }

    #[test]
    fn respects_max_samples() {
        let mut b = Bencher {
            warmup_iters: 0,
            min_samples: 1,
            max_samples: 4,
            time_budget: Duration::from_secs(100),
            results: Vec::new(),
        };
        let r = b.bench("capped", || {});
        assert!(r.samples.len() <= 4);
    }

    #[test]
    fn stddev_is_sample_not_population() {
        let r = BenchResult {
            name: "sd".into(),
            samples: vec![Duration::from_secs(1), Duration::from_secs(3)],
        };
        // Sample sd of {1, 3}: sqrt(((1-2)² + (3-2)²) / (2-1)) = sqrt(2).
        let want = 2.0f64.sqrt();
        assert!((r.stddev().as_secs_f64() - want).abs() < 1e-9);
        // Degenerate sizes have no spread estimate.
        let one = BenchResult { name: "one".into(), samples: vec![Duration::from_secs(5)] };
        assert_eq!(one.stddev(), Duration::ZERO);
        let none = BenchResult { name: "none".into(), samples: vec![] };
        assert_eq!(none.stddev(), Duration::ZERO);
    }
}
