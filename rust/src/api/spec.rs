//! How to solve an [`OtProblem`](crate::api::OtProblem): which
//! registered method, at what sample budget, over which scaling
//! backend, with which stopping rule and seed.

use crate::ot::sinkhorn::SinkhornParams;
use crate::solvers::backend::ScalingBackend;

/// Every solver registered in [`crate::api::registry`]. The name
/// returned by [`Method::name`] is the registry key and the spelling
/// accepted by the CLI and coordinator.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Exact dense Sinkhorn: Alg. 1 (balanced), Alg. 2 (unbalanced), or
    /// IBP (Alg. 5) for barycenter problems.
    Sinkhorn,
    /// The paper's importance-sparsified Spar-Sink (Algs. 3-4).
    SparSink,
    /// Spar-Sink with the log-domain sparse engine forced on — stays
    /// solvable at ε far below the multiplicative underflow point.
    SparSinkLog,
    /// Uniform-sampling ablation (same sparse loop, `p_ij = 1/n²`).
    RandSink,
    /// Nyström-factorized Sinkhorn (Altschuler et al. 2019); the robust
    /// variant (Le et al. 2021) via [`SolverSpec::robust_clip`].
    NysSink,
    /// Greedy coordinate Sinkhorn (Altschuler et al. 2017). Balanced
    /// dense problems only.
    Greenkhorn,
    /// Screened Sinkhorn (Alaya et al. 2019). Balanced dense problems
    /// only.
    Screenkhorn,
    /// Importance-sparsified IBP (Alg. 6). Barycenter problems only.
    SparIbp,
}

impl Method {
    /// All registered methods, in registry order.
    pub const ALL: [Method; 8] = [
        Method::Sinkhorn,
        Method::SparSink,
        Method::SparSinkLog,
        Method::RandSink,
        Method::NysSink,
        Method::Greenkhorn,
        Method::Screenkhorn,
        Method::SparIbp,
    ];

    /// The registry key / CLI spelling.
    pub fn name(&self) -> &'static str {
        match self {
            Method::Sinkhorn => "sinkhorn",
            Method::SparSink => "spar-sink",
            Method::SparSinkLog => "spar-sink-log",
            Method::RandSink => "rand-sink",
            Method::NysSink => "nys-sink",
            Method::Greenkhorn => "greenkhorn",
            Method::Screenkhorn => "screenkhorn",
            Method::SparIbp => "spar-ibp",
        }
    }

    /// Inverse of [`Method::name`].
    pub fn parse(name: &str) -> Option<Method> {
        Method::ALL.iter().copied().find(|m| m.name() == name)
    }

    /// Position in [`Method::ALL`] (stable index for per-method metric
    /// arrays).
    pub fn index(&self) -> usize {
        Method::ALL.iter().position(|m| m == self).expect("method in ALL")
    }
}

/// Parse a scaling-backend spelling (`auto`, `multiplicative`/`mult`,
/// `log-domain`/`log`).
pub fn parse_backend(name: &str) -> Option<ScalingBackend> {
    match name {
        "auto" => Some(ScalingBackend::default()),
        "multiplicative" | "mult" => Some(ScalingBackend::Multiplicative),
        "log-domain" | "log" => Some(ScalingBackend::LogDomain),
        _ => None,
    }
}

/// Builder-style solver request. Defaults mirror the paper's Section 5-6
/// setups: budget `s = 8·s₀(n)`, δ = 10⁻⁶, 1000 iterations, shrinkage
/// θ = 1, `Auto` backend (multiplicative above the ε threshold,
/// log-domain below it or on numerical failure).
#[derive(Clone, Debug)]
pub struct SolverSpec {
    /// Which registered solver runs the problem.
    pub method: Method,
    /// Sample budget in units of the crate-wide
    /// [`sketch_budget`](crate::solvers::sketch_budget) convention
    /// `s₀(max(n, m))`, s₀(n) = 10⁻³ n log⁴ n (sparsified methods; also
    /// sets the matched Nyström rank when `rank` is None).
    pub s_multiplier: f64,
    /// Scaling-backend override; `None` = the solver's default policy
    /// (`Auto` for the sparse family).
    pub backend: Option<ScalingBackend>,
    /// Stopping threshold δ on the L1 scaling displacement.
    pub delta: f64,
    /// Iteration cap.
    pub max_iters: usize,
    /// Error instead of best-effort when the cap is hit.
    pub strict: bool,
    /// RNG seed used by [`crate::api::solve`] (sparsifier / pivot
    /// sampling); ignored by deterministic dense solvers.
    pub seed: u64,
    /// Spar-Sink shrinkage θ mixing importance and uniform probabilities.
    pub shrinkage: f64,
    /// Nys-Sink rank override; `None` = matched budget `⌈s/n⌉`.
    pub rank: Option<usize>,
    /// Robust-Nys-Sink clip (scalings clamped to `[1/c, c]`); `None` =
    /// plain Nys-Sink.
    pub robust_clip: Option<f64>,
    /// Screenkhorn decimation factor κ (keeps n/κ active points).
    pub decimation: usize,
    /// Greenkhorn update cap factor (max updates = factor · n).
    pub max_updates_factor: usize,
}

impl SolverSpec {
    /// A spec for `method` with the paper-default knobs (see the struct
    /// docs); refine it with the `with_*` builders.
    pub fn new(method: Method) -> Self {
        SolverSpec {
            method,
            s_multiplier: 8.0,
            backend: None,
            delta: 1e-6,
            max_iters: 1000,
            strict: false,
            seed: 0,
            shrinkage: 1.0,
            rank: None,
            robust_clip: None,
            decimation: 3,
            max_updates_factor: 5,
        }
    }

    /// Sample budget in units of s₀(n).
    pub fn with_budget(mut self, s_multiplier: f64) -> Self {
        self.s_multiplier = s_multiplier;
        self
    }

    /// Force a scaling backend (overrides the solver's `Auto` policy).
    pub fn with_backend(mut self, backend: ScalingBackend) -> Self {
        self.backend = backend.into();
        self
    }

    /// Stopping threshold δ on the L1 scaling displacement.
    pub fn with_tolerance(mut self, delta: f64) -> Self {
        self.delta = delta;
        self
    }

    /// Iteration cap for the scaling loop.
    pub fn with_max_iters(mut self, max_iters: usize) -> Self {
        self.max_iters = max_iters;
        self
    }

    /// Error instead of best-effort when the iteration cap is hit.
    pub fn with_strict(mut self, strict: bool) -> Self {
        self.strict = strict;
        self
    }

    /// RNG seed for the sparsifier / pivot sampling.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Spar-Sink shrinkage θ (1 = pure importance sampling).
    pub fn with_shrinkage(mut self, shrinkage: f64) -> Self {
        self.shrinkage = shrinkage;
        self
    }

    /// Explicit Nys-Sink rank (instead of the matched budget).
    pub fn with_rank(mut self, rank: usize) -> Self {
        self.rank = Some(rank);
        self
    }

    /// Robust Nys-Sink: clamp scalings to `[1/clip, clip]`.
    pub fn with_robust_clip(mut self, clip: f64) -> Self {
        self.robust_clip = Some(clip);
        self
    }

    /// Screenkhorn decimation factor κ (keeps n/κ active points).
    pub fn with_decimation(mut self, decimation: usize) -> Self {
        self.decimation = decimation;
        self
    }

    /// Greenkhorn update cap factor (max updates = factor · n).
    pub fn with_max_updates_factor(mut self, factor: usize) -> Self {
        self.max_updates_factor = factor;
        self
    }

    /// The inner Sinkhorn-loop parameters this spec describes.
    pub fn sinkhorn_params(&self) -> SinkhornParams {
        SinkhornParams { delta: self.delta, max_iters: self.max_iters, strict: self.strict }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_names_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()), Some(m));
            assert_eq!(Method::ALL[m.index()], m);
        }
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn builder_chains() {
        let spec = SolverSpec::new(Method::SparSink)
            .with_budget(16.0)
            .with_backend(ScalingBackend::LogDomain)
            .with_tolerance(1e-8)
            .with_max_iters(200)
            .with_seed(7)
            .with_shrinkage(0.9);
        assert_eq!(spec.s_multiplier, 16.0);
        assert_eq!(spec.backend, Some(ScalingBackend::LogDomain));
        let p = spec.sinkhorn_params();
        assert_eq!(p.delta, 1e-8);
        assert_eq!(p.max_iters, 200);
        assert!(!p.strict);
    }

    #[test]
    fn backend_spellings() {
        assert_eq!(parse_backend("mult"), Some(ScalingBackend::Multiplicative));
        assert_eq!(parse_backend("log"), Some(ScalingBackend::LogDomain));
        assert!(matches!(parse_backend("auto"), Some(ScalingBackend::Auto { .. })));
        assert_eq!(parse_backend("nope"), None);
    }
}
