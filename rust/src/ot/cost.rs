//! Ground costs and Gibbs kernels.
//!
//! * Squared Euclidean cost (the paper's OT experiments, Section 5.1).
//! * Wasserstein–Fisher–Rao cost `C_ij = -log cos²₊(d_ij / 2η)` whose
//!   kernel is sparse and near-full-rank (Section 2.2) — the regime where
//!   Nyström-based acceleration breaks down and Spar-Sink shines.

use crate::linalg::Mat;

/// Euclidean distance between two points.
#[inline]
pub fn euclidean(x: &[f64], y: &[f64]) -> f64 {
    sq_euclidean(x, y).sqrt()
}

/// Squared Euclidean distance.
#[inline]
pub fn sq_euclidean(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum()
}

/// Pairwise squared-Euclidean cost matrix `C_ij = ||x_i - y_j||²`.
pub fn sq_euclidean_cost(xs: &[Vec<f64>], ys: &[Vec<f64>]) -> Mat {
    Mat::from_fn(xs.len(), ys.len(), |i, j| sq_euclidean(&xs[i], &ys[j]))
}

/// WFR ground cost for a single distance:
/// `-log cos²₊(d / 2η)` with `cos₊(z) = cos(min(z, π/2))`.
/// Returns `f64::INFINITY` when `d ≥ π η` (transport blocked).
#[inline]
pub fn wfr_cost_from_distance(d: f64, eta: f64) -> f64 {
    let z = d / (2.0 * eta);
    if z >= std::f64::consts::FRAC_PI_2 {
        return f64::INFINITY;
    }
    let c = z.cos();
    -(c * c).ln()
}

/// WFR kernel entry `K_ij = exp(-C_ij / ε) = cos₊(d/2η)^(2/ε)`.
/// Exactly zero when `d ≥ π η`.
#[inline]
pub fn wfr_kernel_from_distance(d: f64, eta: f64, eps: f64) -> f64 {
    let z = d / (2.0 * eta);
    if z >= std::f64::consts::FRAC_PI_2 {
        return 0.0;
    }
    let c = z.cos();
    (c * c).powf(1.0 / eps)
}

/// Pairwise WFR cost matrix from supports (Euclidean ground distance).
pub fn wfr_cost(xs: &[Vec<f64>], ys: &[Vec<f64>], eta: f64) -> Mat {
    Mat::from_fn(xs.len(), ys.len(), |i, j| {
        wfr_cost_from_distance(euclidean(&xs[i], &ys[j]), eta)
    })
}

/// Gibbs kernel `K = exp(-C / ε)`, mapping `C = ∞` to exactly 0.
pub fn gibbs_kernel(cost: &Mat, eps: f64) -> Mat {
    cost.map(|c| if c.is_infinite() { 0.0 } else { (-c / eps).exp() })
}

/// Log-Gibbs kernel entry `ln K = −C/ε`, mapping `C = ∞` (blocked
/// transport) to −∞. The single blocked-entry convention shared by every
/// log-kernel oracle — the Spar-Sink `_logk` entry points and the
/// coordinator build their sketches through this.
#[inline]
pub fn log_gibbs_from_cost(c: f64, eps: f64) -> f64 {
    if c.is_infinite() {
        f64::NEG_INFINITY
    } else {
        -c / eps
    }
}

/// Fraction of non-zero entries in a kernel (used to calibrate η for the
/// paper's R1/R2/R3 sparsity regimes: ~70%, ~50%, ~30% nnz).
pub fn kernel_density(kernel: &Mat) -> f64 {
    let nnz = kernel.as_slice().iter().filter(|&&k| k > 0.0).count();
    nnz as f64 / (kernel.rows() * kernel.cols()) as f64
}

/// Binary-search η so that the WFR kernel has approximately the target
/// density (fraction of entries with `d_ij < π η`).
pub fn calibrate_eta(
    xs: &[Vec<f64>],
    ys: &[Vec<f64>],
    target_density: f64,
    tol: f64,
) -> f64 {
    // Collect all pairwise distances once (O(n²)); pick the quantile.
    let mut ds: Vec<f64> = Vec::with_capacity(xs.len() * ys.len());
    for x in xs {
        for y in ys {
            ds.push(euclidean(x, y));
        }
    }
    ds.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = ((target_density * ds.len() as f64) as usize).min(ds.len() - 1);
    let _ = tol;
    // d < π η  ⇔  η > d/π: choose η at the target quantile distance.
    ds[q] / std::f64::consts::PI
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sq_euclidean_basic() {
        assert_eq!(sq_euclidean(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
        assert_eq!(euclidean(&[0.0, 0.0], &[3.0, 4.0]), 5.0);
    }

    #[test]
    fn cost_matrix_symmetric_on_shared_support() {
        let pts = vec![vec![0.0], vec![1.0], vec![2.5]];
        let c = sq_euclidean_cost(&pts, &pts);
        for i in 0..3 {
            assert_eq!(c.get(i, i), 0.0);
            for j in 0..3 {
                assert_eq!(c.get(i, j), c.get(j, i));
            }
        }
    }

    #[test]
    fn wfr_cost_blocks_long_range() {
        let eta = 2.0;
        // d >= pi*eta -> infinite cost, zero kernel.
        let d_blocked = std::f64::consts::PI * eta;
        assert!(wfr_cost_from_distance(d_blocked, eta).is_infinite());
        assert_eq!(wfr_kernel_from_distance(d_blocked, eta, 0.1), 0.0);
        // d = 0 -> zero cost, kernel 1.
        assert_eq!(wfr_cost_from_distance(0.0, eta), 0.0);
        assert!((wfr_kernel_from_distance(0.0, eta, 0.1) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn wfr_kernel_consistent_with_cost() {
        let (eta, eps) = (1.5, 0.3);
        for &d in &[0.1, 0.5, 1.0, 2.0, 4.0] {
            let c = wfr_cost_from_distance(d, eta);
            let k = wfr_kernel_from_distance(d, eta, eps);
            if c.is_infinite() {
                assert_eq!(k, 0.0);
            } else {
                assert!((k - (-c / eps).exp()).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn smaller_eta_sparser_kernel() {
        let pts: Vec<Vec<f64>> = (0..40).map(|i| vec![i as f64 * 0.1]).collect();
        let dense = gibbs_kernel(&wfr_cost(&pts, &pts, 2.0), 0.1);
        let sparse = gibbs_kernel(&wfr_cost(&pts, &pts, 0.2), 0.1);
        assert!(kernel_density(&sparse) < kernel_density(&dense));
    }

    #[test]
    fn calibrate_eta_hits_target_density() {
        let pts: Vec<Vec<f64>> = (0..60)
            .map(|i| vec![(i as f64 * 0.618).fract(), (i as f64 * 0.383).fract()])
            .collect();
        for &target in &[0.7, 0.5, 0.3] {
            let eta = calibrate_eta(&pts, &pts, target, 1e-3);
            let k = gibbs_kernel(&wfr_cost(&pts, &pts, eta), 0.1);
            let density = kernel_density(&k);
            assert!(
                (density - target).abs() < 0.05,
                "target {target}, got {density}"
            );
        }
    }

    #[test]
    fn gibbs_kernel_handles_infinite_cost() {
        let mut c = Mat::zeros(2, 2);
        c.set(0, 1, f64::INFINITY);
        let k = gibbs_kernel(&c, 0.5);
        assert_eq!(k.get(0, 1), 0.0);
        assert_eq!(k.get(0, 0), 1.0);
    }
}
