//! Color transfer (Appendix D.1): move the sunset palette onto the
//! daytime point cloud with a Spar-Sink transport plan and compare the
//! resulting color map against the exact Sinkhorn map. Both plans come
//! from the same `OtProblem` via `api::solve`.
//!
//! ```sh
//! cargo run --release --example color_transfer
//! ```

use std::sync::Arc;

use spar_sink::api::{self, Method, OtProblem, SolverSpec};
use spar_sink::data::images::{barycentric_map, daytime_cloud, sunset_cloud};
use spar_sink::linalg::Mat;
use spar_sink::ot::cost::{gibbs_kernel, normalize_cost, sq_euclidean_cost};
use spar_sink::ot::sinkhorn::transport_plan;
use spar_sink::rng::Rng;

fn mean_rgb(cloud: &[Vec<f64>]) -> [f64; 3] {
    let n = cloud.len() as f64;
    let mut m = [0.0; 3];
    for p in cloud {
        for c in 0..3 {
            m[c] += p[c] / n;
        }
    }
    m
}

fn main() {
    let n = 1500;
    let eps = 1e-2;
    let mut rng = Rng::seed_from(13);
    let source = daytime_cloud(n, &mut rng);
    let target = sunset_cloud(n, &mut rng);
    let a = vec![1.0 / n as f64; n];
    let cost = Arc::new(normalize_cost(&sq_euclidean_cost(&source, &target)));
    let kernel = gibbs_kernel(&cost, eps);
    let problem = OtProblem::balanced(&cost, a.clone(), a, eps);

    // Exact plan.
    let exact = api::solve(&problem, &SolverSpec::new(Method::Sinkhorn)).unwrap();
    let plan = transport_plan(&kernel, &exact.u, &exact.v);
    let exact_map = barycentric_map(|i| (0..n).map(|j| (j, plan.get(i, j))).collect(), &target, n);

    // Spar-Sink plan at s = 8 s0(n).
    let spec = SolverSpec::new(Method::SparSink).with_budget(8.0).with_seed(13);
    let approx = api::solve(&problem, &spec).unwrap();
    let plan_s = Mat::from_fn(n, n, |i, j| approx.u[i] * kernel.get(i, j) * approx.v[j]);
    let spar_map =
        barycentric_map(|i| (0..n).map(|j| (j, plan_s.get(i, j))).collect(), &target, n);

    let dev: f64 = exact_map
        .iter()
        .zip(&spar_map)
        .map(|(x, y)| {
            x.iter().zip(y).map(|(u, v)| (u - v) * (u - v)).sum::<f64>().sqrt()
        })
        .sum::<f64>()
        / n as f64;

    println!("n = {n} RGB samples, eps = {eps}");
    println!("source (daytime) mean RGB: {:?}", mean_rgb(&source));
    println!("target (sunset)  mean RGB: {:?}", mean_rgb(&target));
    println!(
        "sinkhorn transferred mean: {:?}  ({:?})",
        mean_rgb(&exact_map),
        exact.wall_time
    );
    println!(
        "spar-sink transferred mean: {:?}  ({:?})",
        mean_rgb(&spar_map),
        approx.wall_time
    );
    println!(
        "mean RGB deviation from Sinkhorn map: {dev:.4}   speedup {:.1}x",
        exact.wall_time.as_secs_f64() / approx.wall_time.as_secs_f64()
    );
}
