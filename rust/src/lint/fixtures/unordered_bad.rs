//! Seeded violation (unordered-iter): HashMap storage order feeding id
//! assembly — exactly the nondeterministic-flush-ids bug class.

use std::collections::HashMap;

/// Assigns ids in whatever order the hasher happens to produce.
pub fn assign_ids(groups: HashMap<u64, Vec<u32>>) -> Vec<(u64, usize)> {
    let mut out = Vec::new();
    for (key, jobs) in groups.iter() {
        out.push((*key, jobs.len()));
    }
    let more: Vec<u64> = groups.keys().copied().collect();
    drop(more);
    out
}
