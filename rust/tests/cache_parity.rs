//! The shared-cost artifact engine's contract wall:
//!
//! * warm solves (through `CostSource::Shared` / `api::solve_batch`)
//!   are BITWISE-identical to the cold dense/oracle paths for every
//!   sketch-based solver, OT + UOT + barycenter, square and
//!   RECTANGULAR dense costs alike (the unified `sketch_budget`
//!   convention makes the upgrade shape-agnostic);
//! * the `ArtifactCache` LRU never exceeds its byte budget and counts
//!   hits/misses/evictions;
//! * different supports never collide on a fingerprint;
//! * the coordinator's pairwise warm path reproduces the legacy oracle
//!   path bit for bit while building artifacts exactly once per
//!   (support, η, ε);
//! * the SHARDED coordinator is placement-invariant: one submission
//!   sequence yields bitwise-identical results, identical batch ids and
//!   identical cache builds at every shard count, stealing on or off.
//!
//! Case counts scale with `PROPTEST_CASES` (the CI cache-parity job
//! runs at 96).

use std::sync::Arc;

use spar_sink::api::{self, CostSource, EntryOracle, Method, OtProblem, SolverSpec};
use spar_sink::coordinator::{
    BarycenterJob, CoordinatorConfig, DistanceJob, DistanceService, Measure, ProblemSpec,
};
use spar_sink::engine::{ArtifactCache, CostArtifacts, Fingerprint, FormulationKey};
use spar_sink::linalg::Mat;
use spar_sink::ot::cost::{
    euclidean, log_gibbs_from_cost, normalize_cost, sq_euclidean_cost, wfr_cost,
    wfr_cost_from_distance,
};
use spar_sink::rng::Rng;

const CASES: usize = 6;

fn cases() -> usize {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(CASES)
}

fn points(n: usize, rng: &mut Rng) -> Vec<Vec<f64>> {
    (0..n).map(|_| vec![rng.uniform() * 4.0, rng.uniform() * 4.0]).collect()
}

fn histogram(n: usize, rng: &mut Rng) -> Vec<f64> {
    let raw: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.05).collect();
    let s: f64 = raw.iter().sum();
    raw.iter().map(|x| x / s).collect()
}

fn assert_bitwise(tag: &str, cold: &api::Solution, warm: &api::Solution) {
    assert_eq!(
        cold.objective.to_bits(),
        warm.objective.to_bits(),
        "{tag}: objective {} vs {}",
        cold.objective,
        warm.objective
    );
    assert_eq!(cold.iterations, warm.iterations, "{tag}: iterations");
    assert_eq!(cold.backend, warm.backend, "{tag}: backend");
    assert_eq!(cold.u.len(), warm.u.len(), "{tag}: u length");
    for (x, y) in cold.u.iter().zip(&warm.u) {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: u entry {x} vs {y}");
    }
    for (x, y) in cold.v.iter().zip(&warm.v) {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: v entry {x} vs {y}");
    }
    match (&cold.barycenter, &warm.barycenter) {
        (Some(qc), Some(qw)) => {
            for (x, y) in qc.iter().zip(qw) {
                assert_eq!(x.to_bits(), y.to_bits(), "{tag}: q entry {x} vs {y}");
            }
        }
        (None, None) => {}
        _ => panic!("{tag}: barycenter presence mismatch"),
    }
    assert_eq!(cold.stats.len(), warm.stats.len(), "{tag}: stats length");
    for (sc, sw) in cold.stats.iter().zip(&warm.stats) {
        assert_eq!(sc.nnz, sw.nnz, "{tag}: nnz");
        assert_eq!(sc.saturated, sw.saturated, "{tag}: saturated");
    }
}

/// Warm-hit solutions bitwise-match the cold dense path: balanced OT
/// across the sketch/low-rank family.
#[test]
fn warm_balanced_ot_matches_cold_bitwise() {
    let mut master = Rng::seed_from(0xCA5E_0001);
    for case in 0..cases() {
        let seed = master.next_u64();
        let mut rng = Rng::seed_from(seed);
        let n = 20 + rng.gen_range(20);
        let pts = points(n, &mut rng);
        let cost = Arc::new(normalize_cost(&sq_euclidean_cost(&pts, &pts)));
        let eps = 0.05 + rng.uniform() * 0.1;
        let a = histogram(n, &mut rng);
        let b = histogram(n, &mut rng);
        let problem = OtProblem::balanced(cost, a, b, eps);
        for method in [Method::SparSink, Method::RandSink, Method::NysSink] {
            let spec = SolverSpec::new(method).with_budget(8.0).with_seed(seed ^ 0x55);
            let cold = api::solve(&problem, &spec).unwrap();
            let cache = ArtifactCache::new(1 << 30);
            let warm = api::solve_batch_with_cache(std::slice::from_ref(&problem), &spec, &cache)
                .pop()
                .unwrap()
                .unwrap();
            assert_bitwise(&format!("case {case} seed {seed} {method:?} OT"), &cold, &warm);
            assert_eq!(cache.stats().misses, 1);
            // A second batch over the same problem is a pure hit and
            // still bitwise-identical.
            let warm2 = api::solve_batch_with_cache(std::slice::from_ref(&problem), &spec, &cache)
                .pop()
                .unwrap()
                .unwrap();
            assert_bitwise(&format!("case {case} {method:?} warm-hit"), &warm, &warm2);
            assert_eq!(cache.stats().hits, 1);
        }
    }
}

/// Warm-hit solutions bitwise-match the cold dense path: unbalanced OT
/// on a WFR cost (exercises the amortized β·ln K sampling factor and
/// blocked entries).
#[test]
fn warm_unbalanced_ot_matches_cold_bitwise() {
    let mut master = Rng::seed_from(0xCA5E_0002);
    for case in 0..cases() {
        let seed = master.next_u64();
        let mut rng = Rng::seed_from(seed);
        let n = 20 + rng.gen_range(20);
        let pts = points(n, &mut rng);
        let eta = 1.0 + rng.uniform() * 2.0;
        let cost = Arc::new(wfr_cost(&pts, &pts, eta));
        let eps = 0.03 + rng.uniform() * 0.1;
        let lambda = 0.5 + rng.uniform();
        let a: Vec<f64> = histogram(n, &mut rng).iter().map(|x| x * 5.0).collect();
        let b: Vec<f64> = histogram(n, &mut rng).iter().map(|x| x * 3.0).collect();
        let problem = OtProblem::unbalanced(cost, a, b, lambda, eps);
        for method in [Method::SparSink, Method::RandSink] {
            let spec = SolverSpec::new(method).with_budget(8.0).with_seed(seed ^ 0x77);
            let cold = api::solve(&problem, &spec);
            let cache = ArtifactCache::new(1 << 30);
            let warm = api::solve_batch_with_cache(std::slice::from_ref(&problem), &spec, &cache)
                .pop()
                .unwrap();
            match (cold, warm) {
                (Ok(cold), Ok(warm)) => assert_bitwise(
                    &format!("case {case} seed {seed} {method:?} UOT"),
                    &cold,
                    &warm,
                ),
                // Degenerate draws (fully blocked kernel) must fail the
                // same way on both paths.
                (Err(ec), Err(ew)) => assert_eq!(ec.to_string(), ew.to_string()),
                (c, w) => panic!("cold/warm outcome mismatch: {c:?} vs {w:?}"),
            }
        }
    }
}

/// Warm-hit barycenters bitwise-match the cold dense path (Spar-IBP and
/// the exact dense IBP alike).
#[test]
fn warm_barycenter_matches_cold_bitwise() {
    let mut master = Rng::seed_from(0xCA5E_0003);
    for case in 0..cases() {
        let seed = master.next_u64();
        let mut rng = Rng::seed_from(seed);
        let n = 24 + rng.gen_range(16);
        let pts: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 / (n - 1) as f64]).collect();
        let cost = Arc::new(normalize_cost(&sq_euclidean_cost(&pts, &pts)));
        let eps = 0.01 + rng.uniform() * 0.02;
        let bs = vec![histogram(n, &mut rng), histogram(n, &mut rng), histogram(n, &mut rng)];
        let w = vec![1.0 / 3.0; 3];
        let problem = OtProblem::barycenter(cost, bs, w, eps);
        for method in [Method::SparIbp, Method::Sinkhorn] {
            let spec = SolverSpec::new(method).with_budget(12.0).with_seed(seed ^ 0x99);
            let cold = api::solve(&problem, &spec).unwrap();
            let cache = ArtifactCache::new(1 << 30);
            let warm = api::solve_batch_with_cache(std::slice::from_ref(&problem), &spec, &cache)
                .pop()
                .unwrap()
                .unwrap();
            assert_bitwise(&format!("case {case} seed {seed} {method:?} bary"), &cold, &warm);
        }
    }
}

/// `solve_batch` seeding contract: slot 0 is bitwise `solve`, slot i is
/// bitwise `solve` at seed + i.
#[test]
fn solve_batch_seed_derivation_is_stable() {
    let mut rng = Rng::seed_from(0xCA5E_0004);
    let n = 30;
    let pts = points(n, &mut rng);
    let cost = Arc::new(normalize_cost(&sq_euclidean_cost(&pts, &pts)));
    let problem = OtProblem::balanced(cost, histogram(n, &mut rng), histogram(n, &mut rng), 0.08);
    let spec = SolverSpec::new(Method::SparSink).with_budget(8.0).with_seed(41);
    let cache = ArtifactCache::new(1 << 30);
    let batch = api::solve_batch_with_cache(
        &[problem.clone(), problem.clone(), problem.clone()],
        &spec,
        &cache,
    );
    assert_eq!(batch.len(), 3);
    let solo0 = api::solve(&problem, &spec).unwrap();
    let solo2 = api::solve(&problem, &spec.clone().with_seed(43)).unwrap();
    assert_bitwise("batch[0] vs solve", &solo0, batch[0].as_ref().unwrap());
    assert_bitwise("batch[2] vs solve(seed+2)", &solo2, batch[2].as_ref().unwrap());
    let stats = cache.stats();
    assert_eq!((stats.misses, stats.hits), (1, 2), "{stats:?}");
}

/// Eviction respects the byte budget while the cache is driven through
/// the public batch API.
#[test]
fn eviction_respects_byte_budget_under_batch_load() {
    let mut rng = Rng::seed_from(0xCA5E_0005);
    let n = 24;
    // One artifact's size, probed on an identical shape.
    let probe = CostArtifacts::for_sq_euclidean_support(
        &points(n, &mut rng),
        0.1,
        FormulationKey::Balanced,
    );
    let budget = probe.bytes() * 2 + probe.bytes() / 2; // room for two
    let cache = ArtifactCache::new(budget);
    let spec = SolverSpec::new(Method::SparSink).with_budget(6.0).with_seed(1);
    for _ in 0..6 {
        let pts = points(n, &mut rng);
        let cost = Arc::new(sq_euclidean_cost(&pts, &pts));
        let problem =
            OtProblem::balanced(cost, histogram(n, &mut rng), histogram(n, &mut rng), 0.1);
        api::solve_batch_with_cache(std::slice::from_ref(&problem), &spec, &cache)
            .pop()
            .unwrap()
            .unwrap();
        let stats = cache.stats();
        assert!(stats.bytes <= stats.byte_budget, "{stats:?}");
        assert!(stats.entries <= 2, "{stats:?}");
    }
    let stats = cache.stats();
    assert_eq!(stats.misses, 6);
    assert_eq!(stats.evictions, 4, "{stats:?}");
}

/// Distinct random supports never collide on a fingerprint, and the
/// support hash covers both sides of a pair.
#[test]
fn distinct_supports_get_distinct_fingerprints() {
    let mut master = Rng::seed_from(0xCA5E_0006);
    let key = FormulationKey::Balanced;
    let mut seen = std::collections::HashSet::new();
    for _ in 0..(cases() * 16).max(64) {
        let mut rng = Rng::seed_from(master.next_u64());
        let n = 4 + rng.gen_range(12);
        let pts = points(n, &mut rng);
        let fp = Fingerprint::for_supports(&pts, &pts, None, 0.05, key);
        assert!(seen.insert(fp), "fingerprint collision across supports");
    }
    // Dense fingerprints are content-addressed too: same values in two
    // allocations collide ON PURPOSE, a one-entry change never does.
    let mut rng = Rng::seed_from(7);
    let pts = points(10, &mut rng);
    let c1 = sq_euclidean_cost(&pts, &pts);
    let c2 = c1.clone();
    assert_eq!(
        Fingerprint::for_dense(&c1, 0.05, key),
        Fingerprint::for_dense(&c2, 0.05, key)
    );
    let mut c3 = c1.clone();
    c3.set(3, 4, c3.get(3, 4) + 1e-12);
    assert_ne!(
        Fingerprint::for_dense(&c1, 0.05, key),
        Fingerprint::for_dense(&c3, 0.05, key)
    );
}

/// The acceptance bar, end to end: a pairwise distance-matrix run over
/// 10 frames on one shared support builds artifacts once per (η, ε),
/// reports it through the MetricsSnapshot cache gauges, and every warm
/// objective is bitwise-identical to the legacy cold oracle path.
#[test]
fn coordinator_warm_path_matches_cold_oracle_path_bitwise() {
    let frames = 10;
    let n = 32;
    let mut rng = Rng::seed_from(0xCA5E_0007);
    let support: Arc<Vec<Vec<f64>>> = Arc::new(points(n, &mut rng));
    let masses: Vec<Arc<Vec<f64>>> =
        (0..frames).map(|_| Arc::new(histogram(n, &mut rng))).collect();
    let problem_spec = ProblemSpec { eta: 3.0, eps: 0.05, ..Default::default() };

    let mut jobs = Vec::new();
    let mut id = 0u64;
    for i in 0..frames {
        for j in (i + 1)..frames {
            jobs.push(DistanceJob {
                id,
                source: Measure { points: support.clone(), mass: masses[i].clone() },
                target: Measure { points: support.clone(), mass: masses[j].clone() },
                method: Method::SparSink,
                spec: problem_spec.clone(),
                seed: 1000 + id,
            });
            id += 1;
        }
    }
    let total = jobs.len() as u64; // 45 pairs
    let pair_of = |job_id: u64| -> (usize, usize) {
        let mut k = 0u64;
        for i in 0..frames {
            for j in (i + 1)..frames {
                if k == job_id {
                    return (i, j);
                }
                k += 1;
            }
        }
        unreachable!()
    };

    let service = DistanceService::start(CoordinatorConfig { workers: 4, ..Default::default() });
    let results = service.submit_all(jobs).unwrap();
    let metrics = service.shutdown();
    assert_eq!(metrics.completed, total);
    assert_eq!(metrics.cache.misses, 1, "{:?}", metrics.cache);
    assert_eq!(metrics.cache.hits, total - 1, "{:?}", metrics.cache);

    // Cold reference: the legacy oracle-cost problem, exactly as the
    // pre-engine worker built it.
    for r in &results {
        assert!(r.error.is_none(), "job {}: {:?}", r.id, r.error);
        let (i, j) = pair_of(r.id);
        let (eta, eps) = (problem_spec.eta, problem_spec.eps);
        let (src, tgt) = (support.clone(), support.clone());
        let cost: EntryOracle = Arc::new(move |p: usize, q: usize| {
            wfr_cost_from_distance(euclidean(&src[p], &tgt[q]), eta)
        });
        let cost_for_lk = cost.clone();
        let log_kernel: EntryOracle =
            Arc::new(move |p: usize, q: usize| log_gibbs_from_cost(cost_for_lk(p, q), eps));
        let problem = OtProblem::unbalanced(
            CostSource::Oracle { rows: n, cols: n, cost, log_kernel: Some(log_kernel) },
            masses[i].clone(),
            masses[j].clone(),
            problem_spec.lambda,
            eps,
        );
        let spec = SolverSpec::new(Method::SparSink)
            .with_budget(problem_spec.s_multiplier)
            .with_tolerance(problem_spec.delta)
            .with_max_iters(problem_spec.max_iters)
            .with_seed(1000 + r.id);
        let cold = api::solve(&problem, &spec).unwrap();
        assert_eq!(
            cold.objective.to_bits(),
            r.objective.to_bits(),
            "job {} ({i},{j}): cold {} vs warm {}",
            r.id,
            cold.objective,
            r.objective
        );
        assert_eq!(cold.iterations, r.iterations, "job {}", r.id);
    }
}

/// The sharded coordinator's invariance wall: the SAME submission
/// sequence — mixed methods, sizes, ε values and job shapes — produces
/// bitwise-identical results, identical batch ids and identical
/// artifact builds at shard counts 1/2/4, stealing on or off. Batch
/// composition is pinned by `max_batch` = total job count (the flush
/// fires exactly when the last job arrives) plus a long window, so the
/// only thing that varies between configurations is placement.
#[test]
fn sharded_coordinator_is_shard_count_invariant() {
    use std::time::Duration;

    let mut rng = Rng::seed_from(0xCA5E_000C);
    let small: Arc<Vec<Vec<f64>>> = Arc::new(points(24, &mut rng));
    let big: Arc<Vec<Vec<f64>>> = Arc::new(points(40, &mut rng));
    let bary_support: Arc<Vec<Vec<f64>>> =
        Arc::new((0..32).map(|i| vec![i as f64 / 31.0]).collect());
    let small_masses: Vec<Arc<Vec<f64>>> =
        (0..4).map(|_| Arc::new(histogram(24, &mut rng))).collect();
    let big_masses: Vec<Arc<Vec<f64>>> =
        (0..2).map(|_| Arc::new(histogram(40, &mut rng))).collect();
    let bary_hists: Vec<Vec<f64>> = (0..3).map(|_| histogram(32, &mut rng)).collect();

    let distance_jobs = || -> Vec<DistanceJob> {
        let mut jobs = Vec::new();
        let mut id = 0u64;
        for &eps in &[0.05, 0.09] {
            for i in 0..small_masses.len() {
                for j in (i + 1)..small_masses.len() {
                    jobs.push(DistanceJob {
                        id,
                        source: Measure { points: small.clone(), mass: small_masses[i].clone() },
                        target: Measure { points: small.clone(), mass: small_masses[j].clone() },
                        method: Method::SparSink,
                        spec: ProblemSpec { eta: 3.0, eps, ..Default::default() },
                        seed: 1000 + id,
                    });
                    id += 1;
                }
            }
            // A second, larger support in another size bucket + method.
            jobs.push(DistanceJob {
                id,
                source: Measure { points: big.clone(), mass: big_masses[0].clone() },
                target: Measure { points: big.clone(), mass: big_masses[1].clone() },
                method: Method::RandSink,
                spec: ProblemSpec { eta: 3.0, eps, ..Default::default() },
                seed: 1000 + id,
            });
            id += 1;
        }
        jobs
    };
    let bary_jobs = || -> Vec<BarycenterJob> {
        (0..2)
            .map(|k| BarycenterJob {
                id: 500 + k,
                support: bary_support.clone(),
                marginals: bary_hists.clone(),
                weights: vec![1.0 / 3.0; 3],
                method: Method::SparIbp,
                spec: ProblemSpec { eps: 0.02, s_multiplier: 12.0, ..Default::default() },
                seed: 77 + k,
            })
            .collect()
    };

    let run = |shards: usize, steal: bool| {
        let d_jobs = distance_jobs();
        let b_jobs = bary_jobs();
        let total = d_jobs.len() + b_jobs.len();
        let service = DistanceService::start(CoordinatorConfig {
            workers: 3,
            shards,
            steal,
            max_batch: total,
            batch_window: Duration::from_secs(30),
            ..Default::default()
        });
        let d_rx: Vec<_> = d_jobs.into_iter().map(|j| service.submit(j).unwrap()).collect();
        let b_rx: Vec<_> =
            b_jobs.into_iter().map(|j| service.submit_barycenter(j).unwrap()).collect();
        let d: Vec<_> = d_rx.into_iter().map(|rx| rx.recv().unwrap()).collect();
        let b: Vec<_> = b_rx.into_iter().map(|rx| rx.recv().unwrap()).collect();
        let m = service.shutdown();
        (d, b, m)
    };

    let (d0, b0, m0) = run(1, false);
    assert!(d0.iter().all(|r| r.error.is_none()), "{d0:?}");
    assert!(b0.iter().all(|r| r.error.is_none()), "{b0:?}");
    // One flush, one batch per (method, size bucket) group, ids
    // assigned in sorted-group order by the fixed flush.
    assert_eq!(m0.batches, 3, "{m0:?}");
    for shards in [1usize, 2, 4] {
        for steal in [true, false] {
            let (d, b, m) = run(shards, steal);
            let tag = format!("shards {shards} steal {steal}");
            assert_eq!(m.batches, m0.batches, "{tag}: batch count");
            assert_eq!(m.cache.misses, m0.cache.misses, "{tag}: artifact builds");
            assert_eq!(m.shards.len(), shards.min(3), "{tag}: resolved shard count");
            for (x, y) in d0.iter().zip(&d) {
                let t = format!("{tag} job {}", x.id);
                assert_eq!(x.id, y.id, "{t}: order");
                assert_eq!(x.objective.to_bits(), y.objective.to_bits(), "{t}: objective");
                assert_eq!(x.distance.to_bits(), y.distance.to_bits(), "{t}: distance");
                assert_eq!(x.iterations, y.iterations, "{t}: iterations");
                assert_eq!(x.backend, y.backend, "{t}: backend");
                assert_eq!(x.batch_id, y.batch_id, "{t}: batch id");
            }
            for (x, y) in b0.iter().zip(&b) {
                let t = format!("{tag} bary {}", x.id);
                assert_eq!(x.id, y.id, "{t}: order");
                assert_eq!(x.iterations, y.iterations, "{t}: iterations");
                assert_eq!(x.backend, y.backend, "{t}: backend");
                assert_eq!(x.batch_id, y.batch_id, "{t}: batch id");
                assert_eq!(x.q.len(), y.q.len(), "{t}: q length");
                for (qa, qb) in x.q.iter().zip(&y.q) {
                    assert_eq!(qa.to_bits(), qb.to_bits(), "{t}: q entry");
                }
            }
        }
    }
}

/// Dense costs that are value-identical but separately allocated share
/// one artifact through `solve_batch` (content addressing, not pointer
/// identity).
#[test]
fn value_identical_dense_costs_share_artifacts() {
    let mut rng = Rng::seed_from(0xCA5E_0008);
    let n = 20;
    let pts = points(n, &mut rng);
    let build = || Arc::new(normalize_cost(&sq_euclidean_cost(&pts, &pts)));
    let a = histogram(n, &mut rng);
    let b = histogram(n, &mut rng);
    let p1 = OtProblem::balanced(build(), a.clone(), b.clone(), 0.07);
    let p2 = OtProblem::balanced(build(), a, b, 0.07);
    let cache = ArtifactCache::new(1 << 30);
    let spec = SolverSpec::new(Method::SparSink).with_budget(8.0).with_seed(5);
    let out = api::solve_batch_with_cache(&[p1, p2], &spec, &cache);
    assert!(out.iter().all(|r| r.is_ok()));
    let stats = cache.stats();
    assert_eq!((stats.misses, stats.hits), (1, 1), "{stats:?}");
}

/// A shared handle refuses to serve a problem at a different ε — the
/// artifacts are ε-specific and silent reuse would be wrong.
#[test]
fn shared_handle_rejects_mismatched_eps() {
    let mut rng = Rng::seed_from(0xCA5E_0009);
    let n = 12;
    let pts = points(n, &mut rng);
    let arts = CostArtifacts::for_sq_euclidean_support(&pts, 0.05, FormulationKey::Balanced);
    let handle = spar_sink::engine::CostHandle::new(arts);
    let mut problem = OtProblem::balanced(
        CostSource::Shared(handle),
        histogram(n, &mut rng),
        histogram(n, &mut rng),
        0.05,
    );
    problem.eps = 0.1;
    let err = api::solve(&problem, &SolverSpec::new(Method::SparSink)).unwrap_err();
    assert!(err.to_string().contains("eps"), "{err}");
}

/// Rectangular dense problems upgrade to shared artifacts and stay
/// bitwise-identical warm vs cold: every sketch solver resolves its
/// budget through the one `sketch_budget` convention `s₀(max(n, m))`
/// in every cost arm, so the upgrade cannot change the sketch — for
/// any shape. Exercises OT and UOT across the sketch family, both the
/// n < m and n > m orientations.
#[test]
fn rectangular_dense_batches_match_cold_bitwise() {
    let mut master = Rng::seed_from(0xCA5E_000B);
    for (case, (n, m)) in [(18usize, 30usize), (30, 18)].into_iter().enumerate() {
        let seed = master.next_u64();
        let mut rng = Rng::seed_from(seed);
        let src = points(n, &mut rng);
        let tgt = points(m, &mut rng);
        let cost = Arc::new(normalize_cost(&sq_euclidean_cost(&src, &tgt)));
        let a = histogram(n, &mut rng);
        let b = histogram(m, &mut rng);
        let problems = [
            OtProblem::balanced(cost.clone(), a.clone(), b.clone(), 0.08),
            OtProblem::unbalanced(cost.clone(), a, b, 1.0, 0.08),
        ];
        for problem in &problems {
            // The upgrade actually happens for rectangular shapes now…
            let probe_cache = ArtifactCache::new(1 << 30);
            let shared = api::share_via_cache(problem, &probe_cache);
            assert!(
                matches!(shared.cost, CostSource::Shared(_)),
                "rectangular dense must upgrade: {:?}",
                shared.cost
            );
            assert_eq!(probe_cache.stats().misses, 1);
            // …and warm solves stay bitwise-identical to the cold path.
            for method in [Method::SparSink, Method::RandSink] {
                let spec = SolverSpec::new(method).with_budget(8.0).with_seed(seed ^ 0x3D);
                let cold = api::solve(problem, &spec).unwrap();
                let cache = ArtifactCache::new(1 << 30);
                let warm =
                    api::solve_batch_with_cache(std::slice::from_ref(problem), &spec, &cache)
                        .pop()
                        .unwrap()
                        .unwrap();
                assert_bitwise(&format!("case {case} {n}x{m} {method:?} rect"), &cold, &warm);
                assert_eq!(cache.stats().misses, 1);
            }
            // Nys-Sink's symmetric-PSD factorization requires a shared
            // square support; it rejects rectangular shapes loudly —
            // with the IDENTICAL error cold and through the upgrade.
            let spec = SolverSpec::new(Method::NysSink).with_budget(8.0).with_seed(1);
            let cold_err = api::solve(problem, &spec).unwrap_err();
            let cache = ArtifactCache::new(1 << 30);
            let warm_err =
                api::solve_batch_with_cache(std::slice::from_ref(problem), &spec, &cache)
                    .pop()
                    .unwrap()
                    .unwrap_err();
            assert_eq!(cold_err.to_string(), warm_err.to_string());
            assert!(cold_err.to_string().contains("shared support"), "{cold_err}");
        }
    }
}

/// Sanity: warm solves still read a real matrix — spot-check the
/// artifact against the dense source it was built from.
#[test]
fn upgraded_problem_reads_identical_cost_values() {
    let mut rng = Rng::seed_from(0xCA5E_000A);
    let n = 16;
    let pts = points(n, &mut rng);
    let cost: Arc<Mat> = Arc::new(normalize_cost(&sq_euclidean_cost(&pts, &pts)));
    let problem =
        OtProblem::balanced(cost.clone(), histogram(n, &mut rng), histogram(n, &mut rng), 0.05);
    let cache = ArtifactCache::new(1 << 30);
    let shared = api::share_via_cache(&problem, &cache);
    let CostSource::Shared(handle) = &shared.cost else {
        panic!("dense problem should upgrade to a shared handle");
    };
    assert!(Arc::ptr_eq(&handle.artifacts().cost, &cost), "cost must be shared, not copied");
    for i in 0..n {
        for j in 0..n {
            assert_eq!(
                shared.cost.cost_at(i, j).to_bits(),
                problem.cost.cost_at(i, j).to_bits()
            );
            assert_eq!(
                shared.cost.kernel_at(i, j, 0.05).to_bits(),
                problem.cost.kernel_at(i, j, 0.05).to_bits()
            );
        }
    }
}
