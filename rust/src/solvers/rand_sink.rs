//! Rand-Sink — the naive uniform element-wise subsampling baseline
//! (Section 5): identical to Spar-Sink except every entry has the same
//! probability `p_ij = 1/n²`. Implemented as the θ = 0 shrinkage limit
//! of the Poisson sparsifier so the code path is shared.
//!
//! The dense entry points keep their paper signatures; the unified API
//! dispatches through the [`SolverSpec`]-consuming adapter
//! [`rand_sink_solve`], which also covers oracle costs (the sketch is
//! sampled straight from the kernel oracle, never materialized).

use super::backend::ScalingBackend;
use super::sketch_budget;
use super::spar_sink::{solve_sketch_ot, solve_sketch_uot, SparSolution};
use crate::api::{Formulation, OtProblem, SolverSpec};
use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::ot::sinkhorn::SinkhornParams;
use crate::rng::Rng;
use crate::sparse::{poisson_sparsify_with, CsrMatrix, SparsifyStats};

fn oracle_kernel(cost: &Mat, eps: f64) -> impl Fn(usize, usize) -> f64 + Sync + '_ {
    move |i, j| {
        let c = cost.get(i, j);
        if c.is_infinite() {
            0.0
        } else {
            (-c / eps).exp()
        }
    }
}

/// Uniform Poisson sketch: every entry at probability ∝ 1 over the
/// `n·m` grid, expected budget `s`.
fn uniform_sketch(
    n: usize,
    m: usize,
    kernel: impl Fn(usize, usize) -> f64 + Sync,
    cost: impl Fn(usize, usize) -> f64 + Sync,
    s: f64,
    rng: &mut Rng,
) -> Result<(CsrMatrix, SparsifyStats)> {
    let n2 = (n * m) as f64;
    poisson_sparsify_with(n, m, kernel, cost, |_, _| 1.0, n2, s, 1.0, rng)
}

/// Rand-Sink for OT: uniform Poisson sampling + multiplicative sparse
/// Sinkhorn (the baseline as the paper defines it).
pub fn rand_sink_ot(
    cost: &Mat,
    a: &[f64],
    b: &[f64],
    eps: f64,
    s_multiplier: f64,
    params: &SinkhornParams,
    rng: &mut Rng,
) -> Result<SparSolution> {
    let (n, m) = (a.len(), b.len());
    let s = sketch_budget(s_multiplier, n, m);
    let (sketch, stats) =
        uniform_sketch(n, m, oracle_kernel(cost, eps), |i, j| cost.get(i, j), s, rng)?;
    solve_sketch_ot(&sketch, stats, a, b, eps, ScalingBackend::Multiplicative, params)
}

/// Rand-Sink for UOT.
// 8 arguments: paper-reproduction entry point mirroring the Algorithm 4
// baseline's parameter list; richer configurations go through
// `rand_sink_solve`.
#[allow(clippy::too_many_arguments)]
pub fn rand_sink_uot(
    cost: &Mat,
    a: &[f64],
    b: &[f64],
    lambda: f64,
    eps: f64,
    s_multiplier: f64,
    params: &SinkhornParams,
    rng: &mut Rng,
) -> Result<SparSolution> {
    let (n, m) = (a.len(), b.len());
    let s = sketch_budget(s_multiplier, n, m);
    let (sketch, stats) =
        uniform_sketch(n, m, oracle_kernel(cost, eps), |i, j| cost.get(i, j), s, rng)?;
    solve_sketch_uot(&sketch, stats, a, b, lambda, eps, ScalingBackend::Multiplicative, params)
}

/// The [`SolverSpec`]-consuming adapter behind the `rand-sink` registry
/// entry. Without a [`SolverSpec::backend`] override the scaling loop is
/// multiplicative — the naive baseline exactly as the paper evaluates
/// it; an explicit override (e.g. a per-job `ScalingBackend::LogDomain`
/// from the distance service) is honored, with the log engine deriving
/// `ln k` from the uniformly sampled linear values. The budget follows
/// the crate-wide [`sketch_budget`] convention `s₀(max(n, m))` in every
/// cost arm (dense, oracle, and shared-artifact alike).
pub fn rand_sink_solve(
    problem: &OtProblem,
    spec: &SolverSpec,
    rng: &mut Rng,
) -> Result<SparSolution> {
    let params = spec.sinkhorn_params();
    let backend = spec.backend.unwrap_or(ScalingBackend::Multiplicative);
    let (a, b, eps) = (&problem.a[..], &problem.b[..], problem.eps);
    if matches!(problem.formulation, Formulation::Barycenter { .. }) {
        return Err(Error::InvalidParam(
            "rand-sink solves OT/UOT problems; use spar-ibp for barycenters".into(),
        ));
    }
    let (n, m) = (a.len(), b.len());
    // One budget convention for every cost arm. Shared sources also
    // serve `kernel_at` from the materialized kernel, so the uniform
    // sketch samples without per-entry exp calls.
    let s = sketch_budget(spec.s_multiplier, n, m);
    let (sketch, stats) = uniform_sketch(
        n,
        m,
        |i, j| problem.cost.kernel_at(i, j, eps),
        |i, j| problem.cost.cost_at(i, j),
        s,
        rng,
    )?;
    match &problem.formulation {
        Formulation::Balanced => solve_sketch_ot(&sketch, stats, a, b, eps, backend, &params),
        Formulation::Unbalanced { lambda } => {
            solve_sketch_uot(&sketch, stats, a, b, *lambda, eps, backend, &params)
        }
        Formulation::Barycenter { .. } => unreachable!("rejected above"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::cost::{gibbs_kernel, sq_euclidean_cost};
    use crate::ot::sinkhorn::sinkhorn_ot;
    use crate::solvers::spar_sink::spar_sink_ot;

    fn problem(n: usize, seed: u64) -> (Mat, Vec<f64>, Vec<f64>) {
        let mut rng = Rng::seed_from(seed);
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..3).map(|_| rng.uniform()).collect())
            .collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        // Strongly non-uniform marginals: the regime where importance
        // sampling beats uniform sampling.
        let a: Vec<f64> = (0..n).map(|i| ((i % 10) as f64 + 0.1).powi(3)).collect();
        let sa: f64 = a.iter().sum();
        let b: Vec<f64> = (0..n).map(|i| (((i + 5) % 10) as f64 + 0.1).powi(3)).collect();
        let sb: f64 = b.iter().sum();
        (cost, a.iter().map(|x| x / sa).collect(), b.iter().map(|x| x / sb).collect())
    }

    #[test]
    fn backend_override_is_honored_through_the_adapter() {
        // Default: the multiplicative baseline. Overridden: the log
        // engine runs on the same uniform sketch (ln of stored values)
        // and reports itself in the solution.
        use crate::api::{Method, SolverSpec};
        use crate::solvers::backend::BackendKind;
        let n = 100;
        let (cost, a, b) = problem(n, 51);
        let eps = 0.1;
        let prob = OtProblem::balanced(cost, a, b, eps);
        let mut rng = Rng::seed_from(3);
        let base = rand_sink_solve(&prob, &SolverSpec::new(Method::RandSink), &mut rng).unwrap();
        assert_eq!(base.backend, BackendKind::Multiplicative);
        let mut rng = Rng::seed_from(3);
        let spec = SolverSpec::new(Method::RandSink).with_backend(ScalingBackend::LogDomain);
        let logd = rand_sink_solve(&prob, &spec, &mut rng).unwrap();
        assert_eq!(logd.backend, BackendKind::LogDomain);
        // Same sketch, same fixed point (the engines stop on different
        // displacement statistics, so agreement is tolerance-level, not
        // bitwise).
        let rel = (base.solution.objective - logd.solution.objective).abs()
            / base.solution.objective.abs();
        assert!(rel < 1e-3, "mult {} vs log {}", base.solution.objective, logd.solution.objective);
    }

    #[test]
    fn runs_and_is_in_the_ballpark() {
        let n = 200;
        let (cost, a, b) = problem(n, 21);
        let eps = 0.1;
        let kernel = gibbs_kernel(&cost, eps);
        let exact = sinkhorn_ot(&kernel, &cost, &a, &b, eps, &SinkhornParams::default()).unwrap();
        let mut rng = Rng::seed_from(2);
        let sol = rand_sink_ot(&cost, &a, &b, eps, 16.0, &SinkhornParams::default(), &mut rng)
            .unwrap();
        let rel = (sol.solution.objective - exact.objective).abs() / exact.objective.abs();
        assert!(rel < 1.0, "relative error {rel}");
    }

    #[test]
    fn spar_sink_beats_rand_sink_on_skewed_marginals() {
        // The paper's headline: importance sampling dominates uniform.
        let n = 256;
        let (cost, a, b) = problem(n, 23);
        let eps = 0.1;
        let kernel = gibbs_kernel(&cost, eps);
        let exact = sinkhorn_ot(&kernel, &cost, &a, &b, eps, &SinkhornParams::default()).unwrap();
        let reps = 10;
        let mut rng = Rng::seed_from(4);
        let mut rand_err = 0.0;
        let mut spar_err = 0.0;
        for _ in 0..reps {
            let r = rand_sink_ot(&cost, &a, &b, eps, 4.0, &SinkhornParams::default(), &mut rng)
                .unwrap();
            rand_err += (r.solution.objective - exact.objective).abs();
            let s = spar_sink_ot(
                &cost,
                &a,
                &b,
                eps,
                4.0,
                &crate::solvers::spar_sink::SparSinkParams::default(),
                &mut rng,
            )
            .unwrap();
            spar_err += (s.solution.objective - exact.objective).abs();
        }
        assert!(
            spar_err < rand_err,
            "spar {spar_err:.4} should beat rand {rand_err:.4}"
        );
    }
}
