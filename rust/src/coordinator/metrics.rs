//! Service metrics: lock-free counters plus a bucketed latency
//! histogram with approximate quantiles.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::engine::CacheStats;

/// Log-spaced latency buckets from 10 µs up: 32 log₂ buckets, so the
/// last one starts at 10 µs · 2³¹ ≈ 2×10⁴ s (anything slower clamps
/// into it).
const BUCKET_COUNT: usize = 32;

fn bucket_for(d: Duration) -> usize {
    let us = d.as_micros().max(1) as f64;
    // bucket = log2(us / 10), clamped.
    let b = (us / 10.0).log2().floor();
    b.clamp(0.0, (BUCKET_COUNT - 1) as f64) as usize
}

fn bucket_upper_us(b: usize) -> f64 {
    10.0 * 2f64.powi(b as i32 + 1)
}

/// Thread-safe latency histogram.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKET_COUNT],
    count: AtomicU64,
    total_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            total_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    /// Record one latency sample.
    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        self.buckets[bucket_for(d)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.total_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency.
    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.total_us.load(Ordering::Relaxed) / c)
    }

    /// Approximate quantile from the bucket upper bounds (q in [0,1]).
    pub fn quantile(&self, q: f64) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        // Floor the target at 1 sample and skip empty buckets: with a
        // target of 0, `seen >= target` held at bucket 0 even when that
        // bucket was empty, so q = 0 reported 20 µs regardless of the
        // recorded data.
        let target = ((q.clamp(0.0, 1.0) * c as f64).ceil() as u64).max(1);
        let mut seen = 0;
        for (b, bucket) in self.buckets.iter().enumerate() {
            let in_bucket = bucket.load(Ordering::Relaxed);
            if in_bucket == 0 {
                continue;
            }
            seen += in_bucket;
            if seen >= target {
                return Duration::from_micros(bucket_upper_us(b) as u64);
            }
        }
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Largest recorded latency.
    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Fold another histogram's samples into this one (bucket-wise).
    ///
    /// The cross-shard merge: each shard records the latency of the jobs
    /// its workers executed into its own histogram, and the service
    /// snapshot merges them into one service-wide distribution — the
    /// same quantiles the single-queue design reported from its single
    /// histogram.
    pub fn absorb(&self, other: &LatencyHistogram) {
        for (mine, theirs) in self.buckets.iter().zip(&other.buckets) {
            mine.fetch_add(theirs.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.total_us.fetch_add(other.total_us.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_us.fetch_max(other.max_us.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

/// Point-in-time gauges of one shard of the sharded worker pool
/// (surfaced in [`MetricsSnapshot::shards`]).
///
/// Attribution: `depth`, `routed`, `queued_max` and `stolen_from`
/// describe the shard's QUEUE (its home batches); `busy`, `stolen`,
/// `completed`, `failed` and `p99_latency` describe the shard's WORKERS
/// (including batches they stole from other shards). Summing
/// `completed`/`failed` across shards therefore reproduces the global
/// counters exactly, whether or not stealing moved work.
#[derive(Clone, Debug)]
pub struct ShardStats {
    /// Shard index (0-based).
    pub shard: usize,
    /// Batches currently queued on this shard.
    pub depth: usize,
    /// Peak queue depth observed since start.
    pub queued_max: u64,
    /// This shard's workers currently executing a batch.
    pub busy: u64,
    /// Batches the scheduler routed to this shard.
    pub routed: u64,
    /// Batches this shard's workers stole from other shards.
    pub stolen: u64,
    /// Batches other shards' workers stole from this queue.
    pub stolen_from: u64,
    /// Jobs completed by this shard's workers.
    pub completed: u64,
    /// Jobs failed on this shard's workers.
    pub failed: u64,
    /// 99th-percentile latency of jobs executed by this shard's workers
    /// (bucket upper bound).
    pub p99_latency: Duration,
}

impl ShardStats {
    /// One-line rendering (one per shard in
    /// [`MetricsSnapshot::render`]).
    pub fn render(&self) -> String {
        format!(
            "shard {}: depth {} (max {})  busy {}  routed {}  stolen {} (lost {})  \
             completed {}  failed {}  p99 {:.1?}",
            self.shard,
            self.depth,
            self.queued_max,
            self.busy,
            self.routed,
            self.stolen,
            self.stolen_from,
            self.completed,
            self.failed,
            self.p99_latency
        )
    }
}

/// Point-in-time snapshot of service metrics.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Jobs accepted into the queue.
    pub submitted: u64,
    /// Jobs completed successfully.
    pub completed: u64,
    /// Jobs that came back with a per-job error.
    pub failed: u64,
    /// Batches flushed by the batcher.
    pub batches: u64,
    /// Mean end-to-end job latency (queue + solve).
    pub mean_latency: Duration,
    /// Median end-to-end job latency (bucket upper bound).
    pub p50_latency: Duration,
    /// 99th-percentile end-to-end job latency (bucket upper bound).
    pub p99_latency: Duration,
    /// Largest observed end-to-end job latency.
    pub max_latency: Duration,
    /// Jobs per second over the service lifetime.
    pub throughput: f64,
    /// Per-method log-domain escalation counters: completed jobs —
    /// distance and barycenter jobs alike — whose solution reports
    /// `BackendKind::LogDomain` although neither the method
    /// (`spar-sink-log`) nor the job's `ProblemSpec::backend` forced the
    /// log engine — i.e. the `Auto` policy escalated, either up front
    /// (small ε) or after a multiplicative failure/collapse. Only
    /// methods with a non-zero count appear.
    pub log_escalations: Vec<(&'static str, u64)>,
    /// Gauge: escalated jobs / completed jobs.
    pub log_escalation_rate: f64,
    /// Per-shard gauges of the sharded worker pool, one entry per
    /// shard. Queue-side gauges (`depth`, `routed`, `stolen_from`)
    /// describe each shard's home queue; worker-side counters (`busy`,
    /// `stolen`, `completed`, `failed`, `p99_latency`) describe the
    /// batches its workers actually executed, so the per-shard
    /// completed/failed counts sum to the global counters above. The
    /// service-wide latency quantiles are the cross-shard
    /// [`LatencyHistogram`] merge.
    pub shards: Vec<ShardStats>,
    /// Shared-cost artifact cache counters/gauges: hits, misses,
    /// evictions, resident entries/bytes, in-flight builds (the
    /// `building` gauge — single-flight slots under construction), and
    /// the byte budget. A pairwise run over T frames on one shared
    /// support shows exactly one miss per (η, ε, formulation) and hits
    /// for every other job — including jobs that arrived while the
    /// build was in flight and blocked on its slot.
    pub cache: CacheStats,
}

impl MetricsSnapshot {
    /// Multi-line human-readable rendering (the `serve` summary).
    pub fn render(&self) -> String {
        let escalations = if self.log_escalations.is_empty() {
            "none".to_string()
        } else {
            self.log_escalations
                .iter()
                .map(|(method, count)| format!("{method}={count}"))
                .collect::<Vec<_>>()
                .join(" ")
        };
        let mut out = format!(
            "jobs: {} submitted / {} completed / {} failed in {} batches\n\
             latency: mean {:.1?}  p50 {:.1?}  p99 {:.1?}  max {:.1?}\n\
             throughput: {:.2} jobs/s\n\
             log-domain escalations: {} (rate {:.3})\n\
             artifact cache: {}",
            self.submitted,
            self.completed,
            self.failed,
            self.batches,
            self.mean_latency,
            self.p50_latency,
            self.p99_latency,
            self.max_latency,
            self.throughput,
            escalations,
            self.log_escalation_rate,
            self.cache.render()
        );
        for shard in &self.shards {
            out.push('\n');
            out.push_str(&shard.render());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_and_mean() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_millis(1));
        h.record(Duration::from_millis(3));
        assert_eq!(h.count(), 2);
        let mean = h.mean();
        assert!(mean >= Duration::from_millis(1) && mean <= Duration::from_millis(3));
    }

    #[test]
    fn quantiles_are_monotone() {
        let h = LatencyHistogram::new();
        for i in 1..=100u64 {
            h.record(Duration::from_micros(i * 100));
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99, "{p50:?} vs {p99:?}");
        assert!(p99 <= h.max() * 4, "bucket upper bound sanity");
    }

    #[test]
    fn empty_histogram() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.9), Duration::ZERO);
        assert_eq!(h.quantile(0.0), Duration::ZERO);
    }

    #[test]
    fn quantile_zero_skips_empty_buckets() {
        // A single 1 s sample: every quantile, including q = 0, must
        // land in that sample's bucket — not report bucket 0's 20 µs
        // upper bound just because the target rounded down to 0.
        let h = LatencyHistogram::new();
        h.record(Duration::from_secs(1));
        let q0 = h.quantile(0.0);
        assert!(q0 >= Duration::from_secs(1), "q0 {q0:?}");
        assert_eq!(q0, h.quantile(0.5));
        assert_eq!(q0, h.quantile(1.0));
    }

    #[test]
    fn absorb_merges_bucketwise() {
        let a = LatencyHistogram::new();
        let b = LatencyHistogram::new();
        a.record(Duration::from_micros(100));
        b.record(Duration::from_millis(10));
        b.record(Duration::from_millis(20));
        let merged = LatencyHistogram::new();
        merged.absorb(&a);
        merged.absorb(&b);
        assert_eq!(merged.count(), 3);
        assert_eq!(merged.max(), b.max());
        // Mean of the merge is the pooled mean, not the mean of means
        // (integer-µs division, matching `mean()`).
        assert_eq!(merged.mean(), Duration::from_micros((100 + 10_000 + 20_000) / 3));
        // Quantiles span both sources: p0 from `a`, p100 from `b`.
        assert!(merged.quantile(0.0) <= Duration::from_micros(400));
        assert!(merged.quantile(1.0) >= Duration::from_millis(10));
    }

    #[test]
    fn shard_stats_render_one_line_each() {
        let s = ShardStats {
            shard: 3,
            depth: 2,
            queued_max: 5,
            busy: 1,
            routed: 7,
            stolen: 4,
            stolen_from: 2,
            completed: 40,
            failed: 1,
            p99_latency: Duration::from_millis(3),
        };
        let line = s.render();
        assert!(line.starts_with("shard 3:"), "{line}");
        assert!(line.contains("routed 7"), "{line}");
        assert!(line.contains("stolen 4 (lost 2)"), "{line}");
        assert!(!line.contains('\n'), "{line}");
    }

    #[test]
    fn bucket_mapping_monotone() {
        let mut prev = 0;
        for ms in [1u64, 2, 5, 10, 100, 1000, 10_000] {
            let b = bucket_for(Duration::from_millis(ms));
            assert!(b >= prev);
            prev = b;
        }
    }
}
