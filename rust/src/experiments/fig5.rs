//! Figure 5 — CPU time versus n for OT and UOT: the classical Sinkhorn,
//! Greenkhorn, Screenkhorn, Nys-Sink and Spar-Sink at s = 8·s₀(n).
//!
//! Reported as wall-clock seconds per solve; the *shape* (Spar-Sink and
//! Nys-Sink scale ~linearly while dense Sinkhorn scales quadratically,
//! with Spar-Sink pulling ahead as n grows) is the reproduction target.

use std::time::Instant;

use super::common::{ot_cost, run_method_ot, run_method_uot, wfr_cost_at_density, Method};
use super::{ExperimentOutput, Profile};
use crate::api::{self, OtProblem, SolverSpec};
use crate::data::synthetic::{instance, Scenario, SparsityRegime};
use crate::rng::Rng;
use crate::util::json::Json;
use crate::util::table::{f, Table};

/// Figure 5: CPU time vs n for OT and UOT across the solver family.
pub fn run(profile: Profile) -> ExperimentOutput {
    let ns: Vec<usize> = profile.pick(vec![400, 800, 1600], vec![800, 1600, 3200, 6400, 12800]);
    let eps_list: Vec<f64> = profile.pick(vec![1e-2], vec![1e-1, 1e-2]);
    let d = 5;
    let s_mult = 8.0;
    let mut table = Table::new(&["problem", "eps", "n", "method", "seconds"]);
    let mut rows = Vec::new();
    let mut rng = Rng::seed_from(0xF165);

    for &eps in &eps_list {
        for &n in &ns {
            // ---- OT ----
            let inst = instance(Scenario::C1, n, d, 1.0, 1.0, &mut rng);
            let cost = ot_cost(&inst.points);
            let record = |problem: &str,
                              method: &str,
                              secs: f64,
                              table: &mut Table,
                              rows: &mut Vec<Json>| {
                table.row(vec![
                    problem.into(),
                    format!("{eps:.0e}"),
                    n.to_string(),
                    method.into(),
                    f(secs, 4),
                ]);
                rows.push(super::common::row(vec![
                    ("problem", Json::str(problem)),
                    ("eps", Json::num(eps)),
                    ("n", Json::num(n as f64)),
                    ("method", Json::str(method)),
                    ("seconds", Json::num(secs)),
                ]));
            };

            // Dense baselines through the registry (each solve includes
            // its own kernel materialization — the full cost a fresh
            // request pays).
            let problem = OtProblem::balanced(&cost, inst.a.clone(), inst.b.clone(), eps);
            for method in
                [api::Method::Sinkhorn, api::Method::Greenkhorn, api::Method::Screenkhorn]
            {
                let t0 = Instant::now();
                let _ = api::solve(&problem, &SolverSpec::new(method));
                record("OT", method.name(), t0.elapsed().as_secs_f64(), &mut table, &mut rows);
            }

            for method in [Method::NysSink, Method::SparSink] {
                let t0 = Instant::now();
                let _ = run_method_ot(method, &cost, &inst.a, &inst.b, eps, s_mult, &mut rng);
                record("OT", method.name(), t0.elapsed().as_secs_f64(), &mut table, &mut rows);
            }

            // ---- UOT (WFR, R2 density) ----
            let inst = instance(Scenario::C1, n, d, 5.0, 3.0, &mut rng);
            let wcost = wfr_cost_at_density(&inst.points, SparsityRegime::R2.density());
            let (lambda, ueps) = (0.1, eps);

            let uproblem =
                OtProblem::unbalanced(&wcost, inst.a.clone(), inst.b.clone(), lambda, ueps);
            let t0 = Instant::now();
            let _ = api::solve(&uproblem, &SolverSpec::new(api::Method::Sinkhorn));
            record("UOT", "sinkhorn", t0.elapsed().as_secs_f64(), &mut table, &mut rows);

            for method in [Method::NysSink, Method::SparSink] {
                let t0 = Instant::now();
                let _ = run_method_uot(
                    method, &wcost, &inst.a, &inst.b, lambda, ueps, s_mult, &mut rng,
                );
                record("UOT", method.name(), t0.elapsed().as_secs_f64(), &mut table, &mut rows);
            }
        }
    }
    let text = format!(
        "Figure 5 — CPU time (s) vs n  (s = 8 s0(n); single solve per cell)\n{}",
        table.render()
    );
    ExperimentOutput { id: "fig5", text, rows: Json::arr(rows) }
}
