//! Microbench: CSR sparse matvec vs dense matvec, the Poisson
//! sparsifier construction pass — the O(s)-per-iteration claim of
//! Section 5.2 — and the multiplicative vs log-domain sparse scaling
//! iteration throughput (both are O(nnz)/iter; the log engine pays one
//! exp per stored entry per half-iteration).

use spar_sink::bench::Bencher;
use spar_sink::data::synthetic::{instance, Scenario};
use spar_sink::experiments::common::ot_cost;
use spar_sink::metrics::s0;
use spar_sink::ot::cost::gibbs_kernel;
use spar_sink::ot::sinkhorn::SinkhornParams;
use spar_sink::rng::Rng;
use spar_sink::solvers::log_sparse::log_sparse_scalings;
use spar_sink::solvers::sparse_loop::sparse_scalings;
use spar_sink::sparse::{poisson_sparsify_ot, poisson_sparsify_ot_logk};

fn main() {
    let mut bencher = Bencher::default();
    for &n in &[1000usize, 2000, 4000] {
        let mut rng = Rng::seed_from(1);
        let inst = instance(Scenario::C1, n, 5, 1.0, 1.0, &mut rng);
        let cost = ot_cost(&inst.points);
        let eps = 0.05;
        let kernel = gibbs_kernel(&cost, eps);
        let s = 8.0 * s0(n);
        let (sketch, _) = poisson_sparsify_ot(
            |i, j| kernel.get(i, j),
            |i, j| cost.get(i, j),
            &inst.a,
            &inst.b,
            s,
            1.0,
            &mut rng,
        )
        .unwrap();
        let x: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64 * 0.1).collect();

        bencher.bench(format!("dense_matvec/n={n}"), || {
            std::hint::black_box(kernel.matvec(std::hint::black_box(&x)));
        });
        bencher.bench(
            format!("sparse_matvec/n={n}/nnz={}", sketch.nnz()),
            || {
                std::hint::black_box(sketch.matvec(std::hint::black_box(&x)));
            },
        );
        bencher.bench(format!("sparsify_construct/n={n}"), || {
            let mut r = Rng::seed_from(2);
            std::hint::black_box(
                poisson_sparsify_ot(
                    |i, j| kernel.get(i, j),
                    |i, j| cost.get(i, j),
                    &inst.a,
                    &inst.b,
                    s,
                    1.0,
                    &mut r,
                )
                .unwrap(),
            );
        });

        // Multiplicative vs log-domain sparse scaling-loop throughput at
        // a fixed iteration count (delta = 0 disables early stopping) on
        // a log-kernel sketch of the same budget.
        let mut r = Rng::seed_from(3);
        let (logk_sketch, _) = poisson_sparsify_ot_logk(
            |i, j| -cost.get(i, j) / eps,
            |i, j| cost.get(i, j),
            &inst.a,
            &inst.b,
            s,
            1.0,
            &mut r,
        )
        .unwrap();
        let iter_params = SinkhornParams { delta: 0.0, max_iters: 25, strict: false };
        bencher.bench(format!("sparse_scalings_mult/n={n}/25it"), || {
            std::hint::black_box(
                sparse_scalings(&logk_sketch, &inst.a, &inst.b, 1.0, &iter_params).unwrap(),
            );
        });
        bencher.bench(format!("sparse_scalings_log/n={n}/25it"), || {
            std::hint::black_box(
                log_sparse_scalings(&logk_sketch, &inst.a, &inst.b, 1.0, eps, &iter_params)
                    .unwrap(),
            );
        });
    }
    println!("\n{}", bencher.report("bench_sparse"));
}
