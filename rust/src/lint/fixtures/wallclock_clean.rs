//! Clean twin of `wallclock_bad.rs`: logical ticks and thread counts
//! are passed in by the caller, so results cannot depend on the clock.

/// A solve parameterized on caller-owned ticks and parallelism.
pub fn tick_solve(logical_tick: u64, threads: usize) -> f64 {
    (logical_tick as f64) * (threads as f64)
}
