//! Compressed-sparse-row matrix with *dual values*: each stored entry
//! carries both the (rescaled) kernel value `K̃_ij` and the ground cost
//! `C_ij`, so the sparsified objective `<T̃, C> − εH(T̃)` can be
//! evaluated over the sampled support without touching the dense cost.
//!
//! Entries may additionally carry an explicit log-kernel value
//! `ln K̃_ij` (see [`CsrMatrix::from_rows_logk`]): for small ε the linear
//! kernel `exp(−C/ε)` underflows f64 while its logarithm stays finite,
//! and the log-domain scaling loop iterates on those values through the
//! [`CsrMatrix::row_lse`] / [`CsrMatrix::col_lse`] log-sum-exp
//! primitives without ever forming a kernel entry.

use std::sync::OnceLock;

use crate::error::{Error, Result};
use crate::ot::barycenter::KernelOp;
use crate::pool;

/// CSR matrix holding kernel and cost values per entry.
#[derive(Clone, Debug)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    /// Row pointer array, length rows+1.
    row_ptr: Vec<usize>,
    /// Column indices, length nnz.
    col_idx: Vec<u32>,
    /// Rescaled kernel values K̃_ij, length nnz.
    kernel: Vec<f64>,
    /// Ground-cost values C_ij for the same entries, length nnz.
    cost: Vec<f64>,
    /// Explicit log-kernel values `ln K̃_ij`, length nnz when present.
    /// `None` means "derive from `kernel`" — correct whenever the kernel
    /// values did not underflow.
    log_kernel: Option<Vec<f64>>,
    /// Derived `ln K̃` values, materialized lazily on the first
    /// log-domain sweep when no explicit `log_kernel` is stored. The
    /// LSE hot loops stream this array directly, so `ln` is computed
    /// once per stored entry over the matrix lifetime — never inside a
    /// scaling sweep.
    derived_logk: OnceLock<Vec<f64>>,
}

/// One sampled entry during construction.
#[derive(Clone, Copy, Debug)]
pub struct Triplet {
    /// Row index.
    pub row: usize,
    /// Column index.
    pub col: usize,
    /// Sampled (reweighted) kernel value `K̃_ij`.
    pub kernel: f64,
    /// Ground-cost value `C_ij` at the same entry.
    pub cost: f64,
}

impl CsrMatrix {
    /// Build from triplets (need not be sorted; duplicates are summed
    /// for the kernel value — the with-replacement estimator needs this —
    /// while the cost value is taken from the first occurrence).
    pub fn from_triplets(rows: usize, cols: usize, mut trips: Vec<Triplet>) -> Result<Self> {
        for t in &trips {
            if t.row >= rows || t.col >= cols {
                return Err(Error::Dimension(format!(
                    "triplet ({}, {}) outside {}x{}",
                    t.row, t.col, rows, cols
                )));
            }
        }
        trips.sort_unstable_by(|a, b| (a.row, a.col).cmp(&(b.row, b.col)));
        let mut row_ptr = vec![0usize; rows + 1];
        let mut col_idx: Vec<u32> = Vec::with_capacity(trips.len());
        let mut kernel: Vec<f64> = Vec::with_capacity(trips.len());
        let mut cost: Vec<f64> = Vec::with_capacity(trips.len());
        let mut last: Option<(usize, usize)> = None;
        for t in trips {
            if last == Some((t.row, t.col)) {
                // Duplicate (row, col): accumulate the kernel value
                // (with-replacement estimators sum repeated draws); the
                // ground cost is identical by construction.
                *kernel.last_mut().expect("a duplicate always follows a pushed entry") += t.kernel;
                continue;
            }
            col_idx.push(t.col as u32);
            kernel.push(t.kernel);
            cost.push(t.cost);
            row_ptr[t.row + 1] = col_idx.len();
            last = Some((t.row, t.col));
        }
        // Rows without entries inherit the previous pointer.
        for r in 1..=rows {
            if row_ptr[r] < row_ptr[r - 1] {
                row_ptr[r] = row_ptr[r - 1];
            }
        }
        Ok(CsrMatrix {
            rows,
            cols,
            row_ptr,
            col_idx,
            kernel,
            cost,
            log_kernel: None,
            derived_logk: OnceLock::new(),
        })
    }

    /// Build directly from per-row entry lists (already sorted by column).
    /// This is the fast path used by the Poisson sparsifier.
    pub fn from_rows(rows: usize, cols: usize, row_entries: Vec<Vec<(u32, f64, f64)>>) -> Self {
        assert_eq!(row_entries.len(), rows);
        let nnz: usize = row_entries.iter().map(|r| r.len()).sum();
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::with_capacity(nnz);
        let mut kernel = Vec::with_capacity(nnz);
        let mut cost = Vec::with_capacity(nnz);
        row_ptr.push(0);
        for entries in row_entries {
            for (c, k, co) in entries {
                debug_assert!((c as usize) < cols);
                col_idx.push(c);
                kernel.push(k);
                cost.push(co);
            }
            row_ptr.push(col_idx.len());
        }
        CsrMatrix {
            rows,
            cols,
            row_ptr,
            col_idx,
            kernel,
            cost,
            log_kernel: None,
            derived_logk: OnceLock::new(),
        }
    }

    /// Build from per-row entry lists carrying explicit log-kernel
    /// values: each entry is `(col, kernel, log_kernel, cost)`. The
    /// kernel value may be 0 (underflowed) as long as the log-kernel is
    /// finite — the log-domain loop then still sees the entry.
    pub fn from_rows_logk(
        rows: usize,
        cols: usize,
        row_entries: Vec<Vec<(u32, f64, f64, f64)>>,
    ) -> Self {
        assert_eq!(row_entries.len(), rows);
        let nnz: usize = row_entries.iter().map(|r| r.len()).sum();
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::with_capacity(nnz);
        let mut kernel = Vec::with_capacity(nnz);
        let mut log_kernel = Vec::with_capacity(nnz);
        let mut cost = Vec::with_capacity(nnz);
        row_ptr.push(0);
        for entries in row_entries {
            for (c, k, lk, co) in entries {
                debug_assert!((c as usize) < cols);
                col_idx.push(c);
                kernel.push(k);
                log_kernel.push(lk);
                cost.push(co);
            }
            row_ptr.push(col_idx.len());
        }
        CsrMatrix {
            rows,
            cols,
            row_ptr,
            col_idx,
            kernel,
            cost,
            log_kernel: Some(log_kernel),
            derived_logk: OnceLock::new(),
        }
    }

    /// Whether explicit log-kernel values are stored (vs derived).
    pub fn has_log_kernel(&self) -> bool {
        self.log_kernel.is_some()
    }

    /// `ln K̃` for every stored entry, as one contiguous slice aligned
    /// with `col_idx`/`kernel`/`cost` (structure-of-arrays layout).
    ///
    /// Explicit log values (from [`CsrMatrix::from_rows_logk`]) are
    /// returned directly; otherwise the logs are derived from `kernel`
    /// exactly once, on first use, and cached for the matrix lifetime —
    /// so the LSE sweeps never call `ln` inside their hot loops.
    /// Underflowed (zero) kernel values map to −∞, matching the old
    /// per-entry derivation bit for bit.
    pub fn log_kernel_values(&self) -> &[f64] {
        match &self.log_kernel {
            Some(lk) => lk,
            None => self.derived_logk.get_or_init(|| {
                self.kernel
                    .iter()
                    .map(|&k| if k > 0.0 { k.ln() } else { f64::NEG_INFINITY })
                    .collect()
            }),
        }
    }

    /// `ln K̃` for stored entry index `e` (derived from `kernel` when no
    /// explicit log values are stored). Hot loops should hoist
    /// [`CsrMatrix::log_kernel_values`] instead of calling this per entry.
    #[inline(always)]
    fn log_kernel_at(&self, e: usize) -> f64 {
        self.log_kernel_values()[e]
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// Entries of row `i` as (col, kernel, cost) triples.
    #[inline]
    pub fn row_entries(&self, i: usize) -> impl Iterator<Item = (usize, f64, f64)> + '_ {
        let lo = self.row_ptr[i];
        let hi = self.row_ptr[i + 1];
        (lo..hi).map(move |k| (self.col_idx[k] as usize, self.kernel[k], self.cost[k]))
    }

    /// `y = K̃ x` — the O(s) hot path (parallel over row blocks).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "sparse matvec dimension mismatch");
        let row_ptr = &self.row_ptr;
        let col_idx = &self.col_idx;
        let vals = &self.kernel;
        pool::parallel_map(self.rows, |i| {
            let lo = row_ptr[i];
            let hi = row_ptr[i + 1];
            let mut acc = 0.0;
            for k in lo..hi {
                acc += vals[k] * x[col_idx[k] as usize];
            }
            acc
        })
    }

    /// `y = K̃ᵀ x` — parallel with per-worker scratch accumulators.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows, "sparse matvec_t dimension mismatch");
        let cols = self.cols;
        let row_ptr = &self.row_ptr;
        let col_idx = &self.col_idx;
        let vals = &self.kernel;
        pool::parallel_fold(
            self.rows,
            |start, end| {
                let mut acc = vec![0.0; cols];
                for i in start..end {
                    let xi = x[i];
                    if xi == 0.0 {
                        continue;
                    }
                    for k in row_ptr[i]..row_ptr[i + 1] {
                        acc[col_idx[k] as usize] += vals[k] * xi;
                    }
                }
                acc
            },
            |mut a, b| {
                for (x, y) in a.iter_mut().zip(b) {
                    *x += y;
                }
                a
            },
            vec![0.0; cols],
        )
    }

    /// Fused `out[i] = f(i, (K̃ x)_i)`: one pass over the CSR arrays
    /// with the elementwise post-map applied at write-back, into a
    /// caller-owned buffer (zero allocation per call). The accumulation
    /// order per row is exactly [`CsrMatrix::matvec`]'s, so the result
    /// is bitwise-identical to `matvec` followed by a map, at every
    /// thread count.
    pub fn matvec_map_into<F>(&self, x: &[f64], out: &mut [f64], f: F)
    where
        F: Fn(usize, f64) -> f64 + Sync,
    {
        assert_eq!(x.len(), self.cols, "sparse matvec dimension mismatch");
        assert_eq!(out.len(), self.rows, "sparse matvec output length mismatch");
        let row_ptr = &self.row_ptr;
        let col_idx = &self.col_idx;
        let vals = &self.kernel;
        pool::parallel_fill_rows(out, 1, |i, cell| {
            let lo = row_ptr[i];
            let hi = row_ptr[i + 1];
            let mut acc = 0.0;
            for k in lo..hi {
                acc += vals[k] * x[col_idx[k] as usize];
            }
            cell[0] = f(i, acc);
        });
    }

    /// Fused `out[j] = f(j, (K̃ᵀ x)_j)` — the transpose twin of
    /// [`CsrMatrix::matvec_map_into`]. The gather is exactly
    /// [`CsrMatrix::matvec_t`] (deterministic chunked fold); only the
    /// elementwise post-map is fused into the write-back, so the result
    /// is bitwise-identical to `matvec_t` followed by a map.
    pub fn matvec_t_map_into<F>(&self, x: &[f64], out: &mut [f64], f: F)
    where
        F: Fn(usize, f64) -> f64,
    {
        assert_eq!(out.len(), self.cols, "sparse matvec_t output length mismatch");
        let acc = self.matvec_t(x);
        for (j, (o, v)) in out.iter_mut().zip(acc).enumerate() {
            *o = f(j, v);
        }
    }

    /// Row-wise log-sum-exp over stored entries:
    /// `y_i = log Σ_{j ∈ row i} exp(ln K̃_ij + g_j)` — the log-domain
    /// analogue of `matvec` (`(K̃ e^g)_i = e^{y_i}`), O(nnz) and parallel
    /// over row blocks. Rows with no entries (or whose every term is
    /// −∞) yield −∞, mirroring the `sketch_div` empty-row convention.
    /// `g` values may be −∞ (absent columns) but must not be +∞/NaN.
    ///
    /// The sweep is a single fused pass over the structure-of-arrays
    /// CSR layout: each term `ln K̃ + g[col]` is gathered exactly once
    /// (tracking the running max as it lands in a chunk-reused scratch
    /// buffer), then summed as `exp(t − max)` over the contiguous
    /// scratch. −∞ terms need no branch — they flow through `exp` to 0.
    /// This is bitwise-identical to the classic two-pass max-then-sum
    /// reference (same terms, same order, same operations), which the
    /// `fused_row_lse_matches_two_pass_reference` test pins.
    pub fn row_lse(&self, g: &[f64]) -> Vec<f64> {
        assert_eq!(g.len(), self.cols, "sparse row_lse dimension mismatch");
        let row_ptr = &self.row_ptr;
        let col_idx = &self.col_idx;
        let lk = self.log_kernel_values();
        pool::parallel_map_init(self.rows, Vec::<f64>::new, |terms, i| {
            let lo = row_ptr[i];
            let hi = row_ptr[i + 1];
            terms.clear();
            let mut max = f64::NEG_INFINITY;
            for e in lo..hi {
                let t = lk[e] + g[col_idx[e] as usize];
                if t > max {
                    max = t;
                }
                terms.push(t);
            }
            if max == f64::NEG_INFINITY {
                return f64::NEG_INFINITY;
            }
            let mut acc = 0.0;
            for &t in terms.iter() {
                acc += (t - max).exp();
            }
            max + acc.ln()
        })
    }

    /// Column-wise log-sum-exp over stored entries:
    /// `y_j = log Σ_{i: (i,j) stored} exp(ln K̃_ij + f_i)` — the
    /// transpose of [`CsrMatrix::row_lse`]. Parallel over row blocks
    /// with per-worker `(max, scaled-sum)` accumulators merged by the
    /// streaming log-sum-exp rule.
    pub fn col_lse(&self, f: &[f64]) -> Vec<f64> {
        assert_eq!(f.len(), self.rows, "sparse col_lse dimension mismatch");
        let cols = self.cols;
        let lk = self.log_kernel_values();
        let (mx, sm) = pool::parallel_fold(
            self.rows,
            |start, end| {
                let mut mx = vec![f64::NEG_INFINITY; cols];
                let mut sm = vec![0.0f64; cols];
                for i in start..end {
                    if f[i] == f64::NEG_INFINITY {
                        continue;
                    }
                    for e in self.row_ptr[i]..self.row_ptr[i + 1] {
                        let t = lk[e] + f[i];
                        if t == f64::NEG_INFINITY {
                            continue;
                        }
                        let j = self.col_idx[e] as usize;
                        if t > mx[j] {
                            sm[j] = sm[j] * (mx[j] - t).exp() + 1.0;
                            mx[j] = t;
                        } else {
                            sm[j] += (t - mx[j]).exp();
                        }
                    }
                }
                (mx, sm)
            },
            |(mut mx_a, mut sm_a), (mx_b, sm_b)| {
                for j in 0..cols {
                    if mx_b[j] == f64::NEG_INFINITY {
                        continue;
                    }
                    if mx_b[j] > mx_a[j] {
                        sm_a[j] = sm_a[j] * (mx_a[j] - mx_b[j]).exp() + sm_b[j];
                        mx_a[j] = mx_b[j];
                    } else {
                        sm_a[j] += sm_b[j] * (mx_b[j] - mx_a[j]).exp();
                    }
                }
                (mx_a, sm_a)
            },
            (vec![f64::NEG_INFINITY; cols], vec![0.0; cols]),
        );
        (0..cols)
            .map(|j| {
                if mx[j] == f64::NEG_INFINITY {
                    f64::NEG_INFINITY
                } else {
                    mx[j] + sm[j].ln()
                }
            })
            .collect()
    }

    /// Entries of row `i` as (col, log_kernel, cost) triples.
    #[inline]
    pub fn row_entries_log(&self, i: usize) -> impl Iterator<Item = (usize, f64, f64)> + '_ {
        let lo = self.row_ptr[i];
        let hi = self.row_ptr[i + 1];
        (lo..hi).map(move |e| (self.col_idx[e] as usize, self.log_kernel_at(e), self.cost[e]))
    }

    /// Iterate all entries as (row, col, kernel, cost).
    pub fn iter(&self) -> impl Iterator<Item = (usize, usize, f64, f64)> + '_ {
        (0..self.rows).flat_map(move |i| {
            self.row_entries(i).map(move |(j, k, c)| (i, j, k, c))
        })
    }

    /// Iterate all entries as (row, col, log_kernel, cost).
    pub fn iter_log(&self) -> impl Iterator<Item = (usize, usize, f64, f64)> + '_ {
        (0..self.rows).flat_map(move |i| {
            self.row_entries_log(i).map(move |(j, lk, c)| (i, j, lk, c))
        })
    }

    /// Densify the kernel values (tests / small problems only).
    pub fn to_dense_kernel(&self) -> crate::linalg::Mat {
        let mut m = crate::linalg::Mat::zeros(self.rows, self.cols);
        for (i, j, k, _) in self.iter() {
            m.set(i, j, m.get(i, j) + k);
        }
        m
    }

    /// Frobenius-norm of the kernel values.
    pub fn kernel_frob_norm(&self) -> f64 {
        self.kernel.iter().map(|k| k * k).sum::<f64>().sqrt()
    }
}

impl KernelOp for CsrMatrix {
    fn apply(&self, x: &[f64]) -> Vec<f64> {
        self.matvec(x)
    }
    fn apply_t(&self, x: &[f64]) -> Vec<f64> {
        self.matvec_t(x)
    }
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn example() -> CsrMatrix {
        // [[1, 0, 2],
        //  [0, 0, 0],
        //  [3, 4, 0]]
        CsrMatrix::from_rows(
            3,
            3,
            vec![
                vec![(0, 1.0, 0.1), (2, 2.0, 0.2)],
                vec![],
                vec![(0, 3.0, 0.3), (1, 4.0, 0.4)],
            ],
        )
    }

    #[test]
    fn nnz_and_shape() {
        let m = example();
        assert_eq!(m.nnz(), 4);
        assert_eq!((m.rows(), m.cols()), (3, 3));
    }

    #[test]
    fn matvec_matches_dense() {
        let m = example();
        let x = [1.0, -1.0, 0.5];
        assert_eq!(m.matvec(&x), vec![2.0, 0.0, -1.0]);
    }

    #[test]
    fn matvec_t_matches_dense_transpose() {
        let m = example();
        let x = [1.0, 5.0, -1.0];
        let dense = m.to_dense_kernel();
        let want = dense.matvec_t(&x);
        let got = m.matvec_t(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-14);
        }
    }

    #[test]
    fn from_triplets_unsorted() {
        let trips = vec![
            Triplet { row: 2, col: 1, kernel: 4.0, cost: 0.4 },
            Triplet { row: 0, col: 2, kernel: 2.0, cost: 0.2 },
            Triplet { row: 0, col: 0, kernel: 1.0, cost: 0.1 },
            Triplet { row: 2, col: 0, kernel: 3.0, cost: 0.3 },
        ];
        let m = CsrMatrix::from_triplets(3, 3, trips).unwrap();
        let e = example();
        assert_eq!(m.nnz(), e.nnz());
        let x = [0.3, 0.7, -0.2];
        let got = m.matvec(&x);
        let want = e.matvec(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-14);
        }
    }

    #[test]
    fn from_triplets_accumulates_duplicates() {
        let trips = vec![
            Triplet { row: 0, col: 0, kernel: 1.0, cost: 0.5 },
            Triplet { row: 0, col: 0, kernel: 2.5, cost: 0.5 },
        ];
        let m = CsrMatrix::from_triplets(1, 1, trips).unwrap();
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.matvec(&[1.0]), vec![3.5]);
    }

    #[test]
    fn from_triplets_rejects_out_of_bounds() {
        let trips = vec![Triplet { row: 5, col: 0, kernel: 1.0, cost: 0.0 }];
        assert!(CsrMatrix::from_triplets(3, 3, trips).is_err());
    }

    #[test]
    fn dense_roundtrip_random() {
        let mut rng = crate::rng::Rng::seed_from(99);
        let n = 20;
        let mut rows = vec![Vec::new(); n];
        for (i, row) in rows.iter_mut().enumerate() {
            for j in 0..n {
                if rng.bernoulli(0.3) {
                    row.push((j as u32, rng.uniform(), rng.uniform()));
                }
            }
            let _ = i;
        }
        let m = CsrMatrix::from_rows(n, n, rows);
        let dense: Mat = m.to_dense_kernel();
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.77).sin()).collect();
        let got = m.matvec(&x);
        let want = dense.matvec(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12);
        }
        let got_t = m.matvec_t(&x);
        let want_t = dense.matvec_t(&x);
        for (g, w) in got_t.iter().zip(&want_t) {
            assert!((g - w).abs() < 1e-12);
        }
    }

    #[test]
    fn empty_rows_are_fine() {
        let m = CsrMatrix::from_rows(4, 2, vec![vec![], vec![(1, 2.0, 0.0)], vec![], vec![]]);
        assert_eq!(m.matvec(&[1.0, 1.0]), vec![0.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn row_lse_matches_log_of_matvec() {
        let m = example();
        // g = ln x for positive x: row_lse(ln x) must equal ln(K x).
        let x = [0.5, 2.0, 1.5];
        let g: Vec<f64> = x.iter().map(|v| v.ln()).collect();
        let want = m.matvec(&x);
        let got = m.row_lse(&g);
        for (i, (lse, w)) in got.iter().zip(&want).enumerate() {
            if *w == 0.0 {
                assert_eq!(*lse, f64::NEG_INFINITY, "row {i}");
            } else {
                assert!((lse.exp() - w).abs() < 1e-12, "row {i}: {} vs {w}", lse.exp());
            }
        }
    }

    #[test]
    fn col_lse_matches_log_of_matvec_t() {
        let m = example();
        let x = [0.7, 1.3, 0.9];
        let f_vals: Vec<f64> = x.iter().map(|v| v.ln()).collect();
        let want = m.matvec_t(&x);
        let got = m.col_lse(&f_vals);
        for (j, (lse, w)) in got.iter().zip(&want).enumerate() {
            if *w == 0.0 {
                assert_eq!(*lse, f64::NEG_INFINITY, "col {j}");
            } else {
                assert!((lse.exp() - w).abs() < 1e-12, "col {j}: {} vs {w}", lse.exp());
            }
        }
    }

    #[test]
    fn lse_handles_neg_infinity_potentials() {
        let m = example();
        // Column 0 masked out entirely.
        let g = [f64::NEG_INFINITY, 0.0, 0.0];
        let got = m.row_lse(&g);
        // Row 0 keeps its (2, 2.0) entry; row 1 is empty; row 2 keeps (1, 4.0).
        assert!((got[0] - 2.0f64.ln()).abs() < 1e-12);
        assert_eq!(got[1], f64::NEG_INFINITY);
        assert!((got[2] - 4.0f64.ln()).abs() < 1e-12);
        let f_vals = [f64::NEG_INFINITY, 0.0, 0.0];
        let cols = m.col_lse(&f_vals);
        // Only row 2 contributes: col 0 gets 3.0, col 1 gets 4.0, col 2 empty.
        assert!((cols[0] - 3.0f64.ln()).abs() < 1e-12);
        assert!((cols[1] - 4.0f64.ln()).abs() < 1e-12);
        assert_eq!(cols[2], f64::NEG_INFINITY);
    }

    #[test]
    fn logk_storage_survives_underflowed_kernels() {
        // Kernel values below f64's minimum positive: the linear value is
        // stored as 0, the log value stays finite and drives the LSE.
        let lk = -800.0; // exp(-800) underflows
        let m = CsrMatrix::from_rows_logk(
            2,
            2,
            vec![
                vec![(0, 0.0, lk, 1.0), (1, 0.0, lk + 1.0, 2.0)],
                vec![(1, 0.0, lk - 1.0, 3.0)],
            ],
        );
        assert!(m.has_log_kernel());
        assert_eq!(m.nnz(), 3);
        let got = m.row_lse(&[0.0, 0.0]);
        // LSE(lk, lk+1) = lk + 1 + ln(1 + e^{-1}).
        let want0 = lk + 1.0 + (1.0 + (-1.0f64).exp()).ln();
        assert!((got[0] - want0).abs() < 1e-10, "{} vs {want0}", got[0]);
        assert!((got[1] - (lk - 1.0)).abs() < 1e-10);
        // Entries iterate with their log values.
        let entries: Vec<_> = m.iter_log().collect();
        assert_eq!(entries.len(), 3);
        assert!((entries[0].2 - lk).abs() < 1e-12);
    }

    #[test]
    fn derived_log_kernel_matches_ln_of_values() {
        let m = example();
        assert!(!m.has_log_kernel());
        for ((_, _, k, _), (_, _, lk, _)) in m.iter().zip(m.iter_log()) {
            assert!((k.ln() - lk).abs() < 1e-14);
        }
    }

    #[test]
    fn random_lse_matches_dense_reference() {
        let mut rng = crate::rng::Rng::seed_from(123);
        let n = 30;
        let mut rows = vec![Vec::new(); n];
        for row in rows.iter_mut() {
            for j in 0..n {
                if rng.bernoulli(0.25) {
                    let k = rng.uniform() + 1e-3;
                    row.push((j as u32, k, k.ln(), rng.uniform()));
                }
            }
        }
        let m = CsrMatrix::from_rows_logk(n, n, rows);
        let g: Vec<f64> = (0..n).map(|i| ((i as f64 * 0.31).sin()) * 3.0).collect();
        let x: Vec<f64> = g.iter().map(|v| v.exp()).collect();
        let want_r = m.matvec(&x);
        for (lse, w) in m.row_lse(&g).iter().zip(&want_r) {
            if *w > 0.0 {
                assert!((lse.exp() - w).abs() < 1e-10 * w.max(1.0));
            }
        }
        let want_c = m.matvec_t(&x);
        for (lse, w) in m.col_lse(&g).iter().zip(&want_c) {
            if *w > 0.0 {
                assert!((lse.exp() - w).abs() < 1e-10 * w.max(1.0));
            }
        }
    }

    /// Classic two-pass scalar LSE over one row: max sweep, then a
    /// separate sum sweep re-gathering every term. This is the
    /// pre-fusion `row_lse` body, kept as the bitwise reference.
    fn row_lse_two_pass(m: &CsrMatrix, g: &[f64]) -> Vec<f64> {
        let lk = m.log_kernel_values();
        (0..m.rows())
            .map(|i| {
                let lo = m.row_ptr[i];
                let hi = m.row_ptr[i + 1];
                let mut max = f64::NEG_INFINITY;
                for e in lo..hi {
                    let t = lk[e] + g[m.col_idx[e] as usize];
                    if t > max {
                        max = t;
                    }
                }
                if max == f64::NEG_INFINITY {
                    return f64::NEG_INFINITY;
                }
                let mut acc = 0.0;
                for e in lo..hi {
                    let t = lk[e] + g[m.col_idx[e] as usize];
                    acc += (t - max).exp();
                }
                max + acc.ln()
            })
            .collect()
    }

    #[test]
    fn fused_row_lse_matches_two_pass_reference() {
        let mut rng = crate::rng::Rng::seed_from(2024);
        for (rows, cols, density) in [(1, 1, 1.0), (7, 5, 0.5), (40, 33, 0.2), (16, 64, 0.7)] {
            let mut entries = vec![Vec::new(); rows];
            for row in entries.iter_mut() {
                for j in 0..cols {
                    if rng.bernoulli(density) {
                        row.push((j as u32, rng.uniform() * 2.0, rng.uniform()));
                    }
                }
            }
            let m = CsrMatrix::from_rows(rows, cols, entries);
            let g: Vec<f64> = (0..cols).map(|_| (rng.uniform() - 0.5) * 8.0).collect();
            let want = row_lse_two_pass(&m, &g);
            let got = m.row_lse(&g);
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "row {i} ({rows}x{cols})");
            }
        }
    }

    #[test]
    fn fused_row_lse_handles_all_neg_infinity_rows() {
        // Row 0: every term masked by a −∞ potential. Row 1: empty.
        // Row 2: underflowed (zero) kernel values → derived logs are −∞.
        let m = CsrMatrix::from_rows(
            3,
            2,
            vec![vec![(0, 1.0, 0.0)], vec![], vec![(0, 0.0, 0.0), (1, 0.0, 0.0)]],
        );
        let g = [f64::NEG_INFINITY, 0.5];
        let want = row_lse_two_pass(&m, &g);
        let got = m.row_lse(&g);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "row {i}");
        }
        assert_eq!(got[0], f64::NEG_INFINITY);
        assert_eq!(got[1], f64::NEG_INFINITY);
        assert_eq!(got[2], f64::NEG_INFINITY);
    }

    #[test]
    fn log_kernel_values_materializes_once_and_matches_per_entry_ln() {
        let m = CsrMatrix::from_rows(
            2,
            2,
            vec![vec![(0, 2.0, 0.0), (1, 0.0, 0.0)], vec![(1, 0.25, 0.0)]],
        );
        let first = m.log_kernel_values().as_ptr();
        let lk = m.log_kernel_values();
        // Same cached allocation on every call.
        assert_eq!(first, lk.as_ptr());
        assert_eq!(lk.len(), m.nnz());
        assert_eq!(lk[0].to_bits(), 2.0f64.ln().to_bits());
        assert_eq!(lk[1], f64::NEG_INFINITY);
        assert_eq!(lk[2].to_bits(), 0.25f64.ln().to_bits());
        // Explicit log storage is returned verbatim, not re-derived.
        let e = CsrMatrix::from_rows_logk(1, 1, vec![vec![(0, 0.0, -900.0, 0.0)]]);
        assert_eq!(e.log_kernel_values(), &[-900.0]);
    }

    #[test]
    fn matvec_map_into_matches_unfused_sequence() {
        let m = example();
        let x = [0.5, 2.0, 1.5];
        let a = [0.2, 0.3, 0.5];
        let post = |i: usize, v: f64| if v == 0.0 { 0.0 } else { a[i] / v };
        let mv = m.matvec(&x);
        let want: Vec<f64> = mv.iter().enumerate().map(|(i, &v)| post(i, v)).collect();
        let mut got = vec![0.0; m.rows()];
        m.matvec_map_into(&x, &mut got, post);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
        let mvt = m.matvec_t(&x);
        let want_t: Vec<f64> = mvt.iter().enumerate().map(|(j, &v)| post(j, v)).collect();
        let mut got_t = vec![0.0; m.cols()];
        m.matvec_t_map_into(&x, &mut got_t, post);
        for (g, w) in got_t.iter().zip(&want_t) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }
}
