//! Nyström low-rank factorization of a PSD kernel matrix — the substrate
//! for the Nys-Sink baseline (Altschuler et al., 2019).
//!
//! Given `K` (n×n, symmetric PSD) and a landmark set S of size r, the
//! Nyström approximation is `K ≈ C W⁺ Cᵀ` with `C = K[:, S]`,
//! `W = K[S, S]`. We store `C` and the symmetric square factor
//! `M = W⁺` (pseudo-inverse via Jacobi eigendecomposition of the r×r
//! core), so `K v ≈ C (M (Cᵀ v))` costs O(nr).

use super::{jacobi_eigen, Mat};
use crate::rng::Rng;

/// Low-rank Nyström factor: `K ≈ C · Winv · Cᵀ`.
#[derive(Clone, Debug)]
pub struct NystromFactor {
    /// n × r column sample of the kernel.
    pub c: Mat,
    /// r × r pseudo-inverse of the core.
    pub winv: Mat,
    /// Landmark indices.
    pub landmarks: Vec<usize>,
}

impl NystromFactor {
    /// `y ≈ K x` in O(nr).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let t = self.c.matvec_t(x); // r
        let s = self.winv.matvec(&t); // r
        self.c.matvec(&s) // n
    }

    /// For symmetric K the transpose product is identical; kept for
    /// interface parity with the dense/sparse kernels.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        self.matvec(x)
    }

    /// Rank of the factorization (number of retained core eigenvalues).
    pub fn rank(&self) -> usize {
        self.winv.rows()
    }

    /// Approximate entry (i, j): `C_i · Winv · C_jᵀ`. O(r²); for bulk
    /// evaluation use [`NystromFactor::left_factor`] + [`NystromFactor::entry_with`]
    /// which amortize the core product (O(r) per entry).
    pub fn entry(&self, i: usize, j: usize) -> f64 {
        let r = self.winv.rows();
        let ci = self.c.row(i);
        let cj = self.c.row(j);
        let mut acc = 0.0;
        for p in 0..r {
            let mut inner = 0.0;
            for q in 0..r {
                inner += self.winv.get(p, q) * cj[q];
            }
            acc += ci[p] * inner;
        }
        acc
    }

    /// Precompute `M = C · Winv` (n × r) so entries evaluate in O(r):
    /// `K_ij ≈ M_i · C_j`.
    pub fn left_factor(&self) -> Mat {
        self.c.matmul(&self.winv)
    }

    /// Entry via a precomputed left factor (see [`NystromFactor::left_factor`]).
    #[inline]
    pub fn entry_with(&self, left: &Mat, i: usize, j: usize) -> f64 {
        crate::linalg::dot(left.row(i), self.c.row(j))
    }
}

/// Factorize a kernel given by an entry oracle `k(i, j)` with `r` uniform
/// landmark columns (the standard Nyström sampling; the paper's Nys-Sink
/// rows use uniform landmarks as well for the main comparison).
///
/// `ridge` regularizes the core pseudo-inverse: eigenvalues below
/// `ridge * lambda_max` are dropped.
pub fn nystrom_factorize(
    n: usize,
    k: impl Fn(usize, usize) -> f64 + Sync,
    r: usize,
    ridge: f64,
    rng: &mut Rng,
) -> NystromFactor {
    let r = r.clamp(1, n);
    let landmarks = rng.sample_indices(n, r);
    let c = Mat::from_fn(n, r, |i, p| k(i, landmarks[p]));
    let w = Mat::from_fn(r, r, |p, q| k(landmarks[p], landmarks[q]));
    // Pseudo-inverse of the symmetric core via Jacobi.
    let (vals, vecs) = jacobi_eigen(&w, 60, 1e-13);
    let lmax = vals.iter().cloned().fold(0.0f64, f64::max);
    let cut = (ridge * lmax).max(f64::MIN_POSITIVE);
    let winv = Mat::from_fn(r, r, |i, j| {
        let mut acc = 0.0;
        for (k_idx, &lam) in vals.iter().enumerate() {
            if lam > cut {
                acc += vecs.get(k_idx, i) * vecs.get(k_idx, j) / lam;
            }
        }
        acc
    });
    NystromFactor { c, winv, landmarks }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Gaussian RBF kernel over 1-D points — PSD and (for wide
    /// bandwidth) numerically low-rank, Nyström's sweet spot.
    fn rbf_points(n: usize) -> Vec<f64> {
        (0..n).map(|i| i as f64 / n as f64).collect()
    }

    #[test]
    fn nystrom_exact_when_rank_full() {
        // Full-rank landmarks on a well-conditioned kernel: the
        // factorization reproduces K. (A tight RBF grid would be
        // exponentially ill-conditioned, so use well-separated points.)
        let n = 10;
        let pts: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let k = |i: usize, j: usize| (-(pts[i] - pts[j]).powi(2) / 0.5).exp();
        let mut rng = Rng::seed_from(8);
        let f = nystrom_factorize(n, k, n, 1e-12, &mut rng);
        for i in 0..n {
            for j in 0..n {
                assert!(
                    (f.entry(i, j) - k(i, j)).abs() < 1e-6,
                    "({i},{j}): {} vs {}",
                    f.entry(i, j),
                    k(i, j)
                );
            }
        }
    }

    #[test]
    fn nystrom_matvec_close_for_smooth_kernel() {
        let n = 64;
        let pts = rbf_points(n);
        let k = |i: usize, j: usize| (-(pts[i] - pts[j]).powi(2) / 0.8).exp();
        let mut rng = Rng::seed_from(9);
        let f = nystrom_factorize(n, k, 12, 1e-10, &mut rng);
        let x: Vec<f64> = (0..n).map(|i| ((i * 7) % 5) as f64 * 0.2 + 0.1).collect();
        let full = Mat::from_fn(n, n, k);
        let want = full.matvec(&x);
        let got = f.matvec(&x);
        let rel: f64 = want
            .iter()
            .zip(&got)
            .map(|(w, g)| (w - g).abs())
            .sum::<f64>()
            / want.iter().map(|w| w.abs()).sum::<f64>();
        assert!(rel < 1e-3, "relative error {rel}");
    }

    #[test]
    fn nystrom_struggles_on_near_diagonal_kernel() {
        // The WFR regime: narrow bandwidth -> near-full-rank kernel.
        // Nyström with small r should have a LARGE error here; this is
        // the failure mode the paper exploits (Section 1).
        let n = 64;
        let pts = rbf_points(n);
        let k = |i: usize, j: usize| (-(pts[i] - pts[j]).powi(2) / 1e-4).exp();
        let mut rng = Rng::seed_from(10);
        let f = nystrom_factorize(n, k, 8, 1e-10, &mut rng);
        let x = vec![1.0; n];
        let full = Mat::from_fn(n, n, k);
        let want = full.matvec(&x);
        let got = f.matvec(&x);
        let rel: f64 = want
            .iter()
            .zip(&got)
            .map(|(w, g)| (w - g).abs())
            .sum::<f64>()
            / want.iter().map(|w| w.abs()).sum::<f64>();
        assert!(rel > 0.05, "expected Nyström to fail on near-diagonal kernel, rel {rel}");
    }

    #[test]
    fn rank_respects_request() {
        let n = 16;
        let pts = rbf_points(n);
        let k = |i: usize, j: usize| (-(pts[i] - pts[j]).powi(2)).exp();
        let mut rng = Rng::seed_from(11);
        let f = nystrom_factorize(n, k, 5, 1e-10, &mut rng);
        assert_eq!(f.rank(), 5);
        assert_eq!(f.landmarks.len(), 5);
        assert_eq!(f.c.rows(), n);
        assert_eq!(f.c.cols(), 5);
    }
}
