//! Work stealing across shards, for tail latency.
//!
//! Fingerprint-affine routing optimizes cache locality, but a skewed
//! workload (every job on one support) would leave all but one shard
//! idle. When a worker's own queue drains, it steals the OLDEST batch
//! (FIFO end — see [`super::shard`]) from the DEEPEST other shard:
//! deepest-first relieves the most overloaded queue before lightly
//! loaded ones, and oldest-first takes exactly the batch dominating the
//! tail. Stealing moves batches between workers but never changes what
//! a batch computes — artifacts are content-addressed and solutions
//! placement-independent — so results stay bitwise identical with
//! stealing on or off (pinned by `cache_parity`).

use std::sync::Arc;

use super::scheduler::Batch;
use super::shard::Shard;

/// Steal one batch for the worker that owns shard `own`: victims are
/// scanned deepest-first (ties break on the lowest shard index, so the
/// scan order is deterministic), skipping `own` and empty shards.
/// Returns `None` when every other shard is empty — the caller parks
/// briefly and retries.
pub(crate) fn steal_for(own: usize, shards: &[Arc<Shard>]) -> Option<Batch> {
    let mut candidates: Vec<(usize, usize)> = shards
        .iter()
        .enumerate()
        .filter(|&(idx, _)| idx != own)
        .map(|(idx, shard)| (shard.depth(), idx))
        .filter(|&(depth, _)| depth > 0)
        .collect();
    // Deepest first; `sort_by` with a reversed depth key keeps the
    // index ascending within equal depths (sort is stable).
    candidates.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    for (_, victim) in candidates {
        // Depths are racy gauges — the victim may have drained between
        // the scan and the pop, so fall through to the next candidate.
        if let Some(batch) = shards[victim].pop_stolen() {
            return Some(batch);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empty_batch(id: u64) -> Batch {
        Batch { id, fingerprint: None, jobs: Vec::new() }
    }

    fn pool(n: usize) -> Vec<Arc<Shard>> {
        (0..n).map(|_| Arc::new(Shard::new(16))).collect()
    }

    #[test]
    fn steals_oldest_from_deepest_shard() {
        let shards = pool(3);
        shards[1].push(empty_batch(10));
        shards[2].push(empty_batch(20));
        shards[2].push(empty_batch(21));
        shards[2].push(empty_batch(22));
        // Shard 2 is deepest; its OLDEST batch (20) is taken first.
        assert_eq!(steal_for(0, &shards).unwrap().id, 20);
        assert_eq!(shards[2].stolen_from.load(std::sync::atomic::Ordering::Relaxed), 1);
        // Depths now tie at 1 vs 2 → still shard 2, then shard 1.
        assert_eq!(steal_for(0, &shards).unwrap().id, 21);
        assert_eq!(steal_for(0, &shards).unwrap().id, 22);
        assert_eq!(steal_for(0, &shards).unwrap().id, 10);
        assert!(steal_for(0, &shards).is_none());
    }

    #[test]
    fn never_steals_from_its_own_shard() {
        let shards = pool(2);
        shards[0].push(empty_batch(1));
        assert!(steal_for(0, &shards).is_none());
        assert_eq!(steal_for(1, &shards).unwrap().id, 1);
    }

    #[test]
    fn single_shard_pool_has_nothing_to_steal() {
        let shards = pool(1);
        shards[0].push(empty_batch(1));
        assert!(steal_for(0, &shards).is_none());
    }
}
