//! Small utilities: a dependency-free JSON writer for experiment output
//! and a minimal JSON reader for the artifact manifest.

pub mod json;
pub mod table;
