//! HTTP response assembly: status + reason, a small header set, and a
//! body, written in one buffered pass. The gateway emits exactly three
//! content shapes — compact JSON, a JSON error object, and the
//! Prometheus text page — so three constructors cover the surface.

use std::io::Write;

use crate::util::json::Json;

/// Canonical reason phrase for the status codes the gateway emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// One response, ready to serialize. `close` ends the connection after
/// the write — protocol errors always close (the stream position may be
/// unreliable after a malformed request), success responses keep alive.
#[derive(Clone, Debug)]
pub struct Response {
    /// HTTP status code.
    pub status: u16,
    /// `Content-Type` header value.
    pub content_type: &'static str,
    /// Response body bytes.
    pub body: Vec<u8>,
    /// Whether the connection closes after this response.
    pub close: bool,
    /// Extra headers (e.g. `allow` on 405, `retry-after` on 429).
    pub extra: Vec<(&'static str, String)>,
}

impl Response {
    /// A JSON response. Error statuses (≥ 400) close the connection.
    pub fn json(status: u16, payload: &Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: payload.to_string_compact().into_bytes(),
            close: status >= 400,
            extra: Vec::new(),
        }
    }

    /// A JSON error body `{"error": message}` with the given status.
    pub fn error(status: u16, message: &str) -> Response {
        Response::json(status, &Json::obj(vec![("error", Json::str(message))]))
    }

    /// A plain-text response (the `/metrics` exposition page).
    pub fn text(status: u16, content_type: &'static str, body: String) -> Response {
        Response {
            status,
            content_type,
            body: body.into_bytes(),
            close: status >= 400,
            extra: Vec::new(),
        }
    }

    /// Attach an extra header (builder-style).
    pub fn with_header(mut self, name: &'static str, value: String) -> Response {
        self.extra.push((name, value));
        self
    }

    /// Serialize status line, headers, and body to `writer` and flush.
    pub fn write_to<W: Write>(&self, writer: &mut W) -> std::io::Result<()> {
        write!(writer, "HTTP/1.1 {} {}\r\n", self.status, reason(self.status))?;
        write!(writer, "content-type: {}\r\n", self.content_type)?;
        write!(writer, "content-length: {}\r\n", self.body.len())?;
        for (name, value) in &self.extra {
            write!(writer, "{name}: {value}\r\n")?;
        }
        if self.close {
            writer.write_all(b"connection: close\r\n")?;
        }
        writer.write_all(b"\r\n")?;
        writer.write_all(&self.body)?;
        writer.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serializes_a_json_success() {
        let mut out: Vec<u8> = Vec::new();
        Response::json(200, &Json::obj(vec![("ok", Json::Bool(true))]))
            .write_to(&mut out)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("content-type: application/json\r\n"), "{text}");
        assert!(text.contains("content-length: 11\r\n"), "{text}");
        assert!(!text.contains("connection: close"), "{text}");
        assert!(text.ends_with("\r\n\r\n{\"ok\":true}"), "{text}");
    }

    #[test]
    fn errors_close_and_carry_a_json_body() {
        let mut out: Vec<u8> = Vec::new();
        let resp = Response::error(429, "busy").with_header("retry-after", "1".to_string());
        assert!(resp.close);
        resp.write_to(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("retry-after: 1\r\n"), "{text}");
        assert!(text.contains("connection: close\r\n"), "{text}");
        assert!(text.ends_with("{\"error\":\"busy\"}"), "{text}");
    }

    #[test]
    fn reason_phrases_cover_the_gateway_statuses() {
        for status in [200, 400, 404, 405, 413, 429, 431, 500, 503, 505] {
            assert_ne!(reason(status), "Unknown", "{status}");
        }
        assert_eq!(reason(418), "Unknown");
    }
}
