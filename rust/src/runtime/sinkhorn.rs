//! Dense Sinkhorn driven through the AOT artifacts: the Rust side owns
//! the convergence loop; each `sinkhorn_block` execution advances the
//! scalings by `block_iters` fused iterations (L1 Pallas matvec+scale
//! kernels inside), and the objective is evaluated on-device.

use std::sync::Arc;

use super::registry::{ArtifactRegistry, Entry};
use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::ot::uot::uot_rho;

/// Result of a runtime-backed solve.
#[derive(Clone, Debug)]
pub struct RuntimeSolution {
    pub u: Vec<f64>,
    pub v: Vec<f64>,
    pub objective: f64,
    /// Total scaling iterations (multiples of `block_iters`).
    pub iterations: usize,
    pub displacement: f64,
    pub converged: bool,
}

/// Mass assigned to padded support points: small enough to be
/// negligible in objectives, large enough to keep `a / (K v)` finite.
const PAD_MASS: f32 = 1e-20;

/// Dense entropic OT/UOT solver executing on the PJRT runtime.
pub struct DenseSinkhornRuntime {
    registry: Arc<ArtifactRegistry>,
}

impl DenseSinkhornRuntime {
    pub fn new(registry: Arc<ArtifactRegistry>) -> Self {
        DenseSinkhornRuntime { registry }
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    /// Solve entropic OT (Algorithm 1) on-device and evaluate Eq. 6.
    pub fn solve_ot(
        &self,
        kernel: &Mat,
        cost: &Mat,
        a: &[f64],
        b: &[f64],
        eps: f64,
        delta: f64,
        max_iters: usize,
    ) -> Result<RuntimeSolution> {
        self.solve(kernel, cost, a, b, 1.0, ObjectiveKind::Ot { eps }, delta, max_iters)
    }

    /// Solve entropic UOT (Algorithm 2) on-device and evaluate Eq. 10.
    #[allow(clippy::too_many_arguments)]
    pub fn solve_uot(
        &self,
        kernel: &Mat,
        cost: &Mat,
        a: &[f64],
        b: &[f64],
        lambda: f64,
        eps: f64,
        delta: f64,
        max_iters: usize,
    ) -> Result<RuntimeSolution> {
        self.solve(
            kernel,
            cost,
            a,
            b,
            uot_rho(lambda, eps),
            ObjectiveKind::Uot { lambda, eps },
            delta,
            max_iters,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn solve(
        &self,
        kernel: &Mat,
        cost: &Mat,
        a: &[f64],
        b: &[f64],
        rho: f64,
        objective: ObjectiveKind,
        delta: f64,
        max_iters: usize,
    ) -> Result<RuntimeSolution> {
        let n = a.len();
        if kernel.rows() != n || kernel.cols() != n || b.len() != n {
            return Err(Error::Dimension(
                "runtime solver requires square kernel with matching marginals".into(),
            ));
        }
        let np = self.registry.padded_size(Entry::SinkhornBlock, n)?;
        let block_iters = self.registry.block_iters();
        let block_exe = self.registry.executable(Entry::SinkhornBlock, np)?;

        // Padded f32 buffers. Padded points get PAD_MASS marginals and a
        // unit diagonal kernel entry so their scalings stay finite.
        let kbuf = pad_matrix(kernel, n, np, true);
        let abuf = pad_vector(a, n, np);
        let bbuf = pad_vector(b, n, np);
        let mut u: Vec<f32> = vec![1.0; np];
        let mut v: Vec<f32> = vec![1.0; np];

        let k_lit = literal_matrix(&kbuf, np)?;
        let a_lit = literal_col(&abuf)?;
        let b_lit = literal_col(&bbuf)?;
        let rho_lit = xla::Literal::scalar(rho as f32);

        let mut iterations = 0;
        let mut displacement = f64::INFINITY;
        let mut converged = false;
        while iterations < max_iters {
            let u_lit = literal_col(&u)?;
            let v_lit = literal_col(&v)?;
            let result = block_exe
                .execute::<xla::Literal>(&[
                    k_lit.clone(),
                    a_lit.clone(),
                    b_lit.clone(),
                    u_lit,
                    v_lit,
                    rho_lit.clone(),
                ])?[0][0]
                .to_literal_sync()?;
            let (u_out, v_out, err) = result.to_tuple3()?;
            u = u_out.to_vec::<f32>()?;
            v = v_out.to_vec::<f32>()?;
            displacement = err.to_vec::<f32>()?[0] as f64;
            iterations += block_iters;
            if !displacement.is_finite() {
                return Err(Error::Numerical(format!(
                    "runtime scalings diverged at iteration {iterations}"
                )));
            }
            if displacement <= delta {
                converged = true;
                break;
            }
        }

        // Objective on-device.
        let cbuf = pad_matrix(cost, n, np, false);
        let c_lit = literal_matrix(&cbuf, np)?;
        let u_lit = literal_col(&u)?;
        let v_lit = literal_col(&v)?;
        let obj = match objective {
            ObjectiveKind::Ot { eps } => {
                let exe = self.registry.executable(Entry::OtObjective, np)?;
                let out = exe.execute::<xla::Literal>(&[
                    k_lit.clone(),
                    c_lit,
                    u_lit,
                    v_lit,
                    xla::Literal::scalar(eps as f32),
                ])?[0][0]
                    .to_literal_sync()?;
                out.to_tuple1()?.to_vec::<f32>()?[0] as f64
            }
            ObjectiveKind::Uot { lambda, eps } => {
                let exe = self.registry.executable(Entry::UotObjective, np)?;
                let out = exe.execute::<xla::Literal>(&[
                    k_lit.clone(),
                    c_lit,
                    a_lit.clone(),
                    b_lit.clone(),
                    u_lit,
                    v_lit,
                    xla::Literal::scalar(lambda as f32),
                    xla::Literal::scalar(eps as f32),
                ])?[0][0]
                    .to_literal_sync()?;
                out.to_tuple1()?.to_vec::<f32>()?[0] as f64
            }
        };
        if !obj.is_finite() {
            return Err(Error::Numerical("runtime objective is not finite".into()));
        }
        Ok(RuntimeSolution {
            u: u[..n].iter().map(|&x| x as f64).collect(),
            v: v[..n].iter().map(|&x| x as f64).collect(),
            objective: obj,
            iterations,
            displacement,
            converged,
        })
    }
}

enum ObjectiveKind {
    Ot { eps: f64 },
    Uot { lambda: f64, eps: f64 },
}

/// Pad an n×n matrix to np×np f32. `diag_pad` puts 1.0 on padded
/// diagonal entries (kernel) vs 0.0 (cost).
fn pad_matrix(m: &Mat, n: usize, np: usize, diag_pad: bool) -> Vec<f32> {
    let mut out = vec![0.0f32; np * np];
    for i in 0..n {
        let row = m.row(i);
        for j in 0..n {
            let v = row[j];
            out[i * np + j] = if v.is_finite() { v as f32 } else { 0.0 };
        }
    }
    if diag_pad {
        for i in n..np {
            out[i * np + i] = 1.0;
        }
    }
    out
}

fn pad_vector(x: &[f64], n: usize, np: usize) -> Vec<f32> {
    let mut out = vec![PAD_MASS; np];
    for i in 0..n {
        out[i] = x[i] as f32;
    }
    out
}

fn literal_matrix(buf: &[f32], np: usize) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(buf).reshape(&[np as i64, np as i64])?)
}

fn literal_col(buf: &[f32]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(buf).reshape(&[buf.len() as i64, 1])?)
}
