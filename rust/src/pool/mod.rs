//! From-scratch data-parallel helpers (the build image has no `rayon`).
//!
//! Built on `std::thread::scope` (stable since 1.63): work is split into
//! contiguous chunks, one per worker, so there is no work-stealing
//! overhead — appropriate for the embarrassingly parallel loops in this
//! crate (row-blocked matvecs, per-replication experiment sweeps,
//! element-wise Poisson sampling).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::util::sync::lock_unpoisoned;

/// Number of worker threads to use (respects `SPAR_SINK_THREADS`,
/// defaults to available parallelism, minimum 1).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("SPAR_SINK_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `f(start, end)` over disjoint chunks of `[0, len)` in parallel.
///
/// `f` must be `Sync` (shared by reference across workers). Chunks are
/// contiguous, sized `ceil(len / workers)`.
pub fn parallel_chunks<F>(len: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if len == 0 {
        return;
    }
    let workers = num_threads().min(len);
    if workers <= 1 || len < 2 {
        f(0, len);
        return;
    }
    let chunk = len.div_ceil(workers);
    std::thread::scope(|scope| {
        for w in 0..workers {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(len);
            if start >= end {
                break;
            }
            let f = &f;
            scope.spawn(move || f(start, end));
        }
    });
}

/// Parallel map over indices `0..len`, collecting results in order.
///
/// Each index is evaluated exactly once; results are written into a
/// pre-allocated vector through disjoint chunk views.
pub fn parallel_map<T, F>(len: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); len];
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        parallel_chunks(len, |start, end| {
            // SAFETY: chunks are disjoint, each index written exactly once,
            // and the vector outlives the scope.
            let p = out_ptr;
            for i in start..end {
                unsafe { *p.0.add(i) = f(i) };
            }
        });
    }
    out
}

struct SendPtr<T>(*mut T);
impl<T> Clone for SendPtr<T> { fn clone(&self) -> Self { *self } }
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// [`parallel_map`] with chunk-local scratch state: `init()` runs once
/// per worker chunk and the resulting value is threaded through every
/// `f(&mut scratch, i)` call in that chunk. This is the allocation
/// hoist for per-item temporary buffers — the fused streaming-LSE row
/// sweep reuses one scratch vector across all rows of a chunk instead
/// of allocating per row. `f` must not let results depend on scratch
/// *contents* carried across items (only capacity), or chunk boundaries
/// would leak into outputs.
pub fn parallel_map_init<T, S, FI, F>(len: usize, init: FI, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    FI: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let mut out = vec![T::default(); len];
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        parallel_chunks(len, |start, end| {
            // SAFETY: chunks are disjoint, each index written exactly
            // once, and the vector outlives the scope.
            let p = out_ptr;
            let mut scratch = init();
            for i in start..end {
                unsafe { *p.0.add(i) = f(&mut scratch, i) };
            }
        });
    }
    out
}

/// Tiled variant of [`parallel_fill_rows`]: rows are grouped into
/// fixed-height blocks of `tile_rows` (the last block may be shorter)
/// and `f(row_start, row_end, slab)` writes one whole block into its
/// contiguous slab of `(row_end - row_start) * width` elements.
///
/// Block boundaries depend only on the total row count — never on the
/// worker count — and workers own contiguous runs of whole blocks, so
/// any builder whose entries are independent functions of their index
/// stays bit-identical across `SPAR_SINK_THREADS` (the same contract
/// as [`parallel_fill_rows`], pinned by the `thread_determinism` wall).
/// The block shape is what lets the dense cost/Gibbs builders loop
/// column tiles inside a row block for cache locality.
pub fn parallel_fill_row_tiles<T, F>(out: &mut [T], width: usize, tile_rows: usize, f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    if width == 0 || out.is_empty() {
        return;
    }
    assert!(tile_rows > 0, "tile height must be positive");
    assert_eq!(out.len() % width, 0, "buffer is not a whole number of rows");
    let rows = out.len() / width;
    let tiles = rows.div_ceil(tile_rows);
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_chunks(tiles, |start, end| {
        for t in start..end {
            let r0 = t * tile_rows;
            let r1 = (r0 + tile_rows).min(rows);
            // SAFETY: blocks are disjoint contiguous slices of `out`,
            // each written by exactly one worker, and `out` outlives
            // the scoped threads inside `parallel_chunks`.
            let slab = unsafe {
                std::slice::from_raw_parts_mut(ptr.0.add(r0 * width), (r1 - r0) * width)
            };
            f(r0, r1, slab);
        }
    });
}

/// Fill `out` (a whole number of `width`-sized rows) in parallel:
/// `f(i, row)` writes row `i` into its disjoint slice. Built on
/// [`parallel_chunks`], so rows are split into contiguous per-worker
/// blocks; each row is written by exactly one worker and every entry is
/// an independent function of its index, making the result identical
/// for any thread count (pinned by the `thread_determinism` test wall).
///
/// This is the row-loop primitive behind the dense cost/kernel builders
/// in [`crate::ot::cost`] — it avoids both the per-element index
/// arithmetic of [`parallel_map`] and per-row allocations.
pub fn parallel_fill_rows<T, F>(out: &mut [T], width: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if width == 0 || out.is_empty() {
        return;
    }
    assert_eq!(out.len() % width, 0, "buffer is not a whole number of rows");
    let rows = out.len() / width;
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_chunks(rows, |start, end| {
        for i in start..end {
            // SAFETY: rows are disjoint width-sized slices of `out`,
            // each written by exactly one worker, and `out` outlives
            // the scoped threads inside `parallel_chunks`.
            let row = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(i * width), width) };
            f(i, row);
        }
    });
}

/// Parallel fold: map each chunk to a partial value, then reduce the
/// partials sequentially (deterministic reduce order by chunk index).
pub fn parallel_fold<T, FM, FR>(len: usize, map_chunk: FM, reduce: FR, init: T) -> T
where
    T: Send,
    FM: Fn(usize, usize) -> T + Sync,
    FR: Fn(T, T) -> T,
{
    let workers = num_threads().min(len.max(1));
    if workers <= 1 || len < 2 {
        return reduce(init, map_chunk(0, len));
    }
    let chunk = len.div_ceil(workers);
    let partials: Mutex<Vec<(usize, T)>> = Mutex::new(Vec::with_capacity(workers));
    std::thread::scope(|scope| {
        for w in 0..workers {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(len);
            if start >= end {
                break;
            }
            let map_chunk = &map_chunk;
            let partials = &partials;
            scope.spawn(move || {
                let v = map_chunk(start, end);
                lock_unpoisoned(partials).push((w, v));
            });
        }
    });
    // A panicking map_chunk propagates out of the scope join above, so
    // this is only reachable with every partial pushed; recover the
    // (intact) buffer even if a late-poisoned flag is set.
    let mut parts = partials.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
    parts.sort_by_key(|(w, _)| *w);
    parts.into_iter().fold(init, |acc, (_, v)| reduce(acc, v))
}

/// A simple dynamic work queue: workers pull indices until exhausted.
/// Useful when per-item cost is highly variable (e.g. per-video solves).
pub fn parallel_for_dynamic<F>(len: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let workers = num_threads().min(len.max(1));
    if workers <= 1 || len < 2 {
        for i in 0..len {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let f = &f;
            let next = &next;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= len {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn chunks_cover_range_exactly_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_chunks(1000, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(257, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn fold_sums_correctly() {
        let total = parallel_fold(
            10_001,
            |s, e| (s..e).map(|i| i as u64).sum::<u64>(),
            |a, b| a + b,
            0u64,
        );
        assert_eq!(total, 10_000 * 10_001 / 2);
    }

    #[test]
    fn dynamic_covers_all() {
        let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
        parallel_for_dynamic(97, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_and_single() {
        parallel_chunks(0, |_, _| panic!("must not run"));
        let out = parallel_map(1, |i| i + 1);
        assert_eq!(out, vec![1]);
    }

    #[test]
    fn map_init_matches_map_and_reuses_scratch() {
        let want = parallel_map(301, |i| i * 3);
        let got = parallel_map_init(301, Vec::<usize>::new, |scratch, i| {
            scratch.clear();
            scratch.extend(0..3);
            i * scratch.len()
        });
        assert_eq!(got, want);
        assert_eq!(parallel_map_init(0, || (), |(), i| i), Vec::<usize>::new());
    }

    #[test]
    fn fill_row_tiles_covers_every_entry_once() {
        // Tile heights straddling the row count, including the
        // boundary cases tile-1 / tile / tile+1 rows.
        for rows in [1usize, 6, 7, 8, 23] {
            for tile in [1usize, 7, 32] {
                let width = 5;
                let mut out = vec![0usize; rows * width];
                parallel_fill_row_tiles(&mut out, width, tile, |r0, r1, slab| {
                    assert_eq!(slab.len(), (r1 - r0) * width);
                    for (k, v) in slab.iter_mut().enumerate() {
                        *v = r0 * width + k + 1;
                    }
                });
                for (k, v) in out.iter().enumerate() {
                    assert_eq!(*v, k + 1, "rows {rows} tile {tile}");
                }
            }
        }
        // Degenerate shapes are no-ops.
        parallel_fill_row_tiles(&mut [] as &mut [usize], 4, 8, |_, _, _| panic!("must not run"));
        let mut some = vec![0usize; 3];
        parallel_fill_row_tiles(&mut some, 0, 8, |_, _, _| panic!("must not run"));
    }

    #[test]
    fn fill_rows_writes_each_row_once() {
        let (rows, width) = (37, 11);
        let mut out = vec![0usize; rows * width];
        parallel_fill_rows(&mut out, width, |i, row| {
            for (j, v) in row.iter_mut().enumerate() {
                *v = i * width + j + 1;
            }
        });
        for (k, v) in out.iter().enumerate() {
            assert_eq!(*v, k + 1);
        }
        // Degenerate shapes are no-ops.
        parallel_fill_rows(&mut [] as &mut [usize], 4, |_, _| panic!("must not run"));
        let mut some = vec![0usize; 3];
        parallel_fill_rows(&mut some, 0, |_, _| panic!("must not run"));
    }
}
