//! Parity tests for the unified `api::solve` surface: for every
//! registered method, dispatching through the registry must return a
//! BITWISE-identical objective to the legacy free-function entry point
//! it adapts — on OT and UOT formulations, from dense costs and from
//! entry oracles. Plus registry-resolution coverage.

use std::sync::Arc;

use spar_sink::api::{self, CostSource, Formulation, Method, OtProblem, SolverSpec};
use spar_sink::experiments::common::normalize_cost;
use spar_sink::linalg::Mat;
use spar_sink::metrics::s0;
use spar_sink::ot::barycenter::ibp_barycenter;
use spar_sink::ot::cost::{gibbs_kernel, sq_euclidean_cost};
use spar_sink::ot::sinkhorn::{sinkhorn_ot, SinkhornParams};
use spar_sink::ot::uot::sinkhorn_uot;
use spar_sink::rng::Rng;
use spar_sink::solvers::backend::ScalingBackend;
use spar_sink::solvers::greenkhorn::{greenkhorn_ot, GreenkhornParams};
use spar_sink::solvers::nys_sink::{nys_sink_ot, nys_sink_uot, NysSinkParams};
use spar_sink::solvers::rand_sink::{rand_sink_ot, rand_sink_uot};
use spar_sink::solvers::screenkhorn::{screenkhorn_ot, ScreenkhornParams};
use spar_sink::solvers::spar_ibp::spar_ibp;
use spar_sink::solvers::spar_sink::{spar_sink_ot, spar_sink_uot, SparSinkParams};

const SEED: u64 = 77;
const S_MULT: f64 = 8.0;

/// Square instance with skewed marginals on a normalized cost.
fn instance(n: usize, seed: u64) -> (Arc<Mat>, Vec<f64>, Vec<f64>) {
    let mut rng = Rng::seed_from(seed);
    let pts: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..3).map(|_| rng.uniform()).collect())
        .collect();
    let cost = Arc::new(normalize_cost(&sq_euclidean_cost(&pts, &pts)));
    let mk = |rng: &mut Rng| -> Vec<f64> {
        let raw: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.05).collect();
        let s: f64 = raw.iter().sum();
        raw.iter().map(|x| x / s).collect()
    };
    let a = mk(&mut rng);
    let b = mk(&mut rng);
    (cost, a, b)
}

/// The same problem exposed through entry oracles instead of the dense
/// matrix (log-kernel left to the derived `−C/ε`, exactly what the
/// dense path samples through).
fn as_oracle(problem: &OtProblem) -> OtProblem {
    let dense = problem.cost.to_mat();
    let mut out = problem.clone();
    out.cost = CostSource::oracle(dense.rows(), dense.cols(), move |i, j| dense.get(i, j));
    out
}

fn spec(method: Method) -> SolverSpec {
    SolverSpec::new(method).with_budget(S_MULT).with_seed(SEED)
}

fn assert_bits(label: &str, api_obj: f64, legacy_obj: f64) {
    assert_eq!(
        api_obj.to_bits(),
        legacy_obj.to_bits(),
        "{label}: api {api_obj} != legacy {legacy_obj}"
    );
}

/// Legacy objective for `method` on a balanced problem (the free
/// functions the registry adapts).
fn legacy_ot(method: Method, cost: &Mat, a: &[f64], b: &[f64], eps: f64) -> f64 {
    let params = SinkhornParams::default();
    let mut rng = Rng::seed_from(SEED);
    match method {
        Method::Sinkhorn => {
            let kernel = gibbs_kernel(cost, eps);
            sinkhorn_ot(&kernel, cost, a, b, eps, &params).unwrap().objective
        }
        Method::SparSink => {
            spar_sink_ot(cost, a, b, eps, S_MULT, &SparSinkParams::default(), &mut rng)
                .unwrap()
                .solution
                .objective
        }
        Method::SparSinkLog => {
            let p = SparSinkParams { backend: ScalingBackend::LogDomain, ..Default::default() };
            spar_sink_ot(cost, a, b, eps, S_MULT, &p, &mut rng).unwrap().solution.objective
        }
        Method::RandSink => rand_sink_ot(cost, a, b, eps, S_MULT, &params, &mut rng)
            .unwrap()
            .solution
            .objective,
        Method::NysSink => {
            let n = a.len();
            let rank = ((S_MULT * s0(n) / n as f64).ceil() as usize).max(1);
            let kernel = gibbs_kernel(cost, eps);
            nys_sink_ot(
                |i, j| kernel.get(i, j),
                |i, j| cost.get(i, j),
                a,
                b,
                eps,
                rank,
                &NysSinkParams::default(),
                &mut rng,
            )
            .unwrap()
            .objective
        }
        Method::Greenkhorn => {
            let kernel = gibbs_kernel(cost, eps);
            greenkhorn_ot(&kernel, cost, a, b, eps, &GreenkhornParams::default())
                .unwrap()
                .objective
        }
        Method::Screenkhorn => {
            let kernel = gibbs_kernel(cost, eps);
            screenkhorn_ot(&kernel, cost, a, b, eps, &ScreenkhornParams::default())
                .unwrap()
                .objective
        }
        Method::SparIbp => unreachable!("barycenter-only"),
    }
}

/// Legacy objective for `method` on an unbalanced problem.
fn legacy_uot(method: Method, cost: &Mat, a: &[f64], b: &[f64], lambda: f64, eps: f64) -> f64 {
    let params = SinkhornParams::default();
    let mut rng = Rng::seed_from(SEED);
    match method {
        Method::Sinkhorn => {
            let kernel = gibbs_kernel(cost, eps);
            sinkhorn_uot(&kernel, cost, a, b, lambda, eps, &params).unwrap().objective
        }
        Method::SparSink => {
            spar_sink_uot(cost, a, b, lambda, eps, S_MULT, &SparSinkParams::default(), &mut rng)
                .unwrap()
                .solution
                .objective
        }
        Method::SparSinkLog => {
            let p = SparSinkParams { backend: ScalingBackend::LogDomain, ..Default::default() };
            spar_sink_uot(cost, a, b, lambda, eps, S_MULT, &p, &mut rng)
                .unwrap()
                .solution
                .objective
        }
        Method::RandSink => rand_sink_uot(cost, a, b, lambda, eps, S_MULT, &params, &mut rng)
            .unwrap()
            .solution
            .objective,
        Method::NysSink => {
            let n = a.len();
            let rank = ((S_MULT * s0(n) / n as f64).ceil() as usize).max(1);
            let kernel = gibbs_kernel(cost, eps);
            nys_sink_uot(
                |i, j| kernel.get(i, j),
                |i, j| cost.get(i, j),
                a,
                b,
                lambda,
                eps,
                rank,
                &NysSinkParams::default(),
                &mut rng,
            )
            .unwrap()
            .objective
        }
        _ => unreachable!("not a UOT method"),
    }
}

const OT_METHODS: [Method; 7] = [
    Method::Sinkhorn,
    Method::SparSink,
    Method::SparSinkLog,
    Method::RandSink,
    Method::NysSink,
    Method::Greenkhorn,
    Method::Screenkhorn,
];

const UOT_METHODS: [Method; 5] = [
    Method::Sinkhorn,
    Method::SparSink,
    Method::SparSinkLog,
    Method::RandSink,
    Method::NysSink,
];

#[test]
fn every_method_resolves_in_the_registry() {
    for method in Method::ALL {
        let solver = api::lookup(method.name())
            .unwrap_or_else(|| panic!("{method:?} has no registered solver"));
        assert_eq!(solver.name(), method.name());
        assert_eq!(Method::parse(method.name()), Some(method));
    }
    assert_eq!(api::registry().len(), Method::ALL.len());
}

#[test]
fn dense_ot_objectives_are_bitwise_identical_to_legacy() {
    let (cost, a, b) = instance(48, 101);
    let eps = 0.1;
    let problem = OtProblem::balanced(&cost, a.clone(), b.clone(), eps);
    for method in OT_METHODS {
        let sol = api::solve(&problem, &spec(method)).unwrap();
        let legacy = legacy_ot(method, &cost, &a, &b, eps);
        assert_bits(&format!("dense OT {method:?}"), sol.objective, legacy);
    }
}

#[test]
fn dense_uot_objectives_are_bitwise_identical_to_legacy() {
    let (cost, a, b) = instance(40, 103);
    // Unbalance the masses.
    let a: Vec<f64> = a.iter().map(|x| x * 5.0).collect();
    let b: Vec<f64> = b.iter().map(|x| x * 3.0).collect();
    let (lambda, eps) = (1.0, 0.1);
    let problem = OtProblem::unbalanced(&cost, a.clone(), b.clone(), lambda, eps);
    for method in UOT_METHODS {
        let sol = api::solve(&problem, &spec(method)).unwrap();
        let legacy = legacy_uot(method, &cost, &a, &b, lambda, eps);
        assert_bits(&format!("dense UOT {method:?}"), sol.objective, legacy);
    }
}

#[test]
fn oracle_ot_objectives_are_bitwise_identical_to_legacy() {
    // Oracle costs over the SAME entries: every method must sample /
    // materialize its way to the exact same objective as the dense
    // legacy call (square problem, so the oracle budget convention
    // s0(max(n, m)) coincides with the dense s0(n)).
    let (cost, a, b) = instance(48, 107);
    let eps = 0.1;
    let dense = OtProblem::balanced(&cost, a.clone(), b.clone(), eps);
    let oracle = as_oracle(&dense);
    for method in OT_METHODS {
        let sol = api::solve(&oracle, &spec(method)).unwrap();
        let legacy = legacy_ot(method, &cost, &a, &b, eps);
        assert_bits(&format!("oracle OT {method:?}"), sol.objective, legacy);
    }
}

#[test]
fn oracle_uot_objectives_are_bitwise_identical_to_legacy() {
    let (cost, a, b) = instance(40, 109);
    let a: Vec<f64> = a.iter().map(|x| x * 5.0).collect();
    let b: Vec<f64> = b.iter().map(|x| x * 3.0).collect();
    let (lambda, eps) = (1.0, 0.1);
    let dense = OtProblem::unbalanced(&cost, a.clone(), b.clone(), lambda, eps);
    let oracle = as_oracle(&dense);
    for method in UOT_METHODS {
        let sol = api::solve(&oracle, &spec(method)).unwrap();
        let legacy = legacy_uot(method, &cost, &a, &b, lambda, eps);
        assert_bits(&format!("oracle UOT {method:?}"), sol.objective, legacy);
    }
}

#[test]
fn barycenter_solves_are_bitwise_identical_to_legacy() {
    let n = 32;
    let pts: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 / (n - 1) as f64]).collect();
    let cost = Arc::new(normalize_cost(&sq_euclidean_cost(&pts, &pts)));
    let eps = 0.01;
    let hist = |mu: f64| -> Vec<f64> {
        let w: Vec<f64> =
            pts.iter().map(|p| (-(p[0] - mu).powi(2) / 0.01).exp() + 1e-4).collect();
        let s: f64 = w.iter().sum();
        w.iter().map(|x| x / s).collect()
    };
    let marginals = vec![hist(0.2), hist(0.5), hist(0.8)];
    let weights = vec![1.0 / 3.0; 3];
    let problem =
        OtProblem::barycenter(&cost, marginals.clone(), weights.clone(), eps);
    let kernels = vec![gibbs_kernel(&cost, eps); 3];
    let params = SinkhornParams::default();

    // Exact IBP through the registry's `sinkhorn` entry.
    let exact = api::solve(&problem, &spec(Method::Sinkhorn)).unwrap();
    let legacy = ibp_barycenter(&kernels, &marginals, &weights, &params).unwrap();
    let q = exact.barycenter.as_ref().expect("q");
    assert_eq!(q.len(), legacy.q.len());
    for (i, (x, y)) in q.iter().zip(&legacy.q).enumerate() {
        assert_bits(&format!("ibp q[{i}]"), *x, *y);
    }

    // Spar-IBP through the registry.
    let sol = api::solve(&problem, &spec(Method::SparIbp)).unwrap();
    let mut rng = Rng::seed_from(SEED);
    let legacy =
        spar_ibp(&kernels, &marginals, &weights, S_MULT * s0(n), &params, &mut rng).unwrap();
    let q = sol.barycenter.as_ref().expect("q");
    assert_eq!(sol.stats.len(), 3);
    for (i, (x, y)) in q.iter().zip(&legacy.solution.q).enumerate() {
        assert_bits(&format!("spar-ibp q[{i}]"), *x, *y);
    }
}

#[test]
fn formulation_mismatches_are_rejected() {
    let (cost, a, b) = instance(16, 113);
    let balanced = OtProblem::balanced(&cost, a, b, 0.1);
    assert!(api::solve(&balanced, &spec(Method::SparIbp)).is_err());
    let mut unbalanced = balanced.clone();
    unbalanced.formulation = Formulation::Unbalanced { lambda: 1.0 };
    for method in [Method::Greenkhorn, Method::Screenkhorn] {
        assert!(api::solve(&unbalanced, &spec(method)).is_err(), "{method:?}");
    }
}
