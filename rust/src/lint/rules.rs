//! The contract-rule registry.
//!
//! Each rule is a token-level check over [`super::scanner::ScannedFile`]
//! lines (comments and string literals already blanked). Rules are
//! scoped to path prefixes relative to the lint root; an empty scope
//! means the whole tree. Findings can be suppressed per line with
//! `// lint: allow(rule-id, "reason")` (see [`super::lint_source`]) or
//! per file via `lint.toml` (see [`super::config::LintConfig`]).

use super::diagnostics::Finding;
use super::scanner::ScannedFile;
use std::collections::BTreeSet;

/// One contract rule: id, one-line summary, scope, and checker.
pub struct Rule {
    /// Stable rule id, used in diagnostics, pragmas, and `lint.toml`.
    pub id: &'static str,
    /// One-line summary shown by `repro lint --list-rules`.
    pub summary: &'static str,
    /// Path prefixes (relative to the lint root) the rule applies to;
    /// empty = every file.
    pub scope: &'static [&'static str],
    /// The checker: appends findings for `file` to the output vector.
    pub check: fn(&ScannedFile, &mut Vec<Finding>),
}

impl Rule {
    /// Whether this rule applies to `path` (relative, forward slashes).
    pub fn applies_to(&self, path: &str) -> bool {
        self.scope.is_empty() || self.scope.iter().any(|prefix| path.starts_with(prefix))
    }
}

/// Rule id for the pragma-hygiene rule, which is implemented by the
/// driver (it needs suppression results) rather than a checker here.
pub const PRAGMA_RULE: &str = "lint-pragma";

/// The full registry. `lint-pragma` has no checker function: its
/// findings (unknown rule, missing reason, stale pragma) are emitted by
/// [`super::lint_source`] after suppression is resolved.
pub const RULES: &[Rule] = &[
    Rule {
        id: "budget-convention",
        summary: "sampling budgets in solvers/ and engine/ must go through \
                  solvers::sketch_budget, not raw s_multiplier * s0(n) arithmetic",
        scope: &["solvers/", "engine/"],
        check: check_budget,
    },
    Rule {
        id: "unordered-iter",
        summary: "no HashMap/HashSet iteration feeding ids, batches, fingerprints, \
                  or rendered output — sort first or pragma with a reason",
        scope: &[],
        check: check_unordered,
    },
    Rule {
        id: "wall-clock",
        summary: "no Instant::now/SystemTime/available_parallelism in \
                  result-affecting modules (ot/, solvers/, sparse/, engine/)",
        scope: &["ot/", "solvers/", "sparse/", "engine/"],
        check: check_wall_clock,
    },
    Rule {
        id: "lock-unwrap",
        summary: "worker paths must use util::sync::lock_unpoisoned, not \
                  .lock().unwrap() — a panicking peer poisons the lock",
        scope: &["coordinator/", "pool/", "engine/", "runtime/"],
        check: check_lock_unwrap,
    },
    Rule {
        id: PRAGMA_RULE,
        summary: "every `// lint: allow` pragma names a known rule, carries a \
                  reason, and still suppresses something (stale pragmas are errors)",
        scope: &[],
        check: check_nothing,
    },
];

/// No-op checker for rules implemented by the driver.
fn check_nothing(_file: &ScannedFile, _out: &mut Vec<Finding>) {}

// ---------------------------------------------------------------------------
// R1: budget-convention
// ---------------------------------------------------------------------------

/// Adjacent `s_multiplier`/`*` forms that indicate a hand-rolled budget
/// (`sketch_budget(s_multiplier, ..)` passes the multiplier through and
/// stays legal).
const BUDGET_PRODUCTS: &[&str] =
    &["s_multiplier *", "* s_multiplier", "s_multiplier*", "*s_multiplier"];

fn check_budget(file: &ScannedFile, out: &mut Vec<Finding>) {
    for line in &file.lines {
        // The convention's single implementation is exempt from itself,
        // and so is test code: tests legitimately compute `mult * s0(n)`
        // to assert the convention or to drive the legacy raw-budget
        // entry points.
        if line.enclosing_fn.as_deref() == Some("sketch_budget") || line.in_test {
            continue;
        }
        let code = line.code.as_str();
        let hit = has_call_token(code, "s0")
            || BUDGET_PRODUCTS.iter().any(|p| code.contains(p))
            || (code.contains(".ceil()") && code.contains("budget"));
        if hit {
            out.push(Finding {
                path: file.path.clone(),
                line: line.number,
                rule: "budget-convention",
                message: "hand-rolled sampling budget; call solvers::sketch_budget \
                          (s = MULT * s0(max(n, m)))"
                    .to_string(),
            });
        }
    }
}

/// Whether `code` contains a call `name(` that is not the tail of a
/// longer identifier (e.g. `res0(` must not match `s0`).
fn has_call_token(code: &str, name: &str) -> bool {
    let pat = format!("{name}(");
    let mut from = 0;
    while let Some(pos) = code[from..].find(&pat) {
        let at = from + pos;
        let tail_of_ident = code[..at]
            .bytes()
            .last()
            .is_some_and(|b| b.is_ascii_alphanumeric() || b == b'_');
        if !tail_of_ident {
            return true;
        }
        from = at + 1;
    }
    false
}

// ---------------------------------------------------------------------------
// R2: unordered-iter
// ---------------------------------------------------------------------------

/// Method calls that iterate a collection in storage order.
const ITER_VERBS: &[&str] = &[
    ".iter()",
    ".iter_mut()",
    ".into_iter()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".into_keys()",
    ".into_values()",
    ".drain(",
];

/// Type names whose storage order is nondeterministic across runs
/// (`RandomState` hashing). The `<`/`::` suffixes anchor to type
/// position so an identifier merely containing the word does not match.
const UNORDERED_TYPES: &[&str] = &["HashMap<", "HashSet<", "HashMap::", "HashSet::"];

fn check_unordered(file: &ScannedFile, out: &mut Vec<Finding>) {
    // Pass 1: register every binding (let, field, or parameter) whose
    // declared type mentions HashMap/HashSet. File-scoped and
    // flow-insensitive by design — a same-named ordered binding in
    // another function is a false positive worth a pragma, not a parser.
    let mut names: BTreeSet<String> = BTreeSet::new();
    for line in &file.lines {
        register_unordered_names(&line.code, &mut names);
    }
    if names.is_empty() {
        return;
    }

    // Pass 2: flag iteration over a registered name, including
    // rustfmt-split method chains (a line starting with an iteration
    // verb whose previous code line ends with a registered name).
    let mut prev_code: Option<&str> = None;
    for line in &file.lines {
        let code = line.code.as_str();
        let trimmed = code.trim();
        let direct = names.iter().find(|n| line_iterates(n, code));
        let continuation = || {
            let prev = prev_code?;
            if !ITER_VERBS.iter().any(|v| trimmed.starts_with(v)) {
                return None;
            }
            names.iter().find(|n| ends_with_name(prev, n))
        };
        if let Some(name) = direct.or_else(continuation) {
            out.push(Finding {
                path: file.path.clone(),
                line: line.number,
                rule: "unordered-iter",
                message: format!(
                    "iteration over unordered collection `{name}`; collect + sort \
                     before anything order-sensitive, or pragma with a reason"
                ),
            });
        }
        if !trimmed.is_empty() {
            prev_code = Some(trimmed);
        }
    }
}

/// Register binding names declared with an unordered type on this line.
fn register_unordered_names(code: &str, names: &mut BTreeSet<String>) {
    for ty in UNORDERED_TYPES {
        let mut from = 0;
        while let Some(pos) = code[from..].find(ty) {
            let at = from + pos;
            let tail_of_ident = code[..at]
                .bytes()
                .last()
                .is_some_and(|b| b.is_ascii_alphanumeric() || b == b'_');
            if !tail_of_ident {
                if let Some(name) = binding_name_before(code, at) {
                    names.insert(name);
                }
            }
            from = at + ty.len();
        }
    }
}

/// The binding name for a type mention at byte `at`: the identifier
/// before the nearest single `:` whose gap to `at` is all type-ish
/// characters (`name: HashMap<..>`, `cache: Mutex<HashMap<..>>`), or
/// the `let [mut] name = ...` pattern when there is no annotation.
fn binding_name_before(code: &str, at: usize) -> Option<String> {
    let bytes = code.as_bytes();
    let mut i = at;
    while i > 0 {
        let b = bytes[i - 1];
        let single_colon = b == b':'
            && bytes.get(i.wrapping_sub(2)) != Some(&b':')
            && bytes.get(i) != Some(&b':');
        if single_colon {
            return ident_ending_at(code, i - 1);
        }
        let type_ish = b.is_ascii_alphanumeric()
            || matches!(b, b'_' | b' ' | b'<' | b'>' | b'&' | b',' | b'\'' | b':' | b'(' | b')');
        if !type_ish {
            break;
        }
        i -= 1;
    }
    // `let [mut] name = HashMap::new()` with no annotation.
    let trimmed = code.trim_start();
    let rest = trimmed.strip_prefix("let ")?;
    let rest = rest.strip_prefix("mut ").unwrap_or(rest);
    let eq = rest.find('=')?;
    let name = rest[..eq].trim();
    let is_ident = !name.is_empty()
        && name
            .bytes()
            .all(|b| b.is_ascii_alphanumeric() || b == b'_');
    is_ident.then(|| name.to_string())
}

/// The identifier whose last character sits just before byte `end`
/// (skipping trailing spaces).
fn ident_ending_at(code: &str, end: usize) -> Option<String> {
    let bytes = code.as_bytes();
    let mut stop = end;
    while stop > 0 && bytes[stop - 1] == b' ' {
        stop -= 1;
    }
    let mut start = stop;
    while start > 0 && (bytes[start - 1].is_ascii_alphanumeric() || bytes[start - 1] == b'_') {
        start -= 1;
    }
    (start < stop).then(|| code[start..stop].to_string())
}

/// Whether this line iterates the registered binding `name`: either a
/// `name.iter()`-style call (with an identifier boundary before `name`)
/// or a `for .. in [&[mut ]]name` loop header.
fn line_iterates(name: &str, code: &str) -> bool {
    for verb in ITER_VERBS {
        let pat = format!("{name}{verb}");
        let mut from = 0;
        while let Some(pos) = code[from..].find(&pat) {
            let at = from + pos;
            let tail_of_ident = code[..at]
                .bytes()
                .last()
                .is_some_and(|b| b.is_ascii_alphanumeric() || b == b'_');
            if !tail_of_ident {
                return true;
            }
            from = at + 1;
        }
    }
    // `for x in name {` / `for x in &name` / trailing `name` at EOL.
    if let Some(for_pos) = find_keyword(code, "for ") {
        if let Some(in_rel) = find_keyword(&code[for_pos..], " in ") {
            let rest = code[for_pos + in_rel + 4..].trim_start();
            let rest = rest.strip_prefix("&mut ").unwrap_or(rest);
            let rest = rest.strip_prefix('&').unwrap_or(rest);
            if let Some(after) = rest.strip_prefix(name) {
                let boundary = !after
                    .bytes()
                    .next()
                    .is_some_and(|b| b.is_ascii_alphanumeric() || b == b'_' || b == b'.');
                if boundary {
                    return true;
                }
            }
        }
    }
    false
}

/// Find `word` in `code` with a non-identifier character (or start of
/// line) before it.
fn find_keyword(code: &str, word: &str) -> Option<usize> {
    let mut from = 0;
    while let Some(pos) = code[from..].find(word) {
        let at = from + pos;
        let tail_of_ident = code[..at]
            .bytes()
            .last()
            .is_some_and(|b| b.is_ascii_alphanumeric() || b == b'_');
        if !tail_of_ident {
            return Some(at);
        }
        from = at + 1;
    }
    None
}

/// Whether `prev` (a trimmed code line) ends with the identifier `name`
/// at an identifier boundary — the head of a rustfmt-split chain.
fn ends_with_name(prev: &str, name: &str) -> bool {
    let Some(head) = prev.strip_suffix(name) else {
        return false;
    };
    !head
        .bytes()
        .last()
        .is_some_and(|b| b.is_ascii_alphanumeric() || b == b'_')
}

// ---------------------------------------------------------------------------
// R3: wall-clock
// ---------------------------------------------------------------------------

/// Tokens that read wall-clock time or machine shape. Inside
/// result-affecting modules these make outputs depend on when/where the
/// run happened; timing belongs in metrics/bench/experiments.
const WALL_CLOCK_TOKENS: &[&str] = &["Instant::now", "SystemTime", "available_parallelism"];

fn check_wall_clock(file: &ScannedFile, out: &mut Vec<Finding>) {
    for line in &file.lines {
        for token in WALL_CLOCK_TOKENS {
            if find_keyword(&line.code, token).is_some() {
                out.push(Finding {
                    path: file.path.clone(),
                    line: line.number,
                    rule: "wall-clock",
                    message: format!(
                        "`{token}` in a result-affecting module; pass ticks/threads \
                         in from the caller (metrics/bench own timing)"
                    ),
                });
                break;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// R4: lock-unwrap
// ---------------------------------------------------------------------------

fn check_lock_unwrap(file: &ScannedFile, out: &mut Vec<Finding>) {
    let mut prev_code: Option<&str> = None;
    for line in &file.lines {
        let trimmed = line.code.trim();
        let split_chain = trimmed.starts_with(".unwrap()")
            && prev_code.is_some_and(|prev| prev.ends_with(".lock()"));
        if line.code.contains(".lock().unwrap()") || split_chain {
            out.push(Finding {
                path: file.path.clone(),
                line: line.number,
                rule: "lock-unwrap",
                message: "bare .lock().unwrap() panics again on a poisoned lock; \
                          use util::sync::lock_unpoisoned"
                    .to_string(),
            });
        }
        if !trimmed.is_empty() {
            prev_code = Some(trimmed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::scanner::scan;

    fn run(rule_id: &str, path: &str, src: &str) -> Vec<Finding> {
        let rule = RULES
            .iter()
            .find(|r| r.id == rule_id)
            .expect("rule id exists");
        let file = scan(path, src);
        let mut out = Vec::new();
        (rule.check)(&file, &mut out);
        out
    }

    #[test]
    fn budget_flags_raw_products_and_s0_calls() {
        let src = "fn f(s_multiplier: f64, n: usize) -> usize {\n\
                   let s = (s_multiplier * s0(n)).ceil() as usize;\n\
                   s\n}\n";
        let hits = run("budget-convention", "solvers/x.rs", src);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].line, 2);
    }

    #[test]
    fn budget_allows_sketch_budget_passthrough_and_its_own_body() {
        let src = "fn sketch_budget(s_multiplier: f64, n: usize, m: usize) -> usize {\n\
                   (s_multiplier * s0(n.max(m))).ceil() as usize\n\
                   }\n\
                   fn f() { let s = sketch_budget(spec.s_multiplier, n, m); }\n";
        assert!(run("budget-convention", "solvers/x.rs", src).is_empty());
    }

    #[test]
    fn budget_ignores_longer_identifiers() {
        assert!(run("budget-convention", "solvers/x.rs", "let y = res0(n);\n").is_empty());
    }

    #[test]
    fn budget_exempts_test_modules() {
        let src = "#[cfg(test)]\n\
                   mod tests {\n\
                   fn expected(n: usize) -> f64 { 8.0 * s0(n) }\n\
                   }\n";
        assert!(run("budget-convention", "solvers/x.rs", src).is_empty());
    }

    #[test]
    fn unordered_flags_registered_bindings_and_split_chains() {
        let src = "struct S { entries: HashMap<u64, u32> }\n\
                   fn f(s: &S) {\n\
                   for k in s.entries.keys() { use_it(k); }\n\
                   let v = s\n\
                   .entries\n\
                   .iter()\n\
                   .count();\n\
                   }\n";
        let hits = run("unordered-iter", "engine/x.rs", src);
        let lines: Vec<usize> = hits.iter().map(|h| h.line).collect();
        assert_eq!(lines, vec![3, 6]);
    }

    #[test]
    fn unordered_registers_let_without_annotation_and_for_loops() {
        let src = "fn f() {\n\
                   let mut seen = HashSet::new();\n\
                   for x in &seen { use_it(x); }\n\
                   }\n";
        let hits = run("unordered-iter", "a.rs", src);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].line, 3);
    }

    #[test]
    fn unordered_ignores_sorted_vec_with_same_words_in_strings() {
        let src = "fn f() {\n\
                   let v: Vec<u32> = Vec::new();\n\
                   println!(\"HashMap<k,v>.iter()\");\n\
                   for x in &v { use_it(x); }\n\
                   }\n";
        assert!(run("unordered-iter", "a.rs", src).is_empty());
    }

    #[test]
    fn wall_clock_fires_only_on_real_tokens() {
        let src = "fn f() { let t = Instant::now(); }\n\
                   fn g() { let p = std::thread::available_parallelism(); }\n\
                   fn h() { instant_noodles(); }\n";
        let hits = run("wall-clock", "ot/x.rs", src);
        let lines: Vec<usize> = hits.iter().map(|h| h.line).collect();
        assert_eq!(lines, vec![1, 2]);
    }

    #[test]
    fn lock_unwrap_fires_inline_and_across_split_chains() {
        let src = "fn f(m: &Mutex<u32>) {\n\
                   let a = m.lock().unwrap();\n\
                   let b = m\n\
                   .lock()\n\
                   .unwrap();\n\
                   let c = lock_unpoisoned(m);\n\
                   }\n";
        let hits = run("lock-unwrap", "pool/x.rs", src);
        let lines: Vec<usize> = hits.iter().map(|h| h.line).collect();
        assert_eq!(lines, vec![2, 5]);
    }

    #[test]
    fn scopes_gate_rules_to_their_directories() {
        let budget = RULES.iter().find(|r| r.id == "budget-convention").expect("exists");
        assert!(budget.applies_to("solvers/spar_sink.rs"));
        assert!(!budget.applies_to("metrics.rs"));
        let unordered = RULES.iter().find(|r| r.id == "unordered-iter").expect("exists");
        assert!(unordered.applies_to("anything/at/all.rs"));
    }
}
