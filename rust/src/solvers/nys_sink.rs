//! Nys-Sink (Altschuler et al., 2019) — Sinkhorn over a rank-r Nyström
//! approximation `K ≈ C W⁺ Cᵀ`, giving O(nr) iterations, plus the
//! robust variant (Le et al., 2021) which clips the scaling updates to
//! damp outlier mass (our simplification of their row-constrained
//! robust OT; DESIGN.md §3 documents the substitution).
//!
//! The factorization requires K symmetric PSD and effectively low-rank —
//! exactly the assumptions the paper shows fail for sparse near-full-rank
//! WFR kernels (Section 1), which our experiments reproduce.
//!
//! Kernel entries are consumed through a closure, so a
//! [`CostSource::Shared`](crate::api::CostSource) problem feeds the
//! column sampling and the post-convergence objective pass from the
//! cached [`CostArtifacts`](crate::engine::CostArtifacts) kernel
//! instead of re-deriving `exp(−C/ε)` per probed entry.

use crate::error::{Error, Result};
use crate::linalg::{l1_diff, nystrom_factorize, NystromFactor};
use crate::ot::objective::kl_divergence;
use crate::ot::sinkhorn::{safe_div, SinkhornParams};
use crate::ot::uot::uot_rho;
use crate::ot::SinkhornSolution;
use crate::rng::Rng;

/// Nys-Sink configuration.
#[derive(Clone, Debug)]
pub struct NysSinkParams {
    /// Scaling-loop parameters (δ, iteration cap).
    pub sinkhorn: SinkhornParams,
    /// Core eigenvalue cutoff (relative ridge) for the pseudo-inverse.
    pub ridge: f64,
    /// Robust variant: clip scalings to `[1/clip, clip]` (None = off).
    pub robust_clip: Option<f64>,
}

impl Default for NysSinkParams {
    fn default() -> Self {
        NysSinkParams { sinkhorn: SinkhornParams::default(), ridge: 1e-10, robust_clip: None }
    }
}

/// Scaling loop over the low-rank factor; the low-rank matvec can go
/// slightly negative (indefinite pseudo-inverse), so clamp at zero —
/// matching the reference implementation's `max(Kv, 0)` guard.
fn lowrank_scalings(
    factor: &NystromFactor,
    a: &[f64],
    b: &[f64],
    rho: f64,
    params: &NysSinkParams,
) -> Result<(Vec<f64>, Vec<f64>, usize, f64, bool)> {
    let n = a.len();
    let m = b.len();
    let mut u = vec![1.0; n];
    let mut v = vec![1.0; m];
    let mut u_prev = u.clone();
    let mut v_prev = v.clone();
    let clip = params.robust_clip;
    let apply_clip = |x: f64| -> f64 {
        match clip {
            Some(c) => x.clamp(1.0 / c, c),
            None => x,
        }
    };
    let mut displacement = f64::INFINITY;
    let mut iters = 0;
    while iters < params.sinkhorn.max_iters {
        iters += 1;
        u_prev.copy_from_slice(&u);
        v_prev.copy_from_slice(&v);
        let kv = factor.matvec(&v);
        for i in 0..n {
            let val = safe_div(a[i], kv[i].max(0.0));
            u[i] = apply_clip(if rho == 1.0 { val } else { val.powf(rho) });
        }
        let ktu = factor.matvec_t(&u);
        for j in 0..m {
            let val = safe_div(b[j], ktu[j].max(0.0));
            v[j] = apply_clip(if rho == 1.0 { val } else { val.powf(rho) });
        }
        if u.iter().chain(v.iter()).any(|x| !x.is_finite()) {
            return Err(Error::Numerical(format!(
                "Nys-Sink scalings diverged at iteration {iters}"
            )));
        }
        displacement = l1_diff(&u, &u_prev) + l1_diff(&v, &v_prev);
        if displacement <= params.sinkhorn.delta {
            return Ok((u, v, iters, displacement, true));
        }
    }
    Ok((u, v, iters, displacement, false))
}

/// Objective over the low-rank plan. One parallel entry pass after
/// convergence (objective evaluation only; the iterations stay O(nr)),
/// matching how the reference evaluates `<T, C>` once at the end.
fn lowrank_ot_objective(
    factor: &NystromFactor,
    cost: impl Fn(usize, usize) -> f64 + Sync,
    u: &[f64],
    v: &[f64],
    eps: f64,
) -> f64 {
    let n = u.len();
    let m = v.len();
    // Amortize the core product: K_ij ~ left_i . C_j in O(r).
    let left = factor.left_factor();
    let (transport, entropy) = crate::pool::parallel_fold(
        n,
        |start, end| {
            let mut transport = 0.0;
            let mut entropy = 0.0;
            for i in start..end {
                if u[i] == 0.0 {
                    continue;
                }
                for j in 0..m {
                    let k = factor.entry_with(&left, i, j).max(0.0);
                    let t = u[i] * k * v[j];
                    if t > 0.0 {
                        transport += t * cost(i, j);
                        entropy -= t * (t.ln() - 1.0);
                    }
                }
            }
            (transport, entropy)
        },
        |x, y| (x.0 + y.0, x.1 + y.1),
        (0.0, 0.0),
    );
    transport - eps * entropy
}

/// Nys-Sink for OT: rank `r` ≈ s/n landmarks (the paper's comparison
/// protocol: `r = ceil(s/n)` so selected element counts match).
pub fn nys_sink_ot(
    kernel: impl Fn(usize, usize) -> f64 + Sync,
    cost: impl Fn(usize, usize) -> f64 + Sync,
    a: &[f64],
    b: &[f64],
    eps: f64,
    rank: usize,
    params: &NysSinkParams,
    rng: &mut Rng,
) -> Result<SinkhornSolution> {
    let n = a.len();
    if b.len() != n {
        return Err(Error::Dimension("Nys-Sink requires shared support (n = m)".into()));
    }
    let factor = nystrom_factorize(n, &kernel, rank.max(1), params.ridge, rng);
    let (u, v, iterations, displacement, converged) =
        lowrank_scalings(&factor, a, b, 1.0, params)?;
    let objective = lowrank_ot_objective(&factor, &cost, &u, &v, eps);
    if !objective.is_finite() {
        return Err(Error::Numerical("Nys-Sink objective is not finite".into()));
    }
    Ok(SinkhornSolution { u, v, objective, iterations, displacement, converged })
}

/// Nys-Sink for UOT (the regime the paper shows it struggles in).
#[allow(clippy::too_many_arguments)]
pub fn nys_sink_uot(
    kernel: impl Fn(usize, usize) -> f64 + Sync,
    cost: impl Fn(usize, usize) -> f64 + Sync,
    a: &[f64],
    b: &[f64],
    lambda: f64,
    eps: f64,
    rank: usize,
    params: &NysSinkParams,
    rng: &mut Rng,
) -> Result<SinkhornSolution> {
    let n = a.len();
    if b.len() != n {
        return Err(Error::Dimension("Nys-Sink requires shared support (n = m)".into()));
    }
    let factor = nystrom_factorize(n, &kernel, rank.max(1), params.ridge, rng);
    let rho = uot_rho(lambda, eps);
    let (u, v, iterations, displacement, converged) =
        lowrank_scalings(&factor, a, b, rho, params)?;
    // Objective: transport + entropy over approx plan, plus KL penalties.
    let base = lowrank_ot_objective(&factor, &cost, &u, &v, eps);
    // Marginals of the low-rank plan in O(nr): T 1 = u . (C (Winv (C^T v))).
    let row: Vec<f64> = factor
        .matvec(&v)
        .iter()
        .zip(u.iter())
        .map(|(kv, ui)| (ui * kv).max(0.0))
        .collect();
    let col: Vec<f64> = factor
        .matvec_t(&u)
        .iter()
        .zip(v.iter())
        .map(|(ku, vj)| (vj * ku).max(0.0))
        .collect();
    let objective =
        base + lambda * kl_divergence(&row, a) + lambda * kl_divergence(&col, b);
    if !objective.is_finite() {
        return Err(Error::Numerical("Nys-Sink UOT objective is not finite".into()));
    }
    Ok(SinkhornSolution { u, v, objective, iterations, displacement, converged })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::cost::{gibbs_kernel, sq_euclidean_cost, wfr_kernel_from_distance, euclidean, wfr_cost_from_distance};
    use crate::ot::sinkhorn::sinkhorn_ot;
    use crate::ot::uot::sinkhorn_uot;
    use crate::linalg::Mat;

    fn problem(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>, Vec<f64>) {
        let mut rng = Rng::seed_from(seed);
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..2).map(|_| rng.uniform()).collect())
            .collect();
        let a: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.2).collect();
        let sa: f64 = a.iter().sum();
        let b: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.2).collect();
        let sb: f64 = b.iter().sum();
        (pts, a.iter().map(|x| x / sa).collect(), b.iter().map(|x| x / sb).collect())
    }

    #[test]
    fn accurate_on_smooth_low_rank_kernel() {
        // Large eps -> smooth kernel -> genuinely low rank: Nys-Sink's
        // sweet spot, error should be small.
        let n = 128;
        let (pts, a, b) = problem(n, 31);
        let cost = sq_euclidean_cost(&pts, &pts);
        let eps = 0.5;
        let kernel = gibbs_kernel(&cost, eps);
        let exact = sinkhorn_ot(&kernel, &cost, &a, &b, eps, &SinkhornParams::default()).unwrap();
        let mut rng = Rng::seed_from(6);
        let sol = nys_sink_ot(
            |i, j| kernel.get(i, j),
            |i, j| cost.get(i, j),
            &a,
            &b,
            eps,
            24,
            &NysSinkParams::default(),
            &mut rng,
        )
        .unwrap();
        let rel = (sol.objective - exact.objective).abs() / exact.objective.abs();
        assert!(rel < 0.1, "relative error {rel}");
    }

    #[test]
    fn struggles_on_sparse_wfr_kernel() {
        // The paper's motivating failure mode: sparse near-full-rank WFR
        // kernel defeats low-rank approximation.
        let n = 128;
        let (pts, a, b) = problem(n, 37);
        let a: Vec<f64> = a.iter().map(|x| x * 5.0).collect();
        let b: Vec<f64> = b.iter().map(|x| x * 3.0).collect();
        let eta = crate::ot::cost::calibrate_eta(&pts, &pts, 0.3, 1e-3);
        let (lambda, eps) = (1.0, 0.1);
        let kfun = |i: usize, j: usize| {
            wfr_kernel_from_distance(euclidean(&pts[i], &pts[j]), eta, eps)
        };
        let cfun = |i: usize, j: usize| {
            wfr_cost_from_distance(euclidean(&pts[i], &pts[j]), eta)
        };
        let kernel = Mat::from_fn(n, n, kfun);
        let cost = Mat::from_fn(n, n, cfun);
        let exact =
            sinkhorn_uot(&kernel, &cost, &a, &b, lambda, eps, &SinkhornParams::default()).unwrap();
        let mut rng = Rng::seed_from(8);
        let nys = nys_sink_uot(
            kfun, cfun, &a, &b, lambda, eps, 12, &NysSinkParams::default(), &mut rng,
        );
        // Either it errs out (numerical) or its error is large compared
        // with Spar-Sink at matched budget (12 * n selected elements),
        // expressed as an oracle-cost problem through the unified API.
        let mut spar_rng = Rng::seed_from(9);
        let pts_o = std::sync::Arc::new(pts.clone());
        let problem = crate::api::OtProblem {
            cost: crate::api::CostSource::oracle(n, n, move |i, j| {
                wfr_cost_from_distance(euclidean(&pts_o[i], &pts_o[j]), eta)
            }),
            a: std::sync::Arc::new(a.clone()),
            b: std::sync::Arc::new(b.clone()),
            eps,
            formulation: crate::api::Formulation::Unbalanced { lambda },
        };
        let s_mult = (12 * n) as f64 / crate::metrics::s0(n);
        let spec = crate::api::SolverSpec::new(crate::api::Method::SparSink)
            .with_budget(s_mult);
        let spar = crate::solvers::spar_sink::spar_sink_solve(&problem, &spec, &mut spar_rng)
            .unwrap();
        let spar_rel = (spar.solution.objective - exact.objective).abs() / exact.objective.abs();
        match nys {
            Ok(sol) => {
                let nys_rel = (sol.objective - exact.objective).abs() / exact.objective.abs();
                assert!(
                    spar_rel < nys_rel,
                    "spar {spar_rel:.4} should beat nys {nys_rel:.4} on WFR"
                );
            }
            Err(_) => { /* failure on this regime is itself the expected outcome */ }
        }
    }

    #[test]
    fn robust_clip_keeps_scalings_bounded() {
        let n = 64;
        let (pts, a, mut b) = problem(n, 41);
        b[0] = 1e-9; // outlier-ish target mass
        let sb: f64 = b.iter().sum();
        let b: Vec<f64> = b.iter().map(|x| x / sb).collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        let eps = 0.2;
        let kernel = gibbs_kernel(&cost, eps);
        let mut rng = Rng::seed_from(10);
        let params = NysSinkParams {
            robust_clip: Some(100.0),
            ..NysSinkParams::default()
        };
        let sol = nys_sink_ot(
            |i, j| kernel.get(i, j),
            |i, j| cost.get(i, j),
            &a,
            &b,
            eps,
            16,
            &params,
            &mut rng,
        )
        .unwrap();
        for x in sol.u.iter().chain(sol.v.iter()) {
            assert!(*x <= 100.0 + 1e-9 && *x >= 1.0 / 100.0 - 1e-12);
        }
    }
}
