//! Sparse-matrix substrate for the importance sparsifier: CSR storage
//! (with a parallel kernel/cost dual-value layout so objectives evaluate
//! over sampled entries only, plus optional exact log-kernel values for
//! the log-domain backend), the Poisson element-sampling scheme (Eq. 7),
//! and the paper's importance probabilities (Eqs. 9 and 11) in both
//! linear- and log-kernel-oracle forms.

pub mod csr;
pub mod sampling;

pub use csr::CsrMatrix;
pub use sampling::{
    poisson_sparsify_ibp_logk, poisson_sparsify_ot, poisson_sparsify_ot_logk,
    poisson_sparsify_uot, poisson_sparsify_uot_logk, poisson_sparsify_uot_logk_amortized,
    poisson_sparsify_with, sample_with_replacement_ot, SparsifyStats,
};
