//! Table 1 — ED time-point prediction on echocardiogram videos:
//! predict end-diastole from end-systole by taking the frame with the
//! largest WFR distance to the ES frame within one cycle.  Panel (a)
//! runs at the native frame size; panel (b) repeats after 2×2
//! mean-pooling.  Methods: Nys-Sink, Robust-Nys-Sink, Rand-Sink,
//! Spar-Sink at s ∈ {1,2,4,8}·s₀(n), and exact Sinkhorn.

use std::sync::Arc;
use std::time::Instant;

use super::common::row;
use super::{ExperimentOutput, Profile};
use crate::api::{self, CostSource, EntryOracle, Formulation, Method, OtProblem, SolverSpec};
use crate::data::echo::{frame_to_measure, generate, mean_pool, EchoConfig, Health};
use crate::metrics::{ed_prediction_error, mean_sd};
use crate::ot::cost::{euclidean, log_gibbs_from_cost, wfr_cost_from_distance};
use crate::rng::Rng;
use crate::util::json::Json;
use crate::util::table::{f, pm, Table};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum T1Method {
    NysSink,
    RobustNysSink,
    RandSink,
    SparSink,
    Sinkhorn,
}

impl T1Method {
    fn name(&self) -> &'static str {
        match self {
            T1Method::NysSink => "nys-sink",
            T1Method::RobustNysSink => "robust-nyssink",
            T1Method::RandSink => "rand-sink",
            T1Method::SparSink => "spar-sink",
            T1Method::Sinkhorn => "sinkhorn",
        }
    }
}

struct FrameMeasure {
    pts: Arc<Vec<Vec<f64>>>,
    mass: Arc<Vec<f64>>,
}

/// Entropic UOT objective between two frames with the requested method
/// (debiasing to a distance happens in the caller). The frame pair is
/// expressed as an oracle-cost [`OtProblem`] and every arm dispatches
/// through `api::solve_with_rng`.
#[allow(clippy::too_many_arguments)]
fn wfr_between(
    method: T1Method,
    src: &FrameMeasure,
    dst: &FrameMeasure,
    eta: f64,
    lambda: f64,
    eps: f64,
    s_mult: f64,
    rng: &mut Rng,
) -> Option<f64> {
    if matches!(method, T1Method::NysSink | T1Method::RobustNysSink)
        && src.mass.len() != dst.mass.len()
    {
        return None; // Nyström needs shared support size
    }
    let (sp, tp) = (src.pts.clone(), dst.pts.clone());
    let cost: EntryOracle = Arc::new(move |i: usize, j: usize| {
        wfr_cost_from_distance(euclidean(&sp[i], &tp[j]), eta)
    });
    let cost_for_lk = cost.clone();
    let log_kernel: EntryOracle =
        Arc::new(move |i: usize, j: usize| log_gibbs_from_cost(cost_for_lk(i, j), eps));
    let problem = OtProblem {
        cost: CostSource::Oracle {
            rows: src.mass.len(),
            cols: dst.mass.len(),
            cost,
            log_kernel: Some(log_kernel),
        },
        a: src.mass.clone(),
        b: dst.mass.clone(),
        eps,
        formulation: Formulation::Unbalanced { lambda },
    };
    let spec = match method {
        T1Method::Sinkhorn => SolverSpec::new(Method::Sinkhorn),
        T1Method::SparSink => SolverSpec::new(Method::SparSink).with_budget(s_mult),
        T1Method::RandSink => SolverSpec::new(Method::RandSink).with_budget(s_mult),
        T1Method::NysSink => SolverSpec::new(Method::NysSink).with_budget(s_mult),
        T1Method::RobustNysSink => {
            SolverSpec::new(Method::NysSink).with_budget(s_mult).with_robust_clip(1e3)
        }
    };
    api::solve_with_rng(&problem, &spec, rng).ok().map(|s| s.objective)
}

/// Debiased squared distance between frames i (ES) and j: the
/// Sinkhorn-divergence correction `obj(i,j) - (obj(i,i)+obj(j,j))/2`
/// removes the entropic bias so the ED frame (most dissimilar) wins the
/// argmax. The `obj(i,i)` term is constant over candidates j and can be
/// dropped from the ranking.
fn debiased_score(obj_ij: f64, obj_jj: f64) -> f64 {
    obj_ij - 0.5 * obj_jj
}

/// Extract per-cycle (ES, ED) ground-truth pairs with ES < ED.
fn cycles(es: &[usize], ed: &[usize]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for &e in es {
        if let Some(&d) = ed.iter().find(|&&d| d > e) {
            out.push((e, d));
        }
    }
    out
}

/// Table 1: echocardiogram ED-prediction error and wall time per method.
pub fn run(profile: Profile) -> ExperimentOutput {
    let native = profile.pick(48, 112);
    let videos_n = profile.pick(4, 100);
    let s_mults = profile.pick(vec![1.0, 8.0], vec![1.0, 2.0, 4.0, 8.0]);
    let methods = [
        T1Method::NysSink,
        T1Method::RobustNysSink,
        T1Method::RandSink,
        T1Method::SparSink,
        T1Method::Sinkhorn,
    ];
    let (lambda, eps) = (1.0, 0.05);
    let mut rng = Rng::seed_from(0xAB1E);

    let mut text = String::from("Table 1 — ED time-point prediction error and CPU time\n");
    let mut rows = Vec::new();
    for (panel, pool) in [("a (native)", 1usize), ("b (2x2 mean-pooled)", 2)] {
        let size = native / pool;
        let eta = size as f64 / 7.5;
        // Pre-generate videos with ground truth.
        let mut vids = Vec::new();
        for v in 0..videos_n {
            let video = generate(
                &EchoConfig {
                    size: native,
                    frames: profile.pick(30, 60),
                    period: 12.0,
                    health: if v % 3 == 0 { Health::Normal } else if v % 3 == 1 { Health::HeartFailure } else { Health::Arrhythmia },
                    noise: 0.01,
                },
                &mut rng,
            );
            vids.push(video);
        }

        let mut table = Table::new(&["method", "s/s0", "error (mean±sd)", "time (s)"]);
        for method in methods {
            let mults: Vec<Option<f64>> = if method == T1Method::Sinkhorn {
                vec![None]
            } else {
                s_mults.iter().map(|&m| Some(m)).collect()
            };
            for mult in mults {
                let mut errors = Vec::new();
                let t0 = Instant::now();
                for video in &vids {
                    let frames: Vec<FrameMeasure> = video
                        .frames
                        .iter()
                        .map(|fr| {
                            let (img, sz) = if pool > 1 {
                                mean_pool(fr, native, pool)
                            } else {
                                (fr.clone(), native)
                            };
                            let (pts, mass) = frame_to_measure(&img, sz, 0.05);
                            FrameMeasure { pts: Arc::new(pts), mass: Arc::new(mass) }
                        })
                        .collect();
                    for &(t_es, t_ed) in &cycles(&video.es_frames, &video.ed_frames) {
                        // Candidate frames within the cycle after ES.
                        let cycle_end = (t_es + (t_ed - t_es) * 2).min(frames.len() - 1);
                        let mut best = (t_es, f64::NEG_INFINITY);
                        for cand in (t_es + 1)..=cycle_end {
                            let obj_ij = wfr_between(
                                method,
                                &frames[t_es],
                                &frames[cand],
                                eta,
                                lambda,
                                eps,
                                mult.unwrap_or(8.0),
                                &mut rng,
                            );
                            let obj_jj = wfr_between(
                                method,
                                &frames[cand],
                                &frames[cand],
                                eta,
                                lambda,
                                eps,
                                mult.unwrap_or(8.0),
                                &mut rng,
                            );
                            if let (Some(oij), Some(ojj)) = (obj_ij, obj_jj) {
                                let d = debiased_score(oij, ojj);
                                if d > best.1 {
                                    best = (cand, d);
                                }
                            }
                        }
                        errors.push(ed_prediction_error(
                            t_es as f64,
                            t_ed as f64,
                            best.0 as f64,
                        ));
                    }
                }
                let secs = t0.elapsed().as_secs_f64();
                let (mean, sd) = if errors.is_empty() { (f64::NAN, 0.0) } else { mean_sd(&errors) };
                let s_label = mult.map(|m| f(m, 0)).unwrap_or_else(|| "n^2".into());
                table.row(vec![method.name().into(), s_label.clone(), pm(mean, sd, 2), f(secs, 2)]);
                rows.push(row(vec![
                    ("panel", Json::str(panel)),
                    ("method", Json::str(method.name())),
                    ("s_mult", mult.map(Json::num).unwrap_or(Json::Null)),
                    ("error_mean", Json::num(mean)),
                    ("error_sd", Json::num(sd)),
                    ("seconds", Json::num(secs)),
                ]));
            }
        }
        text.push_str(&format!(
            "\nPanel {panel}: frame {size}x{size}, {videos_n} videos, eta = {eta:.1}, eps = {eps}, lambda = {lambda}\n{}",
            table.render()
        ));
    }
    ExperimentOutput { id: "table1", text, rows: Json::arr(rows) }
}
