//! PJRT runtime — loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust request path.
//!
//! Interchange is HLO *text* (`artifacts/*.hlo.txt`): serialized
//! `HloModuleProto`s from jax ≥ 0.5 carry 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! Each artifact is compiled once per process and cached in the
//! [`ArtifactRegistry`]; [`DenseSinkhornRuntime`] then drives the outer
//! convergence loop over the fused `sinkhorn_block` (10 scaling
//! iterations per call, see `model.BLOCK_ITERS`) and evaluates
//! objectives on-device. Requests whose size is not on the compiled
//! menu are zero-padded up to the next menu size (padded support points
//! carry ~0 mass and a diagonal kernel entry so the scaling updates stay
//! finite; validated in `tests/runtime_integration.rs`).

mod registry;
mod sinkhorn;

pub use registry::{manifest_path, ArtifactRegistry, Entry};
pub use sinkhorn::{DenseSinkhornRuntime, RuntimeSolution};

/// Default artifact directory: `$SPAR_SINK_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::env::var("SPAR_SINK_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
