//! `repro bench gateway`: serving throughput/latency of the balancer +
//! gateway stack under the replay load generator, emitting
//! `BENCH_gateway.json`.
//!
//! Three scenario families on one machine, all loopback:
//!
//! * `direct` — loadgen straight at one gateway (the single-process
//!   baseline the balancer rows are read against).
//! * `balanced-N` — the same load through a [`Balancer`] fronting N
//!   gateway backends; the fingerprint-affine router keeps each ε
//!   class's artifact cache warm on one backend.
//! * `saturated` — a deliberately starved gateway (one worker, queue
//!   cap 1, batch size 1) driven directly, so the report's 429 rate is
//!   exercised, not just zero. (Through the balancer a 429 is retried
//!   internally and clients see 200 or a budget-exhausted 503 — that
//!   policy is pinned by `tests/balancer_integration.rs`, not here.)
//!
//! Rows carry the [`LoadReport`](crate::net::loadgen::LoadReport)
//! counters; numbers are hardware-dependent, but `failed_other` and
//! `io_errors` should be 0 in every scenario on a healthy stack.

use std::time::Duration;

use crate::coordinator::CoordinatorConfig;
use crate::net::balancer::{Balancer, BalancerConfig};
use crate::net::gateway::spawn_backends;
use crate::net::loadgen::{self, LoadgenConfig};
use crate::util::json::Json;

/// Workload + topology parameters for one gateway bench run.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Worker threads per backend service.
    pub workers: usize,
    /// Workload pixel-grid side (`size²` support points per measure).
    pub size: usize,
    /// Workload frames per video (downsampled 3:1 before pairing).
    pub frames: usize,
    /// Workload ε sweep (affinity classes for the balancer to place).
    pub eps_values: Vec<f64>,
    /// Requests per scenario.
    pub jobs: usize,
    /// Concurrent loadgen clients.
    pub clients: usize,
    /// Backend counts for the `balanced-N` scenarios.
    pub backend_counts: Vec<usize>,
}

impl BenchConfig {
    /// A minutes-scale configuration for the committed artifact.
    pub fn quick(workers: usize) -> Self {
        BenchConfig {
            workers,
            size: 12,
            frames: 12,
            eps_values: vec![0.05, 0.1],
            jobs: 48,
            clients: 4,
            backend_counts: vec![1, 2],
        }
    }
}

/// One scenario: stand the topology up, replay the workload, tear it
/// down, return the row.
fn scenario(
    name: &str,
    cfg: &BenchConfig,
    backend_config: &CoordinatorConfig,
    backends: usize,
    balanced: bool,
) -> Json {
    let mut gateways = spawn_backends(backends, backend_config).expect("bench backends start");
    let mut balancer = None;
    let target = if balanced {
        let b = Balancer::start(BalancerConfig {
            backends: gateways.iter().map(|g| g.local_addr().to_string()).collect(),
            ..BalancerConfig::default()
        })
        .expect("bench balancer starts");
        let addr = b.local_addr().to_string();
        balancer = Some(b);
        addr
    } else {
        gateways[0].local_addr().to_string()
    };
    let report = loadgen::run(&LoadgenConfig {
        addr: target,
        clients: cfg.clients,
        jobs: cfg.jobs,
        size: cfg.size,
        frames: cfg.frames,
        eps_values: cfg.eps_values.clone(),
        ..LoadgenConfig::default()
    })
    .expect("bench loadgen runs");
    println!("gateway bench: {name}: {}", report.render());
    if let Some(mut b) = balancer.take() {
        b.drain();
    }
    for gateway in &mut gateways {
        gateway.drain();
    }
    let Json::Obj(mut row) = report.json() else {
        unreachable!("LoadReport::json renders an object")
    };
    row.insert("scenario".to_string(), Json::str(name));
    row.insert("backends".to_string(), Json::num(backends as f64));
    row.insert("clients".to_string(), Json::num(cfg.clients as f64));
    Json::Obj(row)
}

/// Run the bench and return the `BENCH_gateway.json` document. Also
/// prints one line per scenario.
pub fn run(cfg: &BenchConfig) -> Json {
    let backend_config =
        CoordinatorConfig { workers: cfg.workers, shards: 1, ..CoordinatorConfig::default() };
    let mut rows = Vec::new();
    rows.push(scenario("direct", cfg, &backend_config, 1, false));
    for &n in &cfg.backend_counts {
        rows.push(scenario(&format!("balanced-{n}"), cfg, &backend_config, n.max(1), true));
    }
    // The starved topology, driven directly: admission control must
    // answer 429 under this load, and loadgen must count every one.
    let starved = CoordinatorConfig {
        workers: 1,
        shards: 1,
        queue_cap: 1,
        max_batch: 1,
        batch_window: Duration::from_millis(1),
        ..CoordinatorConfig::default()
    };
    rows.push(scenario("saturated", cfg, &starved, 1, false));
    Json::obj(vec![
        ("bench", Json::str("gateway")),
        (
            "workload",
            Json::obj(vec![
                ("grid", Json::num(cfg.size as f64)),
                (
                    "eps_values",
                    Json::arr(cfg.eps_values.iter().map(|&e| Json::num(e)).collect()),
                ),
                ("jobs_per_scenario", Json::num(cfg.jobs as f64)),
                ("clients", Json::num(cfg.clients as f64)),
                ("workers_per_backend", Json::num(cfg.workers as f64)),
            ]),
        ),
        ("rows", Json::arr(rows)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_bench_run_produces_schema_shaped_rows() {
        let cfg = BenchConfig {
            workers: 2,
            size: 6,
            frames: 6,
            eps_values: vec![0.1],
            jobs: 4,
            clients: 2,
            backend_counts: vec![2],
        };
        let doc = run(&cfg);
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("gateway"));
        let rows = doc.get("rows").expect("rows").items();
        // direct + balanced-2 + saturated.
        assert_eq!(rows.len(), 3);
        for row in rows {
            for key in
                ["scenario", "backends", "sent", "ok", "rejected_429", "rate_429", "p99_us"]
            {
                assert!(row.get(key).is_some(), "row missing '{key}'");
            }
            // Every request is answered with HTTP in every scenario —
            // saturation shows up as 429s, never as socket errors.
            assert_eq!(row.get("io_errors").and_then(Json::as_f64), Some(0.0));
        }
        // The healthy scenarios complete everything.
        assert_eq!(rows[0].get("ok").and_then(Json::as_f64), Some(4.0));
        assert_eq!(rows[1].get("ok").and_then(Json::as_f64), Some(4.0));
    }
}
