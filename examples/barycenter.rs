//! Wasserstein barycenters with IBP vs Spar-IBP (Appendix A / C.3):
//! three 1-D measures (Gaussian, mixture, t5) and a digit-glyph demo.
//!
//! ```sh
//! cargo run --release --example barycenter
//! ```

use spar_sink::data::digits::random_digit;
use spar_sink::data::synthetic::barycenter_measures;
use spar_sink::experiments::common::normalize_cost;
use spar_sink::experiments::fig12::ascii_render;
use spar_sink::metrics::{l1_distance, s0};
use spar_sink::ot::barycenter::ibp_barycenter;
use spar_sink::ot::cost::{gibbs_kernel, sq_euclidean_cost};
use spar_sink::ot::sinkhorn::SinkhornParams;
use spar_sink::rng::Rng;
use spar_sink::solvers::spar_ibp::spar_ibp;

fn normalized(q: &[f64]) -> Vec<f64> {
    let s: f64 = q.iter().sum();
    q.iter().map(|x| x / s).collect()
}

fn main() {
    let mut rng = Rng::seed_from(21);
    let params = SinkhornParams { delta: 1e-7, max_iters: 1000, strict: false };

    // --- 1-D synthetic measures ---
    let n = 400;
    let pts: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 / (n - 1) as f64]).collect();
    let cost = normalize_cost(&sq_euclidean_cost(&pts, &pts));
    let kernel = gibbs_kernel(&cost, 5e-3);
    let bs = barycenter_measures(n, &mut rng);
    let kernels = vec![kernel.clone(), kernel.clone(), kernel.clone()];
    let w = vec![1.0 / 3.0; 3];

    let t0 = std::time::Instant::now();
    let exact = ibp_barycenter(&kernels, &bs, &w, &params).expect("ibp");
    let ibp_time = t0.elapsed();
    let t0 = std::time::Instant::now();
    let approx =
        spar_ibp(&kernels, &bs, &w, 20.0 * s0(n), &params, &mut rng).expect("spar-ibp");
    let spar_time = t0.elapsed();
    let gap = l1_distance(&normalized(&exact.q), &normalized(&approx.solution.q));
    println!("1-D barycenter (n = {n}): IBP {ibp_time:?} vs Spar-IBP {spar_time:?}");
    println!("normalized L1 gap = {gap:.4}  (IBP iters {}, Spar-IBP iters {})", exact.iterations, approx.solution.iterations);

    // --- digit glyphs (Fig. 12 style) ---
    let grid = 24;
    let n = grid * grid;
    let pts: Vec<Vec<f64>> = (0..n)
        .map(|k| vec![(k % grid) as f64 / grid as f64, (k / grid) as f64 / grid as f64])
        .collect();
    let cost = normalize_cost(&sq_euclidean_cost(&pts, &pts));
    let kernel = gibbs_kernel(&cost, 2e-3);
    let digit = 3u8;
    let bs: Vec<Vec<f64>> = (0..8).map(|_| random_digit(digit, grid, &mut rng)).collect();
    let kernels: Vec<_> = (0..8).map(|_| kernel.clone()).collect();
    let w = vec![1.0 / 8.0; 8];
    let exact = ibp_barycenter(&kernels, &bs, &w, &params).expect("ibp digits");
    let approx =
        spar_ibp(&kernels, &bs, &w, 20.0 * s0(n), &params, &mut rng).expect("spar-ibp digits");
    println!("\ndigit {digit} barycenter, IBP:");
    println!("{}", ascii_render(&normalized(&exact.q), grid));
    println!("digit {digit} barycenter, Spar-IBP:");
    println!("{}", ascii_render(&normalized(&approx.solution.q), grid));
}
