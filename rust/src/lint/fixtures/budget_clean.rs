//! Clean twin of `budget_bad.rs`: the budget goes through the single
//! convention entry point, passing the multiplier through untouched.

/// Computes a sketch budget the sanctioned way.
pub fn good_budget(s_multiplier: f64, n: usize, m: usize) -> usize {
    crate::solvers::sketch_budget(s_multiplier, n, m)
}
