//! JSON ⇄ job/result codecs for the gateway.
//!
//! Decoding is strict where it matters for determinism: every float
//! passes through [`crate::util::json`]'s shortest-round-trip parser,
//! so a job encoded by [`distance_job_json`], posted over the wire, and
//! decoded here carries bit-identical `f64`s — the foundation of the
//! gateway's bitwise loopback-parity wall
//! (`tests/gateway_integration.rs`). Structural errors return a plain
//! `String` the router turns into a `400` with a JSON error body;
//! nothing here panics on untrusted input (notably, support/mass
//! lengths are checked *before* [`Measure::new`], which asserts).

use std::sync::Arc;

use crate::api::parse_backend;
use crate::coordinator::{BarycenterJob, BarycenterResult, DistanceJob, DistanceResult};
use crate::coordinator::{Measure, Method, ProblemSpec};
use crate::solvers::backend::{BackendKind, ScalingBackend};
use crate::util::json::Json;

/// Decode outcome: `Err` is a client-facing message for the 400 body.
pub type DecodeResult<T> = std::result::Result<T, String>;

fn field<'a>(obj: &'a Json, key: &str) -> DecodeResult<&'a Json> {
    obj.get(key).ok_or_else(|| format!("missing field '{key}'"))
}

/// A number that is actually usable as a job parameter: the JSON
/// parser's `str::parse::<f64>` happily yields `inf` for an oversized
/// literal like `1e999`, and a NaN/∞ smuggled into a mass or parameter
/// would poison the solve (or trip `Measure::new`'s assert) far from
/// the request — reject it here, naming the field.
fn finite(x: f64, name: impl FnOnce() -> String) -> DecodeResult<f64> {
    if x.is_finite() {
        Ok(x)
    } else {
        Err(format!("{} must be a finite number", name()))
    }
}

fn f64_field(obj: &Json, key: &str) -> DecodeResult<f64> {
    let x = field(obj, key)?.as_f64().ok_or_else(|| format!("field '{key}' must be a number"))?;
    finite(x, || format!("field '{key}'"))
}

/// Optional numeric field: absent is `None`, present-but-not-a-number
/// (or non-finite) is an error (silently ignoring a typo'd parameter
/// would change the solve).
fn opt_f64(obj: &Json, key: &str) -> DecodeResult<Option<f64>> {
    match obj.get(key) {
        None => Ok(None),
        Some(v) => {
            let x = v.as_f64().ok_or_else(|| format!("field '{key}' must be a number"))?;
            Ok(Some(finite(x, || format!("field '{key}'"))?))
        }
    }
}

fn vec_f64(v: &Json, what: &str) -> DecodeResult<Vec<f64>> {
    match v {
        Json::Arr(items) => items
            .iter()
            .map(|x| {
                let x =
                    x.as_f64().ok_or_else(|| format!("{what} must contain only numbers"))?;
                finite(x, || what.to_string())
            })
            .collect(),
        _ => Err(format!("{what} must be an array of numbers")),
    }
}

fn points(v: &Json, what: &str) -> DecodeResult<Vec<Vec<f64>>> {
    match v {
        Json::Arr(rows) => {
            rows.iter().map(|row| vec_f64(row, &format!("each point in {what}"))).collect()
        }
        _ => Err(format!("{what} must be an array of points")),
    }
}

fn measure(obj: &Json, key: &str) -> DecodeResult<Measure> {
    let m = field(obj, key)?;
    let pts = points(field(m, "points")?, &format!("'{key}.points'"))?;
    let mass = vec_f64(field(m, "mass")?, &format!("'{key}.mass'"))?;
    if pts.is_empty() {
        return Err(format!("measure '{key}' must have at least one support point"));
    }
    if pts.len() != mass.len() {
        return Err(format!(
            "measure '{key}': {} points but {} masses",
            pts.len(),
            mass.len()
        ));
    }
    Ok(Measure::new(pts, mass))
}

fn method_field(obj: &Json, default: Method) -> DecodeResult<Method> {
    match obj.get("method") {
        None => Ok(default),
        Some(v) => {
            let name = v.as_str().ok_or("field 'method' must be a string")?;
            Method::parse(name).ok_or_else(|| format!("unknown method '{name}'"))
        }
    }
}

/// Decode an optional `spec` object over [`ProblemSpec::default`]: each
/// field overrides the Section 6 default it names; unknown backends are
/// refused by name.
pub fn decode_spec(v: Option<&Json>) -> DecodeResult<ProblemSpec> {
    let mut spec = ProblemSpec::default();
    let Some(v) = v else { return Ok(spec) };
    if !matches!(v, Json::Obj(_)) {
        return Err("field 'spec' must be an object".into());
    }
    if let Some(x) = opt_f64(v, "lambda")? {
        spec.lambda = x;
    }
    if let Some(x) = opt_f64(v, "eps")? {
        spec.eps = x;
    }
    if let Some(x) = opt_f64(v, "eta")? {
        spec.eta = x;
    }
    if let Some(x) = opt_f64(v, "s_multiplier")? {
        spec.s_multiplier = x;
    }
    if let Some(x) = opt_f64(v, "delta")? {
        spec.delta = x;
    }
    if let Some(x) = opt_f64(v, "max_iters")? {
        spec.max_iters = x as usize;
    }
    if let Some(name) = v.get("backend") {
        let name = name.as_str().ok_or("field 'spec.backend' must be a string")?;
        spec.backend = Some(parse_backend(name).ok_or_else(|| {
            format!("unknown backend '{name}' (use auto|multiplicative|log-domain)")
        })?);
    }
    Ok(spec)
}

/// Decode a `POST /solve` payload into a [`DistanceJob`].
pub fn decode_distance_job(v: &Json) -> DecodeResult<DistanceJob> {
    if !matches!(v, Json::Obj(_)) {
        return Err("payload must be a JSON object".into());
    }
    Ok(DistanceJob {
        id: opt_f64(v, "id")?.unwrap_or(0.0) as u64,
        source: measure(v, "source")?,
        target: measure(v, "target")?,
        method: method_field(v, Method::SparSink)?,
        spec: decode_spec(v.get("spec"))?,
        seed: opt_f64(v, "seed")?.unwrap_or(0.0) as u64,
    })
}

/// Decode a `POST /barycenter` payload into a [`BarycenterJob`].
pub fn decode_barycenter_job(v: &Json) -> DecodeResult<BarycenterJob> {
    if !matches!(v, Json::Obj(_)) {
        return Err("payload must be a JSON object".into());
    }
    let support = points(field(v, "support")?, "'support'")?;
    if support.is_empty() {
        return Err("'support' must have at least one point".into());
    }
    let marginals: Vec<Vec<f64>> = match field(v, "marginals")? {
        Json::Arr(rows) => rows
            .iter()
            .map(|row| vec_f64(row, "each histogram in 'marginals'"))
            .collect::<DecodeResult<_>>()?,
        _ => return Err("'marginals' must be an array of histograms".into()),
    };
    if marginals.is_empty() {
        return Err("'marginals' must have at least one histogram".into());
    }
    for (i, m) in marginals.iter().enumerate() {
        if m.len() != support.len() {
            return Err(format!(
                "marginal {i} has {} entries but the support has {} points",
                m.len(),
                support.len()
            ));
        }
    }
    let weights = match v.get("weights") {
        None => vec![1.0 / marginals.len() as f64; marginals.len()],
        Some(w) => {
            let w = vec_f64(w, "'weights'")?;
            if w.len() != marginals.len() {
                return Err(format!(
                    "{} weights for {} marginals",
                    w.len(),
                    marginals.len()
                ));
            }
            w
        }
    };
    Ok(BarycenterJob {
        id: opt_f64(v, "id")?.unwrap_or(0.0) as u64,
        support: Arc::new(support),
        marginals,
        weights,
        method: method_field(v, Method::SparIbp)?,
        spec: decode_spec(v.get("spec"))?,
        seed: opt_f64(v, "seed")?.unwrap_or(0.0) as u64,
    })
}

/// Wire name of an executed backend.
pub fn backend_name(kind: BackendKind) -> &'static str {
    match kind {
        BackendKind::Multiplicative => "multiplicative",
        BackendKind::LogDomain => "log-domain",
    }
}

/// Wire name of a requested backend policy.
pub fn scaling_backend_name(backend: &ScalingBackend) -> &'static str {
    match backend {
        ScalingBackend::Multiplicative => "multiplicative",
        ScalingBackend::LogDomain => "log-domain",
        ScalingBackend::Auto { .. } => "auto",
    }
}

/// Encode a measure as `{"points": [[..]], "mass": [..]}`.
pub fn measure_json(m: &Measure) -> Json {
    Json::obj(vec![
        (
            "points",
            Json::arr(
                m.points
                    .iter()
                    .map(|p| Json::arr(p.iter().map(|x| Json::num(*x)).collect()))
                    .collect(),
            ),
        ),
        ("mass", Json::arr(m.mass.iter().map(|x| Json::num(*x)).collect())),
    ])
}

/// Encode a [`ProblemSpec`] (the `backend` key appears only when set).
pub fn spec_json(spec: &ProblemSpec) -> Json {
    let mut pairs = vec![
        ("lambda", Json::num(spec.lambda)),
        ("eps", Json::num(spec.eps)),
        ("eta", Json::num(spec.eta)),
        ("s_multiplier", Json::num(spec.s_multiplier)),
        ("delta", Json::num(spec.delta)),
        ("max_iters", Json::num(spec.max_iters as f64)),
    ];
    if let Some(backend) = &spec.backend {
        pairs.push(("backend", Json::str(scaling_backend_name(backend))));
    }
    Json::obj(pairs)
}

/// Encode a [`DistanceJob`] as a `POST /solve` payload.
pub fn distance_job_json(job: &DistanceJob) -> Json {
    Json::obj(vec![
        ("id", Json::num(job.id as f64)),
        ("source", measure_json(&job.source)),
        ("target", measure_json(&job.target)),
        ("method", Json::str(job.method.name())),
        ("spec", spec_json(&job.spec)),
        ("seed", Json::num(job.seed as f64)),
    ])
}

/// Encode a [`BarycenterJob`] as a `POST /barycenter` payload.
pub fn barycenter_job_json(job: &BarycenterJob) -> Json {
    Json::obj(vec![
        ("id", Json::num(job.id as f64)),
        (
            "support",
            Json::arr(
                job.support
                    .iter()
                    .map(|p| Json::arr(p.iter().map(|x| Json::num(*x)).collect()))
                    .collect(),
            ),
        ),
        (
            "marginals",
            Json::arr(
                job.marginals
                    .iter()
                    .map(|m| Json::arr(m.iter().map(|x| Json::num(*x)).collect()))
                    .collect(),
            ),
        ),
        ("weights", Json::arr(job.weights.iter().map(|x| Json::num(*x)).collect())),
        ("method", Json::str(job.method.name())),
        ("spec", spec_json(&job.spec)),
        ("seed", Json::num(job.seed as f64)),
    ])
}

/// Encode a [`DistanceResult`] for the response body.
pub fn distance_result_json(result: &DistanceResult) -> Json {
    let mut pairs = vec![
        ("id", Json::num(result.id as f64)),
        ("distance", Json::num(result.distance)),
        ("objective", Json::num(result.objective)),
        ("iterations", Json::num(result.iterations as f64)),
        (
            "backend",
            match result.backend {
                Some(kind) => Json::str(backend_name(kind)),
                None => Json::Null,
            },
        ),
        ("latency_seconds", Json::num(result.latency.as_secs_f64())),
        ("batch_id", Json::num(result.batch_id as f64)),
    ];
    if let Some(error) = &result.error {
        pairs.push(("error", Json::str(error.as_str())));
    }
    Json::obj(pairs)
}

/// Encode a [`BarycenterResult`] for the response body.
pub fn barycenter_result_json(result: &BarycenterResult) -> Json {
    let mut pairs = vec![
        ("id", Json::num(result.id as f64)),
        ("q", Json::arr(result.q.iter().map(|x| Json::num(*x)).collect())),
        ("iterations", Json::num(result.iterations as f64)),
        ("converged", Json::Bool(result.converged)),
        (
            "backend",
            match result.backend {
                Some(kind) => Json::str(backend_name(kind)),
                None => Json::Null,
            },
        ),
        ("latency_seconds", Json::num(result.latency.as_secs_f64())),
        ("batch_id", Json::num(result.batch_id as f64)),
    ];
    if let Some(error) = &result.error {
        pairs.push(("error", Json::str(error.as_str())));
    }
    Json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_measure(offset: f64) -> Measure {
        Measure::new(
            vec![vec![offset, 0.25 + offset], vec![offset + 1.0, offset + 0.125]],
            vec![0.5, 0.5],
        )
    }

    #[test]
    fn distance_job_round_trips_bitwise() {
        let job = DistanceJob {
            id: 42,
            source: toy_measure(0.1),
            target: toy_measure(0.7),
            method: Method::SparSink,
            spec: ProblemSpec {
                eps: 0.037,
                backend: Some(ScalingBackend::LogDomain),
                ..ProblemSpec::default()
            },
            seed: 9,
        };
        let wire = distance_job_json(&job).to_string_compact();
        let back = decode_distance_job(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.id, 42);
        assert_eq!(back.seed, 9);
        assert_eq!(back.method, Method::SparSink);
        assert_eq!(back.spec.eps.to_bits(), job.spec.eps.to_bits());
        assert_eq!(back.spec.delta.to_bits(), job.spec.delta.to_bits());
        assert!(matches!(back.spec.backend, Some(ScalingBackend::LogDomain)));
        for (a, b) in back.source.points.iter().zip(job.source.points.iter()) {
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        for (x, y) in back.target.mass.iter().zip(job.target.mass.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn barycenter_job_round_trips_and_defaults_uniform_weights() {
        let job = BarycenterJob {
            id: 7,
            support: Arc::new(vec![vec![0.0], vec![0.5], vec![1.0]]),
            marginals: vec![vec![0.6, 0.2, 0.2], vec![0.1, 0.3, 0.6]],
            weights: vec![0.5, 0.5],
            method: Method::SparIbp,
            spec: ProblemSpec::default(),
            seed: 3,
        };
        let wire = barycenter_job_json(&job).to_string_compact();
        let back = decode_barycenter_job(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.marginals, job.marginals);
        assert_eq!(back.weights, job.weights);
        assert_eq!(back.method, Method::SparIbp);

        // Weights omitted → uniform over the marginals.
        let minimal = Json::parse(
            r#"{"support": [[0.0], [1.0]], "marginals": [[0.5, 0.5], [0.25, 0.75]]}"#,
        )
        .unwrap();
        let decoded = decode_barycenter_job(&minimal).unwrap();
        assert_eq!(decoded.weights, vec![0.5, 0.5]);
        assert_eq!(decoded.method, Method::SparIbp);
    }

    #[test]
    fn structural_errors_name_the_offending_field() {
        let cases: Vec<(&str, &str)> = vec![
            (r#"{"target": {"points": [[0]], "mass": [1]}}"#, "missing field 'source'"),
            (
                r#"{"source": {"points": [[0], [1]], "mass": [1]},
                    "target": {"points": [[0]], "mass": [1]}}"#,
                "2 points but 1 masses",
            ),
            (
                r#"{"source": {"points": [], "mass": []},
                    "target": {"points": [[0]], "mass": [1]}}"#,
                "at least one support point",
            ),
            (
                r#"{"source": {"points": [[0]], "mass": [1]},
                    "target": {"points": [[0]], "mass": [1]},
                    "method": "teleport"}"#,
                "unknown method 'teleport'",
            ),
            (
                r#"{"source": {"points": [[0]], "mass": [1]},
                    "target": {"points": [[0]], "mass": [1]},
                    "spec": {"backend": "gpu"}}"#,
                "unknown backend 'gpu'",
            ),
            (
                r#"{"source": {"points": [[0]], "mass": [1]},
                    "target": {"points": [[0]], "mass": [1]},
                    "spec": {"eps": "small"}}"#,
                "field 'eps' must be a number",
            ),
            // Non-finite floats: the JSON number parser turns the
            // oversized literal 1e999 into f64::INFINITY, which used to
            // sail through into `Measure::new` / the solver. The decode
            // layer now refuses it, naming the field.
            (
                r#"{"source": {"points": [[0]], "mass": [1e999]},
                    "target": {"points": [[0]], "mass": [1]}}"#,
                "'source.mass' must be a finite number",
            ),
            (
                r#"{"source": {"points": [[1e999]], "mass": [1]},
                    "target": {"points": [[0]], "mass": [1]}}"#,
                "each point in 'source.points' must be a finite number",
            ),
            (
                r#"{"source": {"points": [[0]], "mass": [1]},
                    "target": {"points": [[0]], "mass": [1]},
                    "spec": {"eps": 1e999}}"#,
                "field 'eps' must be a finite number",
            ),
            (
                r#"{"source": {"points": [[0]], "mass": [1]},
                    "target": {"points": [[0]], "mass": [1]},
                    "spec": {"eta": -1e999}}"#,
                "field 'eta' must be a finite number",
            ),
        ];
        for (raw, needle) in cases {
            let err = decode_distance_job(&Json::parse(raw).unwrap())
                .expect_err(needle);
            assert!(err.contains(needle), "'{err}' should contain '{needle}'");
        }
        let err = decode_barycenter_job(
            &Json::parse(r#"{"support": [[0.0], [1.0]], "marginals": [[0.5, 0.5, 0.5]]}"#)
                .unwrap(),
        )
        .expect_err("length mismatch");
        assert!(err.contains("3 entries but the support has 2 points"), "{err}");
    }

    #[test]
    fn backend_names_round_trip_through_parse_backend() {
        for backend in
            [ScalingBackend::Multiplicative, ScalingBackend::LogDomain, ScalingBackend::default()]
        {
            let name = scaling_backend_name(&backend);
            let parsed = parse_backend(name).unwrap();
            assert_eq!(scaling_backend_name(&parsed), name);
        }
        assert_eq!(backend_name(BackendKind::Multiplicative), "multiplicative");
        assert_eq!(backend_name(BackendKind::LogDomain), "log-domain");
    }
}
