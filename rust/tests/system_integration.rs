//! Cross-module integration tests: full pipelines exercising data
//! generators → cost/kernel construction → solvers → metrics, and the
//! coordinator under load, plus failure injection.

use spar_sink::coordinator::{
    BarycenterJob, CoordinatorConfig, DistanceJob, DistanceService, Measure, Method, ProblemSpec,
};
use spar_sink::data::echo::{frame_to_measure, generate, EchoConfig, Health};
use spar_sink::data::synthetic::{instance, Scenario, SparsityRegime};
use spar_sink::experiments::common::{
    exact_ot, exact_uot, ot_cost, run_method_ot, run_method_uot, wfr_cost_at_density, Method as M,
};
use spar_sink::metrics::rmae;
use spar_sink::rng::Rng;

#[test]
fn fig2_pipeline_shape_spar_beats_rand_beats_nothing() {
    // One (scenario, eps, d) cell of Fig. 2 end to end: Spar-Sink must
    // beat Rand-Sink at every budget on average.
    let mut rng = Rng::seed_from(0x51);
    let inst = instance(Scenario::C2, 300, 10, 1.0, 1.0, &mut rng);
    let cost = ot_cost(&inst.points);
    let eps = 0.1;
    let truth = exact_ot(&cost, &inst.a, &inst.b, eps).unwrap();
    let reps = 6;
    let mut spar = Vec::new();
    let mut rand = Vec::new();
    for _ in 0..reps {
        spar.push(run_method_ot(M::SparSink, &cost, &inst.a, &inst.b, eps, 8.0, &mut rng).unwrap());
        rand.push(run_method_ot(M::RandSink, &cost, &inst.a, &inst.b, eps, 8.0, &mut rng).unwrap());
    }
    let truths = vec![truth; reps];
    assert!(
        rmae(&spar, &truths) < rmae(&rand, &truths),
        "spar {} !< rand {}",
        rmae(&spar, &truths),
        rmae(&rand, &truths)
    );
}

#[test]
fn fig3_pipeline_nys_fails_where_spar_succeeds() {
    // The paper's motivating regime: sparse WFR kernel. Nys-Sink either
    // errors or is far worse than Spar-Sink.
    let mut rng = Rng::seed_from(0x52);
    let inst = instance(Scenario::C1, 200, 5, 5.0, 3.0, &mut rng);
    let cost = wfr_cost_at_density(&inst.points, SparsityRegime::R3.density());
    let (lambda, eps) = (0.1, 0.1);
    let truth = exact_uot(&cost, &inst.a, &inst.b, lambda, eps).unwrap();
    let spar =
        run_method_uot(M::SparSink, &cost, &inst.a, &inst.b, lambda, eps, 16.0, &mut rng).unwrap();
    let spar_err = (spar - truth).abs() / truth.abs();
    match run_method_uot(M::NysSink, &cost, &inst.a, &inst.b, lambda, eps, 16.0, &mut rng) {
        Ok(nys) => {
            let nys_err = (nys - truth).abs() / truth.abs();
            assert!(spar_err < nys_err, "spar {spar_err} !< nys {nys_err}");
        }
        Err(_) => { /* outright failure is the expected outcome too */ }
    }
    assert!(spar_err < 0.5, "spar error too large: {spar_err}");
}

#[test]
fn echo_to_distance_pipeline() {
    // Synthetic video -> measures -> coordinator WFR jobs -> distances
    // that increase between distant cardiac phases.
    let mut rng = Rng::seed_from(0x53);
    let size = 32;
    let video = generate(
        &EchoConfig { size, frames: 16, period: 12.0, health: Health::Normal, noise: 0.0 },
        &mut rng,
    );
    let m: Vec<Measure> = video
        .frames
        .iter()
        .map(|f| {
            let (p, w) = frame_to_measure(f, size, 0.05);
            Measure::new(p, w)
        })
        .collect();
    let service = DistanceService::start(CoordinatorConfig::default());
    let spec = ProblemSpec { eta: size as f64 / 7.5, eps: 0.05, ..Default::default() };
    // obj(0, 1) vs obj(0, ~ES): adjacent frames more similar than
    // ES-vs-ED after the divergence debias.
    let mk = |id: u64, j: usize| DistanceJob {
        id,
        source: m[0].clone(),
        target: m[j].clone(),
        method: Method::SparSink,
        spec: spec.clone(),
        seed: 100 + id,
    };
    let self0 = DistanceJob {
        id: 9,
        source: m[0].clone(),
        target: m[0].clone(),
        method: Method::SparSink,
        spec: spec.clone(),
        seed: 99,
    };
    let es = video.es_frames[0].min(m.len() - 1);
    let results = service
        .submit_all(vec![mk(0, 1), mk(1, es), self0.clone(), mk(2, 1), {
            let mut j = self0;
            j.id = 10;
            j.target = m[1].clone();
            j.source = m[1].clone();
            j
        }])
        .unwrap();
    let obj = |k: usize| results[k].objective;
    let d_near = obj(0) - 0.5 * (obj(2) + obj(4));
    // ES frame should be farther from frame 0 (ED) than frame 1 is.
    let es_self = {
        let svc_res = service
            .submit_all(vec![DistanceJob {
                id: 11,
                source: m[es].clone(),
                target: m[es].clone(),
                method: Method::SparSink,
                spec: spec.clone(),
                seed: 111,
            }])
            .unwrap();
        svc_res[0].objective
    };
    let d_far = obj(1) - 0.5 * (obj(2) + es_self);
    assert!(
        d_far > d_near,
        "ES-ED divergence {d_far} should exceed adjacent-frame divergence {d_near}"
    );
    service.shutdown();
}

#[test]
fn coordinator_backpressure_bounded_queue() {
    // queue_cap = 1 with a single slow worker: submissions still all
    // complete (blocking, not dropping).
    let service = DistanceService::start(CoordinatorConfig {
        workers: 1,
        queue_cap: 1,
        max_batch: 1,
        batch_window: std::time::Duration::from_millis(1),
        ..Default::default()
    });
    let mut rng = Rng::seed_from(0x54);
    let pts: Vec<Vec<f64>> = (0..40).map(|_| vec![rng.uniform() * 5.0, rng.uniform() * 5.0]).collect();
    let mass = vec![1.0 / 40.0; 40];
    let m = Measure::new(pts, mass);
    let jobs: Vec<DistanceJob> = (0..12)
        .map(|i| DistanceJob {
            id: i,
            source: m.clone(),
            target: m.clone(),
            method: Method::RandSink,
            spec: ProblemSpec { eta: 3.0, ..Default::default() },
            seed: i,
        })
        .collect();
    let results = service.submit_all(jobs).unwrap();
    assert_eq!(results.len(), 12);
    let metrics = service.shutdown();
    assert_eq!(metrics.submitted, 12);
    assert_eq!(metrics.completed + metrics.failed, 12);
}

#[test]
fn per_shard_gauges_sum_to_global_counters_and_render() {
    // A mixed distance + barycenter run (including one injected
    // failure) on a 3-shard pool: the per-shard worker-side counters
    // must sum exactly to the global ones, the queues must be drained,
    // and `render()` must carry one line per shard.
    use std::sync::Arc;

    let service = DistanceService::start(CoordinatorConfig {
        workers: 4,
        shards: 3,
        ..Default::default()
    });
    let mut rng = Rng::seed_from(0x55);
    let pts: Vec<Vec<f64>> =
        (0..30).map(|_| vec![rng.uniform() * 4.0, rng.uniform() * 4.0]).collect();
    let m = Measure::new(pts, vec![1.0 / 30.0; 30]);
    // Several ε values → several fingerprints for the router to spread.
    let mut jobs: Vec<DistanceJob> = [0.04f64, 0.06, 0.08, 0.1]
        .iter()
        .enumerate()
        .map(|(i, &eps)| DistanceJob {
            id: i as u64,
            source: m.clone(),
            target: m.clone(),
            method: Method::SparSink,
            spec: ProblemSpec { eta: 3.0, eps, ..Default::default() },
            seed: 10 + i as u64,
        })
        .collect();
    // One guaranteed failure: disjoint WFR supports.
    jobs.push(DistanceJob {
        id: 99,
        source: Measure::new(vec![vec![0.0, 0.0], vec![1.0, 0.0]], vec![0.6, 0.4]),
        target: Measure::new(vec![vec![500.0, 500.0], vec![501.0, 500.0]], vec![0.5, 0.5]),
        method: Method::SparSink,
        spec: ProblemSpec { eta: 1.0, ..Default::default() },
        seed: 3,
    });
    let support: Arc<Vec<Vec<f64>>> = Arc::new((0..24).map(|i| vec![i as f64 / 23.0]).collect());
    let hist = |mu: f64| -> Vec<f64> {
        let w: Vec<f64> = support
            .iter()
            .map(|p| (-(p[0] - mu).powi(2) / 0.02).exp() + 1e-4)
            .collect();
        let s: f64 = w.iter().sum();
        w.iter().map(|x| x / s).collect()
    };
    let bary_jobs: Vec<BarycenterJob> = (0..2)
        .map(|k| BarycenterJob {
            id: 200 + k,
            support: support.clone(),
            marginals: vec![hist(0.3), hist(0.7)],
            weights: vec![0.5, 0.5],
            method: Method::SparIbp,
            spec: ProblemSpec { eps: 0.02, s_multiplier: 12.0, ..Default::default() },
            seed: 40 + k,
        })
        .collect();

    let d_results = service.submit_all(jobs).unwrap();
    let b_results = service.submit_all_barycenters(bary_jobs).unwrap();
    assert_eq!(d_results.iter().filter(|r| r.error.is_some()).count(), 1);
    assert!(b_results.iter().all(|r| r.error.is_none()), "{b_results:?}");

    let m = service.shutdown();
    assert_eq!(m.shards.len(), 3);
    assert_eq!(m.completed + m.failed, 7);
    assert_eq!(m.failed, 1);
    let completed: u64 = m.shards.iter().map(|s| s.completed).sum();
    let failed: u64 = m.shards.iter().map(|s| s.failed).sum();
    let routed: u64 = m.shards.iter().map(|s| s.routed).sum();
    let recorded: u64 = m.shards.iter().map(|s| s.completed + s.failed).sum();
    assert_eq!(completed, m.completed, "worker-side completions must sum to the global");
    assert_eq!(failed, m.failed, "worker-side failures must sum to the global");
    assert_eq!(routed, m.batches, "every flushed batch is routed to exactly one shard");
    assert_eq!(recorded, m.submitted, "no job lost or double-counted across shards");
    let stolen: u64 = m.shards.iter().map(|s| s.stolen).sum();
    let stolen_from: u64 = m.shards.iter().map(|s| s.stolen_from).sum();
    assert_eq!(stolen, stolen_from, "each theft is debited from exactly one queue");
    for s in &m.shards {
        assert_eq!(s.depth, 0, "drained after shutdown: {s:?}");
        assert_eq!(s.busy, 0, "no worker mid-batch after shutdown: {s:?}");
    }
    let rendered = m.render();
    for s in 0..3 {
        assert!(rendered.contains(&format!("shard {s}: depth")), "missing shard {s}:\n{rendered}");
    }
}

#[test]
fn failure_injection_empty_overlap() {
    // Two measures with disjoint WFR supports: the solver must fail
    // cleanly (reported error), not panic or hang.
    let service = DistanceService::start(CoordinatorConfig::default());
    let m1 = Measure::new(vec![vec![0.0, 0.0], vec![1.0, 0.0]], vec![0.6, 0.4]);
    let m2 = Measure::new(vec![vec![500.0, 500.0], vec![501.0, 500.0]], vec![0.5, 0.5]);
    let job = DistanceJob {
        id: 0,
        source: m1,
        target: m2,
        method: Method::SparSink,
        spec: ProblemSpec { eta: 1.0, ..Default::default() },
        seed: 3,
    };
    let results = service.submit_all(vec![job]).unwrap();
    assert!(results[0].error.is_some(), "expected clean failure, got {:?}", results[0]);
    service.shutdown();
}

#[test]
fn experiment_registry_runs_one_quick_cell() {
    // The ablation experiment is the cheapest full registry entry; it
    // must produce non-empty output rows in quick mode.
    let outs = spar_sink::experiments::run("ablation", spar_sink::experiments::Profile::Quick)
        .expect("ablation runs");
    assert_eq!(outs.len(), 1);
    assert!(!outs[0].rows.items().is_empty());
    assert!(outs[0].text.contains("shrinkage"));
}
