//! Quickstart: approximate an entropic OT distance with Spar-Sink and
//! compare against the exact Sinkhorn solution.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use spar_sink::data::synthetic::{instance, Scenario};
use spar_sink::experiments::common::{exact_ot, ot_cost};
use spar_sink::rng::Rng;
use spar_sink::solvers::spar_sink::{spar_sink_ot, SparSinkParams};

fn main() {
    let n = 1000;
    let d = 5;
    let eps = 0.05;
    let mut rng = Rng::seed_from(7);

    // 1. A C1 workload: Gaussian histograms on uniform support (Sec. 5.1).
    let inst = instance(Scenario::C1, n, d, 1.0, 1.0, &mut rng);
    let cost = ot_cost(&inst.points);

    // 2. Exact entropic OT via the classical Sinkhorn algorithm.
    let t0 = std::time::Instant::now();
    let exact = exact_ot(&cost, &inst.a, &inst.b, eps).expect("sinkhorn");
    let exact_time = t0.elapsed();

    // 3. Spar-Sink at s = 8·s0(n) — expected O(n log^4 n) sampled entries.
    let t0 = std::time::Instant::now();
    let approx = spar_sink_ot(&cost, &inst.a, &inst.b, eps, 8.0, &SparSinkParams::default(), &mut rng)
        .expect("spar-sink");
    let spar_time = t0.elapsed();

    println!("n = {n}, d = {d}, eps = {eps}");
    println!("exact  OT_eps = {:>12.6}   ({exact_time:?})", exact);
    println!(
        "spar   OT_eps = {:>12.6}   ({spar_time:?}, nnz = {} of {})",
        approx.solution.objective,
        approx.stats.nnz,
        n * n
    );
    println!(
        "relative error = {:.4}   speedup = {:.1}x",
        (approx.solution.objective - exact).abs() / exact.abs(),
        exact_time.as_secs_f64() / spar_time.as_secs_f64()
    );
}
