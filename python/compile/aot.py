"""AOT pipeline: lower every L2 entry point to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Emits ``<entry>_n<N>.hlo.txt`` for every entry point in ``model.ENTRIES``
and every N in the size menu, plus a ``manifest.json`` the Rust runtime
uses to discover the menu.
"""

from __future__ import annotations

import argparse
import json
import os

import jax

from compile import model

# Size menu.  The Rust coordinator pads any request up to the next menu
# size (padded rows carry zero mass; validated in runtime tests).
SIZES = (64, 256, 1024)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name: str, n: int) -> str:
    fn = model.ENTRIES[name]
    specs = model.specs_for(n)[name]
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--sizes", type=int, nargs="*", default=list(SIZES),
        help="problem-size menu to compile",
    )
    parser.add_argument(
        "--entries", nargs="*", default=list(model.ENTRIES),
        help="subset of entry points to lower",
    )
    args = parser.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"block_iters": model.BLOCK_ITERS, "artifacts": []}
    for name in args.entries:
        for n in args.sizes:
            text = lower_entry(name, n)
            fname = f"{name}_n{n}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {"entry": name, "n": n, "file": fname, "bytes": len(text)}
            )
            print(f"lowered {name} n={n}: {len(text)} chars -> {path}")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
