//! Color transfer (Appendix D.1): move the sunset palette onto the
//! daytime point cloud with a Spar-Sink transport plan and compare the
//! resulting color map against the exact Sinkhorn map.
//!
//! ```sh
//! cargo run --release --example color_transfer
//! ```

use spar_sink::data::images::{barycentric_map, daytime_cloud, sunset_cloud};
use spar_sink::experiments::common::normalize_cost;
use spar_sink::linalg::Mat;
use spar_sink::ot::cost::{gibbs_kernel, sq_euclidean_cost};
use spar_sink::ot::sinkhorn::{sinkhorn_ot, transport_plan, SinkhornParams};
use spar_sink::rng::Rng;
use spar_sink::solvers::spar_sink::{spar_sink_ot, SparSinkParams};

fn mean_rgb(cloud: &[Vec<f64>]) -> [f64; 3] {
    let n = cloud.len() as f64;
    let mut m = [0.0; 3];
    for p in cloud {
        for c in 0..3 {
            m[c] += p[c] / n;
        }
    }
    m
}

fn main() {
    let n = 1500;
    let eps = 1e-2;
    let mut rng = Rng::seed_from(13);
    let source = daytime_cloud(n, &mut rng);
    let target = sunset_cloud(n, &mut rng);
    let a = vec![1.0 / n as f64; n];
    let cost = normalize_cost(&sq_euclidean_cost(&source, &target));
    let kernel = gibbs_kernel(&cost, eps);

    // Exact plan.
    let t0 = std::time::Instant::now();
    let exact = sinkhorn_ot(&kernel, &cost, &a, &a, eps, &SinkhornParams::default()).unwrap();
    let sink_time = t0.elapsed();
    let plan = transport_plan(&kernel, &exact.u, &exact.v);
    let exact_map = barycentric_map(|i| (0..n).map(|j| (j, plan.get(i, j))).collect(), &target, n);

    // Spar-Sink plan at s = 8 s0(n).
    let t0 = std::time::Instant::now();
    let approx = spar_sink_ot(&cost, &a, &a, eps, 8.0, &SparSinkParams::default(), &mut rng).unwrap();
    let spar_time = t0.elapsed();
    let plan_s = Mat::from_fn(n, n, |i, j| {
        approx.solution.u[i] * kernel.get(i, j) * approx.solution.v[j]
    });
    let spar_map =
        barycentric_map(|i| (0..n).map(|j| (j, plan_s.get(i, j))).collect(), &target, n);

    let dev: f64 = exact_map
        .iter()
        .zip(&spar_map)
        .map(|(x, y)| {
            x.iter().zip(y).map(|(u, v)| (u - v) * (u - v)).sum::<f64>().sqrt()
        })
        .sum::<f64>()
        / n as f64;

    println!("n = {n} RGB samples, eps = {eps}");
    println!("source (daytime) mean RGB: {:?}", mean_rgb(&source));
    println!("target (sunset)  mean RGB: {:?}", mean_rgb(&target));
    println!("sinkhorn transferred mean: {:?}  ({sink_time:?})", mean_rgb(&exact_map));
    println!("spar-sink transferred mean: {:?}  ({spar_time:?})", mean_rgb(&spar_map));
    println!(
        "mean RGB deviation from Sinkhorn map: {dev:.4}   speedup {:.1}x",
        sink_time.as_secs_f64() / spar_time.as_secs_f64()
    );
}
