//! Objective evaluation: entropic OT (Eq. 6) and entropic UOT (Eq. 10),
//! plus the generalized KL divergence and plan entropy helpers shared by
//! the dense and sparse solvers.

use crate::linalg::Mat;

/// Generalized KL divergence `KL(x‖y) = Σ x log(x/y) − x + y` with the
/// convention `0 log 0 = 0`.
pub fn kl_divergence(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .map(|(&xi, &yi)| {
            if xi > 0.0 {
                xi * (xi / yi).ln() - xi + yi
            } else {
                yi
            }
        })
        .sum()
}

/// Shannon entropy `H(T) = −Σ T (log T − 1)` over plan entries, with
/// `0 log 0 = 0`. Accepts an iterator so dense and sparse plans share it.
pub fn plan_entropy(entries: impl Iterator<Item = f64>) -> f64 {
    entries
        .map(|t| if t > 0.0 { -t * (t.ln() - 1.0) } else { 0.0 })
        .sum()
}

/// Entropic OT objective `<T,C> − ε H(T)` for a dense plan
/// `T = diag(u) K diag(v)`.
pub fn ot_objective_dense(kernel: &Mat, cost: &Mat, u: &[f64], v: &[f64], eps: f64) -> f64 {
    let (n, m) = (kernel.rows(), kernel.cols());
    let mut transport = 0.0;
    let mut entropy = 0.0;
    for i in 0..n {
        let ui = u[i];
        if ui == 0.0 {
            continue;
        }
        let krow = kernel.row(i);
        let crow = cost.row(i);
        for j in 0..m {
            let t = ui * krow[j] * v[j];
            if t > 0.0 {
                // cost may be +inf where kernel is 0; skip those (t=0).
                transport += t * crow[j];
                entropy -= t * (t.ln() - 1.0);
            }
        }
    }
    transport - eps * entropy
}

/// Marginals of a dense plan `T = diag(u) K diag(v)`.
pub fn plan_marginals_dense(kernel: &Mat, u: &[f64], v: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let (n, m) = (kernel.rows(), kernel.cols());
    let mut row = vec![0.0; n];
    let mut col = vec![0.0; m];
    for i in 0..n {
        let ui = u[i];
        let krow = kernel.row(i);
        let mut acc = 0.0;
        for j in 0..m {
            let t = ui * krow[j] * v[j];
            acc += t;
            col[j] += t;
        }
        row[i] = acc;
    }
    (row, col)
}

/// Entropic UOT objective (Eq. 10):
/// `<T,C> + λ KL(T1‖a) + λ KL(Tᵀ1‖b) − ε H(T)`.
pub fn uot_objective_dense(
    kernel: &Mat,
    cost: &Mat,
    a: &[f64],
    b: &[f64],
    u: &[f64],
    v: &[f64],
    lambda: f64,
    eps: f64,
) -> f64 {
    let base = ot_objective_dense(kernel, cost, u, v, eps);
    let (row, col) = plan_marginals_dense(kernel, u, v);
    base + lambda * kl_divergence(&row, a) + lambda * kl_divergence(&col, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kl_zero_when_equal() {
        let x = [0.3, 0.2, 0.5];
        assert!(kl_divergence(&x, &x).abs() < 1e-15);
    }

    #[test]
    fn kl_nonnegative_and_zero_mass_ok() {
        let x = [0.0, 0.5, 0.5];
        let y = [0.2, 0.4, 0.4];
        let kl = kl_divergence(&x, &y);
        assert!(kl >= 0.0);
        assert!(kl.is_finite());
    }

    #[test]
    fn entropy_of_uniform_plan() {
        // T_ij = 1/4 on a 2x2 plan: H = -sum t(log t - 1) = 4 * (1/4)(log 4 + 1)/... compute directly.
        let t: f64 = 0.25;
        let want = 4.0 * (-t * (t.ln() - 1.0));
        let got = plan_entropy([t; 4].into_iter());
        assert!((got - want).abs() < 1e-15);
    }

    #[test]
    fn ot_objective_product_plan() {
        // K = ones, u = a, v = b: T = a b^T (the eps -> inf limit).
        let a = [0.4, 0.6];
        let b = [0.5, 0.5];
        let kernel = Mat::from_fn(2, 2, |_, _| 1.0);
        let cost = Mat::from_fn(2, 2, |i, j| (i as f64 - j as f64).abs());
        let eps = 0.7;
        let got = ot_objective_dense(&kernel, &cost, &a, &b, eps);
        let mut want = 0.0;
        for i in 0..2 {
            for j in 0..2 {
                let t: f64 = a[i] * b[j];
                want += t * cost.get(i, j) + eps * t * (t.ln() - 1.0);
            }
        }
        assert!((got - want).abs() < 1e-12);
    }

    #[test]
    fn infinite_cost_zero_kernel_is_skipped() {
        let mut kernel = Mat::from_fn(2, 2, |_, _| 1.0);
        kernel.set(0, 1, 0.0);
        let mut cost = Mat::zeros(2, 2);
        cost.set(0, 1, f64::INFINITY);
        let obj = ot_objective_dense(&kernel, &cost, &[0.5, 0.5], &[0.5, 0.5], 0.1);
        assert!(obj.is_finite());
    }

    #[test]
    fn marginals_sum_to_plan_mass() {
        let kernel = Mat::from_fn(3, 3, |i, j| 1.0 / (1.0 + (i + j) as f64));
        let u = [0.9, 1.1, 1.0];
        let v = [1.2, 0.8, 1.0];
        let (row, col) = plan_marginals_dense(&kernel, &u, &v);
        let mass_r: f64 = row.iter().sum();
        let mass_c: f64 = col.iter().sum();
        assert!((mass_r - mass_c).abs() < 1e-12);
    }

    #[test]
    fn uot_objective_reduces_to_ot_when_marginals_met() {
        // If T's marginals equal (a, b) the KL terms vanish.
        let kernel = Mat::from_fn(2, 2, |_, _| 0.25);
        let cost = Mat::from_fn(2, 2, |i, j| ((i + j) % 2) as f64);
        let u = [1.0, 1.0];
        let v = [1.0, 1.0];
        let (row, col) = plan_marginals_dense(&kernel, &u, &v);
        let got = uot_objective_dense(&kernel, &cost, &row, &col, &u, &v, 3.0, 0.2);
        let want = ot_objective_dense(&kernel, &cost, &u, &v, 0.2);
        assert!((got - want).abs() < 1e-12);
    }
}
