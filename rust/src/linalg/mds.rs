//! Classical multidimensional scaling (Torgerson), used to embed the
//! pairwise WFR distance matrix of an echocardiogram video into 2-D for
//! cardiac-cycle visualization (paper Fig. 7, bottom row).

use super::{top_eigenpairs, Mat};
use crate::rng::Rng;

/// Classical MDS: embed an `n x n` distance matrix into `dim` dimensions.
///
/// Steps: square the distances, double-center (`B = -1/2 J D2 J`), take
/// the top `dim` eigenpairs, scale eigenvectors by sqrt(lambda).
/// Negative eigenvalues (non-Euclidean distances — WFR is a metric but
/// not flat) are clamped to zero, as standard.
pub fn classical_mds(dist: &Mat, dim: usize, rng: &mut Rng) -> Vec<Vec<f64>> {
    assert_eq!(dist.rows(), dist.cols(), "distance matrix must be square");
    let n = dist.rows();
    assert!(n > 0);
    // D2 = element-wise squared distances.
    let d2 = dist.map(|x| x * x);
    // Double centering: B_ij = -1/2 (D2_ij - rowmean_i - colmean_j + mean).
    let row_means: Vec<f64> = d2.row_sums().iter().map(|s| s / n as f64).collect();
    let col_means: Vec<f64> = d2.col_sums().iter().map(|s| s / n as f64).collect();
    let grand = row_means.iter().sum::<f64>() / n as f64;
    let b = Mat::from_fn(n, n, |i, j| {
        -0.5 * (d2.get(i, j) - row_means[i] - col_means[j] + grand)
    });
    let pairs = top_eigenpairs(&b, dim, 1000, 1e-12, rng);
    (0..n)
        .map(|i| {
            pairs
                .iter()
                .map(|(lambda, v)| lambda.max(0.0).sqrt() * v[i])
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn euclid(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
    }

    #[test]
    fn mds_recovers_planar_configuration() {
        // Points on a plane: MDS must reproduce pairwise distances.
        let pts = [
            vec![0.0, 0.0],
            vec![1.0, 0.0],
            vec![1.0, 1.5],
            vec![0.0, 1.0],
            vec![-0.5, 0.25],
        ];
        let n = pts.len();
        let d = Mat::from_fn(n, n, |i, j| euclid(&pts[i], &pts[j]));
        let mut rng = Rng::seed_from(6);
        let emb = classical_mds(&d, 2, &mut rng);
        for i in 0..n {
            for j in 0..n {
                let got = euclid(&emb[i], &emb[j]);
                assert!((got - d.get(i, j)).abs() < 1e-6, "({i},{j}): {got} vs {}", d.get(i, j));
            }
        }
    }

    #[test]
    fn mds_circle_stays_circular() {
        // Frames of a cyclic process embed onto a closed loop — the
        // qualitative property behind Fig. 7.
        let n = 24;
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|k| {
                let t = 2.0 * std::f64::consts::PI * k as f64 / n as f64;
                vec![t.cos(), t.sin()]
            })
            .collect();
        let d = Mat::from_fn(n, n, |i, j| euclid(&pts[i], &pts[j]));
        let mut rng = Rng::seed_from(7);
        let emb = classical_mds(&d, 2, &mut rng);
        // All embedded points should sit near radius 1 from the centroid.
        let cx = emb.iter().map(|p| p[0]).sum::<f64>() / n as f64;
        let cy = emb.iter().map(|p| p[1]).sum::<f64>() / n as f64;
        for p in &emb {
            let r = ((p[0] - cx).powi(2) + (p[1] - cy).powi(2)).sqrt();
            assert!((r - 1.0).abs() < 1e-6, "radius {r}");
        }
    }
}
