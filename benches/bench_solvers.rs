//! End-to-end solver benchmark at matched budgets (paper Fig. 5
//! companion): one full solve per method per size.

use spar_sink::bench::Bencher;
use spar_sink::data::synthetic::{instance, Scenario};
use spar_sink::experiments::common::{ot_cost, run_method_ot, Method};
use spar_sink::ot::cost::gibbs_kernel;
use spar_sink::ot::sinkhorn::{sinkhorn_ot, SinkhornParams};
use spar_sink::rng::Rng;
use spar_sink::solvers::greenkhorn::{greenkhorn_ot, GreenkhornParams};
use spar_sink::solvers::screenkhorn::{screenkhorn_ot, ScreenkhornParams};

fn main() {
    let mut bencher = Bencher::quick();
    let eps = 0.05;
    for &n in &[500usize, 1000, 2000] {
        let mut rng = Rng::seed_from(3);
        let inst = instance(Scenario::C1, n, 5, 1.0, 1.0, &mut rng);
        let cost = ot_cost(&inst.points);
        let kernel = gibbs_kernel(&cost, eps);

        bencher.bench(format!("sinkhorn/n={n}"), || {
            std::hint::black_box(
                sinkhorn_ot(&kernel, &cost, &inst.a, &inst.b, eps, &SinkhornParams::default())
                    .unwrap(),
            );
        });
        bencher.bench(format!("greenkhorn/n={n}"), || {
            std::hint::black_box(
                greenkhorn_ot(&kernel, &cost, &inst.a, &inst.b, eps, &GreenkhornParams::default())
                    .unwrap(),
            );
        });
        bencher.bench(format!("screenkhorn/n={n}"), || {
            let _ = std::hint::black_box(screenkhorn_ot(
                &kernel,
                &cost,
                &inst.a,
                &inst.b,
                eps,
                &ScreenkhornParams::default(),
            ));
        });
        for method in Method::all() {
            bencher.bench(format!("{}/n={n}", method.name()), || {
                let mut r = Rng::seed_from(4);
                let _ = std::hint::black_box(run_method_ot(
                    method, &cost, &inst.a, &inst.b, eps, 8.0, &mut r,
                ));
            });
        }
    }
    println!("\n{}", bencher.report("bench_solvers"));
}
