//! Empirical validation of the theory (Theorems 1-3 and Lemma 5):
//!
//! 1. **Lemma 5** — the sketch's spectral error `‖K̃−K‖₂/‖K‖₂` decays
//!    like √(n^{3−2α}/s); at fixed n it must scale ~s^{-1/2}.
//! 2. **Theorem 1** — the objective error inherits the √(1/s) rate.
//! 3. **Theorem 3** — Spar-Sink's iteration count stays within a
//!    constant factor of Sinkhorn's.

use super::common::{exact_ot, ot_cost, row};
use super::{ExperimentOutput, Profile};
use crate::api::{self, Method, OtProblem, SolverSpec};
use crate::data::synthetic::{instance, Scenario};
use crate::linalg::{spectral_norm, Mat};
use crate::metrics::{mean_sd, s0};
use crate::ot::cost::gibbs_kernel;
use crate::ot::sinkhorn::{sinkhorn_scalings, SinkhornParams};
use crate::rng::Rng;
use crate::sparse::poisson_sparsify_ot;
use crate::util::json::Json;
use crate::util::table::{f, Table};

/// Empirical validation of Lemma 5 and Theorems 1 & 3 (concentration/rates).
pub fn run(profile: Profile) -> ExperimentOutput {
    let n = profile.pick(300, 800);
    let reps = profile.reps(5, 30);
    let eps = 0.1;
    let mut rng = Rng::seed_from(0x7E01);
    let inst = instance(Scenario::C1, n, 5, 1.0, 1.0, &mut rng);
    let cost = ot_cost(&inst.points);
    let kernel = gibbs_kernel(&cost, eps);
    let k_norm = spectral_norm(&kernel, 300, 1e-10, &mut rng);
    let truth = exact_ot(&cost, &inst.a, &inst.b, eps).expect("exact");

    let s_mults = [2.0, 8.0, 32.0];
    let mut table = Table::new(&[
        "s/s0", "spectral err", "obj RMAE", "pred ratio (s^-1/2)", "meas ratio",
    ]);
    let mut rows = Vec::new();
    let mut spectral = Vec::new();
    let mut rmaes = Vec::new();
    for &mult in &s_mults {
        let s = mult * s0(n);
        let mut spec_errs = Vec::new();
        let mut obj_errs = Vec::new();
        for _ in 0..reps {
            let (sketch, _) = poisson_sparsify_ot(
                |i, j| kernel.get(i, j),
                |i, j| cost.get(i, j),
                &inst.a,
                &inst.b,
                s,
                1.0,
                &mut rng,
            )
            .expect("the A.2 sampler accepts the synthetic instance");
            // Spectral error of the sketch.
            let dense_sketch = sketch.to_dense_kernel();
            let diff = Mat::from_fn(n, n, |i, j| dense_sketch.get(i, j) - kernel.get(i, j));
            spec_errs.push(spectral_norm(&diff, 200, 1e-8, &mut rng) / k_norm);
            // Objective error (through the unified API).
            let problem = OtProblem::balanced(&cost, inst.a.clone(), inst.b.clone(), eps);
            let spec = SolverSpec::new(Method::SparSink).with_budget(mult);
            if let Ok(sol) = api::solve_with_rng(&problem, &spec, &mut rng) {
                obj_errs.push((sol.objective - truth).abs() / truth.abs());
            }
        }
        let (spec_mean, _) = mean_sd(&spec_errs);
        let (obj_mean, _) = mean_sd(&obj_errs);
        spectral.push(spec_mean);
        rmaes.push(obj_mean);
        let pred = (s_mults[0] / mult).sqrt();
        let meas = spec_mean / spectral[0];
        table.row(vec![
            f(mult, 0),
            f(spec_mean, 4),
            f(obj_mean, 4),
            f(pred, 3),
            f(meas, 3),
        ]);
        rows.push(row(vec![
            ("s_mult", Json::num(mult)),
            ("spectral_err", Json::num(spec_mean)),
            ("obj_rmae", Json::num(obj_mean)),
        ]));
    }

    // Theorem 3 — iterations until the OBJECTIVE stabilizes (relative
    // change < 1e-3 when doubling the iteration budget). The raw scaling
    // displacement is the wrong statistic for the sketch: a sampled
    // support generally admits no exactly-feasible plan, so u/v keep
    // drifting at a floor even though the objective has long converged.
    let stabilize_dense = |budgets: &[usize]| -> usize {
        let mut prev = f64::NAN;
        for &k in budgets {
            let p = SinkhornParams { delta: 0.0, max_iters: k, strict: false };
            let (u, v, ..) = sinkhorn_scalings(&kernel, &inst.a, &inst.b, 1.0, &p)
                .expect("non-strict dense sinkhorn cannot fail on this instance");
            let obj = crate::ot::objective::ot_objective_dense(&kernel, &cost, &u, &v, eps);
            if prev.is_finite() && (obj - prev).abs() <= 1e-3 * prev.abs().max(1e-12) {
                return k;
            }
            prev = obj;
        }
        *budgets.last().expect("the budget ladder is non-empty")
    };
    let budgets = [5usize, 10, 20, 40, 80, 160, 320];
    let dense_iters = stabilize_dense(&budgets);
    let (sketch, _) = poisson_sparsify_ot(
        |i, j| kernel.get(i, j),
        |i, j| cost.get(i, j),
        &inst.a,
        &inst.b,
        8.0 * s0(n),
        1.0,
        &mut rng,
    )
    .expect("the A.2 sampler accepts the synthetic instance");
    let mut spar_iters = *budgets.last().expect("the budget ladder is non-empty");
    let mut prev = f64::NAN;
    for &k in &budgets {
        let p = SinkhornParams { delta: 0.0, max_iters: k, strict: false };
        let (u, v, ..) =
            crate::solvers::sparse_loop::sparse_scalings(&sketch, &inst.a, &inst.b, 1.0, &p)
                .expect("non-strict sparse scalings cannot fail on this sketch");
        let obj = crate::solvers::sparse_loop::sparse_ot_objective(&sketch, &u, &v, eps);
        if prev.is_finite() && (obj - prev).abs() <= 1e-3 * prev.abs().max(1e-12) {
            spar_iters = k;
            break;
        }
        prev = obj;
    }
    let iter_ratio = spar_iters as f64 / dense_iters as f64;

    let text = format!(
        "Theory validation (n = {n}, eps = {eps}, {reps} reps)\n\
         Lemma 5 / Theorem 1: spectral and objective errors vs s (expect ~s^-1/2 decay)\n{}\n\
         Theorem 3: iterations to objective stabilization — Sinkhorn {dense_iters}, Spar-Sink {spar_iters} (ratio {iter_ratio:.2}; expected O(1))\n",
        table.render(),
    );
    rows.push(row(vec![
        ("dense_iters", Json::num(dense_iters as f64)),
        ("spar_iters", Json::num(spar_iters as f64)),
    ]));
    ExperimentOutput { id: "theory", text, rows: Json::arr(rows) }
}
