//! Inexact proximal-point OT (the paper's §7 future-work direction,
//! after Xie et al. 2020) combined with Spar-Sink inner solves:
//! approximate the *unregularized* OT distance by the proximal scheme
//!
//! ```text
//! T^{t+1} = argmin_T <T, C> + ε KL(T ‖ T^t)
//! ```
//!
//! Each proximal step is an entropic OT problem with the modified kernel
//! `K^t = exp(-C/ε) ⊙ T^t`, solved either exactly (dense Sinkhorn) or
//! inexactly via the importance sparsifier — the combination the paper
//! leaves to future work. The iterates converge to the unregularized OT
//! plan even for moderate ε (the sequence anneals the effective
//! regularization like ε/t).

use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::ot::sinkhorn::{sinkhorn_scalings, transport_plan, SinkhornParams};
use crate::rng::Rng;
use crate::solvers::sparse_loop;
use crate::sparse::poisson_sparsify_with;

/// Proximal-point configuration.
#[derive(Clone, Debug)]
pub struct ProximalParams {
    /// Entropic step size ε per proximal iteration.
    pub eps: f64,
    /// Outer proximal iterations.
    pub outer_iters: usize,
    /// Inner Sinkhorn parameters.
    pub inner: SinkhornParams,
    /// If set, sparsify each inner problem with this expected budget
    /// (Spar-Sink inner solves); None = exact dense inner solves.
    pub sparsify_budget: Option<f64>,
}

impl Default for ProximalParams {
    fn default() -> Self {
        ProximalParams {
            eps: 0.05,
            outer_iters: 10,
            inner: SinkhornParams { delta: 1e-8, max_iters: 500, strict: false },
            sparsify_budget: None,
        }
    }
}

/// Result of the proximal scheme.
#[derive(Clone, Debug)]
pub struct ProximalSolution {
    /// Unregularized transport cost `<T, C>` of the final iterate.
    pub transport_cost: f64,
    /// Final plan.
    pub plan: Mat,
    /// Outer iterations run.
    pub outer_iterations: usize,
}

/// Run inexact proximal-point OT.
pub fn proximal_ot(
    cost: &Mat,
    a: &[f64],
    b: &[f64],
    params: &ProximalParams,
    rng: &mut Rng,
) -> Result<ProximalSolution> {
    let n = a.len();
    let m = b.len();
    if cost.rows() != n || cost.cols() != m {
        return Err(Error::Dimension(format!(
            "cost {}x{} vs a[{n}], b[{m}]",
            cost.rows(),
            cost.cols()
        )));
    }
    if params.eps <= 0.0 || params.outer_iters == 0 {
        return Err(Error::InvalidParam("eps > 0 and outer_iters >= 1 required".into()));
    }
    let gibbs = cost.map(|c| if c.is_finite() { (-c / params.eps).exp() } else { 0.0 });
    // T^0 = a b^T (the eps -> inf plan).
    let mut plan = Mat::from_fn(n, m, |i, j| a[i] * b[j]);
    for _ in 0..params.outer_iters {
        // Proximal kernel K^t = exp(-C/eps) .* T^t (entrywise).
        let kernel = Mat::from_fn(n, m, |i, j| gibbs.get(i, j) * plan.get(i, j));
        let (u, v) = match params.sparsify_budget {
            None => {
                let (u, v, ..) = sinkhorn_scalings(&kernel, a, b, 1.0, &params.inner)?;
                (u, v)
            }
            Some(s) => {
                // Importance-sparsified inner solve. Unlike one-shot
                // Spar-Sink, the proximal scheme KNOWS the previous plan
                // T^t — which upper-bounds where T^{t+1} concentrates —
                // so we sample with p_ij ∝ T^t_ij: the "optimal"
                // plan-proportional probability that Section 3.1 calls
                // infeasible in the one-shot setting.
                let total = plan.sum();
                let plan_ref = &plan;
                let (sketch, _) = poisson_sparsify_with(
                    n,
                    m,
                    |i, j| kernel.get(i, j),
                    |i, j| cost.get(i, j),
                    |i, j| plan_ref.get(i, j),
                    total,
                    s,
                    1.0,
                    rng,
                )?;
                // Inexact step: estimate the scalings on the sketch, but
                // carry the plan forward through the FULL proximal kernel
                // (diag(u) K^t diag(v)); carrying it through the sketch
                // would shrink the support to the intersection of all
                // sketches and collapse the iterates.
                let (u, v, ..) =
                    sparse_loop::sparse_scalings(&sketch, a, b, 1.0, &params.inner)?;
                (u, v)
            }
        };
        plan = transport_plan(&kernel, &u, &v);
    }
    let transport_cost = plan_cost(&plan, cost);
    if !transport_cost.is_finite() {
        return Err(Error::Numerical("proximal transport cost is not finite".into()));
    }
    Ok(ProximalSolution { transport_cost, plan, outer_iterations: params.outer_iters })
}

fn plan_cost(plan: &Mat, cost: &Mat) -> f64 {
    let mut acc = 0.0;
    for i in 0..plan.rows() {
        let prow = plan.row(i);
        let crow = cost.row(i);
        for j in 0..plan.cols() {
            if prow[j] > 0.0 && crow[j].is_finite() {
                acc += prow[j] * crow[j];
            }
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::cost::sq_euclidean_cost;

    /// 1-D problem with known unregularized OT cost: two point masses
    /// shifted by delta -> W2^2 = delta^2.
    #[test]
    fn converges_to_unregularized_cost_on_translation() {
        let n = 16;
        let pts_a: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 / n as f64]).collect();
        let shift = 0.25;
        let pts_b: Vec<Vec<f64>> = pts_a.iter().map(|p| vec![p[0] + shift]).collect();
        let cost = sq_euclidean_cost(&pts_a, &pts_b);
        let a = vec![1.0 / n as f64; n];
        let b = a.clone();
        let mut rng = Rng::seed_from(301);
        let sol = proximal_ot(
            &cost,
            &a,
            &b,
            &ProximalParams { eps: 0.05, outer_iters: 60, ..Default::default() },
            &mut rng,
        )
        .unwrap();
        // Optimal plan: identity matching, cost = shift^2. The proximal
        // bias anneals like eps/t, so a few percent remains at t = 60.
        let want = shift * shift;
        assert!(
            (sol.transport_cost - want).abs() < 0.05 * want,
            "got {} want {want}",
            sol.transport_cost
        );
    }

    #[test]
    fn proximal_beats_single_entropic_solve() {
        // The annealing effect: after k proximal steps the bias is far
        // below the one-shot entropic bias at the same eps.
        let n = 24;
        let pts: Vec<Vec<f64>> = (0..n).map(|i| vec![(i as f64 * 0.618).fract()]).collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        let a: Vec<f64> = {
            let raw: Vec<f64> = (0..n).map(|i| 1.0 + (i % 3) as f64).collect();
            let s: f64 = raw.iter().sum();
            raw.iter().map(|x| x / s).collect()
        };
        let b: Vec<f64> = {
            let raw: Vec<f64> = (0..n).map(|i| 1.0 + ((i + 1) % 4) as f64).collect();
            let s: f64 = raw.iter().sum();
            raw.iter().map(|x| x / s).collect()
        };
        let mut rng = Rng::seed_from(303);
        let one = proximal_ot(
            &cost,
            &a,
            &b,
            &ProximalParams { eps: 0.2, outer_iters: 1, ..Default::default() },
            &mut rng,
        )
        .unwrap();
        let many = proximal_ot(
            &cost,
            &a,
            &b,
            &ProximalParams { eps: 0.2, outer_iters: 25, ..Default::default() },
            &mut rng,
        )
        .unwrap();
        // More proximal steps -> sharper plan -> lower transport cost
        // (closer to the LP optimum from above).
        assert!(
            many.transport_cost < one.transport_cost,
            "{} !< {}",
            many.transport_cost,
            one.transport_cost
        );
    }

    #[test]
    fn sparsified_inner_solves_stay_close_to_exact() {
        let n = 64;
        let mut rng = Rng::seed_from(305);
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|_| vec![rng.uniform(), rng.uniform()])
            .collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        let a = vec![1.0 / n as f64; n];
        let b = a.clone();
        let exact = proximal_ot(
            &cost,
            &a,
            &b,
            &ProximalParams { eps: 0.1, outer_iters: 6, ..Default::default() },
            &mut rng,
        )
        .unwrap();
        let sparse = proximal_ot(
            &cost,
            &a,
            &b,
            &ProximalParams {
                eps: 0.1,
                outer_iters: 6,
                sparsify_budget: Some((n * n) as f64 * 0.4),
                ..Default::default()
            },
            &mut rng,
        )
        .unwrap();
        let rel = (exact.transport_cost - sparse.transport_cost).abs()
            / exact.transport_cost.max(1e-12);
        assert!(rel < 0.5, "relative gap {rel}");
    }

    #[test]
    fn rejects_bad_params() {
        let cost = sq_euclidean_cost(&[vec![0.0]], &[vec![1.0]]);
        let mut rng = Rng::seed_from(307);
        assert!(proximal_ot(
            &cost,
            &[1.0],
            &[1.0],
            &ProximalParams { eps: -1.0, ..Default::default() },
            &mut rng
        )
        .is_err());
        assert!(proximal_ot(
            &cost,
            &[1.0],
            &[1.0],
            &ProximalParams { outer_iters: 0, ..Default::default() },
            &mut rng
        )
        .is_err());
    }
}
