//! Ablations called out in DESIGN.md §7:
//! 1. shrinkage θ mixing importance and uniform probabilities
//!    (condition (ii) of Theorem 1) — θ = 1 is the paper's pure
//!    importance sampling, θ = 0 degenerates to Rand-Sink;
//! 2. Poisson sampling (Eq. 7) vs sampling-with-replacement at the same
//!    expected budget (the Wang & Zou 2021 comparison the paper cites).

use super::common::{exact_ot, ot_cost, rmae_over_reps, row};
use super::{ExperimentOutput, Profile};
use crate::api::{self, Method, OtProblem, SolverSpec};
use crate::data::synthetic::{instance, Scenario};
use crate::metrics::s0;
use crate::ot::sinkhorn::SinkhornParams;
use crate::rng::Rng;
use crate::solvers::sparse_loop;
use crate::sparse::sample_with_replacement_ot;
use crate::util::json::Json;
use crate::util::table::{f, Table};

/// Ablations: shrinkage θ and sampling-scheme variants at fixed budget.
pub fn run(profile: Profile) -> ExperimentOutput {
    let n = profile.pick(300, 1000);
    let reps = profile.reps(5, 50);
    let eps = 0.1;
    let d = 5;
    let s_mult = 8.0;
    let mut rng = Rng::seed_from(0xAB3A);
    let inst = instance(Scenario::C1, n, d, 1.0, 1.0, &mut rng);
    let cost = ot_cost(&inst.points);
    let truth = exact_ot(&cost, &inst.a, &inst.b, eps).expect("exact");

    // --- shrinkage sweep ---
    let problem = OtProblem::balanced(&cost, inst.a.clone(), inst.b.clone(), eps);
    let mut table = Table::new(&["ablation", "setting", "rmae", "se"]);
    let mut rows = Vec::new();
    for theta in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let spec = SolverSpec::new(Method::SparSink)
            .with_budget(s_mult)
            .with_shrinkage(theta);
        let (rmae, se, _) = rmae_over_reps(
            reps,
            truth,
            |r| api::solve_with_rng(&problem, &spec, r).map(|s| s.objective),
            &mut rng,
        );
        table.row(vec!["shrinkage".into(), format!("theta={theta}"), f(rmae, 4), f(se, 4)]);
        rows.push(row(vec![
            ("ablation", Json::str("shrinkage")),
            ("theta", Json::num(theta)),
            ("rmae", Json::num(rmae)),
        ]));
    }

    // --- Poisson vs with-replacement at matched budget ---
    let budget = (s_mult * s0(n)) as usize;
    let (rmae_wr, se_wr, _) = rmae_over_reps(
        reps,
        truth,
        |r| {
            let sketch = sample_with_replacement_ot(
                |i, j| {
                    let c = cost.get(i, j);
                    if c.is_finite() { (-c / eps).exp() } else { 0.0 }
                },
                |i, j| cost.get(i, j),
                &inst.a,
                &inst.b,
                budget,
                r,
            )?;
            let (u, v, ..) =
                sparse_loop::sparse_scalings(&sketch, &inst.a, &inst.b, 1.0, &SinkhornParams::default())?;
            Ok(sparse_loop::sparse_ot_objective(&sketch, &u, &v, eps))
        },
        &mut rng,
    );
    table.row(vec!["sampling".into(), "with-replacement".into(), f(rmae_wr, 4), f(se_wr, 4)]);
    rows.push(row(vec![
        ("ablation", Json::str("sampling")),
        ("scheme", Json::str("with-replacement")),
        ("rmae", Json::num(rmae_wr)),
    ]));
    let spec = SolverSpec::new(Method::SparSink).with_budget(s_mult);
    let (rmae_p, se_p, _) = rmae_over_reps(
        reps,
        truth,
        |r| api::solve_with_rng(&problem, &spec, r).map(|s| s.objective),
        &mut rng,
    );
    table.row(vec!["sampling".into(), "poisson".into(), f(rmae_p, 4), f(se_p, 4)]);
    rows.push(row(vec![
        ("ablation", Json::str("sampling")),
        ("scheme", Json::str("poisson")),
        ("rmae", Json::num(rmae_p)),
    ]));

    let text = format!(
        "Ablations (n = {n}, eps = {eps}, s = 8 s0(n), {reps} reps)\n{}",
        table.render()
    );
    ExperimentOutput { id: "ablation", text, rows: Json::arr(rows) }
}
