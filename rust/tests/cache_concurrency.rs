//! The single-flight contract of the [`ArtifactCache`]:
//!
//! * exactly ONE build per fingerprint no matter how many threads race
//!   the first lookup — latecomers block on the building slot and share
//!   the published `Arc` (counted as hits);
//! * builds on DISTINCT fingerprints never serialize: while one ε's
//!   kernel build is in flight, lookups and builds at other ε values
//!   proceed (the many-ε sweep shape of `fig11`/`smalleps`);
//! * a build that panics clears its slot — waiters wake and retry, the
//!   next caller builds afresh, and nothing deadlocks on a poisoned
//!   slot.
//!
//! These tests deadlock (and time out) under the old build-under-the-
//! cache-mutex design, so a hang here IS the failure signal.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Barrier};
use std::time::Duration;

use spar_sink::engine::{ArtifactCache, CostArtifacts, Fingerprint, FormulationKey};
use spar_sink::rng::Rng;

fn pts(n: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::seed_from(seed);
    (0..n).map(|_| vec![rng.uniform() * 4.0, rng.uniform() * 4.0]).collect()
}

fn artifacts_for(seed: u64, eps: f64) -> (Fingerprint, Arc<CostArtifacts>) {
    let p = pts(16, seed);
    let key = FormulationKey::Balanced;
    let arts = CostArtifacts::for_sq_euclidean_support(&p, eps, key);
    (arts.fingerprint(), arts)
}

/// Many threads race the first lookup of ONE fingerprint: the build
/// runs exactly once, every thread gets the same resident `Arc`, and
/// the counters read 1 miss + (threads − 1) hits.
#[test]
fn exactly_once_build_per_fingerprint_under_contention() {
    let threads = 8;
    let cache = Arc::new(ArtifactCache::new(1 << 30));
    let builds = Arc::new(AtomicUsize::new(0));
    let barrier = Arc::new(Barrier::new(threads));
    let (fp, arts) = artifacts_for(1, 0.1);

    let handles: Vec<_> = (0..threads)
        .map(|_| {
            let (cache, builds, barrier, arts) =
                (cache.clone(), builds.clone(), barrier.clone(), arts.clone());
            std::thread::spawn(move || {
                barrier.wait();
                cache.get_or_build(fp, move || {
                    builds.fetch_add(1, Ordering::SeqCst);
                    arts
                })
            })
        })
        .collect();
    let shares: Vec<_> = handles.into_iter().map(|h| h.join().unwrap().share()).collect();

    assert_eq!(builds.load(Ordering::SeqCst), 1, "the build must run exactly once");
    for share in &shares[1..] {
        assert!(Arc::ptr_eq(&shares[0], share), "all threads must share one artifact");
    }
    let stats = cache.stats();
    assert_eq!(stats.misses, 1, "{stats:?}");
    assert_eq!(stats.hits, threads as u64 - 1, "{stats:?}");
    assert_eq!((stats.entries, stats.building), (1, 0), "{stats:?}");
}

/// Threaded stress across MANY fingerprints at once: every fingerprint
/// builds exactly once even when all threads sweep all fingerprints
/// concurrently (different ε values over one support — each its own
/// fingerprint).
#[test]
fn every_fingerprint_builds_exactly_once_across_a_sweep() {
    let threads = 6;
    let eps_sweep: Vec<f64> = (1..=8).map(|k| 0.01 * k as f64).collect();
    let cache = Arc::new(ArtifactCache::new(1 << 30));
    let support = Arc::new(pts(16, 3));
    let builds: Arc<Vec<AtomicUsize>> =
        Arc::new(eps_sweep.iter().map(|_| AtomicUsize::new(0)).collect());
    let barrier = Arc::new(Barrier::new(threads));

    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let (cache, support, builds, barrier, eps_sweep) = (
                cache.clone(),
                support.clone(),
                builds.clone(),
                barrier.clone(),
                eps_sweep.clone(),
            );
            std::thread::spawn(move || {
                barrier.wait();
                // Each thread walks the sweep from a different offset so
                // the contention pattern varies per fingerprint.
                for k in 0..eps_sweep.len() {
                    let idx = (k + t) % eps_sweep.len();
                    let eps = eps_sweep[idx];
                    let key = FormulationKey::Balanced;
                    let fp = Fingerprint::for_supports(&support, &support, None, eps, key);
                    let (support, builds) = (support.clone(), builds.clone());
                    let handle = cache.get_or_build(fp, move || {
                        builds[idx].fetch_add(1, Ordering::SeqCst);
                        CostArtifacts::for_sq_euclidean_support(&support, eps, key)
                    });
                    assert_eq!(handle.artifacts().eps.to_bits(), eps.to_bits());
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    for (idx, count) in builds.iter().enumerate() {
        assert_eq!(count.load(Ordering::SeqCst), 1, "fingerprint {idx} built more than once");
    }
    let stats = cache.stats();
    let fingerprints = eps_sweep.len() as u64;
    assert_eq!(stats.misses, fingerprints, "{stats:?}");
    assert_eq!(stats.hits, fingerprints * (threads as u64 - 1), "{stats:?}");
    assert_eq!((stats.entries as u64, stats.building), (fingerprints, 0), "{stats:?}");
}

/// No cross-fingerprint stall: while one ε's build is deliberately held
/// open, a lookup at ANOTHER ε completes. Under the old
/// build-under-the-lock design the second lookup blocks on the cache
/// mutex held across the first build and this test deadlocks.
#[test]
fn distinct_eps_builds_overlap() {
    let cache = Arc::new(ArtifactCache::new(1 << 30));
    let (fp_slow, arts_slow) = artifacts_for(5, 0.05);
    let (fp_fast, arts_fast) = artifacts_for(5, 0.1);
    assert_ne!(fp_slow, fp_fast, "distinct ε must give distinct fingerprints");

    let (entered_tx, entered_rx) = mpsc::channel::<()>();
    let (release_tx, release_rx) = mpsc::channel::<()>();
    let slow = {
        let cache = cache.clone();
        std::thread::spawn(move || {
            cache.get_or_build(fp_slow, move || {
                entered_tx.send(()).unwrap();
                // Hold the build open until the main thread has proven
                // it can use the cache concurrently.
                release_rx.recv().unwrap();
                arts_slow
            })
        })
    };

    // The slow build is now in flight (and NOT holding the map lock).
    entered_rx.recv_timeout(Duration::from_secs(30)).expect("slow build never started");
    let gauge_mid_build = cache.stats();
    assert_eq!(gauge_mid_build.building, 1, "{gauge_mid_build:?}");

    // A different fingerprint misses, builds, and hits — all while the
    // slow build is still open. Reaching the release send below IS the
    // no-stall proof.
    let fast = cache.get_or_build(fp_fast, || arts_fast.clone());
    assert!(Arc::ptr_eq(&fast.share(), &arts_fast));
    let fast_hit = cache.get_or_build(fp_fast, || unreachable!("fast is resident"));
    assert!(Arc::ptr_eq(&fast_hit.share(), &arts_fast));

    release_tx.send(()).unwrap();
    let slow_handle = slow.join().unwrap();
    assert_eq!(slow_handle.artifacts().eps.to_bits(), 0.05f64.to_bits());

    let stats = cache.stats();
    assert_eq!(stats.misses, 2, "{stats:?}");
    assert_eq!(stats.hits, 1, "{stats:?}");
    assert_eq!((stats.entries, stats.building), (2, 0), "{stats:?}");
}

/// Retry after a poisoned build: the panicking builder clears its slot,
/// a waiter blocked on that slot wakes and rebuilds, and the cache ends
/// up healthy (2 misses, artifact resident, nothing stuck building).
#[test]
fn waiter_retries_after_a_panicked_build() {
    let cache = Arc::new(ArtifactCache::new(1 << 30));
    let (fp, arts) = artifacts_for(9, 0.07);
    let rebuilds = Arc::new(AtomicUsize::new(0));

    let (entered_tx, entered_rx) = mpsc::channel::<()>();
    let (release_tx, release_rx) = mpsc::channel::<()>();
    let poisoned = {
        let cache = cache.clone();
        std::thread::spawn(move || {
            cache.get_or_build(fp, move || {
                entered_tx.send(()).unwrap();
                release_rx.recv().unwrap();
                panic!("simulated build failure");
            })
        })
    };
    entered_rx.recv_timeout(Duration::from_secs(30)).expect("build never started");

    // A waiter arrives while the doomed build is in flight…
    let waiter = {
        let (cache, arts, rebuilds) = (cache.clone(), arts.clone(), rebuilds.clone());
        std::thread::spawn(move || {
            cache.get_or_build(fp, move || {
                rebuilds.fetch_add(1, Ordering::SeqCst);
                arts
            })
        })
    };
    // Give the waiter time to block on the building slot (correctness
    // does not depend on it — arriving after the panic also retries).
    std::thread::sleep(Duration::from_millis(50));

    release_tx.send(()).unwrap();
    assert!(poisoned.join().is_err(), "the build panic must reach the builder");
    let handle = waiter.join().expect("waiter must recover, not deadlock or panic");
    assert!(Arc::ptr_eq(&handle.share(), &arts));
    assert_eq!(rebuilds.load(Ordering::SeqCst), 1, "the waiter rebuilds exactly once");

    let stats = cache.stats();
    assert_eq!(stats.misses, 2, "poisoned + retry: {stats:?}");
    assert_eq!((stats.entries, stats.building), (1, 0), "{stats:?}");
    // And the slot is genuinely healthy: the next lookup is a pure hit.
    let hit = cache.get_or_build(fp, || unreachable!("resident after the retry"));
    assert!(Arc::ptr_eq(&hit.share(), &arts));
    assert_eq!(cache.stats().hits, 1, "{:?}", cache.stats());
}
