//! Greenkhorn (Altschuler et al., 2017) — greedy coordinate Sinkhorn:
//! instead of rescaling every row and column each sweep, update only the
//! single row or column with the largest marginal violation, measured by
//! the distance `ρ(x, y) = y − x + x log(x/y)`.
//!
//! Each update is O(n), and the paper's experiments cap the number of
//! updates at 5n (Section 5 setup).

use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::ot::objective::ot_objective_dense;
use crate::ot::SinkhornSolution;

/// Greenkhorn configuration (paper defaults: 5n updates, δ = 1e-6 on the
/// total marginal violation).
#[derive(Clone, Debug)]
pub struct GreenkhornParams {
    /// Stop when Σ|r−a| + Σ|c−b| ≤ delta.
    pub delta: f64,
    /// Maximum greedy updates per support point (cap = factor * n).
    pub max_updates_factor: usize,
}

impl Default for GreenkhornParams {
    fn default() -> Self {
        GreenkhornParams { delta: 1e-6, max_updates_factor: 5 }
    }
}

/// The Greenkhorn violation distance ρ(x, y) = y − x + x log(x/y).
#[inline]
fn rho_dist(x: f64, y: f64) -> f64 {
    if x <= 0.0 {
        return y;
    }
    if y <= 0.0 {
        return f64::INFINITY;
    }
    y - x + x * (x / y).ln()
}

/// Run Greenkhorn for entropic OT and evaluate Eq. 6.
pub fn greenkhorn_ot(
    kernel: &Mat,
    cost: &Mat,
    a: &[f64],
    b: &[f64],
    eps: f64,
    params: &GreenkhornParams,
) -> Result<SinkhornSolution> {
    let n = a.len();
    let m = b.len();
    if kernel.rows() != n || kernel.cols() != m {
        return Err(Error::Dimension(format!(
            "kernel {}x{} vs a[{n}], b[{m}]",
            kernel.rows(),
            kernel.cols()
        )));
    }
    let mut u = vec![1.0; n];
    let mut v = vec![1.0; m];
    // Current plan marginals r = T1, c = T^T 1 maintained incrementally.
    let mut r = kernel.row_sums();
    let mut c = kernel.col_sums();
    let max_updates = params.max_updates_factor * n.max(m);
    let mut updates = 0;
    let mut violation = f64::INFINITY;
    while updates < max_updates {
        // Greedy pick: argmax rho(a_i, r_i) vs argmax rho(b_j, c_j).
        let (bi, bri) = (0..n)
            .map(|i| (i, rho_dist(a[i], r[i])))
            .max_by(|x, y| x.1.total_cmp(&y.1))
            .expect("marginals are non-empty (dimension-checked at entry)");
        let (bj, bcj) = (0..m)
            .map(|j| (j, rho_dist(b[j], c[j])))
            .max_by(|x, y| x.1.total_cmp(&y.1))
            .expect("marginals are non-empty (dimension-checked at entry)");
        violation = (0..n).map(|i| (r[i] - a[i]).abs()).sum::<f64>()
            + (0..m).map(|j| (c[j] - b[j]).abs()).sum::<f64>();
        if violation <= params.delta {
            break;
        }
        updates += 1;
        if bri >= bcj {
            // Rescale row bi: u_i <- a_i / (K v)_i.
            let kv: f64 = (0..m).map(|j| kernel.get(bi, j) * v[j]).sum();
            let new_u = if kv > 0.0 { a[bi] / kv } else { 0.0 };
            let old_u = u[bi];
            u[bi] = new_u;
            // Update marginals incrementally.
            let mut new_r = 0.0;
            for j in 0..m {
                let t_old = old_u * kernel.get(bi, j) * v[j];
                let t_new = new_u * kernel.get(bi, j) * v[j];
                c[j] += t_new - t_old;
                new_r += t_new;
            }
            r[bi] = new_r;
        } else {
            let ktu: f64 = (0..n).map(|i| kernel.get(i, bj) * u[i]).sum();
            let new_v = if ktu > 0.0 { b[bj] / ktu } else { 0.0 };
            let old_v = v[bj];
            v[bj] = new_v;
            let mut new_c = 0.0;
            for i in 0..n {
                let t_old = u[i] * kernel.get(i, bj) * old_v;
                let t_new = u[i] * kernel.get(i, bj) * new_v;
                r[i] += t_new - t_old;
                new_c += t_new;
            }
            c[bj] = new_c;
        }
    }
    let objective = ot_objective_dense(kernel, cost, &u, &v, eps);
    if !objective.is_finite() {
        return Err(Error::Numerical("Greenkhorn objective is not finite".into()));
    }
    Ok(SinkhornSolution {
        u,
        v,
        objective,
        iterations: updates,
        displacement: violation,
        converged: violation <= params.delta,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::cost::{gibbs_kernel, sq_euclidean_cost};
    use crate::ot::sinkhorn::{sinkhorn_ot, SinkhornParams};
    use crate::rng::Rng;

    fn problem(n: usize, seed: u64) -> (Mat, Mat, Vec<f64>, Vec<f64>) {
        let mut rng = Rng::seed_from(seed);
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..2).map(|_| rng.uniform()).collect())
            .collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        let kernel = gibbs_kernel(&cost, 0.1);
        let a: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.1).collect();
        let sa: f64 = a.iter().sum();
        let b: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.1).collect();
        let sb: f64 = b.iter().sum();
        (
            kernel,
            cost,
            a.iter().map(|x| x / sa).collect(),
            b.iter().map(|x| x / sb).collect(),
        )
    }

    #[test]
    fn rho_dist_properties() {
        assert_eq!(rho_dist(0.5, 0.5), 0.0);
        assert!(rho_dist(0.5, 0.1) > 0.0);
        assert!(rho_dist(0.1, 0.5) > 0.0);
        assert_eq!(rho_dist(0.0, 0.3), 0.3);
    }

    #[test]
    fn agrees_with_sinkhorn() {
        let (kernel, cost, a, b) = problem(48, 51);
        let eps = 0.1;
        let exact = sinkhorn_ot(&kernel, &cost, &a, &b, eps, &SinkhornParams::default()).unwrap();
        let green = greenkhorn_ot(
            &kernel,
            &cost,
            &a,
            &b,
            eps,
            &GreenkhornParams { delta: 1e-8, max_updates_factor: 400 },
        )
        .unwrap();
        let rel = (green.objective - exact.objective).abs() / exact.objective.abs();
        assert!(rel < 0.02, "relative gap {rel}");
    }

    #[test]
    fn violation_decreases() {
        let (kernel, cost, a, b) = problem(32, 53);
        let loose = greenkhorn_ot(
            &kernel,
            &cost,
            &a,
            &b,
            0.1,
            &GreenkhornParams { delta: 0.0, max_updates_factor: 1 },
        )
        .unwrap();
        let tight = greenkhorn_ot(
            &kernel,
            &cost,
            &a,
            &b,
            0.1,
            &GreenkhornParams { delta: 0.0, max_updates_factor: 100 },
        )
        .unwrap();
        assert!(tight.displacement < loose.displacement);
    }

    #[test]
    fn dimension_mismatch() {
        let (kernel, cost, a, b) = problem(8, 55);
        assert!(greenkhorn_ot(&kernel, &cost, &a[..4], &b, 0.1, &GreenkhornParams::default())
            .is_err());
    }
}
