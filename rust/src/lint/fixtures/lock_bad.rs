//! Seeded violation (lock-unwrap): bare `.lock().unwrap()` in a worker
//! path, inline and as a rustfmt-split chain.

use std::sync::Mutex;

/// Drains a shared queue, double-panicking if a peer ever poisoned it.
pub fn drain(queue: &Mutex<Vec<u64>>) -> Vec<u64> {
    let mut guard = queue.lock().unwrap();
    let len = queue
        .lock()
        .unwrap()
        .len();
    drop(len);
    guard.split_off(0)
}
