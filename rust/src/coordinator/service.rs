//! The distance service: bounded submission queue → batcher →
//! fingerprint-affine router → sharded worker pool, all on std threads
//! (the image has no tokio; the architecture mirrors a
//! continuous-batching server loop with per-queue worker shards).
//!
//! Workers carry NO per-method solver plumbing: every job is expressed
//! as an [`OtProblem`] — distance jobs as WFR cost/log-kernel oracles +
//! unbalanced formulation, barycenter jobs as a shared-support
//! barycenter formulation — plus a [`SolverSpec`] derived from the
//! job's [`ProblemSpec`], and dispatched through [`api::solve`]. The
//! per-job [`ProblemSpec::backend`] override is honored end-to-end,
//! each result reports the [`BackendKind`] that actually ran, and
//! `Auto` escalations from either job shape feed the same per-method
//! counters.
//!
//! Batching and routing live in [`super::scheduler`]; the per-worker
//! bounded queues in [`super::shard`]; work stealing in
//! [`super::steal`]. Sharding moves work between workers but never
//! changes it: artifacts are content-addressed and every solution is a
//! pure function of its job, so results are bitwise identical at any
//! shard count, stealing on or off (pinned by `cache_parity` and
//! `thread_determinism`).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use super::jobs::{
    BarycenterJob, BarycenterResult, DistanceJob, DistanceResult, Method, ProblemSpec,
};
use super::metrics::{LatencyHistogram, MetricsSnapshot};
use super::scheduler::{self, Batch, QueuedJob};
use super::shard::Shard;
use super::steal;
use crate::api::{self, CostSource, EntryOracle, Formulation, OtProblem, SolverSpec};
use crate::engine::{ArtifactCache, CostArtifacts, Fingerprint};
use crate::error::{Error, Result};
use crate::ot::cost::{euclidean, log_gibbs_from_cost, sq_euclidean, wfr_cost_from_distance};
use crate::ot::uot::wfr_distance_from_objective;
use crate::solvers::backend::BackendKind;

const N_METHODS: usize = Method::ALL.len();

/// How long an idle worker parks before re-scanning its own queue and
/// (with stealing on) the other shards. Bounds steal-discovery latency;
/// workers are woken immediately when work is routed to THEIR shard.
const WORKER_PARK: Duration = Duration::from_millis(1);

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker threads solving jobs. `0` resolves to
    /// `std::thread::available_parallelism()` — the same convention as
    /// `shards`.
    pub workers: usize,
    /// Shards: per-worker bounded batch queues with FIFO-submit /
    /// LIFO-pop scheduling. Batches are routed fingerprint-affinely
    /// (one cost fingerprint → one shard, so artifact hits stay
    /// shard-local); `0` resolves to available parallelism, and the
    /// count is always clamped to the resolved worker count so every
    /// shard has at least one worker. Sharding never changes results —
    /// only where they are computed.
    pub shards: usize,
    /// Work stealing: a worker whose shard has drained takes the
    /// OLDEST batch from the DEEPEST other shard (tail latency).
    /// Placement-only; results are bitwise identical on or off.
    pub steal: bool,
    /// Maximum jobs in flight before `submit` blocks (backpressure);
    /// also the per-shard queue bound, in batches.
    pub queue_cap: usize,
    /// Flush a batch at this many jobs…
    pub max_batch: usize,
    /// …or after this window since the first queued job.
    pub batch_window: Duration,
    /// Byte budget of the shared-cost artifact cache (LRU): pairwise
    /// jobs on one support build their cost/kernel/sampling-factor
    /// artifacts once per (η, ε, formulation) and reuse them across the
    /// batch.
    pub cache_bytes: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: crate::pool::num_threads().min(8),
            shards: 0,
            steal: true,
            queue_cap: 256,
            max_batch: 16,
            batch_window: Duration::from_millis(5),
            cache_bytes: crate::engine::DEFAULT_CACHE_BYTES,
        }
    }
}

fn available_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl CoordinatorConfig {
    /// The worker count the service will actually start: `workers`,
    /// with `0` meaning available parallelism.
    pub fn resolved_workers(&self) -> usize {
        if self.workers == 0 {
            available_parallelism()
        } else {
            self.workers
        }
    }

    /// The shard count the service will actually start: `shards` (`0` =
    /// available parallelism), clamped to [`Self::resolved_workers`] so
    /// no shard is left without a worker.
    pub fn resolved_shards(&self) -> usize {
        let shards = if self.shards == 0 {
            available_parallelism()
        } else {
            self.shards
        };
        shards.min(self.resolved_workers()).max(1)
    }
}

/// Why a non-blocking submission ([`DistanceService::try_submit`] /
/// [`DistanceService::try_submit_barycenter`]) was refused. The HTTP
/// gateway maps `Busy` to `429 Too Many Requests` and `Stopped` to
/// `503 Service Unavailable`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitRejection {
    /// `queue_cap` jobs are already in flight — the blocking
    /// [`DistanceService::submit`] would park. Transient: back off and
    /// retry.
    Busy,
    /// The service is draining ([`DistanceService::begin_drain`]) or
    /// its submission channel is gone; no retry will succeed.
    Stopped,
}

impl std::fmt::Display for SubmitRejection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitRejection::Busy => write!(f, "submission queue at capacity (backpressure)"),
            SubmitRejection::Stopped => write!(f, "service is draining or stopped"),
        }
    }
}

impl From<SubmitRejection> for Error {
    fn from(rejection: SubmitRejection) -> Self {
        Error::Coordinator(rejection.to_string())
    }
}

/// Counters and the artifact cache shared by every service thread.
/// Latency lives per shard (see [`Shard`]); the snapshot merges the
/// per-shard histograms.
pub(crate) struct Shared {
    pub(crate) submitted: AtomicU64,
    pub(crate) completed: AtomicU64,
    pub(crate) failed: AtomicU64,
    /// Batch-id source; ids are assigned by the batcher at flush time
    /// in sorted-group order (see [`super::scheduler`]).
    pub(crate) batches: AtomicU64,
    /// Per-method count of completed jobs whose solution came back from
    /// the log-domain engine WITHOUT the job forcing it (neither
    /// `Method::SparSinkLog` nor a `ProblemSpec::backend` override) —
    /// the `Auto` policy escalated. Indexed by [`Method::index`].
    pub(crate) escalations: [AtomicU64; N_METHODS],
    pub(crate) started: Instant,
    pub(crate) stopping: AtomicBool,
    /// Shared-cost artifact cache (content-addressed, byte-budget LRU,
    /// per-fingerprint single-flight); workers of both job shapes
    /// resolve their geometry through it CONCURRENTLY — a long build on
    /// one fingerprint (one ε, say) never stalls workers hitting or
    /// building other fingerprints. Fingerprint-affine routing keeps
    /// each fingerprint's hits on one shard's workers.
    pub(crate) cache: ArtifactCache,
}

/// The batched WFR-distance service.
pub struct DistanceService {
    tx: Option<SyncSender<QueuedJob>>,
    shared: Arc<Shared>,
    shards: Vec<Arc<Shard>>,
    batcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl DistanceService {
    /// Start the service threads: one batcher/router, and
    /// `config.resolved_workers()` workers over
    /// `config.resolved_shards()` shards (worker `w` owns shard
    /// `w % shards`).
    pub fn start(config: CoordinatorConfig) -> Self {
        let worker_count = config.resolved_workers();
        let shard_count = config.resolved_shards();
        let (tx, rx) = sync_channel::<QueuedJob>(config.queue_cap);
        let shards: Vec<Arc<Shard>> =
            (0..shard_count).map(|_| Arc::new(Shard::new(config.queue_cap))).collect();
        let shared = Arc::new(Shared {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            escalations: std::array::from_fn(|_| AtomicU64::new(0)),
            started: Instant::now(),
            stopping: AtomicBool::new(false),
            cache: ArtifactCache::new(config.cache_bytes),
        });

        // Batcher + router: collect jobs until max_batch or
        // batch_window, group by (method, size bucket), route each
        // group to its fingerprint-affine shard.
        let batcher = {
            let shared = shared.clone();
            let shards = shards.clone();
            let cfg = config.clone();
            std::thread::spawn(move || scheduler::batcher_loop(rx, cfg, shared, shards))
        };

        // Workers: each owns one shard (LIFO pop for cache warmth) and,
        // when stealing is on, relieves the deepest other shard once
        // its own queue drains.
        let steal = config.steal;
        let workers = (0..worker_count)
            .map(|w| {
                let shared = shared.clone();
                let shards = shards.clone();
                let own = w % shard_count;
                std::thread::spawn(move || worker_loop(own, &shards, &shared, steal))
            })
            .collect();

        DistanceService { tx: Some(tx), shared, shards, batcher: Some(batcher), workers }
    }

    fn enqueue(&self, queued: QueuedJob) -> Result<()> {
        // Checked BEFORE touching the channel: once a drain (or
        // shutdown) has begun, a blocking `send` could park forever on
        // a queue nobody will ever pop again, and a send on the closed
        // channel would surface as the misleading "queue closed". A
        // loud refusal is the contract instead — never block, never
        // panic (pinned by `submission_after_drain_fails_loudly`).
        if self.shared.stopping.load(Ordering::SeqCst) {
            return Err(Error::Coordinator(
                "service is draining: new submissions are refused \
                 (in-flight jobs still complete)"
                    .into(),
            ));
        }
        self.tx
            .as_ref()
            .ok_or_else(|| Error::Coordinator("service stopped".into()))?
            .send(queued)
            .map_err(|_| Error::Coordinator("queue closed".into()))?;
        self.shared.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Non-blocking [`enqueue`](Self::enqueue): where the blocking path
    /// parks on a full queue, this refuses with
    /// [`SubmitRejection::Busy`] — the admission-control primitive the
    /// HTTP gateway's 429 path is built on.
    fn try_enqueue(&self, queued: QueuedJob) -> std::result::Result<(), SubmitRejection> {
        if self.shared.stopping.load(Ordering::SeqCst) {
            return Err(SubmitRejection::Stopped);
        }
        let tx = self.tx.as_ref().ok_or(SubmitRejection::Stopped)?;
        match tx.try_send(queued) {
            Ok(()) => {
                self.shared.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(TrySendError::Full(_)) => Err(SubmitRejection::Busy),
            Err(TrySendError::Disconnected(_)) => Err(SubmitRejection::Stopped),
        }
    }

    /// Submit a job; blocks when the queue is full (backpressure).
    /// Returns the channel on which the result will arrive.
    pub fn submit(&self, job: DistanceJob) -> Result<Receiver<DistanceResult>> {
        let (tx, rx) = mpsc::channel();
        self.enqueue(QueuedJob::Distance { job, enqueued: Instant::now(), respond: tx })?;
        Ok(rx)
    }

    /// Submit a barycenter job; same queue, batcher and worker pool as
    /// distance jobs (backpressure applies identically).
    pub fn submit_barycenter(&self, job: BarycenterJob) -> Result<Receiver<BarycenterResult>> {
        let (tx, rx) = mpsc::channel();
        self.enqueue(QueuedJob::Barycenter { job, enqueued: Instant::now(), respond: tx })?;
        Ok(rx)
    }

    /// Non-blocking [`submit`](Self::submit): refuses instead of
    /// parking when the bounded submission queue is full
    /// ([`SubmitRejection::Busy`]) or the service is draining/stopped
    /// ([`SubmitRejection::Stopped`]). This is the gateway's admission
    /// control — refuse work that cannot be queued instead of stalling
    /// the caller's socket.
    pub fn try_submit(
        &self,
        job: DistanceJob,
    ) -> std::result::Result<Receiver<DistanceResult>, SubmitRejection> {
        let (tx, rx) = mpsc::channel();
        self.try_enqueue(QueuedJob::Distance { job, enqueued: Instant::now(), respond: tx })?;
        Ok(rx)
    }

    /// Non-blocking [`submit_barycenter`](Self::submit_barycenter);
    /// same admission semantics as [`try_submit`](Self::try_submit).
    pub fn try_submit_barycenter(
        &self,
        job: BarycenterJob,
    ) -> std::result::Result<Receiver<BarycenterResult>, SubmitRejection> {
        let (tx, rx) = mpsc::channel();
        self.try_enqueue(QueuedJob::Barycenter { job, enqueued: Instant::now(), respond: tx })?;
        Ok(rx)
    }

    /// Begin a graceful drain: every subsequent submission — blocking
    /// or non-blocking, distance or barycenter — returns a loud error
    /// instead of entering the queue (never blocks, never panics),
    /// while jobs already accepted keep flowing through the batcher
    /// and workers and deliver their results on their response
    /// channels. Call [`shutdown`](Self::shutdown) (or drop the
    /// service) afterwards to join the threads. Idempotent.
    pub fn begin_drain(&self) {
        self.shared.stopping.store(true, Ordering::SeqCst);
    }

    /// Whether [`begin_drain`](Self::begin_drain) (or a shutdown) has
    /// been called — new submissions are being refused.
    pub fn is_draining(&self) -> bool {
        self.shared.stopping.load(Ordering::SeqCst)
    }

    /// Convenience: submit many jobs and wait for all results (order
    /// matches input order).
    pub fn submit_all(&self, jobs: Vec<DistanceJob>) -> Result<Vec<DistanceResult>> {
        let receivers: Result<Vec<_>> = jobs.into_iter().map(|j| self.submit(j)).collect();
        receivers?
            .into_iter()
            .map(|rx| {
                rx.recv()
                    .map_err(|_| Error::Coordinator("worker dropped response".into()))
            })
            .collect()
    }

    /// Convenience: submit many barycenter jobs and wait for all results
    /// (order matches input order).
    pub fn submit_all_barycenters(
        &self,
        jobs: Vec<BarycenterJob>,
    ) -> Result<Vec<BarycenterResult>> {
        let receivers: Result<Vec<_>> =
            jobs.into_iter().map(|j| self.submit_barycenter(j)).collect();
        receivers?
            .into_iter()
            .map(|rx| {
                rx.recv()
                    .map_err(|_| Error::Coordinator("worker dropped response".into()))
            })
            .collect()
    }

    /// Metrics snapshot. Service-wide latency quantiles are the
    /// cross-shard histogram merge; per-shard gauges ride along in
    /// [`MetricsSnapshot::shards`].
    pub fn metrics(&self) -> MetricsSnapshot {
        let s = &self.shared;
        let elapsed = s.started.elapsed().as_secs_f64().max(1e-9);
        let completed = s.completed.load(Ordering::Relaxed);
        let log_escalations: Vec<(&'static str, u64)> = Method::ALL
            .iter()
            .filter_map(|m| {
                let count = s.escalations[m.index()].load(Ordering::Relaxed);
                (count > 0).then_some((m.name(), count))
            })
            .collect();
        let escalated: u64 = log_escalations.iter().map(|(_, c)| c).sum();
        let merged = LatencyHistogram::new();
        for shard in &self.shards {
            merged.absorb(&shard.latency);
        }
        MetricsSnapshot {
            submitted: s.submitted.load(Ordering::Relaxed),
            completed,
            failed: s.failed.load(Ordering::Relaxed),
            batches: s.batches.load(Ordering::Relaxed),
            mean_latency: merged.mean(),
            p50_latency: merged.quantile(0.5),
            p99_latency: merged.quantile(0.99),
            max_latency: merged.max(),
            throughput: completed as f64 / elapsed,
            log_escalations,
            log_escalation_rate: escalated as f64 / completed.max(1) as f64,
            shards: self.shards.iter().enumerate().map(|(i, sh)| sh.stats(i)).collect(),
            cache: s.cache.stats(),
            balancer: Vec::new(),
        }
    }

    /// Drain and stop all threads.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.stop_threads();
        self.metrics()
    }

    fn stop_threads(&mut self) {
        self.shared.stopping.store(true, Ordering::SeqCst);
        self.tx.take(); // close the submission channel
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        // The batcher has routed everything; closing the shards (no
        // further pushes possible) lets workers drain and exit.
        for shard in &self.shards {
            shard.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for DistanceService {
    fn drop(&mut self) {
        self.stop_threads();
    }
}

/// One worker: LIFO-pop the own shard while it has work; once it
/// drains, steal the oldest batch from the deepest other shard (when
/// enabled); exit when the own shard is closed and drained (nothing can
/// arrive after close — remaining batches elsewhere belong to their own
/// shards' workers).
fn worker_loop(own_idx: usize, shards: &[Arc<Shard>], shared: &Arc<Shared>, steal_on: bool) {
    let own = &shards[own_idx];
    loop {
        if let Some(batch) = own.pop_own() {
            execute_batch(batch, own, shared);
            continue;
        }
        if steal_on {
            if let Some(batch) = steal::steal_for(own_idx, shards) {
                own.stolen.fetch_add(1, Ordering::Relaxed);
                execute_batch(batch, own, shared);
                continue;
            }
        }
        if own.is_drained() {
            break;
        }
        own.wait_for_work(WORKER_PARK);
    }
}

/// Book-keeping shared by both job shapes: latency and success/failure
/// counters on BOTH the executing shard and the global counters (so
/// per-shard gauges sum to the global ones), plus the per-method
/// `Auto`-escalation counter (a completed job that came back from the
/// log engine without having pinned it).
#[allow(clippy::too_many_arguments)] // internal book-keeping fan-in, not API
fn record_outcome(
    shared: &Arc<Shared>,
    shard: &Shard,
    method: Method,
    forced_log: bool,
    backend: Option<BackendKind>,
    latency: Duration,
    failed: bool,
) {
    shard.latency.record(latency);
    if failed {
        shard.failed.fetch_add(1, Ordering::Relaxed);
        shared.failed.fetch_add(1, Ordering::Relaxed);
    } else {
        shard.completed.fetch_add(1, Ordering::Relaxed);
        shared.completed.fetch_add(1, Ordering::Relaxed);
        if backend == Some(BackendKind::LogDomain) && !forced_log {
            shared.escalations[method.index()].fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Run every job of one batch on the given (executing) shard's
/// book-keeping. The batch id travels with the batch; each job's cost
/// fingerprint is recomputed by [`QueuedJob::fingerprint`] — the same
/// function the router used, so routing and cache lookups agree.
fn execute_batch(batch: Batch, shard: &Shard, shared: &Arc<Shared>) {
    shard.busy.fetch_add(1, Ordering::Relaxed);
    let Batch { id: batch_id, jobs, .. } = batch;
    for queued in jobs {
        let (method, forced_log) = (queued.method(), queued.forces_log_domain());
        let fingerprint = queued.fingerprint();
        match queued {
            QueuedJob::Distance { job, enqueued, respond } => {
                let result = solve_job(&job, fingerprint, batch_id, enqueued, &shared.cache);
                record_outcome(
                    shared,
                    shard,
                    method,
                    forced_log,
                    result.backend,
                    result.latency,
                    result.error.is_some(),
                );
                let _ = respond.send(result);
            }
            QueuedJob::Barycenter { job, enqueued, respond } => {
                let result =
                    solve_barycenter_job(job, fingerprint, batch_id, enqueued, &shared.cache);
                record_outcome(
                    shared,
                    shard,
                    method,
                    forced_log,
                    result.backend,
                    result.latency,
                    result.error.is_some(),
                );
                let _ = respond.send(result);
            }
        }
    }
    shard.busy.fetch_sub(1, Ordering::Relaxed);
}

/// Express one WFR-distance job as an [`OtProblem`] + [`SolverSpec`]
/// and dispatch it through `api::solve` — the single method-agnostic
/// solver surface.
///
/// Jobs with a shareable `fingerprint` (grid fits
/// [`SHARED_ARTIFACT_ENTRY_CAP`](crate::engine::SHARED_ARTIFACT_ENTRY_CAP))
/// resolve their geometry through the service's [`ArtifactCache`]: the
/// WFR cost, the Gibbs kernel and the cost-dependent sampling factor
/// are built once per (support pair, η, ε, λ) and every other job on
/// the same fingerprint is a cache hit ("reuse + reweight") — and since
/// the router sends every batch on this fingerprint to one shard, those
/// hits stay shard-local. Jobs racing the build block on its
/// single-flight slot, while jobs on other fingerprints (a many-ε
/// sweep) build and hit unimpeded. Warm solutions are
/// bitwise-identical to the oracle cold path, which oversized jobs keep
/// (kernel and cost stay entry oracles, never materialized densely).
fn solve_job(
    job: &DistanceJob,
    fingerprint: Option<Fingerprint>,
    batch_id: u64,
    enqueued: Instant,
    cache: &ArtifactCache,
) -> DistanceResult {
    let spec = &job.spec;
    let (eta, eps) = (spec.eta, spec.eps);
    let (rows, cols) = (job.source.len(), job.target.len());
    let cost_source = if let Some(fingerprint) = fingerprint {
        let handle = cache.get_or_build(fingerprint, || {
            CostArtifacts::for_wfr_supports(
                &job.source.points,
                &job.target.points,
                eta,
                eps,
                crate::engine::FormulationKey::unbalanced(spec.lambda),
            )
        });
        CostSource::Shared(handle)
    } else {
        let src = job.source.points.clone();
        let tgt = job.target.points.clone();
        let cost: EntryOracle = Arc::new(move |i: usize, j: usize| {
            wfr_cost_from_distance(euclidean(&src[i], &tgt[j]), eta)
        });
        // Log-kernel oracle for the sparsified arms: the WFR cost is
        // finite below the π·η cutoff, so `−C/ε` stays finite where the
        // linear kernel underflows at small ε. Sampling through it keeps
        // every selected entry usable by the log-domain backend — a
        // sketch built from the linear oracle would silently DROP
        // underflowed entries, and no later escalation could recover
        // them. (The shared-artifact path derives the same `−C/ε` from
        // the cached cost matrix.)
        let cost_for_lk = cost.clone();
        let log_kernel: EntryOracle =
            Arc::new(move |i: usize, j: usize| log_gibbs_from_cost(cost_for_lk(i, j), eps));
        CostSource::Oracle { rows, cols, cost, log_kernel: Some(log_kernel) }
    };
    let problem = OtProblem {
        cost: cost_source,
        a: job.source.mass.clone(),
        b: job.target.mass.clone(),
        eps,
        formulation: Formulation::Unbalanced { lambda: spec.lambda },
    };
    let solver_spec = solver_spec_for(job.method, spec, job.seed);

    let solved = api::solve(&problem, &solver_spec);
    let latency = enqueued.elapsed();
    match solved {
        Ok(solution) => DistanceResult {
            id: job.id,
            distance: wfr_distance_from_objective(solution.objective),
            objective: solution.objective,
            iterations: solution.iterations,
            backend: solution.backend,
            latency,
            batch_id,
            error: None,
        },
        Err(e) => DistanceResult {
            id: job.id,
            distance: f64::NAN,
            objective: f64::NAN,
            iterations: 0,
            backend: None,
            latency,
            batch_id,
            error: Some(e.to_string()),
        },
    }
}

/// Translate the job-level [`ProblemSpec`] into the unified
/// [`SolverSpec`] — shared by distance and barycenter workers so the
/// per-job backend override is honored identically everywhere.
fn solver_spec_for(method: Method, spec: &ProblemSpec, seed: u64) -> SolverSpec {
    let mut solver_spec = SolverSpec::new(method)
        .with_budget(spec.s_multiplier)
        .with_tolerance(spec.delta)
        .with_max_iters(spec.max_iters)
        .with_seed(seed);
    if let Some(backend) = spec.backend {
        solver_spec = solver_spec.with_backend(backend);
    }
    solver_spec
}

/// Express one barycenter job as a barycenter [`OtProblem`] over the
/// shared support's squared-Euclidean ground cost and dispatch it
/// through `api::solve`, exactly like the distance path. Jobs with a
/// shareable `fingerprint` share one cached cost materialization per
/// (support, ε) — the Spar-IBP sampler otherwise re-derives the ground
/// cost per (kernel, entry); oversized jobs keep the entry oracle. The
/// job is consumed so its histograms move into the problem instead of
/// being copied per solve.
fn solve_barycenter_job(
    job: BarycenterJob,
    fingerprint: Option<Fingerprint>,
    batch_id: u64,
    enqueued: Instant,
    cache: &ArtifactCache,
) -> BarycenterResult {
    let BarycenterJob { id, support, marginals, weights, method, spec, seed } = job;
    let n = support.len();
    let cost_source = if let Some(fingerprint) = fingerprint {
        let support = support.clone();
        let eps = spec.eps;
        let handle = cache.get_or_build(fingerprint, move || {
            CostArtifacts::for_sq_euclidean_support(
                &support,
                eps,
                crate::engine::FormulationKey::Barycenter,
            )
        });
        CostSource::Shared(handle)
    } else {
        let support = support.clone();
        let cost: EntryOracle =
            Arc::new(move |i: usize, j: usize| sq_euclidean(&support[i], &support[j]));
        CostSource::Oracle { rows: n, cols: n, cost, log_kernel: None }
    };
    let problem = OtProblem {
        cost: cost_source,
        a: Arc::new(Vec::new()),
        b: Arc::new(Vec::new()),
        eps: spec.eps,
        formulation: Formulation::Barycenter { marginals, weights },
    };
    let solver_spec = solver_spec_for(method, &spec, seed);
    let solved = api::solve(&problem, &solver_spec);
    let latency = enqueued.elapsed();
    match solved {
        Ok(solution) => BarycenterResult {
            id,
            q: solution.barycenter.unwrap_or_default(),
            iterations: solution.iterations,
            converged: solution.converged,
            backend: solution.backend,
            latency,
            batch_id,
            error: None,
        },
        Err(e) => BarycenterResult {
            id,
            q: Vec::new(),
            iterations: 0,
            converged: false,
            backend: None,
            latency,
            batch_id,
            error: Some(e.to_string()),
        },
    }
}
#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::jobs::Measure;
    use crate::rng::Rng;
    use crate::solvers::backend::ScalingBackend;

    fn toy_measure(n: usize, seed: u64, mass: f64) -> Measure {
        let mut rng = Rng::seed_from(seed);
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|_| vec![rng.uniform() * 10.0, rng.uniform() * 10.0])
            .collect();
        let mut m: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.1).collect();
        let s: f64 = m.iter().sum();
        m.iter_mut().for_each(|x| *x *= mass / s);
        Measure::new(pts, m)
    }

    fn job(id: u64, method: Method, n: usize) -> DistanceJob {
        DistanceJob {
            id,
            source: toy_measure(n, 1000 + id, 1.0),
            target: toy_measure(n, 2000 + id, 1.2),
            method,
            spec: ProblemSpec { eta: 3.0, eps: 0.05, ..Default::default() },
            seed: 42 + id,
        }
    }

    #[test]
    fn submits_and_completes_jobs() {
        let service = DistanceService::start(CoordinatorConfig {
            workers: 2,
            ..Default::default()
        });
        let jobs: Vec<DistanceJob> = (0..8).map(|i| job(i, Method::SparSink, 60)).collect();
        let results = service.submit_all(jobs).unwrap();
        assert_eq!(results.len(), 8);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.error.is_none(), "job {i}: {:?}", r.error);
            assert!(r.distance.is_finite() && r.distance >= 0.0);
            // Moderate eps on the Auto policy: multiplicative engine.
            assert_eq!(r.backend, Some(BackendKind::Multiplicative));
        }
        let m = service.shutdown();
        assert_eq!(m.completed, 8);
        assert_eq!(m.failed, 0);
        assert!(m.batches >= 1);
        assert!(m.log_escalations.is_empty());
        assert_eq!(m.log_escalation_rate, 0.0);
    }

    #[test]
    fn spar_sink_jobs_approximate_sinkhorn_jobs() {
        let service = DistanceService::start(CoordinatorConfig::default());
        let mk = |method: Method, id: u64| DistanceJob {
            id,
            source: toy_measure(120, 7, 1.0),
            target: toy_measure(120, 8, 1.3),
            method,
            spec: ProblemSpec { eta: 4.0, eps: 0.05, s_multiplier: 16.0, ..Default::default() },
            seed: 99 + id,
        };
        let results = service
            .submit_all(vec![mk(Method::Sinkhorn, 0), mk(Method::SparSink, 1)])
            .unwrap();
        let exact = results[0].distance;
        let approx = results[1].distance;
        let rel = (exact - approx).abs() / exact.max(1e-12);
        assert!(rel < 0.5, "exact {exact} vs spar {approx} (rel {rel})");
        drop(service);
    }

    #[test]
    fn mixed_methods_are_batched_separately() {
        let service = DistanceService::start(CoordinatorConfig {
            workers: 2,
            max_batch: 64,
            batch_window: Duration::from_millis(30),
            ..Default::default()
        });
        let mut jobs = Vec::new();
        for i in 0..4 {
            jobs.push(job(i, Method::SparSink, 40));
            jobs.push(job(100 + i, Method::RandSink, 40));
        }
        let results = service.submit_all(jobs).unwrap();
        assert_eq!(results.len(), 8);
        let m = service.shutdown();
        // At least two groups (one per method).
        assert!(m.batches >= 2, "batches {}", m.batches);
    }

    #[test]
    fn batch_ids_are_distinct_per_batch() {
        // max_batch = 1: every job flushes as its own batch, so with the
        // id carried by the batch the results must report one distinct
        // id per batch. (The racy version re-read the global counter
        // and reported duplicate/late ids.)
        let service = DistanceService::start(CoordinatorConfig {
            workers: 4,
            max_batch: 1,
            ..Default::default()
        });
        let jobs: Vec<DistanceJob> = (0..6).map(|i| job(i, Method::RandSink, 20)).collect();
        let results = service.submit_all(jobs).unwrap();
        let mut ids: Vec<u64> = results.iter().map(|r| r.batch_id).collect();
        ids.sort_unstable();
        ids.dedup();
        let m = service.shutdown();
        assert_eq!(m.batches, 6);
        assert_eq!(ids.len() as u64, m.batches, "duplicate batch ids: {ids:?}");
        assert!(ids.iter().all(|&id| id >= 1 && id <= m.batches), "{ids:?}");
    }

    #[test]
    fn small_eps_spar_sink_reports_log_domain_and_escalation_metrics() {
        // ε below the Auto threshold (2e-3): plain SparSink jobs must
        // come back solved BY the log-domain engine, report that in the
        // result, and show up in the per-method escalation counters.
        let service = DistanceService::start(CoordinatorConfig {
            workers: 2,
            ..Default::default()
        });
        let mk = |id: u64| DistanceJob {
            id,
            source: toy_measure(50, 31, 1.0),
            target: toy_measure(50, 32, 1.2),
            method: Method::SparSink,
            spec: ProblemSpec {
                eta: 3.0,
                eps: 5e-4,
                s_multiplier: 16.0,
                ..Default::default()
            },
            seed: 7 + id,
        };
        let results = service.submit_all(vec![mk(0), mk(1)]).unwrap();
        for r in &results {
            assert!(r.error.is_none(), "job {}: {:?}", r.id, r.error);
            assert!(r.distance.is_finite() && r.distance >= 0.0);
            assert_eq!(r.backend, Some(BackendKind::LogDomain), "job {}", r.id);
        }
        let m = service.shutdown();
        assert_eq!(m.completed, 2);
        assert_eq!(m.log_escalations, vec![("spar-sink", 2)]);
        assert!((m.log_escalation_rate - 1.0).abs() < 1e-12);
        assert!(m.render().contains("spar-sink=2"));
    }

    #[test]
    fn spar_sink_log_jobs_survive_small_eps_without_counting_as_escalations() {
        // ε far below the multiplicative underflow point: SparSinkLog
        // pins the log engine itself, so the jobs succeed but are NOT
        // escalations.
        let service = DistanceService::start(CoordinatorConfig {
            workers: 2,
            ..Default::default()
        });
        let mk = |id: u64| DistanceJob {
            id,
            source: toy_measure(50, 31, 1.0),
            target: toy_measure(50, 32, 1.2),
            method: Method::SparSinkLog,
            spec: ProblemSpec {
                eta: 3.0,
                eps: 5e-4,
                s_multiplier: 16.0,
                ..Default::default()
            },
            seed: 7 + id,
        };
        let results = service.submit_all(vec![mk(0), mk(1)]).unwrap();
        for r in &results {
            assert!(r.error.is_none(), "job {}: {:?}", r.id, r.error);
            assert_eq!(r.backend, Some(BackendKind::LogDomain));
        }
        let m = service.shutdown();
        assert_eq!(m.completed, 2);
        assert_eq!(m.failed, 0);
        assert!(m.log_escalations.is_empty());
        assert_eq!(m.log_escalation_rate, 0.0);
    }

    #[test]
    fn per_job_backend_override_is_honored_end_to_end() {
        // Same moderate-eps problem twice: the default Auto policy runs
        // multiplicative; a per-job LogDomain override must actually
        // reach the scaling loop and be reported back.
        let service = DistanceService::start(CoordinatorConfig {
            workers: 2,
            ..Default::default()
        });
        let mk = |id: u64, backend: Option<ScalingBackend>| DistanceJob {
            id,
            source: toy_measure(60, 11, 1.0),
            target: toy_measure(60, 12, 1.2),
            method: Method::SparSink,
            spec: ProblemSpec { eta: 3.0, eps: 0.05, backend, ..Default::default() },
            seed: 5,
        };
        let results = service
            .submit_all(vec![mk(0, None), mk(1, Some(ScalingBackend::LogDomain))])
            .unwrap();
        assert!(results.iter().all(|r| r.error.is_none()), "{results:?}");
        assert_eq!(results[0].backend, Some(BackendKind::Multiplicative));
        assert_eq!(results[1].backend, Some(BackendKind::LogDomain));
        // Forced-log job is not an escalation.
        let m = service.shutdown();
        assert!(m.log_escalations.is_empty(), "{:?}", m.log_escalations);
    }

    fn bary_job(
        id: u64,
        method: Method,
        eps: f64,
        backend: Option<ScalingBackend>,
    ) -> BarycenterJob {
        let n = 32;
        let support: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 / (n - 1) as f64]).collect();
        let hist = |mu: f64| -> Vec<f64> {
            let w: Vec<f64> = support
                .iter()
                .map(|p| (-(p[0] - mu).powi(2) / 0.01).exp() + 1e-4)
                .collect();
            let s: f64 = w.iter().sum();
            w.iter().map(|x| x / s).collect()
        };
        BarycenterJob {
            id,
            marginals: vec![hist(0.25), hist(0.75)],
            support: Arc::new(support),
            weights: vec![0.5, 0.5],
            method,
            spec: ProblemSpec { eps, s_multiplier: 40.0, backend, ..Default::default() },
            seed: 11 + id,
        }
    }

    #[test]
    fn barycenter_jobs_complete_alongside_distance_jobs() {
        let service = DistanceService::start(CoordinatorConfig {
            workers: 2,
            ..Default::default()
        });
        let bary_rx = service
            .submit_barycenter(bary_job(7, Method::SparIbp, 0.01, None))
            .unwrap();
        let dist = service.submit_all(vec![job(0, Method::SparSink, 40)]).unwrap();
        let bary = bary_rx.recv().unwrap();
        assert_eq!(bary.id, 7);
        assert!(bary.error.is_none(), "{:?}", bary.error);
        assert_eq!(bary.q.len(), 32);
        // Moderate ε on the Auto policy: multiplicative, no escalation.
        assert_eq!(bary.backend, Some(BackendKind::Multiplicative));
        assert!(dist[0].error.is_none());
        let m = service.shutdown();
        assert_eq!(m.completed, 2);
        assert!(m.log_escalations.is_empty());
    }

    #[test]
    fn small_eps_barycenter_jobs_escalate_and_feed_the_counters() {
        // ε below the Auto threshold: exact-IBP and Spar-IBP barycenter
        // jobs must come back from the log engine and increment the
        // per-method escalation counters, exactly like distance jobs.
        let service = DistanceService::start(CoordinatorConfig {
            workers: 2,
            ..Default::default()
        });
        let results = service
            .submit_all_barycenters(vec![
                bary_job(0, Method::SparIbp, 5e-4, None),
                bary_job(1, Method::Sinkhorn, 5e-4, None),
            ])
            .unwrap();
        for r in &results {
            assert!(r.error.is_none(), "job {}: {:?}", r.id, r.error);
            assert_eq!(r.backend, Some(BackendKind::LogDomain), "job {}", r.id);
            let mass: f64 = r.q.iter().sum();
            assert!((mass - 1.0).abs() < 1e-9, "job {} mass {mass}", r.id);
        }
        let m = service.shutdown();
        assert_eq!(m.completed, 2);
        let mut escalations = m.log_escalations.clone();
        escalations.sort_unstable();
        assert_eq!(escalations, vec![("sinkhorn", 1), ("spar-ibp", 1)]);
        assert!((m.log_escalation_rate - 1.0).abs() < 1e-12);
    }

    #[test]
    fn barycenter_backend_override_is_honored_and_not_counted() {
        let service = DistanceService::start(CoordinatorConfig {
            workers: 2,
            ..Default::default()
        });
        let results = service
            .submit_all_barycenters(vec![
                bary_job(0, Method::SparIbp, 0.01, None),
                bary_job(1, Method::SparIbp, 0.01, Some(ScalingBackend::LogDomain)),
            ])
            .unwrap();
        assert!(results.iter().all(|r| r.error.is_none()), "{results:?}");
        assert_eq!(results[0].backend, Some(BackendKind::Multiplicative));
        assert_eq!(results[1].backend, Some(BackendKind::LogDomain));
        let m = service.shutdown();
        // The forced-log job pinned the engine itself: no escalation.
        assert!(m.log_escalations.is_empty(), "{:?}", m.log_escalations);
    }

    #[test]
    fn ot_only_methods_report_errors_per_job() {
        // Greenkhorn is balanced-OT-only: a WFR (unbalanced) job comes
        // back with the registry's error instead of wedging the service.
        let service = DistanceService::start(CoordinatorConfig::default());
        let results = service
            .submit_all(vec![job(0, Method::Greenkhorn, 20), job(1, Method::SparSink, 20)])
            .unwrap();
        assert!(results[0].error.is_some());
        assert!(results[1].error.is_none());
        let m = service.shutdown();
        assert_eq!(m.failed, 1);
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn failure_is_reported_not_panicked() {
        let service = DistanceService::start(CoordinatorConfig::default());
        // eta so small the kernel is all-zero off-diagonal and masses
        // disjoint -> solver should fail or produce NaN -> error path.
        let bad = DistanceJob {
            id: 0,
            source: Measure::new(vec![vec![0.0, 0.0]], vec![1.0]),
            target: Measure::new(vec![vec![100.0, 100.0]], vec![1.0]),
            method: Method::SparSink,
            spec: ProblemSpec { eta: 0.01, ..Default::default() },
            seed: 1,
        };
        let results = service.submit_all(vec![bad]).unwrap();
        assert!(results[0].error.is_some() || results[0].distance.is_nan() || results[0].distance >= 0.0);
        let m = service.shutdown();
        assert_eq!(m.submitted, 1);
    }

    #[test]
    fn shared_support_pairwise_run_builds_artifacts_once() {
        // The acceptance bar: a pairwise distance-matrix run over >= 10
        // frames on ONE shared support constructs cost/kernel artifacts
        // exactly once per (eta, eps) — every other job is a cache hit.
        let frames = 12;
        let n = 36;
        let support: Arc<Vec<Vec<f64>>> =
            Arc::new((0..n).map(|k| vec![(k % 6) as f64, (k / 6) as f64]).collect());
        let measures: Vec<Measure> = (0..frames)
            .map(|f| {
                let mut rng = Rng::seed_from(500 + f as u64);
                let mut mass: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.05).collect();
                let s: f64 = mass.iter().sum();
                mass.iter_mut().for_each(|x| *x /= s);
                Measure { points: support.clone(), mass: Arc::new(mass) }
            })
            .collect();
        let mut jobs = Vec::new();
        let mut id = 0u64;
        for i in 0..frames {
            for j in (i + 1)..frames {
                jobs.push(DistanceJob {
                    id,
                    source: measures[i].clone(),
                    target: measures[j].clone(),
                    method: Method::SparSink,
                    spec: ProblemSpec { eta: 3.0, eps: 0.05, ..Default::default() },
                    seed: 100 + id,
                });
                id += 1;
            }
        }
        let total = jobs.len() as u64; // 66 pairs
        let service = DistanceService::start(CoordinatorConfig {
            workers: 4,
            ..Default::default()
        });
        let results = service.submit_all(jobs).unwrap();
        for r in &results {
            assert!(r.error.is_none(), "job {}: {:?}", r.id, r.error);
            assert!(r.distance.is_finite() && r.distance >= 0.0);
        }
        let m = service.shutdown();
        assert_eq!(m.completed, total);
        assert_eq!(m.cache.misses, 1, "one build per (support, eta, eps): {:?}", m.cache);
        assert_eq!(m.cache.hits, total - 1, "{:?}", m.cache);
        assert_eq!(m.cache.evictions, 0);
        assert_eq!(m.cache.entries, 1);
        assert!(m.cache.bytes > 0 && m.cache.bytes <= m.cache.byte_budget);
        assert!(m.render().contains("artifact cache"));
    }

    #[test]
    fn distinct_eps_builds_distinct_artifacts() {
        // Two (eta, eps) combos over one support: exactly two misses.
        let n = 25;
        let support: Arc<Vec<Vec<f64>>> =
            Arc::new((0..n).map(|k| vec![(k % 5) as f64, (k / 5) as f64]).collect());
        let measure = |seed: u64| {
            let mut rng = Rng::seed_from(seed);
            let mut mass: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.05).collect();
            let s: f64 = mass.iter().sum();
            mass.iter_mut().for_each(|x| *x /= s);
            Measure { points: support.clone(), mass: Arc::new(mass) }
        };
        let mut jobs = Vec::new();
        for (id, eps) in [(0u64, 0.05), (1, 0.05), (2, 0.1), (3, 0.1)] {
            jobs.push(DistanceJob {
                id,
                source: measure(10 + id),
                target: measure(20 + id),
                method: Method::SparSink,
                spec: ProblemSpec { eta: 3.0, eps, ..Default::default() },
                seed: id,
            });
        }
        let service = DistanceService::start(CoordinatorConfig {
            workers: 1,
            ..Default::default()
        });
        let results = service.submit_all(jobs).unwrap();
        assert!(results.iter().all(|r| r.error.is_none()), "{results:?}");
        let m = service.shutdown();
        assert_eq!(m.cache.misses, 2, "{:?}", m.cache);
        assert_eq!(m.cache.hits, 2, "{:?}", m.cache);
    }

    #[test]
    fn barycenter_jobs_share_support_artifacts() {
        // Several barycenter jobs on one support: one artifact build.
        let service = DistanceService::start(CoordinatorConfig {
            workers: 2,
            ..Default::default()
        });
        let results = service
            .submit_all_barycenters(vec![
                bary_job(0, Method::SparIbp, 0.01, None),
                bary_job(1, Method::SparIbp, 0.01, None),
                bary_job(2, Method::Sinkhorn, 0.01, None),
            ])
            .unwrap();
        assert!(results.iter().all(|r| r.error.is_none()), "{results:?}");
        let m = service.shutdown();
        assert_eq!(m.cache.misses, 1, "{:?}", m.cache);
        assert_eq!(m.cache.hits, 2, "{:?}", m.cache);
    }

    #[test]
    fn metrics_track_latency() {
        let service = DistanceService::start(CoordinatorConfig::default());
        let jobs: Vec<DistanceJob> = (0..4).map(|i| job(i, Method::RandSink, 30)).collect();
        service.submit_all(jobs).unwrap();
        let m = service.metrics();
        assert!(m.mean_latency > Duration::ZERO);
        assert!(m.p99_latency >= m.p50_latency);
        assert!(m.throughput > 0.0);
        assert!(!m.render().is_empty());
    }

    #[test]
    fn zero_knobs_resolve_to_available_parallelism() {
        let par = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let cfg = CoordinatorConfig { workers: 0, shards: 0, ..Default::default() };
        assert_eq!(cfg.resolved_workers(), par);
        assert_eq!(cfg.resolved_shards(), par);
        // Explicit knobs pass through…
        let cfg = CoordinatorConfig { workers: 3, shards: 2, ..Default::default() };
        assert_eq!(cfg.resolved_workers(), 3);
        assert_eq!(cfg.resolved_shards(), 2);
        // …but shards clamp to the worker count: a shard with no worker
        // would strand its queue when stealing is off.
        let cfg = CoordinatorConfig { workers: 2, shards: 8, ..Default::default() };
        assert_eq!(cfg.resolved_shards(), 2);
    }

    #[test]
    fn zero_worker_config_starts_and_completes_jobs() {
        let service = DistanceService::start(CoordinatorConfig {
            workers: 0,
            shards: 0,
            ..Default::default()
        });
        let results =
            service.submit_all((0..4).map(|i| job(i, Method::SparSink, 30)).collect()).unwrap();
        assert!(results.iter().all(|r| r.error.is_none()), "{results:?}");
        let m = service.shutdown();
        assert_eq!(m.completed, 4);
        let cfg = CoordinatorConfig { workers: 0, shards: 0, ..Default::default() };
        assert_eq!(m.shards.len(), cfg.resolved_shards());
    }

    #[test]
    fn sharded_run_attributes_per_shard_counters_that_sum_to_globals() {
        for steal in [true, false] {
            let service = DistanceService::start(CoordinatorConfig {
                workers: 4,
                shards: 4,
                steal,
                ..Default::default()
            });
            let jobs: Vec<DistanceJob> = (0..12).map(|i| job(i, Method::SparSink, 40)).collect();
            let results = service.submit_all(jobs).unwrap();
            assert!(results.iter().all(|r| r.error.is_none()), "{results:?}");
            let m = service.shutdown();
            assert_eq!(m.shards.len(), 4);
            let completed: u64 = m.shards.iter().map(|s| s.completed).sum();
            let failed: u64 = m.shards.iter().map(|s| s.failed).sum();
            let routed: u64 = m.shards.iter().map(|s| s.routed).sum();
            assert_eq!(completed, m.completed, "steal={steal}");
            assert_eq!(failed, m.failed, "steal={steal}");
            assert_eq!(routed, m.batches, "steal={steal}");
            assert!(m.shards.iter().all(|s| s.depth == 0), "drained: {:?}", m.shards);
            // Every stolen batch is debited from some shard's queue.
            let stolen: u64 = m.shards.iter().map(|s| s.stolen).sum();
            let stolen_from: u64 = m.shards.iter().map(|s| s.stolen_from).sum();
            assert_eq!(stolen, stolen_from, "steal={steal}");
            if !steal {
                assert_eq!(stolen, 0);
            }
        }
    }

    #[test]
    fn submission_after_drain_fails_loudly_without_blocking_or_panicking() {
        let service = DistanceService::start(CoordinatorConfig {
            workers: 1,
            ..Default::default()
        });
        // A job accepted before the drain completes normally…
        let rx = service.submit(job(0, Method::SparSink, 30)).unwrap();
        assert!(!service.is_draining());
        service.begin_drain();
        assert!(service.is_draining());
        // …while every post-drain submission — blocking and
        // non-blocking, both job shapes — is refused loudly. A hang
        // here would time the test out; a panic would fail it.
        let err = service.submit(job(1, Method::SparSink, 30)).err().expect("must refuse");
        assert!(err.to_string().contains("draining"), "{err}");
        let err = service
            .submit_barycenter(bary_job(2, Method::SparIbp, 0.01, None))
            .err()
            .expect("must refuse");
        assert!(err.to_string().contains("draining"), "{err}");
        assert_eq!(
            service.try_submit(job(3, Method::SparSink, 30)).err(),
            Some(SubmitRejection::Stopped)
        );
        assert_eq!(
            service.try_submit_barycenter(bary_job(4, Method::SparIbp, 0.01, None)).err(),
            Some(SubmitRejection::Stopped)
        );
        // The in-flight job still delivers its result through the
        // drain, and only it was ever counted as submitted.
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        let m = service.shutdown();
        assert_eq!(m.submitted, 1);
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn submission_after_shutdown_path_is_the_same_loud_error() {
        // `Drop`/`shutdown` route through the same stopping flag: a
        // service whose threads are being stopped behaves exactly like
        // a drained one (this used to hit the closed channel instead).
        let service = DistanceService::start(CoordinatorConfig {
            workers: 1,
            ..Default::default()
        });
        service.begin_drain();
        let rejection =
            service.try_submit(job(0, Method::SparSink, 20)).err().expect("must refuse");
        assert_eq!(rejection, SubmitRejection::Stopped);
        // The Error conversion used by blocking callers carries the
        // same human-readable reason.
        assert!(
            Error::from(rejection).to_string().contains("draining or stopped"),
            "{}",
            Error::from(rejection)
        );
        let m = service.shutdown();
        assert_eq!(m.submitted, 0);
    }

    #[test]
    fn try_submit_refuses_busy_when_queue_cap_is_saturated() {
        // Stalled-worker fixture: one worker, every queue bound at 1
        // batch, and jobs slow enough (δ = 0 keeps dense Sinkhorn
        // iterating) that a burst outruns the pipeline. Total capacity
        // is a handful of jobs (submission channel + batcher in hand +
        // shard queue + the one executing), so a fast burst of 64 MUST
        // see `Busy` — and must never block doing so.
        let service = DistanceService::start(CoordinatorConfig {
            workers: 1,
            shards: 1,
            queue_cap: 1,
            max_batch: 1,
            batch_window: Duration::from_millis(1),
            ..Default::default()
        });
        let slow = |id: u64| DistanceJob {
            id,
            source: toy_measure(64, 301 + id, 1.0),
            target: toy_measure(64, 401 + id, 1.2),
            method: Method::Sinkhorn,
            spec: ProblemSpec {
                eta: 3.0,
                eps: 0.05,
                delta: 0.0,
                max_iters: 20_000,
                ..Default::default()
            },
            seed: id,
        };
        let mut accepted = Vec::new();
        let mut saw_busy = false;
        for id in 0..64 {
            match service.try_submit(slow(id)) {
                Ok(rx) => accepted.push(rx),
                Err(SubmitRejection::Busy) => {
                    saw_busy = true;
                    break;
                }
                Err(SubmitRejection::Stopped) => panic!("service is running"),
            }
        }
        assert!(saw_busy, "a 64-job burst must saturate a capacity-1 pipeline");
        assert!(!accepted.is_empty(), "the first try_submit lands in the empty queue");
        // Backpressure refused the burst without wedging anything:
        // every accepted job still completes.
        for rx in accepted {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
        }
        let m = service.shutdown();
        assert_eq!(m.failed, 0);
        assert_eq!(m.submitted, m.completed);
    }
}
