//! Thread-count determinism wall for the parallelized dense builders:
//! with `SPAR_SINK_THREADS=1` versus the default worker count, the
//! chunked row loops in `ot::cost` (squared-Euclidean cost, WFR cost,
//! Gibbs kernel) and the artifact construction built on them must
//! produce bit-identical matrices — each entry is an independent
//! function of its index, and this wall keeps accidental
//! accumulation-order dependence from creeping in.
//!
//! The coordinator leg repeats the wall one level up: a full sharded
//! service run (explicit workers + shards, so the pool topology itself
//! is env-independent) must return bitwise-identical results at every
//! builder thread count.
//!
//! Lives in its own integration binary because it mutates the
//! `SPAR_SINK_THREADS` process environment; case counts scale with
//! `PROPTEST_CASES`.

use spar_sink::coordinator::{
    CoordinatorConfig, DistanceJob, DistanceService, Measure, Method, ProblemSpec,
};
use spar_sink::engine::{CostArtifacts, FormulationKey};
use spar_sink::linalg::Mat;
use spar_sink::ot::cost::{
    euclidean, gibbs_kernel, sq_euclidean, sq_euclidean_cost, wfr_cost, wfr_cost_from_distance,
    TILE_COLS, TILE_ROWS,
};
use spar_sink::rng::Rng;

const CASES: usize = 12;

fn cases() -> usize {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(CASES)
}

fn assert_same_bits(tag: &str, a: &Mat, b: &Mat) {
    assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()), "{tag}: shape");
    for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: {x} vs {y}");
    }
}

/// One test function (not several) so the env-var mutation cannot race
/// against a sibling test in this binary.
#[test]
fn parallel_builders_are_thread_count_invariant() {
    let mut master = Rng::seed_from(0x7D_0001);
    for case in 0..cases() {
        let seed = master.next_u64();
        let mut rng = Rng::seed_from(seed);
        let n = 8 + rng.gen_range(40);
        let m = 8 + rng.gen_range(40);
        let d = 1 + rng.gen_range(3);
        let xs: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| rng.uniform() * 3.0).collect()).collect();
        let ys: Vec<Vec<f64>> =
            (0..m).map(|_| (0..d).map(|_| rng.uniform() * 3.0).collect()).collect();
        let eta = 0.3 + rng.uniform() * 2.0;
        let eps = 0.01 + rng.uniform() * 0.2;
        let lambda = 0.5 + rng.uniform();

        let build = || {
            let sq = sq_euclidean_cost(&xs, &ys);
            let wfr = wfr_cost(&xs, &ys, eta);
            let gibbs = gibbs_kernel(&wfr, eps);
            let arts = CostArtifacts::for_wfr_supports(
                &xs,
                &ys,
                eta,
                eps,
                FormulationKey::unbalanced(lambda),
            );
            (sq, wfr, gibbs, arts)
        };

        // Serial reference…
        std::env::set_var("SPAR_SINK_THREADS", "1");
        let (sq1, wfr1, gibbs1, arts1) = build();
        // …a forced odd worker count (uneven chunk boundaries)…
        std::env::set_var("SPAR_SINK_THREADS", "3");
        let (sq3, wfr3, gibbs3, arts3) = build();
        // …and the default (available parallelism).
        std::env::remove_var("SPAR_SINK_THREADS");
        let (sqd, wfrd, gibbsd, artsd) = build();

        for (tag, other_sq, other_wfr, other_gibbs, other_arts) in [
            ("3 threads", &sq3, &wfr3, &gibbs3, &arts3),
            ("default threads", &sqd, &wfrd, &gibbsd, &artsd),
        ] {
            let tag = format!("case {case} seed {seed} ({tag})");
            assert_same_bits(&format!("{tag}: sq_euclidean_cost"), &sq1, other_sq);
            assert_same_bits(&format!("{tag}: wfr_cost"), &wfr1, other_wfr);
            assert_same_bits(&format!("{tag}: gibbs_kernel"), &gibbs1, other_gibbs);
            assert_same_bits(&format!("{tag}: artifacts.cost"), &arts1.cost, &other_arts.cost);
            assert_same_bits(
                &format!("{tag}: artifacts.kernel"),
                &arts1.kernel,
                &other_arts.kernel,
            );
            assert_eq!(
                arts1.fingerprint(),
                other_arts.fingerprint(),
                "{tag}: fingerprints diverged"
            );
            let f1 = arts1.uot_factor.as_ref().unwrap();
            let f2 = other_arts.uot_factor.as_ref().unwrap();
            for (x, y) in f1.beta_log_kernel.iter().zip(f2.beta_log_kernel.iter()) {
                assert!(
                    x.to_bits() == y.to_bits() || (x.is_nan() && y.is_nan()),
                    "{tag}: uot factor {x} vs {y}"
                );
            }
        }
    }

    // Tiled-builder leg: the cache-tiled builders must reproduce the
    // scalar `Mat::from_fn` reference — the pre-tiling output — bitwise
    // at every thread count, on the tile-boundary and rectangular
    // shapes where blocking bugs live.
    let tile_shapes = [
        (TILE_ROWS - 1, TILE_COLS - 1),
        (TILE_ROWS, TILE_COLS),
        (TILE_ROWS + 1, TILE_COLS + 1),
        (2 * TILE_ROWS + 5, 9),
        (5, 2 * TILE_COLS + 3),
    ];
    for &(n, m) in &tile_shapes {
        let mut rng = Rng::seed_from(0x7D_0003 ^ (((n as u64) << 16) | m as u64));
        let xs: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform() * 3.0, rng.uniform() * 3.0]).collect();
        let ys: Vec<Vec<f64>> =
            (0..m).map(|_| vec![rng.uniform() * 3.0, rng.uniform() * 3.0]).collect();
        let (eta, eps) = (0.7, 0.05);
        let sq_ref = Mat::from_fn(n, m, |i, j| sq_euclidean(&xs[i], &ys[j]));
        let wfr_ref =
            Mat::from_fn(n, m, |i, j| wfr_cost_from_distance(euclidean(&xs[i], &ys[j]), eta));
        let gibbs_ref = wfr_ref.map(|c| {
            if c.is_infinite() {
                0.0
            } else {
                (-c / eps).exp()
            }
        });
        for threads in [Some("1"), Some("3"), None] {
            match threads {
                Some(t) => std::env::set_var("SPAR_SINK_THREADS", t),
                None => std::env::remove_var("SPAR_SINK_THREADS"),
            }
            let tag = format!("tiled {n}x{m} threads {threads:?}");
            let sq = sq_euclidean_cost(&xs, &ys);
            assert_same_bits(&format!("{tag}: sq_euclidean_cost"), &sq, &sq_ref);
            let wfr = wfr_cost(&xs, &ys, eta);
            assert_same_bits(&format!("{tag}: wfr_cost"), &wfr, &wfr_ref);
            let gibbs = gibbs_kernel(&wfr, eps);
            assert_same_bits(&format!("{tag}: gibbs_kernel"), &gibbs, &gibbs_ref);
        }
    }

    // Coordinator leg: the same wall one level up, through the sharded
    // service. The pool topology is pinned explicitly (workers, shards,
    // deterministic batch composition via max_batch = job count), so
    // the ONLY thing the env var changes is the builder thread count —
    // and the results must not notice.
    let service_run = || -> Vec<(u64, u64, usize)> {
        let mut rng = Rng::seed_from(0x7D_0002);
        let n = 28;
        let support: std::sync::Arc<Vec<Vec<f64>>> = std::sync::Arc::new(
            (0..n).map(|_| vec![rng.uniform() * 3.0, rng.uniform() * 3.0]).collect(),
        );
        let masses: Vec<std::sync::Arc<Vec<f64>>> = (0..4)
            .map(|_| {
                let raw: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.05).collect();
                let s: f64 = raw.iter().sum();
                std::sync::Arc::new(raw.iter().map(|x| x / s).collect())
            })
            .collect();
        let mut jobs = Vec::new();
        let mut id = 0u64;
        for i in 0..masses.len() {
            for j in (i + 1)..masses.len() {
                jobs.push(DistanceJob {
                    id,
                    source: Measure { points: support.clone(), mass: masses[i].clone() },
                    target: Measure { points: support.clone(), mass: masses[j].clone() },
                    method: Method::SparSink,
                    spec: ProblemSpec { eta: 3.0, eps: 0.05, ..Default::default() },
                    seed: 300 + id,
                });
                id += 1;
            }
        }
        let total = jobs.len();
        let service = DistanceService::start(CoordinatorConfig {
            workers: 2,
            shards: 2,
            max_batch: total,
            batch_window: std::time::Duration::from_secs(30),
            ..Default::default()
        });
        let results = service.submit_all(jobs).unwrap();
        results.iter().for_each(|r| assert!(r.error.is_none(), "{:?}", r.error));
        results.into_iter().map(|r| (r.objective.to_bits(), r.batch_id, r.iterations)).collect()
    };
    std::env::set_var("SPAR_SINK_THREADS", "1");
    let serial = service_run();
    std::env::set_var("SPAR_SINK_THREADS", "3");
    let three = service_run();
    std::env::remove_var("SPAR_SINK_THREADS");
    let dflt = service_run();
    assert_eq!(serial, three, "coordinator results depend on builder thread count");
    assert_eq!(serial, dflt, "coordinator results depend on builder thread count");
}
