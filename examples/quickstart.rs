//! Quickstart: approximate an entropic OT distance with Spar-Sink and
//! compare against the exact Sinkhorn solution — one problem, two
//! `SolverSpec`s, both dispatched through `api::solve`.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use spar_sink::api::{self, Method, OtProblem, SolverSpec};
use spar_sink::data::synthetic::{instance, Scenario};
use spar_sink::experiments::common::ot_cost;
use spar_sink::rng::Rng;

fn main() {
    let n = 1000;
    let d = 5;
    let eps = 0.05;
    let mut rng = Rng::seed_from(7);

    // 1. A C1 workload: Gaussian histograms on uniform support (Sec. 5.1),
    //    described once as an OtProblem.
    let inst = instance(Scenario::C1, n, d, 1.0, 1.0, &mut rng);
    let cost = ot_cost(&inst.points);
    let problem = OtProblem::balanced(&cost, inst.a, inst.b, eps);

    // 2. Exact entropic OT via the registered dense Sinkhorn solver.
    let exact = api::solve(&problem, &SolverSpec::new(Method::Sinkhorn)).expect("sinkhorn");

    // 3. Spar-Sink at s = 8·s0(n) — expected O(n log^4 n) sampled entries.
    let spec = SolverSpec::new(Method::SparSink).with_budget(8.0).with_seed(7);
    let approx = api::solve(&problem, &spec).expect("spar-sink");

    println!("n = {n}, d = {d}, eps = {eps}");
    println!("exact  OT_eps = {:>12.6}   ({:?})", exact.objective, exact.wall_time);
    println!(
        "spar   OT_eps = {:>12.6}   ({:?}, backend {:?}, nnz = {} of {})",
        approx.objective,
        approx.wall_time,
        approx.backend.expect("sparse solve reports its engine"),
        approx.nnz().expect("sparse solve reports its sketch size"),
        n * n
    );
    println!(
        "relative error = {:.4}   speedup = {:.1}x",
        (approx.objective - exact.objective).abs() / exact.objective.abs(),
        exact.wall_time.as_secs_f64() / approx.wall_time.as_secs_f64()
    );
}
