//! The solver registry: one [`Solver`] adapter per registered method,
//! looked up by name, all dispatched through [`solve`] /
//! [`solve_with_rng`].
//!
//! The adapters translate an ([`OtProblem`], [`SolverSpec`]) pair into
//! the concrete solver's native entry point, so callers — coordinator,
//! CLI, experiments, examples — never touch per-method argument lists.

use std::time::Instant;

use super::problem::{CostSource, Formulation, OtProblem};
use super::solution::Solution;
use super::spec::SolverSpec;
use crate::engine::{
    self, ArtifactCache, CostArtifacts, Fingerprint, FormulationKey, SHARED_ARTIFACT_ENTRY_CAP,
};
use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::solvers::backend::ScalingBackend;
use crate::solvers::sketch_budget;
use crate::solvers::greenkhorn::{greenkhorn_ot, GreenkhornParams};
use crate::solvers::nys_sink::{nys_sink_ot, nys_sink_uot, NysSinkParams};
use crate::solvers::rand_sink::rand_sink_solve;
use crate::solvers::screenkhorn::{screenkhorn_ot, ScreenkhornParams};
use crate::solvers::spar_ibp::spar_ibp_solve;
use crate::solvers::spar_sink::spar_sink_solve;

/// A registered solver: adapts one method to the unified problem/spec
/// surface.
pub trait Solver: Sync {
    /// Registry key (matches [`super::spec::Method::name`]).
    fn name(&self) -> &'static str;
    /// Solve `problem` per `spec`, drawing randomness from `rng`.
    fn solve(&self, problem: &OtProblem, spec: &SolverSpec, rng: &mut Rng) -> Result<Solution>;
}

fn unsupported(method: &str, problem: &OtProblem) -> Error {
    let formulation = match problem.formulation {
        Formulation::Balanced => "balanced OT",
        Formulation::Unbalanced { .. } => "unbalanced OT",
        Formulation::Barycenter { .. } => "barycenter",
    };
    Error::InvalidParam(format!("{method} does not solve {formulation} problems"))
}

/// Materialize the Gibbs kernel of `problem` (blocked entries → 0).
fn kernel_mat(problem: &OtProblem) -> Mat {
    let eps = problem.eps;
    Mat::from_fn(problem.cost.rows(), problem.cost.cols(), |i, j| {
        problem.cost.kernel_at(i, j, eps)
    })
}

struct SinkhornSolver;

impl Solver for SinkhornSolver {
    fn name(&self) -> &'static str {
        "sinkhorn"
    }

    fn solve(&self, problem: &OtProblem, spec: &SolverSpec, _rng: &mut Rng) -> Result<Solution> {
        let params = spec.sinkhorn_params();
        // All three dense formulations materialize the cost and let the
        // backend derive the Gibbs kernel as −C/ε (see
        // `CostSource::with_log_kernel` for the scope of custom
        // log-kernel oracles — they feed the sparsified samplers, not
        // the dense engines).
        match &problem.formulation {
            Formulation::Balanced => {
                let cost = problem.cost.to_mat();
                let backend = spec.backend.unwrap_or_default();
                let (sol, kind) =
                    backend.dense_ot(&cost, &problem.a, &problem.b, problem.eps, &params)?;
                Ok(Solution::from_sinkhorn(self.name(), sol, Some(kind)))
            }
            Formulation::Unbalanced { lambda } => {
                let cost = problem.cost.to_mat();
                let backend = spec.backend.unwrap_or_default();
                let (sol, kind) = backend.dense_uot(
                    &cost,
                    &problem.a,
                    &problem.b,
                    *lambda,
                    problem.eps,
                    &params,
                )?;
                Ok(Solution::from_sinkhorn(self.name(), sol, Some(kind)))
            }
            Formulation::Barycenter { marginals, weights } => {
                let cost = problem.cost.to_mat();
                let backend = spec.backend.unwrap_or_default();
                let (sol, kind) =
                    backend.dense_ibp(&cost, marginals, weights, problem.eps, &params)?;
                Ok(Solution::from_barycenter(self.name(), sol, Vec::new(), Some(kind)))
            }
        }
    }
}

struct SparSinkSolver;

impl Solver for SparSinkSolver {
    fn name(&self) -> &'static str {
        "spar-sink"
    }

    fn solve(&self, problem: &OtProblem, spec: &SolverSpec, rng: &mut Rng) -> Result<Solution> {
        spar_sink_solve(problem, spec, rng).map(|s| Solution::from_spar(self.name(), s))
    }
}

struct SparSinkLogSolver;

impl Solver for SparSinkLogSolver {
    fn name(&self) -> &'static str {
        "spar-sink-log"
    }

    fn solve(&self, problem: &OtProblem, spec: &SolverSpec, rng: &mut Rng) -> Result<Solution> {
        // This method IS the log-domain pin; a contradictory per-job
        // override must fail loudly rather than be silently dropped.
        if !matches!(spec.backend, None | Some(ScalingBackend::LogDomain)) {
            return Err(Error::InvalidParam(
                "spar-sink-log pins the log-domain engine; use method spar-sink \
                 for a multiplicative or auto backend override"
                    .into(),
            ));
        }
        let spec = spec.clone().with_backend(ScalingBackend::LogDomain);
        spar_sink_solve(problem, &spec, rng).map(|s| Solution::from_spar(self.name(), s))
    }
}

struct RandSinkSolver;

impl Solver for RandSinkSolver {
    fn name(&self) -> &'static str {
        "rand-sink"
    }

    fn solve(&self, problem: &OtProblem, spec: &SolverSpec, rng: &mut Rng) -> Result<Solution> {
        rand_sink_solve(problem, spec, rng).map(|s| Solution::from_spar(self.name(), s))
    }
}

struct NysSinkSolver;

impl Solver for NysSinkSolver {
    fn name(&self) -> &'static str {
        "nys-sink"
    }

    fn solve(&self, problem: &OtProblem, spec: &SolverSpec, rng: &mut Rng) -> Result<Solution> {
        let (a, b, eps) = (&problem.a[..], &problem.b[..], problem.eps);
        // Matched-budget rank r = ceil(s / max(n, m)): the paper's
        // protocol for comparing at equal sampled-entry budgets, with
        // `s` resolved through the crate-wide `sketch_budget`
        // convention (identical to the historical s₀(n)/n on the
        // square supports the paper evaluates).
        let dim = a.len().max(b.len()).max(1);
        let rank = spec.rank.unwrap_or_else(|| {
            ((sketch_budget(spec.s_multiplier, a.len(), b.len()) / dim as f64).ceil() as usize)
                .max(1)
        });
        let params = NysSinkParams {
            sinkhorn: spec.sinkhorn_params(),
            robust_clip: spec.robust_clip,
            ..Default::default()
        };
        let kernel = |i: usize, j: usize| problem.cost.kernel_at(i, j, eps);
        let cost = |i: usize, j: usize| problem.cost.cost_at(i, j);
        let sol = match &problem.formulation {
            Formulation::Balanced => nys_sink_ot(kernel, cost, a, b, eps, rank, &params, rng)?,
            Formulation::Unbalanced { lambda } => {
                nys_sink_uot(kernel, cost, a, b, *lambda, eps, rank, &params, rng)?
            }
            Formulation::Barycenter { .. } => return Err(unsupported(self.name(), problem)),
        };
        Ok(Solution::from_sinkhorn(self.name(), sol, None))
    }
}

struct GreenkhornSolver;

impl Solver for GreenkhornSolver {
    fn name(&self) -> &'static str {
        "greenkhorn"
    }

    fn solve(&self, problem: &OtProblem, spec: &SolverSpec, _rng: &mut Rng) -> Result<Solution> {
        let Formulation::Balanced = &problem.formulation else {
            return Err(unsupported(self.name(), problem));
        };
        let cost = problem.cost.to_mat();
        let kernel = kernel_mat(problem);
        let params = GreenkhornParams {
            delta: spec.delta,
            max_updates_factor: spec.max_updates_factor,
        };
        greenkhorn_ot(&kernel, &cost, &problem.a, &problem.b, problem.eps, &params)
            .map(|s| Solution::from_sinkhorn(self.name(), s, None))
    }
}

struct ScreenkhornSolver;

impl Solver for ScreenkhornSolver {
    fn name(&self) -> &'static str {
        "screenkhorn"
    }

    fn solve(&self, problem: &OtProblem, spec: &SolverSpec, _rng: &mut Rng) -> Result<Solution> {
        let Formulation::Balanced = &problem.formulation else {
            return Err(unsupported(self.name(), problem));
        };
        let cost = problem.cost.to_mat();
        let kernel = kernel_mat(problem);
        let params = ScreenkhornParams {
            sinkhorn: spec.sinkhorn_params(),
            decimation: spec.decimation,
        };
        screenkhorn_ot(&kernel, &cost, &problem.a, &problem.b, problem.eps, &params)
            .map(|s| Solution::from_sinkhorn(self.name(), s, None))
    }
}

struct SparIbpSolver;

impl Solver for SparIbpSolver {
    fn name(&self) -> &'static str {
        "spar-ibp"
    }

    fn solve(&self, problem: &OtProblem, spec: &SolverSpec, rng: &mut Rng) -> Result<Solution> {
        if !matches!(problem.formulation, Formulation::Barycenter { .. }) {
            return Err(unsupported(self.name(), problem));
        }
        let sol = spar_ibp_solve(problem, spec, rng)?;
        Ok(Solution::from_barycenter(
            self.name(),
            sol.solution,
            sol.stats,
            Some(sol.backend),
        ))
    }
}

/// The static solver registry, in [`super::spec::Method::ALL`] order.
static REGISTRY: &[&dyn Solver] = &[
    &SinkhornSolver,
    &SparSinkSolver,
    &SparSinkLogSolver,
    &RandSinkSolver,
    &NysSinkSolver,
    &GreenkhornSolver,
    &ScreenkhornSolver,
    &SparIbpSolver,
];

/// All registered solvers.
pub fn registry() -> &'static [&'static dyn Solver] {
    REGISTRY
}

/// Look a solver up by registry name (see [`super::spec::Method::name`]).
pub fn lookup(name: &str) -> Option<&'static dyn Solver> {
    REGISTRY.iter().copied().find(|s| s.name() == name)
}

/// Solve `problem` per `spec`, seeding the solver's RNG from
/// [`SolverSpec::seed`]. This is THE entry point: the coordinator, CLI,
/// experiments, and examples all dispatch through it.
pub fn solve(problem: &OtProblem, spec: &SolverSpec) -> Result<Solution> {
    let mut rng = Rng::seed_from(spec.seed);
    solve_with_rng(problem, spec, &mut rng)
}

/// The artifact-fingerprint component of a formulation (λ enters
/// bit-exactly for unbalanced problems — the cost-dependent sampling
/// factor depends on it).
pub fn formulation_key(formulation: &Formulation) -> FormulationKey {
    match formulation {
        Formulation::Balanced => FormulationKey::Balanced,
        Formulation::Unbalanced { lambda } => FormulationKey::unbalanced(*lambda),
        Formulation::Barycenter { .. } => FormulationKey::Barycenter,
    }
}

/// Upgrade a dense-cost problem to a [`CostSource::Shared`] handle via
/// `cache`, so repeated solves on one cost reuse a single
/// kernel/factor materialization. Square AND rectangular dense costs
/// upgrade — every sketch solver resolves its budget through the one
/// [`sketch_budget`](crate::solvers::sketch_budget) convention
/// `s₀(max(n, m))` in every cost arm, so the upgrade is
/// bitwise-invisible regardless of shape. Pass-through cases (problem
/// returned unchanged): oracle sources (un-fingerprintable without
/// materializing), already-shared problems, and grids beyond
/// [`SHARED_ARTIFACT_ENTRY_CAP`].
pub fn share_via_cache(problem: &OtProblem, cache: &ArtifactCache) -> OtProblem {
    share_with_memo(problem, cache, &mut Vec::new())
}

/// Per-batch fingerprint memo entry: Arc identity × ε bits ×
/// formulation key. Pointer identity is safe here because the memo
/// never outlives the `problems` slice that keeps the Arcs alive.
type FingerprintMemo = Vec<(*const Mat, u64, FormulationKey, Fingerprint)>;

fn share_with_memo(
    problem: &OtProblem,
    cache: &ArtifactCache,
    memo: &mut FingerprintMemo,
) -> OtProblem {
    let CostSource::Dense(cost) = &problem.cost else {
        return problem.clone();
    };
    let (rows, cols) = (cost.rows(), cost.cols());
    if rows * cols > SHARED_ARTIFACT_ENTRY_CAP || rows * cols == 0 {
        return problem.clone();
    }
    let key = formulation_key(&problem.formulation);
    let eps = problem.eps;
    // Batches typically clone ONE cost Arc across slots: hash its
    // contents once per (allocation, ε, formulation), not per slot.
    let ptr = std::sync::Arc::as_ptr(cost);
    let fingerprint = match memo
        .iter()
        .find(|(p, e, k, _)| *p == ptr && *e == eps.to_bits() && *k == key)
    {
        Some((_, _, _, fp)) => *fp,
        None => {
            let fp = Fingerprint::for_dense(cost, eps, key);
            memo.push((ptr, eps.to_bits(), key, fp));
            fp
        }
    };
    let handle =
        cache.get_or_build(fingerprint, || CostArtifacts::from_dense(cost.clone(), eps, key));
    let mut shared = problem.clone();
    shared.cost = CostSource::Shared(handle);
    shared
}

/// Solve a batch of problems through the process-global
/// [`ArtifactCache`](crate::engine::ArtifactCache): dense costs —
/// square and rectangular alike — are upgraded to shared artifacts
/// (content-addressed, so problems on one support build the
/// kernel-side work exactly once per (η, ε, formulation); see
/// [`share_via_cache`] for the pass-through cases), then each problem
/// dispatches through [`solve`].
///
/// Problem `i` is seeded with `spec.seed + i` (wrapping), so a batch of
/// N clones of one problem is an N-replicate sweep and
/// `solve_batch(&[p], spec)[0]` is bitwise-identical to
/// `solve(&p, spec)`. Per-problem failures come back as per-slot `Err`
/// without failing the batch.
pub fn solve_batch(problems: &[OtProblem], spec: &SolverSpec) -> Vec<Result<Solution>> {
    solve_batch_with_cache(problems, spec, engine::global_cache())
}

/// [`solve_batch`] against a caller-owned cache (isolated counters —
/// what the tests and benches use).
pub fn solve_batch_with_cache(
    problems: &[OtProblem],
    spec: &SolverSpec,
    cache: &ArtifactCache,
) -> Vec<Result<Solution>> {
    let mut memo: FingerprintMemo = Vec::new();
    problems
        .iter()
        .enumerate()
        .map(|(i, problem)| {
            let shared = share_with_memo(problem, cache, &mut memo);
            let spec_i = spec.clone().with_seed(spec.seed.wrapping_add(i as u64));
            solve(&shared, &spec_i)
        })
        .collect()
}

/// [`solve`] with an external RNG — for replication sweeps that thread
/// one generator across many solves (each draw advances the stream).
pub fn solve_with_rng(
    problem: &OtProblem,
    spec: &SolverSpec,
    rng: &mut Rng,
) -> Result<Solution> {
    problem.validate()?;
    let solver = lookup(spec.method.name()).ok_or_else(|| {
        Error::InvalidParam(format!("no registered solver named '{}'", spec.method.name()))
    })?;
    let t0 = Instant::now();
    let mut solution = solver.solve(problem, spec, rng)?;
    solution.wall_time = t0.elapsed();
    Ok(solution)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Method;
    use crate::ot::cost::sq_euclidean_cost;
    use crate::solvers::backend::BackendKind;

    fn toy_problem(n: usize) -> OtProblem {
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|i| vec![(i as f64 * 0.618).fract(), (i as f64 * 0.383).fract()])
            .collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        let a = vec![1.0 / n as f64; n];
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 3) as f64).collect();
        let sb: f64 = b.iter().sum();
        let b: Vec<f64> = b.iter().map(|x| x / sb).collect();
        OtProblem::balanced(cost, a, b, 0.1)
    }

    #[test]
    fn every_method_variant_resolves() {
        for method in Method::ALL {
            let solver = lookup(method.name());
            assert!(solver.is_some(), "no solver registered for {method:?}");
            assert_eq!(solver.unwrap().name(), method.name());
        }
        assert_eq!(registry().len(), Method::ALL.len());
        assert!(lookup("does-not-exist").is_none());
    }

    #[test]
    fn balanced_ot_methods_agree_roughly() {
        let problem = toy_problem(60);
        let exact = solve(&problem, &SolverSpec::new(Method::Sinkhorn)).unwrap();
        assert!(exact.objective.is_finite());
        assert!(exact.wall_time > std::time::Duration::ZERO);
        for method in [Method::SparSink, Method::RandSink, Method::Greenkhorn] {
            let spec = SolverSpec::new(method).with_budget(16.0).with_seed(5);
            let sol = solve(&problem, &spec).unwrap();
            assert!(sol.objective.is_finite(), "{method:?}");
            let rel = (sol.objective - exact.objective).abs() / exact.objective.abs();
            assert!(rel < 1.0, "{method:?}: rel {rel}");
        }
    }

    #[test]
    fn unsupported_formulations_error_cleanly() {
        let problem = toy_problem(20);
        for method in [Method::Greenkhorn, Method::Screenkhorn] {
            let mut p = problem.clone();
            p.formulation = Formulation::Unbalanced { lambda: 1.0 };
            assert!(matches!(
                solve(&p, &SolverSpec::new(method)),
                Err(Error::InvalidParam(_))
            ));
        }
        assert!(matches!(
            solve(&problem, &SolverSpec::new(Method::SparIbp)),
            Err(Error::InvalidParam(_))
        ));
    }

    #[test]
    fn backend_override_is_honored() {
        let problem = toy_problem(40);
        let default = solve(&problem, &SolverSpec::new(Method::SparSink).with_seed(3)).unwrap();
        assert_eq!(default.backend, Some(BackendKind::Multiplicative));
        let forced = solve(
            &problem,
            &SolverSpec::new(Method::SparSink)
                .with_seed(3)
                .with_backend(ScalingBackend::LogDomain),
        )
        .unwrap();
        assert_eq!(forced.backend, Some(BackendKind::LogDomain));
        let via_method =
            solve(&problem, &SolverSpec::new(Method::SparSinkLog).with_seed(3)).unwrap();
        assert_eq!(via_method.backend, Some(BackendKind::LogDomain));
        assert_eq!(via_method.objective.to_bits(), forced.objective.to_bits());
    }

    fn toy_barycenter(n: usize, eps: f64) -> OtProblem {
        let pts: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64 / (n - 1) as f64]).collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        let hist = |mu: f64| -> Vec<f64> {
            let w: Vec<f64> =
                pts.iter().map(|p| (-(p[0] - mu).powi(2) / 0.01).exp() + 1e-4).collect();
            let s: f64 = w.iter().sum();
            w.iter().map(|x| x / s).collect()
        };
        OtProblem::barycenter(cost, vec![hist(0.25), hist(0.75)], vec![0.5, 0.5], eps)
    }

    #[test]
    fn barycenter_through_the_registry() {
        let n = 32;
        let problem = toy_barycenter(n, 0.01);
        let exact = solve(&problem, &SolverSpec::new(Method::Sinkhorn)).unwrap();
        let q = exact.barycenter.as_ref().expect("barycenter histogram");
        assert_eq!(q.len(), n);
        assert!(q.iter().all(|x| x.is_finite() && *x >= 0.0));
        // Moderate ε on the default Auto policy: multiplicative engine,
        // and the barycenter Solution now reports it.
        assert_eq!(exact.backend, Some(BackendKind::Multiplicative));
        let spar = solve(
            &problem,
            &SolverSpec::new(Method::SparIbp).with_budget(40.0).with_seed(11),
        )
        .unwrap();
        assert_eq!(spar.stats.len(), 2);
        assert!(spar.nnz().unwrap() > 0);
        assert!(spar.barycenter.is_some());
        assert_eq!(spar.backend, Some(BackendKind::Multiplicative));
    }

    #[test]
    fn log_domain_override_is_served_for_dense_uot_and_barycenter() {
        // These were hard InvalidParam rejections before the log
        // engines existed; now the override must be ROUTED and reported.
        let mut uot = toy_problem(20);
        uot.formulation = Formulation::Unbalanced { lambda: 1.0 };
        let sol = solve(
            &uot,
            &SolverSpec::new(Method::Sinkhorn).with_backend(ScalingBackend::LogDomain),
        )
        .unwrap();
        assert_eq!(sol.backend, Some(BackendKind::LogDomain));
        assert!(sol.objective.is_finite());

        let bary = toy_barycenter(32, 0.01);
        let sol = solve(
            &bary,
            &SolverSpec::new(Method::Sinkhorn).with_backend(ScalingBackend::LogDomain),
        )
        .unwrap();
        assert_eq!(sol.backend, Some(BackendKind::LogDomain));
        let q = sol.barycenter.as_ref().expect("q");
        let mass: f64 = q.iter().sum();
        assert!((mass - 1.0).abs() < 1e-9, "mass {mass}");

        let sol = solve(
            &bary,
            &SolverSpec::new(Method::SparIbp)
                .with_budget(40.0)
                .with_seed(3)
                .with_backend(ScalingBackend::LogDomain),
        )
        .unwrap();
        assert_eq!(sol.backend, Some(BackendKind::LogDomain));
        assert_eq!(sol.stats.len(), 2);
        assert!(sol.barycenter.is_some());
    }

    #[test]
    fn sub_threshold_eps_auto_routes_barycenter_and_uot_to_log_domain() {
        // The acceptance bar: ε below DEFAULT_LOG_EPS_THRESHOLD, default
        // spec — the multiplicative path used to error or be rejected;
        // now Auto serves the log engine and the result is finite.
        let eps = 5e-4;
        let bary = toy_barycenter(32, eps);
        let exact = solve(&bary, &SolverSpec::new(Method::Sinkhorn)).unwrap();
        assert_eq!(exact.backend, Some(BackendKind::LogDomain));
        let q = exact.barycenter.as_ref().expect("q");
        assert!(q.iter().all(|x| x.is_finite() && *x >= 0.0));
        let mass: f64 = q.iter().sum();
        assert!((mass - 1.0).abs() < 1e-9, "mass {mass}");

        let spar = solve(
            &bary,
            &SolverSpec::new(Method::SparIbp).with_budget(40.0).with_seed(9),
        )
        .unwrap();
        assert_eq!(spar.backend, Some(BackendKind::LogDomain));
        assert!(spar.nnz().unwrap() > 0);
        let q = spar.barycenter.as_ref().expect("q");
        assert!(q.iter().all(|x| x.is_finite() && *x >= 0.0));

        let mut uot = toy_problem(20);
        uot.eps = eps;
        uot.formulation = Formulation::Unbalanced { lambda: 1.0 };
        let sol = solve(&uot, &SolverSpec::new(Method::Sinkhorn)).unwrap();
        assert_eq!(sol.backend, Some(BackendKind::LogDomain));
        assert!(sol.objective.is_finite());
    }
}
