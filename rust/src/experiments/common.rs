//! Shared experiment plumbing: method runners at matched budgets, RMAE
//! sweeps, and result-row helpers.

use crate::linalg::Mat;
use crate::metrics::{mean_sd, s0};
use crate::ot::cost::{gibbs_kernel, sq_euclidean_cost, wfr_cost};
use crate::ot::sinkhorn::{sinkhorn_ot, SinkhornParams};
use crate::ot::uot::sinkhorn_uot;
use crate::rng::Rng;
use crate::solvers::backend::ScalingBackend;
use crate::solvers::nys_sink::{nys_sink_ot, nys_sink_uot, NysSinkParams};
use crate::solvers::rand_sink::{rand_sink_ot, rand_sink_uot};
use crate::solvers::spar_sink::{spar_sink_ot, spar_sink_uot, SparSinkParams};
use crate::util::json::Json;

/// Subsampling-based methods compared in Figs. 2-3 and 8-10, plus the
/// log-domain Spar-Sink variant used by the small-ε harness.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    NysSink,
    RandSink,
    SparSink,
    /// Spar-Sink with the log-domain sparse backend forced on.
    SparSinkLog,
}

impl Method {
    /// The three methods the paper's figures compare.
    pub fn all() -> [Method; 3] {
        [Method::NysSink, Method::RandSink, Method::SparSink]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::NysSink => "nys-sink",
            Method::RandSink => "rand-sink",
            Method::SparSink => "spar-sink",
            Method::SparSinkLog => "spar-sink-log",
        }
    }
}

/// Normalize a cost matrix to max 1 — the standard preprocessing that
/// keeps `exp(-C/eps)` representable down to eps = 1e-3 (C_ij <= c0 is
/// the paper's boundedness assumption; this fixes c0 = 1).
pub fn normalize_cost(cost: &Mat) -> Mat {
    let max = cost
        .as_slice()
        .iter()
        .cloned()
        .filter(|c| c.is_finite())
        .fold(0.0f64, f64::max);
    if max <= 0.0 {
        return cost.clone();
    }
    cost.map(move |c| c / max)
}

/// Build the (normalized) squared-Euclidean cost of an instance.
pub fn ot_cost(points: &[Vec<f64>]) -> Mat {
    normalize_cost(&sq_euclidean_cost(points, points))
}

/// Build the WFR cost at a target kernel density (R1-R3).
pub fn wfr_cost_at_density(points: &[Vec<f64>], density: f64) -> Mat {
    let eta = crate::ot::cost::calibrate_eta(points, points, density, 1e-3);
    wfr_cost(points, points, eta)
}

/// Run one subsampling method on an OT problem at budget `s_mult`·s₀(n);
/// Nys-Sink gets rank r = ceil(s/n) per the paper's matched protocol.
pub fn run_method_ot(
    method: Method,
    cost: &Mat,
    a: &[f64],
    b: &[f64],
    eps: f64,
    s_mult: f64,
    rng: &mut Rng,
) -> crate::error::Result<f64> {
    let n = a.len();
    match method {
        Method::SparSink => spar_sink_ot(cost, a, b, eps, s_mult, &SparSinkParams::default(), rng)
            .map(|s| s.solution.objective),
        Method::SparSinkLog => {
            let params =
                SparSinkParams { backend: ScalingBackend::LogDomain, ..Default::default() };
            spar_sink_ot(cost, a, b, eps, s_mult, &params, rng).map(|s| s.solution.objective)
        }
        Method::RandSink => {
            rand_sink_ot(cost, a, b, eps, s_mult, &SinkhornParams::default(), rng)
                .map(|s| s.solution.objective)
        }
        Method::NysSink => {
            let rank = ((s_mult * s0(n) / n as f64).ceil() as usize).max(1);
            let kernel = gibbs_kernel(cost, eps);
            nys_sink_ot(
                |i, j| kernel.get(i, j),
                |i, j| cost.get(i, j),
                a,
                b,
                eps,
                rank,
                &NysSinkParams::default(),
                rng,
            )
            .map(|s| s.objective)
        }
    }
}

/// Same for UOT (WFR cost).
#[allow(clippy::too_many_arguments)]
pub fn run_method_uot(
    method: Method,
    cost: &Mat,
    a: &[f64],
    b: &[f64],
    lambda: f64,
    eps: f64,
    s_mult: f64,
    rng: &mut Rng,
) -> crate::error::Result<f64> {
    let n = a.len();
    match method {
        Method::SparSink => spar_sink_uot(
            cost,
            a,
            b,
            lambda,
            eps,
            s_mult,
            &SparSinkParams::default(),
            rng,
        )
        .map(|s| s.solution.objective),
        Method::SparSinkLog => {
            let params =
                SparSinkParams { backend: ScalingBackend::LogDomain, ..Default::default() };
            spar_sink_uot(cost, a, b, lambda, eps, s_mult, &params, rng)
                .map(|s| s.solution.objective)
        }
        Method::RandSink => rand_sink_uot(
            cost,
            a,
            b,
            lambda,
            eps,
            s_mult,
            &SinkhornParams::default(),
            rng,
        )
        .map(|s| s.solution.objective),
        Method::NysSink => {
            let rank = ((s_mult * s0(n) / n as f64).ceil() as usize).max(1);
            let kernel = gibbs_kernel_inf(cost, eps);
            nys_sink_uot(
                |i, j| kernel.get(i, j),
                |i, j| cost.get(i, j),
                a,
                b,
                lambda,
                eps,
                rank,
                &NysSinkParams::default(),
                rng,
            )
            .map(|s| s.objective)
        }
    }
}

/// Gibbs kernel that maps infinite costs (WFR truncation) to zero.
pub fn gibbs_kernel_inf(cost: &Mat, eps: f64) -> Mat {
    cost.map(move |c| if c.is_finite() { (-c / eps).exp() } else { 0.0 })
}

/// Exact OT solve (truth for RMAE).
pub fn exact_ot(cost: &Mat, a: &[f64], b: &[f64], eps: f64) -> crate::error::Result<f64> {
    let kernel = gibbs_kernel(cost, eps);
    sinkhorn_ot(&kernel, cost, a, b, eps, &SinkhornParams::default()).map(|s| s.objective)
}

/// Exact OT truth that stays stable at small ε: routes through the
/// backend abstraction — the multiplicative dense solve above the
/// threshold, the dense log-domain solve below it or on failure.
pub fn exact_ot_stable(cost: &Mat, a: &[f64], b: &[f64], eps: f64) -> crate::error::Result<f64> {
    ScalingBackend::default()
        .dense_ot(cost, a, b, eps, &SinkhornParams::default())
        .map(|(s, _)| s.objective)
}

/// Exact UOT solve (truth for RMAE).
pub fn exact_uot(
    cost: &Mat,
    a: &[f64],
    b: &[f64],
    lambda: f64,
    eps: f64,
) -> crate::error::Result<f64> {
    let kernel = gibbs_kernel_inf(cost, eps);
    sinkhorn_uot(&kernel, cost, a, b, lambda, eps, &SinkhornParams::default())
        .map(|s| s.objective)
}

/// RMAE ± se of a method over `reps` independent sketches.
pub fn rmae_over_reps(
    reps: usize,
    truth: f64,
    mut run_once: impl FnMut(&mut Rng) -> crate::error::Result<f64>,
    rng: &mut Rng,
) -> (f64, f64, usize) {
    let mut errs = Vec::with_capacity(reps);
    let mut failures = 0usize;
    for _ in 0..reps {
        match run_once(rng) {
            Ok(est) => errs.push((est - truth).abs() / truth.abs().max(f64::MIN_POSITIVE)),
            Err(_) => failures += 1,
        }
    }
    if errs.is_empty() {
        return (f64::NAN, f64::NAN, failures);
    }
    let (mean, sd) = mean_sd(&errs);
    (mean, sd / (errs.len() as f64).sqrt(), failures)
}

/// A JSON row builder for experiment outputs.
pub fn row(fields: Vec<(&str, Json)>) -> Json {
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{instance, Scenario};

    #[test]
    fn normalize_cost_caps_at_one() {
        let c = Mat::from_fn(3, 3, |i, j| (i * 3 + j) as f64);
        let n = normalize_cost(&c);
        assert!((n.max() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn methods_all_run_on_small_instance() {
        let mut rng = Rng::seed_from(7);
        let inst = instance(Scenario::C1, 120, 5, 1.0, 1.0, &mut rng);
        let cost = ot_cost(&inst.points);
        let truth = exact_ot(&cost, &inst.a, &inst.b, 0.1).unwrap();
        assert!(truth.is_finite());
        for m in Method::all() {
            let est = run_method_ot(m, &cost, &inst.a, &inst.b, 0.1, 8.0, &mut rng).unwrap();
            assert!(est.is_finite(), "{m:?}");
        }
    }

    #[test]
    fn rmae_over_reps_counts_failures() {
        let mut rng = Rng::seed_from(9);
        let mut flip = false;
        let (mean, se, failures) = rmae_over_reps(
            4,
            1.0,
            |_| {
                flip = !flip;
                if flip {
                    Ok(1.1)
                } else {
                    Err(crate::error::Error::Numerical("x".into()))
                }
            },
            &mut rng,
        );
        assert_eq!(failures, 2);
        assert!((mean - 0.1).abs() < 1e-12);
        assert!(se >= 0.0);
    }
}
