//! # Spar-Sink — importance sparsification for the Sinkhorn algorithm
//!
//! Production-quality reproduction of *“Importance Sparsification for
//! Sinkhorn Algorithm”* (Li, Yu, Li & Meng, JMLR 2023) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the solver library behind one stable surface:
//!   describe a problem as an [`api::OtProblem`] (marginals + dense cost
//!   or entry oracles + balanced/unbalanced/barycenter
//!   [`api::Formulation`]), pick a registered method with an
//!   [`api::SolverSpec`], and get an [`api::Solution`] back from
//!   [`api::solve`]. The registry covers exact Sinkhorn/IBP, the paper's
//!   Spar-Sink / Spar-IBP, and every evaluated baseline (Greenkhorn,
//!   Screenkhorn, Nys-Sink ± robust clip, Rand-Sink). Every
//!   formulation — balanced/unbalanced OT and barycenters, dense and
//!   sketched — has both a multiplicative and a log-domain stabilized
//!   engine behind the `ScalingBackend` switch, so small-ε problems
//!   stay solvable across the board. Batched workloads on one support
//!   (the echocardiogram pairwise-distance matrix) route through the
//!   shared-cost artifact [`engine`]: [`engine::CostArtifacts`]
//!   (dense cost, Gibbs kernel + row/col sums + Frobenius norm, the
//!   cost-dependent `β·ln K` factor of the UOT sampling probabilities)
//!   live behind a content-addressed [`engine::ArtifactCache`]
//!   (fingerprint = support hash × η × ε × formulation, byte-budget
//!   LRU, hit/miss/eviction counters), are consumed as
//!   [`api::CostSource::Shared`] handles by the factorized samplers
//!   (cost factor amortized, marginal factor per job), and surface as
//!   [`api::solve_batch`] — warm solves are bitwise-identical to cold
//!   ones. On top sit the batched distance-and-barycenter
//!   [`coordinator`] (whose workers share artifacts through the same
//!   cache and report its gauges in `MetricsSnapshot`), the serve-mode
//!   HTTP gateway ([`net`]: zero-dependency HTTP/1.1 listener with
//!   admission control — full queues answer 429 instead of stalling —
//!   plus a Prometheus `/metrics` endpoint and graceful drain), the
//!   [`experiments`] harness regenerating every figure/table, and
//!   (behind the `xla` feature) the PJRT runtime executing the
//!   AOT-compiled L2/L1 artifacts.
//! * **L2 (python/compile/model.py)** — JAX definition of the fused
//!   Sinkhorn scaling blocks and objectives, lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas tile kernels for the
//!   matvec+scale hot-spot.
//!
//! Python never runs on the request path: `make artifacts` is build-time
//! only and the `repro` binary is self-contained afterwards.
//!
//! ## Quick start
//!
//! Mirrors `examples/quickstart.rs`: one problem, two specs, one
//! `solve` call each.
//!
//! ```
//! use spar_sink::api::{self, Method, OtProblem, SolverSpec};
//! use spar_sink::ot::cost::sq_euclidean_cost;
//! use spar_sink::rng::Rng;
//!
//! let n = 64;
//! let mut rng = Rng::seed_from(7);
//! let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform(), rng.uniform()]).collect();
//! let a = vec![1.0 / n as f64; n];
//! let problem = OtProblem::balanced(sq_euclidean_cost(&pts, &pts), a.clone(), a, 0.05);
//!
//! let exact = api::solve(&problem, &SolverSpec::new(Method::Sinkhorn)).unwrap();
//! let spec = SolverSpec::new(Method::SparSink).with_budget(8.0).with_seed(7);
//! let approx = api::solve(&problem, &spec).unwrap();
//! assert!(exact.objective.is_finite() && approx.objective.is_finite());
//! println!(
//!     "exact {:.6} sparse {:.6}  (backend {:?}, nnz {:?}, {:?})",
//!     exact.objective, approx.objective, approx.backend, approx.nnz(), approx.wall_time
//! );
//! ```
//!
//! The per-paper free functions (`ot::sinkhorn::sinkhorn_ot`,
//! `solvers::spar_sink::spar_sink_ot`, …) remain as thin entry points
//! the registry adapters call into — use them when reproducing an
//! algorithm line-by-line, and `api::solve` for everything else.

#![warn(missing_docs)]
#![warn(clippy::unwrap_used)]
// Tests may unwrap freely: a panic IS the failure report there. The
// allow must come after the warn so it wins under cfg(test); the lib
// target (production code only) still enforces the warning in CI.
#![cfg_attr(test, allow(clippy::unwrap_used))]

pub mod api;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod error;
pub mod experiments;
pub mod linalg;
pub mod lint;
pub mod metrics;
pub mod net;
pub mod ot;
pub mod pool;
pub mod rng;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod solvers;
pub mod sparse;
pub mod util;

pub use error::{Error, Result};
