//! Algorithms 3 & 4 — the Spar-Sink solver: importance-sparsify the
//! kernel with the paper's probabilities (Eqs. 9 / 11), then run the
//! sparse Sinkhorn loop and evaluate the objective over the sketch.
//!
//! Every entry point builds its sketch through the log-kernel samplers,
//! so each sampled entry keeps an exact `ln K̃` even when `exp(−C/ε)`
//! underflows — combined with the [`ScalingBackend`] escalation this
//! makes Spar-Sink return finite objectives at ε orders of magnitude
//! below the multiplicative loop's underflow point.
//!
//! The dense paper-reproduction entry points ([`spar_sink_ot`] /
//! [`spar_sink_uot`]) keep their Algorithm 3/4 signatures; everything
//! else — oracle costs, backend overrides, budget resolution — goes
//! through the [`SolverSpec`]-consuming adapter [`spar_sink_solve`],
//! which is what the [`crate::api`] registry dispatches to.

use super::backend::{BackendKind, ScalingBackend};
use super::sketch_budget;
use crate::api::{CostSource, Formulation, OtProblem, SolverSpec};
use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::ot::sinkhorn::SinkhornParams;
use crate::ot::SinkhornSolution;
use crate::rng::Rng;
use crate::sparse::{
    poisson_sparsify_ot_logk, poisson_sparsify_uot_logk, poisson_sparsify_uot_logk_amortized,
    CsrMatrix, SparsifyStats,
};

/// Parameters for the Spar-Sink estimators.
#[derive(Clone, Debug)]
pub struct SparSinkParams {
    /// Sinkhorn loop parameters (δ, iteration cap).
    pub sinkhorn: SinkhornParams,
    /// Shrinkage θ mixing importance and uniform probabilities
    /// (condition (ii) of Theorem 1); 1.0 = pure importance sampling,
    /// matching the paper's experiments.
    pub shrinkage: f64,
    /// Scaling-loop backend; the default `Auto` escalates to the
    /// stabilized log-domain engine for small ε or on numerical failure
    /// of the multiplicative loop.
    pub backend: ScalingBackend,
}

impl Default for SparSinkParams {
    fn default() -> Self {
        SparSinkParams {
            sinkhorn: SinkhornParams::default(),
            shrinkage: 1.0,
            backend: ScalingBackend::default(),
        }
    }
}

impl SparSinkParams {
    /// Adapter from the unified [`SolverSpec`]: stopping rule, shrinkage
    /// θ, and the backend override (`None` → the `Auto` policy).
    pub fn from_spec(spec: &SolverSpec) -> Self {
        SparSinkParams {
            sinkhorn: spec.sinkhorn_params(),
            shrinkage: spec.shrinkage,
            backend: spec.backend.unwrap_or_default(),
        }
    }
}

/// Solution plus sparsification diagnostics.
#[derive(Clone, Debug)]
pub struct SparSolution {
    /// Objective, scalings, iterations, convergence flag.
    pub solution: SinkhornSolution,
    /// Sparsifier diagnostics (nnz, saturated entries, …).
    pub stats: SparsifyStats,
    /// Which scaling engine actually produced the solution.
    pub backend: BackendKind,
}

/// Scalar inputs of one balanced-OT sketch solve (grouped so the oracle
/// helpers stay within a sane argument count).
struct OtInputs<'a> {
    a: &'a [f64],
    b: &'a [f64],
    eps: f64,
    /// Absolute expected sample budget s.
    s: f64,
}

/// Scalar inputs of one unbalanced-OT sketch solve.
struct UotInputs<'a> {
    a: &'a [f64],
    b: &'a [f64],
    lambda: f64,
    eps: f64,
    s: f64,
}

/// Algorithm 3 (OT) from a LOG-kernel oracle `ln K(i,j)` (−∞ = blocked
/// entry): sampled entries keep exact log-kernel values, so the sketch
/// stays solvable through the log-domain backend at any ε.
fn ot_from_logk_oracle(
    log_kernel: impl Fn(usize, usize) -> f64 + Sync,
    cost: impl Fn(usize, usize) -> f64 + Sync,
    inputs: &OtInputs<'_>,
    params: &SparSinkParams,
    rng: &mut Rng,
) -> Result<SparSolution> {
    let (sketch, stats) = poisson_sparsify_ot_logk(
        log_kernel,
        cost,
        inputs.a,
        inputs.b,
        inputs.s,
        params.shrinkage,
        rng,
    )?;
    solve_sketch_ot(
        &sketch,
        stats,
        inputs.a,
        inputs.b,
        inputs.eps,
        params.backend,
        &params.sinkhorn,
    )
}

/// Algorithm 4 (UOT) from a LOG-kernel oracle: both the Eq. 11 sampling
/// probabilities and the stored sketch values are computed in the log
/// domain, so the pipeline survives full kernel underflow end to end.
fn uot_from_logk_oracle(
    log_kernel: impl Fn(usize, usize) -> f64 + Sync,
    cost: impl Fn(usize, usize) -> f64 + Sync,
    inputs: &UotInputs<'_>,
    params: &SparSinkParams,
    rng: &mut Rng,
) -> Result<SparSolution> {
    let (sketch, stats) = poisson_sparsify_uot_logk(
        log_kernel,
        cost,
        inputs.a,
        inputs.b,
        inputs.lambda,
        inputs.eps,
        inputs.s,
        params.shrinkage,
        rng,
    )?;
    solve_sketch_uot(
        &sketch,
        stats,
        inputs.a,
        inputs.b,
        inputs.lambda,
        inputs.eps,
        params.backend,
        &params.sinkhorn,
    )
}

/// Algorithm 3 (OT) from a dense cost matrix; `s_multiplier` is in
/// units of the crate-wide [`sketch_budget`] convention
/// `s₀(max(n, m))` (the paper sweeps s ∈ {2,4,8,16}·s₀(n) on square
/// supports, where the two conventions coincide). The sketch is built
/// with exact log-kernel values `−C_ij/ε`, so small-ε problems stay
/// solvable through the log-domain backend.
pub fn spar_sink_ot(
    cost: &Mat,
    a: &[f64],
    b: &[f64],
    eps: f64,
    s_multiplier: f64,
    params: &SparSinkParams,
    rng: &mut Rng,
) -> Result<SparSolution> {
    let s = sketch_budget(s_multiplier, a.len(), b.len());
    ot_from_logk_oracle(
        |i, j| crate::ot::cost::log_gibbs_from_cost(cost.get(i, j), eps),
        |i, j| cost.get(i, j),
        &OtInputs { a, b, eps, s },
        params,
        rng,
    )
}

/// Run the sparse OT scaling loop over a sketch on `backend` and attach
/// the sparsification diagnostics — the shared sketch→solution adapter
/// for the whole sparse family (Spar-Sink here, Rand-Sink's uniform
/// sketches too).
pub(crate) fn solve_sketch_ot(
    sketch: &CsrMatrix,
    stats: SparsifyStats,
    a: &[f64],
    b: &[f64],
    eps: f64,
    backend: ScalingBackend,
    sinkhorn: &SinkhornParams,
) -> Result<SparSolution> {
    let (solution, backend) = backend.sparse_ot(sketch, a, b, eps, sinkhorn)?;
    Ok(SparSolution { solution, stats, backend })
}

/// UOT twin of [`solve_sketch_ot`].
// 8 arguments: λ joins the same flat scalar list the sparse kernels use;
// grouping here would just re-wrap what the two call sites immediately
// unwrap.
#[allow(clippy::too_many_arguments)]
pub(crate) fn solve_sketch_uot(
    sketch: &CsrMatrix,
    stats: SparsifyStats,
    a: &[f64],
    b: &[f64],
    lambda: f64,
    eps: f64,
    backend: ScalingBackend,
    sinkhorn: &SinkhornParams,
) -> Result<SparSolution> {
    let (solution, backend) = backend.sparse_uot(sketch, a, b, lambda, eps, sinkhorn)?;
    Ok(SparSolution { solution, stats, backend })
}

/// Algorithm 4 (UOT) from a dense cost matrix; `s_multiplier` in units
/// of the [`sketch_budget`] convention `s₀(max(n, m))`. Routes through
/// the log-kernel pipeline like [`spar_sink_ot`].
// 8 arguments: this is the published Algorithm 4 entry point and its
// parameter list mirrors the paper's; grouping would break the
// reproduction call sites. Everything richer goes through
// `spar_sink_solve`.
#[allow(clippy::too_many_arguments)]
pub fn spar_sink_uot(
    cost: &Mat,
    a: &[f64],
    b: &[f64],
    lambda: f64,
    eps: f64,
    s_multiplier: f64,
    params: &SparSinkParams,
    rng: &mut Rng,
) -> Result<SparSolution> {
    let s = sketch_budget(s_multiplier, a.len(), b.len());
    uot_from_logk_oracle(
        |i, j| crate::ot::cost::log_gibbs_from_cost(cost.get(i, j), eps),
        |i, j| cost.get(i, j),
        &UotInputs { a, b, lambda, eps, s },
        params,
        rng,
    )
}

/// The [`SolverSpec`]-consuming adapter behind the `spar-sink` /
/// `spar-sink-log` registry entries: resolves the budget, picks the
/// log-kernel oracle (caller-provided or derived `−C/ε`), and runs
/// Algorithm 3 or 4 per the problem's [`Formulation`].
///
/// Every cost arm — dense (through the paper entry points above),
/// oracle, and shared-artifact — resolves its budget through the one
/// crate-wide [`sketch_budget`] convention `s₀(max(n, m))`, so the
/// sketch is identical no matter which representation carries the
/// cost. Shared sources additionally consume the
/// amortized cost-dependent UOT sampling factor from their
/// [`CostArtifacts`](crate::engine::CostArtifacts), producing sketches
/// bitwise-identical to the cold path.
pub fn spar_sink_solve(
    problem: &OtProblem,
    spec: &SolverSpec,
    rng: &mut Rng,
) -> Result<SparSolution> {
    let params = SparSinkParams::from_spec(spec);
    let (a, b, eps) = (&problem.a[..], &problem.b[..], problem.eps);
    match (&problem.cost, &problem.formulation) {
        (CostSource::Dense(cost), Formulation::Balanced) => {
            spar_sink_ot(cost, a, b, eps, spec.s_multiplier, &params, rng)
        }
        (CostSource::Dense(cost), Formulation::Unbalanced { lambda }) => {
            spar_sink_uot(cost, a, b, *lambda, eps, spec.s_multiplier, &params, rng)
        }
        (oracle @ CostSource::Oracle { .. }, Formulation::Balanced) => {
            let s = sketch_budget(spec.s_multiplier, a.len(), b.len());
            ot_from_logk_oracle(
                |i, j| oracle.log_kernel_at(i, j, eps),
                |i, j| oracle.cost_at(i, j),
                &OtInputs { a, b, eps, s },
                &params,
                rng,
            )
        }
        (oracle @ CostSource::Oracle { .. }, Formulation::Unbalanced { lambda }) => {
            let s = sketch_budget(spec.s_multiplier, a.len(), b.len());
            uot_from_logk_oracle(
                |i, j| oracle.log_kernel_at(i, j, eps),
                |i, j| oracle.cost_at(i, j),
                &UotInputs { a, b, lambda: *lambda, eps, s },
                &params,
                rng,
            )
        }
        (CostSource::Shared(handle), Formulation::Balanced) => {
            // OT probabilities are purely marginal (Eq. 9); the
            // amortized part is the cached cost matrix itself, read by
            // the lazy per-selected-entry oracles.
            let s = sketch_budget(spec.s_multiplier, a.len(), b.len());
            let arts = handle.artifacts();
            let cmat: &Mat = &arts.cost;
            ot_from_logk_oracle(
                |i, j| crate::ot::cost::log_gibbs_from_cost(cmat.get(i, j), eps),
                |i, j| cmat.get(i, j),
                &OtInputs { a, b, eps, s },
                &params,
                rng,
            )
        }
        (CostSource::Shared(handle), Formulation::Unbalanced { lambda }) => {
            // Consume the precomputed cost-dependent factor β·ln K when
            // it matches this job's (λ, ε) bit-exactly; the remaining
            // per-job work is the O(n + m) marginal factor. Values,
            // RNG stream and sketch are bitwise-identical to the cold
            // oracle path either way.
            let s = sketch_budget(spec.s_multiplier, a.len(), b.len());
            let arts = handle.artifacts();
            let cmat: &Mat = &arts.cost;
            let factor = arts.uot_factor.as_ref().filter(|f| {
                f.lambda.to_bits() == lambda.to_bits() && arts.eps.to_bits() == eps.to_bits()
            });
            if let Some(factor) = factor {
                let (sketch, stats) = poisson_sparsify_uot_logk_amortized(
                    &factor.beta_log_kernel,
                    factor.alpha,
                    |i, j| crate::ot::cost::log_gibbs_from_cost(cmat.get(i, j), eps),
                    |i, j| cmat.get(i, j),
                    a,
                    b,
                    s,
                    params.shrinkage,
                    rng,
                )?;
                solve_sketch_uot(
                    &sketch,
                    stats,
                    a,
                    b,
                    *lambda,
                    eps,
                    params.backend,
                    &params.sinkhorn,
                )
            } else {
                uot_from_logk_oracle(
                    |i, j| crate::ot::cost::log_gibbs_from_cost(cmat.get(i, j), eps),
                    |i, j| cmat.get(i, j),
                    &UotInputs { a, b, lambda: *lambda, eps, s },
                    &params,
                    rng,
                )
            }
        }
        (_, Formulation::Barycenter { .. }) => Err(Error::InvalidParam(
            "spar-sink solves OT/UOT problems; use spar-ibp for barycenters".into(),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::cost::{gibbs_kernel, sq_euclidean_cost, wfr_cost};
    use crate::ot::sinkhorn::sinkhorn_ot;
    use crate::ot::uot::sinkhorn_uot;
    use crate::rng::Rng;

    fn problem(n: usize, seed: u64) -> (Mat, Vec<f64>, Vec<f64>, Vec<Vec<f64>>) {
        let mut rng = Rng::seed_from(seed);
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..3).map(|_| rng.uniform()).collect())
            .collect();
        let cost = sq_euclidean_cost(&pts, &pts);
        let a: Vec<f64> = (0..n).map(|_| rng.normal_ms(1.0 / 3.0, (1.0f64 / 20.0).sqrt()).abs() + 1e-3).collect();
        let sa: f64 = a.iter().sum();
        let b: Vec<f64> = (0..n).map(|_| rng.normal_ms(0.5, (1.0f64 / 20.0).sqrt()).abs() + 1e-3).collect();
        let sb: f64 = b.iter().sum();
        (
            cost,
            a.iter().map(|x| x / sa).collect(),
            b.iter().map(|x| x / sb).collect(),
            pts,
        )
    }

    #[test]
    fn approximates_exact_ot_objective() {
        let n = 200;
        let (cost, a, b, _) = problem(n, 7);
        let eps = 0.1;
        let kernel = gibbs_kernel(&cost, eps);
        let exact = sinkhorn_ot(&kernel, &cost, &a, &b, eps, &SinkhornParams::default()).unwrap();
        let mut rng = Rng::seed_from(1);
        let mut errs = Vec::new();
        for _ in 0..5 {
            let approx =
                spar_sink_ot(&cost, &a, &b, eps, 16.0, &SparSinkParams::default(), &mut rng)
                    .unwrap();
            errs.push((approx.solution.objective - exact.objective).abs() / exact.objective.abs());
        }
        let mean_err = errs.iter().sum::<f64>() / errs.len() as f64;
        // n = 200 is small for the sqrt(n^(3-2a)/s) bound; the
        // fig2 harness at n = 1000 shows the paper-scale errors.
        assert!(mean_err < 0.5, "mean relative error {mean_err}");
    }

    #[test]
    fn error_decreases_with_budget() {
        let n = 200;
        let (cost, a, b, _) = problem(n, 11);
        let eps = 0.1;
        let kernel = gibbs_kernel(&cost, eps);
        let exact = sinkhorn_ot(&kernel, &cost, &a, &b, eps, &SinkhornParams::default()).unwrap();
        let mut rng = Rng::seed_from(3);
        let mut rmae_for = |mult: f64| -> f64 {
            let reps = 8;
            let mut acc = 0.0;
            for _ in 0..reps {
                let approx =
                    spar_sink_ot(&cost, &a, &b, eps, mult, &SparSinkParams::default(), &mut rng)
                        .unwrap();
                acc += (approx.solution.objective - exact.objective).abs()
                    / exact.objective.abs();
            }
            acc / reps as f64
        };
        let small = rmae_for(2.0);
        let large = rmae_for(16.0);
        assert!(large < small, "rmae did not decrease: s=2s0 {small} vs s=16s0 {large}");
    }

    #[test]
    fn uot_wfr_workflow() {
        let n = 150;
        let (_, a, b, pts) = problem(n, 13);
        // Unbalance the masses (5 and 3 as in the paper).
        let a: Vec<f64> = a.iter().map(|x| x * 5.0).collect();
        let b: Vec<f64> = b.iter().map(|x| x * 3.0).collect();
        let eta = crate::ot::cost::calibrate_eta(&pts, &pts, 0.5, 1e-3);
        let cost = wfr_cost(&pts, &pts, eta);
        let (lambda, eps) = (1.0, 0.1);
        let kernel = cost.map(|c| if c.is_infinite() { 0.0 } else { (-c / eps).exp() });
        let exact =
            sinkhorn_uot(&kernel, &cost, &a, &b, lambda, eps, &SinkhornParams::default()).unwrap();
        let mut rng = Rng::seed_from(5);
        let mut errs = Vec::new();
        for _ in 0..5 {
            let approx = spar_sink_uot(
                &cost,
                &a,
                &b,
                lambda,
                eps,
                16.0,
                &SparSinkParams::default(),
                &mut rng,
            )
            .unwrap();
            errs.push((approx.solution.objective - exact.objective).abs() / exact.objective.abs());
        }
        let mean_err = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!(mean_err < 0.9, "mean relative UOT error {mean_err}");
    }

    #[test]
    fn tiny_eps_ot_succeeds_with_default_backend() {
        // ε two orders of magnitude below the multiplicative underflow
        // cliff: the multiplicative backend errors or collapses; the
        // default (Auto) backend routes to the log engine and returns a
        // finite, positive objective.
        let n = 120;
        let (cost, a, b, _) = problem(n, 23);
        let eps = 1e-5;
        let mut rng = Rng::seed_from(71);
        let sol = spar_sink_ot(&cost, &a, &b, eps, 16.0, &SparSinkParams::default(), &mut rng)
            .unwrap();
        assert_eq!(sol.backend, crate::solvers::backend::BackendKind::LogDomain);
        assert!(sol.solution.objective.is_finite());
        assert!(sol.solution.objective > 0.0, "objective {}", sol.solution.objective);
        // The multiplicative backend on the same sketch either errors,
        // stalls, or collapses onto the handful of entries whose kernel
        // survived underflow — a gross underestimate of the transport.
        let mult_params = SparSinkParams {
            backend: crate::solvers::backend::ScalingBackend::Multiplicative,
            ..Default::default()
        };
        let mut rng = Rng::seed_from(71);
        match spar_sink_ot(&cost, &a, &b, eps, 16.0, &mult_params, &mut rng) {
            Err(crate::error::Error::Numerical(_)) => {}
            Err(e) => panic!("unexpected error kind: {e}"),
            Ok(s) => assert!(
                !s.solution.converged || s.solution.objective < 0.5 * sol.solution.objective,
                "multiplicative loop unexpectedly healthy at eps={eps}: {} vs log {}",
                s.solution.objective,
                sol.solution.objective
            ),
        }
    }

    #[test]
    fn tiny_eps_uot_succeeds_with_default_backend() {
        let n = 100;
        let (_, a, b, pts) = problem(n, 29);
        let a: Vec<f64> = a.iter().map(|x| x * 5.0).collect();
        let b: Vec<f64> = b.iter().map(|x| x * 3.0).collect();
        let eta = crate::ot::cost::calibrate_eta(&pts, &pts, 0.5, 1e-3);
        let cost = wfr_cost(&pts, &pts, eta);
        let (lambda, eps) = (1.0, 1e-4);
        let mut rng = Rng::seed_from(37);
        let sol = spar_sink_uot(
            &cost,
            &a,
            &b,
            lambda,
            eps,
            16.0,
            &SparSinkParams::default(),
            &mut rng,
        )
        .unwrap();
        assert_eq!(sol.backend, crate::solvers::backend::BackendKind::LogDomain);
        assert!(sol.solution.objective.is_finite());
        assert!(sol.stats.nnz > 0);
    }

    #[test]
    fn moderate_eps_still_runs_multiplicative() {
        // Above the threshold nothing changes: Auto uses the fast path.
        let n = 150;
        let (cost, a, b, _) = problem(n, 41);
        let mut rng = Rng::seed_from(43);
        let sol = spar_sink_ot(&cost, &a, &b, 0.1, 8.0, &SparSinkParams::default(), &mut rng)
            .unwrap();
        assert_eq!(sol.backend, crate::solvers::backend::BackendKind::Multiplicative);
        assert!(sol.solution.objective.is_finite());
    }

    #[test]
    fn sketch_budget_respected() {
        let n = 300;
        let (cost, a, b, _) = problem(n, 17);
        let mut rng = Rng::seed_from(9);
        let sol = spar_sink_ot(&cost, &a, &b, 0.1, 8.0, &SparSinkParams::default(), &mut rng)
            .unwrap();
        let budget = 8.0 * crate::metrics::s0(n);
        assert!(
            (sol.stats.nnz as f64) < budget * 1.2,
            "nnz {} exceeds budget {budget}",
            sol.stats.nnz
        );
        // Far sparser than dense.
        assert!((sol.stats.nnz as f64) < (n * n) as f64 * 0.5);
    }
}
