//! Appendix Fig. 13 — color transfer: transfer the sunset palette onto
//! the daytime cloud via entropic OT plans computed by Sinkhorn,
//! Nys-Sink and Spar-Sink; report each method's barycentric color-map
//! deviation from the Sinkhorn map plus wall time.

use std::time::Instant;

use super::common::{normalize_cost, row};
use super::{ExperimentOutput, Profile};
use crate::data::images::{barycentric_map, daytime_cloud, sunset_cloud};
use crate::linalg::Mat;
use crate::metrics::s0;
use crate::ot::cost::{gibbs_kernel, sq_euclidean_cost};
use crate::ot::sinkhorn::{sinkhorn_ot, transport_plan, SinkhornParams};
use crate::rng::Rng;
use crate::solvers::nys_sink::{nys_sink_ot, NysSinkParams};
use crate::solvers::spar_sink::{spar_sink_ot, SparSinkParams};
use crate::util::json::Json;
use crate::util::table::{f, Table};

/// Mean RGB deviation between two color maps.
fn map_deviation(a: &[Vec<f64>], b: &[Vec<f64>]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            x.iter()
                .zip(y)
                .map(|(u, v)| (u - v) * (u - v))
                .sum::<f64>()
                .sqrt()
        })
        .sum::<f64>()
        / a.len() as f64
}

pub fn run(profile: Profile) -> ExperimentOutput {
    let n = profile.pick(600, 5000);
    let eps = 1e-2;
    let s_mult = 8.0;
    let mut rng = Rng::seed_from(0xF173);
    let source = daytime_cloud(n, &mut rng);
    let target = sunset_cloud(n, &mut rng);
    let a = vec![1.0 / n as f64; n];
    let b = vec![1.0 / n as f64; n];
    let cost = normalize_cost(&sq_euclidean_cost(&source, &target));
    let kernel = gibbs_kernel(&cost, eps);
    let params = SinkhornParams::default();

    // Reference: full Sinkhorn plan -> barycentric map.
    let t0 = Instant::now();
    let exact = sinkhorn_ot(&kernel, &cost, &a, &b, eps, &params).expect("sinkhorn");
    let sink_secs = t0.elapsed().as_secs_f64();
    let plan = transport_plan(&kernel, &exact.u, &exact.v);
    let ref_map = barycentric_map(
        |i| (0..n).map(|j| (j, plan.get(i, j))).collect(),
        &target,
        n,
    );

    let mut table = Table::new(&["method", "seconds", "map deviation (RGB)"]);
    let mut rows = Vec::new();
    let push = |name: &str, secs: f64, dev: f64, table: &mut Table, rows: &mut Vec<Json>| {
        table.row(vec![name.into(), f(secs, 3), f(dev, 4)]);
        rows.push(row(vec![
            ("method", Json::str(name)),
            ("seconds", Json::num(secs)),
            ("deviation", Json::num(dev)),
        ]));
    };
    push("sinkhorn", sink_secs, 0.0, &mut table, &mut rows);

    // Spar-Sink plan.
    let t0 = Instant::now();
    if let Ok(sol) = spar_sink_ot(&cost, &a, &b, eps, s_mult, &SparSinkParams::default(), &mut rng)
    {
        let secs = t0.elapsed().as_secs_f64();
        // Sparse plan rows from the sketch would need the sketch; use the
        // scalings against the full kernel for the map (the plan the
        // estimator represents).
        let plan_s = Mat::from_fn(n, n, |i, j| sol.solution.u[i] * kernel.get(i, j) * sol.solution.v[j]);
        let map = barycentric_map(|i| (0..n).map(|j| (j, plan_s.get(i, j))).collect(), &target, n);
        push("spar-sink", secs, map_deviation(&ref_map, &map), &mut table, &mut rows);
    }

    // Nys-Sink plan.
    let rank = ((s_mult * s0(n) / n as f64).ceil() as usize).max(1);
    let t0 = Instant::now();
    if let Ok(sol) = nys_sink_ot(
        |i, j| kernel.get(i, j),
        |i, j| cost.get(i, j),
        &a,
        &b,
        eps,
        rank,
        &NysSinkParams::default(),
        &mut rng,
    ) {
        let secs = t0.elapsed().as_secs_f64();
        let plan_s = Mat::from_fn(n, n, |i, j| sol.u[i] * kernel.get(i, j) * sol.v[j]);
        let map = barycentric_map(|i| (0..n).map(|j| (j, plan_s.get(i, j))).collect(), &target, n);
        push("nys-sink", secs, map_deviation(&ref_map, &map), &mut table, &mut rows);
    }

    // Robust-Nys-Sink.
    let t0 = Instant::now();
    if let Ok(sol) = nys_sink_ot(
        |i, j| kernel.get(i, j),
        |i, j| cost.get(i, j),
        &a,
        &b,
        eps,
        rank,
        &NysSinkParams { robust_clip: Some(1e3), ..Default::default() },
        &mut rng,
    ) {
        let secs = t0.elapsed().as_secs_f64();
        let plan_s = Mat::from_fn(n, n, |i, j| sol.u[i] * kernel.get(i, j) * sol.v[j]);
        let map = barycentric_map(|i| (0..n).map(|j| (j, plan_s.get(i, j))).collect(), &target, n);
        push("robust-nyssink", secs, map_deviation(&ref_map, &map), &mut table, &mut rows);
    }

    let text = format!(
        "Appendix Fig. 13 — color transfer (n = {n} RGB samples, eps = {eps}, s = 8 s0(n))\n\
         deviation = mean RGB distance from the Sinkhorn barycentric map\n{}",
        table.render()
    );
    ExperimentOutput { id: "fig13", text, rows: Json::arr(rows) }
}
