//! Problem description for the unified solve surface: what to transport
//! (marginals), over which geometry (a dense cost matrix or entry
//! oracles), and under which formulation (balanced OT, unbalanced OT,
//! or a fixed-support barycenter).

use std::fmt;
use std::sync::Arc;

use crate::engine::CostHandle;
use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::ot::cost::log_gibbs_from_cost;

/// A shared entry oracle `f(i, j)`. `Arc`'d so a problem built from
/// closures stays cheap to clone across coordinator threads.
pub type EntryOracle = Arc<dyn Fn(usize, usize) -> f64 + Send + Sync>;

/// Where the ground cost (and the Gibbs kernel derived from it) comes
/// from.
///
/// Every registered solver accepts both variants: solvers that need a
/// dense matrix (Greenkhorn, Screenkhorn, dense Sinkhorn) materialize an
/// oracle on demand, while the sparsified solvers sample oracles without
/// ever materializing `n × m` entries.
#[derive(Clone)]
pub enum CostSource {
    /// A materialized ground-cost matrix (`∞` entries = blocked
    /// transport, e.g. the WFR truncation).
    Dense(Arc<Mat>),
    /// Entry oracles evaluated on demand.
    Oracle {
        rows: usize,
        cols: usize,
        /// Ground cost `C(i, j)` (may return `∞` for blocked entries).
        cost: EntryOracle,
        /// Optional exact log-kernel `ln K(i, j)` (−∞ = blocked) for the
        /// SAME ε as [`OtProblem::eps`]. When absent it is derived as
        /// `−C(i, j)/ε`, which is exact for Gibbs kernels.
        log_kernel: Option<EntryOracle>,
    },
    /// Shared, cache-resident cost/kernel artifacts
    /// ([`crate::engine::CostArtifacts`]): many problems on one support
    /// consume one materialization — the cost of each query drops from
    /// "rebuild everything" to "reuse + reweight". The artifacts must
    /// be built at the problem's ε ([`OtProblem::validate`] enforces
    /// the bit-match); solutions are bitwise-identical to the
    /// equivalent dense/oracle cold path.
    Shared(CostHandle),
}

impl CostSource {
    /// Build an oracle source from a cost closure.
    pub fn oracle(
        rows: usize,
        cols: usize,
        cost: impl Fn(usize, usize) -> f64 + Send + Sync + 'static,
    ) -> Self {
        CostSource::Oracle { rows, cols, cost: Arc::new(cost), log_kernel: None }
    }

    /// Attach an exact log-kernel oracle (no-op on dense and shared
    /// sources, whose log-kernel is always derived from the stored
    /// cost).
    ///
    /// Scope: the sparsified solvers sample through this oracle entry by
    /// entry. The DENSE engines behind `Method::Sinkhorn` (balanced,
    /// unbalanced and barycenter, multiplicative and log-domain alike)
    /// materialize the cost and derive the Gibbs kernel as `−C/ε` — a
    /// custom log-kernel that differs from `−C/ε` is not consulted on
    /// those paths.
    pub fn with_log_kernel(
        self,
        log_kernel: impl Fn(usize, usize) -> f64 + Send + Sync + 'static,
    ) -> Self {
        match self {
            CostSource::Oracle { rows, cols, cost, .. } => CostSource::Oracle {
                rows,
                cols,
                cost,
                log_kernel: Some(Arc::new(log_kernel)),
            },
            dense_or_shared => dense_or_shared,
        }
    }

    /// Number of source-side support points (cost rows).
    pub fn rows(&self) -> usize {
        match self {
            CostSource::Dense(m) => m.rows(),
            CostSource::Oracle { rows, .. } => *rows,
            CostSource::Shared(h) => h.artifacts().rows(),
        }
    }

    /// Number of target-side support points (cost columns).
    pub fn cols(&self) -> usize {
        match self {
            CostSource::Dense(m) => m.cols(),
            CostSource::Oracle { cols, .. } => *cols,
            CostSource::Shared(h) => h.artifacts().cols(),
        }
    }

    /// Ground cost entry `C(i, j)`.
    #[inline]
    pub fn cost_at(&self, i: usize, j: usize) -> f64 {
        match self {
            CostSource::Dense(m) => m.get(i, j),
            CostSource::Oracle { cost, .. } => cost(i, j),
            CostSource::Shared(h) => h.artifacts().cost.get(i, j),
        }
    }

    /// Log-kernel entry `ln K(i, j)` at regularization `eps` (−∞ =
    /// blocked). Uses the caller-provided oracle when present, else the
    /// exact Gibbs value `−C(i, j)/ε`.
    #[inline]
    pub fn log_kernel_at(&self, i: usize, j: usize, eps: f64) -> f64 {
        match self {
            CostSource::Oracle { log_kernel: Some(lk), .. } => lk(i, j),
            _ => log_gibbs_from_cost(self.cost_at(i, j), eps),
        }
    }

    /// Linear kernel entry `K(i, j) = exp(ln K)` (exactly 0 for blocked
    /// entries). Shared sources serve the materialized kernel directly
    /// when `eps` bit-matches the artifacts' ε (the stored values are
    /// the same `exp(−C/ε)` expression, so this is exact).
    #[inline]
    pub fn kernel_at(&self, i: usize, j: usize, eps: f64) -> f64 {
        if let CostSource::Shared(h) = self {
            let arts = h.artifacts();
            if arts.eps.to_bits() == eps.to_bits() {
                return arts.kernel.get(i, j);
            }
        }
        self.log_kernel_at(i, j, eps).exp()
    }

    /// The dense cost, materializing an oracle (O(rows·cols)); dense
    /// and shared sources are shared, not copied.
    pub fn to_mat(&self) -> Arc<Mat> {
        match self {
            CostSource::Dense(m) => m.clone(),
            CostSource::Oracle { rows, cols, cost, .. } => {
                Arc::new(Mat::from_fn(*rows, *cols, |i, j| cost(i, j)))
            }
            CostSource::Shared(h) => h.artifacts().cost.clone(),
        }
    }

    /// Borrow the dense cost if this source already holds one.
    pub fn as_dense(&self) -> Option<&Mat> {
        match self {
            CostSource::Dense(m) => Some(m),
            CostSource::Oracle { .. } => None,
            CostSource::Shared(h) => Some(&h.artifacts().cost),
        }
    }
}

impl fmt::Debug for CostSource {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CostSource::Dense(m) => {
                write!(f, "CostSource::Dense({}x{})", m.rows(), m.cols())
            }
            CostSource::Oracle { rows, cols, log_kernel, .. } => write!(
                f,
                "CostSource::Oracle({rows}x{cols}, log_kernel: {})",
                if log_kernel.is_some() { "explicit" } else { "derived" }
            ),
            CostSource::Shared(h) => {
                let arts = h.artifacts();
                write!(
                    f,
                    "CostSource::Shared({}x{}, eps {})",
                    arts.rows(),
                    arts.cols(),
                    arts.eps
                )
            }
        }
    }
}

impl From<CostHandle> for CostSource {
    fn from(handle: CostHandle) -> Self {
        CostSource::Shared(handle)
    }
}

impl From<&CostHandle> for CostSource {
    fn from(handle: &CostHandle) -> Self {
        CostSource::Shared(handle.clone())
    }
}

impl From<Mat> for CostSource {
    fn from(m: Mat) -> Self {
        CostSource::Dense(Arc::new(m))
    }
}

impl From<Arc<Mat>> for CostSource {
    fn from(m: Arc<Mat>) -> Self {
        CostSource::Dense(m)
    }
}

impl From<&Arc<Mat>> for CostSource {
    fn from(m: &Arc<Mat>) -> Self {
        CostSource::Dense(m.clone())
    }
}

/// Which entropic transport problem is being solved.
#[derive(Clone, Debug)]
pub enum Formulation {
    /// Balanced entropic OT (Eq. 6): marginals are matched exactly.
    Balanced,
    /// Unbalanced entropic OT (Eq. 10): marginal deviations penalized by
    /// `lambda · KL` (the WFR distance when paired with the WFR cost).
    Unbalanced { lambda: f64 },
    /// Fixed-support Wasserstein barycenter of `marginals` with simplex
    /// `weights` over the (square) cost's shared support; the problem's
    /// `a`/`b` marginals are unused.
    Barycenter { marginals: Vec<Vec<f64>>, weights: Vec<f64> },
}

/// An entropic transport problem: marginals + cost source + formulation
/// + regularization ε. Cheap to clone (all heavy state is `Arc`-shared)
/// and self-contained, so one problem can be solved by several
/// [`SolverSpec`](crate::api::SolverSpec)s for comparison.
#[derive(Clone, Debug)]
pub struct OtProblem {
    /// Where the ground cost / Gibbs kernel comes from.
    pub cost: CostSource,
    /// Source marginal (row masses). Empty for barycenter problems.
    pub a: Arc<Vec<f64>>,
    /// Target marginal (column masses). Empty for barycenter problems.
    pub b: Arc<Vec<f64>>,
    /// Entropic regularization ε.
    pub eps: f64,
    /// Which entropic transport problem is being solved.
    pub formulation: Formulation,
}

impl OtProblem {
    /// Balanced entropic OT between histograms `a` and `b`.
    pub fn balanced(
        cost: impl Into<CostSource>,
        a: impl Into<Arc<Vec<f64>>>,
        b: impl Into<Arc<Vec<f64>>>,
        eps: f64,
    ) -> Self {
        OtProblem {
            cost: cost.into(),
            a: a.into(),
            b: b.into(),
            eps,
            formulation: Formulation::Balanced,
        }
    }

    /// Unbalanced entropic OT with marginal-relaxation strength `lambda`.
    pub fn unbalanced(
        cost: impl Into<CostSource>,
        a: impl Into<Arc<Vec<f64>>>,
        b: impl Into<Arc<Vec<f64>>>,
        lambda: f64,
        eps: f64,
    ) -> Self {
        OtProblem {
            cost: cost.into(),
            a: a.into(),
            b: b.into(),
            eps,
            formulation: Formulation::Unbalanced { lambda },
        }
    }

    /// Fixed-support barycenter of `marginals` (all living on the shared
    /// support of the square `cost`) with simplex `weights`.
    pub fn barycenter(
        cost: impl Into<CostSource>,
        marginals: Vec<Vec<f64>>,
        weights: Vec<f64>,
        eps: f64,
    ) -> Self {
        OtProblem {
            cost: cost.into(),
            a: Arc::new(Vec::new()),
            b: Arc::new(Vec::new()),
            eps,
            formulation: Formulation::Barycenter { marginals, weights },
        }
    }

    /// Structural validation shared by every solver (individual solvers
    /// still run their own numerical checks).
    pub fn validate(&self) -> Result<()> {
        if !(self.eps.is_finite() && self.eps > 0.0) {
            return Err(Error::InvalidParam(format!("eps = {} must be positive", self.eps)));
        }
        if let CostSource::Shared(handle) = &self.cost {
            // The kernel-side artifacts are ε-specific; a mismatched
            // handle would silently serve the wrong kernel statistics.
            let built_at = handle.artifacts().eps;
            if built_at.to_bits() != self.eps.to_bits() {
                return Err(Error::InvalidParam(format!(
                    "shared cost artifacts built at eps = {built_at} cannot serve a \
                     problem at eps = {} (rebuild through the cache)",
                    self.eps
                )));
            }
        }
        let (rows, cols) = (self.cost.rows(), self.cost.cols());
        match &self.formulation {
            Formulation::Balanced | Formulation::Unbalanced { .. } => {
                if self.a.len() != rows || self.b.len() != cols {
                    return Err(Error::Dimension(format!(
                        "cost {rows}x{cols} vs a[{}], b[{}]",
                        self.a.len(),
                        self.b.len()
                    )));
                }
                if let Formulation::Unbalanced { lambda } = self.formulation {
                    if !(lambda.is_finite() && lambda > 0.0) {
                        return Err(Error::InvalidParam(format!(
                            "lambda = {lambda} must be positive"
                        )));
                    }
                }
                Ok(())
            }
            Formulation::Barycenter { marginals, weights } => {
                if rows != cols {
                    return Err(Error::Dimension(format!(
                        "barycenter needs a square shared-support cost, got {rows}x{cols}"
                    )));
                }
                if marginals.is_empty() || marginals.len() != weights.len() {
                    return Err(Error::Dimension(format!(
                        "{} marginals vs {} weights",
                        marginals.len(),
                        weights.len()
                    )));
                }
                if let Some(bad) = marginals.iter().find(|m| m.len() != cols) {
                    return Err(Error::Dimension(format!(
                        "marginal of length {} on a support of size {cols}",
                        bad.len()
                    )));
                }
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_log_kernel_defaults_to_gibbs() {
        let src = CostSource::oracle(2, 2, |i, j| (i + j) as f64);
        let eps = 0.5;
        assert_eq!(src.log_kernel_at(0, 1, eps), -1.0 / eps);
        assert_eq!(src.kernel_at(0, 1, eps), (-1.0f64 / eps).exp());
        let src = src.with_log_kernel(|_, _| -3.0);
        assert_eq!(src.log_kernel_at(0, 1, eps), -3.0);
    }

    #[test]
    fn dense_source_shares_storage() {
        let m = Arc::new(Mat::from_fn(3, 3, |i, j| (i * 3 + j) as f64));
        let src = CostSource::from(&m);
        assert!(Arc::ptr_eq(&src.to_mat(), &m));
        assert_eq!(src.cost_at(1, 2), 5.0);
    }

    #[test]
    fn shared_source_serves_cached_artifacts() {
        use crate::engine::{CostArtifacts, CostHandle, FormulationKey};
        let pts: Vec<Vec<f64>> = (0..6).map(|i| vec![i as f64 * 0.3]).collect();
        let eps = 0.2;
        let arts =
            CostArtifacts::for_sq_euclidean_support(&pts, eps, FormulationKey::Balanced);
        let handle = CostHandle::new(arts.clone());
        let src = CostSource::from(&handle);
        assert_eq!((src.rows(), src.cols()), (6, 6));
        assert!(Arc::ptr_eq(&src.to_mat(), &arts.cost));
        assert_eq!(src.cost_at(1, 2).to_bits(), arts.cost.get(1, 2).to_bits());
        // Matching eps serves the materialized kernel; a mismatch falls
        // back to the exact derived Gibbs value.
        assert_eq!(src.kernel_at(1, 2, eps).to_bits(), arts.kernel.get(1, 2).to_bits());
        let derived = (-arts.cost.get(1, 2) / 0.1f64).exp();
        assert_eq!(src.kernel_at(1, 2, 0.1).to_bits(), derived.to_bits());
        let a = vec![1.0 / 6.0; 6];
        let ok = OtProblem::balanced(src.clone(), a.clone(), a.clone(), eps);
        assert!(ok.validate().is_ok());
        let mut bad = ok.clone();
        bad.eps = 0.1;
        assert!(matches!(bad.validate(), Err(Error::InvalidParam(_))));
    }

    #[test]
    fn validate_catches_shape_errors() {
        let cost = Mat::zeros(3, 3);
        let ok = OtProblem::balanced(cost.clone(), vec![0.5; 3], vec![0.5; 3], 0.1);
        assert!(ok.validate().is_ok());
        let bad = OtProblem::balanced(cost.clone(), vec![0.5; 2], vec![0.5; 3], 0.1);
        assert!(bad.validate().is_err());
        let bad_eps = OtProblem::balanced(cost.clone(), vec![0.5; 3], vec![0.5; 3], 0.0);
        assert!(bad_eps.validate().is_err());
        let bad_lambda =
            OtProblem::unbalanced(cost.clone(), vec![0.5; 3], vec![0.5; 3], 0.0, 0.1);
        assert!(bad_lambda.validate().is_err());
        let bary = OtProblem::barycenter(cost, vec![vec![0.5; 3]; 2], vec![0.5, 0.5], 0.1);
        assert!(bary.validate().is_ok());
    }
}
