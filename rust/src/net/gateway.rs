//! The serve-mode gateway: a bounded TCP accept loop in front of one
//! [`DistanceService`].
//!
//! Lifecycle and admission control, in one place:
//!
//! * **Accept loop** — a single non-blocking listener thread polling at
//!   [`ACCEPT_POLL`]. Each admitted connection gets its own handler
//!   thread (connections are few and long-lived relative to jobs; the
//!   per-job fan-out happens inside the coordinator, not here).
//! * **Connection cap** — at most [`GatewayConfig::max_connections`]
//!   handlers at once; excess connections are answered `503` and closed
//!   immediately, so the cap can never wedge the listener.
//! * **Queue backpressure** — handlers submit through
//!   [`DistanceService::try_submit`]; a full coordinator queue is a
//!   `429` answered by [`super::router`], never a parked thread.
//! * **Graceful drain** — [`Gateway::drain`] stops the listener, flips
//!   the service to refuse new work, and waits for in-flight handlers
//!   (whose jobs complete normally) before returning. `Drop` drains
//!   too, so a gateway can never outlive its scope half-alive.
//!
//! Everything is std: `TcpListener` + threads, no async runtime.

use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::{CoordinatorConfig, DistanceService};
use crate::error::{Error, Result};
use crate::net::http::{read_request, HttpLimits};
use crate::net::response::Response;
use crate::net::router;
use crate::util::sync::{lock_unpoisoned, wait_timeout_unpoisoned};

/// How often the accept loop re-checks the drain flag between polls of
/// the non-blocking listener.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// Gateway tuning. `Default` binds an OS-picked loopback port — the
/// right setting for tests; the CLI overrides `addr`/`port`.
#[derive(Clone, Debug)]
pub struct GatewayConfig {
    /// Bind address (default loopback).
    pub addr: String,
    /// Bind port; `0` lets the OS pick (reported by
    /// [`Gateway::local_addr`]).
    pub port: u16,
    /// Maximum concurrently served connections; excess connections are
    /// refused with `503` instead of queueing.
    pub max_connections: usize,
    /// Parser size caps, per request.
    pub limits: HttpLimits,
    /// Socket read timeout: an idle keep-alive connection is closed
    /// after this long, so drain never waits on a silent peer.
    pub read_timeout: Duration,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            addr: "127.0.0.1".to_string(),
            port: 0,
            max_connections: 64,
            limits: HttpLimits::default(),
            read_timeout: Duration::from_secs(5),
        }
    }
}

/// Shared connection bookkeeping between the accept loop, the handler
/// threads, and `drain`.
struct Lifecycle {
    /// Set once by `drain`: the accept loop exits and handlers answer
    /// `503` to new jobs.
    draining: AtomicBool,
    /// Set by [`Gateway::begin_drain`]: handlers answer `503` to new
    /// jobs and `/healthz` reports draining, but the accept loop keeps
    /// running — the probe-visible half of a drain, so a balancer can
    /// observe the refusals instead of connection errors.
    refusing: AtomicBool,
    /// Live handler-thread count, guarded so `drain` can wait on it.
    active: Mutex<usize>,
    /// Signaled whenever a handler exits.
    idle: Condvar,
    /// Connections refused at the `max_connections` cap (diagnostics).
    rejected_at_cap: AtomicU64,
}

/// Decrements the active-connection count when a handler thread exits,
/// panic or not — `drain` must never wait on a connection that died.
struct ConnectionGuard {
    lifecycle: Arc<Lifecycle>,
}

impl Drop for ConnectionGuard {
    fn drop(&mut self) {
        let mut active = lock_unpoisoned(&self.lifecycle.active);
        *active = active.saturating_sub(1);
        drop(active);
        self.lifecycle.idle.notify_all();
    }
}

/// A running HTTP gateway over one [`DistanceService`]. See the module
/// docs for the lifecycle; construction is [`Gateway::start`].
pub struct Gateway {
    service: Arc<DistanceService>,
    lifecycle: Arc<Lifecycle>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
}

impl Gateway {
    /// Bind the listener and start the accept loop. The service is
    /// shared: in-process callers may keep submitting alongside the
    /// gateway through the same `Arc`.
    pub fn start(service: Arc<DistanceService>, config: GatewayConfig) -> Result<Gateway> {
        let listener = match TcpListener::bind((config.addr.as_str(), config.port)) {
            Ok(listener) => listener,
            Err(e) => {
                let msg = format!("gateway bind {}:{}: {e}", config.addr, config.port);
                return Err(Error::Coordinator(msg));
            }
        };
        let addr = listener
            .local_addr()
            .map_err(|e| Error::Coordinator(format!("gateway local_addr: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::Coordinator(format!("gateway set_nonblocking: {e}")))?;
        let lifecycle = Arc::new(Lifecycle {
            draining: AtomicBool::new(false),
            refusing: AtomicBool::new(false),
            active: Mutex::new(0),
            idle: Condvar::new(),
            rejected_at_cap: AtomicU64::new(0),
        });
        let accept = {
            let service = Arc::clone(&service);
            let lifecycle = Arc::clone(&lifecycle);
            let config = config.clone();
            std::thread::Builder::new()
                .name("gateway-accept".to_string())
                .spawn(move || accept_loop(listener, service, lifecycle, config))
                .map_err(|e| Error::Coordinator(format!("gateway accept thread: {e}")))?
        };
        Ok(Gateway { service, lifecycle, addr, accept: Some(accept) })
    }

    /// The bound address (resolves port `0` to the OS-picked port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connections refused at the connection cap so far.
    pub fn rejected_at_cap(&self) -> u64 {
        self.lifecycle.rejected_at_cap.load(Ordering::Relaxed)
    }

    /// Flip the gateway (and its service) into refusing mode WITHOUT
    /// stopping the accept loop: `/healthz` answers `503 draining`,
    /// new jobs are refused with `503`, and in-flight jobs still
    /// complete and deliver their responses. This is the probe-visible
    /// half of a graceful drain — a balancer in front sees refusals it
    /// can react to (evict, fail over) rather than connection errors —
    /// pinned by the fault-injection wall in
    /// `tests/balancer_integration.rs`. Call [`drain`](Self::drain)
    /// (or drop the gateway) to actually stop serving. Idempotent.
    pub fn begin_drain(&self) {
        self.lifecycle.refusing.store(true, Ordering::SeqCst);
        self.service.begin_drain();
    }

    /// Graceful drain: stop accepting, refuse new submissions, and wait
    /// for in-flight connections (their jobs complete normally).
    /// Idempotent — second and later calls return immediately.
    pub fn drain(&mut self) {
        self.lifecycle.draining.store(true, Ordering::SeqCst);
        self.service.begin_drain();
        if let Some(accept) = self.accept.take() {
            // Joining drops the listener: the OS refuses new
            // connections from here on.
            let _ = accept.join();
        }
        let mut active = lock_unpoisoned(&self.lifecycle.active);
        while *active > 0 {
            active = wait_timeout_unpoisoned(
                &self.lifecycle.idle,
                active,
                Duration::from_millis(50),
            );
        }
    }

    /// Drain, then report the service's final metrics. The service
    /// `Arc` may still be shared with in-process callers; this reads
    /// the snapshot rather than consuming the service.
    pub fn shutdown(mut self) -> crate::coordinator::MetricsSnapshot {
        self.drain();
        self.service.metrics()
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        self.drain();
    }
}

/// Stand up `n` independent backend gateways on OS-picked loopback
/// ports, each over its OWN coordinator (separate queue, workers and
/// artifact cache) built from `config` — the multi-process topology the
/// balancer fronts, inside one test or bench binary. The gateways are
/// fully isolated from one another: the only thing they share is the
/// process. Tear down by dropping (each gateway drains itself).
pub fn spawn_backends(n: usize, config: &CoordinatorConfig) -> Result<Vec<Gateway>> {
    (0..n)
        .map(|_| {
            let service = Arc::new(DistanceService::start(config.clone()));
            Gateway::start(service, GatewayConfig::default())
        })
        .collect()
}

fn accept_loop(
    listener: TcpListener,
    service: Arc<DistanceService>,
    lifecycle: Arc<Lifecycle>,
    config: GatewayConfig,
) {
    loop {
        if lifecycle.draining.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let admitted = {
                    let mut active = lock_unpoisoned(&lifecycle.active);
                    if *active >= config.max_connections {
                        false
                    } else {
                        *active += 1;
                        true
                    }
                };
                if !admitted {
                    lifecycle.rejected_at_cap.fetch_add(1, Ordering::Relaxed);
                    refuse_at_capacity(stream);
                    continue;
                }
                let guard = ConnectionGuard { lifecycle: Arc::clone(&lifecycle) };
                let service = Arc::clone(&service);
                let lifecycle = Arc::clone(&lifecycle);
                let config = config.clone();
                let spawned = std::thread::Builder::new()
                    .name("gateway-conn".to_string())
                    .spawn(move || {
                        let _guard = guard;
                        handle_connection(stream, &service, &lifecycle, &config);
                    });
                // Spawn failure drops `guard` here, releasing the slot.
                drop(spawned);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

/// Answer `503` on a connection refused at the connection cap. Best
/// effort: the peer may already be gone.
fn refuse_at_capacity(mut stream: TcpStream) {
    let _ = Response::error(503, "connection capacity reached").write_to(&mut stream);
    let _ = stream.flush();
}

/// Serve one connection: parse → route → respond, looping while the
/// client keeps the connection alive (pipelined requests included).
fn handle_connection(
    stream: TcpStream,
    service: &DistanceService,
    lifecycle: &Lifecycle,
    config: &GatewayConfig,
) {
    // Accepted sockets can inherit the listener's non-blocking flag on
    // some platforms; handlers want plain blocking reads with a
    // timeout.
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    let _ = stream.set_read_timeout(Some(config.read_timeout));
    let _ = stream.set_nodelay(true);
    let Ok(mut writer) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(stream);
    loop {
        match read_request(&mut reader, &config.limits) {
            Ok(request) => {
                let draining = lifecycle.draining.load(Ordering::SeqCst)
                    || lifecycle.refusing.load(Ordering::SeqCst);
                let response = router::handle(service, &request, draining);
                let close = response.close || !request.keep_alive();
                if response.write_to(&mut writer).is_err() || close {
                    return;
                }
            }
            Err(err) => {
                if let Some(status) = err.status() {
                    let _ = Response::error(status, &err.message()).write_to(&mut writer);
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use std::io::Read;

    #[test]
    fn capacity_zero_refuses_every_connection_with_503() {
        let service = Arc::new(DistanceService::start(CoordinatorConfig {
            workers: 1,
            shards: 1,
            ..CoordinatorConfig::default()
        }));
        let mut gateway = Gateway::start(
            Arc::clone(&service),
            GatewayConfig { max_connections: 0, ..GatewayConfig::default() },
        )
        .unwrap();
        let mut stream = TcpStream::connect(gateway.local_addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut raw = String::new();
        stream.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 503 "), "{raw}");
        assert!(gateway.rejected_at_cap() >= 1);
        gateway.drain();
        drop(gateway);
        if let Ok(service) = Arc::try_unwrap(service) {
            service.shutdown();
        }
    }
}
